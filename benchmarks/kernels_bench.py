"""Per-kernel microbench: Pallas (interpret on CPU; the TPU kernel) next to
the pure-jnp oracle, plus the int8 MXU-path variants."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.conv_im2col import conv2d_im2col
from repro.kernels.conv_dw import depthwise2d
from repro.kernels.conv_shift import shift_conv2d
from repro.kernels.conv_add import add_conv2d
from repro.kernels.conv1d_causal import causal_conv1d
from repro.kernels.matmul_q8 import matmul

from .common import emit, time_fn

KEY = jax.random.PRNGKey(0)


def main():
    x = jax.random.normal(KEY, (1, 16, 16, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 16, 16))
    us = time_fn(functools.partial(conv2d_im2col, interpret=True), x, w,
                 reps=2, warmup=1)
    us_ref = time_fn(jax.jit(lambda a, b: ref.conv2d_ref(a, b)), x, w)
    emit("kernels/conv_im2col/pallas_interpret", us, f"ref_us={us_ref:.1f}")

    xq = (x * 20).astype(jnp.int8)
    wq = (w * 10).astype(jnp.int8)
    us_q = time_fn(functools.partial(conv2d_im2col, requant_shift=6,
                                     interpret=True), xq, wq, reps=2, warmup=1)
    emit("kernels/conv_im2col/int8", us_q, "algorithm1_epilogue")

    wd = jax.random.normal(KEY, (3, 3, 16))
    emit("kernels/conv_dw/pallas_interpret",
         time_fn(functools.partial(depthwise2d, interpret=True), x, wd,
                 reps=2, warmup=1), "")

    shifts = jnp.array([[(i % 3) - 1, ((i // 3) % 3) - 1] for i in range(16)],
                       jnp.int32)
    wp = jax.random.normal(KEY, (16, 16))
    emit("kernels/conv_shift/pallas_interpret",
         time_fn(functools.partial(shift_conv2d, interpret=True), x, shifts,
                 wp, reps=2, warmup=1), "shift_fused_into_sampling")

    emit("kernels/conv_add/pallas_interpret",
         time_fn(functools.partial(add_conv2d, interpret=True, block_co=4),
                 x, w, reps=2, warmup=1), "vpu_only_no_mxu_analogue")

    xs = jax.random.normal(KEY, (2, 128, 32))
    wc = jax.random.normal(KEY, (4, 32))
    emit("kernels/conv1d_causal/pallas_interpret",
         time_fn(functools.partial(causal_conv1d, interpret=True), xs, wc,
                 reps=2, warmup=1), "mamba_hotpath")

    a = jax.random.normal(KEY, (256, 256), jnp.bfloat16)
    b = jax.random.normal(KEY, (256, 256), jnp.bfloat16)
    emit("kernels/matmul/pallas_interpret",
         time_fn(functools.partial(matmul, bm=128, bn=128, bk=128,
                                   interpret=True), a, b, reps=2, warmup=1), "")
    aq = (jax.random.normal(KEY, (256, 256)) * 30).astype(jnp.int8)
    emit("kernels/matmul_q8/pallas_interpret",
         time_fn(functools.partial(matmul, bm=128, bn=128, bk=128,
                                   requant_shift=7, interpret=True), aq, aq,
                 reps=2, warmup=1), "int8_pow2_requant")


if __name__ == "__main__":
    main()
