"""Per-kernel microbench: Pallas (interpret on CPU; the TPU kernel) next to
the pure-jnp oracle, plus the int8 MXU-path variants and a tuned-vs-default
schedule comparison (repro.tune)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import tune
from repro.kernels import ref
from repro.kernels.conv_im2col import conv2d_im2col
from repro.kernels.conv_dw import depthwise2d
from repro.kernels.conv_shift import shift_conv2d
from repro.kernels.conv_add import add_conv2d
from repro.kernels.conv1d_causal import causal_conv1d
from repro.kernels.matmul_q8 import matmul

from .common import emit, time_fn

KEY = jax.random.PRNGKey(0)


def main():
    x = jax.random.normal(KEY, (1, 16, 16, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 16, 16))
    us = time_fn(functools.partial(conv2d_im2col, interpret=True), x, w,
                 reps=2, warmup=1)
    us_ref = time_fn(jax.jit(lambda a, b: ref.conv2d_ref(a, b)), x, w)
    emit("kernels/conv_im2col/pallas_interpret", us, f"ref_us={us_ref:.1f}")

    xq = (x * 20).astype(jnp.int8)
    wq = (w * 10).astype(jnp.int8)
    us_q = time_fn(functools.partial(conv2d_im2col, requant_shift=6,
                                     interpret=True), xq, wq, reps=2, warmup=1)
    emit("kernels/conv_im2col/int8", us_q, "algorithm1_epilogue")

    wd = jax.random.normal(KEY, (3, 3, 16))
    emit("kernels/conv_dw/pallas_interpret",
         time_fn(functools.partial(depthwise2d, interpret=True), x, wd,
                 reps=2, warmup=1), "")

    shifts = jnp.array([[(i % 3) - 1, ((i // 3) % 3) - 1] for i in range(16)],
                       jnp.int32)
    wp = jax.random.normal(KEY, (16, 16))
    emit("kernels/conv_shift/pallas_interpret",
         time_fn(functools.partial(shift_conv2d, interpret=True), x, shifts,
                 wp, reps=2, warmup=1), "shift_fused_into_sampling")

    emit("kernels/conv_add/pallas_interpret",
         time_fn(functools.partial(add_conv2d, interpret=True, block_co=4),
                 x, w, reps=2, warmup=1), "vpu_only_no_mxu_analogue")

    xs = jax.random.normal(KEY, (2, 128, 32))
    wc = jax.random.normal(KEY, (4, 32))
    emit("kernels/conv1d_causal/pallas_interpret",
         time_fn(functools.partial(causal_conv1d, interpret=True), xs, wc,
                 reps=2, warmup=1), "mamba_hotpath")

    a = jax.random.normal(KEY, (256, 256), jnp.bfloat16)
    b = jax.random.normal(KEY, (256, 256), jnp.bfloat16)
    emit("kernels/matmul/pallas_interpret",
         time_fn(functools.partial(matmul, bm=128, bn=128, bk=128,
                                   interpret=True), a, b, reps=2, warmup=1), "")
    aq = (jax.random.normal(KEY, (256, 256)) * 30).astype(jnp.int8)
    emit("kernels/matmul_q8/pallas_interpret",
         time_fn(functools.partial(matmul, bm=128, bn=128, bk=128,
                                   requant_shift=7, interpret=True), aq, aq,
                 reps=2, warmup=1), "int8_pow2_requant")

    tuned_vs_default()


def tuned_vs_default():
    """Autotune a few representative shapes in-process and report how the
    measured winner compares to the hard-coded default schedule (the cache
    committed by scripts/tune.py makes these wins transparent at dispatch)."""
    xw = jax.random.normal(KEY, (1, 10, 10, 128))
    ww = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 128, 64))
    xa = jax.random.normal(KEY, (1, 16, 16, 16))
    wa = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 16, 16))
    a = jax.random.normal(KEY, (512, 512), jnp.bfloat16)
    combos = [
        # wide-channel conv: filter-block size trades weight reuse vs steps
        ("conv2d", tune.sig_conv2d(1, 10, 10, 128, 64, 3), (xw, ww)),
        # VPU add-conv: the |a-b| broadcast intermediate scales with block_co
        ("add_conv2d", tune.sig_add_conv2d(1, 16, 16, 16, 16, 3), (xa, wa)),
        # 512^3 matmul: the default 256x256 output blocking runs 4 grid
        # steps where a 512-wide block runs 1 — a real schedule gap
        ("matmul", tune.sig_matmul(512, 512, 512), (a, a)),
    ]
    for kernel, sig, args in combos:
        best, best_us, results = tune.autotune(kernel, sig, args,
                                               reps=3, warmup=1)
        default_us = next(us for cfg, us in results
                          if cfg == tune.default_config(kernel))
        emit(f"kernels/tune/{kernel}/{sig.key()}", best_us,
             f"default_us={default_us:.1f} best={best} "
             f"speedup={default_us / max(best_us, 1e-9):.2f} "
             f"tuned_beats_default={best_us < default_us}")


if __name__ == "__main__":
    main()
