"""Batched serving throughput through repro.graph (EXPERIMENTS.md §Throughput).

The tentpole claim of the batched/spatially-tiled kernel schedules: serving
images in microbatches beats the per-image loop because every image in a
batch shares the round's weight-block loads (the paper's Fig-3 data-reuse
quantity grows from Cx*BCO to N*Cx*BCO MACs per weight byte) and the
per-call dispatch overhead amortizes. Three row families per primitive:

  * ``throughput/<prim>/reuse/<node>`` — the analytic MACs/byte table: each
    conv node's per-weight-byte reuse at N=1 vs the bench batch, read off
    the tuned (or analytic-fallback) int8 schedule's effective blocks.
  * ``throughput/<prim>/batch<N>`` — batch-size sweep of delivered
    images/s through ``CompiledPlan.forward_batch`` (skipped under FAST).
  * ``throughput/<prim>/e2e`` — the acceptance row: paired-timed batched
    forward at N=8 vs the N=1 per-image loop on the SAME engine, with
    ``exact=`` flagging batched-vs-looped agreement (int8 trunk bit-exact;
    the float head compares at 1e-5, its argmax exactly).

Both sides run the xla integer oracle engine (fast under interpret-mode CI,
same engine both sides — the delta isolates batching, not pallas-vs-xla),
and the serve row drives the same plan through ``repro.serve.CNNEngine``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Primitives
from repro.graph import CompiledPlan, build_cnn_graph, lower
from repro.models.convnet import CNNConfig, init_cnn

from .common import FAST, emit

BATCH = 8


def _cfg(prim: str) -> CNNConfig:
    if FAST:
        return CNNConfig(primitive=prim, widths=(8, 12), image_size=16)
    return CNNConfig(primitive=prim, widths=(16, 32, 64), image_size=32)


def _paired_time(fn_a, fn_b, *, rounds: int = 7) -> tuple:
    """Median seconds for two thunks in interleaved A/B rounds (drift hits
    both sides equally — the batched-vs-loop ratio is the claim under
    test)."""
    fn_a(), fn_b()                       # warmup / compile both sides
    ta, tb = [], []
    for _ in range(rounds):
        t0 = time.perf_counter()
        fn_a()
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        tb.append(time.perf_counter() - t0)
    return float(np.median(ta)), float(np.median(tb))


def _reuse_rows(prim: str, plan, batch: int):
    """Fig-3 MACs-per-weight-byte table: reuse = block_n * Cx * BCO under
    the schedule the dispatch layer would run at this batch (int8)."""
    from repro import tune
    for node in plan.conv_nodes():
        spec = node.spec
        h, w = node.attrs["in_hw"]
        ci, co, hk = spec.in_channels, spec.out_channels, spec.kernel_size
        p = spec.primitive
        if p in ("standard", "grouped"):
            g = spec.groups if p == "grouped" else 1
            sig1 = tune.sig_conv2d(1, h, w, ci, co, hk, g)
            sigb = tune.sig_conv2d(batch, h, w, ci, co, hk, g)
            cx = ci // g
        elif p == "dws":                 # pointwise stage carries the reuse
            sig1 = tune.sig_conv2d(1, h, w, ci, co, 1, 1)
            sigb = tune.sig_conv2d(batch, h, w, ci, co, 1, 1)
            cx = ci
        elif p == "shift":
            sig1 = tune.sig_shift_conv2d(1, h, w, ci, co)
            sigb = tune.sig_shift_conv2d(batch, h, w, ci, co)
            cx = ci
        else:                            # add
            sig1 = tune.sig_add_conv2d(1, h, w, ci, co, hk)
            sigb = tune.sig_add_conv2d(batch, h, w, ci, co, hk)
            cx = ci
        e1 = tune.effective_config(sig1, tune.get_config(sig1, "int8"))
        eb = tune.effective_config(sigb, tune.get_config(sigb, "int8"))
        bco_key = "block_co" if "block_co" in e1 else "block_c"
        r1 = cx * e1[bco_key]
        rb = eb["block_n"] * cx * eb[bco_key]
        emit(f"throughput/{prim}/reuse/{node.name}", 0.0,
             f"macs={node.spec.mac_count(w)};macs_per_wbyte_n1={r1};"
             f"macs_per_wbyte_n{batch}={rb};reuse_gain={rb / max(r1, 1):.1f}x")


def main() -> None:
    for prim in Primitives:
        cfg = _cfg(prim)
        params = init_cnn(cfg, jax.random.PRNGKey(0))
        shape = (cfg.image_size, cfg.image_size, cfg.in_channels)
        calib = jax.random.normal(jax.random.PRNGKey(1), (4,) + shape) * 0.5
        plan = lower(build_cnn_graph(cfg), params, calib)
        ex = CompiledPlan(plan, method="xla")
        _reuse_rows(prim, plan, BATCH)

        x = jax.random.normal(jax.random.PRNGKey(2), (BATCH,) + shape) * 0.5

        # exact flag: batched == per-image loop (int8 trunk is bit-exact by
        # construction; the float gap->dense head is compared at 1e-5 and
        # by argmax, since XLA picks batch-size-dependent matmul kernels)
        batched = np.asarray(ex.forward_batch(x))
        looped = np.concatenate([np.asarray(ex(x[i:i + 1]))
                                 for i in range(BATCH)])
        exact = int(np.allclose(batched, looped, rtol=1e-5, atol=1e-6)
                    and (batched.argmax(-1) == looped.argmax(-1)).all())
        if not exact:                    # run.py reports a section failure
            raise RuntimeError(
                f"throughput/{prim}: batched forward diverged from the "
                "per-image loop — the batched kernel schedule is not exact")

        if not FAST:
            for n in (1, 2, 4, BATCH, 2 * BATCH):
                tp = ex.throughput(x[:1].repeat(n, 0), reps=3, warmup=1)
                emit(f"throughput/{prim}/batch{n}", tp["us_per_batch"],
                     f"images_per_s={tp['images_per_s']:.0f};"
                     f"us_per_image={tp['us_per_image']:.1f}")

        def run_batched():
            jax.block_until_ready(ex.forward_batch(x))

        def run_loop():
            for i in range(BATCH):
                jax.block_until_ready(ex(x[i:i + 1]))

        tb, tl = _paired_time(run_batched, run_loop)
        ips_b, ips_l = BATCH / tb, BATCH / tl
        emit(f"throughput/{prim}/e2e", tb * 1e6,
             f"loop_us={tl * 1e6:.1f};images_per_s={ips_b:.0f};"
             f"loop_images_per_s={ips_l:.0f};speedup={ips_b / ips_l:.2f}x;"
             f"exact={exact}")

    # serve wiring: the same plan behind the CNNEngine microbatcher
    from repro.serve import CNNEngine, CNNServeConfig, ImageRequest
    cfg = _cfg("standard")
    params = init_cnn(cfg, jax.random.PRNGKey(0))
    shape = (cfg.image_size, cfg.image_size, cfg.in_channels)
    calib = jax.random.normal(jax.random.PRNGKey(1), (4,) + shape) * 0.5
    plan = lower(build_cnn_graph(cfg), params, calib)
    ex = CompiledPlan(plan, method="xla")
    eng = CNNEngine(ex, CNNServeConfig(max_batch=BATCH))
    n_req = 2 * BATCH + 3                # ragged final round
    rng = np.random.default_rng(0)
    # warm both batch buckets the drain will hit (BATCH and the ragged
    # round's pow2 bucket), then zero the counters: the row reports
    # steady-state serving throughput, not jit compilation
    warm = rng.normal(size=(n_req % BATCH,) + shape).astype(np.float32)
    jax.block_until_ready(ex.forward_batch(np.zeros((BATCH,) + shape,
                                                    np.float32)))
    jax.block_until_ready(ex.forward_batch(warm))
    eng.reset_stats()
    for uid in range(n_req):
        eng.submit(ImageRequest(uid, rng.normal(size=shape).astype(np.float32)
                                * 0.5))
    done = eng.run_until_drained()
    s = eng.stats
    assert len(done) == n_req and all(r.done for r in done)
    emit("throughput/serve/engine", 1e6 * s["images_done"]
         / max(s["images_per_s"], 1e-9) / max(s["batch_rounds"], 1),
         f"images={s['images_done']};rounds={s['batch_rounds']};"
         f"occupancy={s['occupancy']:.2f};"
         f"images_per_s={s['images_per_s']:.0f}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
