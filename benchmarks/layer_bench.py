"""Per-layer cost attribution through repro.graph (EXPERIMENTS.md §Per-layer).

The paper reads its Table 2 per LAYER, not per network — "Not All Ops Are
Created Equal!" is the motivating citation — so this section lowers one CNN
per primitive and emits the plan's per-node breakdown from
``CompiledPlan.profile``: measured latency, analytic MACs, and the
paper-calibrated MCU latency/energy model (scalar vs SIMD, 84 MHz).

It then times the same plan end to end twice:

  * **fused**     — the single-jit integer executor (int8 activations
    through ReLU+pool, requantization chained into the kernel epilogues);
  * **unfused**   — ``repro.graph.unfused_forward``: the pre-graph
    float-bounce regime (dequantize -> float ReLU/pool -> requantize per
    block) at the same scales, also jitted end to end.

Both run the same integer conv arithmetic and are bit-exact (reported as
``exact=``); fused does strictly less work, so ``fused_us <= unfused_us``
is the expected shape of the result.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import Primitives
from repro.graph import CompiledPlan, build_cnn_graph, lower, unfused_forward
from repro.models.convnet import CNNConfig, init_cnn

from .common import FAST, emit, time_fn


def _paired_time(fn_a, fn_b, x, *, rounds: int = 11) -> tuple:
    """Median microseconds for two jitted fns, measured in interleaved
    A/B rounds so slow drift in background load hits both sides equally —
    the e2e fused-vs-unfused delta is the claim under test, so it must not
    be an artifact of when each side happened to run."""
    import time

    import numpy as np
    jax.block_until_ready(fn_a(x))
    jax.block_until_ready(fn_b(x))
    ta, tb = [], []
    for _ in range(rounds):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(x))
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(x))
        tb.append(time.perf_counter() - t0)
    return float(np.median(ta) * 1e6), float(np.median(tb) * 1e6)


def _cfg(prim: str) -> CNNConfig:
    if FAST:
        return CNNConfig(primitive=prim, widths=(8, 12), image_size=16)
    return CNNConfig(primitive=prim, widths=(16, 32, 64), image_size=32)


def main() -> None:
    batch = 2 if FAST else 4
    for prim in Primitives:
        cfg = _cfg(prim)
        key = jax.random.PRNGKey(0)
        params = init_cnn(cfg, key)
        calib = jax.random.normal(jax.random.PRNGKey(1),
                                  (batch, cfg.image_size, cfg.image_size,
                                   cfg.in_channels)) * 0.5
        x = jax.random.normal(jax.random.PRNGKey(2), calib.shape) * 0.5

        plan = lower(build_cnn_graph(cfg), params, calib)
        ex = CompiledPlan(plan, method="auto")

        total_macs = sum(n.spec.mac_count(n.attrs["in_hw"][1])
                         for n in plan.conv_nodes())
        for row in ex.profile(x):
            derived = f"op={row['op']};macs={row['macs']}"
            if row["op"] == "qconv":
                derived += (f";mac_share={row['macs'] / total_macs:.3f}"
                            f";mcu_lat_scalar_ms={row['mcu_lat_scalar_ms']:.3f}"
                            f";mcu_lat_simd_ms={row['mcu_lat_simd_ms']:.3f}"
                            f";mcu_e_scalar_mj={row['mcu_e_scalar_mj']:.4f}"
                            f";mcu_e_simd_mj={row['mcu_e_simd_mj']:.4f}")
            emit(f"layers/{prim}/{row['name']}", row["us"], derived)

        # e2e comparison runs both regimes on the SAME engine (the oracle:
        # fast everywhere, incl. interpret-mode CI) so the delta isolates
        # the fusion, not pallas-vs-xla; a serving-sized batch keeps the
        # removed per-block float bounce above timing noise
        xl = jax.random.normal(jax.random.PRNGKey(3),
                               (16 if FAST else 32,) + x.shape[1:]) * 0.5
        fused = CompiledPlan(plan, method="xla")._fn
        unfused = jax.jit(lambda v: unfused_forward(plan, v, method="xla"))
        exact = int(bool(jnp.all(jnp.isclose(fused(xl), unfused(xl),
                                             rtol=1e-6, atol=1e-6))))
        if not exact:    # run.py reports this as a section failure
            raise RuntimeError(
                f"layers/{prim}: fused executor diverged from the unfused "
                "float-bounce reference — the fusion pass is no longer exact")
        fused_us, unfused_us = _paired_time(fused, unfused, xl)
        emit(f"layers/{prim}/e2e", fused_us,
             f"unfused_us={unfused_us:.1f};"
             f"fused_over_unfused={fused_us / max(unfused_us, 1e-9):.3f};"
             f"exact={exact}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
