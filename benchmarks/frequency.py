"""Paper Table 3 / Fig 4: MCU frequency sweep. P(f) = P_static + k*f is
calibrated to the paper's measured mW; the model reproduces the paper's
conclusion that max frequency minimizes energy per inference."""
from __future__ import annotations

from repro.core import ConvSpec, MCUModel

from .common import emit


def main():
    mcu = MCUModel()
    # paper §4.2 fixed layer: groups 2, k3, width 32, cin 3, cout 32
    spec = ConvSpec(primitive="standard", in_channels=3, out_channels=32,
                    kernel_size=3, use_bias=False)
    for simd in (False, True):
        tag = "simd" if simd else "no_simd"
        energies = []
        for f in (10, 20, 40, 80):
            p = mcu.power_mw(simd=simd, f_mhz=f)
            lat = mcu.latency_s(spec, 32, simd=simd, f_mhz=f)
            e = mcu.energy_mj(spec, 32, simd=simd, f_mhz=f)
            energies.append(e)
            emit(f"table3/{tag}/f={f}MHz", lat * 1e6,
                 f"power_mW={p:.2f} energy_mJ={e:.3f}")
        emit(f"table3/{tag}/claim_max_freq_lowest_energy", 0.0,
             f"{energies[-1] == min(energies)}")


if __name__ == "__main__":
    main()
