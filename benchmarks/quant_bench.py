"""Paper Tables 3-4 quantized counterpart: integer-only latency + energy per
primitive (see EXPERIMENTS.md §Quantized and §Sub-byte).

Three engines per Table-2 sweep shape, all running the SAME Algorithm-1
arithmetic where quantized:

  * pallas-int8 — ``qconv_apply(method="pallas")``: fused int8 kernels with
    shift-requantized epilogues, the TPU analogue of the paper's CMSIS-NN
    SIMD build (Table 4's "with SIMD" column);
  * xla-int8    — ``qconv_apply(method="xla")``: the jnp integer oracle,
    the direct / no-SIMD baseline (bit-exact with pallas-int8 — asserted
    per row and reported as ``exact=``);
  * float       — the float reference primitive.

Each shape also gets a ``quant_w4/...`` row: the same layer with its
weights nibble-packed to W4 (``quantize_conv_params(bits=4)``, two int4
codes per byte + per-group shift scales). ``exact=`` there asserts the
triple contract pallas == xla == expanded-int8 oracle (packing changes
data movement, never arithmetic) and the ``w*_wbytes`` fields report the
weight bytes a decode step moves — W4 must be ~half of W8 modulo the
group-shift sideband (``±`` packing overhead).

``derived`` also carries the paper-side model quantities from
``core/energy.py`` (MCU @ 84 MHz, constants calibrated to paper Table 3):
theoretical MACs, modeled scalar vs SIMD energy (mJ) and their ratio —
the MACs<->energy linearity the paper validates holds per construction for
the scalar column; the SIMD column tracks data movement instead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ConvSpec, MCUModel, apply, init
from repro.core.qconv import qconv_apply, quantize_conv_params
from repro.core.quantize import QTensor, QTensorW4, frac_bits_for, quantize

from .common import FAST, emit, time_fn

# Table-2 sweep points: the center cell per primitive plus the structural
# extremes the paper sweeps (groups / kernel / cin). FAST trims to the five
# center cells at a smaller width.
def _shapes():
    w = 16 if FAST else 32
    pts = [
        ("standard", ConvSpec("standard", 16, 16, 3), w),
        ("grouped", ConvSpec("grouped", 16, 16, 3, groups=2), w),
        ("dws", ConvSpec("dws", 16, 16, 3), w),
        ("shift", ConvSpec("shift", 16, 16, 3), w),
        ("add", ConvSpec("add", 16, 16, 3), 8 if FAST else 10),
    ]
    if not FAST:
        pts += [
            ("standard_cin128", ConvSpec("standard", 128, 64, 3), 10),
            ("grouped_g4", ConvSpec("grouped", 128, 64, 3, groups=4), 10),
            ("standard_k7", ConvSpec("standard", 16, 16, 7), w),
        ]
    return pts


def main() -> None:
    mcu = MCUModel()
    key = jax.random.PRNGKey(0)
    for name, spec, width in _shapes():
        params = init(key, spec)
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (1, width, width, spec.in_channels)) * 0.5

        f_float = jax.jit(lambda xx, p=params, s=spec: apply(p, xx, s))
        float_us = time_fn(f_float, x)

        yf = f_float(x)
        ofb = frac_bits_for(yf)
        qp = quantize_conv_params(params, spec)
        xq = quantize(x)

        def int_fn(method):
            fb = xq.frac_bits
            return jax.jit(lambda q, m=method, s=spec, o=ofb, qq=qp:
                           qconv_apply(qq, QTensor(q, fb), s, o, method=m).q)

        f_pallas, f_xla = int_fn("pallas"), int_fn("xla")
        exact = int(bool(jnp.all(f_pallas(xq.q) == f_xla(xq.q))))
        if not exact:   # the run.py harness reports this as a section failure
            raise RuntimeError(
                f"quant/{name}: pallas-int8 diverged from xla-int8 — the "
                "shared apply_requant epilogue contract is broken")
        pallas_us = time_fn(f_pallas, xq.q)
        xla_us = time_fn(f_xla, xq.q)

        macs = spec.mac_count(width)
        e_scalar = mcu.energy_mj(spec, width, simd=False)
        e_simd = mcu.energy_mj(spec, width, simd=True)
        emit(f"quant/{name}/w={width}", pallas_us,
             f"xla_int8_us={xla_us:.1f};float_us={float_us:.1f};"
             f"exact={exact};macs={macs};"
             f"mcu_e_scalar_mj={e_scalar:.3f};mcu_e_simd_mj={e_simd:.3f};"
             f"mcu_e_ratio={e_scalar / max(e_simd, 1e-12):.2f}")

        # ---- W4A8 row: same layer, nibble-packed weights -----------------
        qp4 = quantize_conv_params(params, spec, bits=4)
        qp4x = {k: QTensor(v.expand(), v.frac_bits)
                if isinstance(v, QTensorW4) else v for k, v in qp4.items()}

        def w4_fn(method, qq):
            fb = xq.frac_bits
            return jax.jit(lambda q, m=method, s=spec, o=ofb, p=qq:
                           qconv_apply(p, QTensor(q, fb), s, o, method=m).q)

        f4_pallas, f4_xla = w4_fn("pallas", qp4), w4_fn("xla", qp4)
        f4_oracle = w4_fn("pallas", qp4x)       # unpacked-int8 oracle codes
        y4 = f4_pallas(xq.q)
        exact4 = int(bool(jnp.all(y4 == f4_xla(xq.q))
                          & jnp.all(y4 == f4_oracle(xq.q))))
        if not exact4:
            raise RuntimeError(
                f"quant_w4/{name}: W4 path diverged from the unpacked-int8 "
                "oracle — the in-register unpack changed arithmetic")
        w4_us = time_fn(f4_pallas, xq.q)
        # weight bytes one forward moves: packed nibbles + shift sideband
        # vs the int8 codes (biases identical, excluded from both)
        w8b = sum(v.q.size for k, v in qp.items()
                  if k.startswith("w") and isinstance(v, QTensor))
        w4b = sum(v.q.size + v.shifts.size for v in qp4.values()
                  if isinstance(v, QTensorW4))
        emit(f"quant_w4/{name}/w={width}", w4_us,
             f"int8_us={pallas_us:.1f};exact={exact4};"
             f"w8_wbytes={w8b};w4_wbytes={w4b};"
             f"wbytes_ratio={w4b / max(w8b, 1):.2f};"
             f"mcu_e_simd_mj={e_simd:.3f};macs={macs}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
