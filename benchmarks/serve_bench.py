"""§Serving benchmark: static-drain vs continuous slot scheduling.

Workload: fixed-length prompts with SKEWED ``max_new_tokens`` (one long
request per ``max_batch`` group, interleaved) — the regime where a static
batch drains at the pace of its slowest member while continuous batching
keeps retiring short sequences and refilling their slots. Prompt lengths
are fixed so both schedulers compile the same prefill shape and the
comparison isolates scheduling, not jit caching.

Emits (EXPERIMENTS.md §Serving):
  serve/static,<us/token>,tok_s=...;occupancy=...;ttft_ms=...;rounds=...
  serve/continuous,<us/token>,...
  serve/speedup,0.0,continuous_over_static=<x>

Both engines are compile-warmed on a small drain and their stats reset
before the timed run. REPRO_BENCH_FAST=1 shrinks the workload for CI.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.serve import Engine, Request, ServeConfig

from .common import FAST, emit

MAX_BATCH, MAX_LEN, PLEN = 4, 64, 8


def tiny_cfg():
    return dataclasses.replace(
        get_config("qwen2-0.5b"), n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256)


def workload(n: int, seed: int, long_new: int, short_new: int):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        reqs.append(Request(
            uid=i, prompt=rng.integers(0, 256, (PLEN,)).astype(np.int32),
            # one long request per max_batch group: each static batch stalls
            # on it while its short siblings' slots sit retired-but-held
            max_new_tokens=long_new if i % MAX_BATCH == 0 else short_new))
    return reqs


def run_sched(scheduler: str, cfg, params, n, long_new, short_new):
    eng = Engine(cfg, params, ServeConfig(
        max_batch=MAX_BATCH, max_len=MAX_LEN, scheduler=scheduler,
        prefill_bucket=PLEN))
    for r in workload(MAX_BATCH, seed=99, long_new=2, short_new=2):
        eng.submit(r)                   # compile warmup: prefill + decode
    eng.run_until_drained()
    eng.reset_stats()
    reqs = workload(n, seed=0, long_new=long_new, short_new=short_new)
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    assert len(done) == n and toks == sum(r.max_new_tokens for r in reqs)
    return toks / dt, toks, dt, eng.stats


def main():
    cfg = tiny_cfg()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    n = 8 if FAST else 16
    long_new, short_new = (16, 4) if FAST else (32, 4)
    tok_s = {}
    for sched in ("static", "continuous"):
        tok_s[sched], toks, dt, st = run_sched(
            sched, cfg, params, n, long_new, short_new)
        emit(f"serve/{sched}", dt * 1e6 / max(toks, 1),
             f"tok_s={tok_s[sched]:.1f};occupancy={st['occupancy']:.2f};"
             f"ttft_ms={st['ttft_avg_s'] * 1e3:.1f};rounds={st['decode_steps']}")
    emit("serve/speedup", 0.0,
         f"continuous_over_static={tok_s['continuous'] / tok_s['static']:.2f}x")


if __name__ == "__main__":
    main()
