"""§Serving benchmark: static-drain vs continuous slot scheduling, plus
paged-vs-contiguous KV backing on a shared-prefix workload.

Workload 1 (scheduling): fixed-length prompts with SKEWED
``max_new_tokens`` (one long request per ``max_batch`` group, interleaved)
— the regime where a static batch drains at the pace of its slowest member
while continuous batching keeps retiring short sequences and refilling
their slots. Prompt lengths are fixed so both schedulers compile the same
prefill shape and the comparison isolates scheduling, not jit caching.

Workload 2 (paged KV, EXPERIMENTS.md §Paged-KV): every prompt shares one
long prefix (a system prompt) followed by a short unique suffix. The two
engines get the SAME KV byte budget — contiguous spends it on max_batch
fixed (max_len,) slots; paged spends it on a page pool, which (a) fits
~2x the concurrent requests because resident bytes track actual lengths,
and (b) serves prefix hits by prefilling only the suffix. The paired run
asserts bit-identical greedy streams (exact=1 in the gain row — the
perf gate's exactness guard), mean-concurrency ratio >= 1.5x, and lower
mean TTFT for paged.

Workload 3 (resilience, EXPERIMENTS.md §Resilience): the scheduling
workload drained twice on identically configured engines — once clean,
once under a fixed deterministic FaultPlan (injected decode/prefill
raises absorbed by bounded retries) with a capped queue shedding the
overflow (``shed_policy="drop"``). The row's ``exact=1`` only survives
if every non-shed request retires ``status="ok"`` with a token stream
bit-identical to the clean drain; ``degraded_ratio`` is the throughput
the faulted engine retained (faulted tok/s over clean tok/s).

Emits:
  serve/static,<us/token>,tok_s=...;occupancy=...;ttft_ms=...;rounds=...
  serve/continuous,<us/token>,...
  serve/speedup,0.0,continuous_over_static=<x>
  serve/prefix/contiguous,<us/token>,tok_s=...;conc=...;ttft_ms=...
  serve/prefix/paged,<us/token>,tok_s=...;conc=...;ttft_ms=...;hit_rate=...
  serve/prefix/gain,0.0,concurrent_ratio=...;ttft_speedup=...;exact=1
  serve/resilience,<us/token>,tok_s=...;degraded_ratio=...;shed_rate=...;
      retries=...;errors=...;exact=1

Engines are compile-warmed on a small drain and their stats reset before
the timed run. REPRO_BENCH_FAST=1 shrinks the workloads for CI.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.serve import Engine, Request, ServeConfig

from .common import FAST, emit

MAX_BATCH, MAX_LEN, PLEN = 4, 64, 8
# §Paged-KV workload: 32-token shared system prefix + 8-token unique
# suffix, 8 greedy tokens each; 8-position pages so the prefix spans 4
# hashable full blocks ((plen-1)//bs caps at 4 — the last prompt token is
# always recomputed for first-position logits)
SYS_LEN, SFX_LEN, PFX_NEW, PFX_BS = 32, 8, 8, 8


def tiny_cfg():
    return dataclasses.replace(
        get_config("qwen2-0.5b"), n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256)


def workload(n: int, seed: int, long_new: int, short_new: int):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        reqs.append(Request(
            uid=i, prompt=rng.integers(0, 256, (PLEN,)).astype(np.int32),
            # one long request per max_batch group: each static batch stalls
            # on it while its short siblings' slots sit retired-but-held
            max_new_tokens=long_new if i % MAX_BATCH == 0 else short_new))
    return reqs


def run_sched(scheduler: str, cfg, params, n, long_new, short_new):
    eng = Engine(cfg, params, ServeConfig(
        max_batch=MAX_BATCH, max_len=MAX_LEN, scheduler=scheduler,
        prefill_bucket=PLEN))
    for r in workload(MAX_BATCH, seed=99, long_new=2, short_new=2):
        eng.submit(r)                   # compile warmup: prefill + decode
    eng.run_until_drained()
    eng.reset_stats()
    reqs = workload(n, seed=0, long_new=long_new, short_new=short_new)
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    assert len(done) == n and toks == sum(r.max_new_tokens for r in reqs)
    return toks / dt, toks, dt, eng.stats


def prefix_workload(n: int, seed: int, max_new: int):
    """n requests sharing one SYS_LEN-token prefix, unique SFX_LEN suffixes."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, 256, (SYS_LEN,)).astype(np.int32)
    return [Request(
        uid=i,
        prompt=np.concatenate(
            [sys_prompt, rng.integers(0, 256, (SFX_LEN,)).astype(np.int32)]),
        max_new_tokens=max_new) for i in range(n)]


def run_prefix(kv_layout: str, cfg, params, n: int):
    """Drain the shared-prefix workload under one KV layout.

    Both layouts get the same KV byte budget: contiguous holds MAX_BATCH
    slots of MAX_LEN positions; paged holds the equivalent pool
    (MAX_BATCH * MAX_LEN // PFX_BS usable pages + the garbage page) but
    offers 2x the slots — paged requests only pin pages for positions they
    actually occupy, so more of them fit in the same bytes.
    """
    if kv_layout == "paged":
        scfg = ServeConfig(
            max_batch=2 * MAX_BATCH, max_len=MAX_LEN, scheduler="continuous",
            prefill_bucket=PLEN, kv_layout="paged", kv_block_size=PFX_BS,
            kv_num_blocks=MAX_BATCH * MAX_LEN // PFX_BS + 1)
    else:
        scfg = ServeConfig(max_batch=MAX_BATCH, max_len=MAX_LEN,
                           scheduler="continuous", prefill_bucket=PLEN)
    eng = Engine(cfg, params, scfg)
    # warmup compiles every shape the timed run hits: full-prompt prefill
    # (the miss), suffix-only prefill + page gather (the hits), paged
    # decode, and the page-boundary growth at position SYS_LEN + SFX_LEN
    for r in prefix_workload(MAX_BATCH, seed=99, max_new=2):
        eng.submit(r)
    eng.run_until_drained()
    eng.reset_stats()
    reqs = prefix_workload(n, seed=0, max_new=PFX_NEW)
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    assert len(done) == n and toks == n * PFX_NEW
    st = eng.stats
    # mean resident requests per decode round — the concurrency the budget
    # actually bought (occupancy is normalised by each engine's own slots)
    conc = st["occupancy"] * scfg.max_batch
    streams = {r.uid: tuple(r.out_tokens) for r in done}
    return dict(tok_s=toks / dt, toks=toks, dt=dt, st=st, conc=conc,
                streams=streams)


def run_resilience(cfg, params, n: int):
    """§Resilience: clean vs faulted drain of the same capped-queue
    workload. Returns the emit payload fields; asserts the degradation
    contract (all non-shed ok, survivor streams bit-identical)."""
    from repro.faults import FaultPlan, FaultSpec, inject

    mq = max(MAX_BATCH, n - max(n // 4, 1))     # shed the overflow tail
    scfg = ServeConfig(max_batch=MAX_BATCH, max_len=MAX_LEN,
                       prefill_bucket=PLEN, max_queue=mq,
                       shed_policy="drop")

    def drain(fault_plan):
        eng = Engine(cfg, params, scfg)
        for r in workload(MAX_BATCH, seed=99, long_new=2, short_new=2):
            eng.submit(r)               # compile warmup
        eng.run_until_drained()
        eng.reset_stats()
        reqs = workload(n, seed=0, long_new=8, short_new=4)
        t0 = time.perf_counter()
        if fault_plan is None:
            for r in reqs:
                eng.submit(r)
            done = eng.run_until_drained()
        else:
            fault_plan.reset()
            with fault_plan:
                for r in reqs:
                    eng.submit(r)
                done = eng.run_until_drained()
        dt = time.perf_counter() - t0
        toks = sum(len(r.out_tokens) for r in done)
        return reqs, done, toks, dt, eng

    assert inject.active_plan() is None, \
        "serve_bench owns its fault schedule; unset REPRO_FAULTS"
    clean_reqs, _, clean_toks, clean_dt, _ = drain(None)
    # deterministic schedule: one decode round and one prefill attempt
    # fail transiently — both inside the bounded-retry budget
    plan = FaultPlan([
        FaultSpec(site="engine.decode_round", kind="raise", nth=2, times=2),
        FaultSpec(site="engine.prefill", kind="raise", nth=3, times=1),
    ], seed=0)
    reqs, done, toks, dt, eng = drain(plan)

    base = {r.uid: list(r.out_tokens) for r in clean_reqs
            if r.status == "ok"}
    shed = [r for r in reqs if r.status == "shed"]
    ok = [r for r in reqs if r.status == "ok"]
    exact = (len(ok) + len(shed) == n and len(shed) == n - mq
             and all(list(r.out_tokens) == base.get(r.uid) for r in ok)
             and len(plan.log) == 3)
    st = eng.stats
    return dict(
        tok_s=toks / dt, toks=toks, dt=dt,
        degraded_ratio=(toks / dt) / (clean_toks / clean_dt),
        shed_rate=len(shed) / n, retries=st["retries"],
        errors=st["errors"], exact=int(exact))


def main():
    cfg = tiny_cfg()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    n = 8 if FAST else 16
    long_new, short_new = (16, 4) if FAST else (32, 4)
    tok_s = {}
    for sched in ("static", "continuous"):
        tok_s[sched], toks, dt, st = run_sched(
            sched, cfg, params, n, long_new, short_new)
        emit(f"serve/{sched}", dt * 1e6 / max(toks, 1),
             f"tok_s={tok_s[sched]:.1f};occupancy={st['occupancy']:.2f};"
             f"ttft_ms={st['ttft_avg_s'] * 1e3:.1f};rounds={st['decode_steps']}")
    emit("serve/speedup", 0.0,
         f"continuous_over_static={tok_s['continuous'] / tok_s['static']:.2f}x")

    # §Paged-KV: budget-matched shared-prefix comparison. The gain row's
    # exact=1 is the perf gate's exactness guard — it only survives if the
    # paged greedy streams stay bit-identical to contiguous.
    n_pfx = 8 if FAST else 16
    res = {lay: run_prefix(lay, cfg, params, n_pfx)
           for lay in ("contiguous", "paged")}
    assert res["paged"]["streams"] == res["contiguous"]["streams"], \
        "paged greedy streams diverged from contiguous"
    conc_ratio = res["paged"]["conc"] / res["contiguous"]["conc"]
    ttft_speedup = (res["contiguous"]["st"]["ttft_avg_s"]
                    / max(res["paged"]["st"]["ttft_avg_s"], 1e-9))
    assert conc_ratio >= 1.5, \
        f"paged concurrency {conc_ratio:.2f}x under the 1.5x budget claim"
    assert ttft_speedup > 1.0, \
        f"prefix hits did not lower mean TTFT ({ttft_speedup:.2f}x)"
    for lay in ("contiguous", "paged"):
        r = res[lay]
        extra = (f"tok_s={r['tok_s']:.1f};conc={r['conc']:.2f};"
                 f"ttft_ms={r['st']['ttft_avg_s'] * 1e3:.1f}")
        if lay == "paged":
            extra += f";hit_rate={r['st']['prefix_hit_rate']:.2f}"
        emit(f"serve/prefix/{lay}", r["dt"] * 1e6 / max(r["toks"], 1), extra)
    emit("serve/prefix/gain", 0.0,
         f"paged_prefix_toks={res['paged']['tok_s']:.1f};"
         f"concurrent_ratio={conc_ratio:.2f};ttft_speedup={ttft_speedup:.2f};"
         f"exact=1")

    # §Resilience: the degradation contract under a deterministic fault
    # schedule — exact=1 is mandatory (the perf gate rejects its absence)
    r = run_resilience(cfg, params, n)
    assert r["exact"] == 1, "faulted drain broke the degradation contract"
    emit("serve/resilience", r["dt"] * 1e6 / max(r["toks"], 1),
         f"tok_s={r['tok_s']:.1f};degraded_ratio={r['degraded_ratio']:.2f};"
         f"shed_rate={r['shed_rate']:.2f};retries={r['retries']};"
         f"errors={r['errors']};exact={r['exact']}")


if __name__ == "__main__":
    main()
