"""Paper Fig 2: per-primitive sweeps over groups / kernel / width / channels
/ filters (Table 2 plan), measuring

  * theoretical MACs (Table 1 formulas),
  * measured CPU latency of the DIRECT path (scalar analogue: explicit
    shifted-multiply accumulation, no matrix engine) vs the IM2COL/engine
    path (lax.conv -> Eigen im2col+GEMM; the TPU analogue is the MXU
    Pallas kernel, benchmarked in optlevel.py),
  * modeled MCU latency & energy with/without SIMD (core/energy, constants
    calibrated to the paper's Table 3),

and reproducing the paper's regression claims:
  (a) no-SIMD: MACs <-> energy is linear (r~0.999),
  (b) SIMD: latency predicts energy better than MACs do (Fig 2 d/e).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ConvSpec, MCUModel, init, apply
from repro.core.primitives import shift_channels, add_conv

from .common import FAST, emit, r_squared, time_fn

EXPERIMENTS = {
    # name: (sweep_param, values, fixed)
    "exp1_groups": ("groups", [1, 2, 4, 8] if FAST else [1, 2, 4, 8, 16, 32],
                    dict(kernel_size=3, width=10, cin=128, cout=64)),
    "exp2_kernel": ("kernel_size", [1, 3, 5] if FAST else [1, 3, 5, 7, 9, 11],
                    dict(groups=2, width=32, cin=16, cout=16)),
    "exp3_width": ("width", [8, 16] if FAST else [8, 16, 24, 32],
                   dict(groups=2, kernel_size=3, cin=16, cout=16)),
    "exp4_cin": ("cin", [4, 16] if FAST else [4, 8, 16, 32],
                 dict(groups=2, kernel_size=3, width=32, cout=16)),
    "exp5_cout": ("cout", [4, 16] if FAST else [4, 8, 16, 32],
                  dict(groups=2, kernel_size=3, width=32, cin=16)),
}

PRIMS = ("standard", "grouped", "dws", "shift", "add")


def direct_forward(params, x, spec: ConvSpec):
    """Scalar-path analogue: explicit shifted multiply-accumulate, no dot."""
    hk = spec.kernel_size
    ph, pw = hk // 2, (hk - 1) // 2

    def conv_direct(xx, w):
        cx, cy = w.shape[2], w.shape[3]
        xp = jnp.pad(xx, ((0, 0), (ph, pw), (ph, pw), (0, 0)))
        h = xx.shape[1]
        out = jnp.zeros(xx.shape[:3] + (cy,), xx.dtype)
        for i in range(hk):
            for j in range(hk):
                patch = xp[:, i:i + h, j:j + h, :]
                out = out + jnp.sum(patch[..., None] * w[i, j][None, None, None],
                                    axis=3)
        return out

    p = spec.primitive
    if p == "standard":
        return conv_direct(x, params["w"])
    if p == "grouped":
        cg = spec.in_channels // spec.groups
        outs = []
        per = spec.out_channels // spec.groups
        for g in range(spec.groups):
            outs.append(conv_direct(x[..., g * cg:(g + 1) * cg],
                                    params["w"][..., g * per:(g + 1) * per]))
        return jnp.concatenate(outs, axis=-1)
    if p == "dws":
        h = jnp.zeros_like(x)
        xp = jnp.pad(x, ((0, 0), (ph, pw), (ph, pw), (0, 0)))
        for i in range(hk):
            for j in range(hk):
                h = h + xp[:, i:i + x.shape[1], j:j + x.shape[2], :] \
                    * params["w_dw"][i, j, :, 0][None, None, None]
        return jnp.sum(h[..., None] * params["w_pw"][0, 0][None, None, None],
                       axis=3)
    if p == "shift":
        s = shift_channels(x, params["shifts"],
                           max_shift=spec.kernel_size // 2)
        return jnp.sum(s[..., None] * params["w_pw"][0, 0][None, None, None],
                       axis=3)
    if p == "add":
        return add_conv(x, params["w"])
    raise ValueError(p)


def spec_for(prim, kernel_size, cin, cout, groups):
    g = groups if prim == "grouped" else 1
    while cin % g or cout % g:
        g //= 2
    return ConvSpec(primitive=prim, in_channels=cin, out_channels=cout,
                    kernel_size=1 if prim in () else kernel_size,
                    groups=max(g, 1), use_bias=False)


def main():
    mcu = MCUModel()
    rows = []
    key = jax.random.PRNGKey(0)
    for exp_name, (pname, values, fixed) in EXPERIMENTS.items():
        for prim in PRIMS:
            for v in values:
                cfg = dict(fixed)
                cfg[pname] = v
                spec = spec_for(prim, cfg["kernel_size"], cfg["cin"],
                                cfg["cout"], cfg.get("groups", 1))
                width = cfg["width"]
                params = init(key, spec)
                x = jax.random.normal(key, (1, width, width, spec.in_channels))
                f_direct = jax.jit(functools.partial(direct_forward, spec=spec))
                f_engine = jax.jit(functools.partial(apply, spec=spec))
                us_d = time_fn(f_direct, params, x, reps=3, warmup=1)
                us_e = time_fn(f_engine, params, x, reps=3, warmup=1)
                macs = spec.mac_count(width)
                lat_s = mcu.latency_s(spec, width, simd=False)
                e_s = mcu.energy_mj(spec, width, simd=False)
                lat_v = mcu.latency_s(spec, width, simd=True)
                e_v = mcu.energy_mj(spec, width, simd=True)
                rows.append(dict(exp=exp_name, prim=prim, v=v, macs=macs,
                                 us_direct=us_d, us_engine=us_e,
                                 mcu_lat_scalar=lat_s, mcu_e_scalar=e_s,
                                 mcu_lat_simd=lat_v, mcu_e_simd=e_v))
                emit(f"fig2/{exp_name}/{prim}/{pname}={v}", us_e,
                     f"macs={macs} us_direct={us_d:.1f} "
                     f"speedup={us_d/max(us_e,1e-9):.2f} "
                     f"mcu_ms_scalar={lat_s*1e3:.2f} mcu_mJ_scalar={e_s:.3f} "
                     f"mcu_ms_simd={lat_v*1e3:.2f} mcu_mJ_simd={e_v:.3f}")

    # --- paper regression claims ------------------------------------------
    macs = [r["macs"] for r in rows]
    r2_scalar = r_squared(macs, [r["mcu_e_scalar"] for r in rows])
    r2_simd_macs = r_squared(macs, [r["mcu_e_simd"] for r in rows])
    r2_simd_lat = r_squared([r["mcu_lat_simd"] for r in rows],
                            [r["mcu_e_simd"] for r in rows])
    emit("fig2/regression/no_simd_macs_vs_energy", 0.0, f"r2={r2_scalar:.4f}")
    emit("fig2/regression/simd_macs_vs_energy", 0.0, f"r2={r2_simd_macs:.4f}")
    emit("fig2/regression/simd_latency_vs_energy", 0.0, f"r2={r2_simd_lat:.4f}")
    emit("fig2/claims", 0.0,
         f"no_simd_linear={r2_scalar > 0.99} "
         f"latency_beats_macs_with_simd={r2_simd_lat > r2_simd_macs}")
    return rows


if __name__ == "__main__":
    main()
