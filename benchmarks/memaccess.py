"""Paper Fig 3: ratio of memory accesses (scalar path / im2col path),
normalized per MAC — the data-reuse quantity that explains the varying SIMD
speedup. Analytic counters from core/energy, swept over the same Table-2
experiment plan."""
from __future__ import annotations

from repro.core import ConvSpec, accesses_direct, accesses_im2col, reuse_ratio

from .common import emit
from .sweeps import EXPERIMENTS, PRIMS, spec_for


def main():
    for exp_name, (pname, values, fixed) in EXPERIMENTS.items():
        for prim in PRIMS:
            for v in values:
                cfg = dict(fixed)
                cfg[pname] = v
                spec = spec_for(prim, cfg["kernel_size"], cfg["cin"],
                                cfg["cout"], cfg.get("groups", 1))
                w = cfg["width"]
                macs = spec.mac_count(w)
                a_d = accesses_direct(spec, w)
                a_i = accesses_im2col(spec, w)
                emit(f"fig3/{exp_name}/{prim}/{pname}={v}", 0.0,
                     f"acc_per_mac_scalar={a_d/macs:.3f} "
                     f"acc_per_mac_im2col={a_i/macs:.3f} "
                     f"reuse_ratio={reuse_ratio(spec, w):.3f}")


if __name__ == "__main__":
    main()
