"""Paper Table 1: analytic parameters / MACs per primitive, verified against
the instantiated layers."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import ConvSpec, init

from .common import emit


def main():
    hy = 32
    for prim in ("standard", "grouped", "dws", "shift", "add"):
        spec = ConvSpec(primitive=prim, in_channels=16, out_channels=16,
                        kernel_size=3, groups=2 if prim == "grouped" else 1,
                        use_bias=False)
        p = init(jax.random.PRNGKey(0), spec)
        actual = sum(int(np.prod(v.shape)) for k, v in p.items()
                     if k != "shifts")
        if prim == "shift":
            actual += int(np.prod(p["shifts"].shape))
        emit(f"table1/{prim}", 0.0,
             f"params={spec.param_count()} actual={actual} "
             f"macs={spec.mac_count(hy)} "
             f"param_gain={spec.param_count()/ConvSpec(in_channels=16, out_channels=16).param_count():.3f} "
             f"mac_gain={spec.mac_count(hy)/ConvSpec(in_channels=16, out_channels=16).mac_count(hy):.3f}")
        assert actual == spec.param_count(), (prim, actual, spec.param_count())


if __name__ == "__main__":
    main()
