"""Paper Table 4: compiler-optimization effect. The TPU-framework analogue:
the SAME Pallas kernel body executed (a) interpret=True (unoptimized,
python-interpreted — the -O0 stand-in) vs (b) XLA-compiled reference path
(-Os stand-in); plus the modeled MCU numbers with the paper's measured
penalty factors. Reproduces the claim that optimization matters far MORE
for the matrix-engine path (paper: 9.81x vs 1.52x)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import ConvSpec, MCUModel
from repro.kernels.conv_im2col import conv2d_im2col
from repro.kernels import ref

from .common import emit, time_fn


def main():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 32, 32, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 8, 16))

    f_interp = functools.partial(conv2d_im2col, interpret=True)   # "O0"
    f_comp = jax.jit(lambda a, b: ref.conv2d_ref(a, b))           # "Os"
    us_o0 = time_fn(f_interp, x, w, reps=3, warmup=1)
    us_os = time_fn(f_comp, x, w, reps=5, warmup=2)
    emit("table4/engine/interpret_O0", us_o0, "")
    emit("table4/engine/compiled_Os", us_os,
         f"speedup={us_o0/max(us_os,1e-9):.1f}x")

    mcu = MCUModel()
    spec = ConvSpec(primitive="standard", in_channels=3, out_channels=32,
                    kernel_size=3, use_bias=False)
    for simd in (False, True):
        tag = "simd" if simd else "no_simd"
        for opt in ("O0", "Os"):
            lat = mcu.latency_s(spec, 32, simd=simd, opt=opt)
            e = mcu.energy_mj(spec, 32, simd=simd, opt=opt)
            emit(f"table4/mcu/{tag}/{opt}", lat * 1e6,
                 f"latency_s={lat:.3f} energy_mJ={e:.2f}")
    s_ns = mcu.latency_s(spec, 32, simd=False, opt="O0") / \
        mcu.latency_s(spec, 32, simd=False, opt="Os")
    s_s = mcu.latency_s(spec, 32, simd=True, opt="O0") / \
        mcu.latency_s(spec, 32, simd=True, opt="Os")
    emit("table4/claim_opt_matters_more_with_simd", 0.0,
         f"speedup_no_simd={s_ns:.2f} speedup_simd={s_s:.2f} holds={s_s > s_ns}")


if __name__ == "__main__":
    main()
