"""§Roofline: per (arch x shape x mesh) table from the dry-run artifacts.

compute_s    = HLO dot FLOPs (trip-corrected) / 197 TF/s
memory_s     = min(analytic traffic, HLO out-bytes proxy) / 819 GB/s
               [out-bytes counts every op output = unfused upper bound;
                analytic = params + activation checkpoints + KV, the fused
                lower bound — both are reported]
collective_s = ICI bytes / (4 links x 50 GB/s) + DCN bytes / 25 GB/s
"""
from __future__ import annotations

import glob
import json
import os

from .common import emit

PEAK = 197e12
HBM = 819e9
ICI = 4 * 50e9
DCN = 25e9

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(tag="baseline"):
    rows = []
    for p in sorted(glob.glob(os.path.join(ART, f"*__{tag}.json"))):
        with open(p) as f:
            rows.append(json.load(f))
    return rows


def terms(rec):
    h = rec["hlo"]
    comp = h["dot_flops"] / PEAK
    mem_hi = h["out_bytes"] / HBM
    # analytic floor: every argument byte touched once + outputs
    mem_lo = (rec["memory"]["argument_bytes"] + rec["memory"]["output_bytes"]) / HBM
    coll = h["coll_bytes_ici"] / ICI + h["coll_bytes_dcn"] / DCN
    # fused memory estimate classifies the bottleneck (see scripts/report.py)
    dom = max((comp, "compute"), (mem_lo, "memory"), (coll, "collective"))
    useful = rec["model_flops"] / max(rec["n_chips"] * h["dot_flops"], 1.0)
    return dict(compute_s=comp, memory_s_upper=mem_hi, memory_s_lower=mem_lo,
                collective_s=coll, bottleneck=dom[1],
                flops_ratio=min(useful, 9.99),
                roofline_frac=min(rec["model_flops"] / rec["n_chips"] / PEAK
                                  / max(dom[0], 1e-12), 9.99))


def main(tag="baseline"):
    rows = load(tag)
    ok = [r for r in rows if r.get("status") == "ok"]
    skipped = [r for r in rows if r.get("status") == "skipped"]
    errors = [r for r in rows if r.get("status") == "error"]
    for r in ok:
        t = terms(r)
        peak_tpu = r["memory"].get("peak_bytes_tpu", r["memory"]["peak_bytes"])
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
             f"compute_s={t['compute_s']:.4f} "
             f"memory_s={t['memory_s_lower']:.4f}..{t['memory_s_upper']:.4f} "
             f"collective_s={t['collective_s']:.4f} "
             f"bottleneck={t['bottleneck']} "
             f"model/hlo_flops={t['flops_ratio']:.3f} "
             f"roofline_frac={t['roofline_frac']:.3f} "
             f"peak_GiB={peak_tpu/2**30:.2f}")
    for r in skipped:
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0, "SKIPPED")
    for r in errors:
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
             f"ERROR {r.get('error','')[:90]}")
    emit("roofline/summary", 0.0,
         f"ok={len(ok)} skipped={len(skipped)} errors={len(errors)}")


if __name__ == "__main__":
    main()
