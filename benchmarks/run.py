# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

  table1  -> primitive_costs   (params/MACs formulas)
  fig2    -> sweeps            (latency/energy vs structural params, r^2 claims)
  fig3    -> memaccess         (data-reuse ratio)
  table3  -> frequency         (MCU frequency/power/energy model)
  table4  -> optlevel          (interpret vs compiled; O0 vs Os)
  kernels -> kernel microbench (Pallas interpret vs jnp oracle)
  quant   -> quant_bench       (pallas-int8 / xla-int8 / float per primitive)
  layers  -> layer_bench       (repro.graph per-layer breakdown; fused vs
                                unfused float-bounce e2e)
  throughput -> throughput_bench (batched CompiledPlan images/s vs the N=1
                                loop; MACs/byte reuse table; CNNEngine)
  roofline-> roofline_report   (from dry-run artifacts, if present)
  serving -> serve_bench       (static-drain vs continuous batching)

Section-by-section expected output shapes are documented in
EXPERIMENTS.md. REPRO_BENCH_FAST=1 trims sweep points for CI.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (frequency, kernels_bench, layer_bench, memaccess, optlevel,
                   primitive_costs, quant_bench, roofline_report, serve_bench,
                   sweeps, throughput_bench)
    sections = [
        ("table1", primitive_costs.main),
        ("fig2", sweeps.main),
        ("fig3", memaccess.main),
        ("table3", frequency.main),
        ("table4", optlevel.main),
        ("kernels", kernels_bench.main),
        ("quant", quant_bench.main),
        ("layers", layer_bench.main),
        ("throughput", throughput_bench.main),
        ("roofline", roofline_report.main),
        ("serving", serve_bench.main),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in sections:
        try:
            fn()
        except Exception as e:      # noqa: BLE001 — report, keep benching
            failures += 1
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    print(f"done,0.0,sections={len(sections)} failures={failures}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
