"""Timing + CSV helpers for the benchmark harness."""
from __future__ import annotations

import os
import time

import jax
import numpy as np

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"


def time_fn(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall-clock microseconds per call (jit'd fn, post-warmup)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")


def r_squared(x, y) -> float:
    x, y = np.asarray(x, np.float64), np.asarray(y, np.float64)
    if len(x) < 2:
        return 1.0
    A = np.stack([x, np.ones_like(x)], axis=1)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    pred = A @ coef
    ss_res = np.sum((y - pred) ** 2)
    ss_tot = np.sum((y - y.mean()) ** 2)
    return float(1.0 - ss_res / max(ss_tot, 1e-30))
