"""Serve-engine behaviour tests: retirement (EOS / max_new_tokens / KV cap),
mid-decode slot refill, padded-prefill parity with single-request decode,
KV-slot surgery helpers, stats counters, and the non-blocking queue take."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve import Engine, Request, ServeConfig


def tiny_cfg():
    return dataclasses.replace(get_config("qwen2-0.5b"), n_layers=2,
                               d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                               vocab=64)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = tiny_cfg()
    return cfg, api.init_params(cfg, jax.random.PRNGKey(0))


def make_req(uid, plen=5, max_new=6, seed=None, **kw):
    rng = np.random.default_rng(uid if seed is None else seed)
    return Request(uid=uid, prompt=rng.integers(0, 64, (plen,)).astype(np.int32),
                   max_new_tokens=max_new, **kw)


def drain(cfg, params, reqs, **scfg_kw):
    eng = Engine(cfg, params, ServeConfig(**scfg_kw))
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    return eng, sorted(done, key=lambda r: r.uid)


# --------------------------------------------------------------- retirement


def test_max_new_retirement_and_stats(dense_setup):
    cfg, params = dense_setup
    maxnews = [2, 5, 3, 7, 1]
    eng, done = drain(cfg, params, [make_req(i, max_new=m)
                                    for i, m in enumerate(maxnews)],
                      max_batch=2, max_len=32)
    assert [len(r.out_tokens) for r in done] == maxnews
    assert all(r.done for r in done)
    st = eng.stats
    assert st["prefills"] == 5
    assert st["requests_done"] == 5
    assert st["tokens_out"] == sum(maxnews)
    assert 0.0 < st["occupancy"] <= 1.0
    assert st["ttft_avg_s"] >= 0.0 and st["decode_tok_s"] > 0.0


def test_eos_retirement(dense_setup):
    """Replay a reference generation with eos set to one of its tokens: the
    rerun must truncate exactly there (eos token included, then retire)."""
    cfg, params = dense_setup
    _, (ref,) = drain(cfg, params, [make_req(0, max_new=8)],
                      max_batch=2, max_len=32)
    toks = ref.out_tokens
    assert len(toks) == 8
    # first position whose token did not already appear earlier (prefer a
    # mid-sequence stop so the test exercises decode-round retirement)
    j = next((i for i in range(1, 8) if toks[i] not in toks[:i]), 0)
    _, (got,) = drain(cfg, params,
                      [make_req(0, max_new=8, eos_id=toks[j])],
                      max_batch=2, max_len=32)
    assert got.out_tokens == toks[:j + 1]
    assert got.done


def test_kv_cap_retires_before_overflow(dense_setup):
    """A sequence whose prompt + decode would overflow max_len retires at
    the cap instead of silently dropping K/V writes."""
    cfg, params = dense_setup
    _, (r,) = drain(cfg, params, [make_req(0, plen=6, max_new=50)],
                    max_batch=2, max_len=16, prefill_bucket=8)
    # prefill fills 6 positions; each decoded-token round writes one more
    assert len(r.out_tokens) == 16 - 6 + 1
    assert r.done


# -------------------------------------------------------------- slot refill


def test_slot_refill_admits_queued_request_mid_decode(dense_setup):
    """Acceptance: a queued request is admitted into a freed slot BEFORE the
    running batch drains (this is what distinguishes continuous batching
    from the static drain strategy)."""
    cfg, params = dense_setup
    eng, done = drain(cfg, params,
                      [make_req(0, max_new=3), make_req(1, max_new=12),
                       make_req(2, max_new=6)],
                      max_batch=2, max_len=32)
    r0, r1, r2 = done
    assert [len(r.out_tokens) for r in done] == [3, 12, 6]
    # r2 was queued behind a full batch, then admitted into r0's freed slot
    # while r1 was still decoding
    assert r0.admit_round == r1.admit_round == 0
    assert r2.admit_round > 0, "r2 must wait for a slot to free"
    assert r2.admit_round == r0.finish_round
    assert r2.admit_round < r1.finish_round, "admitted before the batch drained"
    # slot reuse means fewer rounds than static draining [r0,r1] then [r2]
    assert eng.stats["decode_steps"] < (12 - 1) + (6 - 1) + 1


def test_immediate_retirement_frees_slot_for_next(dense_setup):
    """max_new_tokens=1 retires at admission; the slot admits the next
    queued request in the same scheduling pass."""
    cfg, params = dense_setup
    eng, done = drain(cfg, params,
                      [make_req(i, max_new=1) for i in range(3)]
                      + [make_req(3, max_new=2)],
                      max_batch=1, max_len=32)
    assert [len(r.out_tokens) for r in done] == [1, 1, 1, 2]
    assert eng.stats["decode_steps"] == 1      # only req 3 ever decoded


# ------------------------------------------------------------------- parity


def test_padded_prefill_parity_with_single_request_decode(dense_setup):
    """A prompt right-padded to its prefill bucket (per-slot vector-length
    cache) must generate exactly the tokens of an unpadded single-request
    run (scalar-length cache, the static path)."""
    cfg, params = dense_setup
    reqs = lambda: [make_req(0, plen=5, max_new=8)]    # bucket pads 5 -> 16
    _, (cont,) = drain(cfg, params, reqs(), max_batch=4, max_len=32,
                       scheduler="continuous")
    _, (stat,) = drain(cfg, params, reqs(), max_batch=4, max_len=32,
                       scheduler="static")
    assert cont.out_tokens == stat.out_tokens


def test_batch_composition_does_not_change_tokens(dense_setup):
    """Per-slot masking isolates rows: a request decodes the same tokens
    alone and inside a full, skewed batch."""
    cfg, params = dense_setup
    _, (alone,) = drain(cfg, params, [make_req(7, max_new=6)],
                        max_batch=4, max_len=32)
    _, done = drain(cfg, params,
                    [make_req(7, max_new=6), make_req(1, plen=9, max_new=2),
                     make_req(2, plen=3, max_new=11)],
                    max_batch=4, max_len=32)
    got = next(r for r in done if r.uid == 7)
    assert got.out_tokens == alone.out_tokens


# ------------------------------------------------------------- slot surgery


def test_cache_write_and_free_slot(dense_setup):
    cfg, params = dense_setup
    prefill = api.prefill_fn(cfg, max_len=16)
    cache = api.init_slot_cache(cfg, 3, 16)
    assert cache["len"].shape == (3,) and int(cache["len"].sum()) == 0
    rng = np.random.default_rng(0)
    fresh = {}
    for slot, plen in ((1, 4), (2, 7)):
        toks = np.zeros((1, 8), np.int32)
        toks[0, :plen] = rng.integers(0, 64, (plen,))
        _, fresh[slot] = prefill(params, {
            "tokens": jnp.asarray(toks),
            "prompt_lens": jnp.asarray([plen], jnp.int32)})
        cache = api.cache_write_slot(cfg, cache, fresh[slot], slot)
    assert cache["len"].tolist() == [0, 4, 7]
    for slot in (1, 2):
        np.testing.assert_array_equal(np.asarray(cache["k"][:, slot]),
                                      np.asarray(fresh[slot]["k"][:, 0]))
    # freeing only zeroes the length; K/V stay (masked) in place
    freed = api.cache_free_slot(cache, 1)
    assert freed["len"].tolist() == [0, 0, 7]
    np.testing.assert_array_equal(np.asarray(freed["k"]),
                                  np.asarray(cache["k"]))


def test_slot_axes_reject_encdec():
    with pytest.raises(NotImplementedError):
        api.slot_batch_axes(get_config("seamless-m4t-large-v2"))


# ------------------------------------------------------ ssm + sampling + q


def test_continuous_ssm_family():
    """Mamba state has no seq dim — slot surgery writes rows; prefill runs
    at exact length (recurrences are position-exact, no padding)."""
    cfg = dataclasses.replace(get_config("falcon-mamba-7b"), n_layers=2,
                              d_model=32, vocab=64)
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    eng, done = drain(cfg, params, [make_req(i, max_new=4) for i in range(3)],
                      max_batch=2, max_len=32)
    assert [len(r.out_tokens) for r in done] == [4, 4, 4]
    assert eng.stats["requests_done"] == 3


def test_temperature_sampling_smoke(dense_setup):
    cfg, params = dense_setup
    _, done = drain(cfg, params,
                    [make_req(0, max_new=6, temperature=1.0),
                     make_req(1, max_new=6)],
                    max_batch=2, max_len=32, seed=7)
    assert all(len(r.out_tokens) == 6 for r in done)
    assert all(0 <= t < 64 for r in done for t in r.out_tokens)


def test_submit_rejects_oversized_prompt(dense_setup):
    """Oversized prompts fail fast at submit, not mid-drain (which would
    discard finished requests and strand the queue)."""
    cfg, params = dense_setup
    eng = Engine(cfg, params, ServeConfig(max_batch=2, max_len=8))
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.submit(make_req(0, plen=9))
    assert eng.queue.empty()


def test_take_batch_nonblocking(dense_setup):
    cfg, params = dense_setup
    eng = Engine(cfg, params, ServeConfig(max_batch=4, scheduler="static"))
    for i in range(5):
        eng.submit(make_req(i))
    assert [r.uid for r in eng._take_batch()] == [0, 1, 2, 3]
    assert [r.uid for r in eng._take_batch()] == [4]
    assert eng._take_batch() == []


# -------------------------------------------------------- int8 precision --


def test_int8_precision_serves_and_matches_pallas_vs_xla(dense_setup):
    """ServeConfig(precision="int8"): FFN matmuls run integer-only through
    matmul_q8's requantized epilogue; the Pallas and the jnp-oracle integer
    engines accumulate identically, so greedy token streams are identical."""
    cfg, params = dense_setup
    reqs = lambda: [make_req(i, max_new=4) for i in range(3)]
    _, done_p = drain(cfg, params, reqs(), max_batch=2, max_len=32,
                      precision="int8")
    _, done_x = drain(cfg, params, reqs(), max_batch=2, max_len=32,
                      precision="int8-xla")
    assert all(len(r.out_tokens) == 4 for r in done_p)
    assert [r.out_tokens for r in done_p] == [r.out_tokens for r in done_x]
    # the engine's own params stay float; quantized copies ride in "qmlp"
    assert "qmlp" not in params["layers"]


def test_int8_precision_close_to_float(dense_setup):
    """W8A8 FFN decode mostly agrees with the float engine on greedy tokens
    (power-of-two PTQ is lossy, so exact agreement is not required)."""
    cfg, params = dense_setup
    reqs = lambda: [make_req(i, max_new=6) for i in range(4)]
    _, done_f = drain(cfg, params, reqs(), max_batch=2, max_len=32)
    _, done_q = drain(cfg, params, reqs(), max_batch=2, max_len=32,
                      precision="int8-xla")
    toks_f = [t for r in done_f for t in r.out_tokens]
    toks_q = [t for r in done_q for t in r.out_tokens]
    agree = sum(a == b for a, b in zip(toks_f, toks_q)) / len(toks_f)
    assert agree >= 0.5, f"int8 vs float token agreement {agree}"


def test_int8_precision_rejected_for_unsupported_configs(dense_setup):
    cfg, params = dense_setup
    with pytest.raises(ValueError, match="precision"):
        Engine(cfg, params, ServeConfig(precision="fp4"))
    ssm_cfg = dataclasses.replace(get_config("falcon-mamba-7b"), n_layers=2,
                                  d_model=32, vocab=64)
    ssm_params = api.init_params(ssm_cfg, jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="int8"):
        Engine(ssm_cfg, ssm_params, ServeConfig(precision="int8"))


# ---------------------------------------------------------- int8 KV cache --


def test_int8_kv_token_stream_identical_to_float_kv(dense_setup):
    """Acceptance: kv_cache="int8" decode is token-stream-identical to the
    float-KV engine on the SAME workload — including mid-decode slot refill
    (more requests than slots, skewed lengths) and retirement. Per-token
    scales keep the quantization per-position, so splicing a new request
    into a freed slot never re-scales a neighbour's K/V."""
    cfg, params = dense_setup
    reqs = lambda: [make_req(0, max_new=3), make_req(1, max_new=12),
                    make_req(2, plen=7, max_new=6), make_req(3, max_new=5)]
    _, done_f = drain(cfg, params, reqs(), max_batch=2, max_len=32)
    _, done_q = drain(cfg, params, reqs(), max_batch=2, max_len=32,
                      kv_cache="int8")
    # the workload actually exercised mid-decode refill, not just a drain
    assert any(r.admit_round > 0 for r in done_q)
    assert [r.out_tokens for r in done_q] == [r.out_tokens for r in done_f]
    assert all(r.done for r in done_q)


def test_int8_kv_slot_cache_layout_and_write(dense_setup):
    """init_slot_cache(kv="int8") stores int8 K/V + per-(position, head)
    f32 scales; cache_write_slot quantizes the float prefill row on the way
    in (dequantized row close to the float row); freeing only zeroes len."""
    cfg, params = dense_setup
    prefill = api.prefill_fn(cfg, max_len=16)
    cache = api.init_slot_cache(cfg, 3, 16, kv="int8")
    assert cache["k"].dtype == jnp.int8 and cache["v"].dtype == jnp.int8
    assert cache["k_scale"].shape == cache["k"].shape[:-1]  # (L, B, S, Hkv)
    assert cache["k_scale"].dtype == jnp.float32
    rng = np.random.default_rng(0)
    plen = 5
    toks = np.zeros((1, 8), np.int32)
    toks[0, :plen] = rng.integers(0, 64, (plen,))
    _, fresh = prefill(params, {"tokens": jnp.asarray(toks),
                                "prompt_lens": jnp.asarray([plen], jnp.int32)})
    cache = api.cache_write_slot(cfg, cache, fresh, 1)
    assert cache["len"].tolist() == [0, plen, 0]
    deq = (np.asarray(cache["k"][:, 1], np.float32)
           * np.asarray(cache["k_scale"][:, 1])[..., None])
    want = np.asarray(fresh["k"][:, 0], np.float32)
    # symmetric 127-level rounding: |err| <= scale/2 elementwise
    half = np.asarray(cache["k_scale"][:, 1])[..., None] / 2 + 1e-6
    assert (np.abs(deq[:, :plen] - want[:, :plen]) <= half[:, :plen]).all()
    freed = api.cache_free_slot(cache, 1)
    assert freed["len"].tolist() == [0, 0, 0]
    np.testing.assert_array_equal(np.asarray(freed["k"]),
                                  np.asarray(cache["k"]))


def test_int8_kv_rejected_for_unsupported_configs(dense_setup):
    cfg, params = dense_setup
    with pytest.raises(ValueError, match="kv_cache"):
        Engine(cfg, params, ServeConfig(kv_cache="fp8"))
    with pytest.raises(NotImplementedError, match="static"):
        Engine(cfg, params, ServeConfig(kv_cache="int8", scheduler="static"))
    ssm_cfg = dataclasses.replace(get_config("falcon-mamba-7b"), n_layers=2,
                                  d_model=32, vocab=64)
    ssm_params = api.init_params(ssm_cfg, jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="kv_cache"):
        Engine(ssm_cfg, ssm_params, ServeConfig(kv_cache="int8"))
    with pytest.raises(NotImplementedError):
        api.init_slot_cache(ssm_cfg, 2, 16, kv="int8")


# ------------------------------------------------------------- W4A8 serve --


def test_w4a8_precision_serves_with_packed_weights(dense_setup):
    """ServeConfig(precision="w4a8"): the FFN stack is nibble-packed
    (QTensorW4 leaves ride in params["layers"]["qmlp"]) and decode streams
    full token sequences; combining with kv_cache="int8" also drains."""
    from repro.core.quantize import QTensorW4
    cfg, params = dense_setup
    eng = Engine(cfg, params, ServeConfig(max_batch=2, max_len=32,
                                          precision="w4a8"))
    assert all(isinstance(v, QTensorW4)
               for v in eng.params["layers"]["qmlp"].values())
    for i in range(3):
        eng.submit(make_req(i, max_new=4))
    done = sorted(eng.run_until_drained(), key=lambda r: r.uid)
    assert [len(r.out_tokens) for r in done] == [4, 4, 4]
    assert all(0 <= t < 64 for r in done for t in r.out_tokens)
    _, done_kv = drain(cfg, params, [make_req(i, max_new=4) for i in range(3)],
                       max_batch=2, max_len=32, precision="w4a8",
                       kv_cache="int8")
    assert [len(r.out_tokens) for r in done_kv] == [4, 4, 4]
