"""repro.check static-analysis tests: VMEM footprint model + schedule
verdicts, tune-cache audit of the committed artifact, int32 accumulator /
requant-shift range analysis over real lowered plans, dataflow abstract
interpretation (and rejection of tampered plans), build-time CompiledPlan
validation, candidate-space pruning, explicit-config rejection at the ops
layer, serve-config checks, and the AST lint rules on synthetic fixtures
plus the real tree (zero false positives is an acceptance bar)."""
import dataclasses
import os
import textwrap

import jax
import numpy as np
import pytest

from repro.check import (CheckError, audit_cache, check_cnn_serve_config,
                         check_serve_config, validate_plan)
from repro.check.astlint import lint_file, lint_paths
from repro.check.dataflow import check_plan
from repro.check.footprint import (check_schedule, kernel_footprint,
                                   parse_cache_key, summarize_audit,
                                   vmem_budget)
from repro.check.overflow import (INT32_MAX, check_plan_overflow,
                                  check_requant_shift, overflow_errors)
from repro.core import Primitives
from repro.graph import CompiledPlan, build_cnn_graph, lower
from repro.models.convnet import CNNConfig, init_cnn
from repro.tune import space
from repro.tune.space import (default_config, sig_conv2d, sig_depthwise2d,
                              sig_matmul)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _lowered(prim, *, weight_bits=8):
    cfg = CNNConfig(primitive=prim, widths=(8, 12), image_size=16)
    params = init_cnn(cfg, jax.random.PRNGKey(1))
    calib = jax.random.normal(jax.random.PRNGKey(2), (4, 16, 16, 3)) * 0.5
    return lower(build_cnn_graph(cfg), params, calib,
                 weight_bits=weight_bits)


# ------------------------------------------------------ footprint model ---

def test_footprint_terms_positive_and_within_reason():
    sig = sig_conv2d(4, 32, 32, 16, 32, 3)
    fp = kernel_footprint(sig, space.effective_config(
        sig, default_config("conv2d")), "int8")
    assert fp.total_bytes > 0
    terms = dict(fp.terms)
    assert set(terms) == {"img", "wts", "out", "acc"}
    assert all(v >= 0 for v in terms.values())


def test_w4_halves_the_weight_block():
    sig = sig_conv2d(1, 16, 16, 16, 16, 3)
    cfg = space.effective_config(sig, default_config("conv2d"))
    w8 = dict(kernel_footprint(sig, cfg, "int8").terms)["wts"]
    w4 = dict(kernel_footprint(sig, cfg, "w4a8").terms)["wts"]
    assert w4 * 2 == w8


def test_block_n_64_rejected_on_table2_shape():
    # acceptance bar: batching the whole Table-2 batch into one tile is
    # statically infeasible (the f32 accumulator alone fills the budget)
    sig = sig_conv2d(64, 32, 32, 16, 64, 3)
    v = check_schedule(sig, {"block_n": 64}, "int8")
    assert not v.ok
    assert any("exceeds" in e and "budget" in e for e in v.errors)
    assert v.footprint.total_bytes > vmem_budget("tpu")


def test_unknown_key_and_bad_value_are_errors():
    sig = sig_depthwise2d(1, 16, 16, 8, 3)
    assert not check_schedule(sig, {"block_z": 4}, "int8").ok
    assert not check_schedule(sig, {"block_c": 0}, "int8").ok
    assert not check_schedule(sig, {"block_c": "8"}, "int8").ok


def test_degradation_is_a_warning_not_an_error():
    sig = sig_conv2d(1, 8, 8, 8, 8, 3)
    v = check_schedule(sig, {"block_co": 128}, "int8")
    assert v.ok and v.warnings
    assert v.effective["block_co"] == 8


def test_vmem_budget_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "4096")
    assert vmem_budget("tpu") == 4096
    sig = sig_matmul(256, 256, 256)
    assert not check_schedule(sig, {}, "int8").ok


def test_runner_cost_model_shares_the_footprint_model():
    # the tuner's soft VMEM penalty and the hard verdict must agree: any
    # schedule the cost model prices without penalty is feasible
    from repro.tune import runner
    sig = sig_conv2d(8, 32, 32, 16, 32, 3)
    for cfg in space.candidates(sig, "int8"):
        est = runner.estimate_s(sig, cfg, "int8")
        assert est > 0
        assert check_schedule(sig, cfg, "int8").ok


# ------------------------------------------------------ tune-cache audit ---

def test_committed_cache_schedules_all_feasible():
    path = os.path.join(ROOT, "artifacts", "tune_cache.json")
    if not os.path.exists(path):
        pytest.skip("no committed tune cache")
    rows = audit_cache(path)
    summ = summarize_audit(rows)
    assert summ["entries"] > 0
    assert summ["infeasible"] == []
    assert summ["warnings"] == 0      # degradation lands in notes


def test_parse_cache_key_roundtrip():
    sig = sig_conv2d(4, 32, 32, 16, 64, 3, groups=4)
    key = f"{sig.kernel}|{sig.key()}|int8|cpu+interpret"
    got_sig, dtype, backend = parse_cache_key(key)
    assert got_sig == sig and dtype == "int8" and backend == "cpu+interpret"
    with pytest.raises(ValueError):
        parse_cache_key("conv2d|bogus-shape|int8|tpu")


# ----------------------------------------------------- overflow analysis ---

@pytest.mark.parametrize("prim", Primitives)
@pytest.mark.parametrize("bits", [8, 4])
def test_lowered_plan_accumulators_proven_safe(prim, bits):
    plan = _lowered(prim, weight_bits=bits)
    bounds = check_plan_overflow(plan)
    assert bounds, "quantized plan must yield at least one bound"
    assert overflow_errors(bounds) == []
    for b in bounds:
        assert b.acc_max <= INT32_MAX
        assert b.headroom_bits > 0


def test_check_requant_shift_catches_each_failure_mode():
    assert check_requant_shift(1 << 20, 4) == []
    assert any("int32" in m for m in check_requant_shift(INT32_MAX + 1, 4))
    assert check_requant_shift(1 << 20, 40)          # |shift| >= 32
    assert check_requant_shift(1 << 20, 2.5)         # non-integer
    # rounding term 2^(s-1) pushes acc + round over int32
    assert check_requant_shift(INT32_MAX - 2, 3)
    # negative shift = left shift; wrap past int32 is caught
    assert check_requant_shift(1 << 28, -8)


def test_tampered_shift_caught_with_per_node_diagnostic():
    plan = _lowered("standard")
    node = next(n for n in plan.nodes if n.op == "qconv")
    node.out_fb = node.out_fb - 40          # shift now >= 32
    errs = overflow_errors(check_plan_overflow(plan))
    assert errs and any(e.startswith(f"{node.name}/") for e in errs)


def test_qbn_multiplier_budget_enforced():
    plan = _lowered("add")
    node = next(n for n in plan.nodes if n.op == "qbn")
    qp = dict(node.qparams)
    qp["a"] = np.asarray(qp["a"], dtype=np.int64) * 0 + (1 << 20)
    node.qparams = qp
    errs = overflow_errors(check_plan_overflow(plan))
    assert any("int16-range budget" in e for e in errs)


# ----------------------------------------------------- dataflow analysis ---

@pytest.mark.parametrize("prim", Primitives)
def test_lowered_plan_dataflow_clean(prim):
    assert [d for d in check_plan(_lowered(prim))
            if d.level == "error"] == []


def test_broken_scale_chain_rejected():
    plan = _lowered("standard")
    node = next(n for n in plan.nodes if n.op == "qconv")
    node.in_fb = node.in_fb + 3             # no longer the producer's out_fb
    diags = check_plan(plan)
    assert any(d.level == "error" and d.node == node.name for d in diags)


# ------------------------------------------- build-time plan validation ---

def test_compiled_plan_validates_at_build():
    plan = _lowered("standard")
    CompiledPlan(plan)                      # clean plan builds
    node = next(n for n in plan.nodes if n.op == "qconv")
    node.in_fb = node.in_fb + 3
    with pytest.raises(CheckError, match="static verification"):
        CompiledPlan(plan)
    CompiledPlan(plan, validate=False)      # explicit bypass still works


def test_validate_plan_message_lists_every_violation():
    plan = _lowered("standard")
    for n in plan.nodes:
        if n.op == "qconv":
            n.in_fb = n.in_fb + 3
    with pytest.raises(CheckError) as ei:
        validate_plan(plan)
    assert str(ei.value).count("  - ") >= 2


# ------------------------------------------------------ candidate space ---

def test_candidates_pruned_to_feasible_with_default_kept():
    sig = sig_conv2d(64, 32, 32, 16, 64, 3)
    cands = list(space.candidates(sig, "int8"))
    assert cands, "pruning must never empty the space"
    assert space.effective_config(sig, default_config("conv2d")) in [
        space.effective_config(sig, c) for c in cands]
    for c in cands[1:]:                     # default rides along unpruned
        assert check_schedule(sig, c, "int8").ok
    assert all(space.effective_config(sig, c).get("block_n", 1) < 64
               for c in cands[1:])


# ------------------------------------------------- ops explicit configs ---

def test_ops_rejects_explicit_infeasible_config(monkeypatch):
    from repro.kernels import ops
    x = np.zeros((1, 8, 8, 8), np.float32)
    w = np.zeros((3, 3, 8, 8), np.float32)
    with pytest.raises(CheckError, match="infeasible schedule"):
        ops.conv2d(x, w, config={"block_zz": 4})
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "1024")
    with pytest.raises(CheckError, match="exceeds"):
        ops.conv2d(x, w, config=dict(default_config("conv2d")))


# --------------------------------------------------------- serve configs ---

def test_check_serve_config_enums_and_ranges():
    from repro.serve.engine import ServeConfig
    assert check_serve_config(ServeConfig()) == []
    errs = check_serve_config(ServeConfig(scheduler="bogus", max_batch=0,
                                          temperature=-1.0))
    assert len(errs) == 3
    errs = check_serve_config(ServeConfig(kv_cache="int8",
                                          scheduler="static"))
    assert any("continuous" in e for e in errs)


def test_check_serve_config_strict_and_budget():
    from repro.configs.base import ModelConfig
    from repro.serve.engine import ServeConfig
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab=64)
    scfg = ServeConfig(max_len=8, prefill_bucket=16)
    assert check_serve_config(scfg, cfg, strict=False) == []
    assert any("prefill_bucket" in e
               for e in check_serve_config(scfg, cfg, strict=True))
    assert any("KV cache" in e for e in check_serve_config(
        ServeConfig(), cfg, hbm_budget=1 << 10))


def test_cnn_serve_config_checked_at_engine_init():
    from repro.serve.cnn import CNNServeConfig
    assert check_cnn_serve_config(CNNServeConfig()) == []
    assert check_cnn_serve_config(CNNServeConfig(max_batch=0))


# --------------------------------------------------------------- astlint ---

def _lint_src(tmp_path, src):
    p = tmp_path / "fixture.py"
    p.write_text(textwrap.dedent(src))
    return lint_file(str(p))


def test_lint_flags_index_map_default_args(tmp_path):
    fs = _lint_src(tmp_path, """
        import jax.experimental.pallas as pl
        def f(nb):
            spec = pl.BlockSpec((8, 8), lambda i, j, nb=nb: (i * nb, j))
    """)
    assert [f.rule for f in fs] == ["index-map-default-arg"]


def test_lint_flags_named_index_map_with_default(tmp_path):
    fs = _lint_src(tmp_path, """
        import jax.experimental.pallas as pl
        def build(nb):
            def imap(i, j, nb=nb):
                return (i * nb, j)
            return pl.BlockSpec((8, 8), index_map=imap)
    """)
    assert [f.rule for f in fs] == ["index-map-default-arg"]


def test_lint_flags_wall_clock_elapsed(tmp_path):
    fs = _lint_src(tmp_path, """
        import time
        def f():
            t0 = time.time()
            work()
            return time.time() - t0
    """)
    assert [f.rule for f in fs] == ["wall-clock-elapsed"]


def test_lint_flags_stop_before_sync(tmp_path):
    fs = _lint_src(tmp_path, """
        import time, jax
        def f(x):
            t0 = time.perf_counter()
            y = g(x)
            el = time.perf_counter() - t0
            jax.block_until_ready(y)
            return el
    """)
    assert [f.rule for f in fs] == ["timer-stop-before-sync"]


def test_lint_clean_patterns_not_flagged(tmp_path):
    fs = _lint_src(tmp_path, """
        import time, jax
        import jax.experimental.pallas as pl
        def f(x, nb):
            spec = pl.BlockSpec((8, 8), lambda i, j: (i * nb, j))
            t0 = time.perf_counter()
            y = g(x)
            jax.block_until_ready(y)
            el = time.perf_counter() - t0
            wall = time.time()           # bare stamp, not an interval
            return spec, el, wall
    """)
    assert fs == []


def test_lint_clean_on_real_tree():
    # acceptance bar: zero false positives over src/ and scripts/
    fs = lint_paths([os.path.join(ROOT, "src"),
                     os.path.join(ROOT, "scripts")])
    assert [str(f) for f in fs] == []
