"""core/folding.py: fold-vs-unfused numeric equivalence for every primitive
in FOLDABLE, and the add-conv rejection path (|W - x| is not linear in W,
so BN cannot fold — paper §3.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ConvSpec, apply, batchnorm_apply, fold, init
from repro.core.folding import FOLDABLE

KEY = jax.random.PRNGKey(0)


def _block(prim, *, with_bias=True):
    spec = ConvSpec(primitive=prim, in_channels=8, out_channels=12,
                    kernel_size=3, groups=4 if prim == "grouped" else 1,
                    use_bias=with_bias)
    p = init(KEY, spec)
    if with_bias:
        p["b"] = jax.random.normal(jax.random.PRNGKey(1), p["b"].shape) * 0.1
    bn = {
        "gamma": jax.random.uniform(jax.random.PRNGKey(2), (12,), minval=0.5,
                                    maxval=1.5),
        "beta": jax.random.normal(jax.random.PRNGKey(3), (12,)) * 0.2,
        "mean": jax.random.normal(jax.random.PRNGKey(4), (12,)) * 0.3,
        "var": jax.random.uniform(jax.random.PRNGKey(5), (12,), minval=0.2,
                                  maxval=2.0),
    }
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 10, 10, 8)) * 0.5
    return spec, p, bn, x


@pytest.mark.parametrize("with_bias", [True, False])
@pytest.mark.parametrize("prim", FOLDABLE)
def test_fold_matches_unfused_bn(prim, with_bias):
    """apply(fold(conv, bn)) == BN(apply(conv)) for every foldable
    primitive, with and without a conv bias."""
    spec, p, bn, x = _block(prim, with_bias=with_bias)
    want = batchnorm_apply(bn, apply(p, x, spec))
    got = apply(fold(p, bn, spec), x, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_fold_targets_pointwise_for_dws_and_shift():
    """The folded scale lands on the POINTWISE weights (the stage whose
    output BN normalizes); depthwise weights / shift tables are untouched."""
    for prim, wkey in [("dws", "w_pw"), ("shift", "w_pw")]:
        spec, p, bn, _ = _block(prim)
        out = fold(p, bn, spec)
        assert not np.allclose(np.asarray(out[wkey]), np.asarray(p[wkey]))
        if prim == "dws":
            np.testing.assert_array_equal(np.asarray(out["w_dw"]),
                                          np.asarray(p["w_dw"]))
        else:
            np.testing.assert_array_equal(np.asarray(out["shifts"]),
                                          np.asarray(p["shifts"]))


def test_fold_creates_bias_when_absent():
    spec, p, bn, x = _block("standard", with_bias=False)
    out = fold(p, bn, spec)
    assert "b" in out and out["b"].shape == (12,)
    want = batchnorm_apply(bn, apply(p, x, spec))
    got = apply(out, x, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_fold_rejects_add_conv():
    spec, p, bn, _ = _block("add")
    with pytest.raises(ValueError, match="add"):
        fold(p, bn, spec)
