"""Unit + property tests for the five convolution primitives (core/)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import (ConvSpec, Primitives, apply, init, shift_channels,
                        add_conv, standard_conv, depthwise_conv)

KEY = jax.random.PRNGKey(0)


def rand(shape, key=KEY, scale=1.0):
    return jax.random.normal(key, shape) * scale


# ---------------------------------------------------------------- shapes ---
@pytest.mark.parametrize("prim", Primitives)
@pytest.mark.parametrize("hk", [1, 3, 5])
def test_output_shape(prim, hk):
    if prim in ("dws", "shift") and hk == 1 and prim == "shift":
        pass
    spec = ConvSpec(primitive=prim, in_channels=6, out_channels=10,
                    kernel_size=hk, groups=2 if prim == "grouped" else 1)
    p = init(KEY, spec)
    y = apply(p, rand((2, 9, 9, 6)), spec)
    assert y.shape == (2, 9, 9, 10)
    assert bool(jnp.all(jnp.isfinite(y)))


# ------------------------------------------------------- reference math ---
def naive_conv(x, w):
    """Direct NHWC loop conv, SAME padding, stride 1 (paper Eq. 1)."""
    b, h, wd, cx = x.shape
    hk = w.shape[0]
    cy = w.shape[3]
    ph = hk // 2
    xp = np.pad(np.asarray(x), ((0, 0), (ph, (hk - 1) // 2), (ph, (hk - 1) // 2), (0, 0)))
    out = np.zeros((b, h, wd, cy), np.float32)
    for i in range(hk):
        for j in range(hk):
            patch = xp[:, i:i + h, j:j + wd, :]
            out += np.einsum("bhwc,cn->bhwn", patch, np.asarray(w[i, j]))
    return out


def test_standard_matches_naive():
    x, w = rand((2, 7, 7, 3)), rand((3, 3, 3, 5), jax.random.PRNGKey(1))
    np.testing.assert_allclose(standard_conv(x, w), naive_conv(x, w), rtol=2e-5, atol=2e-5)


def naive_add_conv(x, w):
    b, h, wd, cx = x.shape
    hk, _, _, cy = w.shape
    ph = hk // 2
    xp = np.pad(np.asarray(x), ((0, 0), (ph, (hk - 1) // 2), (ph, (hk - 1) // 2), (0, 0)))
    out = np.zeros((b, h, wd, cy), np.float32)
    wn = np.asarray(w)
    for bi in range(b):
        for k in range(h):
            for l in range(wd):
                patch = xp[bi, k:k + hk, l:l + hk, :]          # (hk,hk,cx)
                out[bi, k, l] = -np.abs(patch[..., None] - wn).sum((0, 1, 2))
    return out


def test_add_conv_matches_naive():
    x, w = rand((1, 5, 5, 2)), rand((3, 3, 2, 4), jax.random.PRNGKey(2))
    np.testing.assert_allclose(add_conv(x, w), naive_add_conv(x, w), rtol=2e-5, atol=2e-5)


def test_add_conv_always_negative():
    x, w = rand((2, 6, 6, 3)), rand((3, 3, 3, 4), jax.random.PRNGKey(3))
    assert bool(jnp.all(add_conv(x, w) <= 0.0)), "paper §2.2: add conv output is always negative"


def test_shift_channels_semantics():
    # Eq. 2: I[k,l,m] = X[k+a, l+b, m], zero outside.
    x = jnp.arange(2 * 4 * 4 * 2, dtype=jnp.float32).reshape(2, 4, 4, 2)
    shifts = jnp.array([[1, 0], [0, -1]], jnp.int32)
    y = shift_channels(x, shifts)
    np.testing.assert_allclose(y[:, :3, :, 0], x[:, 1:, :, 0])   # a=+1
    np.testing.assert_allclose(y[:, 3, :, 0], 0.0)
    np.testing.assert_allclose(y[:, :, 1:, 1], x[:, :, :3, 1])   # b=-1
    np.testing.assert_allclose(y[:, :, 0, 1], 0.0)


# ----------------------------------------------------------- properties ---
def test_grouped_equals_concat_of_group_convs():
    g, cx, cy = 3, 6, 9
    spec = ConvSpec(primitive="grouped", in_channels=cx, out_channels=cy,
                    kernel_size=3, groups=g, use_bias=False)
    p = init(KEY, spec)
    x = rand((2, 8, 8, cx))
    y = apply(p, x, spec)
    per = cy // g
    for gi in range(g):
        xg = x[..., gi * (cx // g):(gi + 1) * (cx // g)]
        wg = p["w"][..., gi * per:(gi + 1) * per]
        np.testing.assert_allclose(y[..., gi * per:(gi + 1) * per],
                                   standard_conv(xg, wg), rtol=1e-4, atol=1e-5)


def test_groups1_equals_standard():
    spec_g = ConvSpec(primitive="grouped", in_channels=4, out_channels=6, groups=1, use_bias=False)
    spec_s = ConvSpec(primitive="standard", in_channels=4, out_channels=6, use_bias=False)
    p = init(KEY, spec_g)
    x = rand((1, 6, 6, 4))
    np.testing.assert_allclose(apply(p, x, spec_g), apply({"w": p["w"]}, x, spec_s), rtol=1e-5)


def test_dws_is_depthwise_then_pointwise():
    spec = ConvSpec(primitive="dws", in_channels=4, out_channels=8, use_bias=False)
    p = init(KEY, spec)
    x = rand((2, 6, 6, 4))
    h = depthwise_conv(x, p["w_dw"])
    ref = standard_conv(h, p["w_pw"])
    np.testing.assert_allclose(apply(p, x, spec), ref, rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(["standard", "grouped", "dws", "shift"]),
       st.integers(1, 3))
def test_linearity_in_input(prim, seed):
    """Multiplicative primitives are linear maps in X (add-conv is not)."""
    spec = ConvSpec(primitive=prim, in_channels=4, out_channels=4,
                    groups=2 if prim == "grouped" else 1, use_bias=False)
    p = init(jax.random.PRNGKey(seed), spec)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 100))
    a, b = rand((1, 6, 6, 4), k1), rand((1, 6, 6, 4), k2)
    lhs = apply(p, a + 2.0 * b, spec)
    rhs = apply(p, a, spec) + 2.0 * apply(p, b, spec)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 5))
def test_add_conv_triangle_bound(seed):
    """|conv_add(x)| <= |x| L1 mass + |w| L1 mass * Hy^2 — sanity envelope."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x, w = rand((1, 5, 5, 3), k1), rand((3, 3, 3, 2), k2)
    y = add_conv(x, w)
    bound = jnp.sum(jnp.abs(x)) + 25 * jnp.sum(jnp.abs(w))
    assert bool(jnp.all(-y <= bound + 1e-3))


# ------------------------------------------------ Table 1 analytic check ---
@pytest.mark.parametrize("prim,expect_params", [
    ("standard", 3 * 3 * 16 * 32),
    ("grouped", 3 * 3 * 8 * 32),
    ("dws", 16 * (9 + 32)),
    ("shift", 16 * (2 + 32)),
    ("add", 3 * 3 * 16 * 32),
])
def test_param_count_matches_table1(prim, expect_params):
    spec = ConvSpec(primitive=prim, in_channels=16, out_channels=32,
                    kernel_size=3, groups=2 if prim == "grouped" else 1,
                    use_bias=False)
    assert spec.param_count() == expect_params
    p = init(KEY, spec)
    actual = sum(int(np.prod(v.shape)) for k, v in p.items()
                 if k != "shifts") + (2 * 16 if prim == "shift" else 0)
    assert actual == expect_params
