"""Paper-side CNN: training descends, PTQ integer path tracks float, every
primitive selectable end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, IndexedDataset
from repro.models.convnet import (CNNConfig, cnn_forward, cnn_loss, init_cnn,
                                  quantize_cnn)
from repro.optim import OptConfig, apply_updates, init_opt_state

PRIMS = ["standard", "grouped", "dws", "shift", "add"]


@pytest.mark.parametrize("prim", PRIMS)
def test_cnn_forward_all_primitives(prim):
    cfg = CNNConfig(primitive=prim, widths=(8, 12))
    p = init_cnn(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    logits = cnn_forward(p, x, cfg)
    assert logits.shape == (2, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("prim", ["standard", "shift"])
def test_cnn_trains(prim):
    cfg = CNNConfig(primitive=prim, widths=(8, 16), image_size=16)
    ds = IndexedDataset(DataConfig(kind="image", global_batch=32,
                                   image_size=16, seed=3))
    p = init_cnn(cfg, jax.random.PRNGKey(0))
    opt = OptConfig(lr=3e-3, warmup_steps=2, total_steps=40,
                    weight_decay=0.0)
    st = init_opt_state(p, opt)

    @jax.jit
    def step(p, st, batch):
        (l, acc), g = jax.value_and_grad(lambda q: cnn_loss(q, batch, cfg),
                                         has_aux=True, allow_int=True)(p)
        p, st, _ = apply_updates(p, g, st, opt)
        return p, st, l, acc

    losses = []
    for i in range(40):
        batch = jax.tree_util.tree_map(jnp.asarray, ds.batch(i))
        p, st, l, acc = step(p, st, batch)
        losses.append(float(l))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses[-5:]


@pytest.mark.parametrize("prim", PRIMS)
def test_cnn_ptq_integer_path_tracks_float(prim):
    cfg = CNNConfig(primitive=prim, widths=(8, 12), image_size=16)
    from repro.models.convnet import calibrate_bn
    p = init_cnn(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, 16, 3)) * 0.5
    p = calibrate_bn(p, cfg, x)
    logits_f = cnn_forward(p, x, cfg)
    int_fwd = quantize_cnn(p, cfg, x)
    logits_q = int_fwd(x)
    # int8 classification heads should mostly agree on argmax
    agree = float(jnp.mean((jnp.argmax(logits_f, -1) ==
                            jnp.argmax(logits_q, -1)).astype(jnp.float32)))
    assert agree >= 0.5, (prim, agree)
