"""Integer-only path through the kernel layer: pallas-vs-xla bit-exactness
for all five quantized primitives (qconv_apply method dispatch), the ops.py
requant threading, and the end-to-end quantized CNN accuracy bound."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ConvSpec, Primitives, apply, init, quantize, frac_bits_for
from repro.core.qconv import qconv_apply, quantize_conv_params
from repro.core.quantize import QTensor
from repro.kernels import ops as K
from repro.models.convnet import (CNNConfig, calibrate_bn, cnn_forward,
                                  init_cnn, quantize_cnn)

KEY = jax.random.PRNGKey(0)


def _quantized_layer(prim, *, with_bias=True, kernel_size=3):
    spec = ConvSpec(primitive=prim, in_channels=8, out_channels=12,
                    kernel_size=kernel_size,
                    groups=4 if prim == "grouped" else 1,
                    use_bias=with_bias)
    p = init(KEY, spec)
    if with_bias:
        # non-zero bias so the accumulator-scale bias path is exercised
        p["b"] = jax.random.normal(jax.random.PRNGKey(5), p["b"].shape) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 10, 10, 8)) * 0.5
    yf = apply(p, x, spec)
    return spec, quantize_conv_params(p, spec), quantize(x), frac_bits_for(yf), yf


@pytest.mark.parametrize("prim", Primitives)
def test_qconv_pallas_bit_exact_with_xla(prim):
    """Acceptance: method="pallas" == method="xla" bit-for-bit, all five."""
    spec, qp, xq, ofb, yf = _quantized_layer(prim)
    y_xla = qconv_apply(qp, xq, spec, ofb, method="xla")
    y_pal = qconv_apply(qp, xq, spec, ofb, method="pallas")
    assert y_xla.frac_bits == y_pal.frac_bits == ofb
    np.testing.assert_array_equal(np.asarray(y_xla.q), np.asarray(y_pal.q))
    # and both stay close to the float layer
    rel = float(jnp.mean(jnp.abs(y_pal.dequantize() - yf))
                / jnp.mean(jnp.abs(yf)))
    assert rel < 0.12, f"{prim}: quantized path diverged, rel {rel}"


@pytest.mark.parametrize("prim", Primitives)
def test_qconv_bit_exact_without_bias(prim):
    spec, qp, xq, ofb, _ = _quantized_layer(prim, with_bias=False)
    y_xla = qconv_apply(qp, xq, spec, ofb, method="xla")
    y_pal = qconv_apply(qp, xq, spec, ofb, method="pallas")
    np.testing.assert_array_equal(np.asarray(y_xla.q), np.asarray(y_pal.q))


def test_qconv_bit_exact_under_jit():
    spec, qp, xq, ofb, _ = _quantized_layer("standard")

    def run(method):
        fb = xq.frac_bits
        return jax.jit(lambda q: qconv_apply(qp, QTensor(q, fb), spec, ofb,
                                             method=method).q)(xq.q)
    np.testing.assert_array_equal(np.asarray(run("xla")),
                                  np.asarray(run("pallas")))


def test_qconv_unknown_method_rejected():
    spec, qp, xq, ofb, _ = _quantized_layer("standard")
    with pytest.raises(ValueError, match="method"):
        qconv_apply(qp, xq, spec, ofb, method="cuda")


@pytest.mark.parametrize("spec,out_shape", [
    (ConvSpec("standard", 4, 4, 3, stride=2), (1, 4, 4, 4)),
    (ConvSpec("dws", 4, 4, 3, stride=2), (1, 4, 4, 4)),
    (ConvSpec("shift", 4, 4, 3, stride=2), (1, 4, 4, 4)),
    (ConvSpec("add", 4, 4, 3, padding="VALID"), (1, 6, 6, 4)),
])
def test_qconv_outside_kernel_envelope_falls_back_xla(spec, out_shape):
    """Strided / VALID layers the kernel layer can't express keep working
    under method="xla" (raw-lax fallback, all five primitives) and reject
    method="pallas" with a clear error."""
    p = init(KEY, spec)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 8, 4)) * 0.5
    qp = quantize_conv_params(p, spec)
    xq = quantize(x)
    yf = apply(p, x, spec)
    ofb = frac_bits_for(yf)
    y = qconv_apply(qp, xq, spec, ofb, method="xla")     # raw-lax fallback
    assert y.q.shape == out_shape and y.q.dtype == jnp.int8
    rel = float(jnp.mean(jnp.abs(y.dequantize() - yf)) / jnp.mean(jnp.abs(yf)))
    assert rel < 0.15, f"{spec.primitive}: fallback diverged, rel {rel}"
    with pytest.raises(NotImplementedError, match="stride"):
        qconv_apply(qp, xq, spec, ofb, method="pallas")


# ------------------------------------------------- ops.py requant threading

def test_ops_depthwise_requant_threading():
    """Satellite: ops.depthwise2d no longer drops requant_shift — both
    methods run the integer epilogue and agree bit-for-bit."""
    x = jax.random.randint(KEY, (1, 8, 8, 8), -100, 100, jnp.int32).astype(jnp.int8)
    w = jax.random.randint(jax.random.PRNGKey(1), (3, 3, 8), -100, 100,
                           jnp.int32).astype(jnp.int8)
    got_p = K.depthwise2d(x, w, method="pallas", requant_shift=4)
    got_x = K.depthwise2d(x, w, method="xla", requant_shift=4)
    assert got_x.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(got_x))


def test_ops_shift_and_add_requant_threading():
    x = jax.random.randint(KEY, (1, 6, 6, 4), -100, 100, jnp.int32).astype(jnp.int8)
    shifts = np.array([[0, 1], [-1, 0], [1, -1], [0, 0]], np.int32)
    w_pw = jax.random.randint(jax.random.PRNGKey(1), (4, 8), -100, 100,
                              jnp.int32).astype(jnp.int8)
    b = (jnp.arange(8, dtype=jnp.int32) - 4) * 30
    got_p = K.shift_conv2d(x, shifts, w_pw, b, method="pallas", requant_shift=5)
    got_x = K.shift_conv2d(x, shifts, w_pw, b, method="xla", requant_shift=5)
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(got_x))

    w = jax.random.randint(jax.random.PRNGKey(2), (3, 3, 4, 8), -100, 100,
                           jnp.int32).astype(jnp.int8)
    got_p = K.add_conv2d(x, w, b, method="pallas", requant_shift=3, w_preshift=2)
    got_x = K.add_conv2d(x, w, b, method="xla", requant_shift=3, w_preshift=2)
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(got_x))


def test_ops_float_bias_rejected_where_unsupported():
    x = jax.random.normal(KEY, (1, 6, 6, 4))
    shifts = np.zeros((4, 2), np.int32)
    w_pw = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    b = jnp.zeros((8,), jnp.int32)
    with pytest.raises(ValueError, match="bias"):
        K.shift_conv2d(x, shifts, w_pw, b, method="xla")
    with pytest.raises(ValueError, match="requant_shift"):
        K.add_conv2d(x, jax.random.normal(KEY, (3, 3, 4, 8)), b, method="xla")


# ----------------------------------------------------- end-to-end CNN (PTQ)

@pytest.mark.parametrize("prim", ["standard", "dws", "shift"])
def test_quantize_cnn_end_to_end(prim):
    """PTQ accuracy-drop bound vs the float CNN + pallas/xla agreement."""
    cfg = CNNConfig(primitive=prim, widths=(8, 12), image_size=16,
                    in_channels=3, num_classes=10)
    params = init_cnn(cfg, jax.random.PRNGKey(2))
    calib = jax.random.normal(jax.random.PRNGKey(3), (8, 16, 16, 3)) * 0.5
    x = jax.random.normal(jax.random.PRNGKey(4), (16, 16, 16, 3)) * 0.5

    int_xla = quantize_cnn(params, cfg, calib, method="xla")
    int_pal = quantize_cnn(params, cfg, calib, method="pallas")
    lq_x, lq_p = int_xla(x), int_pal(x)
    # the integer trunk is bit-exact across methods; only the float head
    # (mean-pool @ head matmul over dequantized int8) runs per-method, so
    # logits agree to float tolerance
    np.testing.assert_allclose(np.asarray(lq_x), np.asarray(lq_p),
                               rtol=1e-5, atol=1e-5)

    # accuracy-drop bound: the quantized net predicts like the float net
    # (same BN calibration) on a clear majority of inputs
    lf = cnn_forward(calibrate_bn(params, cfg, calib), x, cfg)
    agree = float(jnp.mean((jnp.argmax(lq_x, -1) == jnp.argmax(lf, -1))
                           .astype(jnp.float32)))
    assert agree >= 0.75, f"{prim}: top-1 agreement {agree}"
    rel = float(jnp.mean(jnp.abs(lq_x - lf)) / jnp.mean(jnp.abs(lf)))
    assert rel < 0.35, f"{prim}: logits rel err {rel}"
