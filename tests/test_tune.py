"""repro.tune subsystem tests: search-space feasibility, analytic fallback,
cache roundtrip + schema versioning, dispatch integration, and ops-level
Pallas-vs-XLA parity across all five primitives (the dispatch layer resolves
schedules through the tuner, so parity here exercises the whole stack)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.kernels import ops
from repro.tune import cache as tcache

KEY = jax.random.PRNGKey(0)


def rnd(shape, dtype=jnp.float32, key=KEY, scale=1.0):
    if jnp.issubdtype(dtype, jnp.integer):
        return jax.random.randint(key, shape, -100, 100, jnp.int32).astype(dtype)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.fixture(autouse=True)
def _clean_tuner_state():
    """Each test starts from no persistent cache and an empty memo."""
    tune.set_default_cache(tune.TuneCache(None))
    yield
    tune.reset()


# ------------------------------------------------------------- space ------

ALL_SIGS = [
    tune.sig_conv2d(1, 8, 8, 4, 8, 3),
    tune.sig_conv2d(2, 12, 12, 16, 16, 5, 4),
    tune.sig_depthwise2d(1, 8, 8, 12, 3),
    tune.sig_shift_conv2d(1, 8, 8, 8, 12),
    tune.sig_add_conv2d(1, 6, 6, 4, 6, 3),
    tune.sig_causal_conv1d(2, 96, 48, 4),
    tune.sig_matmul(96, 64, 80),
]


@pytest.mark.parametrize("sig", ALL_SIGS, ids=lambda s: s.kernel + "/" + s.key())
def test_space_contains_default_and_is_finite(sig):
    cands = list(tune.candidates(sig))
    assert 1 <= len(cands) <= 64
    assert tune.default_config(sig.kernel) in cands
    # no duplicate configs
    keys = [tuple(sorted(c.items())) for c in cands]
    assert len(keys) == len(set(keys))


@pytest.mark.parametrize("sig", ALL_SIGS, ids=lambda s: s.kernel + "/" + s.key())
def test_analytic_fallback_is_feasible(sig):
    cfg = tune.analytic_config(sig, "float32")
    assert cfg in list(tune.candidates(sig))
    assert tune.estimate_s(sig, cfg, "float32") > 0


def test_unknown_kernel_rejected():
    with pytest.raises(ValueError):
        tune.default_config("bogus")
    with pytest.raises(ValueError):
        tune.ShapeSig("bogus", (("m", 8),))


# ------------------------------------------------------------- cache ------

def test_cache_roundtrip(tmp_path):
    path = str(tmp_path / "tuned.json")
    c = tune.TuneCache(None)
    key = tune.cache_key("matmul", "m64_k64_n64", "float32", "cpu+interpret")
    c.put(key, {"bm": 64, "bn": 64, "bk": 64}, us=12.5, source="measured")
    c.save(path)

    c2 = tune.TuneCache(path)
    assert not c2.stale
    entry = c2.get(key)
    assert entry["config"] == {"bm": 64, "bn": 64, "bk": 64}
    assert entry["us"] == 12.5
    assert entry["source"] == "measured"
    blob = json.load(open(path))
    assert blob["schema_version"] == tune.SCHEMA_VERSION


def test_cache_schema_version_mismatch(tmp_path):
    path = str(tmp_path / "stale.json")
    key = tune.cache_key("matmul", "m64_k64_n64", "float32", "cpu+interpret")
    blob = {"schema_version": tune.SCHEMA_VERSION + 1,
            "entries": {key: {"config": {"bm": 1}, "us": 1.0,
                              "source": "measured"}}}
    json.dump(blob, open(path, "w"))

    c = tune.TuneCache(path)
    assert c.stale
    assert len(c) == 0 and c.get(key) is None   # never misapply stale configs

    # dispatch falls back to the analytic schedule, not the stale entry
    tune.set_default_cache(c)
    cfg = tune.get_config(tune.sig_matmul(64, 64, 64), "float32")
    assert cfg != {"bm": 1}
    assert cfg in list(tune.candidates(tune.sig_matmul(64, 64, 64)))


def test_cache_corrupt_file_ignored(tmp_path):
    path = str(tmp_path / "corrupt.json")
    open(path, "w").write("{not json")
    c = tune.TuneCache(path)
    assert c.stale and len(c) == 0


def test_get_config_prefers_cache_then_memoizes():
    sig = tune.sig_matmul(64, 64, 64)
    tagged = tune.cache_key("matmul", sig.key(), "float32", tune.backend_tag())
    c = tune.TuneCache(None)
    c.put(tagged, {"bm": 32, "bn": 32, "bk": 32}, us=1.0)
    tune.set_default_cache(c)
    assert tune.get_config(sig, "float32") == {"bm": 32, "bn": 32, "bk": 32}
    # memo survives swapping the cache out (in-process memoization)
    tcache._default_cache = tune.TuneCache(None)
    assert tune.get_config(sig, "float32") == {"bm": 32, "bn": 32, "bk": 32}


def test_get_config_analytic_when_no_cache():
    sig = tune.sig_conv2d(1, 8, 8, 8, 16, 3)
    cfg = tune.get_config(sig, "float32")
    assert cfg in list(tune.candidates(sig))


# ---------------------------------------------------------- autotune ------

def test_autotune_records_best_and_default(tmp_path):
    a = rnd((32, 32))
    sig = tune.sig_matmul(32, 32, 32)
    c = tune.TuneCache(None)
    best, best_us = tune.autotune_into(c, "matmul", sig, (a, a), "float32",
                                       reps=1, warmup=1, max_candidates=3)
    key = tune.cache_key("matmul", sig.key(), "float32", tune.backend_tag())
    entry = c.get(key)
    assert entry["config"] == best and entry["source"] == "measured"
    assert entry["us"] == best_us > 0
    path = str(tmp_path / "t.json")
    c.save(path)
    tune.set_default_cache(tune.TuneCache(path))
    assert tune.get_config(sig, "float32") == best


# ------------------------------------- ops-level Pallas-vs-XLA parity -----
# The Pallas side resolves its schedule through the tuner (analytic
# fallback, then a planted cache entry) — parity across primitives, shapes
# and dtypes is the end-to-end guarantee the dispatch integration needs.

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    # (N, H, W, Cx, Cy, HK, groups)
    (1, 8, 8, 4, 8, 3, 1),
    (2, 10, 10, 8, 12, 5, 1),
    (1, 9, 9, 6, 9, 3, 3),
])
def test_ops_conv2d_parity(shape, dtype):
    n, h, w, cx, cy, hk, g = shape
    x = rnd((n, h, w, cx), dtype)
    wt = rnd((hk, hk, cx // g, cy), dtype, jax.random.PRNGKey(1))
    got = ops.conv2d(x, wt, groups=g)
    want = ops.conv2d(x, wt, groups=g, method="xla")
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,h,w,c,hk", [(1, 8, 8, 8, 3), (2, 10, 6, 16, 5)])
def test_ops_depthwise_parity(n, h, w, c, hk, dtype):
    x = rnd((n, h, w, c), dtype)
    wd = rnd((hk, hk, c), dtype, jax.random.PRNGKey(1))
    got = ops.depthwise2d(x, wd)
    want = ops.depthwise2d(x, wd, method="xla")
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,h,w,c,cy", [(1, 8, 8, 9, 8), (2, 6, 10, 18, 12)])
def test_ops_shift_parity(n, h, w, c, cy, dtype):
    x = rnd((n, h, w, c), dtype)
    shifts = jnp.array([[(i % 3) - 1, ((i // 3) % 3) - 1] for i in range(c)],
                       jnp.int32)
    wp = rnd((c, cy), dtype, jax.random.PRNGKey(1))
    got = ops.shift_conv2d(x, shifts, wp)
    want = ops.shift_conv2d(x, shifts, wp, method="xla")
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,h,w,cx,cy,hk", [(1, 6, 6, 4, 6, 3),
                                            (1, 8, 8, 3, 4, 5)])
def test_ops_add_parity(n, h, w, cx, cy, hk, dtype):
    x = rnd((n, h, w, cx), dtype)
    wt = rnd((hk, hk, cx, cy), dtype, jax.random.PRNGKey(1))
    got = ops.add_conv2d(x, wt)
    want = ops.add_conv2d(x, wt, method="xla")
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,l,d,k", [(1, 32, 16, 4), (2, 48, 24, 3)])
def test_ops_causal_conv1d_parity(b, l, d, k, dtype):
    x = rnd((b, l, d), dtype)
    w = rnd((k, d), dtype, jax.random.PRNGKey(1))
    got = ops.causal_conv1d(x, w, method="pallas")
    want = ops.causal_conv1d(x, w, method="xla")
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int8])
def test_ops_matmul_parity(dtype):
    a = rnd((48, 40), dtype)
    b = rnd((40, 56), dtype, jax.random.PRNGKey(1))
    shift = 6 if dtype == jnp.int8 else None
    got = ops.matmul(a, b, requant_shift=shift)
    want = ops.matmul(a, b, requant_shift=shift, method="xla")
    if dtype == jnp.int8:
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, **tol(dtype))


def test_ops_parity_with_planted_cache_config():
    """A cache entry with a non-default (still feasible) schedule must not
    change results, only the schedule."""
    sig = tune.sig_conv2d(1, 8, 8, 8, 16, 3)
    key = tune.cache_key("conv2d", sig.key(), "float32", tune.backend_tag())
    c = tune.TuneCache(None)
    c.put(key, {"block_co": 4}, us=1.0)
    tune.set_default_cache(c)
    x = rnd((1, 8, 8, 8))
    w = rnd((3, 3, 8, 16), key=jax.random.PRNGKey(1))
    np.testing.assert_allclose(ops.conv2d(x, w),
                               ops.conv2d(x, w, method="xla"),
                               rtol=2e-5, atol=2e-5)


def test_ops_explicit_config_overrides():
    x = rnd((1, 8, 8, 8))
    w = rnd((3, 3, 8, 16), key=jax.random.PRNGKey(1))
    np.testing.assert_allclose(ops.conv2d(x, w, config={"block_co": 2}),
                               ops.conv2d(x, w, method="xla"),
                               rtol=2e-5, atol=2e-5)
