"""repro.graph: lowering + single-jit integer executor.

Pins the four contracts of the refactor:
  * fused executor == the legacy float-bounce regime, bit-exact, for all
    five primitives (the fusion pass is exact, not approximate);
  * the fused-ReLU kernel epilogue is pallas/xla bit-exact per kernel;
  * the single calibration sweep annotates exactly what the old two-pass
    (calibrate_bn + quantize_cnn) pipeline computed;
  * the executor compiles ONCE (one jit for the whole plan) and keeps
    activations int8 between conv layers (zero float round-trips).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ConvSpec, Primitives, apply_block, fold, frac_bits_for
from repro.core.qconv import quantize_conv_params
from repro.core.quantize import QTensor, quantize
from repro.graph import (CompiledPlan, build_cnn_graph, lower,
                         unfused_forward)
from repro.kernels import ops as K
from repro.models.convnet import CNNConfig, calibrate_bn, cnn_forward, init_cnn

KEY = jax.random.PRNGKey(0)


def _lowered(prim, *, batch=4):
    cfg = CNNConfig(primitive=prim, widths=(8, 12), image_size=16)
    params = init_cnn(cfg, jax.random.PRNGKey(1))
    calib = jax.random.normal(jax.random.PRNGKey(2),
                              (batch, 16, 16, 3)) * 0.5
    plan = lower(build_cnn_graph(cfg), params, calib)
    return cfg, params, calib, plan


# ------------------------------------------------ fused vs legacy regime ---

@pytest.mark.parametrize("prim", Primitives)
def test_fused_bit_exact_with_legacy_float_bounce(prim):
    """Acceptance: the fused integer executor reproduces the pre-graph
    float-bounce path (dequantize -> float ReLU/pool -> requantize at the
    same annotated scales) bit for bit — fusing ReLU into the accumulator
    epilogue and pooling int8 codes is exact, not a numerics change."""
    cfg, params, calib, plan = _lowered(prim)
    x = jax.random.normal(jax.random.PRNGKey(3), calib.shape) * 0.5
    fused = CompiledPlan(plan, method="xla")(x)
    bounce = unfused_forward(plan, x, method="xla")
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(bounce))


@pytest.mark.parametrize("prim", Primitives)
def test_fused_pallas_bit_exact_with_xla(prim):
    """The whole-plan pallas engine == the xla oracle engine on the int8
    trunk (float head compared at float tolerance)."""
    cfg, params, calib, plan = _lowered(prim)
    x = jax.random.normal(jax.random.PRNGKey(4), calib.shape) * 0.5
    lx = CompiledPlan(plan, method="pallas")(x)
    lo = CompiledPlan(plan, method="xla")(x)
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lo),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("prim", ["standard", "dws", "add"])
def test_quantized_graph_tracks_float(prim):
    """PTQ through the graph still tracks the BN-calibrated float net."""
    cfg, params, calib, plan = _lowered(prim)
    x = jax.random.normal(jax.random.PRNGKey(5), calib.shape) * 0.5
    lf = cnn_forward(calibrate_bn(params, cfg, calib), x, cfg)
    lq = CompiledPlan(plan, method="xla")(x)
    agree = float(jnp.mean((jnp.argmax(lq, -1) == jnp.argmax(lf, -1))
                           .astype(jnp.float32)))
    assert agree >= 0.75, f"{prim}: top-1 agreement {agree}"


# -------------------------------------------------- fused-ReLU per kernel --

def _i8(shape, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), shape, -100, 100,
                              jnp.int32).astype(jnp.int8)


@pytest.mark.parametrize("kernel", ["conv2d", "depthwise2d", "shift_conv2d",
                                    "add_conv2d", "matmul"])
def test_fused_relu_pallas_bit_exact_per_kernel(kernel):
    """act='relu' at accumulator scale: pallas == xla bit-exact, and equals
    relu applied AFTER requantization (the epilogue commutes)."""
    if kernel == "conv2d":
        args = (_i8((1, 8, 8, 8)), _i8((3, 3, 8, 16), 1))
        kw = dict(requant_shift=5)
    elif kernel == "depthwise2d":
        args = (_i8((1, 8, 8, 8)), _i8((3, 3, 8), 1))
        kw = dict(requant_shift=4)
    elif kernel == "shift_conv2d":
        shifts = np.array([[(i % 3) - 1, ((i * 2) % 3) - 1] for i in range(8)],
                          np.int32)
        args = (_i8((1, 8, 8, 8)), shifts, _i8((8, 16), 1))
        kw = dict(requant_shift=5)
    elif kernel == "add_conv2d":
        args = (_i8((1, 6, 6, 4)), _i8((3, 3, 4, 8), 1))
        kw = dict(requant_shift=3, w_preshift=1)
    else:
        args = (_i8((32, 64)), _i8((64, 16), 1))
        kw = dict(requant_shift=6)
    fn = getattr(K, kernel)
    got_p = fn(*args, method="pallas", act="relu", **kw)
    got_x = fn(*args, method="xla", act="relu", **kw)
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(got_x))
    assert got_x.dtype == jnp.int8
    assert int(jnp.min(got_x)) >= 0
    # commutation: relu-before-shift == relu on the requantized int8
    post = jnp.maximum(fn(*args, method="xla", **kw), 0)
    np.testing.assert_array_equal(np.asarray(got_x), np.asarray(post))


def test_fused_relu_float_and_causal():
    """Float paths: act='relu' == relu(out) for conv2d and the causal-conv1d
    kernel (kernel-level epilogue; the differentiable ops wrapper stays
    linear)."""
    x = jax.random.normal(KEY, (1, 8, 8, 4))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 4, 8))
    got = K.conv2d(x, w, method="pallas", act="relu")
    want = jax.nn.relu(K.conv2d(x, w, method="xla"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)

    from repro.kernels import ref
    from repro.kernels.conv1d_causal import causal_conv1d
    xs = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 8))
    ws = jax.random.normal(jax.random.PRNGKey(3), (4, 8))
    got = causal_conv1d(xs, ws, block_l=8, block_c=8, act="relu")
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.causal_conv1d_ref(xs, ws,
                                                                act="relu")),
                               rtol=2e-5, atol=2e-5)


def test_maxpool2d_int8_pallas_bit_exact():
    x = _i8((2, 10, 10, 8))
    got = K.maxpool2d(x, method="pallas")
    want = K.maxpool2d(x, method="xla")
    assert got.dtype == jnp.int8 and got.shape == (2, 5, 5, 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # pooling int8 codes == pooling the dequantized floats (max commutes)
    yf = K.maxpool2d(x.astype(jnp.float32) * 2.0 ** -5, method="xla")
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray((yf * 2.0 ** 5).astype(jnp.int8)))


# --------------------------------------- single-sweep calibration parity ---

@pytest.mark.parametrize("prim", Primitives)
def test_single_sweep_matches_two_pass_ptq(prim):
    """The one-sweep lowering annotates exactly what the old two-pass
    pipeline (calibrate_bn, then a second calibration pass inside
    quantize_cnn) computed: same folded+quantized weights, same per-layer
    output frac bits."""
    cfg, params, calib, plan = _lowered(prim)
    from repro.models.convnet import _specs

    # --- the old two-pass pipeline, inline -------------------------------
    p2 = calibrate_bn(params, cfg, calib)       # pass 1: BN stats
    specs = _specs(cfg)
    h = calib
    legacy = []
    for p, s in zip(p2["blocks"], specs):       # pass 2: scales + folding
        float_out = apply_block(p, h, s)
        if s.primitive != "add":
            qp = quantize_conv_params(fold(p["conv"], p["bn"], s), s)
        else:
            qp = quantize_conv_params(p["conv"], s)
        legacy.append((qp, frac_bits_for(float_out)))
        h = jax.lax.reduce_window(float_out, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")

    conv_nodes = plan.conv_nodes()
    assert len(conv_nodes) == len(legacy)
    qbn_fbs = [n.out_fb for n in plan.nodes if n.op == "qbn"]
    for node, (qp, ofb) in zip(conv_nodes, legacy):
        if node.spec.primitive != "add":
            assert node.out_fb == ofb, node.name
        else:
            # add: the block's post-BN+ReLU scale lives on its qbn node
            assert qbn_fbs.pop(0) == ofb, node.name
        for k, v in qp.items():
            got = node.qparams[k]
            if isinstance(v, QTensor):
                assert got.frac_bits == v.frac_bits, (node.name, k)
                np.testing.assert_array_equal(np.asarray(got.q),
                                              np.asarray(v.q))
            else:                        # shift tables
                np.testing.assert_array_equal(np.asarray(got), np.asarray(v))


# ------------------------------------------------------- executor contract --

def test_executor_compiles_once():
    """One jit for the whole plan: repeated calls (same shape) never
    retrace; a new batch shape retraces exactly once more."""
    cfg, params, calib, plan = _lowered("standard")
    ex = CompiledPlan(plan, method="xla")
    x = jax.random.normal(jax.random.PRNGKey(6), calib.shape) * 0.5
    for _ in range(3):
        ex(x)
    assert ex.traces == 1
    ex(x[:2])
    assert ex.traces == 2


def test_executor_trunk_stays_int8():
    """Zero float round-trips between conv layers: every pre-head plan node
    produces an int8 QTensor (ReLU+pool included)."""
    cfg, params, calib, plan = _lowered("add")   # add: hardest case (qbn)
    ex = CompiledPlan(plan, method="xla", jit=False)
    h = quantize(calib, plan.in_fb)
    for node in plan.nodes:
        h = ex._run_node(node, h)
        if node.op in ("qconv", "qbn", "maxpool"):
            assert isinstance(h, QTensor) and h.q.dtype == jnp.int8, node.name
    assert h.shape == (calib.shape[0], cfg.num_classes)


def test_executor_resolves_configs_once_per_node():
    cfg, params, calib, plan = _lowered("dws", batch=2)
    ex = CompiledPlan(plan, method="pallas")
    x = jax.random.normal(jax.random.PRNGKey(7), calib.shape) * 0.5
    ex(x)
    names = {n.name for n in plan.conv_nodes()}
    assert set(ex.node_configs) == names
    assert all(isinstance(c, dict) and c for c in ex.node_configs.values())
    # dws nodes carry a schedule per stage (the stem stays standard)
    dws_names = [n.name for n in plan.conv_nodes()
                 if n.spec.primitive == "dws"]
    assert dws_names, "config lacks a dws layer"
    for name in dws_names:
        assert {"dw", "pw"} <= set(ex.node_configs[name])


def test_plan_rejects_method_conflicts():
    cfg, params, calib, plan = _lowered("standard")
    with pytest.raises(ValueError, match="method"):
        CompiledPlan(plan, method="cuda")


def test_pallas_raises_outside_kernel_envelope_auto_degrades():
    """An explicit method='pallas' is a guarantee, not a preference: a
    stride-2 layer (outside the kernel envelope) raises instead of silently
    running the oracle; method='auto' degrades that node to xla and matches
    the pure-oracle plan bit for bit."""
    from repro.core import init
    from repro.graph import Graph, Node
    spec = ConvSpec("standard", 3, 8, 3, stride=2)
    g = Graph((Node("conv0", "conv", ("x",), spec=spec),
               Node("gap", "gap", ("conv0",)),
               Node("head", "dense", ("gap",),)))
    params = {"blocks": [{"conv": init(jax.random.PRNGKey(0), spec)}],
              "head": jax.random.normal(jax.random.PRNGKey(1), (8, 10)) * 0.3}
    calib = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 16, 3)) * 0.5
    plan = lower(g, params, calib)
    with pytest.raises(NotImplementedError, match="stride"):
        CompiledPlan(plan, method="pallas")(calib)
    got = CompiledPlan(plan, method="auto")(calib)
    want = CompiledPlan(plan, method="xla")(calib)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
