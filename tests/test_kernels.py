"""Per-kernel validation: interpret=True Pallas vs pure-jnp oracle (ref.py),
sweeping shapes and dtypes as required for each kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.conv_add import add_conv2d
from repro.kernels.conv_dw import depthwise2d
from repro.kernels.conv_im2col import conv2d_im2col
from repro.kernels.conv_shift import shift_conv2d
from repro.kernels.conv1d_causal import causal_conv1d
from repro.kernels.matmul_q8 import matmul

KEY = jax.random.PRNGKey(0)


def rnd(shape, dtype=jnp.float32, key=KEY, scale=1.0):
    if jnp.issubdtype(dtype, jnp.integer):
        return jax.random.randint(key, shape, -100, 100, jnp.int32).astype(dtype)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------ conv_im2col --
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    # (N, H, W, Cx, Cy, HK, groups)
    (1, 8, 8, 4, 8, 3, 1),
    (2, 12, 12, 16, 16, 5, 1),
    (1, 9, 9, 6, 9, 3, 3),
    (2, 16, 16, 8, 12, 1, 2),
    (1, 7, 5, 3, 4, 3, 1),      # non-square, odd dims
])
def test_conv_im2col(shape, dtype):
    n, h, w, cx, cy, hk, g = shape
    x = rnd((n, h, w, cx), dtype)
    wt = rnd((hk, hk, cx // g, cy), dtype, jax.random.PRNGKey(1))
    got = conv2d_im2col(x, wt, groups=g, block_co=4)
    want = ref.conv2d_ref(x, wt, groups=g)
    np.testing.assert_allclose(got.astype(jnp.float32), want.astype(jnp.float32), **tol(dtype))


@pytest.mark.parametrize("shift", [0, 3, 7, -1])
def test_conv_im2col_int8(shift):
    x = rnd((2, 8, 8, 8), jnp.int8)
    w = rnd((3, 3, 8, 16), jnp.int8, jax.random.PRNGKey(2))
    got = conv2d_im2col(x, w, requant_shift=shift)
    want = ref.conv2d_q8_ref(x, w, requant_shift=shift)
    np.testing.assert_array_equal(got, want)        # integer path: bit exact


def test_conv_im2col_int8_bias():
    x = rnd((1, 6, 6, 4), jnp.int8)
    w = rnd((3, 3, 4, 8), jnp.int8, jax.random.PRNGKey(3))
    b = jnp.arange(8, dtype=jnp.int32) * 50
    got = conv2d_im2col(x, w, bias=b, requant_shift=5)
    want = ref.conv2d_q8_ref(x, w, b, requant_shift=5)
    np.testing.assert_array_equal(got, want)


# -------------------------------------------------------------- conv_dw ---
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,h,w,c,hk", [(1, 8, 8, 8, 3), (2, 10, 6, 16, 5), (1, 5, 5, 3, 1)])
def test_depthwise(n, h, w, c, hk, dtype):
    x = rnd((n, h, w, c), dtype)
    wd = rnd((hk, hk, c), dtype, jax.random.PRNGKey(1))
    got = depthwise2d(x, wd, block_c=4)
    want = ref.depthwise2d_ref(x, wd)
    np.testing.assert_allclose(got.astype(jnp.float32), want.astype(jnp.float32), **tol(dtype))


def test_depthwise_int8():
    x = rnd((1, 6, 6, 8), jnp.int8)
    wd = rnd((3, 3, 8), jnp.int8, jax.random.PRNGKey(1))
    got = depthwise2d(x, wd, requant_shift=4)
    want = ref.depthwise2d_q8_ref(x, wd, requant_shift=4)
    np.testing.assert_array_equal(got, want)
    # golden: round-to-nearest epilogue (NNoM default build), not floor
    acc = ref.depthwise2d_ref(x.astype(jnp.int32), wd.astype(jnp.int32))
    rounded = jnp.clip(jnp.right_shift(acc + 8, 4), -128, 127).astype(jnp.int8)
    np.testing.assert_array_equal(got, rounded)


# ------------------------------------------------------------ conv_shift --
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("c,cy,h", [(4, 8, 8), (9, 6, 10), (16, 16, 12)])
def test_shift_conv(c, cy, h, dtype):
    x = rnd((2, h, h, c), dtype)
    grid = [(a, b) for a in (-1, 0, 1) for b in (-1, 0, 1)]
    shifts = np.array([grid[i % 9] for i in range(c)], np.int32)
    w = rnd((c, cy), dtype, jax.random.PRNGKey(1))
    got = shift_conv2d(x, shifts, w, block_co=4)
    want = ref.shift_conv2d_ref(x, shifts, w)
    np.testing.assert_allclose(got.astype(jnp.float32), want.astype(jnp.float32), **tol(dtype))


def test_shift_conv_int8():
    c, cy = 6, 8
    x = rnd((1, 8, 8, c), jnp.int8)
    shifts = np.array([[(i % 3) - 1, ((i * 2) % 3) - 1] for i in range(c)], np.int32)
    w = rnd((c, cy), jnp.int8, jax.random.PRNGKey(1))
    got = shift_conv2d(x, shifts, w, requant_shift=5)
    want = ref.shift_conv2d_q8_ref(x, shifts, w, requant_shift=5)
    np.testing.assert_array_equal(got, want)
    from repro.core.primitives import shift_channels, standard_conv
    acc = standard_conv(shift_channels(x.astype(jnp.int32), jnp.asarray(shifts)),
                        w[None, None].astype(jnp.int32))
    # golden: + (1 << (shift-1)) rounding term before the arithmetic shift
    rounded = jnp.clip(jnp.right_shift(acc + 16, 5), -128, 127).astype(jnp.int8)
    np.testing.assert_array_equal(got, rounded)


# -------------------------------------------------------------- conv_add --
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("cx,cy,hk", [(4, 8, 3), (3, 5, 5), (8, 4, 1)])
def test_add_conv(cx, cy, hk, dtype):
    x = rnd((2, 7, 7, cx), dtype)
    w = rnd((hk, hk, cx, cy), dtype, jax.random.PRNGKey(1))
    got = add_conv2d(x, w, block_co=2)
    want = ref.add_conv2d_ref(x, w)
    np.testing.assert_allclose(got.astype(jnp.float32), want.astype(jnp.float32), **tol(dtype))


def test_add_conv_int8_algorithm1():
    """int path incl. the Algorithm-1 (right) scale alignment pre-shift."""
    x = rnd((1, 6, 6, 4), jnp.int8)
    w = rnd((3, 3, 4, 6), jnp.int8, jax.random.PRNGKey(1))
    # fb_x=5, fb_w=3 -> align w by <<2, acc fb=5, out fb=2 -> shift 3
    got = add_conv2d(x, w, requant_shift=3, w_preshift=2)
    from repro.core.primitives import add_conv
    acc = add_conv(x.astype(jnp.float32), (w.astype(jnp.float32) * 4.0))
    want = jnp.clip(jnp.floor(acc / 8.0), -128, 127).astype(jnp.int8)
    np.testing.assert_allclose(got, want, atol=1)   # float ref rounding slack


# --------------------------------------------------------- conv1d_causal --
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,l,d,k,bl", [
    (2, 16, 8, 4, 8), (1, 64, 16, 4, 16), (3, 10, 4, 2, 5), (1, 8, 4, 4, 8),
])
def test_causal_conv1d(b, l, d, k, bl, dtype):
    x = rnd((b, l, d), dtype)
    w = rnd((k, d), dtype, jax.random.PRNGKey(1))
    got = causal_conv1d(x, w, block_l=bl, block_c=4)
    want = ref.causal_conv1d_ref(x, w)
    np.testing.assert_allclose(got.astype(jnp.float32), want.astype(jnp.float32), **tol(dtype))


def test_causal_conv1d_is_causal():
    """Changing x[t0:] must not change outputs before t0."""
    x = rnd((1, 32, 4))
    w = rnd((4, 4), key=jax.random.PRNGKey(1))
    y1 = causal_conv1d(x, w, block_l=8, block_c=4)
    x2 = x.at[:, 20:, :].set(99.0)
    y2 = causal_conv1d(x2, w, block_l=8, block_c=4)
    np.testing.assert_allclose(y1[:, :20], y2[:, :20], rtol=1e-6)


# ------------------------------------------------------------- matmul_q8 --
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (32, 64, 16, 16, 8, 32), (128, 128, 128, 64, 64, 64), (8, 16, 8, 8, 8, 8),
])
def test_matmul(m, k, n, bm, bn, bk, dtype):
    a = rnd((m, k), dtype, scale=0.3)
    b = rnd((k, n), dtype, jax.random.PRNGKey(1), scale=0.3)
    got = matmul(a, b, bm=bm, bn=bn, bk=bk)
    want = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
    np.testing.assert_allclose(got.astype(jnp.float32), want,
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-1 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("shift", [0, 4, 8])
def test_matmul_int8(shift):
    a = rnd((64, 96), jnp.int8)
    b = rnd((96, 32), jnp.int8, jax.random.PRNGKey(1))
    got = matmul(a, b, bm=32, bn=16, bk=32, requant_shift=shift)
    want = ref.matmul_ref(a, b, requant_shift=shift)
    np.testing.assert_array_equal(got, want)


# ------------------------------------------- shift_channels padding bound --
def test_shift_channels_large_shift_jit():
    """Regression: the traced-shift fallback used a hard-coded pad=8, which
    silently corrupted results for |shift| > 8 (kernel_size > 17). With the
    bound passed explicitly the jitted gather must match the concrete one."""
    from repro.core.primitives import shift_channels
    c, s = 4, 9                                  # |shift|=9 broke pad=8
    x = rnd((1, 24, 24, c))
    shifts = jnp.array([[s, -s], [-s, s], [s, s], [0, -s]], jnp.int32)
    want = shift_channels(x, shifts)             # concrete: tight bound
    got = jax.jit(lambda xx, ss: shift_channels(xx, ss, max_shift=s))(x, shifts)
    np.testing.assert_array_equal(got, want)


def test_shift_channels_traced_without_bound_raises():
    from repro.core.primitives import shift_channels
    x = rnd((1, 8, 8, 2))
    shifts = jnp.array([[1, 0], [0, 1]], jnp.int32)
    with pytest.raises(ValueError, match="max_shift"):
        jax.jit(shift_channels)(x, shifts)


def test_shift_channels_bound_violation_raises():
    from repro.core.primitives import shift_channels
    x = rnd((1, 8, 8, 2))
    shifts = jnp.array([[5, 0], [0, -5]], jnp.int32)
    with pytest.raises(ValueError, match="exceeding"):
        shift_channels(x, shifts, max_shift=2)


# ----------------------------------------------- ops method= validation ---
def test_ops_xla_with_explicit_config_rejected():
    """Satellite: method='xla' used to silently ignore an explicit config=
    (and matmul its bm/bn/bk); now it raises the conflicting-arguments
    error, mirroring _check_method."""
    from repro.kernels import ops
    x = rnd((1, 8, 8, 4))
    w = rnd((3, 3, 4, 8), key=jax.random.PRNGKey(1))
    for fn, args in [
        (ops.conv2d, (x, w)),
        (ops.depthwise2d, (x, rnd((3, 3, 4)))),
        (ops.add_conv2d, (x, w)),
        (ops.shift_conv2d, (x, jnp.zeros((4, 2), jnp.int32), rnd((4, 8)))),
        (ops.causal_conv1d, (rnd((1, 16, 4)), rnd((4, 4)))),
        (ops.matmul, (rnd((8, 8)), rnd((8, 8)))),
        (ops.maxpool2d, (rnd((1, 8, 8, 4)),)),
    ]:
        with pytest.raises(ValueError, match="config"):
            fn(*args, method="xla", config={"block_co": 8})
    with pytest.raises(ValueError, match="config"):
        ops.matmul(rnd((8, 8)), rnd((8, 8)), method="xla", bm=8)
    # pallas keeps accepting explicit schedules
    got = ops.matmul(rnd((8, 8)), rnd((8, 8), key=jax.random.PRNGKey(2)),
                     method="pallas", bm=8, bn=8, bk=8)
    assert got.shape == (8, 8)


def test_ops_maxpool2d_shapes_and_parity():
    x = rnd((2, 9, 9, 4))
    got = ops_maxpool_both(x, window=3, stride=3)
    assert got[0].shape == (2, 3, 3, 4)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(got[1]))


def ops_maxpool_both(x, **kw):
    from repro.kernels import ops
    return ops.maxpool2d(x, method="pallas", **kw), \
        ops.maxpool2d(x, method="xla", **kw)


def test_ops_unknown_method_rejected():
    from repro.kernels import ops
    x = rnd((1, 8, 8, 4))
    w = rnd((3, 3, 4, 8), key=jax.random.PRNGKey(1))
    for fn, args in [
        (ops.conv2d, (x, w)),
        (ops.depthwise2d, (x, rnd((3, 3, 4)))),
        (ops.add_conv2d, (x, w)),
        (ops.shift_conv2d, (x, jnp.zeros((4, 2), jnp.int32), rnd((4, 8)))),
        (ops.causal_conv1d, (rnd((1, 16, 4)), rnd((4, 4)))),
        (ops.matmul, (rnd((8, 8)), rnd((8, 8)))),
    ]:
        with pytest.raises(ValueError, match="unknown method"):
            fn(*args, method="nope")
