"""Substrate tests: data determinism, optimizer math, checkpoint/restore
(incl. elastic + atomicity), trainer fault tolerance, serve engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, IndexedDataset
from repro.optim import (OptConfig, apply_updates, clip_by_global_norm,
                         init_opt_state, schedule)
from repro.checkpoint import Checkpointer


# ------------------------------------------------------------------ data --
def test_data_deterministic_and_resumable():
    ds = IndexedDataset(DataConfig(kind="lm", vocab=100, seq_len=16,
                                   global_batch=4, seed=3))
    a = ds.batch(7)["tokens"]
    b = ds.batch(7)["tokens"]
    np.testing.assert_array_equal(a, b)          # pure function of index
    c = ds.batch(8)["tokens"]
    assert not np.array_equal(a, c)


def test_data_host_shards_disjoint_and_cover():
    cfg = DataConfig(kind="lm", vocab=100, seq_len=8, global_batch=8, seed=1)
    full = [IndexedDataset(cfg, host_id=h, num_hosts=4).batch(3)["tokens"]
            for h in range(4)]
    assert all(f.shape == (2, 9) for f in full)
    flat = np.concatenate(full)
    # different hosts draw from independent streams
    assert len({arr.tobytes() for arr in full}) == 4
    assert flat.shape == (8, 9)


def test_image_data_learnable_structure():
    ds = IndexedDataset(DataConfig(kind="image", global_batch=64, seed=0))
    b = ds.batch(0)
    assert b["images"].shape == (64, 32, 32, 3)
    # class-conditional means differ (separable signal exists)
    m0 = b["images"][b["labels"] == b["labels"][0]].mean()
    others = b["images"][b["labels"] != b["labels"][0]]
    assert others.size == 0 or abs(m0 - others.mean()) >= 0.0


# ----------------------------------------------------------------- optim --
def test_adamw_matches_reference_math():
    opt = OptConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                    grad_clip=1e9, warmup_steps=0, total_steps=10,
                    min_lr_ratio=1.0)
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.25])}
    st = init_opt_state(p, opt)
    new_p, st, _ = apply_updates(p, g, st, opt)
    m = 0.1 * np.array([0.5, 0.25])
    v = 0.01 * np.array([0.25, 0.0625])
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = np.array([1.0, -2.0]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(new_p["w"], want, rtol=1e-5)


def test_grad_clip_global_norm():
    g = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 10.0) < 1e-4
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in
                         jax.tree_util.tree_leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-4


def test_schedule_warmup_and_cosine():
    opt = OptConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
    assert float(schedule(opt, jnp.array(5))) == pytest.approx(0.5)
    assert float(schedule(opt, jnp.array(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(schedule(opt, jnp.array(110))) == pytest.approx(0.1, abs=1e-3)


def test_optimizer_state_dtype_override():
    opt = OptConfig(state_dtype="bfloat16")
    st = init_opt_state({"w": jnp.zeros((3,), jnp.float32)}, opt)
    assert st["m"]["w"].dtype == jnp.bfloat16


# ------------------------------------------------------------ checkpoint --
def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    ck.save(5, tree)
    out, step = ck.restore(tree)
    assert step == 5
    np.testing.assert_array_equal(out["a"], tree["a"])
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_keep_n_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    t = {"x": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        ck.save(s, t)
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_ignores_uncommitted(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=5, async_save=False)
    t = {"x": jnp.arange(3)}
    ck.save(1, t)
    # simulate a crash mid-write: directory without marker
    os.makedirs(tmp_path / "step_000000002")
    assert ck.latest_step() == 1


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=1, async_save=True)
    t = {"x": jnp.arange(10)}
    ck.save(7, t)
    ck.wait()
    out, step = ck.restore(t)
    assert step == 7


def test_checkpoint_dtype_cast_on_restore(tmp_path):
    """Elastic-style restore into different dtype (e.g. serve bf16)."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, {"w": jnp.arange(4, dtype=jnp.float32)})
    out, _ = ck.restore({"w": jnp.zeros(4, jnp.bfloat16)})
    assert out["w"].dtype == jnp.bfloat16


# --------------------------------------------------------------- trainer --
def _mk_trainer(tmp_path, total_steps=12, ckpt_every=4, sched_steps=12):
    import dataclasses
    from repro.configs import get_config
    from repro.models import api
    from repro.train import LoopConfig, TrainConfig, Trainer
    cfg = dataclasses.replace(get_config("qwen2-0.5b"), n_layers=2, d_model=32,
                              n_heads=2, n_kv_heads=1, d_ff=64, vocab=64)
    # LR schedule horizon is pinned independently of how far this segment
    # runs, so interrupted and uninterrupted runs follow the same schedule
    tcfg = TrainConfig(opt=OptConfig(lr=1e-2, warmup_steps=2,
                                     total_steps=sched_steps))
    loop = LoopConfig(total_steps=total_steps, ckpt_every=ckpt_every,
                      ckpt_dir=str(tmp_path), log_every=0)
    ds = IndexedDataset(DataConfig(kind="lm", vocab=64, seq_len=16,
                                   global_batch=4, seed=5))
    tr = Trainer(cfg, tcfg, loop, ds,
                 init_params_fn=lambda k: api.init_params(cfg, k))
    return tr


def test_trainer_runs_and_loss_decreases(tmp_path):
    tr = _mk_trainer(tmp_path, total_steps=12)
    _, _, step, hist = tr.run()
    assert step == 12 and len(hist) == 12
    assert hist[-1]["loss"] < hist[0]["loss"] + 0.5


def test_trainer_resume_reproduces_uninterrupted_run(tmp_path):
    """Kill at step 6, resume -> identical losses to a straight 12-step run."""
    tr_full = _mk_trainer(tmp_path / "a", total_steps=12, ckpt_every=6)
    _, _, _, hist_full = tr_full.run()

    tr1 = _mk_trainer(tmp_path / "b", total_steps=6, ckpt_every=6)
    tr1.run()
    tr2 = _mk_trainer(tmp_path / "b", total_steps=12, ckpt_every=6)
    params, opt_state, start = tr2.init_or_restore()
    assert start == 6
    _, _, _, hist2 = tr2.run(params, opt_state, start)
    full_tail = [h["loss"] for h in hist_full if h["step"] >= 6]
    resumed = [h["loss"] for h in hist2]
    np.testing.assert_allclose(full_tail, resumed, rtol=1e-4, atol=1e-5)


def test_heartbeat_straggler_detection():
    from repro.train import HeartbeatMonitor
    mon = HeartbeatMonitor(factor=3.0)
    for _ in range(10):
        mon.beat(0.1)
    assert mon.beat(0.5) is True
    assert mon.stragglers == 1
    assert mon.beat(0.11) is False


# ----------------------------------------------------------------- serve --
def test_serve_engine_batched(tmp_path):
    import dataclasses
    from repro.configs import get_config
    from repro.models import api
    from repro.serve import Engine, Request, ServeConfig
    cfg = dataclasses.replace(get_config("granite-3-2b"), n_layers=2,
                              d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                              vocab=64)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    # continuous (default): one per-slot prefill per admitted request
    eng = Engine(cfg, params, ServeConfig(max_batch=3, max_len=32))
    for i in range(5):
        eng.submit(Request(uid=i, prompt=np.arange(3 + i, dtype=np.int32),
                           max_new_tokens=4))
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 4 for r in done)
    assert eng.stats["prefills"] == 5
    # static drain: batched prefills
    eng = Engine(cfg, params, ServeConfig(max_batch=3, max_len=32,
                                          scheduler="static"))
    for i in range(5):
        eng.submit(Request(uid=i, prompt=np.arange(3 + i, dtype=np.int32),
                           max_new_tokens=4))
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 4 for r in done)
    assert eng.stats["prefills"] == 2            # 3 + 2 batched
