"""Batched / spatially-tiled kernel schedules + the throughput serving path.

Pins the contracts of the tiled-grid rewrite:
  * batched-vs-looped bit-exactness for every primitive + matmul (int8 AND
    float), on odd H/W (ragged halo tiles) and non-pow2 N (ragged batch
    blocks) under explicit block_n/block_h/block_w schedules;
  * ``CompiledPlan.forward_batch`` == the per-sample loop (int8 trunk
    bit-exact; float head at tight tolerance) and compiles once per pow2
    batch bucket (compile-count asserted);
  * the v2 tune space carries the new knobs, resolves them through the
    same ``batch_spatial_schedule`` the kernels run, and refuses v1 caches;
  * ``repro.serve.CNNEngine`` admits queued image requests into batch
    rounds and returns every request's logits.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.core import Primitives
from repro.core.quantize import QTensor, quantize
from repro.graph import CompiledPlan, build_cnn_graph, lower
from repro.kernels import ops
from repro.kernels.conv_add import add_conv2d
from repro.kernels.conv_dw import depthwise2d
from repro.kernels.conv_im2col import conv2d_im2col
from repro.kernels.conv_shift import shift_conv2d
from repro.kernels.matmul_q8 import matmul
from repro.kernels.pool import maxpool2d
from repro.models.convnet import CNNConfig, init_cnn

KEY = jax.random.PRNGKey(0)

# non-pow2 batch and odd H/W: exercises ragged batch blocks (block_n=4 on
# N=5 degrades through effective_block) and ragged final halo tiles
N, H, W = 5, 9, 7


def rnd(shape, dtype=jnp.float32, key=KEY):
    if jnp.issubdtype(dtype, jnp.integer):
        return jax.random.randint(key, shape, -100, 100, jnp.int32).astype(dtype)
    return jax.random.normal(key, shape).astype(dtype)


@pytest.fixture(autouse=True)
def _clean_tuner_state():
    tune.set_default_cache(tune.TuneCache(None))
    yield
    tune.reset()


# ----------------------------------------- kernel-level batched == looped --

TILED_CFG = {"block_n": 4, "block_h": 4, "block_w": 4}


def _assert_batched_equals_looped(fn, x, *args, cfg, **kw):
    """fn(batch, config=tiled) must equal the per-image loop at the default
    (untiled) schedule, bitwise — the tiled grid reorders DMA, never math."""
    got = fn(x, *args, config=cfg, **kw)
    loop = jnp.concatenate([fn(x[i:i + 1], *args, **kw)
                            for i in range(x.shape[0])])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(loop))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int8])
def test_conv2d_batched_vs_looped(dtype):
    x = rnd((N, H, W, 4), dtype)
    w = rnd((3, 3, 4, 8), dtype, jax.random.PRNGKey(1))
    kw = dict(requant_shift=5) if dtype == jnp.int8 else {}
    _assert_batched_equals_looped(conv2d_im2col, x, w,
                                  cfg={**TILED_CFG, "block_co": 4}, **kw)


def test_conv2d_grouped_batched_vs_looped():
    x = rnd((N, H, W, 6), jnp.int8)
    w = rnd((3, 3, 2, 9), jnp.int8, jax.random.PRNGKey(1))
    _assert_batched_equals_looped(conv2d_im2col, x, w,
                                  cfg={**TILED_CFG, "block_co": 3},
                                  groups=3, requant_shift=4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int8])
def test_depthwise_batched_vs_looped(dtype):
    x = rnd((N, H, W, 8), dtype)
    w = rnd((3, 3, 8), dtype, jax.random.PRNGKey(1))
    kw = dict(requant_shift=4) if dtype == jnp.int8 else {}
    _assert_batched_equals_looped(depthwise2d, x, w,
                                  cfg={**TILED_CFG, "block_c": 4}, **kw)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int8])
def test_shift_batched_vs_looped(dtype):
    c, cy = 6, 8
    x = rnd((N, H, W, c), dtype)
    shifts = np.array([[(i % 3) - 1, ((i * 2) % 3) - 1] for i in range(c)],
                      np.int32)
    w = rnd((c, cy), dtype, jax.random.PRNGKey(1))
    kw = dict(requant_shift=5) if dtype == jnp.int8 else {}
    _assert_batched_equals_looped(shift_conv2d, x, shifts, w,
                                  cfg={**TILED_CFG, "block_co": 4}, **kw)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int8])
def test_add_batched_vs_looped(dtype):
    x = rnd((N, H, W, 4), dtype)
    w = rnd((3, 3, 4, 6), dtype, jax.random.PRNGKey(1))
    kw = dict(requant_shift=3, w_preshift=1) if dtype == jnp.int8 else {}
    _assert_batched_equals_looped(add_conv2d, x, w,
                                  cfg={**TILED_CFG, "block_co": 2}, **kw)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int8])
def test_pool_batched_vs_looped(dtype):
    x = rnd((N, 11, 9, 8), dtype)
    got = maxpool2d(x, window=3, stride=2,
                    config={**TILED_CFG, "block_h": 2, "block_w": 2,
                            "block_c": 4})
    loop = jnp.concatenate([maxpool2d(x[i:i + 1], window=3, stride=2)
                            for i in range(N)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(loop))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int8])
def test_matmul_batched_vs_looped(dtype):
    a = rnd((3, 16, 24), dtype)
    b = rnd((24, 8), dtype, jax.random.PRNGKey(1))
    kw = dict(requant_shift=5) if dtype == jnp.int8 else {}
    got = matmul(a, b, bm=16, bn=8, bk=16, **kw)
    loop = jnp.stack([matmul(a[i], b, bm=16, bn=8, bk=16, **kw)
                      for i in range(3)])
    assert got.shape == (3, 16, 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(loop))


def test_tiled_schedule_with_fused_epilogue_matches_oracle():
    """bias + relu + requantization epilogues survive the tiled grid."""
    from repro.kernels import ref
    x = rnd((3, 10, 10, 8), jnp.int8)
    w = rnd((3, 3, 8, 16), jnp.int8, jax.random.PRNGKey(1))
    b = jnp.arange(16, dtype=jnp.int32) * 50
    got = conv2d_im2col(x, w, bias=b, requant_shift=5, act="relu",
                        config={"block_n": 3, "block_h": 4, "block_w": 8,
                                "block_co": 8})
    want = ref.conv2d_q8_ref(x, w, b, requant_shift=5, act="relu")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------- W4 weights x tiled schedules --
#
# Packed weights are grid-invariant (only activations are spatially tiled),
# but the ragged final halo tiles and non-pow2 batch blocks exercise the
# in-register unpack against partial blocks — W4 under the tiled schedule
# must stay bit-exact with the unpacked-int8 oracle AND the per-image loop.

def _w4(w, axis, group=4):
    from repro.core.quantize import quantize_w4
    qt = quantize_w4(w, axis=axis, group_size=group)
    return qt.q, qt.shifts, qt.expand()


def test_conv2d_w4_tiled_vs_oracle_and_looped():
    from repro.kernels import ref
    x = rnd((N, H, W, 5), jnp.int8)                  # odd Cx: pad nibble
    wp, ws, w8 = _w4(rnd((3, 3, 5, 8), key=jax.random.PRNGKey(1)), 2)
    kw = dict(requant_shift=5, w_shifts=ws)
    _assert_batched_equals_looped(conv2d_im2col, x, wp,
                                  cfg={**TILED_CFG, "block_co": 4}, **kw)
    got = conv2d_im2col(x, wp, config={**TILED_CFG, "block_co": 4}, **kw)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.conv2d_q8_ref(x, w8, requant_shift=5)))


def test_depthwise_w4_tiled_vs_oracle_and_looped():
    from repro.kernels import ref
    x = rnd((N, H, W, 8), jnp.int8)
    wp, ws, w8 = _w4(rnd((3, 3, 8), key=jax.random.PRNGKey(1)), 0, group=2)
    kw = dict(requant_shift=4, w_shifts=ws)
    _assert_batched_equals_looped(depthwise2d, x, wp,
                                  cfg={**TILED_CFG, "block_c": 4}, **kw)
    got = depthwise2d(x, wp, config={**TILED_CFG, "block_c": 4}, **kw)
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(ref.depthwise2d_q8_ref(x, w8, requant_shift=4)))


def test_shift_w4_tiled_vs_oracle_and_looped():
    from repro.kernels import ref
    c, cy = 6, 8
    x = rnd((N, H, W, c), jnp.int8)
    shifts = np.array([[(i % 3) - 1, ((i * 2) % 3) - 1] for i in range(c)],
                      np.int32)
    wp, ws, w8 = _w4(rnd((c, cy), key=jax.random.PRNGKey(1)), 0, group=2)
    kw = dict(requant_shift=5, w_shifts=ws)
    _assert_batched_equals_looped(shift_conv2d, x, shifts, wp,
                                  cfg={**TILED_CFG, "block_co": 4}, **kw)
    got = shift_conv2d(x, shifts, wp,
                       config={**TILED_CFG, "block_co": 4}, **kw)
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(ref.shift_conv2d_q8_ref(x, shifts, w8, requant_shift=5)))


def test_add_w4_tiled_vs_oracle_and_looped():
    from repro.kernels import ref
    x = rnd((N, H, W, 4), jnp.int8)
    wp, ws, w8 = _w4(rnd((3, 3, 4, 6), key=jax.random.PRNGKey(1)), 2)
    kw = dict(requant_shift=3, w_preshift=1, w_shifts=ws)
    _assert_batched_equals_looped(add_conv2d, x, wp,
                                  cfg={**TILED_CFG, "block_co": 2}, **kw)
    got = add_conv2d(x, wp, config={**TILED_CFG, "block_co": 2}, **kw)
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(ref.add_conv2d_q8_ref(x, w8, requant_shift=3,
                                         w_preshift=1)))


def test_matmul_w4_batched_vs_looped():
    from repro.kernels import ref
    a = rnd((N, 16, 17), jnp.int8)                  # odd K: packed pad byte
    wp, ws, w8 = _w4(rnd((17, 8), key=jax.random.PRNGKey(1)), 0, group=4)
    kw = dict(requant_shift=5, w_shifts=ws)
    got = matmul(a, wp, bm=16, bn=8, bk=7, **kw)    # odd bk rounds even
    loop = jnp.stack([matmul(a[i], wp, bm=16, bn=8, bk=7, **kw)
                      for i in range(N)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(loop))
    np.testing.assert_array_equal(
        np.asarray(got[0]),
        np.asarray(ref.matmul_ref(a[0], w8, requant_shift=5)))


def test_plan_jobs_emit_w4_dtype():
    """A W4-lowered plan's tune jobs carry the "w4a8" dtype key (own cache
    signature + halved-weight-byte cost ranking) and the packed weights'
    group shifts, so the timed calls are the real W4 dispatches."""
    from repro.core.quantize import QTensorW4
    cfg = CNNConfig(primitive="standard", widths=(8, 12), image_size=16)
    params = init_cnn(cfg, jax.random.PRNGKey(1))
    calib = jax.random.normal(jax.random.PRNGKey(2), (4, 16, 16, 3)) * 0.5
    plan = lower(build_cnn_graph(cfg), params, calib, weight_bits=4,
                 group_size=8)
    jobs = tune.plan_jobs(plan, batch=2)
    w4_jobs = [j for j in jobs if j[3] == "w4a8"]
    assert w4_jobs, "W4 plan produced no w4a8 tune jobs"
    for kernel, sig, arrays, dtype, kwargs in w4_jobs:
        assert "w_shifts" in kwargs


def test_ops_dispatch_accepts_tiled_configs():
    """The ops layer threads the new knobs through config= like any other
    schedule parameter (pallas == xla on a tiled schedule)."""
    x = rnd((4, 12, 12, 8))
    w = rnd((3, 3, 8, 16), key=jax.random.PRNGKey(1))
    got = ops.conv2d(x, w, config={"block_n": 2, "block_h": 8, "block_co": 8})
    want = ops.conv2d(x, w, method="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------- executor forward_batch --

def _lowered(prim, image=16):
    cfg = CNNConfig(primitive=prim, widths=(8, 12), image_size=image)
    params = init_cnn(cfg, jax.random.PRNGKey(1))
    calib = jax.random.normal(jax.random.PRNGKey(2),
                              (4, image, image, 3)) * 0.5
    return cfg, lower(build_cnn_graph(cfg), params, calib)


@pytest.mark.parametrize("prim", Primitives)
def test_forward_batch_matches_per_sample_loop(prim):
    """Acceptance: forward_batch(x[N]) == the per-sample loop. The integer
    trunk is bit-exact per node; the final logits (float gap->dense head)
    agree to tight tolerance and exactly by argmax (XLA picks batch-size-
    dependent float matmul kernels for the head)."""
    cfg, plan = _lowered(prim)
    x = jax.random.normal(jax.random.PRNGKey(3), (N, 16, 16, 3)) * 0.5
    ex = CompiledPlan(plan, method="xla")
    got = ex.forward_batch(x)
    loop = jnp.concatenate([ex(x[i:i + 1]) for i in range(N)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(loop),
                               rtol=1e-5, atol=1e-6)
    assert (np.asarray(got).argmax(-1) == np.asarray(loop).argmax(-1)).all()
    # integer trunk: bitwise, batched vs looped, at every plan node
    exn = CompiledPlan(plan, method="xla", jit=False)
    h = quantize(x, plan.in_fb)
    hl = [quantize(x[i:i + 1], plan.in_fb) for i in range(N)]
    for node in plan.nodes:
        h = exn._run_node(node, h)
        hl = [exn._run_node(node, v) for v in hl]
        if isinstance(h, QTensor):
            np.testing.assert_array_equal(
                np.asarray(h.q),
                np.asarray(jnp.concatenate([v.q for v in hl])), err_msg=node.name)


def test_forward_batch_pallas_matches_xla():
    cfg, plan = _lowered("dws")
    x = jax.random.normal(jax.random.PRNGKey(4), (6, 16, 16, 3)) * 0.5
    got = CompiledPlan(plan, method="pallas").forward_batch(x)
    want = CompiledPlan(plan, method="xla").forward_batch(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_forward_batch_compiles_once_per_bucket():
    """Acceptance: pow2 batch bucketing bounds recompiles — every batch
    size inside a bucket reuses the bucket's single trace."""
    cfg, plan = _lowered("standard")
    ex = CompiledPlan(plan, method="xla")
    x = jax.random.normal(jax.random.PRNGKey(5), (8, 16, 16, 3)) * 0.5
    assert CompiledPlan.batch_bucket(5) == 8
    assert CompiledPlan.batch_bucket(8) == 8
    assert CompiledPlan.batch_bucket(9) == 16
    for n in (5, 6, 7, 8):               # one bucket -> one trace
        ex.forward_batch(x[:n])
    assert ex.traces == 1
    ex.forward_batch(x[:3])              # bucket 4 -> exactly one more
    assert ex.traces == 2
    ex.forward_batch(x[:2])
    assert ex.traces == 3 and ex.forward_batch(x[:1]).shape[0] == 1


def test_throughput_and_profile_mode():
    cfg, plan = _lowered("standard")
    ex = CompiledPlan(plan, method="xla")
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 16, 16, 3)) * 0.5
    tp = ex.throughput(x, reps=1, warmup=1)
    assert tp["batch"] == 4 and tp["bucket"] == 4
    assert tp["images_per_s"] > 0 and tp["us_per_image"] == tp["us_per_batch"] / 4
    rows = ex.profile(x, reps=1, mode="throughput")
    assert rows and all(r["images_per_s"] > 0 for r in rows)
    with pytest.raises(ValueError, match="mode"):
        ex.profile(x, mode="bogus")


# --------------------------------------------------------- tune v2 space ---

def test_space_carries_tiled_knobs():
    sig = tune.sig_conv2d(8, 32, 32, 16, 32, 3)
    cands = list(tune.candidates(sig, "int8"))
    assert any(c.get("block_n", 1) > 1 for c in cands)
    assert any("block_h" in c for c in cands)
    assert tune.default_config("conv2d") in cands
    # effective resolution goes through the kernels' own schedule helper
    eff = tune.effective_config(sig, {"block_n": 8, "block_h": 8})
    assert eff["block_n"] == 8 and eff["block_h"] == 8 and eff["block_w"] == 32
    # infeasible block_n degrades like the kernel grid does
    eff = tune.effective_config(tune.sig_conv2d(5, 9, 7, 4, 8, 3),
                                {"block_n": 4, "block_h": 4})
    assert eff["block_n"] == 1 and eff["block_h"] == 4 and eff["block_w"] == 7


def test_maxpool_is_tunable_and_parity_with_planted_config():
    sig = tune.sig_maxpool2d(4, 12, 12, 8, 2, 2)
    cands = list(tune.candidates(sig, "int8"))
    assert tune.default_config("maxpool2d") in cands and len(cands) > 1
    key = tune.cache_key("maxpool2d", sig.key(), "int8", tune.backend_tag())
    c = tune.TuneCache(None)
    c.put(key, {"block_c": 4, "block_n": 2, "block_h": 3}, us=1.0)
    tune.set_default_cache(c)
    x = rnd((4, 12, 12, 8), jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(ops.maxpool2d(x, method="pallas")),
        np.asarray(ops.maxpool2d(x, method="xla")))


def test_analytic_fallback_feasible_on_batched_shapes():
    for sig in [tune.sig_conv2d(8, 32, 32, 16, 32, 3),
                tune.sig_add_conv2d(8, 10, 10, 8, 8, 3),
                tune.sig_maxpool2d(8, 32, 32, 16, 2, 2)]:
        cfg = tune.analytic_config(sig, "int8")
        assert cfg in list(tune.candidates(sig, "int8"))
        assert tune.estimate_s(sig, cfg, "int8") > 0


def test_schema_v3_rejects_v1_cache(tmp_path):
    """Schema bumps (v2: tiled knobs; v3: the W4A8 "w4a8" dtype key + its
    halved-weight-traffic reranking) must make old caches be ignored
    wholesale, not misapplied."""
    assert tune.SCHEMA_VERSION == 3
    path = str(tmp_path / "v1.json")
    key = tune.cache_key("conv2d", "n1_h8_w8_ci4_co8_k3_g1", "float32",
                         tune.backend_tag())
    json.dump({"schema_version": 1,
               "entries": {key: {"config": {"block_co": 1}, "us": 1.0,
                                 "source": "measured"}}}, open(path, "w"))
    c = tune.TuneCache(path)
    assert c.stale and len(c) == 0


def test_plan_jobs_cover_maxpool_at_serving_batch():
    cfg, plan = _lowered("standard")
    jobs = tune.plan_jobs(plan, batch=8)
    kinds = {j[0] for j in jobs}
    assert "maxpool2d" in kinds and "conv2d" in kinds
    for kernel, sig, arrays, dtype, kwargs in jobs:
        assert sig.get("n") == 8 and arrays[0].shape[0] == 8


# ----------------------------------------------------- CNN serving engine --

def test_cnn_engine_serves_queued_requests():
    from repro.serve import CNNEngine, CNNServeConfig, ImageRequest
    cfg, plan = _lowered("standard")
    ex = CompiledPlan(plan, method="xla")
    eng = CNNEngine(ex, CNNServeConfig(max_batch=4))
    rng = np.random.default_rng(0)
    imgs = [rng.normal(size=(16, 16, 3)).astype(np.float32) * 0.5
            for _ in range(11)]          # 3 rounds: 4 + 4 + ragged 3
    for uid, img in enumerate(imgs):
        eng.submit(ImageRequest(uid, img))
    done = eng.run_until_drained()
    # ragged last round (3 images) reused the pow2 bucket of the full rounds
    assert ex.traces == 1
    assert len(done) == 11 and all(r.done for r in done)
    s = eng.stats
    assert s["batch_rounds"] == 3 and s["images_done"] == 11
    assert 0 < s["occupancy"] <= 1 and s["images_per_s"] > 0
    # logits match the direct batched forward, request by request
    want = np.asarray(ex.forward_batch(np.stack(imgs)))
    by_uid = {r.uid: r.logits for r in done}
    for uid in range(11):
        np.testing.assert_allclose(by_uid[uid], want[uid],
                                   rtol=1e-5, atol=1e-6)
    with pytest.raises(ValueError, match="max_batch"):
        CNNEngine(ex, CNNServeConfig(max_batch=0))


# ------------------------------------------------ interpret default flip ---

def test_interpret_default_is_backend_detected(monkeypatch):
    from repro.kernels.common import resolve_interpret, use_interpret
    assert resolve_interpret(None) == use_interpret()
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")   # the CI pin
    assert resolve_interpret(None) is True
