"""Tests for the power-of-two quantization scheme (Eq. 4, Algorithm 1)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import (ConvSpec, Primitives, apply, init, quantize,
                        frac_bits_for, mac_inner, addmac_inner)
from repro.core.quantize import rshift_round
from repro.core.folding import fold, FOLDABLE
from repro.core.primitives import init_block, batchnorm_apply
from repro.core.qconv import qconv_apply, quantize_conv_params

KEY = jax.random.PRNGKey(0)


def test_eq4_scale_is_power_of_two():
    x = jax.random.normal(KEY, (64,)) * 3.7
    qt = quantize(x)
    assert math.log2(1.0 / qt.scale) == qt.frac_bits
    m = float(jnp.max(jnp.abs(x)))
    assert qt.frac_bits == 7 - math.ceil(math.log2(m))


@settings(max_examples=50, deadline=None)
@given(st.floats(-100.0, 100.0, allow_nan=False).filter(lambda v: abs(v) > 1e-3))
def test_quantize_roundtrip_error_bounded(v):
    qt = quantize(jnp.array([v]))
    err = abs(float(qt.dequantize()[0]) - v)
    assert err <= qt.scale + 1e-9          # floor => one ULP at that scale


def test_rshift_round_nearest_goldens():
    """NNoM's default build rounds to nearest (+(1 << (shift-1)) before >>),
    not floor: 3>>1 is 2 (1.5 -> 2), -3>>1 is -1 (-1.5 -> -1, half up)."""
    vals = jnp.array([3, -3, 5, -5, 4, -4, 1, -1, 0], jnp.int32)
    got = rshift_round(vals, 1)
    np.testing.assert_array_equal(got, [2, -1, 3, -2, 2, -2, 1, 0, 0])
    # floor semantics (the old behavior) would give 1 for 3>>1 and -2 for -3>>1
    np.testing.assert_array_equal(rshift_round(jnp.int32(100), 3), 13)  # 12.5 up
    np.testing.assert_array_equal(rshift_round(jnp.int32(99), 3), 12)   # 12.375
    # shift <= 0: exact left shift / identity, no rounding term
    np.testing.assert_array_equal(rshift_round(jnp.int32(-3), -2), -12)
    np.testing.assert_array_equal(rshift_round(jnp.int32(7), 0), 7)


def test_rshift_round_matches_kernel_epilogue():
    """Host-side requantization and the Pallas/ref epilogue must agree."""
    from repro.kernels.common import apply_requant
    acc = jnp.arange(-1000, 1000, 7, dtype=jnp.int32)
    for shift in (1, 3, 6):
        want = jnp.clip(rshift_round(acc, shift), -128, 127)
        np.testing.assert_array_equal(apply_requant(acc, shift), want)


def test_quantize_int8_range():
    x = jnp.array([-1e6, 1e6, 0.0])
    qt = quantize(x, frac_bits=7)
    assert int(qt.q.min()) >= -128 and int(qt.q.max()) <= 127


@settings(max_examples=40, deadline=None)
@given(st.integers(-128, 127), st.integers(-128, 127),
       st.integers(2, 7), st.integers(2, 7))
def test_algorithm1_left_matches_float(xq, wq, fb_x, fb_w):
    fb_y = max(fb_x + fb_w - 8, 0)
    x_f, w_f = xq * 2.0 ** -fb_x, wq * 2.0 ** -fb_w
    got = int(mac_inner(jnp.array(xq, jnp.int8), jnp.array(wq, jnp.int8),
                        fb_x, fb_w, fb_y))
    want = x_f * w_f * 2.0 ** fb_y
    assert abs(got - want) <= 1.0 + abs(want) * 0.01 or got in (-128, 127)


@settings(max_examples=40, deadline=None)
@given(st.integers(-100, 100), st.integers(-100, 100),
       st.integers(2, 6), st.integers(2, 6))
def test_algorithm1_right_matches_float(xq, wq, fb_x, fb_w):
    """Add-conv integer loop == -|x-w| computed in float, at the out scale."""
    fb_y = min(fb_x, fb_w)
    x_f, w_f = xq * 2.0 ** -fb_x, wq * 2.0 ** -fb_w
    got = int(addmac_inner(jnp.array(xq, jnp.int8), jnp.array(wq, jnp.int8),
                           fb_x, fb_w, fb_y))
    want = -abs(x_f - w_f) * 2.0 ** fb_y
    assert abs(got - want) <= 2.0 + abs(want) * 0.02 or got == -128


@pytest.mark.parametrize("prim", Primitives)
def test_quantized_layer_close_to_float(prim):
    spec = ConvSpec(primitive=prim, in_channels=8, out_channels=12,
                    kernel_size=3, groups=4 if prim == "grouped" else 1)
    p = init(KEY, spec)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 10, 10, 8)) * 0.5
    yf = apply(p, x, spec)
    yq = qconv_apply(quantize_conv_params(p, spec), quantize(x), spec,
                     frac_bits_for(yf))
    rel = float(jnp.mean(jnp.abs(yq.dequantize() - yf)) / jnp.mean(jnp.abs(yf)))
    assert rel < 0.12, f"{prim}: quantized path diverged, rel {rel}"


def test_quantized_conv_is_integer_only():
    """The int path must never touch floats between input and output q."""
    spec = ConvSpec(primitive="standard", in_channels=4, out_channels=4)
    p = init(KEY, spec)
    qp = quantize_conv_params(p, spec)
    xq = quantize(jax.random.normal(KEY, (1, 6, 6, 4)))
    jaxpr = jax.make_jaxpr(lambda q: qconv_apply(qp, type(xq)(q, xq.frac_bits),
                                                 spec, 4).q)(xq.q)
    for eqn in jaxpr.jaxpr.eqns:
        for var in eqn.outvars:
            assert not jnp.issubdtype(var.aval.dtype, jnp.floating), str(eqn)


# ------------------------------------------------------------- folding ---
@pytest.mark.parametrize("prim", FOLDABLE)
def test_bn_folding_exact(prim):
    spec = ConvSpec(primitive=prim, in_channels=6, out_channels=8,
                    groups=2 if prim == "grouped" else 1)
    params = init_block(jax.random.PRNGKey(3), spec, with_bn=True)
    params["bn"]["mean"] = jax.random.normal(KEY, (8,)) * 0.3
    params["bn"]["var"] = jax.nn.softplus(jax.random.normal(KEY, (8,))) + 0.1
    params["bn"]["gamma"] = jax.random.normal(jax.random.PRNGKey(9), (8,)) + 1.0
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 7, 7, 6))
    want = batchnorm_apply(params["bn"], apply(params["conv"], x, spec))
    folded = fold(params["conv"], params["bn"], spec)
    got = apply(folded, x, spec)
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-5)


def test_bn_folding_rejects_add():
    spec = ConvSpec(primitive="add", in_channels=4, out_channels=4)
    params = init_block(KEY, spec, with_bn=True)
    with pytest.raises(ValueError):
        fold(params["conv"], params["bn"], spec)
