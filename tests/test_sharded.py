"""Multi-device correctness on fake CPU devices (subprocess: the device
count must be set before jax initializes, so these run via python -c)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, ndev: int = 8, timeout: int = 900) -> dict:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("RESULT::" + json.dumps(result))
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", code], env=env, timeout=timeout,
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT::")][-1]
    return json.loads(line[len("RESULT::"):])


def test_sharded_train_step_matches_single_device():
    """Same loss/grad-norm on a (2,2,2) mesh as on one device."""
    out = run_py("""
        import dataclasses
        from repro.configs import get_config
        from repro.models import api
        from repro.launch.mesh import make_mesh
        from repro.parallel.sharding import make_rules, use_rules, tree_shardings, prune_batch_axes
        from repro.train import TrainConfig, make_train_step
        from repro.optim import OptConfig, init_opt_state
        cfg = dataclasses.replace(get_config("granite-3-2b"), n_layers=2,
                                  d_model=32, n_heads=4, n_kv_heads=2,
                                  d_ff=64, vocab=128)
        tcfg = TrainConfig(opt=OptConfig(lr=1e-2, warmup_steps=0, grad_clip=1e9))
        step = make_train_step(cfg, tcfg)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params, tcfg.opt)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 128)}
        _, _, m1 = jax.jit(step)(params, opt, batch)

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules = prune_batch_axes(mesh, make_rules(mesh, cfg, "train", fsdp=True), 8)
        with mesh, use_rules(rules):
            psh = tree_shardings(api.param_specs(cfg), mesh)
            params2 = jax.device_put(params, psh)
            opt2 = init_opt_state(params2, tcfg.opt)
            _, _, m2 = jax.jit(step)(params2, opt2, batch)
        result = dict(l1=float(m1["loss"]), l2=float(m2["loss"]),
                      g1=float(m1["grad_norm"]), g2=float(m2["grad_norm"]))
    """)
    assert abs(out["l1"] - out["l2"]) < 2e-3, out
    assert abs(out["g1"] - out["g2"]) / max(out["g1"], 1e-6) < 2e-2, out


def test_moe_sharded_matches_local():
    """shard_map EP path == local path with ample capacity."""
    out = run_py("""
        from repro.configs.base import MoEConfig
        from repro.models.moe import init_moe, moe_ffn_local, moe_ffn_sharded
        from repro.launch.mesh import make_mesh
        from repro.parallel.sharding import use_rules, ShardingRules
        from jax.sharding import PartitionSpec as P, NamedSharding
        moe = MoEConfig(num_experts=4, top_k=2, d_ff=16, capacity_factor=8.0)
        p = init_moe(jax.random.PRNGKey(0), 8, moe, "silu", jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8))
        y_local = moe_ffn_local(x, p, moe, "silu", jnp.float32)
        mesh = make_mesh((2, 4), ("data", "model"))
        with mesh:
            y_sh = jax.jit(lambda xx, pp: moe_ffn_sharded(
                xx, pp, moe, "silu", jnp.float32))(x, p)
        err = float(jnp.max(jnp.abs(y_sh - y_local)))
        result = dict(err=err)
    """)
    assert out["err"] < 5e-4, out


def test_sp_decode_matches_unsharded():
    out = run_py("""
        import dataclasses
        from repro.configs import get_config
        from repro.models import api
        from repro.models.transformer import decode_step, init_cache, prefill
        from repro.launch.mesh import make_mesh
        from repro.parallel.sharding import make_rules, use_rules, tree_shardings, prune_batch_axes
        cfg = dataclasses.replace(get_config("granite-3-2b"), n_layers=2,
                                  d_model=32, n_heads=4, n_kv_heads=2,
                                  d_ff=64, vocab=128)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128)
        _, cache = prefill(params, toks, cfg, 16, attn_impl="full")
        nxt = jax.random.randint(jax.random.PRNGKey(2), (2, 1), 0, 128)
        logits_ref, _ = decode_step(params, nxt, cache, cfg)

        mesh = make_mesh((2, 4), ("data", "model"))
        rules = prune_batch_axes(mesh, make_rules(mesh, cfg, "decode",
                                                  fsdp=False, sp=True), 2)
        with mesh, use_rules(rules):
            csh = tree_shardings(api.cache_specs(cfg), mesh)
            cache_sh = jax.device_put(cache, csh)
            logits_sp, _ = jax.jit(lambda p, t, c: decode_step(
                p, t, c, cfg, sp_axis="model"))(params, nxt, cache_sh)
        err = float(jnp.max(jnp.abs(logits_sp - logits_ref)))
        result = dict(err=err)
    """)
    assert out["err"] < 5e-2, out


def test_compressed_allreduce_matches_mean():
    out = run_py("""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.optim import allreduce_compressed, init_errors
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 0.1
        def body(gs, es):
            out, new_e = allreduce_compressed({"w": gs[0]}, {"w": es[0]}, "data")
            return out["w"][None], new_e["w"][None]
        with mesh:
            fn = shard_map(body, mesh=mesh, in_specs=(P("data", None),)*2,
                           out_specs=(P("data", None),)*2, check_rep=False)
            got, errs = fn(g, jnp.zeros_like(g))
        want = jnp.mean(g, axis=0)
        rel = float(jnp.max(jnp.abs(got[0] - want)) / (jnp.max(jnp.abs(want)) + 1e-9))
        # error feedback: residuals carry the quantization error
        efb = float(jnp.max(jnp.abs(errs)))
        result = dict(rel=rel, efb=efb)
    """)
    assert out["rel"] < 0.08, out          # int8: ~1/128 relative + EF residual
    assert out["efb"] > 0.0                # residual captured for next step


def test_elastic_checkpoint_restore_across_meshes(tmp_path):
    """Save sharded on (4,2), restore onto (2,) — elastic re-shard."""
    out = run_py(f"""
        from repro.checkpoint import Checkpointer
        from repro.launch.mesh import make_mesh
        from jax.sharding import PartitionSpec as P, NamedSharding
        ck = Checkpointer(r"{tmp_path}", async_save=False)
        mesh = make_mesh((4, 2), ("data", "model"))
        w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        ws = jax.device_put(w, NamedSharding(mesh, P("data", "model")))
        ck.save(3, {{"w": ws}})
        mesh2 = make_mesh((2,), ("data",))
        sh2 = {{"w": NamedSharding(mesh2, P("data", None))}}
        out_tree, step = ck.restore({{"w": w}}, shardings=sh2)
        ok = bool(jnp.all(out_tree["w"] == w))
        ndev = len(out_tree["w"].sharding.device_set)
        result = dict(ok=ok, step=step, ndev=ndev)
    """)
    assert out["ok"] and out["step"] == 3 and out["ndev"] == 2
