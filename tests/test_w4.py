"""W4A8 packed sub-byte path: pack/unpack invariants, rshift_round boundary
regressions, and bit-exactness of every W4 kernel against the unpacked-int8
oracle (pallas == xla == ref expand), through the qconv / graph / qmlp
layers, plus the tune-layer contracts (halved weight bytes, schema bump).

Deterministic companions to ``test_w4_props.py`` (the hypothesis suite):
these sweeps always run, so the W4 contract is enforced even where
hypothesis is not installed.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.core import ConvSpec, Primitives, apply, init, quantize
from repro.core.qconv import qconv_apply, quantize_conv_params
from repro.core.quantize import (QTensor, QTensorW4, W4_MAX_GROUP_SHIFT,
                                 expand_w4, frac_bits_for, pack_w4,
                                 quantize_w4, rshift_round, unpack_w4)
from repro.graph import CompiledPlan, build_cnn_graph, lower
from repro.kernels import ops, ref
from repro.kernels.conv_add import add_conv2d
from repro.kernels.conv_dw import depthwise2d
from repro.kernels.conv_im2col import conv2d_im2col
from repro.kernels.conv_shift import shift_conv2d
from repro.kernels.matmul_q8 import matmul
from repro.models.convnet import CNNConfig, init_cnn

KEY = jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _clean_tuner_state():
    tune.set_default_cache(tune.TuneCache(None))
    yield
    tune.reset()


def codes(shape, key=KEY, lo=-8, hi=8):
    return jax.random.randint(key, shape, lo, hi, jnp.int32).astype(jnp.int8)


def rnd_i8(shape, key=KEY):
    return jax.random.randint(key, shape, -100, 100, jnp.int32).astype(jnp.int8)


# ------------------------------------------------------------ pack/unpack --

@pytest.mark.parametrize("n", [1, 2, 3, 7, 8, 17])      # odd extents: pad path
@pytest.mark.parametrize("axis", [0, 1])
def test_pack_unpack_roundtrip(n, axis):
    shape = (n, 5) if axis == 0 else (5, n)
    q = codes(shape)
    p = pack_w4(q, axis)
    assert p.dtype == jnp.int8
    assert p.shape[axis] == (n + 1) // 2                # two codes per byte
    np.testing.assert_array_equal(unpack_w4(p, n, axis), q)


def test_pack_unpack_extreme_codes():
    """All-negative (-8, the asymmetric two's-complement corner) and
    all-saturated (+7) codes survive the nibble trip, odd extent included."""
    for v in (-8, 7):
        q = jnp.full((5, 3), v, jnp.int8)
        np.testing.assert_array_equal(unpack_w4(pack_w4(q, 0), 5, 0), q)


def test_pack_pad_nibble_is_zero():
    """The odd-extent pad nibble must hold code 0: ragged Pallas blocks read
    it as a neutral multiplicand."""
    q = jnp.full((3,), -8, jnp.int8)
    p = pack_w4(q, 0)
    # byte 1 = [code -8, pad]: low nibble 8, high nibble must be 0
    assert int(p[1]) & 0xF0 == 0
    np.testing.assert_array_equal(unpack_w4(p, 4, 0),
                                  jnp.array([-8, -8, -8, 0], jnp.int8))


def test_expand_w4_applies_group_shifts():
    q = codes((6, 4), jax.random.PRNGKey(1))
    shifts = jnp.array([0, 0, 2, 2, 4, 4], jnp.int8)
    got = expand_w4(pack_w4(q, 0), shifts, 6, 0)
    want = (q.astype(jnp.int32) << shifts[:, None].astype(jnp.int32))
    np.testing.assert_array_equal(got, want.astype(jnp.int8))


@pytest.mark.parametrize("n,group", [(32, 8), (17, 4), (5, 32), (48, 16)])
def test_quantize_w4_invariants(n, group):
    w = jax.random.normal(jax.random.PRNGKey(2), (n, 6)) * \
        (2.0 ** jax.random.randint(jax.random.PRNGKey(3), (n, 1), -3, 3))
    qt = quantize_w4(w, axis=0, group_size=group)
    assert qt.size == n and qt.q.shape[0] == (n + 1) // 2
    q4 = unpack_w4(qt.q, n, 0)
    assert int(q4.min()) >= -8 and int(q4.max()) <= 7
    s = np.asarray(qt.shifts)
    assert s.shape == (n,) and s.min() >= 0 and s.max() <= W4_MAX_GROUP_SHIFT
    # per-group constant shifts
    for g in range(0, n, group):
        assert len(set(s[g:g + group].tolist())) == 1
    # expanded codes dequantize to within one group ULP of the float weights
    eff = qt.scale * (2.0 ** s.astype(np.float64))[:, None]
    err = np.abs(np.asarray(qt.expand(), np.float64) * qt.scale - np.asarray(w))
    assert (err <= eff + 1e-9).all()


def test_quantize_w4_zero_group():
    qt = quantize_w4(jnp.zeros((8, 4)), axis=0, group_size=4)
    np.testing.assert_array_equal(qt.expand(), jnp.zeros((8, 4), jnp.int8))


# ------------------------------------------- rshift_round shift boundaries --

def test_rshift_round_negative_acc_at_shift_boundaries():
    """Regression: negative accumulators at the degenerate shifts. shift=0
    must be the identity (no spurious +0.5 rounding term), shift=1 rounds
    half UP (-3 -> -1), and shift=31 — the int32 limit — must collapse every
    representable accumulator to 0 or -1 without overflowing the rounding
    addend (1 << 30 is still a valid int32)."""
    acc = jnp.array([-1, -2, -3, -(2 ** 31) + 1, -(2 ** 30), -1024, 1023],
                    jnp.int32)
    np.testing.assert_array_equal(rshift_round(acc, 0), acc)
    np.testing.assert_array_equal(
        rshift_round(jnp.array([-1, -2, -3, -4, -5], jnp.int32), 1),
        [0, -1, -1, -2, -2])            # round-half-up on negatives
    # shift=31: the rounding addend (1 << 30) is still a valid int32, and no
    # negative accumulator can overflow it (min is -2^31 + 2^30 = -2^30)
    got = np.asarray(rshift_round(acc, 31), np.int64)
    want = np.floor((np.asarray(acc, np.int64) + (1 << 30)) / (1 << 31))
    np.testing.assert_array_equal(got, want)
    assert set(got.tolist()) <= {0, -1}


# --------------------------------------------------- kernel bit-exactness --

def w4ize(w, axis, group=4):
    """Float weights -> (packed, shifts, expanded-int8-oracle)."""
    qt = quantize_w4(w, axis=axis, group_size=group)
    return qt.q, qt.shifts, qt.expand()


def wf(shape, key, spread=True):
    w = jax.random.normal(key, shape)
    if spread:      # per-channel magnitude spread => non-trivial group shifts
        w = w * (2.0 ** jax.random.randint(jax.random.PRNGKey(99),
                                           (shape[-1],), -3, 2))
    return w


@pytest.mark.parametrize("shape", [
    # (N, H, W, Cx, Cy, HK, groups)
    (1, 8, 8, 8, 8, 3, 1),
    (2, 7, 5, 6, 9, 3, 3),      # odd dims, grouped, odd Cx/g
    (1, 6, 6, 5, 4, 1, 1),      # odd Cx: packed pad nibble in-flight
])
def test_conv_im2col_w4_bit_exact(shape):
    n, h, w_, cx, cy, hk, g = shape
    x = rnd_i8((n, h, w_, cx))
    wp, ws, w8 = w4ize(wf((hk, hk, cx // g, cy), jax.random.PRNGKey(1)), 2)
    got = conv2d_im2col(x, wp, groups=g, requant_shift=5, w_shifts=ws,
                        block_co=4)
    want = ref.conv2d_q8_ref(x, w8, groups=g, requant_shift=5)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        ref.conv2d_w4_ref(x, wp, ws, groups=g, requant_shift=5), want)


def test_conv_im2col_w4_bias_relu_epilogue():
    x = rnd_i8((1, 6, 6, 4))
    wp, ws, w8 = w4ize(wf((3, 3, 4, 8), jax.random.PRNGKey(2)), 2)
    b = jnp.arange(8, dtype=jnp.int32) * 50 - 100
    got = conv2d_im2col(x, wp, b, requant_shift=4, act="relu", w_shifts=ws)
    want = ref.conv2d_q8_ref(x, w8, b, requant_shift=4, act="relu")
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("hk", [3, 5])
def test_depthwise_w4_bit_exact(hk):
    x = rnd_i8((2, 8, 8, 8))
    wp, ws, w8 = w4ize(wf((hk, hk, 8), jax.random.PRNGKey(3)), 0, group=2)
    got = depthwise2d(x, wp, requant_shift=4, w_shifts=ws)
    want = ref.depthwise2d_q8_ref(x, w8, requant_shift=4)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        ref.depthwise2d_w4_ref(x, wp, ws, requant_shift=4), want)


def test_shift_conv_w4_bit_exact():
    c, cy = 6, 8
    x = rnd_i8((2, 7, 5, c))
    shifts = np.array([[(i % 3) - 1, ((i * 2) % 3) - 1] for i in range(c)],
                      np.int32)
    wp, ws, w8 = w4ize(wf((c, cy), jax.random.PRNGKey(4)), 0, group=2)
    got = shift_conv2d(x, shifts, wp, requant_shift=5, w_shifts=ws)
    want = ref.shift_conv2d_q8_ref(x, shifts, w8, requant_shift=5)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        ref.shift_conv2d_w4_ref(x, shifts, wp, ws, requant_shift=5), want)


@pytest.mark.parametrize("cx", [4, 5])        # odd Cx: pad channel sliced off
def test_add_conv_w4_bit_exact(cx):
    x = rnd_i8((1, 6, 6, cx))
    wp, ws, w8 = w4ize(wf((3, 3, cx, 6), jax.random.PRNGKey(5)), 2)
    got = add_conv2d(x, wp, requant_shift=3, w_preshift=1, w_shifts=ws,
                     block_co=2)
    want = ref.add_conv2d_q8_ref(x, w8, requant_shift=3, w_preshift=1)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        ref.add_conv2d_w4_ref(x, wp, ws, requant_shift=3, w_preshift=1), want)


@pytest.mark.parametrize("mk", [(16, 24), (8, 17), (5, 33)])  # odd K: pad
def test_matmul_w4_bit_exact(mk):
    m, k = mk
    a = rnd_i8((m, k))
    wp, ws, w8 = w4ize(wf((k, 8), jax.random.PRNGKey(6)), 0, group=8)
    got = matmul(a, wp, requant_shift=5, w_shifts=ws, bm=8, bn=8, bk=7)
    want = ref.matmul_ref(a, w8, requant_shift=5)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        ref.matmul_w4_ref(a, wp, ws, requant_shift=5), want)


def test_w4_requires_requant_shift():
    wp, ws, _ = w4ize(wf((3, 3, 4, 8), jax.random.PRNGKey(7)), 2)
    with pytest.raises(ValueError):
        conv2d_im2col(rnd_i8((1, 6, 6, 4)), wp, w_shifts=ws)   # float path
    with pytest.raises(ValueError):
        matmul(rnd_i8((4, 8)), pack_w4(codes((8, 4)), 0),
               w_shifts=jnp.zeros((8,), jnp.int8))


# ------------------------------------------------------------ ops dispatch --

def test_ops_w4_pallas_matches_xla():
    """The ops layer routes w_shifts through both dispatch methods; they
    must agree bit-for-bit (the ISSUE's pallas == xla == oracle gate)."""
    x = rnd_i8((2, 8, 8, 8))
    wp, ws, w8 = w4ize(wf((3, 3, 8, 8), jax.random.PRNGKey(8)), 2)
    got_p = ops.conv2d(x, wp, requant_shift=5, w_shifts=ws, method="pallas")
    got_x = ops.conv2d(x, wp, requant_shift=5, w_shifts=ws, method="xla")
    np.testing.assert_array_equal(got_p, got_x)
    np.testing.assert_array_equal(
        got_p, ref.conv2d_q8_ref(x, w8, requant_shift=5))


# ----------------------------------------------------------- qconv / graph --

@pytest.mark.parametrize("prim", Primitives)
def test_qconv_w4_matches_expanded_int8(prim):
    """quantize_conv_params(bits=4) through qconv_apply must equal the SAME
    parameters expanded to int8 QTensors — the packing changes data
    movement, never arithmetic."""
    spec = ConvSpec(primitive=prim, in_channels=8, out_channels=12,
                    kernel_size=3, groups=4 if prim == "grouped" else 1)
    p = init(KEY, spec)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 10, 10, 8)) * 0.5
    xq = quantize(x)
    out_fb = frac_bits_for(apply(p, x, spec))
    qp4 = quantize_conv_params(p, spec, bits=4, group_size=4)
    qp8 = {k: QTensor(v.expand(), v.frac_bits) if isinstance(v, QTensorW4)
           else v for k, v in qp4.items()}
    for method in ("pallas", "xla"):
        y4 = qconv_apply(qp4, xq, spec, out_fb, method=method)
        y8 = qconv_apply(qp8, xq, spec, out_fb, method=method)
        np.testing.assert_array_equal(np.asarray(y4.q), np.asarray(y8.q))


def test_quantize_conv_params_rejects_bad_bits():
    spec = ConvSpec(primitive="standard", in_channels=4, out_channels=4)
    with pytest.raises(ValueError):
        quantize_conv_params(init(KEY, spec), spec, bits=2)


@pytest.mark.parametrize("prim", ["standard", "dws", "shift", "add"])
def test_graph_lower_w4_plan_pallas_matches_xla(prim):
    cfg = CNNConfig(primitive=prim, widths=(8, 12), image_size=12)
    params = init_cnn(cfg, jax.random.PRNGKey(1))
    calib = jax.random.normal(jax.random.PRNGKey(2), (4, 12, 12, 3)) * 0.5
    plan = lower(build_cnn_graph(cfg), params, calib, weight_bits=4,
                 group_size=8)
    assert any(isinstance(v, QTensorW4)
               for node in plan.nodes if node.qparams
               for v in node.qparams.values())
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 12, 12, 3)) * 0.5
    got = CompiledPlan(plan, method="pallas")(x)
    want = CompiledPlan(plan, method="xla")(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------------- qmlp (W4) ---

def test_qmlp_w4_bit_exact_vs_expanded_int8():
    from repro.models import blocks as B
    p = B.init_mlp(jax.random.PRNGKey(0), 32, 48, "silu", jnp.float32)
    ps = {k: jnp.stack([v, v * 1.3]) for k, v in p.items()}   # 2-layer stack
    qp4 = B.quantize_mlp_params(ps, bits=4, group_size=8)
    h = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 32), jnp.float32)
    for layer in range(2):
        lp4 = jax.tree_util.tree_map(lambda a: a[layer], qp4)
        lp8 = {k: QTensor(v.expand(), v.frac_bits) for k, v in lp4.items()}
        y4p = B.qmlp(h, lp4, "silu", jnp.float32, method="pallas")
        y4x = B.qmlp(h, lp4, "silu", jnp.float32, method="xla")
        y8 = B.qmlp(h, lp8, "silu", jnp.float32, method="pallas")
        np.testing.assert_array_equal(np.asarray(y4p), np.asarray(y4x))
        np.testing.assert_array_equal(np.asarray(y4p), np.asarray(y8))


# ----------------------------------------------------------- tune contracts --

def test_cost_model_w4_halves_weight_bytes():
    """The analytic model must score W4 weight traffic at half the int8
    bytes — that's what re-ranks schedules toward fatter weight blocks."""
    from repro.tune.runner import estimate_s
    from repro.tune.space import sig_conv2d
    sig = sig_conv2d(4, 16, 16, 8, 16, 3)
    cfg = {"block_co": 8, "block_n": 1}
    t8 = estimate_s(sig, cfg, dtype="int8")
    t4 = estimate_s(sig, cfg, dtype="w4a8")
    assert t4 < t8
    # isolate the weight-traffic term: it is the only dtype-dependent part
    from repro.tune.runner import _bytes_of, _wbytes_of
    assert _wbytes_of("w4a8") == 0.5
    assert _wbytes_of("int8") == 1.0
    assert _bytes_of("w4a8") == 1       # activations stay int8


def test_schema_v3_and_w4_dtype_in_space():
    assert tune.SCHEMA_VERSION == 3
    from repro.tune.space import candidates, sig_conv2d
    sig = sig_conv2d(1, 12, 12, 8, 16, 3)
    c8 = list(candidates(sig, dtype="int8"))
    c4 = list(candidates(sig, dtype="w4a8"))
    assert c4 == c8 and len(c4) > 0     # same knobs; ranking differs via cost
