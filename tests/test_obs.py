"""repro.obs contracts: tracer span nesting + thread-safety, Chrome
trace-event schema validity, disabled-mode no-op, histogram percentile
correctness vs numpy, registry in-place reset, serve-engine stats parity
(registry-backed ``stats`` keeps the legacy keys), request-lifecycle trace
lanes, monotonic request timestamps, and the bench_snapshot compare gate."""
import dataclasses
import importlib.util
import json
import os
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.graph import CompiledPlan, build_cnn_graph, lower
from repro.models import api
from repro.models.convnet import CNNConfig, init_cnn
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve import (CNNEngine, CNNServeConfig, Engine, ImageRequest,
                         Request, ServeConfig)

# ---------------------------------------------------------------- tracer ---


def test_span_nesting_order_and_args():
    tr = obs_trace.Tracer(enabled=True)
    with tr.span("outer", cat="t", a=1):
        with tr.span("inner", cat="t") as sp:
            sp.set(us=42)
    ev = tr.events()
    assert [(e["ph"], e["name"]) for e in ev] == [
        ("B", "outer"), ("B", "inner"), ("E", "inner"), ("E", "outer")]
    assert ev[0]["args"] == {"a": 1}          # ctor attrs ride on B
    assert ev[2]["args"] == {"us": 42}        # set() attrs ride on E
    ts = [e["ts"] for e in ev]
    assert ts == sorted(ts)


def test_complete_replays_recorded_stamps():
    tr = obs_trace.Tracer(enabled=True)
    t0 = tr._t0
    tr.complete("replayed", t0 + 1.0, t0 + 2.5, tid=7, n=3)
    b, e = tr.events()
    assert (b["ph"], e["ph"]) == ("B", "E")
    assert b["tid"] == e["tid"] == 7
    assert b["ts"] == pytest.approx(1.0e6)
    assert e["ts"] == pytest.approx(2.5e6)
    assert b["args"] == {"n": 3}


def test_disabled_mode_is_noop():
    tr = obs_trace.Tracer(enabled=False)
    # shared null span: identity proves no per-call allocation
    assert tr.span("x") is tr.span("y") is obs_trace._NULL_SPAN
    with tr.span("x", a=1) as sp:
        sp.set(b=2)
    tr.begin("x")
    tr.end("x")
    tr.complete("x", 0.0, 1.0)
    assert tr.events() == []


def test_env_gating(monkeypatch):
    for val, want in (("", False), ("0", False), ("1", True), ("yes", True)):
        monkeypatch.setenv(obs_trace.ENV_VAR, val)
        assert obs_trace.Tracer().enabled is want
    monkeypatch.delenv(obs_trace.ENV_VAR)
    assert obs_trace.Tracer().enabled is False


def test_traced_decorator(monkeypatch):
    tr = obs_trace.Tracer(enabled=True)
    monkeypatch.setattr(obs_trace, "TRACER", tr)

    @obs_trace.traced("work", cat="t")
    def f(x):
        return x + 1

    assert f(1) == 2
    assert [(e["ph"], e["name"]) for e in tr.events()] == [
        ("B", "work"), ("E", "work")]
    tr.disable()
    tr.clear()
    assert f(2) == 3 and tr.events() == []


def test_tracer_thread_safety():
    tr = obs_trace.Tracer(enabled=True)
    n_threads, n_spans = 8, 50

    def worker(k):
        for i in range(n_spans):
            with tr.span(f"t{k}", i=i):
                pass

    ts = [threading.Thread(target=worker, args=(k,))
          for k in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    ev = tr.events()
    assert len(ev) == 2 * n_threads * n_spans
    # per-tid: B/E balance and proper nesting (depth never negative)
    by_tid = {}
    for e in ev:
        by_tid.setdefault(e["tid"], []).append(e)
    for seq in by_tid.values():
        depth = 0
        for e in seq:
            depth += 1 if e["ph"] == "B" else -1
            assert depth >= 0
        assert depth == 0


def test_chrome_trace_schema(tmp_path):
    tr = obs_trace.Tracer(enabled=True)
    with tr.span("a", cat="c", k=1):
        with tr.span("b"):
            pass
    lane = obs_trace.next_lane()
    tr.complete("replay", tr._t0, tr._t0 + 0.001, tid=lane)
    path = tr.export(str(tmp_path / "trace.json"))
    with open(path) as f:
        blob = json.load(f)
    assert blob["displayTimeUnit"] == "ms"
    assert blob["otherData"]["wall_clock_t0"] > 1e9       # wall clock, not
    events = blob["traceEvents"]                          # perf_counter
    assert events
    balance = {}
    for e in events:
        for field in ("ph", "name", "cat", "ts", "pid", "tid"):
            assert field in e, f"event missing {field}: {e}"
        assert e["ph"] in ("B", "E")
        balance[e["tid"]] = balance.get(e["tid"], 0) + (
            1 if e["ph"] == "B" else -1)
    assert all(v == 0 for v in balance.values())


def test_next_lane_unique():
    lanes = {obs_trace.next_lane() for _ in range(100)}
    assert len(lanes) == 100
    assert all(l >= obs_trace._LANE_BASE for l in lanes)


# --------------------------------------------------------------- metrics ---


def test_counter_and_gauge():
    reg = obs_metrics.Registry()
    c = reg.counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g")
    g.set(7)
    g.set(3)
    assert g.value == 3.0
    with pytest.raises(TypeError):
        reg.gauge("c")          # type mismatch on an existing name


def test_counter_thread_safety():
    c = obs_metrics.Counter("c")
    ts = [threading.Thread(target=lambda: [c.inc() for _ in range(1000)])
          for _ in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == 8000


def test_histogram_percentiles_vs_numpy():
    # linear buckets at 0.01 resolution -> interpolated percentiles must
    # agree with numpy on uniform data to well within one bucket width
    buckets = np.linspace(0.0, 1.0, 101)[1:]
    h = obs_metrics.Histogram("h", buckets=buckets)
    rng = np.random.default_rng(0)
    xs = rng.uniform(0.0, 1.0, size=2000)
    for x in xs:
        h.observe(float(x))
    assert h.count == 2000
    assert h.mean == pytest.approx(float(np.mean(xs)), rel=1e-9)
    assert h.sum == pytest.approx(float(np.sum(xs)), rel=1e-9)
    for p in (5, 25, 50, 75, 95, 99):
        assert h.percentile(p) == pytest.approx(
            float(np.percentile(xs, p)), abs=0.02), f"p{p}"
    assert h.min == float(np.min(xs)) and h.max == float(np.max(xs))


def test_histogram_edge_cases():
    h = obs_metrics.Histogram("h")
    assert h.percentile(50) == 0.0 and h.mean == 0.0     # empty
    h.observe(0.25)
    # one sample: every percentile clamps to the observed value
    assert h.percentile(0) == h.percentile(50) == h.percentile(100) == 0.25
    with pytest.raises(ValueError):
        h.percentile(101)
    big = obs_metrics.Histogram("big")
    big.observe(1e6)            # above the last bucket -> overflow bin
    assert big.percentile(99) == 1e6
    with pytest.raises(ValueError):
        obs_metrics.Histogram("empty", buckets=[])


def test_registry_reset_in_place_keeps_handles():
    reg = obs_metrics.Registry()
    c = reg.counter("c")
    h = reg.histogram("h")
    c.inc(5)
    h.observe(1.0)
    reg.reset()
    assert c.value == 0.0 and h.count == 0
    assert reg.counter("c") is c          # same instrument, zeroed in place
    c.inc()
    assert reg.snapshot()["c"]["value"] == 1.0


def test_registry_snapshot_json():
    reg = obs_metrics.Registry()
    reg.counter("a").inc(2)
    reg.histogram("b").observe(0.5)
    snap = json.loads(reg.to_json())
    assert snap["a"] == {"type": "counter", "value": 2.0}
    assert snap["b"]["type"] == "histogram" and snap["b"]["count"] == 1
    assert {"p50", "p95", "p99", "mean", "min", "max"} <= set(snap["b"])


def test_kernel_dispatch_counters():
    import jax.numpy as jnp

    from repro.kernels import ops
    c_x = obs_metrics.counter("kernels.dispatch.maxpool2d.xla")
    c_p = obs_metrics.counter("kernels.dispatch.maxpool2d.pallas")
    v_x, v_p = c_x.value, c_p.value
    x = jnp.arange(64, dtype=jnp.float32).reshape(1, 8, 8, 1)
    ops.maxpool2d(x, window=2, method="xla")
    ops.maxpool2d(x, window=2, method="pallas")
    assert c_x.value == v_x + 1 and c_p.value == v_p + 1


# ------------------------------------------------------- engine parity -----


def _tiny_cfg():
    return dataclasses.replace(get_config("qwen2-0.5b"), n_layers=2,
                               d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                               vocab=64)


@pytest.fixture(scope="module")
def engine_setup():
    cfg = _tiny_cfg()
    return cfg, api.init_params(cfg, jax.random.PRNGKey(0))


def _drain(cfg, params, n_req=3, **scfg_kw):
    eng = Engine(cfg, params, ServeConfig(**scfg_kw))
    rng = np.random.default_rng(0)
    for uid in range(n_req):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, 64, (4,)).astype(np.int32),
                           max_new_tokens=3))
    done = eng.run_until_drained()
    return eng, done


# the pre-registry Engine.stats dict keys — the backward-compat contract
ENGINE_LEGACY_KEYS = {"prefills", "decode_steps", "tokens_out",
                      "requests_done", "occupancy", "ttft_avg_s",
                      "decode_tok_s"}
# block-pool gauges ride along for every layout (zero under contiguous)
ENGINE_POOL_KEYS = {"blocks_in_use", "blocks_free", "prefix_hit_rate"}
CNN_LEGACY_KEYS = {"batch_rounds", "images_done", "occupancy",
                   "latency_avg_s", "images_per_s"}


def test_engine_stats_parity_and_quantiles(engine_setup):
    cfg, params = engine_setup
    eng, done = _drain(cfg, params, n_req=3, max_batch=2, max_len=32)
    st = eng.stats
    assert ENGINE_LEGACY_KEYS <= set(st)
    assert ENGINE_POOL_KEYS <= set(st)
    # contiguous layout: pool gauges exist but stay zero
    assert st["blocks_in_use"] == 0 and st["prefix_hit_rate"] == 0.0
    assert st["requests_done"] == 3 and st["prefills"] == 3
    assert st["tokens_out"] == sum(len(r.out_tokens) for r in done) == 9
    assert 0.0 < st["occupancy"] <= 1.0
    assert st["decode_tok_s"] > 0.0
    # quantile keys ride along; p50 <= p99, all sane
    for k in ("ttft_p50_s", "ttft_p95_s", "ttft_p99_s", "tpot_avg_s",
              "queue_wait_avg_s", "queue_wait_p99_s"):
        assert k in st and st[k] >= 0.0
    assert st["ttft_p50_s"] <= st["ttft_p99_s"] + 1e-12
    # in-place reset: same handles, zeroed values
    eng.reset_stats()
    st2 = eng.stats
    assert st2["requests_done"] == 0 and st2["decode_tok_s"] == 0.0


def test_engine_monotonic_request_stamps(engine_setup):
    cfg, params = engine_setup
    _, done = _drain(cfg, params, n_req=2, max_batch=2, max_len=32)
    for r in done:
        # perf_counter stamps: monotonic lifecycle ordering is guaranteed
        assert r.submit_t <= r.admit_t <= r.first_token_t <= r.finish_t
        assert r.queue_wait_s >= 0.0
        assert r.submit_wall_t > 1e9          # the one wall-clock field


def test_engine_trace_lanes(engine_setup, tmp_path):
    cfg, params = engine_setup
    obs_trace.TRACER.clear()
    obs_trace.enable()
    try:
        _drain(cfg, params, n_req=3, max_batch=2, max_len=32)
        ev = obs_trace.TRACER.events()
    finally:
        obs_trace.disable()
        obs_trace.TRACER.clear()
    names = {e["name"] for e in ev}
    assert {"request", "queue_wait", "prefill", "generate",
            "engine.prefill", "engine.decode_round",
            "engine.drain"} <= names
    # one lane per retired request, each a balanced well-nested stack
    req_b = [e for e in ev if e["name"] == "request" and e["ph"] == "B"]
    lanes = {e["tid"] for e in req_b}
    assert len(req_b) == 3 and len(lanes) == 3
    assert all(t >= obs_trace._LANE_BASE for t in lanes)
    for lane in lanes:
        seq = [e for e in ev if e["tid"] == lane]
        depth = 0
        for e in seq:
            assert e["ts"] >= 0.0
            depth += 1 if e["ph"] == "B" else -1
            assert depth >= 0
        assert depth == 0
        # lifecycle sub-spans present on the lane
        assert {"queue_wait", "prefill", "generate"} <= {
            e["name"] for e in seq}


def _cnn_plan():
    cfg = CNNConfig(primitive="standard", widths=(8, 12), image_size=16)
    params = init_cnn(cfg, jax.random.PRNGKey(1))
    calib = jax.random.normal(jax.random.PRNGKey(2), (4, 16, 16, 3)) * 0.5
    return CompiledPlan(lower(build_cnn_graph(cfg), params, calib),
                        method="xla")


def test_cnn_engine_stats_parity_and_trace():
    ex = _cnn_plan()
    eng = CNNEngine(ex, CNNServeConfig(max_batch=4))
    rng = np.random.default_rng(0)
    obs_trace.TRACER.clear()
    obs_trace.enable()
    try:
        for uid in range(6):                   # 2 rounds: 4 + ragged 2
            eng.submit(ImageRequest(
                uid, rng.normal(size=(16, 16, 3)).astype(np.float32) * 0.5))
        done = eng.run_until_drained()
        ev = obs_trace.TRACER.events()
    finally:
        obs_trace.disable()
        obs_trace.TRACER.clear()
    assert len(done) == 6 and all(r.logits is not None for r in done)
    st = eng.stats
    assert CNN_LEGACY_KEYS <= set(st)
    assert st["images_done"] == 6 and st["batch_rounds"] == 2
    assert st["occupancy"] == pytest.approx(6 / 8)
    assert st["images_per_s"] > 0.0
    for k in ("latency_p50_s", "latency_p95_s", "latency_p99_s",
              "queue_wait_avg_s", "queue_wait_p99_s"):
        assert k in st and st[k] >= 0.0
    for r in done:
        assert r.submit_t <= r.admit_t <= r.finish_t
        assert r.submit_wall_t > 1e9
    names = {e["name"] for e in ev}
    assert {"image_request", "queue_wait", "execute",
            "cnn.batch_round"} <= names
    lanes = {e["tid"] for e in ev
             if e["name"] == "image_request" and e["ph"] == "B"}
    assert len(lanes) == 6


def test_cnn_engine_stats_isolated_per_engine():
    ex = _cnn_plan()
    a = CNNEngine(ex, CNNServeConfig(max_batch=2))
    b = CNNEngine(ex, CNNServeConfig(max_batch=2))
    rng = np.random.default_rng(1)
    a.submit(ImageRequest(0, rng.normal(size=(16, 16, 3))
                          .astype(np.float32)))
    a.run_until_drained()
    assert a.stats["images_done"] == 1
    assert b.stats["images_done"] == 0        # private registries


# --------------------------------------------------- bench_snapshot gate ---


def _load_bench_snapshot():
    path = os.path.join(os.path.dirname(__file__), os.pardir, "scripts",
                        "bench_snapshot.py")
    spec = importlib.util.spec_from_file_location("bench_snapshot", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def bs():
    return _load_bench_snapshot()


def _snap(us, *, tok_s=100.0, exact=1.0):
    return {
        "schema_version": 1, "fast": True, "backend": "cpu",
        "sections": {
            "serving": {"ok": True, "error": None, "rows": {
                "serve/static": {"us": us,
                                 "derived": {"tok_s": tok_s}},
            }},
            "quant": {"ok": True, "error": None, "rows": {
                "quant/conv/w=8": {"us": 50.0,
                                   "derived": {"exact": exact}},
            }},
        },
        "exact": {"quant/conv/w=8": exact},
        "headline": {}, "metrics": {},
    }


def test_parse_rows_and_coerce(bs):
    rows = bs.parse_rows(
        "serve/static,123.4,tok_s=99.5;exact=1\n"
        "noise line\nname,us_per_call,derived\n"
        "serve/speedup,0.0,continuous_over_static=2.31x\n")
    assert rows["serve/static"]["us"] == 123.4
    assert rows["serve/static"]["derived"] == {"tok_s": 99.5, "exact": 1.0}
    assert rows["serve/speedup"]["derived"][
        "continuous_over_static"] == 2.31


def test_compare_flags_injected_latency_regression(bs):
    prev, cur = _snap(100.0), _snap(120.0)     # +20% latency
    fails, _ = bs.compare(cur, prev, threshold=10.0, latency_hard=True)
    assert any("latency" in f and "serve/static" in f for f in fails)
    # warn-only downgrades it to a warning
    fails, warns = bs.compare(cur, prev, threshold=10.0, latency_hard=False)
    assert not fails
    assert any("serve/static" in w for w in warns)
    # under threshold: clean
    fails, warns = bs.compare(_snap(105.0), prev, threshold=10.0,
                              latency_hard=True)
    assert not fails and not warns


def test_compare_flags_throughput_drop(bs):
    prev = _snap(100.0, tok_s=100.0)
    cur = _snap(100.0, tok_s=70.0)            # -30% tok/s
    fails, _ = bs.compare(cur, prev, threshold=10.0, latency_hard=True)
    assert any("tok_s" in f for f in fails)


def test_compare_exactness_always_hard_fails(bs):
    prev, cur = _snap(100.0, exact=1.0), _snap(100.0, exact=0.0)
    fails, _ = bs.compare(cur, prev, threshold=10.0, latency_hard=False)
    assert any("exactness" in f for f in fails)


def test_compare_coverage_always_hard_fails(bs):
    prev, cur = _snap(100.0), _snap(100.0)
    del cur["sections"]["serving"]["rows"]["serve/static"]
    fails, _ = bs.compare(cur, prev, threshold=10.0, latency_hard=False)
    assert any("coverage" in f and "serve/static" in f for f in fails)
    cur2 = _snap(100.0)
    cur2["sections"]["quant"] = {"ok": False, "error": "boom", "rows": {}}
    fails, _ = bs.compare(cur2, prev, threshold=10.0, latency_hard=False)
    assert any("coverage" in f and "quant" in f for f in fails)


def test_compare_identical_is_clean(bs):
    fails, warns = bs.compare(_snap(100.0), _snap(100.0), threshold=10.0,
                              latency_hard=True)
    assert not fails and not warns
