"""End-to-end behaviour tests for the paper's system: train -> checkpoint ->
restore -> quantize -> serve, plus the paper's headline claims reproduced
by the cost models."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import ConvSpec, MCUModel
from repro.data import DataConfig, IndexedDataset
from repro.models import api
from repro.models.convnet import CNNConfig, cnn_forward, init_cnn, quantize_cnn


def test_cnn_train_quantize_deploy_pipeline(tmp_path):
    """The paper's full deployment flow: train float CNN (any primitive) ->
    BN-fold + PTQ to int8-pow2 -> integer inference agrees with float."""
    from repro.models.convnet import cnn_loss
    from repro.optim import OptConfig, apply_updates, init_opt_state
    cfg = CNNConfig(primitive="standard", widths=(8, 16), image_size=16)
    ds = IndexedDataset(DataConfig(kind="image", global_batch=32,
                                   image_size=16, seed=1))
    p = init_cnn(cfg, jax.random.PRNGKey(0))
    opt = OptConfig(lr=3e-3, warmup_steps=2, total_steps=60, weight_decay=0.0)
    st = init_opt_state(p, opt)

    @jax.jit
    def step(p, st, batch):
        (l, acc), g = jax.value_and_grad(lambda q: cnn_loss(q, batch, cfg),
                                         has_aux=True, allow_int=True)(p)
        p, st, _ = apply_updates(p, g, st, opt)
        return p, st, l

    for i in range(60):
        p, st, l = step(p, st, jax.tree_util.tree_map(jnp.asarray, ds.batch(i)))

    from repro.models.convnet import calibrate_bn
    x = jnp.asarray(ds.batch(100)["images"])
    y = jnp.asarray(ds.batch(100)["labels"])
    calib = jnp.asarray(ds.batch(200)["images"])
    p = calibrate_bn(p, cfg, calib)          # deployment BN re-estimation
    acc_f = float(jnp.mean(jnp.argmax(cnn_forward(p, x, cfg), -1) == y))
    int_fwd = quantize_cnn(p, cfg, calib)
    acc_q = float(jnp.mean(jnp.argmax(int_fwd(x), -1) == y))
    assert acc_f > 0.22                      # learned something (chance=0.1)
    assert acc_q > acc_f - 0.15              # PTQ drop bounded (paper flow)


def test_lm_train_checkpoint_serve_roundtrip(tmp_path):
    """Train a reduced LM, checkpoint, restore into bf16, serve batched."""
    from repro.optim import OptConfig
    from repro.train import LoopConfig, TrainConfig, Trainer
    from repro.checkpoint import Checkpointer
    from repro.serve import Engine, Request, ServeConfig

    cfg = dataclasses.replace(get_config("granite-3-2b"), n_layers=2,
                              d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                              vocab=64)
    ds = IndexedDataset(DataConfig(kind="lm", vocab=64, seq_len=16,
                                   global_batch=4, seed=2))
    tr = Trainer(cfg, TrainConfig(opt=OptConfig(lr=1e-2, warmup_steps=1,
                                                total_steps=8)),
                 LoopConfig(total_steps=8, ckpt_every=8,
                            ckpt_dir=str(tmp_path), log_every=0),
                 ds, init_params_fn=lambda k: api.init_params(cfg, k))
    params, _, step, hist = tr.run()
    assert step == 8 and hist[-1]["loss"] < hist[0]["loss"] + 0.5

    # restore into serve dtype (bf16) and run the batched engine
    ck = Checkpointer(str(tmp_path))
    bf16_like = jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.bfloat16
                            if jnp.issubdtype(x.dtype, jnp.floating)
                            else x.dtype), params)
    tree, got_step = ck.restore({"params": bf16_like,
                                 "opt": tr.init_or_restore()[1]})
    assert got_step == 8
    eng = Engine(cfg, tree["params"], ServeConfig(max_batch=2, max_len=32))
    for i in range(3):
        eng.submit(Request(uid=i, prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=3))
    done = eng.run_until_drained()
    assert len(done) == 3 and all(len(r.out_tokens) == 3 for r in done)


def test_paper_headline_claims():
    """Paper abstract claims, reproduced by the models the framework carries:
    (1) linear MACs<->energy without SIMD; (2) SIMD lowers latency+energy;
    (3) shift conv cheapest per param; (4) add conv same MACs as standard."""
    from benchmarks.common import r_squared
    mcu = MCUModel()
    macs, es = [], []
    for hk in (1, 3, 5, 7):
        s = ConvSpec(in_channels=8, out_channels=16, kernel_size=hk)
        macs.append(s.mac_count(32))
        es.append(mcu.energy_mj(s, 32, simd=False))
    assert r_squared(macs, es) > 0.99

    s = ConvSpec(in_channels=16, out_channels=16)
    assert mcu.latency_s(s, 32, simd=True) < mcu.latency_s(s, 32, simd=False)
    assert mcu.energy_mj(s, 32, simd=True) < mcu.energy_mj(s, 32, simd=False)

    shift = ConvSpec(primitive="shift", in_channels=16, out_channels=16)
    std = ConvSpec(primitive="standard", in_channels=16, out_channels=16)
    add = ConvSpec(primitive="add", in_channels=16, out_channels=16)
    assert shift.param_count() < std.param_count()
    assert add.mac_count(32) == std.mac_count(32)
