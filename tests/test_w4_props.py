"""Property-based tests for the W4 nibble packing (hypothesis; optional dep
like ``test_quantize.py`` — the deterministic sweeps in ``test_w4.py`` cover
the same contracts where hypothesis is absent).

Properties:
  * pack -> unpack is the identity for ANY int4 code tensor — random shapes,
    random pack axis, odd extents (pad nibble), all-negative (-8) and
    all-saturated (+7) corners;
  * quantize_w4 round-trips within one group ULP for ANY float weights and
    group size, and its expanded codes always fit int8;
  * rshift_round matches the float round-half-up model for ANY negative
    accumulator at ANY shift in [0, 31].
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core.quantize import (W4_MAX_GROUP_SHIFT, pack_w4, quantize_w4,
                                 rshift_round, unpack_w4)


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 33), st.integers(1, 5), st.integers(0, 1),
       st.integers(0, 2 ** 32 - 1))
def test_pack_unpack_roundtrip_random(n, m, axis, seed):
    rng = np.random.default_rng(seed)
    shape = (n, m) if axis == 0 else (m, n)
    q = rng.integers(-8, 8, size=shape).astype(np.int8)
    got = unpack_w4(pack_w4(jnp.asarray(q), axis), n, axis)
    np.testing.assert_array_equal(np.asarray(got), q)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 9), st.sampled_from([-8, 7]))
def test_pack_unpack_saturated_corners(n, v):
    """The two's-complement corners: -8 (0b1000, the value with no positive
    partner) and +7 must survive any extent, including the odd-pad path."""
    q = jnp.full((n, 3), v, jnp.int8)
    np.testing.assert_array_equal(unpack_w4(pack_w4(q, 0), n, 0), q)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 40), st.integers(1, 16), st.integers(0, 2 ** 32 - 1),
       st.floats(0.01, 64.0))
def test_quantize_w4_roundtrip_bounded(n, group, seed, spread):
    rng = np.random.default_rng(seed)
    w = (rng.standard_normal((n, 4)) * spread).astype(np.float32)
    qt = quantize_w4(jnp.asarray(w), axis=0, group_size=group)
    q4 = np.asarray(unpack_w4(qt.q, n, 0))
    assert q4.min() >= -8 and q4.max() <= 7
    s = np.asarray(qt.shifts, np.int64)
    assert s.min() >= 0 and s.max() <= W4_MAX_GROUP_SHIFT
    w8 = np.asarray(qt.expand(), np.int64)
    assert w8.min() >= -128 and w8.max() <= 127     # expanded codes fit int8
    # floor quantization: one ULP at each group's effective scale, unless the
    # group was clamped (its natural scale below the reachable window)
    eff = qt.scale * (2.0 ** s)[:, None]
    clamped = (q4 == -8) | (q4 == 7)
    err = np.abs(w8.astype(np.float64) * qt.scale - w)
    assert ((err <= eff + 1e-7) | clamped).all()


@settings(max_examples=80, deadline=None)
@given(st.integers(-(2 ** 31) + 2 ** 30, -1), st.integers(0, 31))
def test_rshift_round_negative_accumulators(acc, shift):
    """Round-half-up on any negative accumulator at any shift 0..31 —
    including the boundary shifts 0 (identity), 1, and 31 (the rounding
    addend 1 << 30 must not overflow int32 for any acc >= -2^30 - 2^30)."""
    got = int(rshift_round(jnp.int32(acc), shift))
    want = acc if shift == 0 else int(np.floor((acc + (1 << (shift - 1)))
                                               / (1 << shift)))
    assert got == want
