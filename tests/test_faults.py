"""repro.faults: deterministic injection core, the hardened serving layer,
and the chaos invariants (EXPERIMENTS.md §Resilience).

Structure mirrors the failure model:
  * injection core — FaultSpec validation, nth/times windows, seeded
    probability determinism, env grammar, nesting, corrupt determinism;
  * BlockPool integrity — double-free / unknown-page / stale-acquire
    ValueErrors, plus a seeded randomized op-sequence sweep auditing the
    pool's structural invariants after every operation;
  * crash-safe tune cache — truncated / non-object JSON warns and falls
    back instead of raising out of construction;
  * kernel degradation — sticky per-kernel pallas->xla fallback behind
    the ``kernels.dispatch`` seam, bit-exact with the oracle;
  * LM/CNN chaos — paired clean/faulted drains through
    ``repro.faults.chaos``: survivors bit-identical, every request
    terminal, pool conserved, spans balanced.

The CI chaos job reruns this file under a REPRO_FAULTS_SEED matrix; the
seeded tests read that env var so each matrix leg exercises a different
deterministic schedule against the same invariants.
"""
import dataclasses
import json
import os
import warnings

import jax
import numpy as np
import pytest

from repro import tune
from repro.configs import get_config
from repro.faults import FaultPlan, FaultSpec, InjectedFault, inject
from repro.faults import chaos
from repro.models import api
from repro.serve import Engine, QueueFullError, Request, ServeConfig
from repro.serve.engine import BlockPool

# the CI chaos matrix pins this; locally it defaults to 0
MATRIX_SEED = int(os.environ.get("REPRO_FAULTS_SEED", "0"))


# ------------------------------------------------------------ injection core


def test_spec_validation():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec(site="engine.nope", kind="raise")
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(site="engine.prefill", kind="explode")
    with pytest.raises(ValueError, match="corrupt"):
        FaultSpec(site="blockpool.alloc", kind="corrupt")
    with pytest.raises(ValueError, match="exactly one"):
        FaultSpec(site="engine.prefill", kind="raise", nth=1,
                  probability=0.5)
    with pytest.raises(ValueError, match="nth"):
        FaultSpec(site="engine.prefill", kind="raise", nth=0)
    with pytest.raises(ValueError, match="probability"):
        FaultSpec(site="engine.prefill", kind="raise", probability=1.5)
    with pytest.raises(ValueError, match="times"):
        FaultSpec(site="engine.prefill", kind="raise", times=0)
    with pytest.raises(ValueError, match="delay_s"):
        FaultSpec(site="engine.prefill", kind="delay", delay_s=-1)
    # nth defaults to 1 when neither trigger is given
    assert FaultSpec(site="engine.prefill", kind="raise").nth == 1


def test_nth_window_fires_consecutively():
    plan = FaultPlan([FaultSpec(site="engine.prefill", kind="raise",
                                nth=3, times=2)])
    fired_hits = []
    with plan:
        for h in range(1, 8):
            try:
                inject.check("engine.prefill")
            except InjectedFault:
                fired_hits.append(h)
    assert fired_hits == [3, 4]
    assert len(plan.log) == 2 and [f.hit for f in plan.log] == [3, 4]
    # other sites' counters are independent
    with plan:
        assert inject.check("engine.decode_round") is None


def test_inactive_check_is_none_and_counts_nothing():
    assert inject.active_plan() is None
    assert inject.check("engine.prefill") is None


def test_probability_is_seed_deterministic():
    def fires(seed):
        plan = FaultPlan([FaultSpec(site="engine.decode_round",
                                    kind="raise", probability=0.4,
                                    times=100)], seed=seed)
        out = []
        with plan:
            for h in range(1, 51):
                try:
                    inject.check("engine.decode_round")
                except InjectedFault:
                    out.append(h)
        return out

    a, b = fires(MATRIX_SEED), fires(MATRIX_SEED)
    assert a == b and 0 < len(a) < 50
    assert fires(MATRIX_SEED + 1) != a


def test_reset_restores_counters():
    plan = FaultPlan([FaultSpec(site="engine.prefill", kind="raise",
                                nth=1)])
    with plan:
        with pytest.raises(InjectedFault):
            inject.check("engine.prefill")
        assert inject.check("engine.prefill") is None   # window passed
    plan.reset()
    with plan:
        with pytest.raises(InjectedFault):               # fires again
            inject.check("engine.prefill")


def test_nesting_restores_previous_plan():
    outer = FaultPlan([FaultSpec(site="engine.prefill", kind="raise",
                                 nth=10**9)])
    inner = FaultPlan([])
    with outer:
        assert inject.active_plan() is outer
        with inner:
            assert inject.active_plan() is inner
        assert inject.active_plan() is outer
    assert inject.active_plan() is None


def test_env_grammar():
    plan = inject.parse_env(
        "engine.decode_round:raise:nth=2:times=3;"
        "kernels.dispatch:delay:p=0.25:delay=0.01; seed=41")
    assert plan.seed == 41 and len(plan.specs) == 2
    a, b = plan.specs
    assert (a.site, a.kind, a.nth, a.times) == \
        ("engine.decode_round", "raise", 2, 3)
    assert (b.site, b.kind, b.probability, b.delay_s) == \
        ("kernels.dispatch", "delay", 0.25, 0.01)
    with pytest.raises(ValueError, match="site:kind"):
        inject.parse_env("engine.decode_round")
    with pytest.raises(ValueError, match="malformed"):
        inject.parse_env("engine.decode_round:raise:nth")
    with pytest.raises(ValueError, match="unknown"):
        inject.parse_env("engine.decode_round:raise:bogus=1")
    with pytest.raises(ValueError, match="unknown fault site"):
        inject.parse_env("engine.bogus:raise")


def test_corrupt_apply_is_deterministic_and_out_of_band():
    f = inject.Fired(site="engine.decode_round", kind="corrupt", hit=3,
                     seed=MATRIX_SEED)
    x = np.linspace(-1.0, 1.0, 64, dtype=np.float32).reshape(4, 16)
    a, b = f.apply(x), f.apply(x)
    np.testing.assert_array_equal(a, b)          # deterministic
    assert a.shape == x.shape and not np.array_equal(a, x)
    assert a.max() > x.max() + 500               # out-of-band: moves argmax
    # a different hit corrupts different positions/values
    g = inject.Fired(site="engine.decode_round", kind="corrupt", hit=4,
                     seed=MATRIX_SEED)
    assert not np.array_equal(g.apply(x), a)
    # integer arrays poison to dtype max
    xi = np.zeros((8,), np.int32)
    assert f.apply(xi).max() == np.iinfo(np.int32).max


def test_delay_kind_sleeps_and_returns_none():
    import time
    plan = FaultPlan([FaultSpec(site="engine.prefill", kind="delay",
                                nth=1, delay_s=0.05)])
    with plan:
        t0 = time.perf_counter()
        assert inject.check("engine.prefill") is None
        assert time.perf_counter() - t0 >= 0.04
    assert len(plan.log) == 1


# --------------------------------------------------------- BlockPool safety


def test_pool_double_free_raises():
    pool = BlockPool(8, 4)
    ids = pool.alloc(2)
    pool.free(ids)
    with pytest.raises(ValueError, match="double-free or unknown"):
        pool.free(ids)
    with pytest.raises(ValueError, match="double-free or unknown"):
        pool.free([999])
    assert pool.audit(expect_drained=True) == []


def test_pool_release_without_reference_raises():
    pool = BlockPool(8, 4)
    with pytest.raises(ValueError, match="no live reference"):
        pool.release([3])
    ids = pool.alloc(1)
    pool.publish(["d0"], ids)
    pool.release(ids)
    with pytest.raises(ValueError, match="no live reference"):
        pool.release(ids)                        # double-release
    assert pool.audit(expect_drained=True) == []


def test_pool_free_of_referenced_or_published_page_raises():
    pool = BlockPool(8, 4)
    ids = pool.alloc(2)
    pool.publish(["d0"], ids[:1])
    with pytest.raises(ValueError, match="live"):
        pool.free(ids[:1])                       # has a live reference
    pool.release(ids[:1])                        # parks it evictable
    with pytest.raises(ValueError, match="published/parked"):
        pool.free(ids[:1])                       # parked pages use hashed=
    pool.free(ids[1:])
    assert pool.audit() == []


def test_pool_acquire_revalidates_evicted_page():
    pool = BlockPool(4, 4)                       # 3 usable pages
    ids = pool.alloc(1)
    pool.publish(["d0"], ids)
    pool.release(ids)                            # parked, evictable
    hit = pool.lookup(["d0"])
    assert hit == ids
    assert pool.alloc(3) is not None             # evicts the parked page
    with pytest.raises(ValueError, match="evicted"):
        pool.acquire(hit)                        # stale lookup result


def test_pool_randomized_op_sequence_keeps_invariants():
    """Property-style sweep: a seeded random interleaving of alloc /
    publish / acquire / release / free / lookup keeps every structural
    invariant (audit() == []) after EVERY op, and full teardown drains
    clean. The CI seed matrix varies the interleaving."""
    rng = np.random.default_rng(MATRIX_SEED)
    pool = BlockPool(10, 4)
    live = []          # [ids, hashed] per simulated request
    next_digest = 0
    for step in range(300):
        op = rng.integers(0, 4)
        if op == 0:                                        # admit
            n = int(rng.integers(1, 4))
            ids = pool.alloc(n)
            if ids is not None:
                h = int(rng.integers(0, n + 1))
                keys = [f"d{next_digest + j}" for j in range(h)]
                next_digest += h
                pool.publish(keys, ids[:h])
                live.append([ids, h, keys])
        elif op == 1 and live:                             # retire
            ids, h, _ = live.pop(int(rng.integers(0, len(live))))
            pool.free(ids, hashed=h)
        elif op == 2 and live:                             # share a prefix
            _, h, keys = live[int(rng.integers(0, len(live)))]
            if h:
                hit = pool.lookup(keys)
                if hit:                                    # may be evicted
                    pool.acquire(hit)
                    live.append([hit, len(hit), keys[:len(hit)]])
        else:                                              # illegal free
            with pytest.raises(ValueError):
                pool.free([999])
        assert pool.audit() == [], f"step {step} broke an invariant"
    for ids, h, _ in live:
        pool.free(ids, hashed=h)
    assert pool.audit(expect_drained=True) == []
    assert len(pool._free) + len(pool._evictable) == pool.usable


def test_pool_alloc_fault_seam_fires_before_state_change():
    pool = BlockPool(8, 4)
    with FaultPlan([FaultSpec(site="blockpool.alloc", kind="raise",
                              nth=1)]):
        with pytest.raises(InjectedFault):
            pool.alloc(2)
        assert pool.audit() == [] and pool.in_use == 0
        assert pool.alloc(2) is not None         # next call succeeds


# ------------------------------------------------------ crash-safe tunecache


def test_truncated_tune_cache_warns_and_falls_back(tmp_path):
    path = str(tmp_path / "cache.json")
    c = tune.TuneCache(None)
    c.put(tune.cache_key("conv2d", "sig", "float32", "cpu"),
          {"block_co": 8}, us=1.0)
    c.save(path)
    blob = open(path).read()
    open(path, "w").write(blob[:len(blob) // 2])   # external truncation
    with pytest.warns(RuntimeWarning, match="unreadable"):
        broken = tune.TuneCache(path)
    assert broken.stale and len(broken) == 0


def test_wrong_typed_tune_cache_warns_and_falls_back(tmp_path):
    path = str(tmp_path / "cache.json")
    json.dump(["not", "a", "dict"], open(path, "w"))
    with pytest.warns(RuntimeWarning, match="unreadable"):
        broken = tune.TuneCache(path)
    assert broken.stale and len(broken) == 0


def test_tune_cache_load_fault_seam(tmp_path):
    path = str(tmp_path / "cache.json")
    c = tune.TuneCache(None)
    c.put("k", {"block_co": 8})
    c.save(path)
    with FaultPlan([FaultSpec(site="tune.cache_load", kind="raise",
                              nth=1)]):
        with pytest.warns(RuntimeWarning, match="unreadable"):
            broken = tune.TuneCache(path)
        assert broken.stale and len(broken) == 0
    assert len(tune.TuneCache(path)) == 1        # file itself is fine


# --------------------------------------------------------- kernel fallback


def test_kernel_dispatch_degrades_sticky_and_bit_exact():
    from repro.kernels import ops
    import jax.numpy as jnp
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 6, 3))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 3, 4)) * 0.1
    b = jnp.zeros((4,))
    want = np.asarray(ops.conv2d(x, w, b, method="xla"))
    ops.reset_degraded()
    try:
        plan = FaultPlan([FaultSpec(site="kernels.dispatch", kind="raise",
                                    nth=1, times=10**6)])
        with plan, warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            got = np.asarray(ops.conv2d(x, w, b, method="pallas"))
            hits_after_first = plan._hits["kernels.dispatch"]
            # sticky: the second call short-circuits to xla WITHOUT
            # re-attempting the pallas dispatch (no new seam hits)
            got2 = np.asarray(ops.conv2d(x, w, b, method="pallas"))
            assert plan._hits["kernels.dispatch"] == hits_after_first
        np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(got2, want)
        assert "conv2d" in ops.degraded()
        warned = [w_ for w_ in rec
                  if issubclass(w_.category, RuntimeWarning)
                  and "degraded" in str(w_.message)]
        assert len(warned) == 1                  # logged once, not per call
    finally:
        ops.reset_degraded()
    assert ops.degraded() == {}


# ----------------------------------------------------------------- LM chaos


def tiny_cfg():
    return dataclasses.replace(get_config("qwen2-0.5b"), n_layers=2,
                               d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                               vocab=64)


@pytest.fixture(scope="module")
def lm_setup():
    cfg = tiny_cfg()
    return cfg, api.init_params(cfg, jax.random.PRNGKey(0))


def make_reqs(n=4, plen=5, max_new=6, **kw):
    def factory():
        out = []
        for uid in range(n):
            rng = np.random.default_rng(uid)
            out.append(Request(
                uid=uid,
                prompt=rng.integers(0, 64, (plen,)).astype(np.int32),
                max_new_tokens=max_new, **kw))
        return out
    return factory


def lm_chaos(lm_setup, fault_plan, scfg_kw=None, req_kw=None, **harness_kw):
    cfg, params = lm_setup
    scfg = ServeConfig(max_batch=2, max_len=32, **(scfg_kw or {}))
    return chaos.run_lm_chaos(
        lambda: Engine(cfg, params, scfg),
        make_reqs(**(req_kw or {})),
        fault_plan, **harness_kw)


def test_lm_prefill_fault_absorbed_by_retry(lm_setup):
    rep = lm_chaos(lm_setup, FaultPlan(
        [FaultSpec(site="engine.prefill", kind="raise", nth=2, times=1)],
        seed=MATRIX_SEED))
    assert rep.ok, rep.summary()
    assert all(s == "ok" for s in rep.statuses.values())
    assert rep.fired == 1 and rep.stats["retries"] >= 1


def test_lm_prefill_fault_exhausts_retries_to_error(lm_setup):
    # times > max_retries+1 on one admission: that request retires as
    # "error"; every other stream stays bit-identical to the clean run
    rep = lm_chaos(lm_setup, FaultPlan(
        [FaultSpec(site="engine.prefill", kind="raise", nth=1, times=3)],
        seed=MATRIX_SEED))
    assert rep.ok, rep.summary()
    assert sorted(rep.statuses.values()) == ["error", "ok", "ok", "ok"]
    assert rep.stats["errors"] == 1


def test_lm_decode_fault_retires_active_set_and_rebuilds(lm_setup):
    # 3 consecutive decode failures exhaust max_retries=2: the active set
    # retires as "error", the arena is rebuilt, and the queued remainder
    # is served bit-identically against the fresh cache
    rep = lm_chaos(lm_setup, FaultPlan(
        [FaultSpec(site="engine.decode_round", kind="raise", nth=2,
                   times=3)], seed=MATRIX_SEED),
        req_kw=dict(n=5))
    assert rep.ok, rep.summary()
    by = sorted(rep.statuses.values())
    assert by.count("error") == 2 and by.count("ok") == 3
    assert rep.stats["arena_rebuilds"] == 1
    assert rep.stats["requests_done"] == 5


def test_lm_corrupt_round_is_contained(lm_setup):
    rep = lm_chaos(lm_setup, FaultPlan(
        [FaultSpec(site="engine.decode_round", kind="corrupt", nth=2)],
        seed=MATRIX_SEED), req_kw=dict(n=5))
    assert rep.ok, rep.summary()
    assert all(s == "ok" for s in rep.statuses.values())
    # the poisoned round's active requests are recorded and excluded from
    # bit-identity; later admissions decode clean and must survive
    assert rep.poisoned and rep.survivors
    assert set(rep.survivors).isdisjoint(rep.poisoned)


def test_lm_deadline_cancels_at_round_boundary(lm_setup):
    # every decode round stalls 30ms against a 10ms budget: requests get
    # their first token (prefill) then cancel at the next round boundary
    rep = lm_chaos(lm_setup, FaultPlan(
        [FaultSpec(site="engine.decode_round", kind="delay", nth=1,
                   times=10**6, delay_s=0.03)], seed=MATRIX_SEED),
        scfg_kw=dict(deadline_s=0.01))
    assert all(s in ("ok", "timeout") for s in rep.statuses.values())
    assert rep.stats["timeouts"] >= 1
    # timeout retirement reclaimed KV: conservation violations would show
    assert not rep.pool_violations and rep.ok, rep.summary()


def test_lm_shedding_reject_and_drop(lm_setup):
    for policy in ("reject", "drop"):
        rep = lm_chaos(lm_setup, FaultPlan([], seed=MATRIX_SEED),
                       scfg_kw=dict(max_queue=3, shed_policy=policy),
                       req_kw=dict(n=6), expect_fired=False)
        assert rep.ok, rep.summary()
        by = sorted(rep.statuses.values())
        assert by == ["ok", "ok", "ok", "shed", "shed", "shed"]
        if policy == "drop":
            assert rep.stats["shed"] == 3


def test_lm_paged_pool_fault_backpressures_not_leaks(lm_setup):
    rep = lm_chaos(lm_setup, FaultPlan(
        [FaultSpec(site="blockpool.alloc", kind="raise", nth=2, times=2)],
        seed=MATRIX_SEED),
        scfg_kw=dict(kv_layout="paged", kv_block_size=4, prefill_bucket=8),
        req_kw=dict(n=5, max_new=8))
    assert rep.ok, rep.summary()
    assert all(s == "ok" for s in rep.statuses.values())
    assert rep.pool_violations == []


def test_lm_paged_decode_error_rebuilds_pool_clean(lm_setup):
    rep = lm_chaos(lm_setup, FaultPlan(
        [FaultSpec(site="engine.decode_round", kind="raise", nth=3,
                   times=3)], seed=MATRIX_SEED),
        scfg_kw=dict(kv_layout="paged", kv_block_size=4, prefill_bucket=8),
        req_kw=dict(n=5, max_new=8))
    assert rep.ok, rep.summary()
    assert "error" in rep.statuses.values()
    assert rep.stats["arena_rebuilds"] == 1
    assert rep.pool_violations == []


def test_lm_static_scheduler_faults(lm_setup):
    for spec in (FaultSpec(site="engine.prefill", kind="raise", nth=1,
                           times=1),
                 FaultSpec(site="engine.decode_round", kind="raise", nth=1,
                           times=3),
                 FaultSpec(site="engine.decode_round", kind="corrupt",
                           nth=2)):
        rep = lm_chaos(lm_setup, FaultPlan([spec], seed=MATRIX_SEED),
                       scfg_kw=dict(scheduler="static"))
        assert rep.ok, rep.summary()
        assert all(s in ("ok", "error") for s in rep.statuses.values())


def test_lm_seeded_probability_chaos_matrix(lm_setup):
    """The CI-matrix leg: a probabilistic schedule over both hot seams at
    the env-pinned seed. Whatever fires, every invariant must hold."""
    rep = lm_chaos(lm_setup, FaultPlan(
        [FaultSpec(site="engine.decode_round", kind="raise",
                   probability=0.2, times=2),
         FaultSpec(site="engine.prefill", kind="raise", probability=0.2,
                   times=2)], seed=MATRIX_SEED),
        req_kw=dict(n=6), expect_fired=False)
    assert rep.ok, rep.summary()
    assert all(s in ("ok", "error") for s in rep.statuses.values())


def test_lm_env_activation_end_to_end(lm_setup, monkeypatch):
    """REPRO_FAULTS= is how the bench/CI layers schedule faults: install
    from the env, run a drain, and the schedule must both fire and be
    fully absorbed."""
    cfg, params = lm_setup
    monkeypatch.setenv(inject.ENV_VAR,
                       "engine.decode_round:raise:nth=2:times=1;"
                       f"seed={MATRIX_SEED}")
    inject.install_from_env(force=True)
    try:
        plan = inject.active_plan()
        assert plan is not None and plan.seed == MATRIX_SEED
        eng = Engine(cfg, params, ServeConfig(max_batch=2, max_len=32))
        for r in make_reqs()():
            eng.submit(r)
        done = eng.run_until_drained()
        assert all(r.status == "ok" for r in done)
        assert len(plan.log) == 1 and eng.stats["retries"] >= 1
    finally:
        inject.deactivate()


# ---------------------------------------------------------------- CNN chaos


def cnn_setup():
    from repro.graph import CompiledPlan, build_cnn_graph, lower
    from repro.models.convnet import CNNConfig, init_cnn
    cfg = CNNConfig(primitive="standard", widths=(8, 12), image_size=16)
    params = init_cnn(cfg, jax.random.PRNGKey(1))
    calib = jax.random.normal(jax.random.PRNGKey(2), (4, 16, 16, 3)) * 0.5
    plan = lower(build_cnn_graph(cfg), params, calib)
    return plan, CompiledPlan


def make_images(n=6):
    def factory():
        from repro.serve import ImageRequest
        rng = np.random.default_rng(0)
        return [ImageRequest(uid, rng.normal(size=(16, 16, 3))
                             .astype(np.float32) * 0.5)
                for uid in range(n)]
    return factory


def test_cnn_round_fault_absorbed_by_retry():
    from repro.serve import CNNEngine, CNNServeConfig
    plan, CompiledPlan = cnn_setup()
    rep = chaos.run_cnn_chaos(
        lambda: CNNEngine(CompiledPlan(plan, method="xla"),
                          CNNServeConfig(max_batch=4)),
        make_images(), FaultPlan(
            [FaultSpec(site="cnn.batch_round", kind="raise", nth=1,
                       times=2)], seed=MATRIX_SEED))
    assert rep.ok, rep.summary()
    assert all(s == "ok" for s in rep.statuses.values())
    assert rep.stats["retries"] >= 2 and rep.stats["degraded"] == 0


def test_cnn_exhausted_retries_degrade_then_serve():
    """times > max_retries+1: the round exhausts its retries, the plan
    degrades to the xla path ONE-SHOT, and the same round then succeeds —
    nothing retires as error. A later fresh engine on the same (degraded)
    plan keeps serving without re-degrading."""
    from repro.serve import CNNEngine, CNNServeConfig
    plan, CompiledPlan = cnn_setup()
    ex = CompiledPlan(plan, method="xla")
    rep = chaos.run_cnn_chaos(
        lambda: CNNEngine(ex, CNNServeConfig(max_batch=4)),
        make_images(), FaultPlan(
            [FaultSpec(site="cnn.batch_round", kind="raise", nth=1,
                       times=3)], seed=MATRIX_SEED))
    # NOTE make_engine is called twice (baseline first), so ex.degraded
    # flips during the faulted run only — baseline ran clean
    assert rep.ok, rep.summary()
    assert all(s == "ok" for s in rep.statuses.values())
    assert ex.degraded and rep.stats["degraded"] == 1


def test_cnn_degraded_plan_error_when_faults_persist():
    from repro.serve import CNNEngine, CNNServeConfig
    plan, CompiledPlan = cnn_setup()
    ex = CompiledPlan(plan, method="xla")
    eng = CNNEngine(ex, CNNServeConfig(max_batch=4))
    with FaultPlan([FaultSpec(site="cnn.batch_round", kind="raise", nth=1,
                              times=10**6)], seed=MATRIX_SEED):
        for r in make_images(3)():
            eng.submit(r)
        done = eng.run_until_drained()
    assert all(r.status == "error" for r in done)
    assert eng.stats["errors"] == 3


def test_cnn_corrupt_round_is_contained():
    from repro.serve import CNNEngine, CNNServeConfig
    plan, CompiledPlan = cnn_setup()
    rep = chaos.run_cnn_chaos(
        lambda: CNNEngine(CompiledPlan(plan, method="xla"),
                          CNNServeConfig(max_batch=4)),
        make_images(), FaultPlan(
            [FaultSpec(site="cnn.batch_round", kind="corrupt", nth=1)],
            seed=MATRIX_SEED))
    assert rep.ok, rep.summary()
    # round 1 (4 images) poisoned + contained; round 2 (2 images) survives
    assert len(rep.poisoned) == 4 and len(rep.survivors) == 2


def test_cnn_deadline_and_shedding():
    from repro.serve import CNNEngine, CNNServeConfig, ImageRequest
    plan, CompiledPlan = cnn_setup()
    ex = CompiledPlan(plan, method="xla")
    # shedding: queue capped below the submitted count
    eng = CNNEngine(ex, CNNServeConfig(max_batch=2, max_queue=3,
                                       shed_policy="reject"))
    shed = 0
    for r in make_images(5)():
        try:
            eng.submit(r)
        except QueueFullError:
            shed += 1
    assert shed == 2 and eng.stats["shed"] == 2
    done = eng.run_until_drained()
    assert len(done) == 3 and all(r.status == "ok" for r in done)
    # deadline: already-expired requests never get a forward
    eng2 = CNNEngine(ex, CNNServeConfig(max_batch=2, deadline_s=1e-9))
    for r in make_images(2)():
        eng2.submit(r)
    done2 = eng2.run_until_drained()
    assert all(r.status == "timeout" for r in done2)
    assert eng2.stats["timeouts"] == 2 and eng2.stats["batch_rounds"] == 0


# ------------------------------------------------------------- config knobs


def test_resilience_knob_validation():
    from repro.check.config import check_serve_config, \
        check_cnn_serve_config
    from repro.serve.cnn import CNNServeConfig
    bad = ServeConfig(max_batch=4, deadline_s=-1.0, max_queue=2,
                      shed_policy="panic", max_retries=-1,
                      retry_backoff_s=-0.5)
    msgs = check_serve_config(bad)
    joined = "\n".join(msgs)
    for frag in ("deadline_s", "max_queue=2 is below max_batch=4",
                 "shed_policy", "max_retries", "retry_backoff_s"):
        assert frag in joined, f"missing {frag!r} in: {joined}"
    assert check_serve_config(ServeConfig(
        max_batch=4, deadline_s=5.0, max_queue=8, shed_policy="drop")) == []
    msgs = check_cnn_serve_config(CNNServeConfig(
        max_batch=4, deadline_s=0, max_queue=1, shed_policy="nope"))
    assert len(msgs) == 3
    with pytest.raises(ValueError, match="shed_policy"):
        Engine(tiny_cfg(), None, ServeConfig(shed_policy="nope"))
