"""Paged-KV serving tests: bit-identical greedy streams vs the contiguous
layout (float + int8 KV, mid-decode retire/refill, growth at page
boundaries), hash-based prefix sharing (refcount correctness under
different retirement orders, storage-only int8 sharing), pool-exhaustion
admission backpressure and mid-decode preemption, BlockPool unit behaviour,
the paged config checks, and the suffix-prefill exactness they all rest on.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve import BlockPool, Engine, Request, ServeConfig


def tiny_cfg():
    return dataclasses.replace(get_config("qwen2-0.5b"), n_layers=2,
                               d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                               vocab=64)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = tiny_cfg()
    return cfg, api.init_params(cfg, jax.random.PRNGKey(0))


def make_req(uid, plen=5, max_new=6, seed=None, **kw):
    rng = np.random.default_rng(uid if seed is None else seed)
    return Request(uid=uid,
                   prompt=rng.integers(0, 64, (plen,)).astype(np.int32),
                   max_new_tokens=max_new, **kw)


def make_prefixed(uid, shared, suffix_len, max_new=6, **kw):
    rng = np.random.default_rng(1000 + uid)
    sfx = rng.integers(0, 64, (suffix_len,)).astype(np.int32)
    return Request(uid=uid, prompt=np.concatenate([shared, sfx]),
                   max_new_tokens=max_new, **kw)


def drain(cfg, params, reqs, **scfg_kw):
    eng = Engine(cfg, params, ServeConfig(**scfg_kw))
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    return eng, sorted(done, key=lambda r: r.uid)


def streams(done):
    return {r.uid: list(r.out_tokens) for r in done}


# ------------------------------------------------- paged-contiguous parity


def test_paged_parity_mid_decode_refill(dense_setup):
    """Greedy streams are bit-identical to the contiguous layout through
    mid-decode retirements and slot refills (staggered max_new keeps slots
    churning), including prompts that cross page boundaries."""
    cfg, params = dense_setup
    reqs = [make_req(i, plen=p, max_new=m) for i, (p, m) in
            enumerate([(5, 9), (13, 2), (8, 7), (16, 4), (3, 11), (9, 1)])]
    _, ref = drain(cfg, params, [dataclasses.replace(r) for r in reqs],
                   max_batch=2, max_len=32)
    _, got = drain(cfg, params, reqs, max_batch=2, max_len=32,
                   kv_layout="paged", kv_block_size=8)
    assert streams(got) == streams(ref)
    assert all(r.done for r in got)


def test_paged_parity_int8_kv(dense_setup):
    cfg, params = dense_setup
    reqs = [make_req(i, plen=p, max_new=m) for i, (p, m) in
            enumerate([(6, 8), (11, 3), (15, 6), (4, 10)])]
    _, ref = drain(cfg, params, [dataclasses.replace(r) for r in reqs],
                   max_batch=2, max_len=32, kv_cache="int8")
    _, got = drain(cfg, params, reqs, max_batch=2, max_len=32,
                   kv_cache="int8", kv_layout="paged", kv_block_size=8)
    assert streams(got) == streams(ref)


def test_paged_growth_at_page_boundary(dense_setup):
    """A prompt landing exactly on a page boundary needs a fresh page
    before its first decode write; generation then crosses further
    boundaries. Streams must still match contiguous bit-for-bit."""
    cfg, params = dense_setup
    reqs = [make_req(0, plen=8, max_new=20), make_req(1, plen=16, max_new=12)]
    _, ref = drain(cfg, params, [dataclasses.replace(r) for r in reqs],
                   max_batch=2, max_len=64)
    eng, got = drain(cfg, params, reqs, max_batch=2, max_len=64,
                     kv_layout="paged", kv_block_size=8)
    assert streams(got) == streams(ref)
    # all pages returned once the drain retired everything
    assert eng.stats["blocks_in_use"] == 0


def test_paged_single_request_vs_contiguous(dense_setup):
    cfg, params = dense_setup
    _, ref = drain(cfg, params, [make_req(0, plen=7, max_new=12)],
                   max_batch=4, max_len=32)
    _, got = drain(cfg, params, [make_req(0, plen=7, max_new=12)],
                   max_batch=4, max_len=32, kv_layout="paged",
                   kv_block_size=16)
    assert streams(got) == streams(ref)


# ------------------------------------------------------------ prefix reuse


def test_prefix_sharing_hits_and_parity(dense_setup):
    """Requests sharing a long prompt prefix hit the donor's published
    pages (block-granular hit rate > 0, fewer prefilled positions) and
    still produce streams bit-identical to contiguous serving."""
    cfg, params = dense_setup
    rng = np.random.default_rng(7)
    shared = rng.integers(0, 64, (17,)).astype(np.int32)  # 2 full 8-blocks
    reqs = [make_prefixed(i, shared, s, max_new=m) for i, (s, m) in
            enumerate([(3, 6), (5, 4), (1, 8), (9, 2)])]
    _, ref = drain(cfg, params, [dataclasses.replace(r) for r in reqs],
                   max_batch=2, max_len=64)
    eng, got = drain(cfg, params, reqs, max_batch=2, max_len=64,
                     kv_layout="paged", kv_block_size=8)
    assert streams(got) == streams(ref)
    st = eng.stats
    assert st["prefix_hit_rate"] > 0.0
    # requests 1..3 each hit the donor's two published prefix pages
    assert st["blocks_in_use"] == 0 and st["blocks_free"] > 0


def test_prefix_refcount_survives_retire_orders(dense_setup):
    """Sharers retiring in different orders (staggered max_new both ways)
    must leave the pool fully drained — refcounts hit zero exactly once
    per page, and streams match the contiguous baseline in both orders."""
    cfg, params = dense_setup
    rng = np.random.default_rng(8)
    shared = rng.integers(0, 64, (17,)).astype(np.int32)
    for maxnews in ([2, 9], [9, 2]):        # donor first / donor last
        reqs = [make_prefixed(i, shared, 3 + i, max_new=m)
                for i, m in enumerate(maxnews)]
        _, ref = drain(cfg, params, [dataclasses.replace(r) for r in reqs],
                       max_batch=2, max_len=64)
        eng, got = drain(cfg, params, reqs, max_batch=2, max_len=64,
                         kv_layout="paged", kv_block_size=8)
        assert streams(got) == streams(ref)
        assert eng.stats["blocks_in_use"] == 0


def test_prefix_sharing_int8_storage_only(dense_setup):
    """int8 KV shares page STORAGE (hit rate > 0, shared pages written
    once) but recomputes each hitting prompt — streams still match the
    contiguous int8 baseline bit-for-bit."""
    cfg, params = dense_setup
    rng = np.random.default_rng(9)
    shared = rng.integers(0, 64, (17,)).astype(np.int32)
    reqs = [make_prefixed(i, shared, 2 + i, max_new=5) for i in range(3)]
    _, ref = drain(cfg, params, [dataclasses.replace(r) for r in reqs],
                   max_batch=2, max_len=64, kv_cache="int8")
    eng, got = drain(cfg, params, reqs, max_batch=2, max_len=64,
                     kv_cache="int8", kv_layout="paged", kv_block_size=8)
    assert streams(got) == streams(ref)
    assert eng.stats["prefix_hit_rate"] > 0.0


def test_prefix_cache_disabled(dense_setup):
    cfg, params = dense_setup
    rng = np.random.default_rng(10)
    shared = rng.integers(0, 64, (17,)).astype(np.int32)
    reqs = [make_prefixed(i, shared, 2, max_new=4) for i in range(3)]
    _, ref = drain(cfg, params, [dataclasses.replace(r) for r in reqs],
                   max_batch=2, max_len=64)
    eng, got = drain(cfg, params, reqs, max_batch=2, max_len=64,
                     kv_layout="paged", kv_block_size=8, prefix_cache=False)
    assert streams(got) == streams(ref)
    assert eng.stats["prefix_hit_rate"] == 0.0


# -------------------------------------------- backpressure and preemption


def test_pool_exhaustion_admission_backpressure(dense_setup):
    """A pool too small for max_batch concurrent requests parks admissions
    in the holdback instead of failing; every request still completes with
    the contiguous baseline's exact stream."""
    cfg, params = dense_setup
    reqs = [make_req(i, plen=12, max_new=10) for i in range(5)]
    _, ref = drain(cfg, params, [dataclasses.replace(r) for r in reqs],
                   max_batch=4, max_len=32)
    # 5 usable pages of 8 positions: at most ~2 requests resident at once
    eng, got = drain(cfg, params, reqs, max_batch=4, max_len=32,
                     kv_layout="paged", kv_block_size=8, kv_num_blocks=6)
    assert streams(got) == streams(ref)
    assert eng.stats["requests_done"] == 5
    assert eng.stats["blocks_in_use"] == 0


def test_preemption_replays_identical_stream(dense_setup):
    """The minimum legal pool (one max_len sequence) forces mid-decode
    preemption when a second request is admitted; the preempted request
    replays from its prompt and the final streams still match contiguous."""
    cfg, params = dense_setup
    reqs = [make_req(i, plen=9, max_new=16) for i in range(3)]
    _, ref = drain(cfg, params, [dataclasses.replace(r) for r in reqs],
                   max_batch=2, max_len=32)
    eng, got = drain(cfg, params, reqs, max_batch=2, max_len=32,
                     kv_layout="paged", kv_block_size=8, kv_num_blocks=5)
    assert streams(got) == streams(ref)
    assert all(r.done for r in got)


# ------------------------------------------------------------- rejections


def test_paged_rejects_bad_configs(dense_setup):
    cfg, params = dense_setup
    with pytest.raises(ValueError, match="kv_layout"):
        Engine(cfg, params, ServeConfig(kv_layout="chunked"))
    with pytest.raises(NotImplementedError, match="static"):
        Engine(cfg, params, ServeConfig(kv_layout="paged",
                                        scheduler="static"))
    with pytest.raises(ValueError, match="divide"):
        Engine(cfg, params, ServeConfig(kv_layout="paged", max_len=40,
                                        kv_block_size=16))
    with pytest.raises(ValueError, match="usable"):
        Engine(cfg, params, ServeConfig(kv_layout="paged", max_len=64,
                                        kv_block_size=16, kv_num_blocks=3))


def test_paged_rejects_recurrent_families():
    ssm = dataclasses.replace(get_config("falcon-mamba-7b"), n_layers=2,
                              d_model=32, d_ff=64, vocab=64)
    with pytest.raises(NotImplementedError, match="attention-family"):
        Engine(ssm, api.init_params(ssm, jax.random.PRNGKey(0)),
               ServeConfig(kv_layout="paged"))


def test_unified_prompt_length_message(dense_setup):
    """Submit-time and admit-time oversized-prompt rejections share ONE
    message (they used to diverge)."""
    cfg, params = dense_setup
    eng = Engine(cfg, params, ServeConfig(max_batch=2, max_len=16))
    big = make_req(7, plen=17)
    with pytest.raises(ValueError, match=r"request 7: prompt length 17 "
                                         r"exceeds max_len=16"):
        eng.submit(big)
    # bypass submit: the admit path must reject with the same message
    eng.queue.put(big)
    with pytest.raises(ValueError, match=r"request 7: prompt length 17 "
                                         r"exceeds max_len=16"):
        eng.run_until_drained()


# ---------------------------------------------------------- pool unit-ness


def test_blockpool_alloc_free_lru():
    pool = BlockPool(num_blocks=6, block_size=8)
    assert pool.usable == 5 and pool.free_pages == 5
    a = pool.alloc(3)
    assert a == [1, 2, 3] and pool.in_use == 3
    assert pool.alloc(3) is None            # only 2 left -> backpressure
    pool.free(a)
    assert pool.free_pages == 5 and pool.in_use == 0
    assert pool.alloc(6) is None            # beyond usable, ever


def test_blockpool_prefix_publish_refcount_evict():
    pool = BlockPool(num_blocks=4, block_size=4)
    prompt = np.arange(9, dtype=np.int32)   # 2 full blocks hashable
    keys = pool.prefix_keys(prompt)
    assert len(keys) == 2
    assert pool.lookup(keys) == []
    ids = pool.alloc(2)
    pool.publish(keys, ids)                 # donor's live reference
    assert pool.lookup(keys) == ids
    # a second sharer acquires, both release -> pages park evictable
    pool.acquire(ids)
    pool.release(ids)
    pool.free(ids, hashed=len(ids))         # donor retires
    assert pool.in_use == 0 and pool.free_pages == 3
    assert pool.lookup(keys) == ids         # retained: still hits
    # pressure reclaims LRU evictable pages and drops their digests
    got = pool.alloc(3)
    assert set(ids) <= set(got)
    assert pool.lookup(keys) == []


def test_blockpool_chained_keys_diverge():
    pool = BlockPool(num_blocks=8, block_size=4)
    a = pool.prefix_keys(np.arange(12, dtype=np.int32))
    b = pool.prefix_keys(np.concatenate([np.arange(4, dtype=np.int32),
                                         np.arange(100, 108,
                                                   dtype=np.int32)]))
    assert a[0] == b[0]                     # identical first block
    assert a[1] != b[1]                     # chained: diverges after


def test_blockpool_no_prefix_cache():
    pool = BlockPool(num_blocks=4, block_size=4, prefix_cache=False)
    assert pool.prefix_keys(np.arange(12, dtype=np.int32)) == []


# ------------------------------------------------------- config/budgeting


def test_check_config_paged():
    from repro.check.config import check_serve_config, kv_cache_bytes, \
        paged_num_blocks
    cfg = tiny_cfg()
    ok = ServeConfig(kv_layout="paged", max_len=64, kv_block_size=16)
    assert check_serve_config(ok, cfg) == []
    assert paged_num_blocks(ok) == 4 * 4 + 1
    # paged bytes with default sizing ~= contiguous bytes + garbage page
    # + table overhead
    contig = kv_cache_bytes(cfg, ServeConfig(max_len=64))
    paged = kv_cache_bytes(cfg, ok)
    per_page = cfg.n_layers * 2 * 16 * cfg.n_kv_heads * cfg.head_dim * 2
    assert paged == contig + per_page + 4 * ok.max_batch * 4
    # violations: layout enum, divisibility, deadlock floor, strict
    # max_batch floor
    assert check_serve_config(
        ServeConfig(kv_layout="nope"), cfg)
    assert check_serve_config(
        ServeConfig(kv_layout="paged", max_len=40, kv_block_size=16), cfg)
    assert check_serve_config(
        ServeConfig(kv_layout="paged", max_len=64, kv_block_size=16,
                    kv_num_blocks=4), cfg)
    strict_small = ServeConfig(kv_layout="paged", max_batch=8, max_len=64,
                               kv_block_size=16, kv_num_blocks=5)
    assert check_serve_config(strict_small, cfg, strict=True)
    assert check_serve_config(strict_small, cfg, strict=False) == []


# ------------------------------------------------- suffix-prefill exactness


def test_prefill_suffix_bitwise_exact(dense_setup):
    """The prefix-hit fast path's foundation: running only the suffix
    against the prefix K/V a bucketed prefill produced yields the SAME
    bits as prefilling the whole prompt — logits and suffix K/V alike."""
    cfg, params = dense_setup
    rng = np.random.default_rng(11)
    plen, pfx = 21, 16
    prompt = rng.integers(0, 64, (plen,)).astype(np.int32)
    max_len = 32
    full = jax.jit(api.prefill_fn(cfg, max_len))
    toks = np.zeros((1, max_len), np.int32)
    toks[0, :plen] = prompt
    logits_ref, cache = full(params, {
        "tokens": jnp.asarray(toks),
        "prompt_lens": jnp.asarray([plen], jnp.int32)})
    # donor ran under a DIFFERENT (shorter) bucket: prefix K/V must be
    # bucket-independent for reuse to be legal
    toks_d = np.zeros((1, pfx), np.int32)
    toks_d[0, :] = prompt[:pfx]
    _, donor = jax.jit(api.prefill_fn(cfg, pfx))(
        params, {"tokens": jnp.asarray(toks_d),
                 "prompt_lens": jnp.asarray([pfx], jnp.int32)})
    np.testing.assert_array_equal(np.asarray(cache["k"])[:, :, :pfx],
                                  np.asarray(donor["k"]))
    # suffix-only prefill over the donor's prefix K/V
    sfx = jax.jit(api.prefill_suffix_fn(cfg))
    s_sfx = plen - pfx
    stoks = np.zeros((1, 8), np.int32)      # bucketed past the real suffix
    stoks[0, :s_sfx] = prompt[pfx:]
    logits, ks, vs = sfx(params, {
        "tokens": jnp.asarray(stoks),
        "prefix_k": jnp.asarray(donor["k"]),
        "prefix_v": jnp.asarray(donor["v"]),
        "suffix_lens": jnp.asarray([s_sfx], jnp.int32)})
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(logits_ref))
    np.testing.assert_array_equal(
        np.asarray(ks)[:, :, :s_sfx],
        np.asarray(cache["k"])[:, :, pfx:pfx + s_sfx])
    np.testing.assert_array_equal(
        np.asarray(vs)[:, :, :s_sfx],
        np.asarray(cache["v"])[:, :, pfx:pfx + s_sfx])
