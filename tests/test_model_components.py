"""Component tests: attention (flash vs full, GQA, decode/SP math), MoE
dispatch invariants, mamba scan vs naive recurrence, chunked CE, rooofline
HLO parser, energy model claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.models import attention as A

KEY = jax.random.PRNGKey(0)


# -------------------------------------------------------------- attention --
@pytest.mark.parametrize("hq,hkv", [(8, 8), (8, 2), (4, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_full(hq, hkv, causal):
    q = jax.random.normal(KEY, (2, 24, hq, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 24, hkv, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 24, hkv, 16))
    o1 = A.flash_attention(q, k, v, causal=causal, block_k=8)
    o2 = A.full_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)


def test_flash_grads_match_full():
    q = jax.random.normal(KEY, (1, 16, 4, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 2, 8))
    f1 = lambda *a: jnp.sum(jnp.tanh(A.flash_attention(*a, causal=True, block_k=4)))
    f2 = lambda *a: jnp.sum(jnp.tanh(A.full_attention(*a, causal=True)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_decode_attention_matches_full_last_row():
    q = jax.random.normal(KEY, (2, 1, 4, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 12, 2, 8))
    # pad cache beyond valid length; decode must mask it
    kp = jnp.pad(k, ((0, 0), (0, 4), (0, 0), (0, 0)), constant_values=9.0)
    vp = jnp.pad(v, ((0, 0), (0, 4), (0, 0), (0, 0)), constant_values=9.0)
    got = A.decode_attention(q, kp, vp, jnp.array(12))
    want = A.full_attention(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_sp_combine_equals_unsharded():
    """Split the KV cache into 4 'shards', combine partials -> same output."""
    q = jax.random.normal(KEY, (1, 1, 4, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 2, 8))
    want = A.decode_attention(q, k, v, jnp.array(16))

    ms, ls, os_ = [], [], []
    for i in range(4):
        ksh, vsh = k[:, i * 4:(i + 1) * 4], v[:, i * 4:(i + 1) * 4]
        m, l, o = A.decode_attention_partial(q, ksh, vsh,
                                             jnp.ones(4, bool))
        ms.append(m), ls.append(l), os_.append(o)
    m_glob = jnp.max(jnp.stack(ms), 0)
    corr = [jnp.exp(m - m_glob) for m in ms]
    l_glob = sum(l * c for l, c in zip(ls, corr))
    o_glob = sum(o * c[..., None] for o, c in zip(os_, corr)) / l_glob[..., None]
    got = jnp.moveaxis(o_glob, 3, 1).reshape(1, 1, 4, 8)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# -------------------------------------------------------------------- moe --
def test_moe_matches_dense_when_capacity_ample():
    from repro.configs.base import MoEConfig
    from repro.models.moe import init_moe, moe_ffn_local
    moe = MoEConfig(num_experts=4, top_k=2, d_ff=16, capacity_factor=8.0)
    p = init_moe(KEY, 8, moe, "silu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 6, 8))
    got = moe_ffn_local(x, p, moe, "silu", jnp.float32)
    # dense reference: weight every expert by its softmaxed top-k prob
    logits = x.reshape(-1, 8) @ p["router"]
    k_v, k_i = jax.lax.top_k(logits, 2)
    probs = jax.nn.softmax(k_v, -1)
    dense = np.zeros((12, 8), np.float32)
    for t in range(12):
        for j in range(2):
            e = int(k_i[t, j])
            h = x.reshape(-1, 8)[t] @ p["w_up"][e]
            g = x.reshape(-1, 8)[t] @ p["w_gate"][e]
            z = jax.nn.silu(g) * h
            dense[t] += float(probs[t, j]) * np.asarray(z @ p["w_down"][e])
    np.testing.assert_allclose(got.reshape(12, 8), dense, rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    from repro.configs.base import MoEConfig
    from repro.models.moe import init_moe, moe_ffn_local
    moe = MoEConfig(num_experts=2, top_k=1, d_ff=8, capacity_factor=0.5)
    p = init_moe(KEY, 4, moe, "silu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 4))
    y = moe_ffn_local(x, p, moe, "silu", jnp.float32)
    # capacity = 2 per expert; at most 4 of 8 tokens get outputs
    nz = jnp.sum(jnp.any(jnp.abs(y) > 1e-9, axis=-1))
    assert int(nz) <= 4


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_moe_combine_weights_sum_bounded(seed):
    """Each token's combine weights are a softmax subset: output norm is
    bounded by max expert output norm."""
    from repro.configs.base import MoEConfig
    from repro.models.moe import _route
    x = jax.random.normal(jax.random.PRNGKey(seed), (16, 8))
    router = jax.random.normal(jax.random.PRNGKey(seed + 1), (8, 4))
    probs, ids = _route(x, router, 2)
    assert probs.shape == (16, 2)
    np.testing.assert_allclose(jnp.sum(probs, -1), 1.0, rtol=1e-5)
    assert int(jnp.max(ids)) < 4


# ------------------------------------------------------------------ mamba --
def test_mamba_scan_matches_naive_recurrence():
    from repro.models.mamba import mamba_scan
    b, l, di, n = 2, 12, 4, 3
    x = jax.random.normal(KEY, (b, l, di))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, l, di)))
    Amat = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (di, n)))
    Bt = jax.random.normal(jax.random.PRNGKey(3), (b, l, n))
    Ct = jax.random.normal(jax.random.PRNGKey(4), (b, l, n))
    y, h_last = mamba_scan(x, dt, Amat, Bt, Ct, chunk=4)

    h = np.zeros((b, di, n), np.float32)
    ys = np.zeros((b, l, di), np.float32)
    for t in range(l):
        a = np.exp(np.asarray(dt)[:, t, :, None] * np.asarray(Amat)[None])
        bx = (np.asarray(dt)[:, t] * np.asarray(x)[:, t])[:, :, None] \
            * np.asarray(Bt)[:, t, None, :]
        h = a * h + bx
        ys[:, t] = np.einsum("bdn,bn->bd", h, np.asarray(Ct)[:, t])
    np.testing.assert_allclose(y, ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h_last, h, rtol=2e-4, atol=2e-4)


def test_mamba_decode_matches_forward():
    import dataclasses
    from repro.configs.base import MambaConfig
    from repro.models.mamba import (init_mamba, mamba_decode_step,
                                    mamba_init_state)
    from repro.models.transformer import _mamba_forward_with_state
    m = MambaConfig(d_state=4, d_conv=3, expand=2)
    p = init_mamba(KEY, 8, m, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 6, 8)) * 0.3
    y_full, state = _mamba_forward_with_state(p, x, m, jnp.float32)
    # decode token-by-token must reproduce the full forward
    st_ = {"conv": jnp.zeros((2, m.d_conv - 1, 16)),
           "ssm": jnp.zeros((2, 16, 4))}
    outs = []
    for t in range(6):
        y_t, st_ = mamba_decode_step(p, x[:, t:t + 1], st_, m, jnp.float32)
        outs.append(y_t)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(y_step, y_full, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(st_["ssm"], state["ssm"], rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------- chunked CE --
def test_chunked_ce_matches_full():
    from repro.models.blocks import chunked_softmax_ce, cross_entropy
    h = jax.random.normal(KEY, (2, 10, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 32))
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 10), 0, 32)
    labels = labels.at[0, :2].set(-1)           # masked positions
    got = chunked_softmax_ce(h, w, labels, chunk=3, z_loss=0.0)
    want = cross_entropy(h @ w, labels)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_chunked_ce_grad_matches():
    from repro.models.blocks import chunked_softmax_ce, cross_entropy
    h = jax.random.normal(KEY, (1, 8, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
    labels = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0, 16)
    g1 = jax.grad(lambda ww: chunked_softmax_ce(h, ww, labels, chunk=4,
                                                z_loss=0.0))(w)
    g2 = jax.grad(lambda ww: cross_entropy(h @ ww, labels))(w)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-6)


# --------------------------------------------------------- roofline parser --
def test_hlo_parser_scan_trip_counts():
    from jax import lax
    from repro.roofline.hlo import analyze_hlo
    def f(x, w):
        y, _ = lax.scan(lambda c, _: (jnp.tanh(c @ w), None), x, None, length=7)
        return y
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                         jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    agg = analyze_hlo(c.as_text())
    assert agg["dot_flops"] == 7 * 2 * 64 ** 3


def test_hlo_parser_iota_replica_groups():
    from repro.roofline.hlo import _crosses_pod, _iota_groups
    g = _iota_groups("[2,4]<=[8]")
    np.testing.assert_array_equal(g, [[0, 1, 2, 3], [4, 5, 6, 7]])
    assert _crosses_pod("replica_groups=[2,4]<=[8]", pod_size=4) is False
    # [4,2]<=[2,4]T(1,0): groups {0,4},{1,5},{2,6},{3,7} — stride-4 pairs
    g2 = _iota_groups("[4,2]<=[2,4]T(1,0)")
    np.testing.assert_array_equal(g2, [[0, 4], [1, 5], [2, 6], [3, 7]])
    assert _crosses_pod("replica_groups=[4,2]<=[2,4]T(1,0)", pod_size=4) is True
    assert _crosses_pod("replica_groups={{0,1},{2,3}}", pod_size=2) is False
    assert _crosses_pod("replica_groups={{0,2},{1,3}}", pod_size=2) is True


# ---------------------------------------------------------------- energy ---
def test_energy_model_reproduces_paper_claims():
    from repro.core import ConvSpec, MCUModel, reuse_ratio
    from benchmarks.common import r_squared
    mcu = MCUModel()
    specs, macs, e_scalar, lat_simd, e_simd = [], [], [], [], []
    for hk in (1, 3, 5, 7):
        for cx in (4, 8, 16):
            s = ConvSpec(primitive="standard", in_channels=cx, out_channels=16,
                         kernel_size=hk, use_bias=False)
            macs.append(s.mac_count(32))
            e_scalar.append(mcu.energy_mj(s, 32, simd=False))
            lat_simd.append(mcu.latency_s(s, 32, simd=True))
            e_simd.append(mcu.energy_mj(s, 32, simd=True))
    r2_scalar = r_squared(macs, e_scalar)
    r2_simd_macs = r_squared(macs, e_simd)
    r2_simd_lat = r_squared(lat_simd, e_simd)
    assert r2_scalar > 0.99                       # paper: 0.995-0.999
    assert r2_simd_lat > r2_simd_macs - 1e-9      # latency predicts better
    # Table 3: max frequency minimizes energy
    e = [mcu.energy_mj(ConvSpec(in_channels=3, out_channels=32), 32,
                       simd=True, f_mhz=f) for f in (10, 20, 40, 80)]
    assert e[-1] == min(e)
    # Fig 3: shift conv has higher reuse ratio than standard at same shape
    r_std = reuse_ratio(ConvSpec(in_channels=16, out_channels=16), 32)
    assert r_std > 1.0
