"""Per-architecture smoke tests: reduced configs of the same family run one
forward/train step on CPU (shapes + no NaNs), and prefill+decode agrees with
the full forward pass. Full configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import api

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def reduce_cfg(cfg):
    """Same family, small everything (per assignment: few experts, tiny
    embeddings, small layers/width)."""
    kw = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
              vocab=256)
    if cfg.moe is not None:
        # ample capacity so decode vs teacher-forcing see identical routing
        kw["moe"] = dataclasses.replace(cfg.moe, num_experts=4,
                                        top_k=min(cfg.moe.top_k, 2), d_ff=64,
                                        capacity_factor=8.0)
    if cfg.family == "hybrid":
        kw.update(n_layers=8, attn_period=8, attn_offset=4)
    if cfg.family == "encdec":
        kw["n_encoder_layers"] = 2
    if cfg.family == "vlm":
        kw["frontend_positions"] = 4
    return dataclasses.replace(cfg, **kw)


def make_batch(cfg):
    if cfg.family == "vlm":
        return {"tokens": jax.random.randint(KEY, (B, S - 4), 0, cfg.vocab),
                "embeds": jax.random.normal(KEY, (B, 4, cfg.d_model),
                                            jnp.bfloat16)}
    if cfg.family == "encdec":
        return {"frames": jax.random.normal(KEY, (B, S, cfg.d_model),
                                            jnp.bfloat16),
                "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    return {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = reduce_cfg(get_config(arch))
    params = api.init_params(cfg, KEY)
    batch = make_batch(cfg)
    loss_fn = api.loss_fn(cfg)
    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))) for g in leaves), \
        f"{arch}: non-finite grads"
    # one SGD step changes the loss
    new_params = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g.astype(p.dtype),
                                        params, grads)
    loss2 = loss_fn(new_params, batch)
    assert jnp.isfinite(loss2) and float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """decode_step after prefill must reproduce teacher-forced logits."""
    cfg = reduce_cfg(get_config(arch))
    params = api.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab)
    max_len = 12

    if cfg.family == "encdec":
        frames = jax.random.normal(KEY, (B, 8, cfg.d_model), jnp.bfloat16)
        from repro.models.encdec import encdec_prefill, encdec_decode_step, encode
        from repro.models.blocks import rmsnorm
        logits_p, cache = encdec_prefill(params, frames, toks, cfg, max_len,
                                         attn_impl="full")
        nxt = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)
        logits_d, cache = encdec_decode_step(params, nxt, cache, cfg)
        # teacher-forced reference: full decoder over prompt+next
        from repro.models import encdec as ED
        import jax.numpy as jnp2
        cdt = jnp.bfloat16
        enc_out = encode(params, frames, cfg, attn_impl="full")
        h = params["embed"][jnp.concatenate([toks, nxt], 1)].astype(cdt)
        import jax.lax as lax
        body = lambda hh, lp: (ED._decoder_layer(hh, lp, enc_out, cfg, cdt, "full")[0], None)
        h, _ = lax.scan(body, h, params["dec_layers"])
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        ref = h[:, -1:].astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
        np.testing.assert_allclose(np.asarray(logits_d), np.asarray(ref),
                                   rtol=0.15, atol=0.15)
        return

    from repro.models.transformer import forward, prefill, decode_step
    kw = {}
    if cfg.family == "vlm":
        kw["embeds"] = jax.random.normal(KEY, (B, 4, cfg.d_model), jnp.bfloat16)
    logits_p, cache = prefill(params, toks, cfg, max_len, attn_impl="full", **kw)
    # teacher-forced full forward over the same prompt
    full = forward(params, toks, cfg, remat="none", **kw)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(full[:, -1]), rtol=0.15, atol=0.15)
    # one decode step vs extending the forward by one token
    nxt = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)
    logits_d, cache = decode_step(params, nxt, cache, cfg)
    full2 = forward(params, jnp.concatenate([toks, nxt], 1), cfg,
                    remat="none", **kw)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(full2[:, -1]), rtol=0.15, atol=0.2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count(arch):
    """Analytic param count of the FULL config lands near the advertised size."""
    sizes = {"internvl2-1b": 0.5e9, "arctic-480b": 480e9,
             "granite-moe-1b-a400m": 1.3e9, "granite-34b": 34e9,
             "qwen1.5-32b": 32e9, "granite-3-2b": 2.5e9,
             "qwen2-0.5b": 0.5e9, "seamless-m4t-large-v2": 1.6e9,
             "jamba-v0.1-52b": 52e9, "falcon-mamba-7b": 7e9}
    cfg = get_config(arch)
    n = cfg.param_count()
    assert 0.55 * sizes[arch] <= n <= 1.45 * sizes[arch], \
        f"{arch}: analytic {n/1e9:.2f}B vs advertised {sizes[arch]/1e9:.1f}B"


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", ["train_4k", "decode_32k"])
def test_input_specs_exist(arch, shape_name):
    from repro.configs.base import SHAPES, cell_supported
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        pytest.skip(why)
    specs = api.input_specs(cfg, shape)
    flat = jax.tree_util.tree_leaves(specs)
    assert all(isinstance(s, jax.ShapeDtypeStruct) for s in flat)
    if shape.kind == "train":
        total = sum(np.prod(s.shape) for s in flat
                    if s.dtype == jnp.int32 and len(s.shape) == 2)
        assert total >= shape.global_batch * shape.seq_len * 0.9
