"""repro: conv-primitive library + multi-pod JAX training/serving framework.

Reproduction target: Nguyen, Moellic, Blayac (2023), "Evaluation of
Convolution Primitives for Embedded Neural Networks on 32-bit
Microcontrollers", adapted TPU-natively (see DESIGN.md).
"""
__version__ = "1.0.0"
