"""Authoritative per-kernel VMEM footprint model + hard feasibility verdicts.

One function per concern, shared by every consumer so the soft cost model
and the hard verifier can never disagree:

* :func:`kernel_footprint` — resident VMEM bytes of one grid step of a
  kernel under a schedule, term by term, from the REAL BlockSpec shapes the
  kernels build (halo-padded image tiles, W4 half-width packed weight
  blocks, int32 accumulator scratch, matmul batch folding via the folded M
  extent in the signature). This replaces the six hand-written ``vmem =``
  formulas that used to live in ``tune/runner.py``.
* :func:`check_schedule` — the hard feasibility verdict the executor, the
  dispatch layer, and the cache audit enforce: unknown/invalid schedule
  keys are errors, a footprint over the per-backend VMEM budget is an
  error, a schedule that silently degrades (requested != effective) is a
  warning.
* :func:`audit_cache` — re-verify every entry of a persistent tune cache
  (``scripts/check_plan.py`` runs it over ``artifacts/tune_cache.json`` in
  CI), flagging stale infeasible entries.

The model prices what is resident in VMEM during one grid step; inter-step
traffic is the cost model's business (``tune.runner.estimate_s``).
"""
from __future__ import annotations

import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.energy import TPUv5e
from repro.kernels.common import cdiv

_TPU = TPUv5e()

# Per-backend VMEM budgets (bytes). Keys are jax backend base names; the
# cpu/interpret entries use the TPU budget because interpret mode VALIDATES
# TPU feasibility on CPU — a schedule that only fits in host RAM is still
# infeasible on the target. REPRO_VMEM_BUDGET overrides everything (e.g. to
# model a smaller part, the paper's Cortex-M framing).
BUDGETS: Dict[str, int] = {
    "tpu": _TPU.vmem_bytes,
    "cpu": _TPU.vmem_bytes,
    "gpu": _TPU.vmem_bytes,
}
DEFAULT_BUDGET = _TPU.vmem_bytes

# Schedule keys each kernel's wrapper understands — anything else in a
# config dict is a typo'd knob that would be silently ignored at dispatch.
KNOWN_KEYS: Dict[str, Tuple[str, ...]] = {
    "conv2d": ("block_co", "block_n", "block_h", "block_w"),
    "depthwise2d": ("block_c", "block_n", "block_h", "block_w"),
    "shift_conv2d": ("block_co", "block_n", "block_h", "block_w"),
    "add_conv2d": ("block_co", "block_n", "block_h", "block_w"),
    "maxpool2d": ("block_c", "block_n", "block_h", "block_w"),
    "causal_conv1d": ("block_l", "block_c"),
    "matmul": ("bm", "bn", "bk"),
}

ACC_BYTES = 4                     # int32 / f32 accumulator width


def element_bytes(dtype: str) -> int:
    """Bytes per *activation* element. "w4a8" activations are int8; the
    nibble-packed weight side is priced by :func:`weight_block_bytes`."""
    return {"int8": 1, "uint8": 1, "w4a8": 1,
            "bfloat16": 2, "float16": 2}.get(str(dtype), 4)


def weight_bytes(dtype: str) -> float:
    """Average bytes per *weight* element: 0.5 for nibble-packed W4 (two
    int4 codes per byte), else the element width. The continuous value the
    cost model prices HBM traffic with."""
    return 0.5 if str(dtype) == "w4a8" else float(element_bytes(dtype))


def weight_block_bytes(n_elems_packed_axis: int, n_rest: int,
                       dtype: str) -> int:
    """Exact VMEM bytes of one weight block: W4 packs two codes per byte
    along its unpack axis (``ceil(n/2)`` bytes, the half-width BlockSpec the
    kernels declare), everything else is ``n * element_bytes``."""
    if str(dtype) == "w4a8":
        return cdiv(n_elems_packed_axis, 2) * n_rest       # int8 bytes
    return n_elems_packed_axis * n_rest * element_bytes(dtype)


def vmem_budget(backend: Optional[str] = None) -> int:
    """Per-backend VMEM budget in bytes (REPRO_VMEM_BUDGET wins)."""
    env = os.environ.get("REPRO_VMEM_BUDGET")
    if env:
        return int(env)
    if backend is None:
        import jax
        backend = jax.default_backend()
    return BUDGETS.get(str(backend).split("+")[0], DEFAULT_BUDGET)


@dataclasses.dataclass(frozen=True)
class Footprint:
    """Resident VMEM bytes of one grid step, term by term."""

    kernel: str
    terms: Tuple[Tuple[str, int], ...]

    @property
    def total_bytes(self) -> int:
        return sum(v for _, v in self.terms)

    def breakdown(self) -> str:
        return " + ".join(f"{k}={v}" for k, v in self.terms)


def _fp(kernel: str, **terms: int) -> Footprint:
    return Footprint(kernel, tuple((k, int(v)) for k, v in terms.items()))


def kernel_footprint(sig, config: Optional[dict] = None,
                     dtype: str = "int8") -> Footprint:
    """VMEM footprint of one grid step of ``sig.kernel`` under ``config``.

    ``config`` is resolved through ``tune.space.effective_config`` first
    (idempotent), so the footprint describes the schedule the kernel
    actually runs. Terms mirror the kernels' BlockSpecs:

    - ``img``: the halo-padded input tile block (the tiled conv/pool grids
      duplicate ``size - step`` halo rows at wrapper level, so the block is
      ``(bn, bh + hk - 1, bw + hk - 1, C)``);
    - ``wts``: the weight block — HALF width for W4 nibble-packed weights
      (only packed bytes cross HBM -> VMEM);
    - ``out``: the output block at the activation width;
    - ``acc``: int32 accumulator scratch (the add-conv |x-w| broadcast
      intermediate is its dominating instance).

    Matmul batch folding: ``CompiledPlan``/``matmul_q8`` fold a leading
    batch dim into M before building the grid, so a batched matmul's
    signature already carries the folded ``m = batch * rows`` and no extra
    term is needed here.
    """
    from repro.tune.space import _out_hw, effective_config

    k = sig.kernel
    eff = effective_config(sig, config or {})
    eb = element_bytes(dtype)

    if k == "conv2d":
        ci, hk, g = sig.get("ci"), sig.get("k"), max(sig.get("g"), 1)
        cxg = ci // g
        bco = eff["block_co"]
        bn, bh, bw = eff["block_n"], eff["block_h"], eff["block_w"]
        halo = hk - 1
        return _fp(
            k,
            img=bn * (bh + halo) * (bw + halo) * cxg * eb,
            wts=hk * hk * weight_block_bytes(cxg, bco, dtype),
            out=bn * bh * bw * bco * eb,
            acc=bn * bh * bw * bco * ACC_BYTES,
        )

    if k == "depthwise2d":
        hk = sig.get("k")
        bc = eff["block_c"]
        bn, bh, bw = eff["block_n"], eff["block_h"], eff["block_w"]
        halo = hk - 1
        return _fp(
            k,
            img=bn * (bh + halo) * (bw + halo) * bc * eb,
            wts=weight_block_bytes(hk, hk * bc, dtype),   # W4 packs tap rows
            out=bn * bh * bw * bc * eb,
            acc=bn * bh * bw * bc * ACC_BYTES,
        )

    if k == "shift_conv2d":
        c = sig.get("c")
        bco = eff["block_co"]
        bn, bh, bw = eff["block_n"], eff["block_h"], eff["block_w"]
        # the shift gather reads every input channel per step; halo = 2*pad
        # with pad = kernel_size // 2 (3x3 shift grid -> pad 1, the only
        # configuration the paper's shift-conv uses; the signature carries
        # no kernel extent)
        pad = 1
        return _fp(
            k,
            img=bn * (bh + 2 * pad) * (bw + 2 * pad) * c * eb,
            wts=weight_block_bytes(c, bco, dtype),
            out=bn * bh * bw * bco * eb,
            acc=bn * bh * bw * bco * ACC_BYTES,
        )

    if k == "add_conv2d":
        ci, hk = sig.get("ci"), sig.get("k")
        bco = eff["block_co"]
        bn, bh, bw = eff["block_n"], eff["block_h"], eff["block_w"]
        halo = hk - 1
        return _fp(
            k,
            img=bn * (bh + halo) * (bw + halo) * ci * eb,
            wts=hk * hk * weight_block_bytes(ci, bco, dtype),
            out=bn * bh * bw * bco * eb,
            # |x - w| broadcast: the (BN*BH*BW, Cx, BCO) intermediate is the
            # VMEM hog the spatial tile exists to bound
            acc=(bn * bh * bw * ci * bco + bn * bh * bw * bco) * ACC_BYTES,
        )

    if k == "maxpool2d":
        win, s = sig.get("k"), sig.get("s")
        bc = eff["block_c"]
        bn, bh, bw = eff["block_n"], eff["block_h"], eff["block_w"]
        return _fp(
            k,
            img=bn * ((bh - 1) * s + win) * ((bw - 1) * s + win) * bc * eb,
            out=bn * bh * bw * bc * eb,
        )

    if k == "causal_conv1d":
        kk = sig.get("k")
        bl, bc = eff["block_l"], eff["block_c"]
        return _fp(
            k,
            # current + lookahead block of the same padded array (the
            # causal-halo trick: two BlockSpecs over one input)
            img=2 * bl * bc * eb,
            wts=kk * bc * eb,
            out=bl * bc * eb,
            acc=bl * bc * ACC_BYTES,
        )

    if k == "matmul":
        bm, bn_, bk = eff["bm"], eff["bn"], eff["bk"]
        return _fp(
            k,
            a=bm * bk * eb,
            b=weight_block_bytes(bk, bn_, dtype),
            out=bm * bn_ * eb,
            acc=bm * bn_ * ACC_BYTES,        # pltpu.VMEM scratch accumulator
        )

    raise ValueError(f"unknown kernel {k!r}")


# --------------------------------------------------------------------------
# Hard feasibility verdict
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Verdict:
    """Result of one :func:`check_schedule` call."""

    ok: bool
    sig_key: str
    kernel: str
    dtype: str
    config: dict
    effective: dict
    footprint: Optional[Footprint]
    budget: int
    errors: List[str]
    warnings: List[str]

    def message(self) -> str:
        head = f"{self.kernel}/{self.sig_key} [{self.dtype}] {self.config}"
        if self.ok and not self.warnings:
            return f"{head}: ok"
        tail = "; ".join(self.errors + self.warnings)
        return f"{head}: {tail}"


def check_schedule(sig, config: Optional[dict], dtype: str = "int8", *,
                   budget: Optional[int] = None,
                   backend: Optional[str] = None) -> Verdict:
    """Static feasibility verdict for one (kernel, shape, schedule, dtype).

    Errors (``ok=False``): unknown schedule keys, non-positive block values,
    VMEM footprint over the per-backend budget. Warnings: a requested block
    the kernel silently degrades (requested != effective schedule) —
    legal, but the measured entry then describes a different schedule than
    its config dict suggests.
    """
    from repro.tune.space import effective_config

    config = dict(config or {})
    dtype = str(dtype)
    budget = vmem_budget(backend) if budget is None else int(budget)
    errors: List[str] = []
    warnings: List[str] = []

    known = KNOWN_KEYS.get(sig.kernel, ())
    unknown = sorted(set(config) - set(known))
    if unknown:
        errors.append(f"unknown schedule key(s) {unknown}; "
                      f"{sig.kernel} understands {sorted(known)}")
    bad = {k: v for k, v in config.items()
           if k in known and (not isinstance(v, int) or v < 1)}
    if bad:
        errors.append(f"non-positive/non-int block value(s) {bad}")

    eff: dict = {}
    fp: Optional[Footprint] = None
    if not errors:
        eff = effective_config(sig, config)
        fp = kernel_footprint(sig, eff, dtype)
        if fp.total_bytes > budget:
            errors.append(
                f"VMEM footprint {fp.total_bytes} B exceeds the "
                f"{budget} B budget ({fp.breakdown()}); shrink "
                f"block_n/block_h/block_w or the channel block")
        degraded = {k: (v, eff[k]) for k, v in config.items()
                    if k in eff and eff[k] != v}
        if degraded:
            warnings.append(
                "requested schedule degrades on this shape: "
                + ", ".join(f"{k}: {a} -> {b}"
                            for k, (a, b) in degraded.items()))

    return Verdict(ok=not errors, sig_key=sig.key(), kernel=sig.kernel,
                   dtype=dtype, config=config, effective=eff, footprint=fp,
                   budget=budget, errors=errors, warnings=warnings)


# --------------------------------------------------------------------------
# Tune-cache audit
# --------------------------------------------------------------------------

_DIM_RE = re.compile(r"([a-z]+)(\d+)")


def parse_cache_key(key: str):
    """Invert ``tune.cache.cache_key``: ``kernel|shape|dtype|backend`` ->
    ``(ShapeSig, dtype, backend)``. The shape key is the underscore-joined
    ``<name><int>`` dims in signature order."""
    from repro.tune.space import ShapeSig
    kernel, shape_key, dtype, backend = key.split("|")
    dims = tuple((m.group(1), int(m.group(2)))
                 for m in _DIM_RE.finditer(shape_key))
    sig = ShapeSig(kernel, dims)
    if sig.key() != shape_key:
        raise ValueError(f"unparseable shape key {shape_key!r} in {key!r}")
    return sig, dtype, backend


def audit_cache(cache=None, *, budget: Optional[int] = None) -> List[dict]:
    """Re-verify every entry of a persistent tune cache against the current
    footprint model; one row per entry. Stale infeasible entries (tuned
    before the verifier existed, or against a larger budget) come back with
    ``ok=False`` and the verdict's reasons — re-tune or drop them.

    ``cache`` is a ``tune.cache.TuneCache``, a path, or None for the
    default committed cache.
    """
    from repro.tune import cache as _cache
    if cache is None or isinstance(cache, str):
        cache = _cache.TuneCache(cache or _cache.default_cache_path())
    rows = []
    for key in sorted(cache.entries):
        entry = cache.entries[key]
        sig, dtype, backend = parse_cache_key(key)
        v = check_schedule(sig, entry.get("config") or {}, dtype,
                           budget=budget, backend=backend)
        # a cached config larger than the shape is deterministic clamping
        # (candidates() dedupes by effective schedule) — informational,
        # not a hazard, so it lands in "notes" rather than "warnings"
        notes = [w for w in v.warnings if "degrades" in w]
        warns = [w for w in v.warnings if w not in notes]
        rows.append({
            "key": key, "ok": v.ok, "config": dict(entry.get("config") or {}),
            "effective": v.effective, "source": entry.get("source"),
            "vmem_bytes": v.footprint.total_bytes if v.footprint else None,
            "budget_bytes": v.budget,
            "errors": v.errors, "warnings": warns, "notes": notes,
        })
    return rows


def summarize_audit(rows: Iterable[dict]) -> dict:
    rows = list(rows)
    return {
        "entries": len(rows),
        "feasible": sum(r["ok"] for r in rows),
        "infeasible": [r["key"] for r in rows if not r["ok"]],
        "warnings": sum(bool(r["warnings"]) for r in rows),
        "notes": sum(bool(r.get("notes")) for r in rows),
    }
