"""AST lint for the repo's historic bug classes — stdlib ``ast`` only.

Each rule encodes a bug a previous PR fixed by hand, so the class can
never silently come back:

* **R1 index-map-default-arg** — a Pallas ``BlockSpec`` index map (inline
  lambda or a named local function) must not take default arguments. The
  PR-5 ``_n=n_co`` capture made an index map's arity lie about the grid:
  Pallas calls index maps with exactly one positional argument per grid
  axis, so a defaulted trailing parameter silently absorbs a grid axis and
  every block lands at index 0 of it — numerically wrong, no error raised.
* **R2 wall-clock-elapsed** — an elapsed-time subtraction must not be
  computed from ``time.time()``; PR 6 moved every timing path to monotonic
  ``time.perf_counter()`` (wall clock steps under NTP adjustment, so
  ``time() - t0`` intervals can go negative or jump). Reading ``time.time``
  for an absolute timestamp is fine; only ``Sub`` expressions over it are
  flagged.
* **R3 timer-stop-before-sync** — inside one function, a
  ``jax.block_until_ready`` call after the LAST timer-stop subtraction
  means the timer measured JAX async-dispatch enqueue time, not device
  time (the fused-kernel speedups this repo reports would be fiction).
  The sync must precede the stop.

Run as a module::

    python -m repro.check.astlint [paths...]     # default: src/ scripts/

Exit status 1 iff any finding. The rules are tuned for zero false
positives on this repo: default-arg lambdas OUTSIDE BlockSpec calls (cost
lambdas, tree maps) and absolute wall-clock stamps (``submit_wall_t``,
trace export) are specifically not flagged.
"""
from __future__ import annotations

import ast
import dataclasses
import sys
from pathlib import Path
from typing import Iterator, List


@dataclasses.dataclass
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _has_defaults(args: ast.arguments) -> bool:
    return bool(args.defaults) or bool(args.kw_defaults)


def _is_attr_call(call: ast.Call, name: str) -> bool:
    f = call.func
    return (isinstance(f, ast.Attribute) and f.attr == name) or \
        (isinstance(f, ast.Name) and f.id == name)


def _local_funcs(tree: ast.AST) -> dict:
    """name -> arguments for every def / ``name = lambda`` in the file."""
    out = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out[node.name] = (node.args, node.lineno)
        elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Lambda):
            out[node.targets[0].id] = (node.value.args, node.lineno)
    return out


def _index_map_args(call: ast.Call) -> Iterator[ast.expr]:
    """The candidate index-map expressions of one BlockSpec(...) call:
    every positional arg after the block-shape tuple plus any
    ``index_map=`` keyword."""
    for a in call.args[1:]:
        yield a
    for kw in call.keywords:
        if kw.arg == "index_map":
            yield kw.value


def _rule_index_map_defaults(path: str, tree: ast.AST) -> List[Finding]:
    funcs = _local_funcs(tree)
    out = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _is_attr_call(node, "BlockSpec")):
            continue
        for im in _index_map_args(node):
            if isinstance(im, ast.Lambda) and _has_defaults(im.args):
                out.append(Finding(
                    path, im.lineno, "index-map-default-arg",
                    "BlockSpec index map takes default arguments; a "
                    "defaulted parameter absorbs a grid axis and the "
                    "block indexing silently degenerates (PR-5 _n=n_co "
                    "bug class)"))
            elif isinstance(im, ast.Name) and im.id in funcs \
                    and _has_defaults(funcs[im.id][0]):
                out.append(Finding(
                    path, im.lineno, "index-map-default-arg",
                    f"BlockSpec index map {im.id!r} (defined line "
                    f"{funcs[im.id][1]}) takes default arguments; a "
                    "defaulted parameter absorbs a grid axis (PR-5 "
                    "_n=n_co bug class)"))
    return out


def _is_time_time(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "time"
            and isinstance(node.func.value, ast.Name)
            and node.func.value.id == "time")


def _is_monotonic_stamp(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in ("perf_counter", "monotonic"))


def _rule_wall_clock_elapsed(path: str, tree: ast.AST) -> List[Finding]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub) \
                and any(_is_time_time(n) for n in ast.walk(node)):
            out.append(Finding(
                path, node.lineno, "wall-clock-elapsed",
                "elapsed time computed from time.time(); wall clock steps "
                "under NTP adjustment — use time.perf_counter() "
                "(monotonic) for intervals"))
    return out


def _rule_stop_before_sync(path: str, tree: ast.AST) -> List[Finding]:
    out = []
    scopes = [n for n in ast.walk(tree)
              if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    scopes.append(tree)                     # module level counts as a scope
    for scope in scopes:
        # direct statements of this scope only — nested defs are their own
        # timing scopes (benchmark closures time themselves)
        nested = {id(n) for s in ast.walk(scope)
                  if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef))
                  and s is not scope for n in ast.walk(s)}
        local = [n for n in ast.walk(scope)
                 if id(n) not in nested and n is not scope]
        stops = [n.lineno for n in local
                 if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Sub)
                 and any(_is_monotonic_stamp(x) for x in ast.walk(n))]
        if not stops:
            continue
        last_stop = max(stops)
        for n in local:
            if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute) \
                    and n.func.attr == "block_until_ready" \
                    and n.lineno > last_stop:
                out.append(Finding(
                    path, n.lineno, "timer-stop-before-sync",
                    f"block_until_ready after the last timer stop (line "
                    f"{last_stop}); the timer measured async-dispatch "
                    "enqueue time, not device time — sync before stopping"))
    return out


RULES = (_rule_index_map_defaults, _rule_wall_clock_elapsed,
         _rule_stop_before_sync)


def lint_file(path) -> List[Finding]:
    src = Path(path).read_text()
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:
        return [Finding(str(path), e.lineno or 0, "syntax-error", str(e))]
    out: List[Finding] = []
    for rule in RULES:
        out.extend(rule(str(path), tree))
    return out


def lint_paths(paths) -> List[Finding]:
    files: List[Path] = []
    for p in map(Path, paths):
        files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    out: List[Finding] = []
    for f in files:
        out.extend(lint_file(f))
    return out


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    paths = argv or ["src", "scripts"]
    findings = lint_paths(paths)
    for f in findings:
        print(f)
    n_files = sum(1 for p in map(Path, paths)
                  for _ in (p.rglob("*.py") if p.is_dir() else [p]))
    print(f"astlint: {len(findings)} finding(s) over {n_files} file(s) "
          f"[{', '.join(r.__name__.replace('_rule_', '') for r in RULES)}]")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
