"""Int32 accumulator and requant-shift range analysis per quantized node.

CMSIS-NN's q7/q15 kernels are only correct because accumulator ranges and
shift amounts are proven safe ahead of time; this module is that proof for
our plans, computed from the ACTUAL weight codes (not a generic
worst-case): for each quantized stage the worst-case accumulator magnitude
is

    |acc| <= sum_over_reduction(|w_code|) * 127 + |bias_at_acc_scale|

(sum over the stage's taps and input channels, maximized over output
channels), with W4 weights expanded through their shift sideband first —
``q4 << group_shift`` is the int8 code the kernels actually accumulate.
On top of the raw bound, the Algorithm-1 requantization epilogue is
validated: the round-to-nearest term ``+ (1 << (shift-1))`` must not push
the accumulator past int32, and a negative shift (pure left shift) must
not wrap. The add-conv integer-BN node (``qbn``) additionally checks its
int16-range multiplier budget from ``graph/lower.py``.

No kernel is executed: the analysis reads the quantized parameter arrays
(host-side numpy sums) and the plan's static scale bookkeeping. See
EXPERIMENTS.md §Static-checks for the per-primitive bound table.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

import numpy as np

INT32_MAX = 2 ** 31 - 1
INT8_ABS_MAX = 127              # worst-case int8 activation magnitude
QBN_MULT_ABS_MAX = 2 ** 15      # lower._quantize_bn_affine's budget


@dataclasses.dataclass
class NodeBound:
    """Worst-case accumulator analysis of one quantized stage."""

    node: str
    stage: str                   # "main" | "dw" | "pw" | "qbn"
    primitive: Optional[str]
    acc_max: int                 # worst-case |int32 accumulator|
    requant_shift: int
    ok: bool
    messages: List[str]

    @property
    def acc_bits(self) -> int:
        """Magnitude bits the accumulator provably never exceeds."""
        return max(1, math.ceil(math.log2(self.acc_max + 1)))

    @property
    def headroom_bits(self) -> float:
        """Spare bits below the int32 sign boundary (>= 0 means safe)."""
        return 31 - math.log2(self.acc_max + 1) if self.acc_max >= 0 else 31.0


def _abs_codes(w) -> np.ndarray:
    """|weight codes| as int64 numpy — QTensorW4 leaves are expanded through
    their shift sideband first (the codes the kernels accumulate)."""
    from repro.core.quantize import QTensorW4
    q = w.expand() if isinstance(w, QTensorW4) else w.q
    return np.abs(np.asarray(q, dtype=np.int64))


def _per_co_abs_sum(w, *, co_axis: int = -1) -> int:
    """max over output channels of sum(|codes|) over every other axis."""
    a = _abs_codes(w)
    axes = tuple(i for i in range(a.ndim) if i != co_axis % a.ndim)
    return int(a.sum(axis=axes).max()) if a.size else 0


def _bias_abs_max(bias, acc_fb: int) -> int:
    """Worst-case |bias| after rescaling to the accumulator scale — the
    exact ``_bias_acc`` arithmetic (rounded right shift / left shift) on
    int64, so the bound covers the rescaled values bit-for-bit."""
    if bias is None:
        return 0
    q = np.abs(np.asarray(bias.q, dtype=np.int64))
    shift = bias.frac_bits - acc_fb
    if shift > 0:
        q = (q + (1 << (shift - 1))) >> shift
    elif shift < 0:
        q = q << (-shift)
    return int(q.max()) if q.size else 0


def check_requant_shift(acc_max: int, shift: int) -> List[str]:
    """Validate one Algorithm-1 requantization against a proven accumulator
    bound. Returns the (possibly empty) list of violations."""
    msgs: List[str] = []
    if not isinstance(shift, (int, np.integer)):
        return [f"requant shift must be a static int, got {shift!r}"]
    shift = int(shift)
    if abs(shift) >= 32:
        msgs.append(f"requant shift {shift} is outside the int32 shift "
                    "range (|shift| must be < 32)")
        return msgs
    if acc_max > INT32_MAX:
        msgs.append(f"worst-case |accumulator| {acc_max} "
                    f"(2^{math.log2(acc_max + 1):.1f}) overflows int32")
    elif shift > 0 and acc_max + (1 << (shift - 1)) > INT32_MAX:
        msgs.append(
            f"round-to-nearest term 1<<{shift - 1} pushes the worst-case "
            f"accumulator {acc_max} past int32 (Algorithm-1 epilogue "
            "overflows before the shift)")
    elif shift < 0 and acc_max << (-shift) > INT32_MAX:
        msgs.append(
            f"left-shift requantization (shift {shift}) wraps: "
            f"{acc_max} << {-shift} overflows int32")
    return msgs


def _bound(node_name: str, stage: str, primitive: Optional[str],
           acc_max: int, shift, extra_msgs: List[str]) -> NodeBound:
    msgs = list(extra_msgs) + check_requant_shift(acc_max, shift)
    return NodeBound(node=node_name, stage=stage, primitive=primitive,
                     acc_max=int(acc_max),
                     requant_shift=int(shift) if isinstance(
                         shift, (int, np.integer)) else 0,
                     ok=not msgs, messages=msgs)


def qconv_bounds(node) -> List[NodeBound]:
    """Per-stage accumulator bounds of one ``qconv`` plan node — the same
    scale chaining as ``core.qconv.qconv_apply``, evaluated symbolically."""
    from repro.core.qconv import _add_preshifts

    spec, qp = node.spec, node.qparams
    p = spec.primitive
    bias = qp.get("b")
    out: List[NodeBound] = []

    if p in ("standard", "grouped"):
        w = qp["w"]
        acc_fb = node.in_fb + w.frac_bits
        acc = _per_co_abs_sum(w) * INT8_ABS_MAX + _bias_abs_max(bias, acc_fb)
        out.append(_bound(node.name, "main", p, acc,
                          acc_fb - node.out_fb, []))

    elif p == "dws":
        w_dw, w_pw = qp["w_dw"], qp["w_pw"]
        mid_fb = qp.get("mid_frac_bits", node.out_fb)
        # depthwise: per-channel tap sum (each output channel only sees its
        # own channel's taps — co axis IS the channel axis)
        a = _abs_codes(w_dw)
        per_c = a.reshape(a.shape[0] * a.shape[1], -1).sum(axis=0)
        acc_dw = int(per_c.max()) * INT8_ABS_MAX if per_c.size else 0
        out.append(_bound(node.name, "dw", p, acc_dw,
                          node.in_fb + w_dw.frac_bits - mid_fb, []))
        acc_fb = mid_fb + w_pw.frac_bits
        acc_pw = (_per_co_abs_sum(w_pw) * INT8_ABS_MAX
                  + _bias_abs_max(bias, acc_fb))
        out.append(_bound(node.name, "pw", p, acc_pw,
                          acc_fb - node.out_fb, []))

    elif p == "shift":
        w_pw = qp["w_pw"]
        acc_fb = node.in_fb + w_pw.frac_bits
        acc = (_per_co_abs_sum(w_pw) * INT8_ABS_MAX
               + _bias_abs_max(bias, acc_fb))
        out.append(_bound(node.name, "main", p, acc,
                          acc_fb - node.out_fb, []))

    elif p == "add":
        w = qp["w"]
        x_pre, w_pre, acc_fb = _add_preshifts(node.in_fb, w.frac_bits)
        msgs = []
        if not (0 <= x_pre < 24 and 0 <= w_pre < 24):
            msgs.append(f"add-conv scale-alignment preshifts out of range: "
                        f"x_preshift={x_pre} w_preshift={w_pre}")
        # |xi - wi| <= 127 << x_pre + |w| << w_pre per tap, summed over the
        # (hk, hk, cx) reduction; the sign-flipped sum has the same bound
        a = (_abs_codes(w) << w_pre).reshape(-1, _abs_codes(w).shape[-1])
        per_co = a.sum(axis=0)
        taps = a.shape[0]                       # hk * hk * cx reduction size
        acc = int(per_co.max()) + taps * (INT8_ABS_MAX << x_pre) \
            + _bias_abs_max(bias, acc_fb)
        out.append(_bound(node.name, "main", p, acc,
                          acc_fb - node.out_fb, msgs))

    else:
        out.append(_bound(node.name, "main", p, 0, 0,
                          [f"unknown primitive {p!r}"]))
    return out


def qbn_bounds(node) -> NodeBound:
    """The add-conv integer-BN affine: ``acc = x * a + b`` with the int16-
    range multiplier budget from ``graph/lower._quantize_bn_affine``."""
    qp = node.qparams
    a = np.abs(np.asarray(qp["a"], dtype=np.int64))
    b = np.abs(np.asarray(qp["b"], dtype=np.int64))
    a_max = int(a.max()) if a.size else 0
    msgs: List[str] = []
    if a_max > QBN_MULT_ABS_MAX:
        msgs.append(
            f"qbn multiplier magnitude {a_max} exceeds the int16-range "
            f"budget {QBN_MULT_ABS_MAX} (lower._quantize_bn_affine "
            "contract)")
    acc = a_max * INT8_ABS_MAX + (int(b.max()) if b.size else 0)
    shift = node.in_fb + qp["a_frac_bits"] - node.out_fb
    return _bound(node.name, "qbn", None, acc, shift, msgs)


def check_plan_overflow(plan) -> List[NodeBound]:
    """Accumulator/shift analysis of every quantized node of a plan."""
    out: List[NodeBound] = []
    for node in plan.nodes:
        if node.op == "qconv":
            out.extend(qconv_bounds(node))
        elif node.op == "qbn":
            out.append(qbn_bounds(node))
    return out


def overflow_errors(bounds: List[NodeBound]) -> List[str]:
    """Flatten failing bounds into per-node diagnostics."""
    return [f"{b.node}/{b.stage}: {m}"
            for b in bounds if not b.ok for m in b.messages]
