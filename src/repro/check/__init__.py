"""repro.check — static feasibility, overflow, and dataflow verification.

Everything in this package reasons about kernels, plans, and schedules
WITHOUT executing any kernel: the analyses read block shapes, dtype widths,
quantized weight codes, and graph structure, and return hard verdicts. It
is the feasibility oracle the paper's workflow needs explicitly — the
microTVM exemplar hand-picks per-conv strategies "because certain strategy
combos exceed available memory", and CMSIS-NN's q7/q15 kernels are only
correct because accumulator ranges and shift amounts are proven safe ahead
of time. Four passes:

* :mod:`~repro.check.footprint` — the single authoritative per-kernel
  VMEM/scratch footprint model (shared with the ``repro.tune`` cost model)
  plus :func:`check_schedule`, the hard per-schedule feasibility verdict,
  and the tune-cache audit.
* :mod:`~repro.check.overflow` — int32 accumulator and requant-shift range
  analysis per quantized plan node, from the actual weight codes.
* :mod:`~repro.check.dataflow` — an abstract interpreter over the graph IR
  checking shape/grid coverage, dtype flow (int8 conv -> gap), and fusion
  legality.
* :mod:`~repro.check.astlint` — an AST lint encoding the repo's historic
  bug classes (default-arg index-map captures, wall-clock timing,
  timers stopped before ``block_until_ready``).

``validate_plan`` bundles dataflow + overflow into the one call
``graph.executor.CompiledPlan`` runs at build; ``scripts/check_plan.py``
is the CLI over all of it. See EXPERIMENTS.md §Static-checks.
"""
from __future__ import annotations

from .config import (check_cnn_serve_config, check_serve_config,
                     kv_cache_bytes)
from .dataflow import Diagnostic, check_plan
from .footprint import (Footprint, Verdict, audit_cache, check_schedule,
                        kernel_footprint, parse_cache_key, vmem_budget)
from .overflow import (INT32_MAX, NodeBound, check_plan_overflow,
                       check_requant_shift, overflow_errors)


class CheckError(ValueError):
    """A static check failed; ``str(exc)`` lists every diagnostic."""

    def __init__(self, header: str, messages):
        self.messages = tuple(messages)
        body = "\n".join(f"  - {m}" for m in self.messages)
        super().__init__(f"{header}\n{body}" if self.messages else header)


def validate_plan(plan) -> None:
    """Build-time plan verification: dataflow legality + accumulator/shift
    safety from the actual weight codes. Raises :class:`CheckError` listing
    every failure; returns None when the plan is statically safe."""
    errors = [d.message for d in check_plan(plan) if d.level == "error"]
    errors += overflow_errors(check_plan_overflow(plan))
    if errors:
        raise CheckError(
            "plan failed static verification (repro.check.validate_plan; "
            "pass validate=False to bypass):", errors)


__all__ = [
    "CheckError", "Diagnostic", "Footprint", "INT32_MAX", "NodeBound",
    "Verdict", "audit_cache", "check_cnn_serve_config", "check_plan",
    "check_plan_overflow", "check_requant_shift", "check_schedule",
    "check_serve_config",
    "kernel_footprint", "kv_cache_bytes", "overflow_errors",
    "parse_cache_key", "validate_plan", "vmem_budget",
]
