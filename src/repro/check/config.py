"""Serve-config static checks: enum/range validation and the KV-cache
budget, computed before any parameter is touched.

``Engine.__init__`` already hard-raises on illegal enum combos; this module
is the same contract as a pure function returning EVERY violation at once
(CI and ``scripts/check_plan.py`` want the full list, not the first raise),
plus the numeric checks the constructor skips: positive batch/length/bucket
knobs and the resident KV budget ``kv_cache_bytes`` against an optional
device budget.
"""
from __future__ import annotations

from typing import List, Optional

SCHEDULERS = ("continuous", "static")
SHED_POLICIES = ("reject", "drop")
PRECISIONS = ("float", "int8", "int8-xla", "w4a8")
KV_CACHES = ("float", "int8")
KV_LAYOUTS = ("contiguous", "paged")
ATTN_IMPLS = ("full", "flash", "flash_tri")

_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2, "int8": 1}


def paged_num_blocks(scfg) -> int:
    """Resolved pool size: ``kv_num_blocks`` when set, else the contiguous
    capacity equivalent — ``max_batch * (max_len // block_size)`` usable
    pages plus the reserved garbage page 0."""
    if getattr(scfg, "kv_num_blocks", None):
        return scfg.kv_num_blocks
    return scfg.max_batch * (scfg.max_len // scfg.kv_block_size) + 1


def kv_cache_bytes(cfg, scfg) -> int:
    """Resident KV budget of the ONE live decode cache.

    Contiguous layout: ``layers * K&V * max_batch * max_len * n_kv_heads *
    head_dim * width`` (int8 kv adds the per-(position, head) f32 scale
    sideband). Paged layout: ``layers * K&V * num_blocks * block_size``
    positions instead — memory scales with the pool, not ``max_batch *
    max_len`` — plus the (max_batch, max_len / block_size) int32 block
    table."""
    width = 1 if scfg.kv_cache == "int8" else \
        _DTYPE_BYTES.get(cfg.compute_dtype, 4)
    per_pos = cfg.n_kv_heads * cfg.head_dim * width
    if scfg.kv_cache == "int8":
        per_pos += cfg.n_kv_heads * 4           # f32 scale per (pos, head)
    if getattr(scfg, "kv_layout", "contiguous") == "paged":
        positions = paged_num_blocks(scfg) * scfg.kv_block_size
        table = 4 * scfg.max_batch * (scfg.max_len // scfg.kv_block_size)
        return cfg.n_layers * 2 * positions * per_pos + table
    return cfg.n_layers * 2 * scfg.max_batch * scfg.max_len * per_pos


def _check_resilience(scfg, errs: List[str]):
    """Failure-model knobs shared by ServeConfig and CNNServeConfig:
    deadline_s / max_queue / shed_policy / max_retries / retry_backoff_s
    (EXPERIMENTS.md §Resilience)."""
    d = getattr(scfg, "deadline_s", None)
    if d is not None and (not isinstance(d, (int, float)) or d <= 0):
        errs.append(f"deadline_s must be > 0 (or None to disable), "
                    f"got {d!r}")
    mq = getattr(scfg, "max_queue", None)
    if mq is not None:
        if not isinstance(mq, int) or mq < 1:
            errs.append(f"max_queue must be a positive int (or None to "
                        f"disable shedding), got {mq!r}")
        elif isinstance(scfg.max_batch, int) and mq < scfg.max_batch:
            errs.append(
                f"max_queue={mq} is below max_batch={scfg.max_batch}: the "
                "scheduler could never fill a round before shedding — "
                "raise max_queue to at least max_batch")
    sp = getattr(scfg, "shed_policy", "reject")
    if sp not in SHED_POLICIES:
        errs.append(f"unknown shed_policy: {sp!r} "
                    f"(choose from {SHED_POLICIES})")
    mr = getattr(scfg, "max_retries", 0)
    if not isinstance(mr, int) or mr < 0:
        errs.append(f"max_retries must be an int >= 0, got {mr!r}")
    rb = getattr(scfg, "retry_backoff_s", 0.0)
    if not isinstance(rb, (int, float)) or rb < 0:
        errs.append(f"retry_backoff_s must be >= 0, got {rb!r}")


def check_serve_config(scfg, cfg=None, *, hbm_budget: Optional[int] = None,
                       strict: bool = True) -> List[str]:
    """Every violation of a :class:`~repro.serve.engine.ServeConfig`
    (optionally against a :class:`~repro.configs.base.ModelConfig`).
    Empty list == the config constructs and fits.

    ``strict=False`` is the constructor-grade subset ``Engine.__init__``
    enforces; strict mode (the CLI/CI default) additionally flags configs
    that only fail later at submit time (a prefill bucket floor no prompt
    can fit under the per-slot KV cap)."""
    errs: List[str] = []
    if scfg.scheduler not in SCHEDULERS:
        errs.append(f"unknown scheduler: {scfg.scheduler!r} "
                    f"(choose from {SCHEDULERS})")
    if scfg.precision not in PRECISIONS:
        errs.append(f"unknown precision: {scfg.precision!r} "
                    f"(choose from {PRECISIONS})")
    if scfg.kv_cache not in KV_CACHES:
        errs.append(f"unknown kv_cache: {scfg.kv_cache!r} "
                    f"(choose from {KV_CACHES})")
    if scfg.attn_impl not in ATTN_IMPLS:
        errs.append(f"unknown attn_impl: {scfg.attn_impl!r} "
                    f"(choose from {ATTN_IMPLS})")
    for knob in ("max_batch", "max_len", "prefill_bucket"):
        v = getattr(scfg, knob)
        if not isinstance(v, int) or v < 1:
            errs.append(f"{knob} must be a positive int, got {v!r}")
    if scfg.temperature < 0:
        errs.append(f"temperature must be >= 0, got {scfg.temperature!r}")
    _check_resilience(scfg, errs)
    if scfg.kv_cache == "int8" and scfg.scheduler != "continuous":
        errs.append("kv_cache='int8' needs scheduler='continuous' (the "
                    "static path decodes off the float prefill cache)")

    layout = getattr(scfg, "kv_layout", "contiguous")
    if layout not in KV_LAYOUTS:
        errs.append(f"unknown kv_layout: {layout!r} "
                    f"(choose from {KV_LAYOUTS})")
    elif layout == "paged":
        if scfg.scheduler != "continuous":
            errs.append("kv_layout='paged' needs scheduler='continuous' "
                        "(the static path decodes off the prefill cache)")
        bs = scfg.kv_block_size
        if not isinstance(bs, int) or bs < 1:
            errs.append(f"kv_block_size must be a positive int, got {bs!r}")
        elif isinstance(scfg.max_len, int) and scfg.max_len % bs:
            errs.append(f"kv_block_size={bs} must divide max_len="
                        f"{scfg.max_len}: the gathered block-table view "
                        "must span exactly max_len positions for paged "
                        "decode to be bit-identical to contiguous")
        nb = scfg.kv_num_blocks
        if nb is not None and (not isinstance(nb, int) or nb < 2):
            errs.append(f"kv_num_blocks must be an int >= 2 (page 0 is the "
                        f"reserved garbage page), got {nb!r}")
        elif isinstance(bs, int) and bs >= 1 \
                and isinstance(scfg.max_len, int) \
                and not scfg.max_len % bs:
            usable = paged_num_blocks(scfg) - 1
            if usable < scfg.max_len // bs:
                errs.append(
                    f"kv_num_blocks={nb} leaves {usable} usable pages, "
                    f"fewer than the {scfg.max_len // bs} one request at "
                    f"max_len={scfg.max_len} needs — the engine could "
                    "deadlock growing a lone sequence")
            elif strict and usable < scfg.max_batch:
                errs.append(
                    f"kv_num_blocks={nb} leaves {usable} usable pages, "
                    f"fewer than max_batch={scfg.max_batch} minimum-length "
                    "requests (one page each) — slots can never all fill")

    if cfg is not None:
        if layout == "paged" and cfg.family in ("ssm", "hybrid", "encdec"):
            errs.append("kv_layout='paged' covers attention-family dense "
                        "KV caches only (no ssm / hybrid / encdec)")
        if cfg.family == "encdec" and scfg.scheduler == "continuous":
            errs.append("continuous batching needs slotted caches; encdec "
                        "is not slotted — use scheduler='static'")
        if scfg.precision != "float" and (
                cfg.family in ("ssm", "hybrid", "encdec")
                or cfg.moe is not None):
            errs.append(f"precision={scfg.precision!r} quantizes dense FFN "
                        "matmuls; moe/ssm/hybrid/encdec are unsupported")
        if scfg.kv_cache == "int8" and cfg.family in ("ssm", "hybrid",
                                                      "encdec"):
            errs.append("kv_cache='int8' covers attention-family dense KV "
                        "caches only (no ssm / hybrid / encdec)")
        if strict and not cfg.sub_quadratic() and cfg.family != "encdec" \
                and scfg.prefill_bucket > scfg.max_len:
            errs.append(f"prefill_bucket={scfg.prefill_bucket} exceeds "
                        f"max_len={scfg.max_len}; every bucket would "
                        "overflow the per-slot KV capacity")
        if hbm_budget is not None and cfg.family not in ("ssm",):
            kv = kv_cache_bytes(cfg, scfg)
            if kv > hbm_budget:
                errs.append(
                    f"resident KV cache needs {kv / 2**20:.1f} MiB "
                    f"({cfg.n_layers} layers x 2 x {scfg.max_batch} slots "
                    f"x {scfg.max_len} positions), over the "
                    f"{hbm_budget / 2**20:.1f} MiB budget — shrink "
                    "max_batch/max_len or use kv_cache='int8'")
    return errs


def check_cnn_serve_config(scfg) -> List[str]:
    """Violations of a :class:`~repro.serve.cnn.CNNServeConfig`."""
    errs: List[str] = []
    if not isinstance(scfg.max_batch, int) or scfg.max_batch < 1:
        errs.append(f"max_batch must be a positive int, got "
                    f"{scfg.max_batch!r}")
    _check_resilience(scfg, errs)
    return errs
