"""Abstract interpretation over the graph IR: shape, dtype, and fusion
legality of a lowered Plan — verified without executing anything.

The interpreter walks the plan's nodes carrying an abstract activation
state (spatial extent, channel count, int8 frac bits, int8-vs-float
regime) and checks every transition:

* **scale chaining** — each consumer must read its input at the producer's
  annotated frac bits (``node.in_fb == state.frac_bits``); a mismatch means
  the fused requantization epilogues would silently rescale.
* **shape/grid coverage** — conv input channels match the spec, groups
  divide the channels, the recorded ``in_hw`` attrs agree with the
  propagated extents, pooling windows fit the map.
* **dtype flow** — activations stay int8 from the first conv to the global
  average pool (the fused-plan contract); quantized weight leaves must be
  int8 arrays (packed W4 included); nothing quantized may run after the
  int8 -> float ``gap`` boundary.
* **fusion legality** — only requant/ReLU/pool chains the kernels can fuse:
  ``act`` is ``None``/``"relu"``, ``qbn`` only follows the unfoldable
  add-conv, ``maxpool`` runs at an unchanged scale (max only commutes with
  a positive pow2 scale), the dense head is terminal.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.graph.lower import PLAN_OPS

_FB_RANGE = (-24, 31)           # sane int8 frac-bit annotations


@dataclasses.dataclass
class Diagnostic:
    node: str
    level: str                   # "error" | "warning"
    message: str


@dataclasses.dataclass
class _State:
    """Abstract activation flowing through the plan."""

    regime: str = "int8"         # "int8" until gap, then "float"
    frac_bits: Optional[int] = None
    hw: Optional[Tuple[int, int]] = None
    channels: Optional[int] = None


def _err(diags, node, msg):
    diags.append(Diagnostic(node.name, "error", f"{node.name}: {msg}"))


def _warn(diags, node, msg):
    diags.append(Diagnostic(node.name, "warning", f"{node.name}: {msg}"))


def _check_fb(diags, node, fb, what):
    if fb is None or not isinstance(fb, (int, np.integer)):
        _err(diags, node, f"{what} frac bits must be a static int, got "
             f"{fb!r}")
        return False
    if not (_FB_RANGE[0] <= fb <= _FB_RANGE[1]):
        _err(diags, node, f"{what} frac bits {fb} outside the sane range "
             f"{_FB_RANGE}")
        return False
    return True


def _check_weight_dtypes(diags, node):
    """Quantized leaves must hold int8 codes (packed W4 bytes are int8)."""
    from repro.core.quantize import QTensor, QTensorW4
    for key, v in (node.qparams or {}).items():
        if isinstance(v, (QTensor, QTensorW4)):
            if str(v.q.dtype) != "int8":
                _err(diags, node, f"quantized leaf {key!r} holds "
                     f"{v.q.dtype}, expected int8 codes")
        if isinstance(v, QTensorW4) and str(v.shifts.dtype) != "int8":
            _err(diags, node, f"W4 leaf {key!r} shift sideband holds "
                 f"{v.shifts.dtype}, expected int8")


def _chain(diags, node, st: _State):
    """Scale-chain check shared by every int8-consuming op."""
    if st.regime != "int8":
        _err(diags, node, f"{node.op} consumes an int8 activation but the "
             "abstract state is already float (op after gap?)")
        return
    if (st.frac_bits is not None and node.in_fb is not None
            and node.in_fb != st.frac_bits):
        _err(diags, node, f"scale chain broken: reads input at in_fb="
             f"{node.in_fb} but the producer wrote frac_bits="
             f"{st.frac_bits}")


def check_plan(plan) -> List[Diagnostic]:
    """Run the abstract interpreter over ``plan``; returns diagnostics
    (errors + warnings). An empty error set means the plan's dataflow is
    statically legal; ``repro.check.validate_plan`` raises on errors."""
    diags: List[Diagnostic] = []
    st = _State(frac_bits=plan.in_fb)
    seen_gap = False
    prev = None

    for node in plan.nodes:
        if node.op not in PLAN_OPS:
            diags.append(Diagnostic(node.name, "error",
                                    f"{node.name}: unknown plan op "
                                    f"{node.op!r}"))
            continue

        if node.op == "qconv":
            spec = node.spec
            _chain(diags, node, st)
            _check_fb(diags, node, node.in_fb, "input")
            _check_fb(diags, node, node.out_fb, "output")
            _check_weight_dtypes(diags, node)
            if node.act not in (None, "relu"):
                _err(diags, node, f"unfusable activation {node.act!r}; the "
                     "kernel epilogues implement only None/'relu'")
            if spec is None:
                _err(diags, node, "qconv node without a ConvSpec")
                continue
            if spec.groups < 1 or spec.in_channels % max(spec.groups, 1):
                _err(diags, node, f"groups={spec.groups} does not divide "
                     f"in_channels={spec.in_channels}")
            if st.channels is not None and st.channels != spec.in_channels:
                _err(diags, node, f"channel mismatch: consumes "
                     f"{spec.in_channels} channels but the producer "
                     f"yields {st.channels}")
            hw = node.attrs.get("in_hw")
            if hw is not None and st.hw is not None and tuple(hw) != st.hw:
                _err(diags, node, f"recorded in_hw={tuple(hw)} disagrees "
                     f"with the propagated extent {st.hw}")
            if hw is not None:
                st.hw = tuple(hw)
            if st.hw is not None and spec.kernel_size > min(st.hw):
                _warn(diags, node, f"kernel {spec.kernel_size} larger than "
                      f"the {st.hw} map (SAME padding dominates the tile)")
            if st.hw is not None and spec.stride != 1:
                h, w = st.hw
                st.hw = ((h - 1) // spec.stride + 1,
                         (w - 1) // spec.stride + 1)
            st.channels = spec.out_channels
            st.frac_bits = node.out_fb

        elif node.op == "qbn":
            _chain(diags, node, st)
            _check_fb(diags, node, node.in_fb, "input")
            _check_fb(diags, node, node.out_fb, "output")
            if node.act not in (None, "relu"):
                _err(diags, node, f"unfusable activation {node.act!r}")
            qp = node.qparams or {}
            if not {"a", "b", "a_frac_bits"} <= set(qp):
                _err(diags, node, "qbn node missing integer-affine params "
                     "(a/b/a_frac_bits)")
            if prev is None or prev.op != "qconv" \
                    or prev.spec is None or prev.spec.primitive != "add":
                _err(diags, node, "qbn is the add-conv integer BN lowering; "
                     "it must directly follow an add-primitive qconv "
                     "(every other primitive BN-folds)")
            elif qp.get("a") is not None and st.channels is not None:
                n_ch = int(np.asarray(qp["a"]).shape[-1])
                if n_ch != st.channels:
                    _err(diags, node, f"qbn affine covers {n_ch} channels, "
                         f"producer yields {st.channels}")
            st.frac_bits = node.out_fb

        elif node.op == "maxpool":
            _chain(diags, node, st)
            if node.in_fb != node.out_fb:
                _err(diags, node, f"maxpool on int8 codes requires an "
                     f"unchanged scale (in_fb={node.in_fb} != out_fb="
                     f"{node.out_fb}); max only commutes with the "
                     "producer's own pow2 scale")
            win = node.attrs.get("window", 2)
            s = node.attrs.get("stride", 2)
            if win < 1 or s < 1:
                _err(diags, node, f"degenerate pooling window={win} "
                     f"stride={s}")
            hw = node.attrs.get("in_hw")
            if hw is not None and st.hw is not None and tuple(hw) != st.hw:
                _err(diags, node, f"recorded in_hw={tuple(hw)} disagrees "
                     f"with the propagated extent {st.hw}")
            if hw is not None:
                st.hw = tuple(hw)
            if st.hw is not None:
                h, w = st.hw
                if win > h or win > w:
                    _err(diags, node, f"pooling window {win} does not fit "
                         f"the {st.hw} map")
                else:
                    st.hw = ((h - win) // s + 1, (w - win) // s + 1)
            st.frac_bits = node.out_fb if node.out_fb is not None \
                else st.frac_bits

        elif node.op == "gap":
            _chain(diags, node, st)
            if seen_gap:
                _err(diags, node, "second gap node; the int8 -> float "
                     "boundary must be unique")
            seen_gap = True
            st.regime = "float"
            st.hw = None
            st.frac_bits = None

        elif node.op == "dense":
            if st.regime != "float":
                _err(diags, node, "dense head expects the float gap "
                     "output; no gap node precedes it")
            w = (node.qparams or {}).get("w")
            if w is None:
                _err(diags, node, "dense node without a weight")
            elif st.channels is not None \
                    and int(np.asarray(w).shape[0]) != st.channels:
                _err(diags, node, f"head weight rows "
                     f"{int(np.asarray(w).shape[0])} != gap features "
                     f"{st.channels}")
            if node is not plan.nodes[-1]:
                _err(diags, node, "dense head must be the terminal node")

        prev = node

    if plan.nodes and plan.nodes[-1].op == "dense" and not seen_gap:
        diags.append(Diagnostic(plan.nodes[-1].name, "warning",
                                "plan ends in dense without a gap boundary"))
    return diags
