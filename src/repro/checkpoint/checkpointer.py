"""Sharded, async, elastic checkpointing.

Layout per step:  <dir>/step_000123/
    manifest.json          pytree structure + leaf shapes/dtypes + step
    shard_<host>.npz       host-local leaf shards (addressable data only)
    _COMMITTED             atomic commit marker (written last)

Fault-tolerance properties:
  * atomic: readers only trust directories with the _COMMITTED marker, so a
    preemption mid-write never corrupts the latest checkpoint;
  * async: serialization happens on a background thread with the arrays
    already fetched to host, keeping the train loop running;
  * keep-N garbage collection;
  * ELASTIC restore: leaves are saved as full (replicated-equivalent) host
    arrays per shard and reassembled on load, so a checkpoint written on an
    N-device mesh restores onto any other mesh/device count (tested with
    fake devices) — the re-shard happens via device_put with the new
    sharding.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


_NATIVE_KINDS = set("biufc?")


def _to_serializable(v: np.ndarray) -> np.ndarray:
    if v.dtype.kind in _NATIVE_KINDS and v.dtype.name != "object":
        return v
    return v.view(np.uint8 if v.dtype.itemsize == 1 else
                  np.uint16 if v.dtype.itemsize == 2 else np.uint32)


def _from_serializable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if arr.dtype.name == dtype_name:
        return arr
    import ml_dtypes
    try:
        dt = np.dtype(dtype_name)
    except TypeError:
        dt = np.dtype(getattr(ml_dtypes, dtype_name))
    if arr.dtype.kind in "u" and dt.kind not in _NATIVE_KINDS - {"V"} \
            and dt.itemsize == arr.dtype.itemsize:
        return arr.view(dt)
    return arr.astype(dt)


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save --
    def save(self, step: int, tree: Any, *, block: bool = False):
        self.wait()                      # one in-flight save at a time
        keys, vals, _ = _flatten_with_paths(tree)
        # fetch to host synchronously (cheap vs serialization) so the caller
        # can donate/overwrite device buffers immediately afterwards
        host_vals = [np.asarray(jax.device_get(v)) for v in vals]

        def _write():
            path = os.path.join(self.dir, f"step_{step:09d}")
            tmp = path + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            manifest = {
                "step": step,
                "leaves": [{"key": k, "shape": list(v.shape),
                            "dtype": str(v.dtype)}
                           for k, v in zip(keys, host_vals)],
            }
            # npz cannot hold ml_dtypes (bf16, fp8): store a byte view; the
            # manifest dtype is authoritative on restore
            np.savez(os.path.join(tmp, "shard_0.npz"),
                     **{k: _to_serializable(v)
                        for k, v in zip(keys, host_vals)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
                f.write(str(time.time()))
            if os.path.exists(path):
                shutil.rmtree(path)
            os.rename(tmp, path)
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore --
    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and not name.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, name, "_COMMITTED")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, int]:
        """Restore into the structure of `tree_like`; reshard onto
        `shardings` (same-structure tree of Shardings) if given — this is
        the elastic path: the stored arrays are mesh-agnostic."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        data = np.load(os.path.join(path, "shard_0.npz"))
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        stored_dtypes = {l["key"]: l["dtype"] for l in manifest["leaves"]}
        keys, vals, treedef = _flatten_with_paths(tree_like)
        out_vals = []
        sh_flat = None
        if shardings is not None:
            _, sh_flat, _ = _flatten_with_paths(shardings)
        for i, k in enumerate(keys):
            arr = _from_serializable(data[k], stored_dtypes[k])
            want = vals[i]
            if hasattr(want, "dtype") and str(arr.dtype) != str(want.dtype):
                arr = arr.astype(want.dtype)
            if sh_flat is not None and sh_flat[i] is not None:
                out_vals.append(jax.device_put(arr, sh_flat[i]))
            else:
                out_vals.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out_vals), step
