from .checkpointer import Checkpointer
