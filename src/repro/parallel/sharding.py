"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP) for the mesh
axes ("pod", "data", "model").

Models annotate tensors with *logical* axis names; the active
:class:`ShardingRules` maps those to mesh axes. This is the single place
where the parallelism layout is decided, so hillclimbing a different
layout (§Perf) is a one-line rules change, not a model edit.

Conventions:
  batch    -> ("pod", "data")       pure DP (pod axis only carries DP/DCN)
  heads/ffn/vocab/experts -> "model"  TP / EP
  embed    -> "data" when fsdp=True   ZeRO-3 weight sharding (all-gather
              per scanned layer, reduce-scatter of grads — XLA-inserted)
  kv_seq   -> "data" for SP decode cells (sharded KV cache + online-softmax
              combine, see models/attention.py::decode_attention_sp)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
from jax.interpreters import pxla
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    batch: tuple | str | None = ("pod", "data")
    seq: tuple | str | None = None            # activations' seq dim (training)
    kv_seq: tuple | str | None = None         # KV-cache seq dim (SP decode)
    heads: tuple | str | None = "model"       # flattened hq*dh weight dim
    kv_heads: tuple | str | None = "model"    # flattened hkv*dh weight dim
    ffn: tuple | str | None = "model"
    ffn_expert: tuple | str | None = None     # expert hidden dim (2nd shard)
    vocab: tuple | str | None = "model"
    experts: tuple | str | None = "model"
    embed: tuple | str | None = None          # d_model dim of weights (FSDP)
    embed_table: tuple | str | None = None    # d_model dim of the embed table
    d_inner: tuple | str | None = "model"     # mamba inner dim
    layers: tuple | str | None = None         # stacked-layer dim
    d_model_act: tuple | str | None = None    # activations' feature dim

    def spec(self, *names: Optional[str]) -> P:
        entries = []
        for n in names:
            if n is None:
                entries.append(None)
            else:
                entries.append(getattr(self, n))
        return P(*entries)


# Default rule sets ---------------------------------------------------------

def rules_for(family: str, *, fsdp: bool = False, sp: bool = False) -> ShardingRules:
    kw = {}
    if fsdp:
        kw["embed"] = "data"
    if sp:
        kw["kv_seq"] = "data"
    return ShardingRules(**kw)


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def make_rules(mesh, cfg, kind: str, *, fsdp: bool = False,
               sp: bool = False, shard_residuals: bool = False) -> ShardingRules:
    """Mesh- and arch-aware rules. Every assignment is divisibility-checked
    so any (arch x shape x mesh) cell lowers; the key semantic choices:

    * attention TP only with WHOLE-head divisibility (n_heads % model == 0);
      sub-head sharding would psum O(S^2) score tensors. Archs with odd head
      counts (qwen2 14H, qwen1.5 40H, arctic 56H) run attention replicated
      across "model" (flash keeps memory bounded); FFN/experts stay TP/EP.
      The replicated-attention waste shows up in the roofline ratio and is
      hillclimb material (§Perf).
    * k/v head TP only when n_kv_heads % model == 0; GQA k/v are small, so
      replication is cheap.
    * FSDP ("embed" -> DP axes) combines with TP dims into 2-D weight
      sharding; optimizer state inherits it (ZeRO-3).
    * decode cells shard the KV-cache seq dim over "model" (and "data" too
      when the batch cannot use it) with online-softmax SP combine.

    kind: train | prefill | decode.
    """
    names = mesh.axis_names
    nm = mesh.shape.get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in names)

    def pick(dim: int, axis="model"):
        return axis if _div(dim, nm) else None

    kw = dict(
        heads="model" if _div(cfg.n_heads, nm) else None,
        kv_heads="model" if _div(cfg.n_kv_heads, nm) else None,
        ffn=pick(cfg.d_ff) if cfg.d_ff else None,
        vocab=pick(cfg.vocab),
        experts=pick(cfg.moe.num_experts) if cfg.moe else None,
        ffn_expert=None,
        d_inner=pick(cfg.mamba.expand * cfg.d_model) if cfg.mamba else None,
        batch=dp_axes if dp_axes else None,
    )
    if cfg.moe and kw["experts"] is None:
        kw["ffn_expert"] = pick(cfg.moe.d_ff)
    if fsdp:
        kw["embed"] = dp_axes or None
        kw["embed_table"] = dp_axes or None
    if sp and kind in ("decode", "prefill"):
        kw["kv_seq"] = "model"      # cache seq sharded; prefill writes it
        kw["kv_heads"] = None       # cache spec cannot use "model" twice
    if shard_residuals and _div(cfg.d_model, nm):
        # residual-stream activations (the per-layer scan checkpoints, the
        # dominant training-memory term at depth) shard d_model over
        # "model"; XLA re-gathers per layer — memory for collectives.
        kw["d_model_act"] = "model"
    return ShardingRules(**kw)


def prune_batch_axes(mesh, rules: ShardingRules, global_batch: int) -> ShardingRules:
    """Drop batch axes that do not divide the global batch."""
    axes = rules.batch
    if axes is None:
        return rules
    if isinstance(axes, str):
        axes = (axes,)
    kept = []
    size = 1
    for a in axes:
        if global_batch % (size * mesh.shape[a]) == 0:
            kept.append(a)
            size *= mesh.shape[a]
    return dataclasses.replace(rules, batch=tuple(kept) if kept else None)


_ACTIVE: list[ShardingRules] = [ShardingRules()]


def active_rules() -> ShardingRules:
    return _ACTIVE[-1]


class use_rules:
    def __init__(self, rules: ShardingRules):
        self.rules = rules

    def __enter__(self):
        _ACTIVE.append(self.rules)
        return self.rules

    def __exit__(self, *exc):
        _ACTIVE.pop()


def current_mesh() -> Optional[Mesh]:
    mesh = pxla.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def axis_size(name) -> int:
    mesh = current_mesh()
    if mesh is None:
        return 1
    if isinstance(name, (tuple, list)):
        size = 1
        for n in name:
            size *= mesh.shape.get(n, 1)
        return size
    return mesh.shape.get(name, 1)


def constrain(x, *names: Optional[str]):
    """with_sharding_constraint by logical names; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = active_rules().spec(*names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*names: Optional[str], mesh: Optional[Mesh] = None):
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, active_rules().spec(*names))


def tree_shardings(tree_of_name_tuples, mesh: Optional[Mesh] = None):
    """Map a pytree of logical-name tuples to NamedShardings."""
    mesh = mesh or current_mesh()
    rules = active_rules()
    return jax.tree_util.tree_map(
        lambda names: NamedSharding(mesh, rules.spec(*names)),
        tree_of_name_tuples, is_leaf=lambda v: isinstance(v, tuple) or v is None)
