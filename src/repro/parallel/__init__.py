from .sharding import (ShardingRules, active_rules, axis_size, constrain,
                       current_mesh, named_sharding, rules_for, tree_shardings,
                       use_rules)
