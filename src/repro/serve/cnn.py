"""CNN microbatch serving: queued image requests through one CompiledPlan.

The LM engine's admission idea, applied to the vision side: requests queue
up, and between *batch rounds* the scheduler admits up to ``max_batch``
queued images into the round's batch slots — the CNN analogue of refilling
decode slots between rounds. Each round runs ONE batched forward through
the plan's single jit (``CompiledPlan.forward_batch``), padded to a pow2
batch bucket so ragged rounds never retrace, and scatters the logits back
onto the originating requests.

A CNN request is one-shot (no decode loop), so the scheduler is simpler
than the LM slot machine — the throughput lever is purely the batched
kernel schedule: every admitted image shares the round's weight-block
loads (the Fig-3 reuse quantity scaled by ``block_n``), which is what
``benchmarks/throughput_bench.py`` measures against the N=1 loop.

Observability mirrors the LM engine (``repro.obs``): ``CNNEngine.stats``
is backed by a private metrics registry (same keys as before plus latency/
queue-wait quantiles), round timers ``jax.block_until_ready`` the batched
forward before stopping so ``images_per_s`` measures device time, request
timestamps are monotonic ``perf_counter`` values with one wall-clock field
for trace export, and with ``REPRO_TRACE=1`` each round and each request
lifecycle (queue_wait -> execute) lands on the process tracer.
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import List, Optional

import jax
import numpy as np

from repro.graph.executor import CompiledPlan
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class ImageRequest:
    """One classification request plus engine-filled result/metric fields."""
    uid: int
    image: np.ndarray               # (H, W, C) float
    logits: Optional[np.ndarray] = None
    done: bool = False
    # engine-filled metrics — monotonic perf_counter stamps (negative-proof
    # intervals); submit_wall_t is the wall-clock field for trace export
    submit_t: float = 0.0
    submit_wall_t: float = 0.0
    admit_t: float = 0.0            # perf_counter when its round started
    finish_t: float = 0.0
    batch_round: int = -1           # round the request was served in

    @property
    def latency_s(self) -> float:
        return max(self.finish_t - self.submit_t, 0.0)

    @property
    def queue_wait_s(self) -> float:
        return max(self.admit_t - self.submit_t, 0.0)


@dataclasses.dataclass
class CNNServeConfig:
    """max_batch: batch slots per round (forward_batch pads a ragged final
    round to its pow2 bucket, so partial rounds reuse a compiled shape)."""
    max_batch: int = 8


class CNNEngine:
    """Microbatching frontend over one :class:`CompiledPlan`."""

    def __init__(self, plan: CompiledPlan,
                 scfg: Optional[CNNServeConfig] = None):
        scfg = scfg or CNNServeConfig()
        from repro.check.config import check_cnn_serve_config
        bad = check_cnn_serve_config(scfg)
        if bad:
            raise ValueError("invalid CNNServeConfig:\n"
                             + "\n".join(f"  - {m}" for m in bad))
        self.plan = plan
        self.scfg = scfg
        self.queue: "queue.Queue[ImageRequest]" = queue.Queue()
        # private registry: per-engine stats isolation, in-place reset
        self.metrics = obs_metrics.Registry()
        self._m = {
            "batch_rounds": self.metrics.counter("serve.cnn.batch_rounds"),
            "images_done": self.metrics.counter("serve.cnn.images_done"),
            "batch_time": self.metrics.counter("serve.cnn.batch_time_s"),
            "latency": self.metrics.histogram("serve.cnn.latency_s"),
            "queue_wait": self.metrics.histogram("serve.cnn.queue_wait_s"),
        }
        self.reset_stats()

    # ------------------------------------------------------------- metrics --

    def reset_stats(self):
        self.metrics.reset()

    @property
    def stats(self) -> dict:
        """Counters + derived scheduler metrics (computed on access from the
        engine's registry); occupancy is served images over offered batch
        slots. Key-compatible with the pre-registry dict plus quantiles."""
        m = self._m
        rounds = int(m["batch_rounds"].value)
        c = dict(batch_rounds=rounds, images_done=int(m["images_done"].value))
        c["occupancy"] = (c["images_done"] / (rounds * self.scfg.max_batch)
                          if rounds else 0.0)
        c["latency_avg_s"] = m["latency"].mean
        batch_time = m["batch_time"].value
        c["images_per_s"] = (c["images_done"] / batch_time
                             if batch_time > 0 else 0.0)
        c["latency_p50_s"] = m["latency"].percentile(50)
        c["latency_p95_s"] = m["latency"].percentile(95)
        c["latency_p99_s"] = m["latency"].percentile(99)
        c["queue_wait_avg_s"] = m["queue_wait"].mean
        c["queue_wait_p99_s"] = m["queue_wait"].percentile(99)
        return c

    def _observe_served(self, req: ImageRequest):
        self._m["latency"].observe(req.latency_s)
        self._m["queue_wait"].observe(req.queue_wait_s)
        tr = obs_trace.TRACER
        if tr.enabled:
            lane = obs_trace.next_lane()
            tr.begin("image_request", ts=req.submit_t, tid=lane, uid=req.uid,
                     round=req.batch_round, submit_wall_t=req.submit_wall_t)
            tr.complete("queue_wait", req.submit_t, req.admit_t, tid=lane)
            tr.complete("execute", req.admit_t, req.finish_t, tid=lane)
            tr.end("image_request", ts=req.finish_t, tid=lane)

    # ----------------------------------------------------------- frontend --

    def submit(self, req: ImageRequest):
        req.submit_t = time.perf_counter()
        req.submit_wall_t = time.time()
        self.queue.put(req)

    def _take_round(self) -> List[ImageRequest]:
        # get_nowait, not .empty(): .empty() is only a racy hint once a
        # producer thread feeds the queue (same contract as the LM engine)
        out: List[ImageRequest] = []
        while len(out) < self.scfg.max_batch:
            try:
                out.append(self.queue.get_nowait())
            except queue.Empty:
                break
        return out

    def run_until_drained(self) -> List[ImageRequest]:
        """Admit queued requests into batch rounds until the queue is empty;
        returns the finished requests in completion order."""
        finished: List[ImageRequest] = []
        while True:
            batch = self._take_round()
            if not batch:
                break
            x = np.stack([r.image for r in batch])
            rnd = int(self._m["batch_rounds"].value)
            t0 = time.perf_counter()
            for r in batch:
                r.admit_t = t0
            with obs_trace.span("cnn.batch_round", round=rnd,
                                batch=len(batch)):
                logits = self.plan.forward_batch(x)
                # sync before stopping the timer: images_per_s must measure
                # device time, not JAX async-dispatch enqueue time
                jax.block_until_ready(logits)
            self._m["batch_time"].inc(time.perf_counter() - t0)
            logits = np.asarray(logits)
            now = time.perf_counter()
            for i, r in enumerate(batch):
                r.logits = logits[i]
                r.done = True
                r.finish_t = now
                r.batch_round = rnd
                self._observe_served(r)
            self._m["batch_rounds"].inc()
            self._m["images_done"].inc(len(batch))
            finished.extend(batch)
        return finished
