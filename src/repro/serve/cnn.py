"""CNN microbatch serving: queued image requests through one CompiledPlan.

The LM engine's admission idea, applied to the vision side: requests queue
up, and between *batch rounds* the scheduler admits up to ``max_batch``
queued images into the round's batch slots — the CNN analogue of refilling
decode slots between rounds. Each round runs ONE batched forward through
the plan's single jit (``CompiledPlan.forward_batch``), padded to a pow2
batch bucket so ragged rounds never retrace, and scatters the logits back
onto the originating requests.

A CNN request is one-shot (no decode loop), so the scheduler is simpler
than the LM slot machine — the throughput lever is purely the batched
kernel schedule: every admitted image shares the round's weight-block
loads (the Fig-3 reuse quantity scaled by ``block_n``), which is what
``benchmarks/throughput_bench.py`` measures against the N=1 loop.
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import List, Optional

import numpy as np

from repro.graph.executor import CompiledPlan


@dataclasses.dataclass
class ImageRequest:
    """One classification request plus engine-filled result/metric fields."""
    uid: int
    image: np.ndarray               # (H, W, C) float
    logits: Optional[np.ndarray] = None
    done: bool = False
    # engine-filled metrics
    submit_t: float = 0.0
    finish_t: float = 0.0
    batch_round: int = -1           # round the request was served in

    @property
    def latency_s(self) -> float:
        return max(self.finish_t - self.submit_t, 0.0)


@dataclasses.dataclass
class CNNServeConfig:
    """max_batch: batch slots per round (forward_batch pads a ragged final
    round to its pow2 bucket, so partial rounds reuse a compiled shape)."""
    max_batch: int = 8


class CNNEngine:
    """Microbatching frontend over one :class:`CompiledPlan`."""

    def __init__(self, plan: CompiledPlan,
                 scfg: Optional[CNNServeConfig] = None):
        scfg = scfg or CNNServeConfig()
        if scfg.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {scfg.max_batch}")
        self.plan = plan
        self.scfg = scfg
        self.queue: "queue.Queue[ImageRequest]" = queue.Queue()
        self.reset_stats()

    # ------------------------------------------------------------- metrics --

    def reset_stats(self):
        self._c = dict(batch_rounds=0, images_done=0)
        self._batch_time = 0.0
        self._lat: List[float] = []

    @property
    def stats(self) -> dict:
        """Counters + derived scheduler metrics (computed on access);
        occupancy is served images over offered batch slots."""
        c = dict(self._c)
        rounds = c["batch_rounds"]
        c["occupancy"] = (c["images_done"] / (rounds * self.scfg.max_batch)
                          if rounds else 0.0)
        c["latency_avg_s"] = float(np.mean(self._lat)) if self._lat else 0.0
        c["images_per_s"] = (c["images_done"] / self._batch_time
                             if self._batch_time > 0 else 0.0)
        return c

    # ----------------------------------------------------------- frontend --

    def submit(self, req: ImageRequest):
        req.submit_t = time.time()
        self.queue.put(req)

    def _take_round(self) -> List[ImageRequest]:
        # get_nowait, not .empty(): .empty() is only a racy hint once a
        # producer thread feeds the queue (same contract as the LM engine)
        out: List[ImageRequest] = []
        while len(out) < self.scfg.max_batch:
            try:
                out.append(self.queue.get_nowait())
            except queue.Empty:
                break
        return out

    def run_until_drained(self) -> List[ImageRequest]:
        """Admit queued requests into batch rounds until the queue is empty;
        returns the finished requests in completion order."""
        finished: List[ImageRequest] = []
        while True:
            batch = self._take_round()
            if not batch:
                break
            x = np.stack([r.image for r in batch])
            t0 = time.perf_counter()
            logits = np.asarray(self.plan.forward_batch(x))
            self._batch_time += time.perf_counter() - t0
            now = time.time()
            for i, r in enumerate(batch):
                r.logits = logits[i]
                r.done = True
                r.finish_t = now
                r.batch_round = self._c["batch_rounds"]
                self._lat.append(r.latency_s)
            self._c["batch_rounds"] += 1
            self._c["images_done"] += len(batch)
            finished.extend(batch)
        return finished
