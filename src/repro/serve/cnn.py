"""CNN microbatch serving: queued image requests through one CompiledPlan.

The LM engine's admission idea, applied to the vision side: requests queue
up, and between *batch rounds* the scheduler admits up to ``max_batch``
queued images into the round's batch slots — the CNN analogue of refilling
decode slots between rounds. Each round runs ONE batched forward through
the plan's single jit (``CompiledPlan.forward_batch``), padded to a pow2
batch bucket so ragged rounds never retrace, and scatters the logits back
onto the originating requests.

A CNN request is one-shot (no decode loop), so the scheduler is simpler
than the LM slot machine — the throughput lever is purely the batched
kernel schedule: every admitted image shares the round's weight-block
loads (the Fig-3 reuse quantity scaled by ``block_n``), which is what
``benchmarks/throughput_bench.py`` measures against the N=1 loop.

Observability mirrors the LM engine (``repro.obs``): ``CNNEngine.stats``
is backed by a private metrics registry (same keys as before plus latency/
queue-wait quantiles), round timers ``jax.block_until_ready`` the batched
forward before stopping so ``images_per_s`` measures device time, request
timestamps are monotonic ``perf_counter`` values with one wall-clock field
for trace export, and with ``REPRO_TRACE=1`` each round and each request
lifecycle (queue_wait -> execute) lands on the process tracer.

Failure model (EXPERIMENTS.md §Resilience): mirrors the LM engine — every
request ends in a terminal ``status`` (ok | timeout | error | shed). The
``cnn.batch_round`` fault seam fires once per round attempt; an injected
raise is absorbed by ``max_retries`` bounded retries, then by a ONE-SHOT
whole-plan degradation to the xla reference path
(:meth:`CompiledPlan.degrade_to_xla` — logged once, counted in obs
metrics) before the round's batch retires with ``status="error"``. A
``corrupt`` fault poisons the round's host logits; the affected uids are
recorded in ``CNNEngine.poisoned_uids`` (contained, not detected).
Deadlines cancel at round admission; a full queue sheds at ``submit``
(``CNNServeConfig(max_queue=, shed_policy=)``).
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import List, Optional

import jax
import numpy as np

from repro.faults import inject as faults
from repro.graph.executor import CompiledPlan
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.engine import QueueFullError


@dataclasses.dataclass
class ImageRequest:
    """One classification request plus engine-filled result/metric fields."""
    uid: int
    image: np.ndarray               # (H, W, C) float
    logits: Optional[np.ndarray] = None
    done: bool = False
    status: str = "pending"         # terminal: ok | timeout | error | shed
    error: Optional[str] = None     # the absorbed exception, status="error"
    deadline_s: Optional[float] = None  # overrides CNNServeConfig.deadline_s
    # engine-filled metrics — monotonic perf_counter stamps (negative-proof
    # intervals); submit_wall_t is the wall-clock field for trace export
    submit_t: float = 0.0
    submit_wall_t: float = 0.0
    admit_t: float = 0.0            # perf_counter when its round started
    finish_t: float = 0.0
    batch_round: int = -1           # round the request was served in

    @property
    def latency_s(self) -> float:
        return max(self.finish_t - self.submit_t, 0.0)

    @property
    def queue_wait_s(self) -> float:
        return max(self.admit_t - self.submit_t, 0.0)


@dataclasses.dataclass
class CNNServeConfig:
    """max_batch: batch slots per round (forward_batch pads a ragged final
    round to its pow2 bucket, so partial rounds reuse a compiled shape).
    deadline_s / max_queue / shed_policy / max_retries / retry_backoff_s
    carry the same failure-model semantics as :class:`ServeConfig`
    (deadlines checked at round admission; "reject" raises
    :class:`QueueFullError`, "drop" marks ``status="shed"``)."""
    max_batch: int = 8
    deadline_s: Optional[float] = None
    max_queue: Optional[int] = None
    shed_policy: str = "reject"
    max_retries: int = 2
    retry_backoff_s: float = 0.0


class CNNEngine:
    """Microbatching frontend over one :class:`CompiledPlan`."""

    def __init__(self, plan: CompiledPlan,
                 scfg: Optional[CNNServeConfig] = None):
        scfg = scfg or CNNServeConfig()
        from repro.check.config import check_cnn_serve_config
        bad = check_cnn_serve_config(scfg)
        if bad:
            raise ValueError("invalid CNNServeConfig:\n"
                             + "\n".join(f"  - {m}" for m in bad))
        self.plan = plan
        self.scfg = scfg
        self.queue: "queue.Queue[ImageRequest]" = queue.Queue()
        # private registry: per-engine stats isolation, in-place reset
        self.metrics = obs_metrics.Registry()
        self._m = {
            "batch_rounds": self.metrics.counter("serve.cnn.batch_rounds"),
            "images_done": self.metrics.counter("serve.cnn.images_done"),
            "batch_time": self.metrics.counter("serve.cnn.batch_time_s"),
            "latency": self.metrics.histogram("serve.cnn.latency_s"),
            "queue_wait": self.metrics.histogram("serve.cnn.queue_wait_s"),
            # resilience counters (EXPERIMENTS.md §Resilience)
            "timeouts": self.metrics.counter("serve.cnn.timeouts"),
            "errors": self.metrics.counter("serve.cnn.errors"),
            "shed": self.metrics.counter("serve.cnn.shed"),
            "retries": self.metrics.counter("serve.cnn.retries"),
            "degraded": self.metrics.counter("serve.cnn.degraded"),
        }
        self.reset_stats()

    # ------------------------------------------------------------- metrics --

    def reset_stats(self):
        self.metrics.reset()
        # uids whose logits an injected "corrupt" fault poisoned (contained,
        # not detected — the chaos harness excludes them from bit-identity)
        self.poisoned_uids: set = set()

    @property
    def stats(self) -> dict:
        """Counters + derived scheduler metrics (computed on access from the
        engine's registry); occupancy is served images over offered batch
        slots. Key-compatible with the pre-registry dict plus quantiles."""
        m = self._m
        rounds = int(m["batch_rounds"].value)
        c = dict(batch_rounds=rounds, images_done=int(m["images_done"].value))
        c["occupancy"] = (c["images_done"] / (rounds * self.scfg.max_batch)
                          if rounds else 0.0)
        c["latency_avg_s"] = m["latency"].mean
        batch_time = m["batch_time"].value
        c["images_per_s"] = (c["images_done"] / batch_time
                             if batch_time > 0 else 0.0)
        c["latency_p50_s"] = m["latency"].percentile(50)
        c["latency_p95_s"] = m["latency"].percentile(95)
        c["latency_p99_s"] = m["latency"].percentile(99)
        c["queue_wait_avg_s"] = m["queue_wait"].mean
        c["queue_wait_p99_s"] = m["queue_wait"].percentile(99)
        c["timeouts"] = int(m["timeouts"].value)
        c["errors"] = int(m["errors"].value)
        c["shed"] = int(m["shed"].value)
        c["retries"] = int(m["retries"].value)
        c["degraded"] = int(m["degraded"].value)
        return c

    def _observe_served(self, req: ImageRequest):
        self._m["latency"].observe(req.latency_s)
        self._m["queue_wait"].observe(req.queue_wait_s)
        tr = obs_trace.TRACER
        if tr.enabled:
            lane = obs_trace.next_lane()
            tr.begin("image_request", ts=req.submit_t, tid=lane, uid=req.uid,
                     round=req.batch_round, submit_wall_t=req.submit_wall_t)
            tr.complete("queue_wait", req.submit_t, req.admit_t, tid=lane)
            tr.complete("execute", req.admit_t, req.finish_t, tid=lane)
            tr.end("image_request", ts=req.finish_t, tid=lane)

    # ----------------------------------------------------------- frontend --

    def submit(self, req: ImageRequest):
        req.submit_t = time.perf_counter()
        req.submit_wall_t = time.time()
        # load shedding at the door (single-threaded, so qsize is exact)
        mq = self.scfg.max_queue
        if mq is not None and self.queue.qsize() >= mq:
            self._m["shed"].inc()
            if self.scfg.shed_policy == "reject":
                raise QueueFullError(
                    f"image request {req.uid}: queue holds max_queue={mq} "
                    f"requests (shed_policy='reject')")
            req.done = True             # "drop": terminal without enqueue
            req.status = "shed"
            req.finish_t = time.perf_counter()
            return
        self.queue.put(req)

    def _expired(self, req: ImageRequest, now: float) -> bool:
        d = (req.deadline_s if req.deadline_s is not None
             else self.scfg.deadline_s)
        return d is not None and (now - req.submit_t) > d

    def _take_round(self) -> List[ImageRequest]:
        # get_nowait, not .empty(): .empty() is only a racy hint once a
        # producer thread feeds the queue (same contract as the LM engine)
        out: List[ImageRequest] = []
        while len(out) < self.scfg.max_batch:
            try:
                out.append(self.queue.get_nowait())
            except queue.Empty:
                break
        return out

    def run_until_drained(self) -> List[ImageRequest]:
        """Admit queued requests into batch rounds until the queue is empty;
        returns the finished requests in completion order (every one with a
        terminal status — a failed round retires its batch, it never kills
        the drain)."""
        finished: List[ImageRequest] = []
        while True:
            batch = self._take_round()
            if not batch:
                break
            # deadline check at round admission: an expired request never
            # gets a forward spent on it
            now = time.perf_counter()
            live: List[ImageRequest] = []
            for r in batch:
                if self._expired(r, now):
                    r.done = True
                    r.status = "timeout"
                    if r.admit_t == 0.0:
                        r.admit_t = now
                    r.finish_t = now
                    self._m["timeouts"].inc()
                    finished.append(r)
                else:
                    live.append(r)
            if not live:
                continue
            batch = live
            x = np.stack([r.image for r in batch])
            rnd = int(self._m["batch_rounds"].value)
            t0 = time.perf_counter()
            for r in batch:
                r.admit_t = t0

            def attempt_round():
                fired = faults.check("cnn.batch_round")
                with obs_trace.span("cnn.batch_round", round=rnd,
                                    batch=len(batch)):
                    logits = self.plan.forward_batch(x)
                    # sync before stopping the timer: images_per_s must
                    # measure device time, not async-dispatch enqueue time
                    jax.block_until_ready(logits)
                return np.asarray(logits), fired

            got = None
            last_err: Optional[BaseException] = None
            for att in range(self.scfg.max_retries + 1):
                if att:
                    self._m["retries"].inc()
                    if self.scfg.retry_backoff_s > 0:
                        time.sleep(self.scfg.retry_backoff_s
                                   * (2 ** (att - 1)))
                try:
                    got = attempt_round()
                    break
                except faults.InjectedFault as e:
                    last_err = e        # fired pre-dispatch: retry is safe
                except Exception as e:
                    last_err = e        # real plan failure: stop retrying,
                    break               # fall through to degradation
            if got is None and not self.plan.degraded:
                # one-shot graceful degradation: recompile the whole plan
                # on the xla reference path (logged + counted inside
                # degrade_to_xla) and give the round one more attempt
                self.plan.degrade_to_xla()
                self._m["degraded"].inc()
                try:
                    got = attempt_round()
                except Exception as e:
                    last_err = e
            if got is None:
                for r in batch:         # one shared forward — the whole
                    r.done = True       # round retires together
                    r.status = "error"
                    r.error = repr(last_err)
                    r.finish_t = time.perf_counter()
                    self._m["errors"].inc()
                finished.extend(batch)
                continue
            self._m["batch_time"].inc(time.perf_counter() - t0)
            logits, fired = got
            if fired is not None:       # corrupt directive: poison the
                logits = fired.apply(logits)   # round's host logits
                self.poisoned_uids.update(r.uid for r in batch)
            now = time.perf_counter()
            for i, r in enumerate(batch):
                r.logits = logits[i]
                r.done = True
                r.status = "ok"
                r.finish_t = now
                r.batch_round = rnd
                self._observe_served(r)
            self._m["batch_rounds"].inc()
            self._m["images_done"].inc(len(batch))
            finished.extend(batch)
        return finished
