"""Continuous-batching serve engine over a fixed (max_batch, max_len) budget.

Two schedulers share one ``Engine`` API; ``ServeConfig.scheduler`` picks:

* ``"continuous"`` (default) — a slot-based scheduler. Each admitted request
  is prefilled on its own (right-padded to a power-of-two length bucket so
  jit recompiles stay O(log max_len)), and its KV cache + position are
  surgically written into a free slot of the ONE live batched cache
  (``models/api.cache_write_slot``). Decode then advances every occupied
  slot one token per round with per-slot cache lengths (``cache["len"]`` is
  a (max_batch,) vector; each row writes/attends at its own position). A
  sequence retires the round it finishes — per-request EOS, per-request
  ``max_new_tokens``, or the ``max_len`` KV cap — and its freed slot is
  refilled from the queue *between decode rounds*, so the batch stays full
  under skewed output lengths instead of draining to the slowest member.
* ``"static"`` — the legacy drain strategy: pack up to ``max_batch``
  requests, left-pad prompts to a common length (unmasked, the historical
  approximation), prefill once, and decode the whole batch to completion
  before admitting more. Kept as the baseline that
  ``benchmarks/serve_bench.py`` measures continuous scheduling against.

Sampling is greedy argmax by default; a positive temperature (per
``ServeConfig`` with ``greedy=False``, or per-``Request`` override) switches
that request to softmax sampling with the engine's seeded host rng.

``Engine.stats`` surfaces scheduler metrics: prefill/decode-round/token
counters, slot occupancy (occupied slot-rounds over offered slot-rounds),
TTFT/TPOT/queue-wait latency quantiles, and decode throughput. The stats
are backed by a private ``repro.obs.metrics.Registry`` per engine (same
keys as the pre-registry dict, plus the histogram quantiles), and with
``REPRO_TRACE=1`` the engine emits per-request lifecycle spans
(queue_wait -> prefill -> generate, each request on its own trace lane)
plus per-round decode spans to the process tracer — export with
``repro.obs.trace.export(path)`` and open in Perfetto.

Timing discipline: decode-round timers ``jax.block_until_ready`` the round
outputs before stopping, so ``decode_tok_s`` measures real device time and
not JAX async-dispatch enqueue time; request timestamps are monotonic
``time.perf_counter()`` values (intervals can't go negative under clock
adjustment) with one wall-clock field (``submit_wall_t``) kept for trace
export.
"""
from __future__ import annotations

import dataclasses
import functools
import queue
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class Request:
    """One generation request plus the engine-filled result/metric fields."""
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None    # overrides ServeConfig.eos_id when set
    temperature: Optional[float] = None  # overrides the engine default
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    # engine-filled metrics — monotonic time.perf_counter() stamps, so the
    # derived intervals (ttft, queue wait, tpot) can never go negative under
    # wall-clock adjustment; submit_wall_t is the one wall-clock field kept
    # so trace export can recover absolute times
    submit_t: float = 0.0           # perf_counter at Engine.submit
    submit_wall_t: float = 0.0      # wall clock at Engine.submit
    admit_t: float = 0.0            # perf_counter at slot admission
    first_token_t: float = 0.0      # perf_counter when the prefill token landed
    finish_t: float = 0.0
    admit_round: int = -1           # global decode-round counter at admission
    finish_round: int = -1          # round the request retired on

    @property
    def ttft_s(self) -> float:
        return max(self.first_token_t - self.submit_t, 0.0)

    @property
    def queue_wait_s(self) -> float:
        return max(self.admit_t - self.submit_t, 0.0)


@dataclasses.dataclass
class ServeConfig:
    """Engine knobs.

    max_batch:  number of decode slots — the batch dim of the KV budget.
    max_len:    per-slot KV capacity. prompt length + generated tokens are
                capped here; a sequence that fills its slot is retired even
                if it has not reached ``max_new_tokens`` / EOS.
    eos_id:     stop-token id. ``-1`` is the "never" sentinel — no token id
                can equal it, so only ``max_new_tokens`` or the ``max_len``
                cap retire a sequence. ``Request.eos_id`` overrides per
                request (including overriding a real id back to -1).
    greedy:     True -> argmax decoding (ignores ``temperature``).
    temperature: softmax temperature used when ``greedy=False`` (or when a
                request carries its own ``temperature`` override). <= 0
                degrades to argmax.
    scheduler:  "continuous" (slot refill between decode rounds) or
                "static" (legacy drain batches).
    prefill_bucket: floor of the power-of-two right-padding buckets used by
                continuous prefill for attention families. ssm/hybrid
                recurrences are position-exact, so those families always
                prefill at the exact prompt length (one compile per
                distinct length).
    attn_impl:  prefill attention implementation ("flash" | "full" | ...).
    seed:       host rng seed for temperature sampling.
    precision:  "float" (default) serves as-is. "int8" PTQ-quantizes every
                layer's FFN weights at engine init (power-of-two scales,
                paper Eq. 4) and runs those matmuls int8 x int8 -> int32
                through the Pallas ``matmul_q8`` kernel with its fused
                Algorithm-1 shift-requantized epilogue; "int8-xla" is the
                same arithmetic on the jnp integer oracle (bit-exact with
                "int8" — the direct / no-SIMD baseline). "w4a8" additionally
                nibble-packs the FFN weights (4-bit codes + per-group shift
                scales, ``quantize_w4``) and the matmul unpacks them
                in-register — half the weight bytes per decode step at the
                same int8 activation path. Attention-family dense-MLP
                configs only (no moe / ssm / hybrid / encdec).
    kv_cache:   "float" (default) keeps the resident KV cache in the model
                compute dtype. "int8" stores K/V as int8 codes with
                per-(position, head) f32 scales — ~halved KV bytes;
                quantize-on-write, dequantize-on-read, per-token scales so
                slot refill/retire never re-scales a neighbour. Continuous
                scheduler + attention-family dense caches only (the static
                path decodes straight off the float prefill cache).
    """
    max_batch: int = 4
    max_len: int = 256
    eos_id: int = -1                # -1: never
    greedy: bool = True
    temperature: float = 0.0
    scheduler: str = "continuous"
    prefill_bucket: int = 16
    attn_impl: str = "flash"
    seed: int = 0
    precision: str = "float"
    kv_cache: str = "float"


class Engine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig):
        if scfg.scheduler not in ("continuous", "static"):
            raise ValueError(f"unknown scheduler: {scfg.scheduler!r}")
        if cfg.family == "encdec" and scfg.scheduler == "continuous":
            raise NotImplementedError(
                "continuous batching needs slotted caches; encdec is not "
                "slotted (models/api.slot_batch_axes) — use scheduler='static'")
        if scfg.precision not in ("float", "int8", "int8-xla", "w4a8"):
            raise ValueError(f"unknown precision: {scfg.precision!r}")
        if scfg.kv_cache not in ("float", "int8"):
            raise ValueError(f"unknown kv_cache: {scfg.kv_cache!r}")
        if scfg.kv_cache == "int8":
            if scfg.scheduler != "continuous":
                raise NotImplementedError(
                    "kv_cache='int8' quantizes the resident slot cache; the "
                    "static scheduler decodes off the float prefill cache — "
                    "use scheduler='continuous'")
            if cfg.family in ("ssm", "hybrid", "encdec"):
                raise NotImplementedError(
                    "kv_cache='int8' covers attention-family dense KV caches "
                    "only (no ssm / hybrid / encdec)")
        if scfg.precision != "float":
            if cfg.family in ("ssm", "hybrid", "encdec") or cfg.moe is not None:
                raise NotImplementedError(
                    "ServeConfig.precision='int8' quantizes dense FFN "
                    "matmuls; moe/ssm/hybrid/encdec configs are unsupported")
        # constructor-grade static checks beyond the enum combos above:
        # positive batch/length/bucket knobs, non-negative temperature
        # (repro.check.config; scripts/check_plan.py runs the strict set)
        from repro.check.config import check_serve_config
        bad = check_serve_config(scfg, cfg, strict=False)
        if bad:
            raise ValueError("invalid ServeConfig:\n"
                             + "\n".join(f"  - {m}" for m in bad))
        if scfg.precision != "float":
            # PTQ the FFN stack once; the quantized tree rides along in
            # params["layers"] so the layer scan slices it like any weight.
            # w4a8: same tree, but nibble-packed QTensorW4 leaves
            from repro.models.blocks import quantize_mlp_params
            layers = dict(params["layers"])
            layers["qmlp"] = quantize_mlp_params(
                layers["mlp"], bits=4 if scfg.precision == "w4a8" else 8)
            params = dict(params, layers=layers)
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self.prefill = jax.jit(
            api.prefill_fn(cfg, scfg.max_len, attn_impl=scfg.attn_impl,
                           precision=scfg.precision))
        # donate the live cache so slot writes / decode rounds update it in
        # place instead of copying the whole KV budget (CPU backends don't
        # implement donation and would warn on every compile, so skip there)
        cpu = jax.default_backend() == "cpu"
        self.decode = jax.jit(api.decode_fn(cfg, precision=scfg.precision),
                              donate_argnums=() if cpu else (2,))
        if cfg.family != "encdec":
            self._write_slot = jax.jit(
                functools.partial(api.cache_write_slot, cfg),
                donate_argnums=() if cpu else (0,))
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self._rng = np.random.default_rng(scfg.seed)
        # private registry: per-engine stats isolation; handles stay valid
        # across reset_stats (Registry.reset zeroes in place)
        self.metrics = obs_metrics.Registry()
        self._m = {
            "prefills": self.metrics.counter("serve.prefills"),
            "decode_steps": self.metrics.counter("serve.decode_steps"),
            "tokens_out": self.metrics.counter("serve.tokens_out"),
            "requests_done": self.metrics.counter("serve.requests_done"),
            "occupied": self.metrics.counter("serve.occupied_slot_rounds"),
            "decode_time": self.metrics.counter("serve.decode_time_s"),
            "ttft": self.metrics.histogram("serve.ttft_s"),
            "tpot": self.metrics.histogram("serve.tpot_s"),
            "queue_wait": self.metrics.histogram("serve.queue_wait_s"),
        }
        self.reset_stats()

    # ------------------------------------------------------------- metrics --

    def reset_stats(self):
        """Zero the counters (e.g. after a compile-warmup drain)."""
        self.metrics.reset()
        self._round = 0

    @property
    def stats(self) -> dict:
        """Counters + derived scheduler metrics (computed on access from the
        engine's registry). Key-compatible with the pre-registry dict
        (prefills/decode_steps/tokens_out/requests_done/occupancy/
        ttft_avg_s/decode_tok_s) plus the histogram quantiles."""
        m = self._m
        rounds = int(m["decode_steps"].value)
        c = dict(prefills=int(m["prefills"].value),
                 decode_steps=rounds,
                 tokens_out=int(m["tokens_out"].value),
                 requests_done=int(m["requests_done"].value))
        c["occupancy"] = (m["occupied"].value
                          / (rounds * self.scfg.max_batch)) if rounds else 0.0
        c["ttft_avg_s"] = m["ttft"].mean
        decode_time = m["decode_time"].value
        c["decode_tok_s"] = (c["tokens_out"] / decode_time
                             if decode_time > 0 else 0.0)
        c["ttft_p50_s"] = m["ttft"].percentile(50)
        c["ttft_p95_s"] = m["ttft"].percentile(95)
        c["ttft_p99_s"] = m["ttft"].percentile(99)
        c["tpot_avg_s"] = m["tpot"].mean
        c["queue_wait_avg_s"] = m["queue_wait"].mean
        c["queue_wait_p99_s"] = m["queue_wait"].percentile(99)
        return c

    def _observe_retired(self, req: Request):
        """Latency histograms + the request's trace-lane replay (the spans
        are emitted at retirement from recorded perf_counter stamps, so
        overlapping requests land on separate, properly nested lanes)."""
        self._m["queue_wait"].observe(req.queue_wait_s)
        n_out = len(req.out_tokens)
        if n_out > 1 and req.finish_t > req.first_token_t:
            self._m["tpot"].observe(
                (req.finish_t - req.first_token_t) / (n_out - 1))
        tr = obs_trace.TRACER
        if tr.enabled:
            lane = obs_trace.next_lane()
            tr.begin("request", ts=req.submit_t, tid=lane, uid=req.uid,
                     prompt_len=int(len(req.prompt)), new_tokens=n_out,
                     submit_wall_t=req.submit_wall_t)
            tr.complete("queue_wait", req.submit_t, req.admit_t, tid=lane)
            tr.complete("prefill", req.admit_t, req.first_token_t, tid=lane)
            tr.complete("generate", req.first_token_t, req.finish_t, tid=lane,
                        tokens=n_out)
            tr.end("request", ts=req.finish_t, tid=lane)

    # ----------------------------------------------------------- frontend --

    def submit(self, req: Request):
        # reject oversized prompts here, not mid-drain: raising during
        # run_until_drained would discard finished requests and strand the
        # rest of the queue
        if len(req.prompt) > self.scfg.max_len:
            raise ValueError(
                f"request {req.uid}: prompt length {len(req.prompt)} exceeds "
                f"max_len={self.scfg.max_len}")
        req.submit_t = time.perf_counter()
        req.submit_wall_t = time.time()
        self.queue.put(req)

    def _next_request(self) -> Optional[Request]:
        try:
            return self.queue.get_nowait()
        except queue.Empty:
            return None

    def _take_batch(self) -> List[Request]:
        # get_nowait, not .empty(): .empty() is only a racy hint once a
        # producer thread (or future async frontend) feeds the queue
        out: List[Request] = []
        while len(out) < self.scfg.max_batch:
            req = self._next_request()
            if req is None:
                break
            out.append(req)
        return out

    def run_until_drained(self) -> List[Request]:
        with obs_trace.span("engine.drain", scheduler=self.scfg.scheduler):
            if self.scfg.scheduler == "static":
                return self._run_static()
            return self._run_continuous()

    # ----------------------------------------------------------- sampling --

    def _pick(self, logits_row: np.ndarray, req: Request) -> int:
        temp = req.temperature
        if temp is None:
            temp = 0.0 if self.scfg.greedy else self.scfg.temperature
        if temp <= 0.0:
            return int(np.argmax(logits_row))
        z = np.asarray(logits_row, np.float64) / temp
        z -= z.max()
        p = np.exp(z)
        return int(self._rng.choice(p.size, p=p / p.sum()))

    def _effective_eos(self, req: Request) -> int:
        return self.scfg.eos_id if req.eos_id is None else req.eos_id

    # --------------------------------------------------------- continuous --

    def _bucket_len(self, plen: int) -> int:
        if plen > self.scfg.max_len:
            raise ValueError(
                f"prompt length {plen} exceeds max_len={self.scfg.max_len}")
        if self.cfg.family in ("ssm", "hybrid"):
            return plen                 # recurrent state is position-exact
        b = max(self.scfg.prefill_bucket, 1)
        while b < plen:
            b *= 2
        return min(b, self.scfg.max_len)

    def _run_continuous(self) -> List[Request]:
        B = self.scfg.max_batch
        cache = api.init_slot_cache(self.cfg, B, self.scfg.max_len,
                                    kv=self.scfg.kv_cache)
        slots: List[Optional[Request]] = [None] * B
        lens = [0] * B                  # host mirror of cache["len"]
        cur = np.zeros((B, 1), np.int32)
        finished: List[Request] = []

        def admit(i: int, req: Request):
            nonlocal cache
            plen = len(req.prompt)
            bucket = self._bucket_len(plen)
            req.admit_t = time.perf_counter()
            toks = np.zeros((bucket,), np.int32)
            toks[:plen] = req.prompt    # right-pad: positions stay 0..plen-1
            with obs_trace.span("engine.prefill", uid=req.uid, slot=i,
                                plen=plen, bucket=bucket):
                logits, fresh = self.prefill(self.params, {
                    "tokens": jnp.asarray(toks[None, :]),
                    "prompt_lens": jnp.asarray([plen], jnp.int32)})
                self._m["prefills"].inc()
                cache = self._write_slot(cache, fresh, jnp.int32(i))
                t = self._pick(np.asarray(logits)[0, -1], req)
            req.first_token_t = time.perf_counter()
            req.admit_round = self._round
            req.out_tokens.append(t)
            self._m["tokens_out"].inc()
            self._m["ttft"].observe(req.ttft_s)
            cur[i, 0] = t
            slots[i] = req
            lens[i] = plen

        def maybe_retire(i: int):
            nonlocal cache
            req = slots[i]
            full = lens[i] >= self.scfg.max_len
            if (req.out_tokens[-1] == self._effective_eos(req)
                    or len(req.out_tokens) >= req.max_new_tokens or full):
                req.done = True
                req.finish_t = time.perf_counter()
                req.finish_round = self._round
                finished.append(req)
                self._m["requests_done"].inc()
                self._observe_retired(req)
                slots[i] = None
                lens[i] = 0
                cache = api.cache_free_slot(cache, i)

        while True:
            # refill free slots from the queue between decode rounds; the
            # inner while re-admits into a slot whose request retired at
            # admission (max_new_tokens=1 / instant EOS)
            for i in range(B):
                while slots[i] is None:
                    req = self._next_request()
                    if req is None:
                        break
                    admit(i, req)
                    maybe_retire(i)
            active = [i for i in range(B) if slots[i] is not None]
            if not active:
                break                   # the admit loop drained the queue
            t0 = time.perf_counter()
            with obs_trace.span("engine.decode_round", round=self._round,
                                active=len(active)):
                logits, cache = self.decode(self.params, jnp.asarray(cur),
                                            cache)
                # block on BOTH outputs before stopping the timer: asarray
                # alone would sync the logits but leave the cache update in
                # flight, skewing decode_tok_s by JAX async dispatch
                jax.block_until_ready((logits, cache))
            self._m["decode_time"].inc(time.perf_counter() - t0)
            logits = np.asarray(logits)
            self._round += 1
            self._m["decode_steps"].inc()
            self._m["occupied"].inc(len(active))
            for i in active:
                lens[i] += 1            # this round wrote K/V at lens[i]
                req = slots[i]
                t = self._pick(logits[i, -1], req)
                req.out_tokens.append(t)
                self._m["tokens_out"].inc()
                cur[i, 0] = t
                maybe_retire(i)
            # decode advanced every row's length, including retired/empty
            # slots; re-zero them so dead rows can never drift past max_len
            cache["len"] = jnp.asarray(np.asarray(lens, np.int32))
        return finished

    # ------------------------------------------------------------- static --

    def _run_static(self) -> List[Request]:
        finished: List[Request] = []
        while True:
            batch = self._take_batch()
            if not batch:
                break
            finished.extend(self._run_batch(batch))
        return finished

    def _run_batch(self, reqs: List[Request]) -> List[Request]:
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt      # left-pad
        now = time.perf_counter()
        for r in reqs:
            r.admit_t = now
        with obs_trace.span("engine.prefill", batch=b, plen=plen):
            logits, cache = self.prefill(self.params,
                                         {"tokens": jnp.asarray(toks)})
            self._m["prefills"].inc()
            lg = np.asarray(logits)
        cur = np.zeros((b, 1), np.int32)
        now = time.perf_counter()
        for i, r in enumerate(reqs):
            t = self._pick(lg[i, -1], r)
            r.first_token_t = now
            self._m["ttft"].observe(r.ttft_s)
            r.out_tokens.append(t)
            self._m["tokens_out"].inc()
            cur[i, 0] = t
            if t == self._effective_eos(r) or r.max_new_tokens <= 1:
                r.done = True
        steps = max(r.max_new_tokens for r in reqs) - 1
        for _ in range(max(steps, 0)):
            if all(r.done for r in reqs):
                break
            t0 = time.perf_counter()
            with obs_trace.span("engine.decode_round", round=self._round,
                                active=sum(not r.done for r in reqs)):
                logits, cache = self.decode(self.params, jnp.asarray(cur),
                                            cache)
                # sync logits AND cache before stopping the timer (see the
                # continuous path): decode_tok_s must be device time
                jax.block_until_ready((logits, cache))
            self._m["decode_time"].inc(time.perf_counter() - t0)
            lg = np.asarray(logits)
            self._round += 1
            self._m["decode_steps"].inc()
            for i, r in enumerate(reqs):
                if r.done:
                    continue
                self._m["occupied"].inc()
                t = self._pick(lg[i, -1], r)
                r.out_tokens.append(t)
                self._m["tokens_out"].inc()
                cur[i, 0] = t
                if (t == self._effective_eos(r)
                        or len(r.out_tokens) >= r.max_new_tokens):
                    r.done = True
        now = time.perf_counter()
        for r in reqs:
            r.done = True
            r.finish_t = now
            r.finish_round = self._round
            self._m["requests_done"].inc()
            self._observe_retired(r)
        return reqs
