"""Continuous-batching serve engine over a fixed (max_batch, max_len) budget.

Two schedulers share one ``Engine`` API; ``ServeConfig.scheduler`` picks:

* ``"continuous"`` (default) — a slot-based scheduler. Each admitted request
  is prefilled on its own (right-padded to a power-of-two length bucket so
  jit recompiles stay O(log max_len)), and its KV cache + position are
  surgically written into a free slot of the ONE live batched cache
  (``models/api.cache_write_slot``). Decode then advances every occupied
  slot one token per round with per-slot cache lengths (``cache["len"]`` is
  a (max_batch,) vector; each row writes/attends at its own position). A
  sequence retires the round it finishes — per-request EOS, per-request
  ``max_new_tokens``, or the ``max_len`` KV cap — and its freed slot is
  refilled from the queue *between decode rounds*, so the batch stays full
  under skewed output lengths instead of draining to the slowest member.
* ``"static"`` — the legacy drain strategy: pack up to ``max_batch``
  requests, left-pad prompts to a common length (unmasked, the historical
  approximation), prefill once, and decode the whole batch to completion
  before admitting more. Kept as the baseline that
  ``benchmarks/serve_bench.py`` measures continuous scheduling against.

Sampling is greedy argmax by default; a positive temperature (per
``ServeConfig`` with ``greedy=False``, or per-``Request`` override) switches
that request to softmax sampling with the engine's seeded host rng.

``Engine.stats`` surfaces scheduler metrics: prefill/decode-round/token
counters, slot occupancy (occupied slot-rounds over offered slot-rounds),
mean time-to-first-token, and decode throughput.
"""
from __future__ import annotations

import dataclasses
import functools
import queue
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api


@dataclasses.dataclass
class Request:
    """One generation request plus the engine-filled result/metric fields."""
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None    # overrides ServeConfig.eos_id when set
    temperature: Optional[float] = None  # overrides the engine default
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    # engine-filled metrics
    submit_t: float = 0.0           # wall time at Engine.submit
    first_token_t: float = 0.0      # wall time when the prefill token landed
    finish_t: float = 0.0
    admit_round: int = -1           # global decode-round counter at admission
    finish_round: int = -1          # round the request retired on

    @property
    def ttft_s(self) -> float:
        return max(self.first_token_t - self.submit_t, 0.0)


@dataclasses.dataclass
class ServeConfig:
    """Engine knobs.

    max_batch:  number of decode slots — the batch dim of the KV budget.
    max_len:    per-slot KV capacity. prompt length + generated tokens are
                capped here; a sequence that fills its slot is retired even
                if it has not reached ``max_new_tokens`` / EOS.
    eos_id:     stop-token id. ``-1`` is the "never" sentinel — no token id
                can equal it, so only ``max_new_tokens`` or the ``max_len``
                cap retire a sequence. ``Request.eos_id`` overrides per
                request (including overriding a real id back to -1).
    greedy:     True -> argmax decoding (ignores ``temperature``).
    temperature: softmax temperature used when ``greedy=False`` (or when a
                request carries its own ``temperature`` override). <= 0
                degrades to argmax.
    scheduler:  "continuous" (slot refill between decode rounds) or
                "static" (legacy drain batches).
    prefill_bucket: floor of the power-of-two right-padding buckets used by
                continuous prefill for attention families. ssm/hybrid
                recurrences are position-exact, so those families always
                prefill at the exact prompt length (one compile per
                distinct length).
    attn_impl:  prefill attention implementation ("flash" | "full" | ...).
    seed:       host rng seed for temperature sampling.
    precision:  "float" (default) serves as-is. "int8" PTQ-quantizes every
                layer's FFN weights at engine init (power-of-two scales,
                paper Eq. 4) and runs those matmuls int8 x int8 -> int32
                through the Pallas ``matmul_q8`` kernel with its fused
                Algorithm-1 shift-requantized epilogue; "int8-xla" is the
                same arithmetic on the jnp integer oracle (bit-exact with
                "int8" — the direct / no-SIMD baseline). Attention-family
                dense-MLP configs only (no moe / ssm / hybrid / encdec).
    """
    max_batch: int = 4
    max_len: int = 256
    eos_id: int = -1                # -1: never
    greedy: bool = True
    temperature: float = 0.0
    scheduler: str = "continuous"
    prefill_bucket: int = 16
    attn_impl: str = "flash"
    seed: int = 0
    precision: str = "float"


class Engine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig):
        if scfg.scheduler not in ("continuous", "static"):
            raise ValueError(f"unknown scheduler: {scfg.scheduler!r}")
        if cfg.family == "encdec" and scfg.scheduler == "continuous":
            raise NotImplementedError(
                "continuous batching needs slotted caches; encdec is not "
                "slotted (models/api.slot_batch_axes) — use scheduler='static'")
        if scfg.precision not in ("float", "int8", "int8-xla"):
            raise ValueError(f"unknown precision: {scfg.precision!r}")
        if scfg.precision != "float":
            if cfg.family in ("ssm", "hybrid", "encdec") or cfg.moe is not None:
                raise NotImplementedError(
                    "ServeConfig.precision='int8' quantizes dense FFN "
                    "matmuls; moe/ssm/hybrid/encdec configs are unsupported")
            # PTQ the FFN stack once; the quantized tree rides along in
            # params["layers"] so the layer scan slices it like any weight
            from repro.models.blocks import quantize_mlp_params
            layers = dict(params["layers"])
            layers["qmlp"] = quantize_mlp_params(layers["mlp"])
            params = dict(params, layers=layers)
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self.prefill = jax.jit(
            api.prefill_fn(cfg, scfg.max_len, attn_impl=scfg.attn_impl,
                           precision=scfg.precision))
        # donate the live cache so slot writes / decode rounds update it in
        # place instead of copying the whole KV budget (CPU backends don't
        # implement donation and would warn on every compile, so skip there)
        cpu = jax.default_backend() == "cpu"
        self.decode = jax.jit(api.decode_fn(cfg, precision=scfg.precision),
                              donate_argnums=() if cpu else (2,))
        if cfg.family != "encdec":
            self._write_slot = jax.jit(
                functools.partial(api.cache_write_slot, cfg),
                donate_argnums=() if cpu else (0,))
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self._rng = np.random.default_rng(scfg.seed)
        self.reset_stats()

    # ------------------------------------------------------------- metrics --

    def reset_stats(self):
        """Zero the counters (e.g. after a compile-warmup drain)."""
        self._c = dict(prefills=0, decode_steps=0, tokens_out=0,
                       requests_done=0, occupied_slot_rounds=0)
        self._ttft: List[float] = []
        self._decode_time = 0.0
        self._round = 0

    @property
    def stats(self) -> dict:
        """Counters + derived scheduler metrics (computed on access)."""
        c = dict(self._c)
        offered = c.pop("occupied_slot_rounds")
        rounds = c["decode_steps"]
        c["occupancy"] = offered / (rounds * self.scfg.max_batch) if rounds \
            else 0.0
        c["ttft_avg_s"] = float(np.mean(self._ttft)) if self._ttft else 0.0
        c["decode_tok_s"] = (c["tokens_out"] / self._decode_time
                             if self._decode_time > 0 else 0.0)
        return c

    # ----------------------------------------------------------- frontend --

    def submit(self, req: Request):
        # reject oversized prompts here, not mid-drain: raising during
        # run_until_drained would discard finished requests and strand the
        # rest of the queue
        if len(req.prompt) > self.scfg.max_len:
            raise ValueError(
                f"request {req.uid}: prompt length {len(req.prompt)} exceeds "
                f"max_len={self.scfg.max_len}")
        req.submit_t = time.time()
        self.queue.put(req)

    def _next_request(self) -> Optional[Request]:
        try:
            return self.queue.get_nowait()
        except queue.Empty:
            return None

    def _take_batch(self) -> List[Request]:
        # get_nowait, not .empty(): .empty() is only a racy hint once a
        # producer thread (or future async frontend) feeds the queue
        out: List[Request] = []
        while len(out) < self.scfg.max_batch:
            req = self._next_request()
            if req is None:
                break
            out.append(req)
        return out

    def run_until_drained(self) -> List[Request]:
        if self.scfg.scheduler == "static":
            return self._run_static()
        return self._run_continuous()

    # ----------------------------------------------------------- sampling --

    def _pick(self, logits_row: np.ndarray, req: Request) -> int:
        temp = req.temperature
        if temp is None:
            temp = 0.0 if self.scfg.greedy else self.scfg.temperature
        if temp <= 0.0:
            return int(np.argmax(logits_row))
        z = np.asarray(logits_row, np.float64) / temp
        z -= z.max()
        p = np.exp(z)
        return int(self._rng.choice(p.size, p=p / p.sum()))

    def _effective_eos(self, req: Request) -> int:
        return self.scfg.eos_id if req.eos_id is None else req.eos_id

    # --------------------------------------------------------- continuous --

    def _bucket_len(self, plen: int) -> int:
        if plen > self.scfg.max_len:
            raise ValueError(
                f"prompt length {plen} exceeds max_len={self.scfg.max_len}")
        if self.cfg.family in ("ssm", "hybrid"):
            return plen                 # recurrent state is position-exact
        b = max(self.scfg.prefill_bucket, 1)
        while b < plen:
            b *= 2
        return min(b, self.scfg.max_len)

    def _run_continuous(self) -> List[Request]:
        B = self.scfg.max_batch
        cache = api.init_slot_cache(self.cfg, B, self.scfg.max_len)
        slots: List[Optional[Request]] = [None] * B
        lens = [0] * B                  # host mirror of cache["len"]
        cur = np.zeros((B, 1), np.int32)
        finished: List[Request] = []

        def admit(i: int, req: Request):
            nonlocal cache
            plen = len(req.prompt)
            bucket = self._bucket_len(plen)
            toks = np.zeros((bucket,), np.int32)
            toks[:plen] = req.prompt    # right-pad: positions stay 0..plen-1
            logits, fresh = self.prefill(self.params, {
                "tokens": jnp.asarray(toks[None, :]),
                "prompt_lens": jnp.asarray([plen], jnp.int32)})
            self._c["prefills"] += 1
            cache = self._write_slot(cache, fresh, jnp.int32(i))
            t = self._pick(np.asarray(logits)[0, -1], req)
            req.first_token_t = time.time()
            req.admit_round = self._round
            req.out_tokens.append(t)
            self._c["tokens_out"] += 1
            self._ttft.append(req.ttft_s)
            cur[i, 0] = t
            slots[i] = req
            lens[i] = plen

        def maybe_retire(i: int):
            nonlocal cache
            req = slots[i]
            full = lens[i] >= self.scfg.max_len
            if (req.out_tokens[-1] == self._effective_eos(req)
                    or len(req.out_tokens) >= req.max_new_tokens or full):
                req.done = True
                req.finish_t = time.time()
                req.finish_round = self._round
                finished.append(req)
                self._c["requests_done"] += 1
                slots[i] = None
                lens[i] = 0
                cache = api.cache_free_slot(cache, i)

        while True:
            # refill free slots from the queue between decode rounds; the
            # inner while re-admits into a slot whose request retired at
            # admission (max_new_tokens=1 / instant EOS)
            for i in range(B):
                while slots[i] is None:
                    req = self._next_request()
                    if req is None:
                        break
                    admit(i, req)
                    maybe_retire(i)
            active = [i for i in range(B) if slots[i] is not None]
            if not active:
                break                   # the admit loop drained the queue
            t0 = time.perf_counter()
            logits, cache = self.decode(self.params, jnp.asarray(cur), cache)
            logits = np.asarray(logits)     # blocks until the round is done
            self._decode_time += time.perf_counter() - t0
            self._round += 1
            self._c["decode_steps"] += 1
            self._c["occupied_slot_rounds"] += len(active)
            for i in active:
                lens[i] += 1            # this round wrote K/V at lens[i]
                req = slots[i]
                t = self._pick(logits[i, -1], req)
                req.out_tokens.append(t)
                self._c["tokens_out"] += 1
                cur[i, 0] = t
                maybe_retire(i)
            # decode advanced every row's length, including retired/empty
            # slots; re-zero them so dead rows can never drift past max_len
            cache["len"] = jnp.asarray(np.asarray(lens, np.int32))
        return finished

    # ------------------------------------------------------------- static --

    def _run_static(self) -> List[Request]:
        finished: List[Request] = []
        while True:
            batch = self._take_batch()
            if not batch:
                break
            finished.extend(self._run_batch(batch))
        return finished

    def _run_batch(self, reqs: List[Request]) -> List[Request]:
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt      # left-pad
        logits, cache = self.prefill(self.params, {"tokens": jnp.asarray(toks)})
        self._c["prefills"] += 1
        lg = np.asarray(logits)
        cur = np.zeros((b, 1), np.int32)
        now = time.time()
        for i, r in enumerate(reqs):
            t = self._pick(lg[i, -1], r)
            r.first_token_t = now
            self._ttft.append(r.ttft_s)
            r.out_tokens.append(t)
            self._c["tokens_out"] += 1
            cur[i, 0] = t
            if t == self._effective_eos(r) or r.max_new_tokens <= 1:
                r.done = True
        steps = max(r.max_new_tokens for r in reqs) - 1
        for _ in range(max(steps, 0)):
            if all(r.done for r in reqs):
                break
            t0 = time.perf_counter()
            logits, cache = self.decode(self.params, jnp.asarray(cur), cache)
            lg = np.asarray(logits)
            self._decode_time += time.perf_counter() - t0
            self._round += 1
            self._c["decode_steps"] += 1
            for i, r in enumerate(reqs):
                if r.done:
                    continue
                self._c["occupied_slot_rounds"] += 1
                t = self._pick(lg[i, -1], r)
                r.out_tokens.append(t)
                self._c["tokens_out"] += 1
                cur[i, 0] = t
                if (t == self._effective_eos(r)
                        or len(r.out_tokens) >= r.max_new_tokens):
                    r.done = True
        now = time.time()
        for r in reqs:
            r.done = True
            r.finish_t = now
            r.finish_round = self._round
            self._c["requests_done"] += 1
        return reqs
