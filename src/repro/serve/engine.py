"""Batched serving loop: continuous-batching-lite over a fixed KV budget.

Requests carry prompts; the engine packs up to `max_batch` of them, runs
one prefill, then steps decode for all sequences in lockstep, retiring
finished ones (EOS or max_new_tokens) and refilling free slots from the
queue between decode rounds. Optional int8 power-of-two weight
quantization (the paper's Eq. 4 scheme) for the serve path.
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 4
    max_len: int = 256
    eos_id: int = -1                # -1: never
    greedy: bool = True


class Engine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig):
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self.prefill = jax.jit(api.prefill_fn(cfg, scfg.max_len))
        self.decode = jax.jit(api.decode_fn(cfg))
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self.stats = dict(prefills=0, decode_steps=0, tokens_out=0)

    def submit(self, req: Request):
        self.queue.put(req)

    def _take_batch(self) -> List[Request]:
        out = []
        while len(out) < self.scfg.max_batch and not self.queue.empty():
            out.append(self.queue.get())
        return out

    def run_until_drained(self) -> List[Request]:
        finished: List[Request] = []
        while not self.queue.empty():
            batch = self._take_batch()
            finished.extend(self._run_batch(batch))
        return finished

    def _run_batch(self, reqs: List[Request]) -> List[Request]:
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt      # left-pad
        logits, cache = self.prefill(self.params, {"tokens": jnp.asarray(toks)})
        self.stats["prefills"] += 1
        cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        for r, t in zip(reqs, np.asarray(cur)[:, 0]):
            r.out_tokens.append(int(t))
        steps = max(r.max_new_tokens for r in reqs) - 1
        for _ in range(max(steps, 0)):
            logits, cache = self.decode(self.params, cur, cache)
            self.stats["decode_steps"] += 1
            cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            alive = False
            for i, r in enumerate(reqs):
                if r.done or len(r.out_tokens) >= r.max_new_tokens:
                    r.done = True
                    continue
                t = int(np.asarray(cur)[i, 0])
                r.out_tokens.append(t)
                self.stats["tokens_out"] += 1
                if t == self.scfg.eos_id:
                    r.done = True
                alive = alive or not r.done
            if not alive:
                break
        for r in reqs:
            r.done = True
        return reqs
