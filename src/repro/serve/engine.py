"""Continuous-batching serve engine over a fixed (max_batch, max_len) budget.

Two schedulers share one ``Engine`` API; ``ServeConfig.scheduler`` picks:

* ``"continuous"`` (default) — a slot-based scheduler. Each admitted request
  is prefilled on its own (right-padded to a power-of-two length bucket so
  jit recompiles stay O(log max_len)), and its KV cache + position are
  surgically written into a free slot of the ONE live batched cache
  (``models/api.cache_write_slot``). Decode then advances every occupied
  slot one token per round with per-slot cache lengths (``cache["len"]`` is
  a (max_batch,) vector; each row writes/attends at its own position). A
  sequence retires the round it finishes — per-request EOS, per-request
  ``max_new_tokens``, or the ``max_len`` KV cap — and its freed slot is
  refilled from the queue *between decode rounds*, so the batch stays full
  under skewed output lengths instead of draining to the slowest member.
* ``"static"`` — the legacy drain strategy: pack up to ``max_batch``
  requests, left-pad prompts to a common length (unmasked, the historical
  approximation), prefill once, and decode the whole batch to completion
  before admitting more. Kept as the baseline that
  ``benchmarks/serve_bench.py`` measures continuous scheduling against.

Sampling is greedy argmax by default; a positive temperature (per
``ServeConfig`` with ``greedy=False``, or per-``Request`` override) switches
that request to softmax sampling with the engine's seeded host rng.

KV backing is picked by ``ServeConfig.kv_layout``:

* ``"contiguous"`` (default) — every slot owns a (max_len,) KV row of the
  one live batched cache; memory is ``max_batch x max_len`` regardless of
  the actual sequence lengths.
* ``"paged"`` — K/V live in a shared :class:`BlockPool` of fixed-size
  pages (``kv_block_size`` positions each); each slot holds a block table
  that grows one page at a time as the sequence crosses a page boundary,
  so resident KV scales with *actual* tokens. Prompt pages are
  content-addressed (chained sha1 over full prompt blocks): requests that
  share a prompt prefix map their leading table entries to the same
  refcounted pages, paying the prefix's prefill FLOPs and KV bytes once —
  on a float-KV hit only the suffix runs through the model
  (``models/api.prefill_suffix_fn``); int8-KV hits share storage only
  (dequantized codes are not the float prefix, so the prompt is recomputed
  and the shared-page writes skipped). Pages of retired requests linger in
  an LRU "evictable" set until memory pressure reclaims them, so serial
  repeats of a prefix still hit. When the pool runs dry the engine parks
  new admissions in a FIFO holdback (backpressure) and, for mid-decode
  growth, preempts the youngest slot (greedy decode makes the replayed
  stream identical). Greedy token streams are BIT-IDENTICAL to the
  contiguous layout for float and int8 KV alike: pages gather back into
  exactly the contiguous cache view (``kv_block_size`` divides
  ``max_len``), masked tail positions carry exact-zero attention weight,
  and a prefix page's K/V are bitwise independent of the bucket the donor
  prefilled under (tests/test_paged.py locks both properties).

``Engine.stats`` surfaces scheduler metrics: prefill/decode-round/token
counters, slot occupancy (occupied slot-rounds over offered slot-rounds),
TTFT/TPOT/queue-wait latency quantiles, decode throughput, and block-pool
gauges (``blocks_in_use`` / ``blocks_free`` / ``prefix_hit_rate``; zero
under the contiguous layout). The stats are backed by a private
``repro.obs.metrics.Registry`` per engine (same keys as the pre-registry
dict, plus the histogram quantiles), and with ``REPRO_TRACE=1`` the
engine emits per-request lifecycle spans (queue_wait -> prefill ->
generate, each request on its own trace lane) plus per-round decode spans
and paged-pool events (``engine.block_alloc`` / ``engine.block_free`` /
``engine.prefix_lookup``) to the process tracer — export with
``repro.obs.trace.export(path)`` and open in Perfetto.

Timing discipline: decode-round timers ``jax.block_until_ready`` the round
outputs before stopping, so ``decode_tok_s`` measures real device time and
not JAX async-dispatch enqueue time; request timestamps are monotonic
``time.perf_counter()`` values (intervals can't go negative under clock
adjustment) with one wall-clock field (``submit_wall_t``) kept for trace
export.

Failure model (EXPERIMENTS.md §Resilience): the engine degrades instead of
dying. Every request reaches exactly one terminal ``status``:

* ``ok``      — retired normally (EOS / max_new_tokens / KV cap).
* ``timeout`` — its ``deadline_s`` (per-request, falling back to
  ``ServeConfig.deadline_s``) elapsed; cancelled at a round boundary with
  partial ``out_tokens``, KV slot and pool pages reclaimed.
* ``error``   — a prefill or decode failure survived
  ``ServeConfig.max_retries`` bounded retries (exponential
  ``retry_backoff_s``); only the poisoned request(s) retire, survivors
  keep decoding bit-identically (batch composition never changes a
  greedy stream). An unrecoverable *decode-round* failure retires the
  whole active set and rebuilds the KV arena (donated buffers may be
  dead), then drains the queue against the fresh arena.
* ``shed``    — rejected at ``submit`` because the queue held
  ``max_queue`` requests (``shed_policy="reject"`` raises
  :class:`QueueFullError` instead of marking).

Fault seams (``repro.faults``): ``engine.prefill`` fires per admission
attempt, ``engine.decode_round`` per round attempt, ``blockpool.alloc``
inside :meth:`BlockPool.alloc`. A ``corrupt`` fault poisons that round's
host logits; the affected uids are recorded in ``Engine.poisoned_uids``
(silent corruption is contained, not detected). With no plan active every
seam is a single global read.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import hashlib
import queue
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.faults import inject as faults
from repro.models import api
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


class QueueFullError(RuntimeError):
    """Raised by :meth:`Engine.submit` under ``shed_policy="reject"`` when
    the queue already holds ``max_queue`` requests."""


@dataclasses.dataclass
class Request:
    """One generation request plus the engine-filled result/metric fields."""
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None    # overrides ServeConfig.eos_id when set
    temperature: Optional[float] = None  # overrides the engine default
    deadline_s: Optional[float] = None   # overrides ServeConfig.deadline_s
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    status: str = "pending"         # terminal: ok | timeout | error | shed
    error: Optional[str] = None     # the absorbed exception, status="error"
    # engine-filled metrics — monotonic time.perf_counter() stamps, so the
    # derived intervals (ttft, queue wait, tpot) can never go negative under
    # wall-clock adjustment; submit_wall_t is the one wall-clock field kept
    # so trace export can recover absolute times
    submit_t: float = 0.0           # perf_counter at Engine.submit
    submit_wall_t: float = 0.0      # wall clock at Engine.submit
    admit_t: float = 0.0            # perf_counter at slot admission
    first_token_t: float = 0.0      # perf_counter when the prefill token landed
    finish_t: float = 0.0
    admit_round: int = -1           # global decode-round counter at admission
    finish_round: int = -1          # round the request retired on

    @property
    def ttft_s(self) -> float:
        return max(self.first_token_t - self.submit_t, 0.0)

    @property
    def queue_wait_s(self) -> float:
        return max(self.admit_t - self.submit_t, 0.0)


class BlockPool:
    """Host-side page allocator + hash-based prefix cache for the paged KV
    layout (``ServeConfig.kv_layout="paged"``).

    Page 0 is RESERVED as the garbage page: never allocated, so a retired
    slot's zeroed block-table row scatters its masked (never-read) decode
    writes there without touching a live page.

    Prompt pages are content-addressed: ``prefix_keys`` chains a sha1 over
    each FULL prompt block (every digest covers all tokens up to and
    including its block, so equal digest == equal token prefix), and
    ``publish`` registers digest -> page after the page's K/V are written.
    A page whose live refcount drops to zero is NOT freed — it parks in an
    LRU *evictable* set with its digest mapping intact, so a later request
    with the same prefix still hits (serial-traffic TTFT wins); ``alloc``
    reclaims evictable pages oldest-first only once the free list runs
    dry. Retention is safe because published pages are never written again
    (decode writes land strictly past the last full prompt block) and
    content-addressing guarantees a hit returns K/V computed from exactly
    the hitting request's token prefix.

    Single-threaded by design — the engine drives it between device calls.

    Integrity: the pool validates its own transitions instead of silently
    corrupting the free list — ``free`` / ``release`` raise ``ValueError``
    on a double-free, an unknown page id, a page with live references, or
    a parked (evictable) page; ``acquire`` revalidates that a refcount-0
    page it is un-parking was not evicted in the meantime. :meth:`audit`
    returns every violated structural invariant (conservation
    ``free + live-in-use + parked == usable``, positive refcounts,
    digest bijection) as a list — the chaos harness and the property
    sweep in ``tests/test_faults.py`` call it after every op/drain.
    """

    def __init__(self, num_blocks: int, block_size: int,
                 prefix_cache: bool = True):
        if num_blocks < 2:
            raise ValueError("BlockPool needs >= 2 pages (page 0 is the "
                             "reserved garbage page)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.prefix_cache = prefix_cache
        # pop() -> lowest id first; freed pages return LIFO (deterministic)
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._allocated: set = set()          # handed out, not yet freed
        self._ref: Dict[int, int] = {}        # page id -> live refcount
        self._digest: Dict[str, int] = {}     # digest -> page id
        self._page_digest: Dict[int, str] = {}
        self._evictable: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()         # refcount-0 hashed pages, LRU
        self.lookups = 0                      # block-granular hit telemetry
        self.hits = 0

    # ------------------------------------------------------------ capacity --

    @property
    def usable(self) -> int:
        return self.num_blocks - 1

    @property
    def free_pages(self) -> int:
        """Allocatable pages: truly free plus evictable-on-demand."""
        return len(self._free) + len(self._evictable)

    @property
    def in_use(self) -> int:
        return self.usable - self.free_pages

    # -------------------------------------------------------- prefix cache --

    def prefix_keys(self, prompt: np.ndarray) -> List[str]:
        """Chained sha1 digest per full prompt block, excluding the block
        holding the last prompt token — at least one position is always
        recomputed so admission has last-token logits to sample from."""
        if not self.prefix_cache:
            return []
        bs = self.block_size
        toks = np.ascontiguousarray(np.asarray(prompt, np.int32))
        h = hashlib.sha1()
        keys = []
        for j in range((len(toks) - 1) // bs):
            h.update(toks[j * bs:(j + 1) * bs].tobytes())
            keys.append(h.hexdigest())
        return keys

    def lookup(self, keys: List[str]) -> List[int]:
        """Page ids for the longest registered leading run of ``keys``.
        Read-only: ``acquire`` the result before any ``alloc`` so eviction
        cannot reclaim a page the caller is about to reference."""
        ids = []
        for k in keys:
            bid = self._digest.get(k)
            if bid is None:
                break
            ids.append(bid)
        self.lookups += len(keys)
        self.hits += len(ids)
        return ids

    def acquire(self, ids: List[int]) -> None:
        """Take a live reference on hashed pages (un-parks evictable ones).

        A refcount-0 page being un-parked is revalidated: it must still be
        parked with its digest mapping intact — if ``alloc``'s eviction scan
        reclaimed it since the lookup, referencing it would alias a page
        now owned by another request, so that is a ``ValueError``."""
        for bid in ids:
            if bid in self._ref:
                self._ref[bid] += 1
                continue
            if bid not in self._evictable or bid not in self._page_digest:
                raise ValueError(
                    f"acquire: page {bid} has no live references and is not "
                    f"parked evictable — it was evicted (or never published); "
                    f"re-run lookup before acquiring")
            self._evictable.pop(bid)
            self._ref[bid] = 1

    def release(self, ids: List[int]) -> None:
        """Drop a live reference; pages reaching zero park as evictable.
        Releasing a page with no live reference (double-release, or an id
        that was never acquired/published) is a ``ValueError``."""
        for bid in ids:
            if self._ref.get(bid, 0) < 1:
                raise ValueError(
                    f"release: page {bid} has no live reference "
                    f"(double-release or unknown page id)")
            self._ref[bid] -= 1
            if self._ref[bid] == 0:
                del self._ref[bid]
                self._evictable[bid] = None

    def publish(self, keys: List[str], ids: List[int]) -> None:
        """Register freshly written full prompt blocks (digest -> page) and
        take the writing request's live reference. The engine is
        single-threaded, so a digest that missed at lookup is still absent
        here — no collision handling needed."""
        for k, bid in zip(keys, ids):
            self._digest[k] = bid
            self._page_digest[bid] = k
            self._ref[bid] = self._ref.get(bid, 0) + 1

    # --------------------------------------------------------- allocation --

    def alloc(self, n: int) -> Optional[List[int]]:
        """``n`` pages, or None when the pool cannot supply them (the
        engine then applies admission backpressure / preemption). Evicts
        LRU refcount-0 hashed pages only when the free list runs dry.
        Fault seam ``blockpool.alloc`` fires before any state changes, so
        an injected raise never half-allocates."""
        faults.check("blockpool.alloc")
        if n > self.free_pages:
            return None
        out = []
        for _ in range(n):
            if self._free:
                out.append(self._free.pop())
            else:
                bid, _ = self._evictable.popitem(last=False)
                del self._digest[self._page_digest.pop(bid)]
                out.append(bid)
        self._allocated.update(out)
        return out

    def free(self, ids: List[int], hashed: int = 0) -> None:
        """Return a retired request's pages: the leading ``hashed`` ids
        (published/hit prompt pages) drop a reference and park when it
        reaches zero; the rest go straight back to the free list.

        The unhashed tail is validated before any state changes: every id
        must be a currently allocated page with no live references, not
        parked evictable, and not published — a double-free or unknown id
        raises ``ValueError`` instead of silently corrupting the free
        list (the old behavior, which later handed one page to two
        requests)."""
        tail = ids[hashed:]
        for bid in tail:
            if bid not in self._allocated:
                raise ValueError(
                    f"free: page {bid} is not allocated "
                    f"(double-free or unknown page id)")
            if self._ref.get(bid, 0) > 0:
                raise ValueError(
                    f"free: page {bid} has {self._ref[bid]} live "
                    f"reference(s) — release them (hashed=) instead")
            if bid in self._evictable or bid in self._page_digest:
                raise ValueError(
                    f"free: page {bid} is published/parked — published "
                    f"prompt pages retire via the hashed= prefix")
        self.release(ids[:hashed])
        self._allocated.difference_update(tail)
        self._free.extend(tail)

    # ------------------------------------------------------------ auditing --

    def audit(self, expect_drained: bool = False) -> List[str]:
        """Every violated structural invariant, as human-readable strings
        (empty == healthy). With ``expect_drained=True`` additionally
        requires quiescence: no live references and no allocated page
        outside the evictable set (i.e. nothing leaked after a drain)."""
        bad: List[str] = []
        free_set = set(self._free)
        if len(free_set) != len(self._free):
            bad.append("duplicate ids on the free list")
        if 0 in free_set or 0 in self._allocated:
            bad.append("reserved garbage page 0 entered circulation")
        both = free_set & self._allocated
        if both:
            bad.append(f"pages simultaneously free and allocated: "
                       f"{sorted(both)}")
        if len(self._free) + len(self._allocated) != self.usable:
            bad.append(
                f"conservation broken: free({len(self._free)}) + "
                f"allocated({len(self._allocated)}) != usable({self.usable})")
        for bid, r in self._ref.items():
            if r < 1:
                bad.append(f"page {bid} has non-positive refcount {r}")
            if bid not in self._allocated:
                bad.append(f"referenced page {bid} is not allocated")
            if bid in self._evictable:
                bad.append(f"page {bid} parked evictable with live refs")
        for bid in self._evictable:
            if bid not in self._allocated:
                bad.append(f"evictable page {bid} is not allocated")
            if bid not in self._page_digest:
                bad.append(f"evictable page {bid} has no digest mapping")
        for d, bid in self._digest.items():
            if self._page_digest.get(bid) != d:
                bad.append(f"digest bijection broken at digest {d[:12]}…")
        for bid, d in self._page_digest.items():
            if self._digest.get(d) != bid:
                bad.append(f"digest bijection broken at page {bid}")
        if expect_drained:
            if self._ref:
                bad.append(f"live references after drain: "
                           f"{dict(sorted(self._ref.items()))}")
            leaked = self._allocated - set(self._evictable) - set(self._ref)
            if leaked:
                bad.append(f"leaked pages (allocated, unreferenced, not "
                           f"parked): {sorted(leaked)}")
        return bad


@dataclasses.dataclass
class ServeConfig:
    """Engine knobs.

    max_batch:  number of decode slots — the batch dim of the KV budget.
    max_len:    per-slot KV capacity. prompt length + generated tokens are
                capped here; a sequence that fills its slot is retired even
                if it has not reached ``max_new_tokens`` / EOS.
    eos_id:     stop-token id. ``-1`` is the "never" sentinel — no token id
                can equal it, so only ``max_new_tokens`` or the ``max_len``
                cap retire a sequence. ``Request.eos_id`` overrides per
                request (including overriding a real id back to -1).
    greedy:     True -> argmax decoding (ignores ``temperature``).
    temperature: softmax temperature used when ``greedy=False`` (or when a
                request carries its own ``temperature`` override). <= 0
                degrades to argmax.
    scheduler:  "continuous" (slot refill between decode rounds) or
                "static" (legacy drain batches).
    prefill_bucket: floor of the power-of-two right-padding buckets used by
                continuous prefill for attention families. ssm/hybrid
                recurrences are position-exact, so those families always
                prefill at the exact prompt length (one compile per
                distinct length).
    attn_impl:  prefill attention implementation ("flash" | "full" | ...).
    seed:       host rng seed for temperature sampling.
    precision:  "float" (default) serves as-is. "int8" PTQ-quantizes every
                layer's FFN weights at engine init (power-of-two scales,
                paper Eq. 4) and runs those matmuls int8 x int8 -> int32
                through the Pallas ``matmul_q8`` kernel with its fused
                Algorithm-1 shift-requantized epilogue; "int8-xla" is the
                same arithmetic on the jnp integer oracle (bit-exact with
                "int8" — the direct / no-SIMD baseline). "w4a8" additionally
                nibble-packs the FFN weights (4-bit codes + per-group shift
                scales, ``quantize_w4``) and the matmul unpacks them
                in-register — half the weight bytes per decode step at the
                same int8 activation path. Attention-family dense-MLP
                configs only (no moe / ssm / hybrid / encdec).
    kv_cache:   "float" (default) keeps the resident KV cache in the model
                compute dtype. "int8" stores K/V as int8 codes with
                per-(position, head) f32 scales — ~halved KV bytes;
                quantize-on-write, dequantize-on-read, per-token scales so
                slot refill/retire never re-scales a neighbour. Continuous
                scheduler + attention-family dense caches only (the static
                path decodes straight off the float prefill cache).
    kv_layout:  "contiguous" (default) gives every slot a (max_len,) KV
                row. "paged" backs K/V with a BlockPool of kv_num_blocks
                fixed-size pages instead — block tables grow on demand, a
                shared prompt prefix is stored (and, for float KV,
                prefilled) once, and greedy streams stay bit-identical to
                the contiguous layout. Continuous scheduler +
                attention-family dense caches only; composes with
                kv_cache="int8" (int8 pool pages + scale pages).
    kv_block_size: positions per page under kv_layout="paged". Must divide
                max_len (the gathered block-table view then spans exactly
                max_len positions — the bit-exactness precondition).
                Smaller pages waste less tail memory but hash/grow more
                often; prefix sharing is full-page-granular.
    kv_num_blocks: pool size under kv_layout="paged", including the
                reserved garbage page 0. None (default) sizes the pool to
                the contiguous capacity equivalent, max_batch *
                (max_len / kv_block_size) + 1 — same KV budget, so paged
                admission/growth can never be the bottleneck. Must leave
                at least max_len / kv_block_size usable pages (one request
                growing to max_len must always be able to finish).
    prefix_cache: hash full prompt pages for reuse (paged layout only).
                True by default; disable to measure pure paging.
    deadline_s: per-request wall budget, measured from ``submit``. None
                (default) disables. Checked at round boundaries and before
                admission — an expired request retires with
                ``status="timeout"`` (partial ``out_tokens`` kept, KV slot
                and pool pages reclaimed). ``Request.deadline_s`` overrides
                per request.
    max_queue:  queue-depth cap enforced at :meth:`Engine.submit`. None
                (default) is unbounded; when set it must be
                ``>= max_batch`` (repro.check.config) so one full batch can
                always queue.
    shed_policy: what a full queue does to the incoming request:
                "reject" (default) raises :class:`QueueFullError`;
                "drop" marks it terminal ``status="shed"`` without
                enqueueing (the caller still holds the object).
    max_retries: bounded retries for an injected/transient prefill or
                decode failure before the poisoned request(s) retire with
                ``status="error"``. 0 disables retrying.
    retry_backoff_s: base of the exponential retry backoff sleep
                (``base * 2**(attempt-1)``). 0 (default) retries
                immediately — the right setting for deterministic tests.
    """
    max_batch: int = 4
    max_len: int = 256
    eos_id: int = -1                # -1: never
    greedy: bool = True
    temperature: float = 0.0
    scheduler: str = "continuous"
    prefill_bucket: int = 16
    attn_impl: str = "flash"
    seed: int = 0
    precision: str = "float"
    kv_cache: str = "float"
    kv_layout: str = "contiguous"
    kv_block_size: int = 16
    kv_num_blocks: Optional[int] = None
    prefix_cache: bool = True
    deadline_s: Optional[float] = None
    max_queue: Optional[int] = None
    shed_policy: str = "reject"
    max_retries: int = 2
    retry_backoff_s: float = 0.0


class Engine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig):
        if scfg.scheduler not in ("continuous", "static"):
            raise ValueError(f"unknown scheduler: {scfg.scheduler!r}")
        if cfg.family == "encdec" and scfg.scheduler == "continuous":
            raise NotImplementedError(
                "continuous batching needs slotted caches; encdec is not "
                "slotted (models/api.slot_batch_axes) — use scheduler='static'")
        if scfg.precision not in ("float", "int8", "int8-xla", "w4a8"):
            raise ValueError(f"unknown precision: {scfg.precision!r}")
        if scfg.kv_cache not in ("float", "int8"):
            raise ValueError(f"unknown kv_cache: {scfg.kv_cache!r}")
        if scfg.kv_cache == "int8":
            if scfg.scheduler != "continuous":
                raise NotImplementedError(
                    "kv_cache='int8' quantizes the resident slot cache; the "
                    "static scheduler decodes off the float prefill cache — "
                    "use scheduler='continuous'")
            if cfg.family in ("ssm", "hybrid", "encdec"):
                raise NotImplementedError(
                    "kv_cache='int8' covers attention-family dense KV caches "
                    "only (no ssm / hybrid / encdec)")
        if scfg.kv_layout not in ("contiguous", "paged"):
            raise ValueError(f"unknown kv_layout: {scfg.kv_layout!r}")
        if scfg.kv_layout == "paged":
            if scfg.scheduler != "continuous":
                raise NotImplementedError(
                    "kv_layout='paged' pages the live slotted decode cache; "
                    "the static scheduler decodes off the prefill cache — "
                    "use scheduler='continuous'")
            if cfg.family in ("ssm", "hybrid", "encdec"):
                raise NotImplementedError(
                    "kv_layout='paged' covers attention-family dense KV "
                    "caches only (no ssm / hybrid / encdec)")
        if scfg.precision != "float":
            if cfg.family in ("ssm", "hybrid", "encdec") or cfg.moe is not None:
                raise NotImplementedError(
                    "ServeConfig.precision='int8' quantizes dense FFN "
                    "matmuls; moe/ssm/hybrid/encdec configs are unsupported")
        # constructor-grade static checks beyond the enum combos above:
        # positive batch/length/bucket knobs, non-negative temperature
        # (repro.check.config; scripts/check_plan.py runs the strict set)
        from repro.check.config import check_serve_config
        bad = check_serve_config(scfg, cfg, strict=False)
        if bad:
            raise ValueError("invalid ServeConfig:\n"
                             + "\n".join(f"  - {m}" for m in bad))
        if scfg.precision != "float":
            # PTQ the FFN stack once; the quantized tree rides along in
            # params["layers"] so the layer scan slices it like any weight.
            # w4a8: same tree, but nibble-packed QTensorW4 leaves
            from repro.models.blocks import quantize_mlp_params
            layers = dict(params["layers"])
            layers["qmlp"] = quantize_mlp_params(
                layers["mlp"], bits=4 if scfg.precision == "w4a8" else 8)
            params = dict(params, layers=layers)
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self.prefill = jax.jit(
            api.prefill_fn(cfg, scfg.max_len, attn_impl=scfg.attn_impl,
                           precision=scfg.precision))
        # donate the live cache so slot writes / decode rounds update it in
        # place instead of copying the whole KV budget (CPU backends don't
        # implement donation and would warn on every compile, so skip there)
        cpu = jax.default_backend() == "cpu"
        self.decode = jax.jit(api.decode_fn(cfg, precision=scfg.precision),
                              donate_argnums=() if cpu else (2,))
        if cfg.family != "encdec":
            self._write_slot = jax.jit(
                functools.partial(api.cache_write_slot, cfg),
                donate_argnums=() if cpu else (0,))
        if scfg.kv_layout == "paged":
            # page-granular cache surgery: scatter prefilled K/V into pool
            # pages, gather shared prefix pages back out, and the
            # suffix-only prefill that makes float-KV prefix hits cheap
            self._write_pages = jax.jit(
                functools.partial(api.paged_write_prompt, cfg),
                static_argnames=("src", "skip_blocks"),
                donate_argnums=() if cpu else (0,))
            self._write_kv = jax.jit(api.paged_write_kv,
                                     donate_argnums=() if cpu else (0,))
            self._gather_prefix = jax.jit(api.paged_gather_prefix)
            if scfg.prefix_cache and scfg.kv_cache == "float":
                self.prefill_suffix = jax.jit(api.prefill_suffix_fn(
                    cfg, attn_impl=scfg.attn_impl,
                    precision=scfg.precision))
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self._rng = np.random.default_rng(scfg.seed)
        # private registry: per-engine stats isolation; handles stay valid
        # across reset_stats (Registry.reset zeroes in place)
        self.metrics = obs_metrics.Registry()
        self._m = {
            "prefills": self.metrics.counter("serve.prefills"),
            "decode_steps": self.metrics.counter("serve.decode_steps"),
            "tokens_out": self.metrics.counter("serve.tokens_out"),
            "requests_done": self.metrics.counter("serve.requests_done"),
            "occupied": self.metrics.counter("serve.occupied_slot_rounds"),
            "decode_time": self.metrics.counter("serve.decode_time_s"),
            "ttft": self.metrics.histogram("serve.ttft_s"),
            "tpot": self.metrics.histogram("serve.tpot_s"),
            "queue_wait": self.metrics.histogram("serve.queue_wait_s"),
            # block-pool gauges: live under kv_layout="paged", zero under
            # contiguous (registered unconditionally for stats key parity)
            "blocks_in_use": self.metrics.gauge("serve.blocks_in_use"),
            "blocks_free": self.metrics.gauge("serve.blocks_free"),
            "prefix_hit_rate": self.metrics.gauge("serve.prefix_hit_rate"),
            # resilience counters (EXPERIMENTS.md §Resilience): terminal
            # statuses other than "ok", retry attempts, arena rebuilds
            "timeouts": self.metrics.counter("serve.timeouts"),
            "errors": self.metrics.counter("serve.errors"),
            "shed": self.metrics.counter("serve.shed"),
            "retries": self.metrics.counter("serve.retries"),
            "arena_rebuilds": self.metrics.counter("serve.arena_rebuilds"),
        }
        self.reset_stats()

    # ------------------------------------------------------------- metrics --

    def reset_stats(self):
        """Zero the counters (e.g. after a compile-warmup drain)."""
        self.metrics.reset()
        self._round = 0
        # uids whose logits an injected "corrupt" fault poisoned — silent
        # corruption is contained (recorded), not detected; the chaos
        # harness excludes these from the bit-identity comparison
        self.poisoned_uids: set = set()
        # the live BlockPool (paged runs only) — exposed so the chaos
        # harness can audit conservation after a drain
        self.pool: Optional[BlockPool] = None

    @property
    def stats(self) -> dict:
        """Counters + derived scheduler metrics (computed on access from the
        engine's registry). Key-compatible with the pre-registry dict
        (prefills/decode_steps/tokens_out/requests_done/occupancy/
        ttft_avg_s/decode_tok_s) plus the histogram quantiles."""
        m = self._m
        rounds = int(m["decode_steps"].value)
        c = dict(prefills=int(m["prefills"].value),
                 decode_steps=rounds,
                 tokens_out=int(m["tokens_out"].value),
                 requests_done=int(m["requests_done"].value))
        c["occupancy"] = (m["occupied"].value
                          / (rounds * self.scfg.max_batch)) if rounds else 0.0
        c["ttft_avg_s"] = m["ttft"].mean
        decode_time = m["decode_time"].value
        c["decode_tok_s"] = (c["tokens_out"] / decode_time
                             if decode_time > 0 else 0.0)
        c["ttft_p50_s"] = m["ttft"].percentile(50)
        c["ttft_p95_s"] = m["ttft"].percentile(95)
        c["ttft_p99_s"] = m["ttft"].percentile(99)
        c["tpot_avg_s"] = m["tpot"].mean
        c["queue_wait_avg_s"] = m["queue_wait"].mean
        c["queue_wait_p99_s"] = m["queue_wait"].percentile(99)
        c["blocks_in_use"] = int(m["blocks_in_use"].value)
        c["blocks_free"] = int(m["blocks_free"].value)
        c["prefix_hit_rate"] = float(m["prefix_hit_rate"].value)
        c["timeouts"] = int(m["timeouts"].value)
        c["errors"] = int(m["errors"].value)
        c["shed"] = int(m["shed"].value)
        c["retries"] = int(m["retries"].value)
        c["arena_rebuilds"] = int(m["arena_rebuilds"].value)
        return c

    def _update_pool_gauges(self, pool: BlockPool):
        self._m["blocks_in_use"].set(pool.in_use)
        self._m["blocks_free"].set(pool.free_pages)
        self._m["prefix_hit_rate"].set(
            pool.hits / pool.lookups if pool.lookups else 0.0)

    def _observe_retired(self, req: Request):
        """Latency histograms + the request's trace-lane replay (the spans
        are emitted at retirement from recorded perf_counter stamps, so
        overlapping requests land on separate, properly nested lanes)."""
        self._m["queue_wait"].observe(req.queue_wait_s)
        n_out = len(req.out_tokens)
        if n_out > 1 and req.finish_t > req.first_token_t:
            self._m["tpot"].observe(
                (req.finish_t - req.first_token_t) / (n_out - 1))
        tr = obs_trace.TRACER
        if tr.enabled:
            lane = obs_trace.next_lane()
            tr.begin("request", ts=req.submit_t, tid=lane, uid=req.uid,
                     prompt_len=int(len(req.prompt)), new_tokens=n_out,
                     submit_wall_t=req.submit_wall_t)
            tr.complete("queue_wait", req.submit_t, req.admit_t, tid=lane)
            tr.complete("prefill", req.admit_t, req.first_token_t, tid=lane)
            tr.complete("generate", req.first_token_t, req.finish_t, tid=lane,
                        tokens=n_out)
            tr.end("request", ts=req.finish_t, tid=lane)

    # ----------------------------------------------------------- frontend --

    def _validate_prompt_len(self, req: Request):
        """THE prompt-length check — submit and admit share it, so both
        reject with one message (they used to diverge)."""
        if len(req.prompt) > self.scfg.max_len:
            raise ValueError(
                f"request {req.uid}: prompt length {len(req.prompt)} exceeds "
                f"max_len={self.scfg.max_len}")

    def submit(self, req: Request):
        # reject oversized prompts here, not mid-drain: raising during
        # run_until_drained would discard finished requests and strand the
        # rest of the queue
        self._validate_prompt_len(req)
        req.submit_t = time.perf_counter()
        req.submit_wall_t = time.time()
        # load shedding: overload rejects at the door instead of growing the
        # queue unboundedly (the engine is single-threaded, so qsize is exact)
        mq = self.scfg.max_queue
        if mq is not None and self.queue.qsize() >= mq:
            self._m["shed"].inc()
            if self.scfg.shed_policy == "reject":
                raise QueueFullError(
                    f"request {req.uid}: queue holds max_queue={mq} "
                    f"requests (shed_policy='reject')")
            req.done = True             # "drop": terminal without enqueue
            req.status = "shed"
            req.finish_t = time.perf_counter()
            return
        self.queue.put(req)

    # ----------------------------------------------------------- deadlines --

    def _deadline_of(self, req: Request) -> Optional[float]:
        return (req.deadline_s if req.deadline_s is not None
                else self.scfg.deadline_s)

    def _expired(self, req: Request, now: Optional[float] = None) -> bool:
        d = self._deadline_of(req)
        if d is None:
            return False
        if now is None:
            now = time.perf_counter()
        return (now - req.submit_t) > d

    def _next_request(self) -> Optional[Request]:
        try:
            return self.queue.get_nowait()
        except queue.Empty:
            return None

    def _take_batch(self) -> List[Request]:
        # get_nowait, not .empty(): .empty() is only a racy hint once a
        # producer thread (or future async frontend) feeds the queue
        out: List[Request] = []
        while len(out) < self.scfg.max_batch:
            req = self._next_request()
            if req is None:
                break
            out.append(req)
        return out

    def run_until_drained(self) -> List[Request]:
        with obs_trace.span("engine.drain", scheduler=self.scfg.scheduler):
            if self.scfg.scheduler == "static":
                return self._run_static()
            return self._run_continuous()

    # ----------------------------------------------------------- sampling --

    def _pick(self, logits_row: np.ndarray, req: Request) -> int:
        temp = req.temperature
        if temp is None:
            temp = 0.0 if self.scfg.greedy else self.scfg.temperature
        if temp <= 0.0:
            return int(np.argmax(logits_row))
        z = np.asarray(logits_row, np.float64) / temp
        z -= z.max()
        p = np.exp(z)
        return int(self._rng.choice(p.size, p=p / p.sum()))

    def _effective_eos(self, req: Request) -> int:
        return self.scfg.eos_id if req.eos_id is None else req.eos_id

    # --------------------------------------------------------- continuous --

    def _bucket_len(self, plen: int) -> int:
        # oversized prompts were already rejected by _validate_prompt_len
        # (at submit, and again at admit for directly enqueued requests)
        if self.cfg.family in ("ssm", "hybrid"):
            return plen                 # recurrent state is position-exact
        b = max(self.scfg.prefill_bucket, 1)
        while b < plen:
            b *= 2
        return min(b, self.scfg.max_len)

    def _run_continuous(self) -> List[Request]:
        B = self.scfg.max_batch
        paged = self.scfg.kv_layout == "paged"
        bs = self.scfg.kv_block_size
        if paged:
            from repro.check.config import paged_num_blocks
            nblocks = paged_num_blocks(self.scfg)
            cache = api.init_paged_cache(self.cfg, B, nblocks, bs,
                                         self.scfg.max_len,
                                         kv=self.scfg.kv_cache)
            pool = BlockPool(nblocks, bs,
                             prefix_cache=self.scfg.prefix_cache)
            self.pool = pool            # audited by repro.faults.chaos
            table = np.zeros((B, self.scfg.max_len // bs), np.int32)
            slot_ids: List[List[int]] = [[] for _ in range(B)]
            slot_hashed = [0] * B       # leading refcounted pages per slot
            holdback: "collections.deque[Request]" = collections.deque()
            self._update_pool_gauges(pool)
        else:
            cache = api.init_slot_cache(self.cfg, B, self.scfg.max_len,
                                        kv=self.scfg.kv_cache)
        slots: List[Optional[Request]] = [None] * B
        admit_seq = [0] * B             # admission order, for victim choice
        seq = 0
        lens = [0] * B                  # host mirror of cache["len"]
        cur = np.zeros((B, 1), np.int32)
        finished: List[Request] = []

        def next_request() -> Optional[Request]:
            # holdback (pool-backpressured / preempted) drains before the
            # queue so paged admission stays FIFO
            if paged and holdback:
                return holdback.popleft()
            return self._next_request()

        def admit_paged(i: int, req: Request, plen: int):
            """Returns last-position logits, or None when the pool cannot
            supply the prompt's pages (admission backpressure). Exception-
            safe: any failure after pages were referenced/allocated rolls
            the pool back before re-raising, so a retried (or retired)
            admission never leaks pages."""
            nonlocal cache
            nb = -(-plen // bs)         # pages covering positions [0, plen)
            keys = pool.prefix_keys(req.prompt)
            with obs_trace.span("engine.prefix_lookup", uid=req.uid,
                                blocks=len(keys)):
                hit_ids = pool.lookup(keys)
            n_hit = len(hit_ids)
            # reference the hit pages BEFORE alloc so its eviction scan
            # cannot reclaim them out from under this admission
            pool.acquire(hit_ids)
            try:
                with obs_trace.span("engine.block_alloc", uid=req.uid,
                                    n=nb - n_hit):
                    fresh = pool.alloc(nb - n_hit)
            except Exception:
                pool.release(hit_ids)   # injected blockpool.alloc fault
                raise
            if fresh is None:
                pool.release(hit_ids)
                return None
            req.admit_t = time.perf_counter()
            ids = hit_ids + fresh
            try:
                return _admit_paged_prefill(i, req, plen, keys, hit_ids,
                                            fresh, ids, nb)
            except Exception:
                pool.release(hit_ids)
                pool.free(fresh)        # unpublished: straight back
                self._update_pool_gauges(pool)
                raise

        def _admit_paged_prefill(i, req, plen, keys, hit_ids, fresh, ids,
                                 nb):
            nonlocal cache
            n_hit = len(hit_ids)
            fids = np.asarray(fresh, np.int32)
            if n_hit and "k_scale" not in cache:
                # float-KV prefix hit: the shared pages already hold the
                # prefix K/V — gather them and run ONLY the suffix (the
                # near-zero-TTFT path)
                pfx = n_hit * bs
                s_sfx = plen - pfx
                sbucket = self._bucket_len(s_sfx)
                toks = np.zeros((sbucket,), np.int32)
                toks[:s_sfx] = req.prompt[pfx:]
                with obs_trace.span("engine.prefill", uid=req.uid, slot=i,
                                    plen=plen, bucket=sbucket,
                                    prefix_hit=pfx):
                    pk, pv = self._gather_prefix(
                        cache, np.asarray(hit_ids, np.int32))
                    logits, ks, vs = self.prefill_suffix(self.params, {
                        "tokens": jnp.asarray(toks[None, :]),
                        "prefix_k": pk, "prefix_v": pv,
                        "suffix_lens": jnp.asarray([s_sfx], jnp.int32)})
                    self._m["prefills"].inc()
                    cache = self._write_kv(cache, ks, vs, fids)
                    logits = np.asarray(logits)
            else:
                # prefix miss — or an int8-KV hit, which shares STORAGE
                # only: dequantized codes are not the float prefix the
                # suffix math needs, so recompute the whole prompt and
                # just skip writing the shared pages
                bucket = self._bucket_len(plen)
                toks = np.zeros((bucket,), np.int32)
                toks[:plen] = req.prompt
                with obs_trace.span("engine.prefill", uid=req.uid, slot=i,
                                    plen=plen, bucket=bucket,
                                    prefix_hit=n_hit * bs):
                    logits, fresh_cache = self.prefill(self.params, {
                        "tokens": jnp.asarray(toks[None, :]),
                        "prompt_lens": jnp.asarray([plen], jnp.int32)})
                    self._m["prefills"].inc()
                    cache = self._write_pages(cache, fresh_cache, fids,
                                              skip_blocks=n_hit)
                    logits = np.asarray(logits)
            # publish-at-admission: the fresh full prompt pages now hold
            # their final K/V (decode writes land strictly past them)
            pool.publish(keys[n_hit:], ids[n_hit:len(keys)])
            slot_ids[i] = ids
            slot_hashed[i] = len(keys)
            table[i, :nb] = ids
            table[i, nb:] = 0
            self._update_pool_gauges(pool)
            return logits

        def try_admit(i: int, req: Request) -> str:
            """Admit ``req`` into free slot ``i``. Returns "ok", "full"
            (paged pool backpressure — park in the holdback), or "failed"
            (the admission survived max_retries and the request was
            retired with status="error"). The ``engine.prefill`` fault
            seam fires once per attempt, BEFORE any device call or pool
            mutation, so an injected raise is always retry-safe."""
            nonlocal cache, seq
            self._validate_prompt_len(req)   # directly enqueued requests
            plen = len(req.prompt)
            last_err: Optional[BaseException] = None
            for attempt in range(self.scfg.max_retries + 1):
                if attempt:
                    self._m["retries"].inc()
                    if self.scfg.retry_backoff_s > 0:
                        time.sleep(self.scfg.retry_backoff_s
                                   * (2 ** (attempt - 1)))
                try:
                    fired = faults.check("engine.prefill")
                    if paged:
                        logits = admit_paged(i, req, plen)
                        if logits is None:
                            return "full"
                    else:
                        bucket = self._bucket_len(plen)
                        req.admit_t = time.perf_counter()
                        toks = np.zeros((bucket,), np.int32)
                        toks[:plen] = req.prompt  # right-pad: 0..plen-1
                        with obs_trace.span("engine.prefill", uid=req.uid,
                                            slot=i, plen=plen,
                                            bucket=bucket):
                            logits, fresh = self.prefill(self.params, {
                                "tokens": jnp.asarray(toks[None, :]),
                                "prompt_lens": jnp.asarray([plen],
                                                           jnp.int32)})
                            self._m["prefills"].inc()
                            cache = self._write_slot(cache, fresh,
                                                     jnp.int32(i))
                            logits = np.asarray(logits)
                except faults.InjectedFault as e:
                    last_err = e        # fired pre-dispatch: retry is safe
                    continue
                except Exception as e:
                    last_err = e        # real failure: state may be gone
                    break               # (donated buffers) — do not retry
                if fired is not None:   # corrupt directive: poison the
                    logits = fired.apply(logits)   # sampled logits only
                    self.poisoned_uids.add(req.uid)
                t = self._pick(logits[0, -1], req)
                req.first_token_t = time.perf_counter()
                req.admit_round = self._round
                req.out_tokens.append(t)
                self._m["tokens_out"].inc()
                self._m["ttft"].observe(req.ttft_s)
                cur[i, 0] = t
                slots[i] = req
                lens[i] = plen
                admit_seq[i] = seq
                seq += 1
                return "ok"
            retire_unadmitted(req, "error", repr(last_err))
            return "failed"

        def retire_unadmitted(req: Request, status: str,
                              err: Optional[str] = None):
            """Terminal bookkeeping for a request that never held a slot
            (queue/holdback deadline expiry, failed admission)."""
            now = time.perf_counter()
            req.done = True
            req.status = status
            req.error = err
            if req.admit_t == 0.0:
                req.admit_t = now
            if req.first_token_t == 0.0:
                req.first_token_t = now
            req.finish_t = now
            req.finish_round = self._round
            finished.append(req)
            self._m["requests_done"].inc()
            self._m["timeouts" if status == "timeout" else "errors"].inc()
            self._observe_retired(req)

        def retire_slot(i: int, status: str = "ok",
                        err: Optional[str] = None):
            """Retire slot ``i``'s request with terminal ``status`` and
            reclaim its KV slot + pool pages — THE slot-release path, so
            ok/timeout/error retirement can never diverge on cleanup."""
            nonlocal cache
            req = slots[i]
            req.done = True
            req.status = status
            if err is not None:
                req.error = err
            req.finish_t = time.perf_counter()
            req.finish_round = self._round
            finished.append(req)
            self._m["requests_done"].inc()
            if status == "timeout":
                self._m["timeouts"].inc()
            elif status == "error":
                self._m["errors"].inc()
            self._observe_retired(req)
            slots[i] = None
            lens[i] = 0
            if paged:
                with obs_trace.span("engine.block_free", uid=req.uid,
                                    n=len(slot_ids[i])):
                    pool.free(slot_ids[i], hashed=slot_hashed[i])
                slot_ids[i] = []
                slot_hashed[i] = 0
                table[i, :] = 0
                self._update_pool_gauges(pool)
            cache = api.cache_free_slot(cache, i)

        def maybe_retire(i: int):
            req = slots[i]
            full = lens[i] >= self.scfg.max_len
            if (req.out_tokens[-1] == self._effective_eos(req)
                    or len(req.out_tokens) >= req.max_new_tokens or full):
                retire_slot(i, "ok")

        def preempt(victim: int):
            """Evict the youngest slot mid-decode to free its pages. Its
            request restarts from the prompt via the holdback — greedy
            decode replays the identical stream (and its published prompt
            pages usually survive as evictable, so the re-prefill hits)."""
            nonlocal cache
            req = slots[victim]
            with obs_trace.span("engine.block_free", uid=req.uid,
                                n=len(slot_ids[victim]), preempt=True):
                pool.free(slot_ids[victim], hashed=slot_hashed[victim])
            # the discarded tokens stay in tokens_out (they were real decode
            # work); the replay after re-admission counts its own
            req.out_tokens = []
            req.done = False
            holdback.appendleft(req)
            slots[victim] = None
            lens[victim] = 0
            slot_ids[victim] = []
            slot_hashed[victim] = 0
            table[victim, :] = 0
            cache = api.cache_free_slot(cache, victim)
            self._update_pool_gauges(pool)

        def pool_alloc(n: int) -> Optional[List[int]]:
            """``pool.alloc`` with the injected-fault seam absorbed: an
            InjectedFault degrades to a transient shortage (None), which
            the callers already handle via backpressure/preemption — so a
            blockpool.alloc fault can never escape mid-decode."""
            try:
                return pool.alloc(n)
            except faults.InjectedFault:
                self._m["retries"].inc()
                return None

        def grow_tables():
            """Allocate the next page for every slot whose write position
            reached a page boundary; under pool pressure preempt youngest-
            admitted slots (oldest-first processing guarantees progress —
            a lone grower can always reclaim evictable pages)."""
            order = sorted((i for i in range(B) if slots[i] is not None),
                           key=lambda i: admit_seq[i])
            for i in order:
                if slots[i] is None:        # preempted by an older grower
                    continue
                pos = lens[i]
                if pos >= self.scfg.max_len or pos % bs \
                        or pos // bs < len(slot_ids[i]):
                    continue
                with obs_trace.span("engine.block_alloc",
                                    uid=slots[i].uid, n=1):
                    got = pool_alloc(1)
                while got is None:
                    victim = max((v for v in range(B)
                                  if slots[v] is not None),
                                 key=lambda v: admit_seq[v])
                    preempt(victim)
                    if victim == i:
                        break
                    got = pool_alloc(1)
                if slots[i] is None or got is None:
                    continue
                slot_ids[i].append(got[0])
                table[i, pos // bs] = got[0]
            self._update_pool_gauges(pool)

        def rebuild_arena():
            """Fresh KV arena after an unrecoverable decode failure: the
            decode jit donates the cache on accelerator backends, so the
            old buffers must be assumed dead. The paged pool restarts
            empty too — its prefix digests would otherwise resolve to
            pages of the reset arena."""
            nonlocal cache, pool
            self._m["arena_rebuilds"].inc()
            if paged:
                cache = api.init_paged_cache(self.cfg, B, nblocks, bs,
                                             self.scfg.max_len,
                                             kv=self.scfg.kv_cache)
                pool = BlockPool(nblocks, bs,
                                 prefix_cache=self.scfg.prefix_cache)
                self.pool = pool
                table[:] = 0
                for i in range(B):
                    slot_ids[i] = []
                    slot_hashed[i] = 0
                self._update_pool_gauges(pool)
            else:
                cache = api.init_slot_cache(self.cfg, B, self.scfg.max_len,
                                            kv=self.scfg.kv_cache)

        stalls = 0                      # consecutive can't-admit iterations
        decode_failures = 0             # consecutive failed round attempts
        while True:
            # refill free slots from the queue between decode rounds; the
            # inner while re-admits into a slot whose request retired at
            # admission (max_new_tokens=1 / instant EOS / failed / already
            # past deadline). A paged admission the pool cannot back parks
            # its request in the FIFO holdback and stops refilling until
            # retirements release pages.
            blocked = False
            for i in range(B):
                while slots[i] is None and not blocked:
                    req = next_request()
                    if req is None:
                        break
                    if self._expired(req):
                        # expired while queued/parked: never admit — the
                        # prefill would be wasted work past the budget
                        retire_unadmitted(req, "timeout")
                        continue
                    res = try_admit(i, req)
                    if res == "full":
                        holdback.appendleft(req)
                        blocked = True
                    elif res == "ok":
                        maybe_retire(i)
                    # "failed": retired inside try_admit — keep refilling
                if blocked:
                    break
            active = [i for i in range(B) if slots[i] is not None]
            if not active:
                if paged and holdback:
                    # can't-admit stall: nothing active to retire and the
                    # holdback head still does not fit. Give retirements
                    # max_retries+1 iterations to change the picture, then
                    # retire the head as "error" instead of deadlocking or
                    # killing the engine (the old RuntimeError) — the
                    # message keeps the kv_num_blocks diagnosis.
                    stalls += 1
                    if stalls > self.scfg.max_retries:
                        retire_unadmitted(
                            holdback.popleft(), "error",
                            "paged KV pool cannot admit this request even "
                            "with every page reclaimable — kv_num_blocks "
                            "is below its worst-case page need")
                        stalls = 0
                    continue
                break                   # the admit loop drained the queue
            stalls = 0
            if paged:
                grow_tables()
                active = [i for i in range(B) if slots[i] is not None]
                if not active:
                    continue            # preemption emptied the batch
                cache["len"] = jnp.asarray(np.asarray(lens, np.int32))
                cache["block_table"] = jnp.asarray(table)
            try:
                # the seam fires BEFORE the device call (retrying an
                # injected fault is safe: the donated cache is untouched)
                round_fired = faults.check("engine.decode_round")
                t0 = time.perf_counter()
                with obs_trace.span("engine.decode_round",
                                    round=self._round,
                                    active=len(active)):
                    logits, cache = self.decode(self.params,
                                                jnp.asarray(cur), cache)
                    # block on BOTH outputs before stopping the timer:
                    # asarray alone would sync the logits but leave the
                    # cache update in flight, skewing decode_tok_s by JAX
                    # async dispatch
                    jax.block_until_ready((logits, cache))
                self._m["decode_time"].inc(time.perf_counter() - t0)
            except Exception as e:
                retriable = isinstance(e, faults.InjectedFault)
                decode_failures += 1
                if retriable and decode_failures <= self.scfg.max_retries:
                    self._m["retries"].inc()
                    if self.scfg.retry_backoff_s > 0:
                        time.sleep(self.scfg.retry_backoff_s
                                   * (2 ** (decode_failures - 1)))
                    continue
                # unrecoverable round: the batch shares one donated cache,
                # so per-request attribution is impossible — retire the
                # whole active set as "error" and rebuild the arena, then
                # keep draining the queue against the fresh one
                for i in active:
                    retire_slot(i, "error", repr(e))
                rebuild_arena()
                decode_failures = 0
                continue
            decode_failures = 0
            logits = np.asarray(logits)
            if round_fired is not None:
                # corrupt directive: poison this round's host logits; every
                # active request sampled from them is contained, not fixed
                logits = round_fired.apply(logits)
                for i in active:
                    self.poisoned_uids.add(slots[i].uid)
            self._round += 1
            self._m["decode_steps"].inc()
            self._m["occupied"].inc(len(active))
            now_r = time.perf_counter()
            for i in active:
                lens[i] += 1            # this round wrote K/V at lens[i]
                req = slots[i]
                t = self._pick(logits[i, -1], req)
                req.out_tokens.append(t)
                self._m["tokens_out"].inc()
                cur[i, 0] = t
                maybe_retire(i)
                if slots[i] is not None and self._expired(req, now_r):
                    retire_slot(i, "timeout")   # round-boundary cancel
            # decode advanced every row's length, including retired/empty
            # slots; re-zero them so dead rows can never drift past max_len
            cache["len"] = jnp.asarray(np.asarray(lens, np.int32))
        return finished

    # ------------------------------------------------------------- static --

    def _run_static(self) -> List[Request]:
        finished: List[Request] = []
        while True:
            batch = self._take_batch()
            if not batch:
                break
            finished.extend(self._run_batch(batch))
        return finished

    def _retry_call(self, site: str, fn):
        """Run ``fn`` behind fault-site ``site`` with bounded retries.
        Returns ``(result, fired, err)``: on success err is None and fired
        is the corrupt directive (if one fired); after exhausting
        ``max_retries`` (InjectedFault only — a real exception may have
        consumed donated buffers, so it never retries) result is None and
        err carries the absorbed exception."""
        last_err: Optional[BaseException] = None
        for attempt in range(self.scfg.max_retries + 1):
            if attempt:
                self._m["retries"].inc()
                if self.scfg.retry_backoff_s > 0:
                    time.sleep(self.scfg.retry_backoff_s
                               * (2 ** (attempt - 1)))
            try:
                fired = faults.check(site)
                return fn(), fired, None
            except faults.InjectedFault as e:
                last_err = e
                continue
            except Exception as e:
                last_err = e
                break
        return None, None, last_err

    def _run_batch(self, reqs: List[Request]) -> List[Request]:
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt      # left-pad
        now = time.perf_counter()
        for r in reqs:
            r.admit_t = now

        def do_prefill():
            with obs_trace.span("engine.prefill", batch=b, plen=plen):
                logits, cache = self.prefill(self.params,
                                             {"tokens": jnp.asarray(toks)})
                self._m["prefills"].inc()
                return np.asarray(logits), cache

        got, fired, err = self._retry_call("engine.prefill", do_prefill)
        if err is not None:
            # the static batch shares one prefill: retire it whole — the
            # next _take_batch keeps draining the queue
            for r in reqs:
                r.status = "error"
                r.error = repr(err)
                r.done = True
                self._m["errors"].inc()
            return self._finish_batch(reqs)
        lg, cache = got
        if fired is not None:
            lg = fired.apply(lg)
            self.poisoned_uids.update(r.uid for r in reqs)
        cur = np.zeros((b, 1), np.int32)
        now = time.perf_counter()
        for i, r in enumerate(reqs):
            t = self._pick(lg[i, -1], r)
            r.first_token_t = now
            self._m["ttft"].observe(r.ttft_s)
            r.out_tokens.append(t)
            self._m["tokens_out"].inc()
            cur[i, 0] = t
            if t == self._effective_eos(r) or r.max_new_tokens <= 1:
                r.done = True
        steps = max(r.max_new_tokens for r in reqs) - 1
        for _ in range(max(steps, 0)):
            now = time.perf_counter()
            for r in reqs:
                if not r.done and self._expired(r, now):
                    r.done = True       # round-boundary cancellation
                    r.status = "timeout"
                    self._m["timeouts"].inc()
            if all(r.done for r in reqs):
                break

            def do_round():
                t0 = time.perf_counter()
                with obs_trace.span("engine.decode_round",
                                    round=self._round,
                                    active=sum(not r.done for r in reqs)):
                    logits, new_cache = self.decode(
                        self.params, jnp.asarray(cur), cache)
                    # sync logits AND cache before stopping the timer (see
                    # the continuous path): decode_tok_s must be device
                    # time
                    jax.block_until_ready((logits, new_cache))
                self._m["decode_time"].inc(time.perf_counter() - t0)
                return np.asarray(logits), new_cache

            got, fired, err = self._retry_call("engine.decode_round",
                                               do_round)
            if err is not None:
                for r in reqs:          # one shared (donated) cache: no
                    if not r.done:      # per-request attribution possible
                        r.status = "error"
                        r.error = repr(err)
                        r.done = True
                        self._m["errors"].inc()
                break
            lg, cache = got
            if fired is not None:
                lg = fired.apply(lg)
                self.poisoned_uids.update(
                    r.uid for r in reqs if not r.done)
            self._round += 1
            self._m["decode_steps"].inc()
            for i, r in enumerate(reqs):
                if r.done:
                    continue
                self._m["occupied"].inc()
                t = self._pick(lg[i, -1], r)
                r.out_tokens.append(t)
                self._m["tokens_out"].inc()
                cur[i, 0] = t
                if (t == self._effective_eos(r)
                        or len(r.out_tokens) >= r.max_new_tokens):
                    r.done = True
        return self._finish_batch(reqs)

    def _finish_batch(self, reqs: List[Request]) -> List[Request]:
        now = time.perf_counter()
        for r in reqs:
            r.done = True
            if r.status == "pending":
                r.status = "ok"
            if r.first_token_t == 0.0:  # batch failed before first token
                r.first_token_t = now
            r.finish_t = now
            r.finish_round = self._round
            self._m["requests_done"].inc()
            self._observe_retired(r)
        return reqs
