"""Serving: continuous-batching engine over a fixed (max_batch, max_len)
KV budget, with the legacy static drain scheduler as baseline. See
engine.Engine / EXPERIMENTS.md §Serving."""
from .engine import Engine, Request, ServeConfig

__all__ = ["Engine", "Request", "ServeConfig"]
