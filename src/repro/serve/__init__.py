"""Serving: continuous-batching LM engine over a fixed (max_batch, max_len)
KV budget (legacy static drain scheduler as baseline; engine.Engine /
EXPERIMENTS.md §Serving) with contiguous or paged KV backing — the paged
layout pools fixed-size pages with hash-based prefix reuse
(engine.BlockPool / EXPERIMENTS.md §Paged-KV) — plus the CNN microbatching
engine that admits queued image requests into batched CompiledPlan rounds
(cnn.CNNEngine / EXPERIMENTS.md §Throughput). Both engines degrade
instead of dying under faults — every request ends in a terminal status
(ok | timeout | error | shed), with load shedding raising QueueFullError
under shed_policy="reject" (repro.faults / EXPERIMENTS.md §Resilience)."""
from .cnn import CNNEngine, CNNServeConfig, ImageRequest
from .engine import (BlockPool, Engine, QueueFullError, Request,
                     ServeConfig)

__all__ = ["BlockPool", "Engine", "QueueFullError", "Request",
           "ServeConfig", "CNNEngine", "CNNServeConfig", "ImageRequest"]
