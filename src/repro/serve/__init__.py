"""Serving: continuous-batching LM engine over a fixed (max_batch, max_len)
KV budget (legacy static drain scheduler as baseline; engine.Engine /
EXPERIMENTS.md §Serving), plus the CNN microbatching engine that admits
queued image requests into batched CompiledPlan rounds (cnn.CNNEngine /
EXPERIMENTS.md §Throughput)."""
from .cnn import CNNEngine, CNNServeConfig, ImageRequest
from .engine import Engine, Request, ServeConfig

__all__ = ["Engine", "Request", "ServeConfig",
           "CNNEngine", "CNNServeConfig", "ImageRequest"]
