"""Serving: continuous-batching LM engine over a fixed (max_batch, max_len)
KV budget (legacy static drain scheduler as baseline; engine.Engine /
EXPERIMENTS.md §Serving) with contiguous or paged KV backing — the paged
layout pools fixed-size pages with hash-based prefix reuse
(engine.BlockPool / EXPERIMENTS.md §Paged-KV) — plus the CNN microbatching
engine that admits queued image requests into batched CompiledPlan rounds
(cnn.CNNEngine / EXPERIMENTS.md §Throughput)."""
from .cnn import CNNEngine, CNNServeConfig, ImageRequest
from .engine import BlockPool, Engine, Request, ServeConfig

__all__ = ["BlockPool", "Engine", "Request", "ServeConfig",
           "CNNEngine", "CNNServeConfig", "ImageRequest"]
