"""Post-SPMD HLO analysis with while-loop trip-count multipliers.

``compiled.cost_analysis()`` counts each while body ONCE (verified on this
backend), so scanned-layer models would be undercounted by n_layers. This
module re-walks the HLO text: it splits computations, resolves operand
shapes through a per-computation symbol table, builds the call graph
(while bodies weighted by ``known_trip_count``) and accumulates per-device

  * dot_flops          — 2 * prod(result dims) * prod(lhs contracting dims)
  * collective_bytes   — operand bytes of all-gather / all-reduce /
                         reduce-scatter / all-to-all / collective-permute,
                         split ICI vs DCN by whether a replica group crosses
                         the pod boundary (device_id // pod_size differs)
  * out_bytes          — Σ op output bytes (HBM-traffic proxy)

Everything is parsed from ``compiled.as_text()``; nothing is allocated.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(([^)]*)\)")


def _elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shapes_bytes(seg: str) -> float:
    return float(sum(_elems(dims) * _DTYPE_BYTES.get(dt, 4)
                     for dt, dims in _SHAPE_RE.findall(seg)))


def _iota_groups(spec: str):
    """Parse v2 iota replica groups: [G,S]<=[dims]T(perm) -> (G,S) ids."""
    m = re.match(r"\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?", spec)
    if not m:
        return None
    g, s = int(m.group(1)), int(m.group(2))
    dims = [int(x) for x in m.group(3).split(",")]
    ids = np.arange(int(np.prod(dims))).reshape(dims)
    if m.group(4):
        ids = ids.transpose([int(x) for x in m.group(4).split(",")])
    return ids.reshape(g, s)


def _crosses_pod(line: str, pod_size: int) -> bool:
    if pod_size <= 0:
        return False
    m = re.search(r"replica_groups=(\{\{[0-9,{} ]*\}\}|"
                  r"\[[0-9,]+\]<=\[[0-9,]+\](?:T\([0-9,]+\))?)", line)
    if not m:
        return False
    spec = m.group(1)
    if spec.startswith("{{"):
        for grp in re.findall(r"\{([0-9,]+)\}", spec):
            ids = [int(x) for x in grp.split(",")]
            if len({i // pod_size for i in ids}) > 1:
                return True
        return False
    groups = _iota_groups(spec)
    if groups is None:
        return False
    pods = groups // pod_size
    return bool(np.any(pods.max(axis=1) != pods.min(axis=1)))


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    coll_ici: float = 0.0
    coll_dcn: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    out_bytes: float = 0.0
    calls: list = dataclasses.field(default_factory=list)


def analyze_hlo(txt: str, *, pod_size: int = 0) -> dict:
    # ---- split into computations -----------------------------------------
    comps: dict[str, list[str]] = {}
    headers: dict[str, str] = {}
    cur, buf = None, []
    for line in txt.splitlines():
        m = _HDR_RE.match(line)
        if m and "->" in line:
            if cur:
                comps[cur] = buf
            cur, buf = m.group(2), []
            headers[cur] = line
        elif line.strip() == "}":
            if cur:
                comps[cur] = buf
                cur, buf = None, []
        elif cur is not None:
            buf.append(line)
    if cur:
        comps[cur] = buf

    stats: dict[str, CompStats] = {}
    for name, lines in comps.items():
        st = CompStats()
        # symbol table: op name -> result type string (first shapes on rhs)
        sym: dict[str, str] = {}
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            opname, rhs = dm.group(1), dm.group(2)
            tmatch = re.match(r"^(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)",
                              rhs)
            if tmatch:
                sym[opname] = tmatch.group(1)
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rhs = dm.group(2)
            ttype = sym.get(dm.group(1), "")
            st.out_bytes += _shapes_bytes(ttype)
            # dot flops: 2 * result elems * contracted size (from lhs shape)
            if re.search(r"\bdot\(", rhs):
                dmatch = re.search(r"\bdot\(([^)]*)\)", rhs)
                res_elems = sum(_elems(d) for _, d in _SHAPE_RE.findall(ttype))
                contract = 1
                opnds = [o.strip().lstrip("%") for o in dmatch.group(1).split(",")]
                lhs_type = sym.get(opnds[0], "") if opnds else ""
                lhs_shapes = _SHAPE_RE.findall(lhs_type)
                lhs_dims = [int(x) for x in lhs_shapes[0][1].split(",")] \
                    if lhs_shapes and lhs_shapes[0][1] else []
                mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
                if mc and mc.group(1):
                    for i in mc.group(1).split(","):
                        if int(i) < len(lhs_dims):
                            contract *= lhs_dims[int(i)]
                st.dot_flops += 2.0 * res_elems * contract
            elif re.search(r"\bconvolution\(", rhs):
                cm = re.search(r"\bconvolution\(([^)]*)\)", rhs)
                res_elems = sum(_elems(d) for _, d in _SHAPE_RE.findall(ttype))
                opnds = [o.strip().lstrip("%") for o in cm.group(1).split(",")]
                k_type = sym.get(opnds[1], "") if len(opnds) > 1 else ""
                ks = _SHAPE_RE.findall(k_type)
                k_elems = _elems(ks[0][1]) if ks else 1
                res_dims = _SHAPE_RE.findall(ttype)
                out_feat = int(res_dims[0][1].split(",")[-1]) \
                    if res_dims and res_dims[0][1] else 1
                st.dot_flops += 2.0 * res_elems * k_elems / max(out_feat, 1)
            # collectives
            mcoll = _COLL_RE.search(rhs)
            if mcoll and not rhs.lstrip().startswith(("all-reduce-done",
                                                      "all-gather-done",
                                                      "collective-permute-done")):
                kind = mcoll.group(1)
                nbytes = 0.0
                for o in mcoll.group(3).split(","):
                    o = o.strip().lstrip("%")
                    nbytes += _shapes_bytes(sym.get(o, ""))
                if _crosses_pod(rhs, pod_size):
                    st.coll_dcn += nbytes
                else:
                    st.coll_ici += nbytes
                st.coll_by_kind[kind] = st.coll_by_kind.get(kind, 0.0) + nbytes
            # call-graph edges
            if re.search(r"\bwhile\(", rhs):
                trip = 1
                mt = re.search(r'known_trip_count[^}]*?"n":"(\d+)"', rhs)
                if mt:
                    trip = int(mt.group(1))
                mb = re.search(r"body=%?([\w.\-]+)", rhs)
                mc2 = re.search(r"condition=%?([\w.\-]+)", rhs)
                if mb:
                    st.calls.append((mb.group(1), trip))
                if mc2:
                    st.calls.append((mc2.group(1), trip + 1))
            else:
                for attr in ("to_apply", "called_computations", "true_computation",
                             "false_computation", "branch_computations", "calls"):
                    for mm in re.finditer(r"\b" + attr + r"=\{?%?([\w.\-]+)", rhs):
                        st.calls.append((mm.group(1), 1))
        stats[name] = st

    # ---- multiplier propagation (Kahn toposort over the call DAG) --------
    called = {c for st in stats.values() for c, _ in st.calls}
    roots = [n for n in stats if n not in called]
    indeg = {n: 0 for n in stats}
    for st in stats.values():
        for c, _ in st.calls:
            if c in indeg:
                indeg[c] += 1
    mult = {n: 0.0 for n in stats}
    for r in roots:
        mult[r] = 1.0
    queue = [n for n in stats if indeg[n] == 0]
    visited = 0
    while queue:
        name = queue.pop()
        visited += 1
        for callee, k in stats[name].calls:
            if callee in indeg:
                mult[callee] += mult[name] * k
                indeg[callee] -= 1
                if indeg[callee] == 0:
                    queue.append(callee)
    # any cycle remnants (shouldn't exist in HLO) keep multiplier 0

    # CPU-backend artifact accounting: XLA-CPU lowers bf16 dots by upcasting
    # operands to f32; LICM hoists whole-tensor f32 copies of loop-invariant
    # (weight/residual) operands to the top level. A TPU (native-bf16 MXU)
    # never materializes these. Sum big top-level bf16->f32 same-shape
    # converts so the dry-run can report a TPU-corrected peak.
    upcast = 0.0
    roots_set = set(roots)
    for name in roots_set:
        lines = comps.get(name, [])
        sym: dict[str, str] = {}
        # ENTRY parameters are typed in the computation header
        for pname, ptype in re.findall(r"%?([\w.\-]+):\s*([a-z0-9]+\[[0-9,]*\])",
                                       headers.get(name, "")):
            sym[pname] = ptype
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                t = re.match(r"^(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)",
                             dm.group(2))
                if t:
                    sym[dm.group(1)] = t.group(1)
        seen_src = set()
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rhs = dm.group(2)
            mm = re.match(r"^f32\[([0-9,]+)\][^ ]* (?:convert|fusion)\(%?([\w.\-]+)\)",
                          rhs)
            if mm:
                dims = mm.group(1)
                nbytes = _elems(dims) * 4
                opnd_t = sym.get(mm.group(2), "")
                # dedupe per source tensor: buffer assignment reuses the
                # converted copy; counting every mention would overstate
                if nbytes >= 2 ** 26 and f"bf16[{dims}]" in opnd_t \
                        and mm.group(2) not in seen_src:
                    seen_src.add(mm.group(2))
                    upcast += nbytes

    agg = dict(dot_flops=0.0, coll_bytes_ici=0.0, coll_bytes_dcn=0.0,
               out_bytes=0.0, coll_by_kind={}, n_computations=len(stats),
               cpu_upcast_bytes=upcast)
    for name, st in stats.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        agg["dot_flops"] += m * st.dot_flops
        agg["coll_bytes_ici"] += m * st.coll_ici
        agg["coll_bytes_dcn"] += m * st.coll_dcn
        agg["out_bytes"] += m * st.out_bytes
        for k, v in st.coll_by_kind.items():
            agg["coll_by_kind"][k] = agg["coll_by_kind"].get(k, 0.0) + m * v
    return agg
