from .hlo import analyze_hlo
