import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init). Everything below assumes 512 host devices.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces a JSON artifact with:
  * memory_analysis (per-device argument/output/temp bytes)
  * cost_analysis flops/bytes (per-device, single-while-iteration counts)
  * trip-count-corrected HLO walk: dot FLOPs, output bytes, collective
    bytes (ICI vs DCN, by kind)   -> §Roofline inputs
  * the sharding rules used

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, SHAPES, cell_supported, get_config
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.optim import OptConfig
from repro.parallel.sharding import (ShardingRules, make_rules,
                                     prune_batch_axes, tree_shardings,
                                     use_rules)
from repro.roofline.hlo import analyze_hlo
from repro.train.train_step import TrainConfig, estimate_model_flops, make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")


def _serve_param_structs(cfg, dtype=jnp.bfloat16):
    tree = api.param_structs(cfg)
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype),
        tree)


def _opt_structs(params, opt: OptConfig):
    sdt = jnp.dtype(opt.state_dtype) if opt.state_dtype else None
    z = lambda s: jax.ShapeDtypeStruct(s.shape, sdt or s.dtype)
    return {"m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def arch_train_overrides(arch: str) -> dict:
    """Per-arch memory knobs (sized in DESIGN.md §5):

    * arctic-480b cannot hold f32 AdamW on 256 chips -> params + m/v bf16
      (8-bit-optimizer-class tradeoff).
    * microbatches: layer-scan residuals are L x B_loc x S x d bf16 per
      device; archs where that exceeds the HBM budget accumulate gradients
      over microbatches (residuals scale with B_loc/microbatch).
    """
    mb = {"arctic-480b": 8, "qwen1.5-32b": 8, "granite-34b": 8,
          "jamba-v0.1-52b": 8, "falcon-mamba-7b": 4, "granite-3-2b": 4,
          "seamless-m4t-large-v2": 4}
    out = {"microbatches": mb.get(arch, 1)}
    if os.environ.get("REPRO_MICROBATCHES"):
        out["microbatches"] = int(os.environ["REPRO_MICROBATCHES"])
    if arch == "arctic-480b":
        out.update(param_dtype="bfloat16", opt_state_dtype="bfloat16")
    return out


def needs_2d_serve_sharding(cfg) -> bool:
    """bf16 weights must fit well under one chip's HBM after TP. Archs whose
    attention cannot TP over 16 heads (n_heads % 16 != 0) keep those weights
    replicated across "model", so the threshold is on the unsharded bytes."""
    if cfg.n_heads % 16:
        return cfg.param_count() * 2 > 8e9     # replicated-attention archs
    return cfg.param_count() * 2 / 16 > 8e9


def build_cell(arch: str, shape_name: str, mesh, rules_override=None):
    """Returns (fn, example_args, in_shardings, donate) for the cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    over = arch_train_overrides(arch)
    if shape.kind == "train" and "param_dtype" in over:
        cfg = dataclasses.replace(cfg, param_dtype=over["param_dtype"])

    kind = shape.kind
    sp = kind in ("decode", "prefill")
    fsdp = kind == "train" or (kind != "train" and needs_2d_serve_sharding(cfg))
    shard_res = False
    if kind == "train":
        dp = 1
        for a in ("pod", "data"):
            dp *= mesh.shape.get(a, 1)
        mb = over.get("microbatches", 1)
        b_loc = max(shape.global_batch // (dp * mb), 1)
        layers = cfg.n_layers if cfg.family != "encdec" \
            else cfg.n_layers + cfg.n_encoder_layers
        resid = layers * b_loc * shape.seq_len * cfg.d_model * 6  # f32+bf16
        shard_res = resid > 4 * 2 ** 30
        if os.environ.get("REPRO_SHARD_RESIDUALS") == "1":
            shard_res = True
    rules = rules_override or make_rules(mesh, cfg, kind, fsdp=fsdp, sp=sp,
                                         shard_residuals=shard_res)
    # §Perf hillclimb knobs (scripts/hillclimb.py)
    if os.environ.get("REPRO_SEQ_SHARD") == "1" and kind == "train":
        rules = dataclasses.replace(
            rules, seq="model", heads=None, kv_heads=None, ffn=None,
            d_model_act=None)
    if os.environ.get("REPRO_POD_LOCAL_FSDP") == "1" and rules.embed:
        rules = dataclasses.replace(rules, embed=("data",),
                                    embed_table=("data",))
    rules = prune_batch_axes(mesh, rules, shape.global_batch)

    with mesh, use_rules(rules):
        pspecs = api.param_specs(cfg)
        psh = tree_shardings(pspecs, mesh)
        batch_specs = api.input_specs(cfg, shape)
        r = rules

        def batch_shard(leaf_names):
            return tree_shardings(leaf_names, mesh)

        if kind == "train":
            params = api.param_structs(cfg)
            opt = OptConfig(state_dtype=over.get("opt_state_dtype"))
            opt_state = _opt_structs(params, opt)
            osh = {"m": psh, "v": psh,
                   "step": tree_shardings((), mesh) or None}
            osh["step"] = jax.tree_util.tree_map(lambda *_: None, 0)  # replicated
            tcfg = TrainConfig(
                opt=opt,
                attn_impl=os.environ.get("REPRO_ATTN_IMPL", "flash"),
                remat=os.environ.get("REPRO_REMAT", "full"),
                microbatches=over.get("microbatches", 1))
            step = make_train_step(cfg, tcfg)
            bsh = {}
            for k in batch_specs:
                ndim = len(batch_specs[k].shape)
                bsh[k] = tree_shardings(("batch",) + (None,) * (ndim - 1), mesh)
            args = (params, opt_state, batch_specs)
            in_sh = (psh, {"m": psh, "v": psh, "step": None}, bsh)
            out_sh = (psh, {"m": psh, "v": psh, "step": None}, None)
            return step, args, in_sh, (0, 1), rules, cfg, shape, out_sh

        params = _serve_param_structs(cfg)
        csh = tree_shardings(api.cache_specs(cfg), mesh)
        logits_sh = tree_shardings(("batch", None, "vocab"), mesh)
        if kind == "prefill":
            fn = api.prefill_fn(cfg, max_len=shape.seq_len)
            bsh = {}
            for k in batch_specs:
                ndim = len(batch_specs[k].shape)
                bsh[k] = tree_shardings(("batch",) + (None,) * (ndim - 1), mesh)
            return (lambda p, b: fn(p, b)), (params, batch_specs), \
                (psh, bsh), (), rules, cfg, shape, (logits_sh, csh)

        # decode
        sp_axis = "model" if "model" in mesh.axis_names else None
        fn = api.decode_fn(cfg, sp_axis=sp_axis)
        token = batch_specs["token"]
        cache = batch_specs["cache"]
        tsh = tree_shardings(("batch", None), mesh)
        step = lambda p, t, c: fn(p, t, c)
        return step, (params, token, cache), (psh, tsh, csh), (2,), rules, \
            cfg, shape, (logits_sh, csh)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             tag: str = "baseline", rules_override=None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    meshname = "2x16x16" if multi_pod else "16x16"
    cellname = f"{arch}__{shape_name}__{meshname}__{tag}"
    path = os.path.join(out_dir, cellname + ".json")
    ok, why = cell_supported(cfg, shape)
    rec = dict(arch=arch, shape=shape_name, mesh=meshname, tag=tag)
    if not ok:
        rec.update(status="skipped", reason=why)
        _write(path, rec)
        return rec

    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        fn, args, in_sh, donate, rules, cfg2, _, out_sh = build_cell(
            arch, shape_name, mesh, rules_override)
        t0 = time.perf_counter()
        with mesh, use_rules(rules):
            jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate)
            lowered = jfn.lower(*args)
            t_lower = time.perf_counter() - t0
            t0 = time.perf_counter()
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        pod_size = 256 if multi_pod else 1 << 30
        hlo = analyze_hlo(compiled.as_text(), pod_size=pod_size)
        n_chips = 512 if multi_pod else 256
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                       else 1)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory=dict(
                argument_bytes=ma.argument_size_in_bytes,
                output_bytes=ma.output_size_in_bytes,
                temp_bytes=ma.temp_size_in_bytes,
                alias_bytes=ma.alias_size_in_bytes,
                peak_bytes=ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes,
                # XLA-CPU upcasts bf16 dot operands to f32 (LICM-hoisted
                # whole-weight copies); TPU's MXU is native bf16 and never
                # materializes them — subtracting gives the TPU estimate.
                cpu_upcast_bytes=hlo.get("cpu_upcast_bytes", 0.0),
                peak_bytes_tpu=ma.argument_size_in_bytes
                + ma.output_size_in_bytes + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes - hlo.get("cpu_upcast_bytes", 0.0)),
            cost_analysis=dict(flops=ca.get("flops", 0.0),
                               bytes_accessed=ca.get("bytes accessed", 0.0)),
            hlo=hlo,
            model_flops=estimate_model_flops(
                cfg2, tokens, "train" if shape.kind == "train" else "serve"),
            n_chips=n_chips,
            tokens=tokens,
            rules={f.name: getattr(rules, f.name)
                   for f in dataclasses.fields(rules)},
        )
    except Exception as e:    # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2500:])
    _write(path, rec)
    return rec


def _write(path, rec):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    def default(o):
        if isinstance(o, (tuple, list)):
            return list(o)
        return str(o)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=default)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", default="both",
                    choices=["both", "single", "multi"])
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--mem-limit-gb", type=float, default=26.0,
                    help="address-space rlimit: a too-big cell raises "
                         "MemoryError (recorded) instead of OOM-killing")
    args = ap.parse_args()

    if args.mem_limit_gb:
        import resource
        lim = int(args.mem_limit_gb * 2 ** 30)
        resource.setrlimit(resource.RLIMIT_AS, (lim, lim))

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    pods = {"both": [False, True], "single": [False], "multi": [True]}[args.multi_pod]

    for arch in archs:
        for shape in shapes:
            for mp in pods:
                meshname = "2x16x16" if mp else "16x16"
                cell = f"{arch}__{shape}__{meshname}__{args.tag}"
                path = os.path.join(args.out, cell + ".json")
                if args.skip_done and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            print(f"[skip-done] {cell}")
                            continue
                t0 = time.perf_counter()
                rec = run_cell(arch, shape, mp, args.out, args.tag)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    extra = (f" compile={rec['compile_s']}s "
                             f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB/dev")
                elif status == "error":
                    extra = " " + rec.get("error", "")[:160]
                print(f"[{status}] {cell} ({time.perf_counter()-t0:.0f}s){extra}",
                      flush=True)


if __name__ == "__main__":
    main()
