"""Production meshes. Functions, not module constants — importing this
module never touches jax device state (dry-run sets XLA_FLAGS first)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod slice: 16x16 = 256 chips single pod; 2 pods = 512 chips.

    Axes: "pod" carries only data-parallel (DCN-friendly) traffic;
    "data" is in-pod DP/FSDP/SP; "model" is TP/EP.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/elastic restore."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
