"""Production meshes. Functions, not module constants — importing this
module never touches jax device state (dry-run sets XLA_FLAGS first)."""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``jax.make_mesh`` kwargs for explicitly-Auto axis types.

    ``jax.sharding.AxisType`` only exists on newer JAX (>= 0.5); older
    releases neither expose it nor accept ``axis_types=`` — there every axis
    is implicitly Auto, so omitting the kwarg is semantically identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod slice: 16x16 = 256 chips single pod; 2 pods = 512 chips.

    Axes: "pod" carries only data-parallel (DCN-friendly) traffic;
    "data" is in-pod DP/FSDP/SP; "model" is TP/EP.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/elastic restore."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
