"""Persistent autotuning config cache.

JSON on disk, keyed by ``(kernel, shape, dtype, backend)``, with a schema
version so stale caches from older tuner revisions are ignored rather than
misapplied (an AutoTVM log-file lesson: configs are only valid against the
search space that produced them). An in-process memo layer sits in front of
the file so the dispatch hot path never re-reads or re-parses JSON.

Cache resolution order used by the kernel dispatch layer:

  1. in-process memo (includes analytic-fallback results)
  2. entries of the loaded persistent cache (``REPRO_TUNE_CACHE`` env var,
     else ``<repo>/artifacts/tune_cache.json`` if present)
  3. analytic fallback cost model (runner.analytic_config), memoized

so models / serve / benchmarks always get *some* schedule with zero setup,
and get measured schedules transparently once a cache has been committed.
"""
from __future__ import annotations

import json
import os
import threading
import warnings
from typing import Dict, Optional

from repro.faults import inject as faults
from repro.obs import metrics as _obs_metrics

# v2: the batched/spatially-tiled kernel grids added block_n/block_h/block_w
# to every conv-kernel search space (and maxpool2d became tunable) — configs
# searched over the v1 spaces are not comparable, so v1 caches are ignored.
# v3: W4A8 packed-weight kernels added the "w4a8" dtype key (halved weight
# traffic reranks schedules, and matmul rounds bk up to even for packing) —
# v2 caches carry no "w4a8" entries and their int8 entries predate the
# W4-aware cost model, so they are ignored rather than misapplied.
SCHEMA_VERSION = 3

# repo root = .../src/repro/tune/cache.py -> four levels up
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
DEFAULT_CACHE_PATH = os.path.join(_REPO_ROOT, "artifacts", "tune_cache.json")


def cache_key(kernel: str, shape_key: str, dtype: str, backend: str) -> str:
    return "|".join((kernel, shape_key, dtype, backend))


class TuneCache:
    """One JSON cache file: {schema_version, entries: {key: entry}}.

    An *entry* is ``{"config": {...}, "us": float|None, "source":
    "measured"|"analytic", ...}``. Unknown extra fields round-trip untouched.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.entries: Dict[str, dict] = {}
        self.stale = False          # True if an on-disk schema mismatched
        self._lock = threading.Lock()
        if path:
            self._load(path)

    def _load(self, path: str):
        """Crash-safe load: a corrupt / truncated / wrong-typed cache file
        NEVER raises out of cache construction — it warns once, marks the
        cache ``stale`` (empty), and the dispatch layer degrades to the
        analytic cost model (``tune.cache.analytic_fallback`` counts it).
        ``save()`` is atomic (temp file + ``os.replace``), so a cache can
        only end up corrupt via external truncation — exactly the case the
        ``tune.cache_load`` fault seam injects in tests/test_faults.py."""
        if not os.path.exists(path):
            return
        try:
            faults.check("tune.cache_load")
            with open(path) as f:
                blob = json.load(f)
            if not isinstance(blob, dict):
                raise ValueError(f"expected a JSON object at top level, "
                                 f"got {type(blob).__name__}")
        except Exception as e:
            warnings.warn(
                f"tune cache {path!r} is unreadable ({e!r}); serving "
                f"falls back to analytic schedules until it is re-tuned",
                RuntimeWarning, stacklevel=2)
            _obs_metrics.counter("tune.cache.load_failed").inc()
            self.stale = True
            return
        if blob.get("schema_version") != SCHEMA_VERSION:
            # Old/foreign schema: ignore entries entirely (never misapply a
            # config searched over a different space), but keep the path so
            # a subsequent save() rewrites the file at the current version.
            self.stale = True
            return
        entries = blob.get("entries", {})
        if isinstance(entries, dict):
            self.entries = entries

    def get(self, key: str) -> Optional[dict]:
        return self.entries.get(key)

    def put(self, key: str, config: dict, *, us: Optional[float] = None,
            source: str = "measured", **meta):
        with self._lock:
            self.entries[key] = dict(config=dict(config), us=us,
                                     source=source, **meta)

    def save(self, path: Optional[str] = None):
        path = path or self.path
        if not path:
            raise ValueError("TuneCache.save: no path given or bound")
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        blob = {"schema_version": SCHEMA_VERSION, "entries": self.entries}
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(blob, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        self.path = path

    def __len__(self):
        return len(self.entries)


# --------------------------------------------------------------------------
# Process-wide default cache + memo (the dispatch hot path)
# --------------------------------------------------------------------------

_default_cache: Optional[TuneCache] = None
_memo: Dict[str, dict] = {}
_memo_lock = threading.Lock()


def default_cache_path() -> Optional[str]:
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return env
    if os.path.exists(DEFAULT_CACHE_PATH):
        return DEFAULT_CACHE_PATH
    return None


def get_default_cache() -> Optional[TuneCache]:
    global _default_cache
    if _default_cache is None:
        path = default_cache_path()
        _default_cache = TuneCache(path) if path else TuneCache(None)
    return _default_cache


def set_default_cache(cache: Optional[TuneCache]):
    """Install a cache for the dispatch layer (tests / scripts); clears memo."""
    global _default_cache
    with _memo_lock:
        _default_cache = cache
        _memo.clear()


def reset():
    """Drop the default cache and memo (re-reads env/disk on next lookup)."""
    set_default_cache(None)


def memo_get(key: str) -> Optional[dict]:
    entry = _memo.get(key)
    # hit/miss counters feed the process metrics registry: a cold memo on a
    # hot path (or a schema-stale cache silently falling back to analytic
    # configs) shows up in the bench_snapshot metrics section
    _obs_metrics.counter(
        "tune.memo.hit" if entry is not None else "tune.memo.miss").inc()
    return entry


def memo_put(key: str, entry: dict):
    with _memo_lock:
        _memo[key] = entry
