"""repro.tune — kernel autotuning: search spaces, measured runner with an
analytic fallback cost model, and a persistent config cache consulted by the
Pallas dispatch layer (repro.kernels.ops)."""
from .cache import (DEFAULT_CACHE_PATH, SCHEMA_VERSION, TuneCache, cache_key,
                    get_default_cache, reset, set_default_cache)
from .runner import (analytic_config, autotune, autotune_into, autotune_plan,
                     backend_tag, estimate_s, get_config, plan_jobs,
                     time_config)
from .space import (KERNELS, ShapeSig, candidates, default_config,
                    effective_config, sig_add_conv2d, sig_causal_conv1d,
                    sig_conv2d, sig_depthwise2d, sig_matmul, sig_maxpool2d,
                    sig_shift_conv2d, space_size)

__all__ = [
    "DEFAULT_CACHE_PATH", "SCHEMA_VERSION", "TuneCache", "cache_key",
    "get_default_cache", "reset", "set_default_cache",
    "analytic_config", "autotune", "autotune_into", "autotune_plan",
    "backend_tag", "estimate_s", "get_config", "plan_jobs", "time_config",
    "KERNELS", "ShapeSig", "candidates", "default_config", "effective_config",
    "sig_add_conv2d", "sig_causal_conv1d", "sig_conv2d", "sig_depthwise2d",
    "sig_matmul", "sig_maxpool2d", "sig_shift_conv2d", "space_size",
]
