"""Measured autotuner + analytic fallback cost model.

Two ways to pick a schedule, mirroring AutoTVM's measured-log / fallback
split (microtvm-blogpost-eval; "Not All Ops Are Created Equal!" motivates
the analytic half — MAC count alone misranks schedules, so the model scores
*data movement and occupancy*, not just arithmetic):

* :func:`autotune` — run every feasible config from ``space.candidates``
  through the real kernel, timing median-of-k with warmup. When the Pallas
  interpreter is active (no TPU) the measurement still ranks configs by the
  work the schedule issues, but the backend tag records the interpret mode
  so a TPU run never consumes interpreter numbers (the interpret-mode
  guard).

* :func:`analytic_config` — no measurement: a first-order TPU cost model
  built from the paper's analytic machinery (``ConvSpec.mac_count`` for
  arithmetic, ``core.energy.TPUv5e`` for peak FLOPs / HBM bandwidth / VMEM
  capacity) plus schedule-dependent terms: per-grid-step overhead, HBM
  traffic as a function of blocking, VPU/MXU lane utilization, and a hard
  VMEM-overflow penalty.

:func:`get_config` is the dispatch-layer entry point: memo -> persistent
cache -> analytic fallback.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.check.footprint import (element_bytes, kernel_footprint,
                                   vmem_budget, weight_bytes)
from repro.core.energy import TPUv5e
from repro.core.primitives import ConvSpec
from repro.kernels.common import cdiv
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace

from . import cache as _cache
from . import space as _space
from .space import ShapeSig, effective_config

TPU = TPUv5e()

# First-order schedule constants (relative scoring is what matters).
GRID_STEP_OVERHEAD_S = 2e-6          # per grid step: DMA setup + dispatch
VPU_DERATE = 1.0 / 64.0              # VPU peak vs MXU peak (8x128 vs 128x128)
VMEM_PENALTY = 1e3                   # multiplier when a schedule overflows VMEM
LANE = 128
SUBLANE = 8


def backend_tag() -> str:
    """Cache-key backend tag; marks interpret mode so interpreter timings are
    never consumed by a real-TPU run (and vice versa)."""
    import jax
    from repro.kernels.common import use_interpret
    tag = jax.default_backend()
    if use_interpret():
        tag += "+interpret"
    return tag


# --------------------------------------------------------------------------
# Analytic fallback cost model
# --------------------------------------------------------------------------

def _util(block: int, tile: int = LANE) -> float:
    """Fraction of compute lanes a block of this width keeps busy."""
    if block <= 0:
        return 1e-9
    full = -(-block // tile) * tile
    return block / full


# Element/weight widths live in check.footprint now (the single source of
# truth the hard verifier shares); the old local names stay as aliases.
_bytes_of = element_bytes
_wbytes_of = weight_bytes


def _vmem_cost(fp) -> float:
    """Soft penalty from the SAME footprint model ``check.check_schedule``
    enforces as a hard verdict — the cost model and the verifier can never
    disagree about what fits."""
    return VMEM_PENALTY if fp.total_bytes > vmem_budget("tpu") else 1.0


def _tiles(sig: ShapeSig, eff: Dict[str, int]):
    """Grid geometry of one tiled-schedule kernel: (bn, bh, bw, tile
    steps-per-image-block). ``eff`` must be an effective config."""
    h, w = _space._out_hw(sig)
    bn, bh, bw = eff["block_n"], eff["block_h"], eff["block_w"]
    return bn, bh, bw, (sig.get("n") // bn) * cdiv(h, bh) * cdiv(w, bw)


def estimate_s(sig: ShapeSig, config: Dict[str, int], dtype: str) -> float:
    """Estimated seconds for one kernel invocation under ``config``.

    The tiled-grid kernels' traffic term reflects the batched schedule's
    weight reuse: one filter-block load per grid step now covers ``block_n``
    images (the Fig-3 reuse quantity grows from Cx*BCO to BN*Cx*BCO MACs
    per weight byte), while spatial tiles shrink the per-step image block —
    and with it the VMEM footprint — at the cost of halo re-reads.

    Per-step block byte counts come from ``check.footprint.kernel_footprint``
    — the same model ``check_schedule`` turns into a hard verdict — so a
    schedule the verifier rejects is exactly a schedule this model prices
    with the ``VMEM_PENALTY`` multiplier.
    """
    k = sig.kernel

    if k == "conv2d":
        n, h, w = sig.get("n"), sig.get("h"), sig.get("w")
        ci, co, hk, g = (sig.get("ci"), sig.get("co"), sig.get("k"),
                         max(sig.get("g"), 1))
        cxg, cog = ci // g, co // g
        eff = effective_config(sig, config)
        bco = eff["block_co"]
        bn, bh, bw, sp_steps = _tiles(sig, eff)
        steps = sp_steps * g * (cog // bco)
        spec = ConvSpec(primitive="grouped" if g > 1 else "standard",
                        in_channels=ci, out_channels=co, kernel_size=hk,
                        groups=g, use_bias=False)
        flops = 2.0 * n * spec.mac_count(w)
        fp = kernel_footprint(sig, eff, dtype)
        t = dict(fp.terms)
        traffic = steps * (t["img"] + t["wts"] + t["out"])
        compute = flops / (TPU.peak_bf16_flops * _util(bco) * _util(cxg))
        return (_vmem_cost(fp)
                * (compute + traffic / TPU.hbm_bw + steps * GRID_STEP_OVERHEAD_S))

    if k == "depthwise2d":
        n, h, w, c, hk = (sig.get("n"), sig.get("h"), sig.get("w"),
                          sig.get("c"), sig.get("k"))
        eff = effective_config(sig, config)
        bc = eff["block_c"]
        bn, bh, bw, sp_steps = _tiles(sig, eff)
        steps = sp_steps * (c // bc)
        flops = 2.0 * n * h * w * c * hk * hk
        fp = kernel_footprint(sig, eff, dtype)
        t = dict(fp.terms)
        traffic = steps * (t["img"] + t["wts"] + t["out"])
        compute = flops / (TPU.peak_bf16_flops * VPU_DERATE * _util(bc))
        return (_vmem_cost(fp)
                * (compute + traffic / TPU.hbm_bw + steps * GRID_STEP_OVERHEAD_S))

    if k == "shift_conv2d":
        n, h, w, c, co = (sig.get("n"), sig.get("h"), sig.get("w"),
                          sig.get("c"), sig.get("co"))
        eff = effective_config(sig, config)
        bco = eff["block_co"]
        bn, bh, bw, sp_steps = _tiles(sig, eff)
        steps = sp_steps * (co // bco)
        flops = 2.0 * n * h * w * c * co
        fp = kernel_footprint(sig, eff, dtype)
        t = dict(fp.terms)
        traffic = steps * (t["img"] + t["wts"] + t["out"])
        compute = flops / (TPU.peak_bf16_flops * _util(bco) * _util(c))
        return (_vmem_cost(fp)
                * (compute + traffic / TPU.hbm_bw + steps * GRID_STEP_OVERHEAD_S))

    if k == "add_conv2d":
        n, h, w = sig.get("n"), sig.get("h"), sig.get("w")
        ci, co, hk = sig.get("ci"), sig.get("co"), sig.get("k")
        eff = effective_config(sig, config)
        bco = eff["block_co"]
        bn, bh, bw, sp_steps = _tiles(sig, eff)
        steps = sp_steps * (co // bco)
        # |a-b| broadcast: the (BN*BH*BW, Cx, BCO) intermediate is the VMEM
        # hog — the spatial tile is what keeps it bounded (the footprint's
        # acc term)
        flops = 3.0 * n * h * w * ci * co * hk * hk  # sub+abs+add per tap
        fp = kernel_footprint(sig, eff, dtype)
        t = dict(fp.terms)
        traffic = steps * (t["img"] + t["wts"] + t["out"])
        compute = flops / (TPU.peak_bf16_flops * VPU_DERATE * _util(bco, SUBLANE))
        return (_vmem_cost(fp)
                * (compute + traffic / TPU.hbm_bw + steps * GRID_STEP_OVERHEAD_S))

    if k == "maxpool2d":
        n, c, win, s = sig.get("n"), sig.get("c"), sig.get("k"), sig.get("s")
        hout, wout = _space._out_hw(sig)
        eff = effective_config(sig, config)
        bc = eff["block_c"]
        bn, bh, bw, sp_steps = _tiles(sig, eff)
        steps = sp_steps * (c // bc)
        flops = 1.0 * n * hout * wout * c * win * win    # VPU compares
        fp = kernel_footprint(sig, eff, dtype)
        t = dict(fp.terms)
        traffic = steps * (t["img"] + t["out"])
        compute = flops / (TPU.peak_bf16_flops * VPU_DERATE * _util(bc))
        return (_vmem_cost(fp)
                * (compute + traffic / TPU.hbm_bw + steps * GRID_STEP_OVERHEAD_S))

    if k == "causal_conv1d":
        b, l, d, kk = (sig.get("b"), sig.get("l"), sig.get("d"), sig.get("k"))
        eff = effective_config(sig, config)
        bl, bc = eff["block_l"], eff["block_c"]
        steps = b * (l // bl) * (d // bc)
        flops = 2.0 * b * l * d * kk
        fp = kernel_footprint(sig, eff, dtype)
        t = dict(fp.terms)
        traffic = steps * (t["img"] + t["wts"] + t["out"])
        compute = flops / (TPU.peak_bf16_flops * VPU_DERATE * _util(bc))
        return (_vmem_cost(fp)
                * (compute + traffic / TPU.hbm_bw + steps * GRID_STEP_OVERHEAD_S))

    if k == "matmul":
        m, kk, n = sig.get("m"), sig.get("k"), sig.get("n")
        eff = effective_config(sig, config)
        bm, bn, bk = eff["bm"], eff["bn"], eff["bk"]
        gi, gj, gk = -(-m // bm), -(-n // bn), -(-kk // bk)
        steps = gi * gj * gk
        flops = 2.0 * m * n * kk
        fp = kernel_footprint(sig, eff, dtype)
        t = dict(fp.terms)
        # A/B blocks stream every step; the output block lands once per
        # (i, j) after the k-axis accumulation
        traffic = steps * (t["a"] + t["b"]) + gi * gj * t["out"]
        compute = flops / (TPU.peak_bf16_flops
                           * _util(bn) * _util(bk) * _util(bm, SUBLANE))
        return (_vmem_cost(fp)
                * (compute + traffic / TPU.hbm_bw + steps * GRID_STEP_OVERHEAD_S))

    raise ValueError(f"unknown kernel {k!r}")


def analytic_config(sig: ShapeSig, dtype: str = "float32") -> Dict[str, int]:
    """Best config under the analytic model (no measurement)."""
    best, best_s = None, float("inf")
    for cfg in _space.candidates(sig, dtype):
        s = estimate_s(sig, cfg, dtype)
        if s < best_s:
            best, best_s = cfg, s
    assert best is not None
    return best


# --------------------------------------------------------------------------
# Measured autotuner
# --------------------------------------------------------------------------

def time_config(fn: Callable, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall-clock microseconds per call (same protocol as
    benchmarks/common.time_fn; duplicated so src/ never imports benchmarks/)."""
    import jax
    out = None
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def _kernel_call(kernel: str) -> Callable:
    """Kernel entry point taking (*arrays, config=...) — imported lazily to
    keep repro.tune importable without pulling the whole kernel layer."""
    from repro.kernels.common import use_interpret
    interp = use_interpret()
    if kernel == "conv2d":
        from repro.kernels.conv_im2col import conv2d_im2col as fn
    elif kernel == "depthwise2d":
        from repro.kernels.conv_dw import depthwise2d as fn
    elif kernel == "shift_conv2d":
        from repro.kernels.conv_shift import shift_conv2d as fn
    elif kernel == "add_conv2d":
        from repro.kernels.conv_add import add_conv2d as fn
    elif kernel == "causal_conv1d":
        from repro.kernels.conv1d_causal import causal_conv1d as fn
    elif kernel == "matmul":
        from repro.kernels.matmul_q8 import matmul as fn
    elif kernel == "maxpool2d":
        from repro.kernels.pool import maxpool2d as fn
    else:
        raise ValueError(f"unknown kernel {kernel!r}")
    return lambda args, cfg, kw: fn(*args, interpret=interp, config=cfg, **kw)


def autotune(kernel: str, sig: ShapeSig, args: Tuple, *,
             kwargs: Optional[dict] = None, dtype: str = "float32",
             reps: int = 5, warmup: int = 2,
             max_candidates: Optional[int] = None,
             verbose: bool = False) -> Tuple[Dict[str, int], float, list]:
    """Measure every candidate config on real arrays; return
    (best_config, best_us, [(config, us), ...]). ``kwargs`` are non-schedule
    kernel arguments (e.g. groups=, requant_shift=) held fixed across
    candidates; ``dtype`` selects the (wider for int8) candidate space."""
    from repro.kernels.common import use_interpret
    if use_interpret() and reps > 3:
        reps = 3                     # interpret-mode guard: interpreter is
        warmup = min(warmup, 1)      # slow & deterministic; fewer reps suffice
    call = _kernel_call(kernel)
    kw = kwargs or {}
    # throwaway pass: absorb process-level one-time costs (thread pools,
    # dtype-specific backend init) so the first timed candidate — always the
    # default schedule — is not systematically penalized
    call(args, _space.default_config(kernel), kw)
    results = []
    for i, cfg in enumerate(_space.candidates(sig, dtype)):
        if max_candidates is not None and i >= max_candidates:
            break
        # one span per measured candidate: an exported trace of a tuning run
        # shows the whole search, config and measured us on each slice
        with _obs_trace.span("tune.candidate", cat="tune", kernel=kernel,
                             shape=sig.key(), config=dict(cfg)) as sp:
            us = time_config(lambda a=args, c=cfg: call(a, c, kw),
                             reps=reps, warmup=warmup)
            sp.set(us=us)
        results.append((cfg, us))
        if verbose:
            print(f"  {kernel}/{sig.key()} {cfg} -> {us:.1f}us")
    best, best_us = min(results, key=lambda t: t[1])
    return best, best_us, results


def autotune_into(cache: _cache.TuneCache, kernel: str, sig: ShapeSig,
                  args: Tuple, dtype: str, **kw) -> Tuple[Dict[str, int], float]:
    """Autotune one (kernel, shape) and record the winner in ``cache``."""
    best, best_us, results = autotune(kernel, sig, args, dtype=dtype, **kw)
    default_us = next((us for cfg, us in results
                       if cfg == _space.default_config(kernel)), None)
    key = _cache.cache_key(kernel, sig.key(), dtype, backend_tag())
    cache.put(key, best, us=best_us, source="measured",
              default_us=default_us, n_candidates=len(results))
    return best, best_us


# --------------------------------------------------------------------------
# Whole-plan pre-tuning (repro.graph integration)
# --------------------------------------------------------------------------

def plan_jobs(plan, *, batch: int = 1) -> list:
    """Autotune jobs covering every kernel invocation of a lowered
    ``repro.graph`` Plan: one ``(kernel, sig, arrays, dtype, kwargs)`` tuple
    per distinct (kernel, shape) the executor will dispatch — dws layers
    contribute their depthwise AND pointwise stages, and int8 maxpool nodes
    contribute their own jobs. Shapes/requant shifts are read off the plan's
    annotated scales, so the timed epilogues are exactly the fused ones
    (requant + act) the executor runs. ``batch`` is the microbatch the
    schedules are searched at — tune at the batch you serve, since the
    block_n/block_h/block_w spaces (and the cache keys) depend on it."""
    import jax
    import jax.numpy as jnp
    from repro.core.quantize import QTensorW4

    def i8(shape, seed=0):
        return jax.random.randint(jax.random.PRNGKey(seed), shape, -100, 100,
                                  jnp.int32).astype(jnp.int8)

    def wkw(wq):
        """(extra kwargs, dtype key) for one weight leaf: W4-packed leaves
        tune under their own "w4a8" signature (halved weight traffic reranks
        the space) and carry their group shifts into the timed call."""
        if isinstance(wq, QTensorW4):
            return {"w_shifts": wq.shifts}, "w4a8"
        return {}, "int8"

    jobs, seen = [], set()

    def emit(kernel, sig, arrays, kwargs, dtype="int8"):
        k = (kernel, sig.key(), dtype)
        if k not in seen:
            seen.add(k)
            jobs.append((kernel, sig, arrays, dtype, kwargs))

    for node in plan.nodes:
        if node.op == "maxpool" and "in_hw" in node.attrs:
            h, w = node.attrs["in_hw"]
            c = node.attrs["in_ch"]
            win, s = node.attrs["window"], node.attrs["stride"]
            emit("maxpool2d", _space.sig_maxpool2d(batch, h, w, c, win, s),
                 (i8((batch, h, w, c)),), dict(window=win, stride=s))
            continue
        if node.op != "qconv":
            continue
        spec = node.spec
        h, w = node.attrs["in_hw"]
        ci, co, hk = spec.in_channels, spec.out_channels, spec.kernel_size
        p = spec.primitive
        if p in ("standard", "grouped"):
            g = spec.groups if p == "grouped" else 1
            wq = node.qparams["w"]
            kw, dt = wkw(wq)
            shift = node.in_fb + wq.frac_bits - node.out_fb
            emit("conv2d", _space.sig_conv2d(batch, h, w, ci, co, hk, g),
                 (i8((batch, h, w, ci)), wq.q),
                 dict(groups=g, requant_shift=shift, act=node.act, **kw), dt)
        elif p == "dws":
            w_dw, w_pw = node.qparams["w_dw"], node.qparams["w_pw"]
            mid_fb = node.qparams.get("mid_frac_bits", node.out_fb)
            kw_dw, dt_dw = wkw(w_dw)
            kw_pw, dt_pw = wkw(w_pw)
            emit("depthwise2d", _space.sig_depthwise2d(batch, h, w, ci, hk),
                 (i8((batch, h, w, ci)), w_dw.q[..., 0]),
                 dict(requant_shift=node.in_fb + w_dw.frac_bits - mid_fb,
                      **kw_dw), dt_dw)
            emit("conv2d", _space.sig_conv2d(batch, h, w, ci, co, 1, 1),
                 (i8((batch, h, w, ci)), w_pw.q),
                 dict(requant_shift=mid_fb + w_pw.frac_bits - node.out_fb,
                      act=node.act, **kw_pw), dt_pw)
        elif p == "shift":
            w_pw = node.qparams["w_pw"]
            kw, dt = wkw(w_pw)
            emit("shift_conv2d", _space.sig_shift_conv2d(batch, h, w, ci, co),
                 (i8((batch, h, w, ci)), node.qparams["shifts"],
                  w_pw.q[0, 0] if w_pw.q.ndim == 4 else w_pw.q),
                 dict(requant_shift=node.in_fb + w_pw.frac_bits - node.out_fb,
                      act=node.act, **kw), dt)
        elif p == "add":
            wq = node.qparams["w"]
            kw, dt = wkw(wq)
            x_pre = max(0, wq.frac_bits - node.in_fb)
            w_pre = max(0, node.in_fb - wq.frac_bits)
            acc_fb = max(node.in_fb, wq.frac_bits)
            emit("add_conv2d", _space.sig_add_conv2d(batch, h, w, ci, co, hk),
                 (i8((batch, h, w, ci)), wq.q),
                 dict(requant_shift=acc_fb - node.out_fb, x_preshift=x_pre,
                      w_preshift=w_pre, act=node.act, **kw), dt)
    return jobs


def autotune_plan(cache: _cache.TuneCache, plan, *, batch: int = 1,
                  **kw) -> list:
    """Pre-tune a whole plan's node set in one call: measure every distinct
    kernel invocation of ``plan`` and record the winners in ``cache`` (the
    executor then picks them up through the normal dispatch lookup).
    Returns ``[(kernel, sig, best_config, best_us), ...]``."""
    out = []
    for kernel, sig, arrays, dtype, kwargs in plan_jobs(plan, batch=batch):
        best, best_us = autotune_into(cache, kernel, sig, arrays, dtype,
                                      kwargs=kwargs, **kw)
        out.append((kernel, sig, best, best_us))
    return out


# --------------------------------------------------------------------------
# Dispatch-layer lookup: memo -> persistent cache -> analytic fallback
# --------------------------------------------------------------------------

def get_config(sig: ShapeSig, dtype: str) -> Dict[str, int]:
    key = _cache.cache_key(sig.kernel, sig.key(), str(dtype), backend_tag())
    hit = _cache.memo_get(key)
    if hit is not None:
        return hit["config"]
    pc = _cache.get_default_cache()
    entry = pc.get(key) if pc is not None else None
    if entry is None:
        # no tuned entry: the analytic cost model picks the schedule —
        # counted so untuned shapes are visible in the metrics snapshot
        _obs_metrics.counter("tune.cache.analytic_fallback").inc()
        entry = {"config": analytic_config(sig, str(dtype)),
                 "us": None, "source": "analytic"}
    else:
        _obs_metrics.counter("tune.cache.hit").inc()
    _cache.memo_put(key, entry)
    return entry["config"]
