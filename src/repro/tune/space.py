"""Per-kernel search-space definitions for the autotuner.

Each Pallas kernel exposes a small set of schedule knobs (block/tile sizes,
grid shape by implication). The paper's NNoM kernels are hand-scheduled per
Cortex-M target; the TPU analogue is a per-(kernel, shape, dtype) config
search over these knobs — the AutoTVM recipe from the microtvm-blogpost-eval
reference, shrunk to the handful of parameters our kernels actually expose.

A *config* is a plain dict of kwargs understood by the kernel wrapper
(e.g. ``{"block_co": 64}``). :func:`candidates` enumerates the feasible
configs for a concrete shape signature; :func:`default_config` returns the
hard-coded seed schedule (what the kernels used before this subsystem
existed), which is always feasible and always a member of the space.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Tuple

from repro.kernels.common import batch_spatial_schedule, effective_block

# Kernels the tuner knows about. Names match repro.kernels.ops entry points.
KERNELS = ("conv2d", "depthwise2d", "shift_conv2d", "add_conv2d",
           "causal_conv1d", "matmul", "maxpool2d")

# Conv-grid kernels that take the tiled (block_n, block_h, block_w) schedule
# on top of their channel blocking (the batched/spatially-tiled grid).
_TILED = ("conv2d", "depthwise2d", "shift_conv2d", "add_conv2d", "maxpool2d")

# Hard-coded schedules shipped with the seed kernels (pre-tuner behavior);
# block_n=1 / whole-map spatial tiles are the untiled legacy grid.
_DEFAULTS: Dict[str, Dict[str, int]] = {
    "conv2d": {"block_co": 128, "block_n": 1},
    "depthwise2d": {"block_c": 128, "block_n": 1},
    "shift_conv2d": {"block_co": 128, "block_n": 1},
    "add_conv2d": {"block_co": 8, "block_n": 1},
    "causal_conv1d": {"block_l": 512, "block_c": 512},
    "matmul": {"bm": 256, "bn": 256, "bk": 512},
    "maxpool2d": {"block_c": 128, "block_n": 1},
}

_POW2_BLOCKS = (8, 16, 32, 64, 128, 256)
_MM_BLOCKS = (128, 256, 512)

# int8 operands are 4x smaller than f32, so schedules that would overflow
# VMEM at f32 are feasible quantized — the int8 spaces extend the block
# ranges upward (the paper's SIMD build likewise unlocks wider tiles via
# 4-way byte packing in 32-bit words).
_POW2_BLOCKS_INT8 = _POW2_BLOCKS + (512,)
_MM_BLOCKS_INT8 = _MM_BLOCKS + (1024,)


def _int8(dtype: str) -> bool:
    # "w4a8" = nibble-packed weights, int8 activations: same lane widths /
    # block feasibility as int8, so it shares the int8 candidate space (the
    # cost model, not the space, sees the halved weight bytes)
    return str(dtype) in ("int8", "uint8", "w4a8")


@dataclasses.dataclass(frozen=True)
class ShapeSig:
    """Canonical shape signature of one kernel invocation.

    ``dims`` is a tuple of named ints in kernel-specific order; it is what the
    cache keys on and what the space enumerates against.
    """

    kernel: str
    dims: Tuple[Tuple[str, int], ...]

    def __post_init__(self):
        if self.kernel not in KERNELS:
            raise ValueError(f"unknown kernel {self.kernel!r}; "
                             f"known: {KERNELS}")

    def get(self, name: str) -> int:
        for k, v in self.dims:
            if k == name:
                return v
        raise KeyError(name)

    def key(self) -> str:
        return "_".join(f"{k}{v}" for k, v in self.dims)


def sig_conv2d(n, h, w, cx, cy, hk, groups=1) -> ShapeSig:
    return ShapeSig("conv2d", (("n", n), ("h", h), ("w", w), ("ci", cx),
                               ("co", cy), ("k", hk), ("g", groups)))


def sig_depthwise2d(n, h, w, c, hk) -> ShapeSig:
    return ShapeSig("depthwise2d", (("n", n), ("h", h), ("w", w), ("c", c),
                                    ("k", hk)))


def sig_shift_conv2d(n, h, w, c, cy) -> ShapeSig:
    return ShapeSig("shift_conv2d", (("n", n), ("h", h), ("w", w), ("c", c),
                                     ("co", cy)))


def sig_add_conv2d(n, h, w, cx, cy, hk) -> ShapeSig:
    return ShapeSig("add_conv2d", (("n", n), ("h", h), ("w", w), ("ci", cx),
                                   ("co", cy), ("k", hk)))


def sig_causal_conv1d(b, l, d, k) -> ShapeSig:
    return ShapeSig("causal_conv1d", (("b", b), ("l", l), ("d", d), ("k", k)))


def sig_matmul(m, k, n) -> ShapeSig:
    return ShapeSig("matmul", (("m", m), ("k", k), ("n", n)))


def sig_maxpool2d(n, h, w, c, window, stride) -> ShapeSig:
    return ShapeSig("maxpool2d", (("n", n), ("h", h), ("w", w), ("c", c),
                                  ("k", window), ("s", stride)))


def default_config(kernel: str) -> Dict[str, int]:
    if kernel not in _DEFAULTS:
        raise ValueError(f"unknown kernel {kernel!r}")
    return dict(_DEFAULTS[kernel])


def _out_hw(sig: ShapeSig) -> Tuple[int, int]:
    """Output spatial extent the (block_h, block_w) tiles grid over: the
    input map for the stride-1 SAME conv kernels, the pooled map for
    maxpool2d."""
    h, w = sig.get("h"), sig.get("w")
    if sig.kernel == "maxpool2d":
        win, s = sig.get("k"), sig.get("s")
        return (h - win) // s + 1, (w - win) // s + 1
    return h, w


def _bs_effective(sig: ShapeSig, cfg: Dict[str, int]) -> Dict[str, int]:
    """Effective (block_n, block_h, block_w) half of a tiled-grid schedule
    — resolved by the SAME ``batch_spatial_schedule`` the kernels run."""
    h, w = _out_hw(sig)
    bn, bh, bw, _, _ = batch_spatial_schedule(
        sig.get("n"), h, w, cfg.get("block_n", 1),
        cfg.get("block_h"), cfg.get("block_w"))
    return {"block_n": bn, "block_h": bh, "block_w": bw}


def effective_config(sig: ShapeSig, cfg: Dict[str, int]) -> Dict[str, int]:
    """The schedule the kernel actually runs for ``cfg`` on this shape.

    Divisor-gridded kernels degrade blocks via ``effective_block`` (and the
    tiled-grid kernels resolve block_n/block_h/block_w through
    ``batch_spatial_schedule``); matmul's cdiv grid only clamps to the
    dimension. Two configs with equal effective schedules are the same
    compiled kernel — the space dedupes on this, and tuned-vs-default
    comparisons are only meaningful across distinct effective schedules.
    """
    k = sig.kernel
    d = default_config(k)

    def get(name):
        return int(cfg.get(name, d[name]))

    if k == "conv2d":
        co_per_g = sig.get("co") // max(sig.get("g"), 1)
        return {"block_co": effective_block(co_per_g, get("block_co")),
                **_bs_effective(sig, cfg)}
    if k == "depthwise2d":
        return {"block_c": effective_block(sig.get("c"), get("block_c")),
                **_bs_effective(sig, cfg)}
    if k == "shift_conv2d":
        return {"block_co": effective_block(sig.get("co"), get("block_co")),
                **_bs_effective(sig, cfg)}
    if k == "add_conv2d":
        return {"block_co": effective_block(sig.get("co"), get("block_co")),
                **_bs_effective(sig, cfg)}
    if k == "maxpool2d":
        return {"block_c": effective_block(sig.get("c"), get("block_c")),
                **_bs_effective(sig, cfg)}
    if k == "causal_conv1d":
        return {"block_l": effective_block(sig.get("l"), get("block_l")),
                "block_c": effective_block(sig.get("d"), get("block_c"))}
    if k == "matmul":
        return {"bm": min(get("bm"), sig.get("m")),
                "bn": min(get("bn"), sig.get("n")),
                "bk": min(get("bk"), sig.get("k"))}
    raise AssertionError(k)  # pragma: no cover - ShapeSig guards kernel


def _bs_variants(sig: ShapeSig) -> List[Dict[str, int]]:
    """(block_n, block_h, block_w) variants for the tiled-grid kernels,
    feasibility-gated on the shape: batch blocks up to the batch size
    (weight reuse), row/tile blocks only when the map is big enough for the
    halo duplication to buy VMEM headroom. The empty dict is the untiled
    legacy schedule; infeasible variants alias it and dedupe away."""
    n = sig.get("n")
    h, w = _out_hw(sig)
    outs: List[Dict[str, int]] = [{}]
    for bn in (2, 4, 8):
        if bn <= n:
            outs.append({"block_n": bn})
    for bh in (8, 16):
        if bh < h:
            outs.append({"block_h": bh})
    if h > 8 and w > 8:
        outs.append({"block_h": 8, "block_w": 8})
    for bn in (4, 8):
        if bn <= n and h > 8:
            outs.append({"block_n": bn, "block_h": 8})
    return outs


def candidates(sig: ShapeSig, dtype: str = "float32") -> Iterator[Dict[str, int]]:
    """Enumerate feasible configs for one shape, default first.

    Deduped by *effective* schedule, so the default's entry represents its
    whole equivalence class and no other candidate aliases it. ``dtype``
    widens the block ranges for int8 operands (4x smaller footprint). The
    tiled-grid kernels additionally sweep (block_n, block_h, block_w)
    variants on top of their default channel blocking.
    """
    from repro.check.footprint import check_schedule

    k = sig.kernel
    seen = set()
    out: List[Dict[str, int]] = []
    pow2 = _POW2_BLOCKS_INT8 if _int8(dtype) else _POW2_BLOCKS
    mm = _MM_BLOCKS_INT8 if _int8(dtype) else _MM_BLOCKS

    def emit(cfg: Dict[str, int], prune: bool = True):
        key = tuple(sorted(effective_config(sig, cfg).items()))
        if key in seen:
            return
        # static feasibility gate: a schedule the hard verifier rejects is
        # never measured (the soft VMEM_PENALTY only ranked it last before)
        if prune and not check_schedule(sig, cfg, dtype).ok:
            return
        seen.add(key)
        out.append(cfg)

    # the default seed schedule is always a member — it is the fallback the
    # kernels ran before the tuner existed, so the space is never empty
    emit(default_config(k), prune=False)

    if k == "conv2d":
        for bco in pow2:
            emit({"block_co": bco})
    elif k == "depthwise2d":
        for bc in pow2:
            emit({"block_c": bc})
    elif k == "shift_conv2d":
        for bco in pow2:
            emit({"block_co": bco})
    elif k == "add_conv2d":
        for bco in (1, 2, 4, 8, 16, 32) + ((64,) if _int8(dtype) else ()):
            emit({"block_co": bco})
    elif k == "maxpool2d":
        for bc in (32, 64, 128, 256) + ((512,) if _int8(dtype) else ()):
            emit({"block_c": bc})
    elif k == "causal_conv1d":
        for bl in (128, 256, 512, 1024):
            for bc in (128, 256, 512):
                emit({"block_l": bl, "block_c": bc})
    elif k == "matmul":
        for bm in mm:
            for bn in mm:
                for bk in mm:
                    emit({"bm": bm, "bn": bn, "bk": bk})
    else:  # pragma: no cover - KERNELS guard above
        raise AssertionError(k)

    if k in _TILED:
        for var in _bs_variants(sig):
            if var:
                emit(var)

    return iter(out)


def space_size(sig: ShapeSig, dtype: str = "float32") -> int:
    return sum(1 for _ in candidates(sig, dtype))
