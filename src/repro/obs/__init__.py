"""repro.obs — unified tracing + metrics for the whole stack.

Two pure-stdlib submodules (no jax import, so every layer can depend on
them without cycles):

* :mod:`repro.obs.trace` — thread-safe span tracer exporting Chrome
  trace-event JSON (Perfetto / ``chrome://tracing``), gated by the
  ``REPRO_TRACE`` env var, near-zero-cost no-op when disabled.
* :mod:`repro.obs.metrics` — counters / gauges / fixed-bucket histograms
  (p50/p95/p99) in a process-wide :data:`~repro.obs.metrics.REGISTRY`,
  with per-engine private registries backing ``Engine.stats`` and
  ``CNNEngine.stats``.

See EXPERIMENTS.md §Observability for capture/read workflows and
``scripts/bench_snapshot.py`` for the machine-readable benchmark record
built on top of both.
"""
from . import metrics, trace
from .metrics import REGISTRY, Registry
from .trace import TRACER, Tracer, span, traced

__all__ = ["metrics", "trace", "REGISTRY", "Registry", "TRACER", "Tracer",
           "span", "traced"]
