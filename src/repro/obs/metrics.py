"""Metrics registry: counters, gauges, fixed-bucket histograms.

A :class:`Registry` is a thread-safe, name-keyed collection of metric
instruments with ``snapshot()``/``to_json()`` for machine-readable export
(``scripts/bench_snapshot.py`` embeds a snapshot in every ``BENCH_*.json``).
:data:`REGISTRY` is the process-wide default that the kernel dispatch
layer, the tune cache, and the graph executor count into; the serve
engines each own a private ``Registry`` so per-engine ``stats`` stay
isolated across engine instances (``reset_stats`` zeroes values in place,
so handles held by an engine stay live across resets).

Histograms use fixed bucket boundaries (default: 1-2-5 log-spaced seconds
covering 1µs..50s — sized for the latency quantities the serve layer
observes) and report p50/p95/p99 by linear interpolation inside the
containing bucket, clamped to the observed min/max; ``sum``/``count`` are
tracked exactly, so ``mean`` is exact even though percentiles are
bucket-resolution approximations.
"""
from __future__ import annotations

import bisect
import json
import threading
from typing import Dict, List, Optional, Sequence, Tuple, Union

# 1-2-5 per decade, 1µs .. 50s: latency-shaped default for seconds values.
DEFAULT_BUCKETS: Tuple[float, ...] = tuple(
    m * 10.0 ** e for e in range(-6, 2) for m in (1.0, 2.0, 5.0))


class Counter:
    """Monotonically increasing value (float increments allowed, so time
    accumulators like ``decode_time_s`` are counters too)."""
    __slots__ = ("name", "_lock", "_v")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._v = 0.0

    def inc(self, n: Union[int, float] = 1):
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        with self._lock:
            self._v += n

    @property
    def value(self) -> float:
        return self._v

    def reset(self):
        with self._lock:
            self._v = 0.0

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self._v}


class Gauge:
    """Last-write-wins value (e.g. current slot occupancy)."""
    __slots__ = ("name", "_lock", "_v")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._v = 0.0

    def set(self, v: Union[int, float]):
        with self._lock:
            self._v = float(v)

    @property
    def value(self) -> float:
        return self._v

    def reset(self):
        with self._lock:
            self._v = 0.0

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self._v}


class Histogram:
    """Fixed-bucket histogram with exact sum/count and interpolated
    percentiles.

    ``buckets`` are the inclusive upper bounds of each bin (ascending); an
    implicit overflow bin catches values above the last bound. Percentiles
    interpolate linearly inside the containing bucket and are clamped to
    the observed [min, max], so they are exact to bucket resolution.
    """
    __slots__ = ("name", "buckets", "_lock", "_counts", "_sum", "_count",
                 "_min", "_max")

    def __init__(self, name: str,
                 buckets: Optional[Sequence[float]] = None):
        self.name = name
        bs = tuple(sorted(buckets if buckets is not None else DEFAULT_BUCKETS))
        if not bs:
            raise ValueError(f"histogram {name}: empty bucket list")
        self.buckets = bs
        self._lock = threading.Lock()
        self._counts = [0] * (len(bs) + 1)     # +1: overflow bin
        self._sum = 0.0
        self._count = 0
        self._min = float("inf")
        self._max = float("-inf")

    def observe(self, v: Union[int, float]):
        v = float(v)
        i = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    @property
    def min(self) -> float:
        return self._min if self._count else 0.0

    @property
    def max(self) -> float:
        return self._max if self._count else 0.0

    def percentile(self, p: float) -> float:
        """p in [0, 100] -> interpolated value at that rank."""
        if not 0.0 <= p <= 100.0:
            raise ValueError(f"percentile {p} outside [0, 100]")
        with self._lock:
            count = self._count
            counts = list(self._counts)
            lo, hi = self._min, self._max
        if count == 0:
            return 0.0
        target = (p / 100.0) * count
        cum = 0.0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c >= target:
                b_lo = self.buckets[i - 1] if i > 0 else min(lo, self.buckets[0])
                b_hi = self.buckets[i] if i < len(self.buckets) else hi
                frac = (target - cum) / c
                v = b_lo + frac * (b_hi - b_lo)
                return min(max(v, lo), hi)
            cum += c
        return hi

    def reset(self):
        with self._lock:
            self._counts = [0] * (len(self.buckets) + 1)
            self._sum = 0.0
            self._count = 0
            self._min = float("inf")
            self._max = float("-inf")

    def snapshot(self) -> dict:
        with self._lock:
            count, s = self._count, self._sum
        return {"type": "histogram", "count": count, "sum": s,
                "mean": s / count if count else 0.0,
                "min": self.min, "max": self.max,
                "p50": self.percentile(50.0),
                "p95": self.percentile(95.0),
                "p99": self.percentile(99.0)}


class Registry:
    """Name-keyed get-or-create store of metric instruments."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}

    def _get_or_create(self, name: str, cls, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, *args)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge)

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        h = self._get_or_create(name, Histogram, buckets)
        return h

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def reset(self):
        """Zero every instrument IN PLACE (handles stay valid — the serve
        engines hold references across ``reset_stats`` calls)."""
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            m.reset()

    def snapshot(self) -> Dict[str, dict]:
        with self._lock:
            metrics = sorted(self._metrics.items())
        return {name: m.snapshot() for name, m in metrics}

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)


# Process-wide default registry (kernel dispatch, tune cache, executor).
REGISTRY = Registry()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str,
              buckets: Optional[Sequence[float]] = None) -> Histogram:
    return REGISTRY.histogram(name, buckets)


def snapshot() -> Dict[str, dict]:
    return REGISTRY.snapshot()


def to_json(indent: Optional[int] = None) -> str:
    return REGISTRY.to_json(indent)
