"""Span tracer with Chrome trace-event export (Perfetto / chrome://tracing).

One process-wide :data:`TRACER` collects duration events ("B"/"E" pairs)
from every instrumented layer — serve-engine request lifecycles, decode
rounds, executor layers, autotuner candidates — and exports them as the
Chrome trace-event JSON format, which loads directly in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``.

Enabling: tracing is OFF by default and gated by the ``REPRO_TRACE`` env
var (any value other than ``""``/``"0"``), read once when the tracer is
constructed; :func:`enable`/:func:`disable` toggle it programmatically.
When disabled every entry point is a near-zero-cost no-op — ``span()``
returns a shared null context manager after one attribute check, and
``begin``/``end``/``complete`` return immediately — so instrumented hot
paths (the serve engines' per-round loops) carry no measurable overhead
with tracing off.

Clocks: event timestamps come from ``time.perf_counter()`` (monotonic, so
intervals can never go negative under wall-clock adjustment), rebased to
the tracer's construction instant and expressed in microseconds as the
trace format requires. The wall-clock time of that instant is recorded in
the export's ``otherData`` so absolute times are recoverable.

Lanes: ``tid`` defaults to the real thread id, but callers may pass a
synthetic lane id — the serve engines replay each retired request's
lifecycle (queue-wait -> prefill -> generate) onto its own fresh lane, so
overlapping requests render as parallel tracks and B/E pairs still nest
properly per lane.
"""
from __future__ import annotations

import functools
import itertools
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

ENV_VAR = "REPRO_TRACE"

# Synthetic-lane allocator: lanes are process-unique so replayed request
# lifecycles from any engine never interleave on one track.
_LANE_BASE = 1 << 20
_lane_counter = itertools.count(1)


def _env_enabled() -> bool:
    return os.environ.get(ENV_VAR, "") not in ("", "0")


def next_lane() -> int:
    """A fresh synthetic tid for one replayed span stack (see module doc)."""
    return _LANE_BASE + next(_lane_counter)


class _NullSpan:
    """Shared do-nothing context manager returned when tracing is off."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        pass


_NULL_SPAN = _NullSpan()


class _Span:
    """Context manager emitting one balanced B/E pair on the owning tracer.

    Attributes passed at construction ride on the "B" event; attributes
    added via :meth:`set` during the span ride on the "E" event (Perfetto
    merges both into the slice's args).
    """
    __slots__ = ("_tr", "_name", "_tid", "_cat", "_attrs", "_exit_attrs")

    def __init__(self, tr: "Tracer", name: str, tid, cat: str, attrs: dict):
        self._tr = tr
        self._name = name
        self._tid = tid
        self._cat = cat
        self._attrs = attrs
        self._exit_attrs: Dict[str, Any] = {}

    def set(self, **attrs):
        """Attach attributes discovered mid-span (e.g. a measured time)."""
        self._exit_attrs.update(attrs)
        return self

    def __enter__(self):
        self._tr.begin(self._name, tid=self._tid, cat=self._cat,
                       **self._attrs)
        return self

    def __exit__(self, *exc):
        self._tr.end(self._name, tid=self._tid, cat=self._cat,
                     **self._exit_attrs)
        return False


class Tracer:
    """Thread-safe collector of Chrome trace duration events."""

    def __init__(self, enabled: Optional[bool] = None):
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._enabled = _env_enabled() if enabled is None else enabled
        self._t0 = time.perf_counter()
        self._wall_t0 = time.time()
        self._pid = os.getpid()

    # ------------------------------------------------------------- gating --

    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self):
        self._enabled = True

    def disable(self):
        self._enabled = False

    def clear(self):
        with self._lock:
            self._events = []

    # ------------------------------------------------------------ emitting --

    def _ts_us(self, t: Optional[float]) -> float:
        """perf_counter seconds (or now) -> trace-relative microseconds."""
        t = time.perf_counter() if t is None else t
        return (t - self._t0) * 1e6

    def _emit(self, ph: str, name: str, ts: Optional[float], tid, cat: str,
              attrs: dict):
        ev = {"ph": ph, "name": name, "cat": cat, "ts": self._ts_us(ts),
              "pid": self._pid,
              "tid": threading.get_ident() if tid is None else tid}
        if attrs:
            ev["args"] = dict(attrs)
        with self._lock:
            self._events.append(ev)

    def begin(self, name: str, *, ts: Optional[float] = None, tid=None,
              cat: str = "repro", **attrs):
        """Open a span. ``ts`` is an optional recorded perf_counter stamp."""
        if self._enabled:
            self._emit("B", name, ts, tid, cat, attrs)

    def end(self, name: str, *, ts: Optional[float] = None, tid=None,
            cat: str = "repro", **attrs):
        if self._enabled:
            self._emit("E", name, ts, tid, cat, attrs)

    def complete(self, name: str, t_start: float, t_end: float, *, tid=None,
                 cat: str = "repro", **attrs):
        """One balanced B/E pair from two recorded perf_counter stamps —
        how engines replay a request lifecycle at retirement."""
        if self._enabled:
            self._emit("B", name, t_start, tid, cat, attrs)
            self._emit("E", name, t_end, tid, cat, {})

    def span(self, name: str, *, tid=None, cat: str = "repro", **attrs):
        """Context manager measuring the enclosed block as one span."""
        if not self._enabled:
            return _NULL_SPAN
        return _Span(self, name, tid, cat, attrs)

    # ------------------------------------------------------------- export --

    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def to_chrome(self) -> dict:
        """The Chrome trace-event JSON object (load in Perfetto as-is)."""
        return {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {
                "wall_clock_t0": self._wall_t0,
                "pid": self._pid,
                "source": "repro.obs.trace",
            },
        }

    def export(self, path: str) -> str:
        """Write the trace JSON to ``path``; returns the path."""
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


# Process-wide tracer: the instance every instrumented layer emits to.
TRACER = Tracer()


def enabled() -> bool:
    return TRACER.enabled


def enable():
    TRACER.enable()


def disable():
    TRACER.disable()


def clear():
    TRACER.clear()


def span(name: str, *, tid=None, cat: str = "repro", **attrs):
    """Module-level span on the process tracer (the common call site)."""
    return TRACER.span(name, tid=tid, cat=cat, **attrs)


def export(path: str) -> str:
    return TRACER.export(path)


def traced(name: Optional[str] = None, *, cat: str = "repro"):
    """Decorator form: trace every call of ``fn`` as one span.

    ``@traced()`` uses the function's qualname; ``@traced("label")`` names
    the span explicitly. Disabled-mode cost is one attribute check.
    """
    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not TRACER.enabled:
                return fn(*args, **kwargs)
            with TRACER.span(label, cat=cat):
                return fn(*args, **kwargs)
        return wrapper
    return deco
