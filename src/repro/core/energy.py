"""Analytical cost / memory-access / energy models (paper Figs 2-3, Tables 3-4).

The paper measures latency with a scope and energy with a current shunt; on
a TPU target neither exists, so the framework carries first-principles
models with the SAME structure the paper validates empirically:

  * theoretical MACs / params per primitive   -> Table 1 (ConvSpec methods)
  * memory accesses, direct vs im2col-blocked -> Fig 3 ratio
  * MCU latency & power vs frequency          -> Fig 4 / Table 3
  * energy = P(f) * latency                   -> Fig 2 c/e
  * TPU v5e energy terms (per roofline op)    -> EXPERIMENTS.md §Roofline

MCU constants are calibrated to the paper's own Table 3 (linear fit of the
reported mW at 10/20/40/80 MHz); they reproduce the paper's headline claims
(MACs<->energy linearity without SIMD; latency as the better predictor with
SIMD) inside the model, which the benchmark harness then demonstrates.
"""
from __future__ import annotations

import dataclasses

from .primitives import ConvSpec

# --------------------------------------------------------------------------
# Memory-access model (element accesses for scalar path, 32-bit word accesses
# for the SIMD path — what the Cortex-M actually issues).
# --------------------------------------------------------------------------


def patch_len(spec: ConvSpec) -> int:
    """im2col column length K for the primitive's matmul stage."""
    if spec.primitive in ("standard", "add"):
        return spec.kernel_size ** 2 * spec.in_channels
    if spec.primitive == "grouped":
        return spec.kernel_size ** 2 * (spec.in_channels // spec.groups)
    if spec.primitive in ("dws", "shift"):
        return spec.in_channels          # pointwise stage
    raise AssertionError


def accesses_direct(spec: ConvSpec, out_width: int) -> int:
    """Scalar loop: 2 loads per MAC + 1 store per output element.

    For dws, depthwise and pointwise stages both follow the same pattern.
    For shift, the shift stage is 1 load + 1 store per input element.
    """
    hy2 = out_width ** 2
    macs = spec.mac_count(out_width)
    stores = hy2 * spec.out_channels
    extra = 0
    if spec.primitive == "dws":
        stores += hy2 * spec.in_channels           # intermediate map
    if spec.primitive == "shift":
        extra = 2 * hy2 * spec.in_channels         # shift copy in/out
    return 2 * macs + stores + extra


def accesses_im2col(spec: ConvSpec, out_width: int) -> float:
    """CMSIS-NN blocked path: per 2-column x 2-filter tile of the matmul,
    2K word loads produce 4K MACs (0.5 word/MAC) — the data-reuse engine the
    paper credits for the SIMD speedup. Patch construction costs
    K loads + K stores per output pixel. Add-conv has no SIMD path.
    """
    if spec.primitive == "add":
        return float(accesses_direct(spec, out_width))
    hy2 = out_width ** 2
    k = patch_len(spec)
    groups = spec.groups if spec.primitive == "grouped" else 1
    cy = spec.out_channels
    build = 0.0
    if spec.primitive in ("standard", "grouped", "shift"):
        build = 2.0 * k * hy2 * groups if spec.primitive == "grouped" else 2.0 * k * hy2
        # shift: construction gathers with per-channel offsets — same volume
    matmul_macs = hy2 * cy * k * (groups if spec.primitive == "grouped" else 1) / max(groups, 1)
    matmul_words = 0.5 * matmul_macs
    stores = hy2 * cy
    if spec.primitive == "dws":
        # depthwise stage stays scalar-ish (paper keeps NNoM dw), pointwise
        # needs no patch construction (K=Cx columns are the input rows).
        dw_spec = dataclasses.replace(spec, primitive="standard",
                                      in_channels=1, out_channels=1)
        dw = spec.in_channels * (2 * spec.kernel_size ** 2 * hy2 + hy2)
        return dw + matmul_words + stores
    return build + matmul_words + stores


def reuse_ratio(spec: ConvSpec, out_width: int) -> float:
    """Fig 3 quantity: (accesses without SIMD) / (accesses with SIMD), per MAC."""
    macs = spec.mac_count(out_width)
    return (accesses_direct(spec, out_width) / macs) / (accesses_im2col(spec, out_width) / macs)


# --------------------------------------------------------------------------
# MCU latency / power / energy model (STM32F401RE @ 3.3V)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MCUModel:
    # P(f) = p_static + p_per_mhz * f   — fit to paper Table 3
    p_static_mw: float = 11.0
    p_per_mhz_scalar: float = 0.513
    p_per_mhz_simd: float = 0.645
    # cycle model: scalar MAC ~ 5 cycles (ldr,ldr,mla,addr-arith); SMLAD does
    # 2 MACs/cycle with word loads amortized over the 2x2 tile.
    cycles_per_mac_scalar: float = 5.0
    cycles_per_mac_simd: float = 0.9
    cycles_per_access: float = 1.4       # paper: memory-access bound gaps
    o0_penalty_scalar: float = 1.52      # Table 4 optimization speedups
    o0_penalty_simd: float = 9.81

    def latency_s(self, spec: ConvSpec, out_width: int, *, simd: bool,
                  f_mhz: float = 84.0, opt: str = "Os") -> float:
        macs = spec.mac_count(out_width)
        if simd and spec.primitive != "add":
            cyc = (self.cycles_per_mac_simd * macs
                   + self.cycles_per_access * accesses_im2col(spec, out_width))
            if opt == "O0":
                cyc *= self.o0_penalty_simd
        else:
            cyc = (self.cycles_per_mac_scalar * macs
                   + self.cycles_per_access * accesses_direct(spec, out_width))
            if opt == "O0":
                cyc *= self.o0_penalty_scalar
        return cyc / (f_mhz * 1e6)

    def power_mw(self, *, simd: bool, f_mhz: float = 84.0) -> float:
        slope = self.p_per_mhz_simd if simd else self.p_per_mhz_scalar
        return self.p_static_mw + slope * f_mhz

    def energy_mj(self, spec: ConvSpec, out_width: int, *, simd: bool,
                  f_mhz: float = 84.0, opt: str = "Os") -> float:
        return self.power_mw(simd=simd, f_mhz=f_mhz) * self.latency_s(
            spec, out_width, simd=simd, f_mhz=f_mhz, opt=opt)


# --------------------------------------------------------------------------
# TPU v5e first-order hardware + energy constants (roofline terms)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TPUv5e:
    peak_bf16_flops: float = 197e12          # per chip
    hbm_bw: float = 819e9                    # B/s per chip
    ici_link_bw: float = 50e9                # B/s per link
    ici_links: int = 4                       # v5e 2D torus: 4 links/chip
    dcn_bw: float = 25e9                     # B/s per host pair (pod axis)
    vmem_bytes: int = 16 * 2 ** 20           # ~16 MiB more precisely 128 MB? v5e: 128 MiB? kept conservative
    hbm_bytes: int = 16 * 2 ** 30
    # order-of-magnitude energy terms (pJ) — used by the energy model only
    pj_per_flop: float = 0.35
    pj_per_hbm_byte: float = 6.0
    pj_per_ici_byte: float = 10.0
    static_w: float = 60.0

    def energy_j(self, flops: float, hbm_bytes: float, ici_bytes: float,
                 seconds: float) -> float:
        dyn = (flops * self.pj_per_flop + hbm_bytes * self.pj_per_hbm_byte
               + ici_bytes * self.pj_per_ici_byte) * 1e-12
        return dyn + self.static_w * seconds

    def roofline_terms(self, flops: float, hbm_bytes: float, ici_bytes: float):
        """Seconds spent in each bottleneck if perfectly overlapped."""
        return dict(
            compute_s=flops / self.peak_bf16_flops,
            memory_s=hbm_bytes / self.hbm_bw,
            collective_s=ici_bytes / (self.ici_links * self.ici_link_bw),
        )
