"""Power-of-two symmetric int8 quantization (paper Eq. 4 + Algorithm 1).

The paper writes Eq. 4 as::

    dec = ceil(log2(max |X_f|));   x_i = floor(x_f * 2^{(8-1)-dec})

i.e. the scale is 2^{dec-7}; ``frac_bits = 7 - dec`` is NNoM's "dec_bits"
(number of fractional bits). Algorithm 1's ``dec_*`` symbols are these
fractional-bit counts — rescaling between scales is then a plain arithmetic
shift, never a division. We carry ``frac_bits`` explicitly.

All integer paths use int32 accumulators and arithmetic right shifts,
mirroring the Cortex-M implementation; the same scheme feeds the int8 MXU
Pallas kernels (kernels/matmul_q8.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

INT8_MIN, INT8_MAX = -128, 127


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QTensor:
    """int8 values with a power-of-two scale: value ≈ q * 2^{-frac_bits}."""

    q: jax.Array                       # int8
    frac_bits: int = dataclasses.field(metadata=dict(static=True))

    @property
    def scale(self) -> float:
        return 2.0 ** (-self.frac_bits)

    def dequantize(self) -> jax.Array:
        return self.q.astype(jnp.float32) * self.scale


def frac_bits_for(x: jax.Array | float) -> int:
    """7 - ceil(log2(max|x|)) — static python int (calibration time)."""
    m = float(jnp.max(jnp.abs(x))) if hasattr(x, "shape") else abs(float(x))
    if m == 0.0:
        return 7
    return 7 - math.ceil(math.log2(m))


def quantize(x: jax.Array, frac_bits: Optional[int] = None) -> QTensor:
    """Eq. 4: floor(x * 2^{frac_bits}) clipped to int8."""
    fb = frac_bits_for(x) if frac_bits is None else frac_bits
    q = jnp.floor(x.astype(jnp.float32) * (2.0 ** fb))
    q = jnp.clip(q, INT8_MIN, INT8_MAX).astype(jnp.int8)
    return QTensor(q=q, frac_bits=fb)


def rshift_round(acc: jax.Array, shift: int) -> jax.Array:
    """Arithmetic right shift with round-to-nearest, as NNoM's default build:
    the ``+ (1 << (shift-1))`` term makes ``>>`` round to the nearest
    representable value (half-way cases toward +inf) instead of flooring.
    shift may be <= 0 (left shift, exact). The single rounding
    implementation: ``kernels.common.apply_requant`` (every Pallas kernel
    epilogue and jnp oracle) delegates here, so host-side and kernel-side
    requantization agree bit-for-bit by construction."""
    if shift > 0:
        return jnp.right_shift(acc + (1 << (shift - 1)), shift)
    if shift < 0:
        return jnp.left_shift(acc, -shift)
    return acc


def requantize(acc: jax.Array, acc_frac_bits: int, out_frac_bits: int) -> jax.Array:
    """int32 accumulator -> int8 at the output scale (Algorithm 1, line 3)."""
    shifted = rshift_round(acc, acc_frac_bits - out_frac_bits)
    return jnp.clip(shifted, INT8_MIN, INT8_MAX).astype(jnp.int8)


# --------------------------------------------------------------------------
# W4: packed sub-byte weights (two int4 codes per byte, per-group scales).
#
# Storage halves weight traffic (the paper's Fig. 3 reuse lever); kernels
# nibble-unpack in-register and then run the unchanged int8 body, so the
# packed path stays bit-exact against the unpacked-int8 oracle. Per-group
# power-of-two scales are folded into per-element left shifts relative to a
# single base ``frac_bits``: the expanded code ``q4 << shift`` is an
# ordinary int8 weight at the base scale, and all downstream requant
# arithmetic (Algorithm 1) is untouched.
# --------------------------------------------------------------------------

W4_MIN, W4_MAX = -8, 7
W4_MAX_GROUP_SHIFT = 4         # |q4| <= 8, 8 << 4 = 128: still an int8 code


def pack_w4(q: jax.Array, axis: int = 0) -> jax.Array:
    """Pack int4-valued codes (each in [-8, 7]) two-per-byte along ``axis``.

    Element ``2i`` lands in the low nibble of byte ``i``, element ``2i+1``
    in the high nibble; an odd extent is zero-padded. Output is int8 with
    ``shape[axis] = ceil(n / 2)``.
    """
    q = jnp.asarray(q)
    axis = axis % q.ndim
    n = q.shape[axis]
    if n % 2:
        pad = [(0, 0)] * q.ndim
        pad[axis] = (0, 1)
        q = jnp.pad(q, pad)
    qi = q.astype(jnp.int32)
    lo = jax.lax.slice_in_dim(qi, 0, None, stride=2, axis=axis)
    hi = jax.lax.slice_in_dim(qi, 1, None, stride=2, axis=axis)
    b = (lo & 0xF) | ((hi & 0xF) << 4)          # 0..255
    return jnp.where(b >= 128, b - 256, b).astype(jnp.int8)


def unpack_w4(packed: jax.Array, size: int, axis: int = 0) -> jax.Array:
    """Inverse of :func:`pack_w4`: nibble-packed int8 -> int8 codes in
    [-8, 7] with ``shape[axis] = size`` (the pad element, if any, dropped).
    """
    packed = jnp.asarray(packed)
    axis = axis % packed.ndim
    pi = packed.astype(jnp.int32)
    lo = jnp.right_shift(jnp.left_shift(pi, 28), 28)    # sign-extend bits 0-3
    hi = jnp.right_shift(jnp.left_shift(pi, 24), 28)    # sign-extend bits 4-7
    out = jnp.stack([lo, hi], axis=axis + 1)
    shape = list(packed.shape)
    shape[axis] = shape[axis] * 2
    out = out.reshape(shape)
    return jax.lax.slice_in_dim(out, 0, size, axis=axis).astype(jnp.int8)


def expand_w4(packed: jax.Array, shifts: jax.Array, size: int,
              axis: int = 0) -> jax.Array:
    """Unpack + apply the per-element group shifts: the unpacked-int8 oracle
    weights (``q4 << shift`` at the base scale). Always fits int8 because
    group shifts are clamped to :data:`W4_MAX_GROUP_SHIFT`."""
    w4 = unpack_w4(packed, size, axis).astype(jnp.int32)
    bshape = [1] * w4.ndim
    bshape[axis % w4.ndim] = size
    s = shifts.astype(jnp.int32).reshape(bshape)
    return jnp.left_shift(w4, s).astype(jnp.int8)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QTensorW4:
    """Nibble-packed int4 weights with per-group power-of-two scales.

    ``q`` holds two codes per byte along ``axis`` (extent ``ceil(size/2)``);
    ``shifts`` is the per-element left shift (one entry per unpacked element
    along ``axis``, constant within a scale group) that brings each group's
    codes to the shared base scale ``2^-frac_bits``. ``expand()`` is the
    int8 weight tensor every W4 kernel must match bit-for-bit.

    For lax.scan-stacked parameter trees the arrays carry an extra leading
    layer axis; ``axis``/``size`` describe the per-layer slice the consumer
    sees after scan slicing.
    """

    q: jax.Array                       # int8, nibble-packed along `axis`
    shifts: jax.Array                  # int8, shape (..., size) along `axis`
    frac_bits: int = dataclasses.field(metadata=dict(static=True))
    size: int = dataclasses.field(metadata=dict(static=True))
    axis: int = dataclasses.field(metadata=dict(static=True))

    @property
    def scale(self) -> float:
        return 2.0 ** (-self.frac_bits)

    def expand(self) -> jax.Array:
        """Unpacked int8 codes at the base scale (the W8 oracle weights)."""
        return expand_w4(self.q, self.shifts, self.size, self.axis)


def quantize_w4(w: jax.Array, *, axis: int = 0, group_size: int = 32,
                frac_bits: Optional[int] = None) -> QTensorW4:
    """Quantize float weights to packed int4 with per-group pow2 scales.

    Groups are ``group_size`` consecutive elements along ``axis`` (scales
    shared across every other axis). Each group g gets its natural int4
    scale ``fb_g = 3 - ceil(log2 max|w_g|)``, clamped so the group shift
    ``frac_bits - fb_g`` stays in [0, 4] (expanded codes must fit int8).
    The base ``frac_bits`` defaults to the finest usable common scale.
    """
    w = jnp.asarray(w)
    axis = axis % w.ndim
    n = w.shape[axis]
    if group_size <= 0:
        raise ValueError(f"quantize_w4: group_size must be > 0, "
                         f"got {group_size}")
    n_groups = -(-n // group_size)

    wa = jnp.moveaxis(w.astype(jnp.float32), axis, 0)
    natural = []
    for g in range(n_groups):
        m = float(jnp.max(jnp.abs(wa[g * group_size:(g + 1) * group_size])))
        # int4: 3 usable magnitude bits; zero groups get a large sentinel
        # that the clamp below pins to the base scale (codes are all zero).
        natural.append(3 - math.ceil(math.log2(m)) if m > 0.0 else 127)
    if frac_bits is None:
        lo, hi = min(natural), max(natural)
        frac_bits = min(lo + W4_MAX_GROUP_SHIFT, hi)

    q_groups, shift_groups = [], []
    for g, nat in enumerate(natural):
        fb_g = min(max(nat, frac_bits - W4_MAX_GROUP_SHIFT), frac_bits)
        q4 = jnp.floor(wa[g * group_size:(g + 1) * group_size] * (2.0 ** fb_g))
        q_groups.append(jnp.clip(q4, W4_MIN, W4_MAX).astype(jnp.int8))
        shift_groups.append(frac_bits - fb_g)
    q4 = jnp.moveaxis(jnp.concatenate(q_groups, axis=0), 0, axis)
    shifts = jnp.asarray(
        [shift_groups[i // group_size] for i in range(n)], jnp.int8)
    return QTensorW4(q=pack_w4(q4, axis), shifts=shifts,
                     frac_bits=frac_bits, size=n, axis=axis)


# --------------------------------------------------------------------------
# Algorithm 1 (left): multiplicative inner loop  out = (i*w) >> shift
# --------------------------------------------------------------------------

def mac_inner(x_q: jax.Array, w_q: jax.Array, fb_x: int, fb_w: int, fb_y: int):
    """Reference integer inner loop for one (input, weight) pair.

    Accumulator frac bits = fb_x + fb_w; output shift = fb_x + fb_w - fb_y.
    """
    acc = x_q.astype(jnp.int32) * w_q.astype(jnp.int32)
    return requantize(acc, fb_x + fb_w, fb_y)


# --------------------------------------------------------------------------
# Algorithm 1 (right): additive (AdderNet) inner loop.
# Operands must sit on a COMMON scale before |i - w|; align the coarser one
# by a left shift, accumulate at max(fb_x, fb_w) fractional bits.
# --------------------------------------------------------------------------

def addmac_align(x_q: jax.Array, w_q: jax.Array, fb_x: int, fb_w: int):
    """Return int32 operands aligned to a common scale + that scale's fb."""
    shift = abs(fb_x - fb_w)
    xi = x_q.astype(jnp.int32)
    wi = w_q.astype(jnp.int32)
    if fb_x > fb_w:        # weight is coarser: w << shift
        wi = jnp.left_shift(wi, shift)
        fb = fb_x
    elif fb_w > fb_x:      # input is coarser: i << shift
        xi = jnp.left_shift(xi, shift)
        fb = fb_w
    else:
        fb = fb_x
    return xi, wi, fb


def addmac_inner(x_q, w_q, fb_x: int, fb_w: int, fb_y: int):
    xi, wi, fb = addmac_align(x_q, w_q, fb_x, fb_w)
    acc = -jnp.abs(xi - wi)
    return requantize(acc, fb, fb_y)


# --------------------------------------------------------------------------
# Calibration helper: run a float fn on sample data, pick output frac bits.
# --------------------------------------------------------------------------

def calibrate(fn, *sample_args) -> int:
    out = fn(*sample_args)
    return frac_bits_for(out)


def quantize_params(params, frac_bits: Optional[dict] = None):
    """Quantize a pytree of float weights leaf-by-leaf (per-tensor scales)."""
    flat, treedef = jax.tree_util.tree_flatten(params)
    out = []
    for leaf in flat:
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            out.append(quantize(leaf))
        else:                      # e.g. shift tables stay int
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)
