"""Power-of-two symmetric int8 quantization (paper Eq. 4 + Algorithm 1).

The paper writes Eq. 4 as::

    dec = ceil(log2(max |X_f|));   x_i = floor(x_f * 2^{(8-1)-dec})

i.e. the scale is 2^{dec-7}; ``frac_bits = 7 - dec`` is NNoM's "dec_bits"
(number of fractional bits). Algorithm 1's ``dec_*`` symbols are these
fractional-bit counts — rescaling between scales is then a plain arithmetic
shift, never a division. We carry ``frac_bits`` explicitly.

All integer paths use int32 accumulators and arithmetic right shifts,
mirroring the Cortex-M implementation; the same scheme feeds the int8 MXU
Pallas kernels (kernels/matmul_q8.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

INT8_MIN, INT8_MAX = -128, 127


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QTensor:
    """int8 values with a power-of-two scale: value ≈ q * 2^{-frac_bits}."""

    q: jax.Array                       # int8
    frac_bits: int = dataclasses.field(metadata=dict(static=True))

    @property
    def scale(self) -> float:
        return 2.0 ** (-self.frac_bits)

    def dequantize(self) -> jax.Array:
        return self.q.astype(jnp.float32) * self.scale


def frac_bits_for(x: jax.Array | float) -> int:
    """7 - ceil(log2(max|x|)) — static python int (calibration time)."""
    m = float(jnp.max(jnp.abs(x))) if hasattr(x, "shape") else abs(float(x))
    if m == 0.0:
        return 7
    return 7 - math.ceil(math.log2(m))


def quantize(x: jax.Array, frac_bits: Optional[int] = None) -> QTensor:
    """Eq. 4: floor(x * 2^{frac_bits}) clipped to int8."""
    fb = frac_bits_for(x) if frac_bits is None else frac_bits
    q = jnp.floor(x.astype(jnp.float32) * (2.0 ** fb))
    q = jnp.clip(q, INT8_MIN, INT8_MAX).astype(jnp.int8)
    return QTensor(q=q, frac_bits=fb)


def rshift_round(acc: jax.Array, shift: int) -> jax.Array:
    """Arithmetic right shift with round-to-nearest, as NNoM's default build:
    the ``+ (1 << (shift-1))`` term makes ``>>`` round to the nearest
    representable value (half-way cases toward +inf) instead of flooring.
    shift may be <= 0 (left shift, exact). The single rounding
    implementation: ``kernels.common.apply_requant`` (every Pallas kernel
    epilogue and jnp oracle) delegates here, so host-side and kernel-side
    requantization agree bit-for-bit by construction."""
    if shift > 0:
        return jnp.right_shift(acc + (1 << (shift - 1)), shift)
    if shift < 0:
        return jnp.left_shift(acc, -shift)
    return acc


def requantize(acc: jax.Array, acc_frac_bits: int, out_frac_bits: int) -> jax.Array:
    """int32 accumulator -> int8 at the output scale (Algorithm 1, line 3)."""
    shifted = rshift_round(acc, acc_frac_bits - out_frac_bits)
    return jnp.clip(shifted, INT8_MIN, INT8_MAX).astype(jnp.int8)


# --------------------------------------------------------------------------
# Algorithm 1 (left): multiplicative inner loop  out = (i*w) >> shift
# --------------------------------------------------------------------------

def mac_inner(x_q: jax.Array, w_q: jax.Array, fb_x: int, fb_w: int, fb_y: int):
    """Reference integer inner loop for one (input, weight) pair.

    Accumulator frac bits = fb_x + fb_w; output shift = fb_x + fb_w - fb_y.
    """
    acc = x_q.astype(jnp.int32) * w_q.astype(jnp.int32)
    return requantize(acc, fb_x + fb_w, fb_y)


# --------------------------------------------------------------------------
# Algorithm 1 (right): additive (AdderNet) inner loop.
# Operands must sit on a COMMON scale before |i - w|; align the coarser one
# by a left shift, accumulate at max(fb_x, fb_w) fractional bits.
# --------------------------------------------------------------------------

def addmac_align(x_q: jax.Array, w_q: jax.Array, fb_x: int, fb_w: int):
    """Return int32 operands aligned to a common scale + that scale's fb."""
    shift = abs(fb_x - fb_w)
    xi = x_q.astype(jnp.int32)
    wi = w_q.astype(jnp.int32)
    if fb_x > fb_w:        # weight is coarser: w << shift
        wi = jnp.left_shift(wi, shift)
        fb = fb_x
    elif fb_w > fb_x:      # input is coarser: i << shift
        xi = jnp.left_shift(xi, shift)
        fb = fb_w
    else:
        fb = fb_x
    return xi, wi, fb


def addmac_inner(x_q, w_q, fb_x: int, fb_w: int, fb_y: int):
    xi, wi, fb = addmac_align(x_q, w_q, fb_x, fb_w)
    acc = -jnp.abs(xi - wi)
    return requantize(acc, fb, fb_y)


# --------------------------------------------------------------------------
# Calibration helper: run a float fn on sample data, pick output frac bits.
# --------------------------------------------------------------------------

def calibrate(fn, *sample_args) -> int:
    out = fn(*sample_args)
    return frac_bits_for(out)


def quantize_params(params, frac_bits: Optional[dict] = None):
    """Quantize a pytree of float weights leaf-by-leaf (per-tensor scales)."""
    flat, treedef = jax.tree_util.tree_flatten(params)
    out = []
    for leaf in flat:
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            out.append(quantize(leaf))
        else:                      # e.g. shift tables stay int
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)
