"""Batch-normalization folding (Jacob et al. 2018; paper §3.2).

Folds an inference-time BN layer into the preceding convolution's weights
and bias so the fused layer computes ``BN(conv(x))`` exactly:

    W' = W * gamma / sqrt(var + eps)        (per output channel)
    b' = beta + (b - mean) * gamma / sqrt(var + eps)

Applies to standard / grouped / shift (fold into the pointwise) / dws (fold
into the pointwise). NOT applicable to add-convolution — |W - x| is not
linear in W, so scaling W does not scale the output; the add-conv path keeps
its explicit BN (the paper reports the same limitation).
"""
from __future__ import annotations

import jax.numpy as jnp

from .primitives import ConvSpec

FOLDABLE = ("standard", "grouped", "dws", "shift")


def fold(conv_params: dict, bn: dict, spec: ConvSpec, eps: float = 1e-5) -> dict:
    if spec.primitive not in FOLDABLE:
        raise ValueError(f"BN folding not applicable to {spec.primitive!r} "
                         "(paper §3.2: add-conv keeps explicit BN)")
    inv = bn["gamma"] * (bn["var"] + eps) ** -0.5          # (Cy,)
    out = dict(conv_params)
    wkey = "w_pw" if spec.primitive in ("dws", "shift") else "w"
    w = conv_params[wkey]
    out[wkey] = (w * inv.astype(w.dtype)).astype(w.dtype)  # last dim = Cy
    b = conv_params.get("b", jnp.zeros(w.shape[-1], w.dtype))
    out["b"] = (bn["beta"] + (b - bn["mean"]) * inv).astype(w.dtype)
    return out
