"""Integer-only quantized forward paths for all five primitives.

Mirrors NNoM's execution model: int8 operands, int32 accumulation, one
arithmetic shift to the output scale (Algorithm 1), optional bias added at
accumulator scale. BN is folded beforehand for the multiplicative
primitives (folding.fold); add-conv keeps an explicit integer BN-free path
followed by a float BN (the paper's layout).

Dispatch: every primitive routes through the kernel layer
(``repro.kernels.ops``), so the quantized network runs the SAME schedules
(and the same ``repro.tune`` autotuned configs) as the float one:

* ``method="pallas"`` — the TPU kernels with their fused int8 epilogues,
  the analogue of the paper's CMSIS-NN/SIMD build;
* ``method="xla"`` — the pure-jnp integer oracles (``kernels.ref``), the
  direct / no-SIMD baseline.

Both methods accumulate in int32 and share ``kernels.common.apply_requant``,
so they are bit-exact against each other (tests/test_qconv.py). Layers the
kernel layer cannot express (stride != 1 or non-SAME padding) fall back to
a raw ``lax`` integer path under ``method="xla"`` and raise under
``method="pallas"``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from .primitives import ConvSpec, shift_channels, _DN
from .quantize import (QTensor, QTensorW4, addmac_align, quantize_w4,
                       requantize, rshift_round)


def _conv_int(x_q: jax.Array, w_q: jax.Array, *, stride=1, padding="SAME",
              groups=1) -> jax.Array:
    """int8 x int8 -> int32 convolution (the MXU-native contraction)."""
    return lax.conv_general_dilated(
        x_q.astype(jnp.int32), w_q.astype(jnp.int32), (stride, stride), padding,
        dimension_numbers=_DN, feature_group_count=groups,
    )


def _bias_acc(bias: Optional[QTensor], acc_fb: int) -> Optional[jax.Array]:
    """Bias rescaled to the int32 accumulator scale (Algorithm 1, line 2)."""
    if bias is None:
        return None
    return rshift_round(bias.q.astype(jnp.int32), bias.frac_bits - acc_fb)


def _add_preshifts(fb_x: int, fb_w: int):
    """Algorithm 1 (right) scale alignment: left-shift the coarser operand
    onto the finer scale; the accumulator then carries max(fb_x, fb_w)
    fractional bits (same arithmetic as quantize.addmac_align, but as static
    per-layer shifts the kernels can fuse)."""
    if fb_x > fb_w:
        return 0, fb_x - fb_w, fb_x
    if fb_w > fb_x:
        return fb_w - fb_x, 0, fb_w
    return 0, 0, fb_x


def _kernel_layer_ok(spec: ConvSpec) -> bool:
    return spec.stride == 1 and spec.padding == "SAME"


def _wq(w):
    """(weight array, w_shifts-or-None) for the kernel layer: a QTensorW4
    leaf stays nibble-packed (the kernels unpack in-register); a QTensor
    passes through. Scale math is identical either way — ``frac_bits`` is
    the W4 *base* scale, and the expanded codes live at exactly that
    scale."""
    if isinstance(w, QTensorW4):
        return w.q, w.shifts
    return w.q, None


def _expand_w4_qparams(qparams: dict) -> dict:
    """W4 leaves -> equivalent int8 QTensors (for the raw-lax fallback path,
    which has no packed-weight kernels)."""
    out = {}
    for k, v in qparams.items():
        if isinstance(v, QTensorW4):
            out[k] = QTensor(v.expand(), v.frac_bits)
        else:
            out[k] = v
    return out


def qconv_apply(qparams: dict, x: QTensor, spec: ConvSpec, out_frac_bits: int,
                *, method: str = "xla", act: Optional[str] = None,
                configs: Optional[dict] = None) -> QTensor:
    """Run one quantized primitive layer; returns int8 QTensor.

    ``method`` picks the execution engine in the kernel layer: ``"pallas"``
    (TPU kernels, fused requantization) or ``"xla"`` (jnp integer oracle).
    ``act="relu"`` fuses the activation into the layer's LAST kernel stage
    at accumulator scale (the graph executor's fused conv+BN+ReLU block).
    ``configs`` pins Pallas schedules per stage — ``{"main": {...}}`` for the
    single-kernel primitives, ``{"dw": ..., "pw": ...}`` for dws; only legal
    with ``method="pallas"`` (the oracle has no schedule knobs).
    """
    from repro.kernels import ops as K   # lazy: core must import without kernels

    if method not in ("pallas", "xla"):
        raise ValueError(f"unknown method {method!r}; expected 'pallas' or 'xla'")
    if configs is not None and method != "pallas":
        raise ValueError("qconv_apply: configs= pins Pallas schedules; "
                         "method='xla' has none (drop configs or use pallas)")
    p = spec.primitive
    bias = qparams.get("b")
    cfgs = configs or {}

    if not _kernel_layer_ok(spec):
        if method == "pallas":
            raise NotImplementedError(
                f"qconv_apply(method='pallas'): the Pallas kernel layer only "
                f"supports stride=1 SAME layers, got stride={spec.stride} "
                f"padding={spec.padding!r}; use method='xla'")
        return _qconv_apply_lax(_expand_w4_qparams(qparams), x, spec,
                                out_frac_bits, act=act)

    if p in ("standard", "grouped"):
        w = qparams["w"]
        wq, ws = _wq(w)
        groups = spec.groups if p == "grouped" else 1
        acc_fb = x.frac_bits + w.frac_bits
        y = K.conv2d(x.q, wq, _bias_acc(bias, acc_fb), groups=groups,
                     method=method, requant_shift=acc_fb - out_frac_bits,
                     act=act, config=cfgs.get("main"), w_shifts=ws)
        return QTensor(y, out_frac_bits)

    if p == "dws":
        w_dw, w_pw = qparams["w_dw"], qparams["w_pw"]
        wdq, wds = _wq(w_dw)
        wpq, wps = _wq(w_pw)
        # depthwise at an intermediate scale, then pointwise
        mid_fb = qparams.get("mid_frac_bits", out_frac_bits)
        h = K.depthwise2d(x.q, wdq, method=method,
                          requant_shift=x.frac_bits + w_dw.frac_bits - mid_fb,
                          config=cfgs.get("dw"), w_shifts=wds)
        acc_fb = mid_fb + w_pw.frac_bits
        y = K.conv2d(h, wpq, _bias_acc(bias, acc_fb), method=method,
                     requant_shift=acc_fb - out_frac_bits, act=act,
                     config=cfgs.get("pw"), w_shifts=wps)
        return QTensor(y, out_frac_bits)

    if p == "shift":
        # shift is pure data movement: exact in integer domain (paper's
        # point) — the Pallas kernel fuses it into the pointwise matmul
        w_pw = qparams["w_pw"]
        wpq, wps = _wq(w_pw)
        acc_fb = x.frac_bits + w_pw.frac_bits
        y = K.shift_conv2d(x.q, qparams["shifts"], wpq,
                           _bias_acc(bias, acc_fb), method=method,
                           requant_shift=acc_fb - out_frac_bits, act=act,
                           max_shift=spec.kernel_size // 2,
                           config=cfgs.get("main"), w_shifts=wps)
        return QTensor(y, out_frac_bits)

    if p == "add":
        w = qparams["w"]
        wq, ws = _wq(w)
        x_pre, w_pre, acc_fb = _add_preshifts(x.frac_bits, w.frac_bits)
        y = K.add_conv2d(x.q, wq, _bias_acc(bias, acc_fb), method=method,
                         requant_shift=acc_fb - out_frac_bits,
                         x_preshift=x_pre, w_preshift=w_pre, act=act,
                         config=cfgs.get("main"), w_shifts=ws)
        return QTensor(y, out_frac_bits)

    raise ValueError(p)


def _qconv_apply_lax(qparams: dict, x: QTensor, spec: ConvSpec,
                     out_frac_bits: int, act: Optional[str] = None) -> QTensor:
    """Raw-lax integer path for layer shapes outside the kernel layer's
    stride-1/SAME envelope — all five primitives, same Algorithm-1
    arithmetic as the ops dispatch (int32 accumulation, accumulator-scale
    bias, fused act, round-to-nearest requantization)."""
    from repro.kernels.common import apply_act

    p = spec.primitive
    bias = qparams.get("b")

    def finish(acc, acc_fb):
        b_acc = _bias_acc(bias, acc_fb)
        if b_acc is not None:
            acc = acc + b_acc
        acc = apply_act(acc, act)
        return QTensor(requantize(acc, acc_fb, out_frac_bits), out_frac_bits)

    if p in ("standard", "grouped"):
        w = qparams["w"]
        groups = spec.groups if p == "grouped" else 1
        acc = _conv_int(x.q, w.q, stride=spec.stride, padding=spec.padding,
                        groups=groups)
        return finish(acc, x.frac_bits + w.frac_bits)

    if p == "dws":
        w_dw, w_pw = qparams["w_dw"], qparams["w_pw"]
        mid_fb = qparams.get("mid_frac_bits", out_frac_bits)
        acc = _conv_int(x.q, jnp.transpose(w_dw.q, (0, 1, 3, 2)),
                        stride=spec.stride, padding=spec.padding,
                        groups=spec.in_channels)
        h = requantize(acc, x.frac_bits + w_dw.frac_bits, mid_fb)
        acc2 = _conv_int(h, w_pw.q, stride=1, padding="SAME")
        return finish(acc2, mid_fb + w_pw.frac_bits)

    if p == "shift":
        w_pw = qparams["w_pw"]
        shifted = shift_channels(x.q, qparams["shifts"],
                                 max_shift=spec.kernel_size // 2)
        acc = _conv_int(shifted, w_pw.q, stride=spec.stride, padding="SAME")
        return finish(acc, x.frac_bits + w_pw.frac_bits)

    if p == "add":
        w = qparams["w"]
        hk, cx = spec.kernel_size, spec.in_channels
        pads = ((hk // 2, (hk - 1) // 2),) * 2 if spec.padding == "SAME" \
            else ((0, 0), (0, 0))
        patches = lax.conv_general_dilated_patches(
            x.q.astype(jnp.int32), (hk, hk), (1, 1), pads,
            dimension_numbers=_DN)
        b, hy, wy, _ = patches.shape
        patches = patches.reshape(b, hy, wy, cx, hk * hk)
        wk = jnp.transpose(w.q, (2, 0, 1, 3)) \
            .reshape(cx, hk * hk, spec.out_channels).astype(jnp.int32)
        xi, wi, acc_fb = addmac_align(patches[..., None], wk[None, None, None],
                                      x.frac_bits, w.frac_bits)
        acc = -jnp.sum(jnp.abs(xi - wi), axis=(3, 4))
        return finish(acc, acc_fb)

    raise ValueError(p)


def _w4_axis(key: str, v) -> int:
    """W4 packing axis per parameter key: the axis the kernels unpack along
    — always one the grid does NOT block (input channels for the
    matmul-family weights — ``ndim - 2`` so 2D pointwise layouts work too —
    tap rows for depthwise, so channels keep the 128-lane axis)."""
    return 0 if key == "w_dw" else v.ndim - 2


def quantize_conv_params(params: dict, spec: ConvSpec, *, bits: int = 8,
                         group_size: int = 32) -> dict:
    """Power-of-two PTQ of a float primitive layer.

    ``bits=8`` (default): per-tensor int8 QTensors, as before. ``bits=4``:
    weight tensors become nibble-packed :class:`QTensorW4` with per-group
    scales (``group_size`` consecutive elements along the unpack axis);
    biases stay int8 (they are added at int32 accumulator scale, packing
    them buys nothing). ``qconv_apply`` routes W4 leaves to the packed
    kernel paths; the raw-lax fallback expands them first.
    """
    from .quantize import quantize
    if bits not in (8, 4):
        raise ValueError(f"quantize_conv_params: bits must be 8 or 4, "
                         f"got {bits}")
    out = {}
    for k, v in params.items():
        if k == "shifts":
            out[k] = v
        elif bits == 4 and k in ("w", "w_dw", "w_pw"):
            out[k] = quantize_w4(v, axis=_w4_axis(k, v),
                                 group_size=group_size)
        else:
            out[k] = quantize(v)
    return out
