"""Integer-only quantized forward paths for all five primitives.

Mirrors NNoM's execution model: int8 operands, int32 accumulation, one
arithmetic shift to the output scale (Algorithm 1), optional bias added at
accumulator scale. BN is folded beforehand for the multiplicative
primitives (folding.fold); add-conv keeps an explicit integer BN-free path
followed by a float BN (the paper's layout).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from .primitives import ConvSpec, shift_channels, _DN
from .quantize import QTensor, addmac_align, requantize, rshift_round


def _conv_int(x_q: jax.Array, w_q: jax.Array, *, stride=1, padding="SAME",
              groups=1) -> jax.Array:
    """int8 x int8 -> int32 convolution (the MXU-native contraction)."""
    return lax.conv_general_dilated(
        x_q.astype(jnp.int32), w_q.astype(jnp.int32), (stride, stride), padding,
        dimension_numbers=_DN, feature_group_count=groups,
    )


def _bias_at(acc: jax.Array, bias: Optional[QTensor], acc_fb: int) -> jax.Array:
    if bias is None:
        return acc
    b = rshift_round(bias.q.astype(jnp.int32), bias.frac_bits - acc_fb)
    return acc + b


def qconv_apply(qparams: dict, x: QTensor, spec: ConvSpec, out_frac_bits: int) -> QTensor:
    """Run one quantized primitive layer; returns int8 QTensor."""
    p = spec.primitive
    bias = qparams.get("b")

    if p in ("standard", "grouped"):
        w = qparams["w"]
        groups = spec.groups if p == "grouped" else 1
        acc_fb = x.frac_bits + w.frac_bits
        acc = _conv_int(x.q, w.q, stride=spec.stride, padding=spec.padding,
                        groups=groups)
        acc = _bias_at(acc, bias, acc_fb)
        return QTensor(requantize(acc, acc_fb, out_frac_bits), out_frac_bits)

    if p == "dws":
        w_dw, w_pw = qparams["w_dw"], qparams["w_pw"]
        # depthwise at an intermediate scale, then pointwise
        mid_fb = qparams.get("mid_frac_bits", out_frac_bits)
        acc = _conv_int(x.q, jnp.transpose(w_dw.q, (0, 1, 3, 2)),
                        stride=spec.stride, padding=spec.padding,
                        groups=spec.in_channels)
        h = QTensor(requantize(acc, x.frac_bits + w_dw.frac_bits, mid_fb), mid_fb)
        acc2 = _conv_int(h.q, w_pw.q, stride=1, padding="SAME")
        acc_fb = h.frac_bits + w_pw.frac_bits
        acc2 = _bias_at(acc2, bias, acc_fb)
        return QTensor(requantize(acc2, acc_fb, out_frac_bits), out_frac_bits)

    if p == "shift":
        # shift is pure data movement: exact in integer domain (paper's point)
        shifted = shift_channels(x.q, qparams["shifts"],
                                 max_shift=spec.kernel_size // 2)
        w_pw = qparams["w_pw"]
        acc_fb = x.frac_bits + w_pw.frac_bits
        acc = _conv_int(shifted, w_pw.q, stride=spec.stride, padding="SAME")
        acc = _bias_at(acc, bias, acc_fb)
        return QTensor(requantize(acc, acc_fb, out_frac_bits), out_frac_bits)

    if p == "add":
        w = qparams["w"]
        hk, cx, cy = spec.kernel_size, spec.in_channels, spec.out_channels
        pads = ((hk // 2, (hk - 1) // 2),) * 2 if spec.padding == "SAME" else ((0, 0), (0, 0))
        patches = lax.conv_general_dilated_patches(
            x.q.astype(jnp.int32), (hk, hk), (1, 1), pads, dimension_numbers=_DN)
        b, hy, wy, _ = patches.shape
        patches = patches.reshape(b, hy, wy, cx, hk * hk)
        wk = jnp.transpose(w.q, (2, 0, 1, 3)).reshape(cx, hk * hk, cy).astype(jnp.int32)
        xi, wi, acc_fb = addmac_align(patches[..., None], wk[None, None, None],
                                      x.frac_bits, w.frac_bits)
        acc = -jnp.sum(jnp.abs(xi - wi), axis=(3, 4))
        acc = _bias_at(acc, bias, acc_fb)
        return QTensor(requantize(acc, acc_fb, out_frac_bits), out_frac_bits)

    raise ValueError(p)


def quantize_conv_params(params: dict, spec: ConvSpec) -> dict:
    """Per-tensor power-of-two PTQ of a float primitive layer."""
    from .quantize import quantize
    out = {}
    for k, v in params.items():
        if k == "shifts":
            out[k] = v
        else:
            out[k] = quantize(v)
    return out
