"""The paper's five convolution primitives, as composable JAX layers.

Float reference semantics (NHWC, square kernels, SAME padding by default),
matching §2.2 of Nguyen et al. 2023:

  * standard   : dense 2-D convolution (Eq. 1)
  * grouped    : G filter groups (Ioannou et al.)
  * dws        : depthwise-separable = depthwise + pointwise (Szegedy et al.)
  * shift      : per-channel spatial shift + pointwise (Jeon & Kim)
  * add        : L1-distance "AdderNet" convolution (Chen et al., Eq. 3)

Every primitive exposes ``init(key, spec)`` / ``apply(params, x)`` with a
common :class:`ConvSpec`, so models select a primitive by name (the way the
paper swaps NNoM layer implementations).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

Primitives = ("standard", "grouped", "dws", "shift", "add")

# NHWC activations, HWIO weights.
_DN = ("NHWC", "HWIO", "NHWC")


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Structural description of one convolution layer (paper Table 2 axes)."""

    primitive: str = "standard"
    in_channels: int = 16
    out_channels: int = 16
    kernel_size: int = 3
    groups: int = 1           # grouped only
    stride: int = 1
    padding: str = "SAME"
    use_bias: bool = True
    dtype: jnp.dtype = jnp.float32

    def __post_init__(self):
        if self.primitive not in Primitives:
            raise ValueError(f"unknown primitive {self.primitive!r}")
        if self.primitive == "grouped":
            if self.in_channels % self.groups or self.out_channels % self.groups:
                raise ValueError("groups must divide both channel counts")
        if self.primitive in ("dws", "shift") and self.padding != "SAME":
            raise ValueError(f"{self.primitive} requires SAME padding")

    # ---- paper Table 1: analytic parameter / MAC counts -----------------
    def param_count(self) -> int:
        hk2 = self.kernel_size ** 2
        cx, cy = self.in_channels, self.out_channels
        if self.primitive == "standard":
            return hk2 * cx * cy
        if self.primitive == "grouped":
            return hk2 * (cx // self.groups) * cy
        if self.primitive == "dws":
            return cx * (hk2 + cy)
        if self.primitive == "shift":
            return cx * (2 + cy)   # 2 shift ints per channel + pointwise
        if self.primitive == "add":
            return hk2 * cx * cy
        raise AssertionError

    def mac_count(self, out_width: int) -> int:
        hy2 = out_width ** 2
        hk2 = self.kernel_size ** 2
        cx, cy = self.in_channels, self.out_channels
        if self.primitive == "standard":
            return hk2 * cx * hy2 * cy
        if self.primitive == "grouped":
            return hk2 * (cx // self.groups) * hy2 * cy
        if self.primitive == "dws":
            return cx * hy2 * (hk2 + cy)
        if self.primitive == "shift":
            return cx * cy * hy2
        if self.primitive == "add":
            return hk2 * cx * hy2 * cy
        raise AssertionError


# --------------------------------------------------------------------------
# Parameter initialisation
# --------------------------------------------------------------------------

def init(key: jax.Array, spec: ConvSpec) -> dict:
    """He-normal weights for the given primitive."""
    hk, cx, cy = spec.kernel_size, spec.in_channels, spec.out_channels
    dt = spec.dtype
    ks = jax.random.split(key, 4)

    def he(k, shape, fan_in):
        return (jax.random.normal(k, shape) * (2.0 / fan_in) ** 0.5).astype(dt)

    params: dict = {}
    if spec.primitive == "standard":
        params["w"] = he(ks[0], (hk, hk, cx, cy), hk * hk * cx)
    elif spec.primitive == "grouped":
        params["w"] = he(ks[0], (hk, hk, cx // spec.groups, cy), hk * hk * cx // spec.groups)
    elif spec.primitive == "dws":
        params["w_dw"] = he(ks[0], (hk, hk, cx, 1), hk * hk)
        params["w_pw"] = he(ks[1], (1, 1, cx, cy), cx)
    elif spec.primitive == "shift":
        # Jeon & Kim: shifts are assigned, not learned: distribute channels
        # uniformly over the Hk×Hk displacement grid.
        disp = hk // 2
        grid = [(a, b) for a in range(-disp, disp + 1) for b in range(-disp, disp + 1)]
        shifts = jnp.array([grid[i % len(grid)] for i in range(cx)], jnp.int32)
        params["shifts"] = shifts
        params["w_pw"] = he(ks[1], (1, 1, cx, cy), cx)
    elif spec.primitive == "add":
        params["w"] = he(ks[0], (hk, hk, cx, cy), hk * hk * cx)
    if spec.use_bias:
        params["b"] = jnp.zeros((cy,), dt)
    return params


# --------------------------------------------------------------------------
# Forward passes
# --------------------------------------------------------------------------

def _maybe_bias(y, params):
    b = params.get("b")
    return y if b is None else y + b.astype(y.dtype)


def standard_conv(x, w, *, stride=1, padding="SAME", groups=1, preferred=None):
    return lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=_DN, feature_group_count=groups,
        preferred_element_type=preferred,
    )


def depthwise_conv(x, w_dw, *, stride=1, padding="SAME", preferred=None):
    cx = x.shape[-1]
    # HWIO depthwise: (hk, hk, cx, 1) -> feature_group_count = cx needs
    # kernel shaped (hk, hk, 1, cx).
    w = jnp.transpose(w_dw, (0, 1, 3, 2))
    return lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=_DN, feature_group_count=cx,
        preferred_element_type=preferred,
    )


def shift_channels(x, shifts, *, max_shift: Optional[int] = None):
    """Per-channel spatial shift (Eq. 2): I[k,l,m] = X[k+a_m, l+b_m, m].

    Zero padding at the borders, matching the paper's SAME-padded reading.
    Implemented as a gather on a padded tensor so it vmaps/shards cleanly.

    The padding bound must be a Python int. With a concrete shift table it
    is read off the table; under tracing (jit) callers must pass
    ``max_shift`` (``spec.kernel_size // 2`` for the paper's assignment) —
    a silent fixed bound would corrupt results for larger displacements.
    """
    b, h, w, c = x.shape
    try:                      # concrete shift table: tight padding bound
        pad = max(1, int(jnp.max(jnp.abs(shifts))) if shifts.size else 1)
    except (jax.errors.TracerArrayConversionError,
            jax.errors.ConcretizationTypeError):
        if max_shift is None:
            raise ValueError(
                "shift_channels: the shift table is traced, so the padding "
                "bound cannot be derived from its values; pass "
                "max_shift=spec.kernel_size // 2 (the maximum |shift| the "
                "table can contain).")
        pad = max(1, int(max_shift))
    if max_shift is not None and pad > max(1, int(max_shift)):
        raise ValueError(
            f"shift_channels: shift table contains |shift|={pad} exceeding "
            f"the declared max_shift={int(max_shift)}")
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    rows = jnp.arange(h)[:, None, None] + pad + shifts[None, None, :, 0]
    cols = jnp.arange(w)[None, :, None] + pad + shifts[None, None, :, 1]
    chan = jnp.arange(c)[None, None, :]
    return xp[:, rows, cols, chan]


def add_conv(x, w, *, padding="SAME"):
    """AdderNet convolution (Eq. 3): Y = -Σ |W - patch|, via patch extraction."""
    hk = w.shape[0]
    cx, cy = w.shape[2], w.shape[3]
    pads = ((hk // 2, (hk - 1) // 2), (hk // 2, (hk - 1) // 2)) if padding == "SAME" else ((0, 0), (0, 0))
    patches = lax.conv_general_dilated_patches(
        x, (hk, hk), (1, 1), pads, dimension_numbers=_DN,
    )  # (B, Hy, Wy, Cx*Hk*Hk) — feature dim ordered (C, kh, kw)
    bsz, hy, wy, _ = patches.shape
    patches = patches.reshape(bsz, hy, wy, cx, hk * hk)
    wk = jnp.transpose(w, (2, 0, 1, 3)).reshape(cx, hk * hk, cy)
    # -Σ_{c,k} |patch[..., c, k] - w[c, k, n]|
    diff = jnp.abs(patches[..., None] - wk[None, None, None])
    return -jnp.sum(diff, axis=(3, 4))


def apply(params: dict, x: jax.Array, spec: ConvSpec) -> jax.Array:
    """Run one primitive layer forward (float path)."""
    p = spec.primitive
    if p == "standard":
        y = standard_conv(x, params["w"], stride=spec.stride, padding=spec.padding)
    elif p == "grouped":
        y = standard_conv(x, params["w"], stride=spec.stride, padding=spec.padding,
                          groups=spec.groups)
    elif p == "dws":
        h = depthwise_conv(x, params["w_dw"], stride=spec.stride, padding=spec.padding)
        y = standard_conv(h, params["w_pw"], stride=1, padding="SAME")
    elif p == "shift":
        h = shift_channels(x, params["shifts"], max_shift=spec.kernel_size // 2)
        y = standard_conv(h, params["w_pw"], stride=spec.stride, padding="SAME")
    elif p == "add":
        y = add_conv(x, params["w"], padding=spec.padding)
    else:
        raise ValueError(p)
    return _maybe_bias(y, params)


# --------------------------------------------------------------------------
# Conv + BatchNorm block (paper couples every primitive with BN; add-conv
# REQUIRES BN to recover positive activations, §2.2)
# --------------------------------------------------------------------------

def init_block(key, spec: ConvSpec, with_bn: bool = True) -> dict:
    kc, _ = jax.random.split(key)
    params = {"conv": init(kc, spec)}
    if with_bn:
        cy = spec.out_channels
        params["bn"] = {
            "gamma": jnp.ones((cy,), spec.dtype),
            "beta": jnp.zeros((cy,), spec.dtype),
            "mean": jnp.zeros((cy,), jnp.float32),
            "var": jnp.ones((cy,), jnp.float32),
        }
    return params


def batchnorm_apply(bn: dict, y: jax.Array, eps: float = 1e-5) -> jax.Array:
    inv = lax.rsqrt(bn["var"] + eps).astype(y.dtype)
    return (y - bn["mean"].astype(y.dtype)) * inv * bn["gamma"].astype(y.dtype) + bn["beta"].astype(y.dtype)


def apply_block(params: dict, x: jax.Array, spec: ConvSpec, *, train_stats=None,
                act=jax.nn.relu) -> jax.Array:
    y = apply(params["conv"], x, spec)
    if "bn" in params:
        if train_stats is not None:
            # batch statistics (training); caller owns the EMA update
            mean = jnp.mean(y, axis=(0, 1, 2))
            var = jnp.var(y, axis=(0, 1, 2))
            train_stats["mean"], train_stats["var"] = mean, var
            bn = dict(params["bn"], mean=mean, var=var)
        else:
            bn = params["bn"]
        y = batchnorm_apply(bn, y)
    return act(y) if act is not None else y
