"""Core: the paper's convolution primitives, quantization, folding, cost models."""
from .primitives import (ConvSpec, Primitives, apply, apply_block, init,
                         init_block, add_conv, depthwise_conv, shift_channels,
                         standard_conv, batchnorm_apply)
from .quantize import (QTensor, QTensorW4, quantize, requantize,
                       frac_bits_for, mac_inner, addmac_inner,
                       quantize_params, pack_w4, unpack_w4, expand_w4,
                       quantize_w4)
from .folding import fold, FOLDABLE
from .energy import MCUModel, TPUv5e, accesses_direct, accesses_im2col, reuse_ratio
from .qconv import qconv_apply, quantize_conv_params
