"""GQA attention: full (training), flash-scan (prefill), sharded-KV decode (SP).

Memory posture per shape cell (DESIGN.md §5):
  * train_4k   -> ``full`` einsum attention inside a remat'd layer; S=4k
                  scores fit VMEM/HBM budgets and stay differentiable.
  * prefill_32k-> ``flash``: lax.scan over KV blocks with online softmax;
                  O(S·block) memory, no S×S materialization. Inference-only,
                  so no custom VJP is needed.
  * decode_*   -> one-token attention against the KV cache; with SP the
                  cache's seq dim is sharded over "data" and partial
                  (m, l, o) statistics are combined with psum/pmax — the
                  collective payload is O(heads·d) not O(S).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def _gqa_scores(q, k):
    """q: (B,Sq,Hkv,G,D); k: (B,Sk,Hkv,D) -> (B,Hkv,G,Sq,Sk) f32."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                      preferred_element_type=jnp.float32)


def _split_gqa(q, n_kv):
    b, s, hq, d = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, d)


def full_attention(q, k, v, *, causal: bool, q_offset=0):
    """Einsum attention. q:(B,Sq,Hq,D), k/v:(B,Sk,Hkv,D) -> (B,Sq,Hq,D)."""
    b, sq, hq, d = q.shape
    n_kv = k.shape[2]
    qg = _split_gqa(q, n_kv) * (d ** -0.5)
    s = _gqa_scores(qg, k)
    if causal:
        qpos = q_offset + jnp.arange(sq)
        kpos = jnp.arange(k.shape[1])
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return o.reshape(b, sq, hq, d)


def _flash_fwd_scan(qg, k, v, *, causal, bk, q_offset):
    b, sq = qg.shape[0], qg.shape[1]
    n_kv, g, d = qg.shape[2], qg.shape[3], qg.shape[-1]
    sk = k.shape[1]
    nb = sk // bk
    qpos = q_offset + jnp.arange(sq)
    kb = jnp.moveaxis(k.reshape(b, nb, bk, n_kv, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nb, bk, n_kv, d), 1, 0)

    def step(carry, blk):
        m, l, acc = carry
        kc, vc, kk = blk
        s = _gqa_scores(qg, kc)                       # (B,Hkv,G,Sq,bk) f32
        if causal:
            kpos = kk * bk + jnp.arange(bk)
            s = jnp.where((qpos[:, None] >= kpos[None, :])[None, None, None],
                          s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(kc.dtype), vc).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, n_kv, g, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, n_kv, g, sq), jnp.float32),
            jnp.zeros((b, n_kv, g, sq, d), jnp.float32))
    (m, l, acc), _ = lax.scan(step, init, (kb, vb, jnp.arange(nb)))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-30))          # (B,Hkv,G,Sq)
    return o, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash(q, k, v, causal, bk, q_offset):
    qg = _split_gqa(q, k.shape[2]) * (q.shape[-1] ** -0.5)
    o, _ = _flash_fwd_scan(qg, k, v, causal=causal, bk=bk, q_offset=q_offset)
    b, sq, hq, d = q.shape
    return jnp.moveaxis(o, 3, 1).reshape(b, sq, hq, d).astype(q.dtype)


def _flash_vjp_fwd(q, k, v, causal, bk, q_offset):
    qg = _split_gqa(q, k.shape[2]) * (q.shape[-1] ** -0.5)
    o, lse = _flash_fwd_scan(qg, k, v, causal=causal, bk=bk, q_offset=q_offset)
    b, sq, hq, d = q.shape
    out = jnp.moveaxis(o, 3, 1).reshape(b, sq, hq, d).astype(q.dtype)
    return out, (q, k, v, o, lse)


def _flash_vjp_bwd(causal, bk, q_offset, res, do):
    """FlashAttention-style backward: re-scan KV blocks, recompute p from
    the saved logsumexp; O(Sq*bk) transient memory, no S^2 residuals."""
    q, k, v, o, lse = res
    b, sq, hq, d = q.shape
    n_kv = k.shape[2]
    g = hq // n_kv
    sk = k.shape[1]
    nb = sk // bk
    scale = d ** -0.5
    qg = (_split_gqa(q, n_kv) * scale).astype(jnp.float32)
    qg = jnp.moveaxis(qg, 1, 3)                        # (B,Hkv,G,Sq,D)
    dog = jnp.moveaxis(_split_gqa(do, n_kv), 1, 3).astype(jnp.float32)
    delta = jnp.sum(dog * o, axis=-1)                  # (B,Hkv,G,Sq)
    qpos = q_offset + jnp.arange(sq)
    kb = jnp.moveaxis(k.reshape(b, nb, bk, n_kv, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nb, bk, n_kv, d), 1, 0)

    def step(dq, blk):
        kc, vc, kk = blk
        s = jnp.einsum("bhgqd,bkhd->bhgqk", qg, kc.astype(jnp.float32))
        if causal:
            kpos = kk * bk + jnp.arange(bk)
            s = jnp.where((qpos[:, None] >= kpos[None, :])[None, None, None],
                          s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                # (B,Hkv,G,Sq,bk)
        dv = jnp.einsum("bhgqk,bhgqd->bkhd", p, dog).astype(v.dtype)
        dp = jnp.einsum("bhgqd,bkhd->bhgqk", dog, vc.astype(jnp.float32))
        ds = p * (dp - delta[..., None])
        dq_new = dq + jnp.einsum("bhgqk,bkhd->bhgqd", ds,
                                 kc.astype(jnp.float32)) * scale
        dk = jnp.einsum("bhgqk,bhgqd->bkhd", ds, qg).astype(k.dtype)
        return dq_new, (dk, dv)

    dq0 = jnp.zeros((b, n_kv, g, sq, d), jnp.float32)
    dq, (dks, dvs) = lax.scan(step, dq0, (kb, vb, jnp.arange(nb)))
    dq = jnp.moveaxis(dq, 3, 1).reshape(b, sq, hq, d).astype(q.dtype)
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, sk, n_kv, d)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, sk, n_kv, d)
    return dq, dk, dv


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention(q, k, v, *, causal: bool, block_k: int = 256,
                    q_offset=0):
    """Blockwise online-softmax attention (differentiable, custom VJP)."""
    sk = k.shape[1]
    bk = min(block_k, sk)
    while sk % bk:
        bk -= 1
    return _flash(q, k, v, causal, bk, q_offset)


def flash_attention_tri(q, k, v, *, block_k: int = 256, n_chunks: int = 8):
    """Causal flash with a static TRIANGLE schedule: q is split into
    n_chunks python-unrolled chunks; chunk i only visits KV blocks
    [0, (i+1)*Sq/n_chunks) — the fully-masked upper-rectangle work of the
    plain scan (≈2x FLOPs at long S) is never issued. §Perf lever."""
    b, sq, hq, d = q.shape
    nc = n_chunks
    while sq % nc:
        nc -= 1
    cq = sq // nc
    outs = []
    for i in range(nc):
        qc = q[:, i * cq:(i + 1) * cq]
        kv_end = (i + 1) * cq
        outs.append(flash_attention(qc, k[:, :kv_end], v[:, :kv_end],
                                    causal=True, block_k=min(block_k, kv_end),
                                    q_offset=i * cq))
    return jnp.concatenate(outs, axis=1)


def attention(q, k, v, *, causal: bool, impl: str = "full", q_offset=0,
              block_k: int = 256):
    if impl == "flash_tri" and causal and q.shape[1] == k.shape[1]:
        return flash_attention_tri(q, k, v, block_k=block_k)
    if impl in ("flash", "flash_tri"):
        return flash_attention(q, k, v, causal=causal, q_offset=q_offset,
                               block_k=block_k)
    return full_attention(q, k, v, causal=causal, q_offset=q_offset)


# ----------------------------------------------------- int8 KV cache ------
#
# Serving-time KV quantization (same Eq.-4 family as the paper's activation
# quantization, but with a per-token-per-head float scale instead of a
# global pow2 one): each cache position stores int8 codes plus one f32
# scale per (position, kv-head) — a 127-max symmetric quantizer over the
# head_dim vector. Storage is ~halved vs bf16 (1 byte/elem + scale/D), and
# the quantize-on-write / dequantize-on-read pair keeps the attention
# arithmetic itself unchanged. Per-token scales mean a slot refill or
# retirement never re-scales neighbouring positions — exactly the property
# continuous batching needs.

def quantize_kv(x):
    """x: (..., H, D) -> (int8 codes, f32 scales (..., H)).

    Symmetric per-(position, head) quantization: scale = amax/127 over the
    head_dim vector (1.0 for all-zero vectors so the codes stay zero)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q, scale, dtype):
    """Inverse of :func:`quantize_kv` (up to the rounding step)."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def decode_attention_q8(q, k_cache, v_cache, k_scale, v_scale, cache_len):
    """:func:`decode_attention` over an int8 KV cache: caches are int8
    (B,S,Hkv,D) + per-(position, head) f32 scales (B,S,Hkv); K/V are
    dequantized on read so masking/softmax numerics match the float path
    on the same codes."""
    k = dequantize_kv(k_cache, k_scale, q.dtype)
    v = dequantize_kv(v_cache, v_scale, q.dtype)
    return decode_attention(q, k, v, cache_len)


# ----------------------------------------------------- paged KV cache -----
#
# Block-pool layout (repro.serve kv_layout="paged"): K/V live in a pool of
# fixed-size pages, (num_blocks, block_size, Hkv, D) per layer, and each
# decode slot owns an ordered block table (max_len // block_size int32 ids)
# instead of a contiguous (S, Hkv, D) row. Gathering the pool rows by table
# reconstructs EXACTLY the contiguous cache a slot would have owned (same
# values at the same positions; table entries past the allocated span point
# at the reserved garbage block 0, whose positions are >= cache_len and
# therefore masked to an exact-zero softmax weight) — so the paged decode
# variants below are bit-identical to their contiguous counterparts by
# construction, provided block_size divides max_len.

def gather_kv_blocks(pool, block_table):
    """pool: (NB, bs, ...); block_table: (B, nb) int32 -> (B, nb*bs, ...).

    Row i of the result is slot i's logical cache: block_table[i, j] names
    the pool page holding positions [j*bs, (j+1)*bs)."""
    g = pool[block_table]                          # (B, nb, bs, ...)
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def decode_attention_paged(q, k_pool, v_pool, block_table, cache_len):
    """:func:`decode_attention` over a paged pool: per-layer pools are
    (NB, bs, Hkv, D); the gather-by-block-table view is bit-identical to the
    contiguous cache, so so is the attention output."""
    k = gather_kv_blocks(k_pool, block_table)
    v = gather_kv_blocks(v_pool, block_table)
    return decode_attention(q, k, v, cache_len)


def decode_attention_paged_q8(q, k_pool, v_pool, k_scale_pool, v_scale_pool,
                              block_table, cache_len):
    """:func:`decode_attention_q8` over an int8 paged pool: code pools are
    int8 (NB, bs, Hkv, D) with per-(position, head) f32 scale pools
    (NB, bs, Hkv); dequantize-on-read after the block-table gather."""
    k = gather_kv_blocks(k_pool, block_table)
    v = gather_kv_blocks(v_pool, block_table)
    ks = gather_kv_blocks(k_scale_pool, block_table)
    vs = gather_kv_blocks(v_scale_pool, block_table)
    return decode_attention_q8(q, k, v, ks, vs, cache_len)


# ------------------------------------------------------------- decoding ---

def decode_attention(q, k_cache, v_cache, cache_len):
    """One-token attention over a (possibly longer-than-filled) cache.

    q: (B,1,Hq,D); caches: (B,S,Hkv,D); cache_len: () int32, or (B,) int32
    for per-slot lengths (continuous batching) — row i masks positions
    >= cache_len[i], so stale K/V in retired/padded slots never scores.
    A slot with length 0 attends to nothing (uniform softmax over NEG_INF
    scores); its output is garbage but confined to its own row.
    """
    b, _, hq, d = q.shape
    n_kv = k_cache.shape[2]
    s = k_cache.shape[1]
    qg = _split_gqa(q, n_kv) * (d ** -0.5)
    sc = _gqa_scores(qg, k_cache)                       # (B,Hkv,G,1,S)
    cl = jnp.asarray(cache_len)
    if cl.ndim == 0:
        mask = (jnp.arange(s) < cl)[None, None, None, None]
    else:
        mask = (jnp.arange(s)[None, :] < cl[:, None])[:, None, None, None, :]
    sc = jnp.where(mask, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_cache)
    return o.reshape(b, 1, hq, d)


def decode_attention_partial(q, k_shard, v_shard, valid_mask):
    """Per-shard flash statistics for SP decode: returns (m, l, o_unnorm)."""
    n_kv = k_shard.shape[2]
    d = q.shape[-1]
    qg = _split_gqa(q, n_kv) * (d ** -0.5)
    sc = _gqa_scores(qg, k_shard)                       # (B,Hkv,G,1,Sloc)
    sc = jnp.where(valid_mask[None, None, None, None], sc, NEG_INF)
    m = jnp.max(sc, axis=-1)
    p = jnp.exp(sc - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(q.dtype), v_shard)
    return m, l, o.astype(jnp.float32)


def sp_combine(m, l, o, axis_name: str):
    """Combine per-shard (m, l, o·l-weighted) stats across the SP axis.

    Collective payload: 2 scalars + d floats per (head, query) — O(S/shards)
    compute, O(d) comms. This is the decode-side analogue of flash's online
    softmax, distributed over the mesh.
    """
    m_glob = lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_glob)
    l_glob = lax.psum(l * corr, axis_name)
    o_glob = lax.psum(o * corr[..., None], axis_name)
    return o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
