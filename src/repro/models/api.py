"""Unified model API: init / loss / prefill / decode / input_specs per family.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of that (arch x shape) cell — the dry-run lowers against these
without allocating anything. Modality frontends (vlm/audio) are STUBS: the
specs include precomputed patch/frame embeddings, per the assignment.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

from . import encdec as E
from . import transformer as T


def init_params(cfg: ModelConfig, key):
    if cfg.family == "encdec":
        return E.init_encdec(cfg, key)
    return T.init_lm(cfg, key)


def param_specs(cfg: ModelConfig):
    if cfg.family == "encdec":
        return E.encdec_param_specs(cfg)
    return T.lm_param_specs(cfg)


def loss_fn(cfg: ModelConfig, *, attn_impl="full", remat="full"):
    if cfg.family == "encdec":
        return functools.partial(E.encdec_loss, cfg=cfg, attn_impl=attn_impl,
                                 remat=remat)
    return functools.partial(T.lm_loss, cfg=cfg, attn_impl=attn_impl,
                             remat=remat)


def prefill_fn(cfg: ModelConfig, max_len: int, *, attn_impl="flash"):
    if cfg.family == "encdec":
        def fn(params, batch):
            return E.encdec_prefill(params, batch["frames"], batch["tokens"],
                                    cfg, max_len, attn_impl=attn_impl)
    else:
        def fn(params, batch):
            return T.prefill(params, batch["tokens"], cfg, max_len,
                             embeds=batch.get("embeds"), attn_impl=attn_impl)
    return fn


def decode_fn(cfg: ModelConfig, *, sp_axis: Optional[str] = None):
    if cfg.family == "encdec":
        return functools.partial(E.encdec_decode_step, cfg=cfg, sp_axis=sp_axis)
    return functools.partial(T.decode_step, cfg=cfg, sp_axis=sp_axis)


def cache_specs(cfg: ModelConfig):
    if cfg.family == "encdec":
        return E.encdec_cache_specs()
    return T.cache_specs(cfg)


# ------------------------------------------------------------ input specs --

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for the cell's step function inputs."""
    b, s = shape.global_batch, shape.seq_len
    cdt = jnp.dtype(cfg.compute_dtype)
    if shape.kind == "train":
        if cfg.family == "vlm":
            p = cfg.frontend_positions
            return {"tokens": _sds((b, s - p), jnp.int32),
                    "embeds": _sds((b, p, cfg.d_model), cdt)}
        if cfg.family == "encdec":
            # split budget: encoder frames S, decoder tokens S (paper-style AST)
            return {"frames": _sds((b, s, cfg.d_model), cdt),
                    "tokens": _sds((b, s), jnp.int32)}
        return {"tokens": _sds((b, s), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.family == "vlm":
            p = cfg.frontend_positions
            return {"tokens": _sds((b, s - p), jnp.int32),
                    "embeds": _sds((b, p, cfg.d_model), cdt)}
        if cfg.family == "encdec":
            return {"frames": _sds((b, s, cfg.d_model), cdt),
                    "tokens": _sds((b, 128), jnp.int32)}
        return {"tokens": _sds((b, s), jnp.int32)}
    # decode: one new token against a cache of seq_len
    specs = {"token": _sds((b, 1), jnp.int32)}
    specs["cache"] = cache_structs(cfg, b, s)
    return specs


def cache_structs(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    # eval_shape: never allocates (decode caches reach tens of GiB)
    if cfg.family != "encdec":
        return jax.eval_shape(
            lambda: T.init_cache(cfg, batch, max_len, dtype))
    return jax.eval_shape(lambda: _encdec_cache_struct(cfg, batch, max_len))


def _encdec_cache_struct(cfg: ModelConfig, batch: int, max_len: int):
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    L = cfg.n_layers
    z = jnp.zeros
    return {"k": z((L, batch, max_len, hkv, dh), jnp.bfloat16),
            "v": z((L, batch, max_len, hkv, dh), jnp.bfloat16),
            "xk": z((L, batch, max_len, hkv, dh), jnp.bfloat16),
            "xv": z((L, batch, max_len, hkv, dh), jnp.bfloat16),
            "len": z((), jnp.int32)}


def param_structs(cfg: ModelConfig):
    """ShapeDtypeStructs of the parameter tree (eval_shape; no allocation)."""
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
