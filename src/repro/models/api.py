"""Unified model API: init / loss / prefill / decode / input_specs per family.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of that (arch x shape) cell — the dry-run lowers against these
without allocating anything. Modality frontends (vlm/audio) are STUBS: the
specs include precomputed patch/frame embeddings, per the assignment.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

from . import attention as A
from . import encdec as E
from . import transformer as T


def init_params(cfg: ModelConfig, key):
    if cfg.family == "encdec":
        return E.init_encdec(cfg, key)
    return T.init_lm(cfg, key)


def param_specs(cfg: ModelConfig):
    if cfg.family == "encdec":
        return E.encdec_param_specs(cfg)
    return T.lm_param_specs(cfg)


def loss_fn(cfg: ModelConfig, *, attn_impl="full", remat="full"):
    if cfg.family == "encdec":
        return functools.partial(E.encdec_loss, cfg=cfg, attn_impl=attn_impl,
                                 remat=remat)
    return functools.partial(T.lm_loss, cfg=cfg, attn_impl=attn_impl,
                             remat=remat)


def prefill_fn(cfg: ModelConfig, max_len: int, *, attn_impl="flash",
               precision: str = "float", attn_block_k: int = 256):
    if cfg.family == "encdec":
        if precision != "float":
            raise NotImplementedError("integer-FFN serve: encdec unsupported")
        def fn(params, batch):
            return E.encdec_prefill(params, batch["frames"], batch["tokens"],
                                    cfg, max_len, attn_impl=attn_impl)
    else:
        def fn(params, batch):
            return T.prefill(params, batch["tokens"], cfg, max_len,
                             embeds=batch.get("embeds"), attn_impl=attn_impl,
                             prompt_lens=batch.get("prompt_lens"),
                             precision=precision, attn_block_k=attn_block_k)
    return fn


def prefill_suffix_fn(cfg: ModelConfig, *, attn_impl="flash",
                      attn_block_k: int = 256, precision: str = "float"):
    """Prefix-cache hit path: run only the suffix of a prompt against
    gathered prefix K/V (see transformer.prefill_suffix). The prefix length
    is taken from ``batch["prefix_k"].shape[2]`` — jit once per
    (prefix_len, suffix_bucket) pair."""
    if cfg.family in ("ssm", "hybrid", "encdec"):
        raise NotImplementedError(
            "prefill_suffix covers attention-family dense layer stacks only")

    def fn(params, batch):
        pk = batch["prefix_k"]
        return T.prefill_suffix(params, batch["tokens"], pk,
                                batch["prefix_v"], pk.shape[2], cfg,
                                suffix_lens=batch["suffix_lens"],
                                attn_impl=attn_impl,
                                attn_block_k=attn_block_k,
                                precision=precision)
    return fn


def decode_fn(cfg: ModelConfig, *, sp_axis: Optional[str] = None,
              precision: str = "float"):
    if cfg.family == "encdec":
        if precision != "float":
            raise NotImplementedError("integer-FFN serve: encdec unsupported")
        return functools.partial(E.encdec_decode_step, cfg=cfg, sp_axis=sp_axis)
    return functools.partial(T.decode_step, cfg=cfg, sp_axis=sp_axis,
                             precision=precision)


def cache_specs(cfg: ModelConfig):
    if cfg.family == "encdec":
        return E.encdec_cache_specs()
    return T.cache_specs(cfg)


# ------------------------------------------------------- KV-slot surgery --
#
# The continuous-batching engine (repro.serve) keeps ONE live batched decode
# cache with per-slot sequence lengths, and splices freshly prefilled
# requests into free slots between decode rounds. These helpers own the
# cache-layout knowledge so the engine stays family-agnostic.


def slot_batch_axes(cfg: ModelConfig) -> dict:
    """Batch axis of every slotted cache leaf (``"len"`` excluded).

    Dense/moe/vlm caches are {k, v} with layout (L, B, S, Hkv, Dh); ssm
    recurrent state is (L, B, ...); hybrid stacks mamba state per super-block
    as (nb, nm, B, ...). encdec's cross-attention cache is not slotted.
    """
    if cfg.family == "encdec":
        raise NotImplementedError(
            "slot surgery: encdec cross-attention caches are per-batch, "
            "not per-slot; serve encdec through the static scheduler")
    if cfg.family == "ssm":
        return {"conv": 1, "ssm": 1}
    if cfg.family == "hybrid":
        return {"k": 1, "v": 1, "conv": 2, "ssm": 2}
    return {"k": 1, "v": 1}


def init_slot_cache(cfg: ModelConfig, batch: int, max_len: int,
                    dtype=jnp.bfloat16, kv: str = "float"):
    """A batched decode cache with per-slot lengths.

    Identical to ``transformer.init_cache`` except ``"len"`` is a (batch,)
    int32 vector — one logical sequence length per slot. Every slot starts
    empty: length 0 masks the entire row out of attention, so uninitialized
    K/V never pollutes a live sequence.

    ``kv="int8"`` stores K/V as int8 codes plus per-(position, head) f32
    scales (``k_scale``/``v_scale``, (L, B, S, Hkv)) — ~halved cache bytes.
    ``cache_write_slot`` quantizes prefilled float K/V on the way in and
    ``decode_step`` quantizes each new token's K/V at its own position
    (per-token scales: refill/retire never re-scales a neighbour).
    Attention-family dense caches only.
    """
    if kv not in ("float", "int8"):
        raise ValueError(f"init_slot_cache: kv must be 'float' or 'int8', "
                         f"got {kv!r}")
    if kv == "int8" and cfg.family in ("ssm", "hybrid", "encdec"):
        raise NotImplementedError(
            "int8 KV slot cache only covers attention-family dense caches")
    cache = T.init_cache(cfg, batch, max_len, dtype)
    if kv == "int8":
        sc = cache["k"].shape[:-1]          # (L, B, S, Hkv)
        cache["k"] = jnp.zeros(cache["k"].shape, jnp.int8)
        cache["v"] = jnp.zeros(cache["v"].shape, jnp.int8)
        cache["k_scale"] = jnp.ones(sc, jnp.float32)
        cache["v_scale"] = jnp.ones(sc, jnp.float32)
    cache["len"] = jnp.zeros((batch,), jnp.int32)
    return cache


def cache_write_slot(cfg: ModelConfig, live: dict, new: dict, slot,
                     src: int = 0) -> dict:
    """Write row ``src`` of a freshly prefilled cache into slot ``slot`` of a
    live batched cache: K/V (and recurrent state) plus the slot's position.

    ``slot`` may be a traced scalar, so a single jit of this function covers
    every slot index. ``new["len"]`` may be the scalar a plain prefill
    produces or the (B,) vector of a ``prompt_lens`` prefill.

    When ``live`` is an int8 KV cache (has ``k_scale``), the prefilled
    *float* K/V row is quantized on the way in — prefill always runs float;
    only the resident cache is int8.
    """
    out = dict(live)
    kv8 = "k_scale" in live
    for key, ax in slot_batch_axes(cfg).items():
        row = jnp.take(new[key], src, axis=ax)
        if kv8 and key in ("k", "v"):
            qrow, srow = A.quantize_kv(row)          # (L,S,Hkv,D) -> (L,S,Hkv)
            out[key] = live[key].at[:, slot].set(qrow)
            out[key + "_scale"] = live[key + "_scale"].at[:, slot].set(srow)
            continue
        row = row.astype(live[key].dtype)
        if ax == 1:
            out[key] = live[key].at[:, slot].set(row)
        else:
            out[key] = live[key].at[:, :, slot].set(row)
    nl = jnp.asarray(new["len"])
    if nl.ndim:
        nl = nl[src]
    out["len"] = live["len"].at[slot].set(nl.astype(jnp.int32))
    return out


def cache_free_slot(live: dict, slot) -> dict:
    """Retire a slot by zeroing its length — the per-slot attention mask
    makes the stale K/V unreachable, so no data movement is needed."""
    return dict(live, len=live["len"].at[slot].set(0))


# ----------------------------------------------------------- paged KV pool --
#
# The paged layout replaces per-slot (max_len,) KV rows with a shared pool
# of fixed-size pages: pools (L, num_blocks, block_size, Hkv, Dh) per K and
# V, plus one (B, max_len // block_size) int32 block table shared by every
# layer. Page 0 is RESERVED as the garbage page: it is never allocated, so
# a retired slot's zeroed table row scatters its (masked, never-read)
# decode writes there without touching any live page. The engine owns
# allocation host-side (serve.BlockPool) and re-uploads the table between
# decode rounds, exactly like the host-side ``len`` vector.


def init_paged_cache(cfg: ModelConfig, batch: int, num_blocks: int,
                     block_size: int, max_len: int, dtype=jnp.bfloat16,
                     kv: str = "float"):
    """A paged decode cache: K/V pools + per-slot block tables + lengths.

    ``kv="int8"`` stores pool pages as int8 codes plus per-(position, head)
    f32 scale pools (``k_scale``/``v_scale``, (L, NB, bs, Hkv)) — the same
    per-token quantization as the contiguous int8 slot cache, so gathered
    pages dequantize bit-identically. Attention-family dense caches only;
    ``block_size`` must divide ``max_len`` (the gathered view then has
    length exactly ``max_len``, which is what makes paged decode attention
    bit-identical to the contiguous path — see attention.gather_kv_blocks).
    """
    if kv not in ("float", "int8"):
        raise ValueError(f"init_paged_cache: kv must be 'float' or 'int8', "
                         f"got {kv!r}")
    if cfg.family in ("ssm", "hybrid", "encdec"):
        raise NotImplementedError(
            "paged KV cache only covers attention-family dense caches")
    if max_len % block_size:
        raise ValueError(f"init_paged_cache: block_size={block_size} must "
                         f"divide max_len={max_len}")
    if num_blocks < 2:
        raise ValueError("init_paged_cache: need >= 2 blocks (page 0 is the "
                         "reserved garbage page)")
    L, hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    shape = (L, num_blocks, block_size, hkv, dh)
    if kv == "int8":
        cache = {"k": jnp.zeros(shape, jnp.int8),
                 "v": jnp.zeros(shape, jnp.int8),
                 "k_scale": jnp.ones(shape[:-1], jnp.float32),
                 "v_scale": jnp.ones(shape[:-1], jnp.float32)}
    else:
        cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    cache["block_table"] = jnp.zeros((batch, max_len // block_size),
                                     jnp.int32)
    cache["len"] = jnp.zeros((batch,), jnp.int32)
    return cache


def _scatter_pages(live: dict, key: str, seg, ids):
    """Write a (L, n*bs, ...) contiguous segment into pages ``ids`` of pool
    leaf ``key`` (quantizing on the way in when the pool is int8)."""
    out = {}
    n, bs = len(ids), live["k"].shape[2]
    if "k_scale" in live and key in ("k", "v"):
        qseg, sseg = A.quantize_kv(seg)           # (L,n*bs,Hkv,D)->(L,n*bs,Hkv)
        L = qseg.shape[0]
        out[key] = live[key].at[:, ids].set(
            qseg.reshape((L, n, bs) + qseg.shape[2:]))
        out[key + "_scale"] = live[key + "_scale"].at[:, ids].set(
            sseg.reshape((L, n, bs) + sseg.shape[2:]))
    else:
        seg = seg.astype(live[key].dtype)
        L = seg.shape[0]
        out[key] = live[key].at[:, ids].set(
            seg.reshape((L, n, bs) + seg.shape[2:]))
    return out


def paged_write_prompt(cfg: ModelConfig, live: dict, new: dict, block_ids,
                       *, src: int = 0, skip_blocks: int = 0) -> dict:
    """Scatter row ``src`` of a freshly prefilled contiguous cache into pool
    pages ``block_ids`` of a paged cache.

    ``block_ids`` are the pages for prompt blocks ``skip_blocks ..
    skip_blocks + len(block_ids) - 1`` — a prefix-cache hit passes
    ``skip_blocks > 0`` to leave the shared (already-populated) leading
    pages untouched. Per-position quantization (int8 pools) makes the
    written codes/scales bit-identical to what ``cache_write_slot`` would
    have produced for the same positions, so paged and contiguous decode
    read the very same numbers. The caller updates the block table and
    ``len`` host-side (serve.BlockPool owns both).
    """
    if not len(block_ids):
        return dict(live)
    bs = live["k"].shape[2]
    ids = jnp.asarray(block_ids, jnp.int32)
    lo = skip_blocks * bs
    out = dict(live)
    for key in ("k", "v"):
        row = jnp.take(new[key], src, axis=1)               # (L, S, Hkv, D)
        seg = jax.lax.slice_in_dim(row, lo, lo + len(block_ids) * bs, axis=1)
        out.update(_scatter_pages(live, key, seg, ids))
    return out


def paged_write_kv(live: dict, k_new, v_new, block_ids, *,
                   src: int = 0) -> dict:
    """Scatter freshly computed K/V rows (L, B, S, Hkv, D — e.g. the suffix
    K/V out of ``prefill_suffix_fn``) into pool pages ``block_ids``,
    padding/truncating the sequence to the page span. Positions past the
    real length carry pad K/V exactly as the contiguous cache does —
    masked, never read."""
    if not len(block_ids):
        return dict(live)
    bs = live["k"].shape[2]
    ids = jnp.asarray(block_ids, jnp.int32)
    span = len(block_ids) * bs
    out = dict(live)
    for key, new in (("k", k_new), ("v", v_new)):
        row = jnp.take(new, src, axis=1)                    # (L, S, Hkv, D)
        s = row.shape[1]
        if s < span:
            row = jnp.pad(row, ((0, 0), (0, span - s), (0, 0), (0, 0)))
        elif s > span:
            row = jax.lax.slice_in_dim(row, 0, span, axis=1)
        out.update(_scatter_pages(live, key, row, ids))
    return out


def paged_gather_prefix(live: dict, block_ids):
    """Gather pages ``block_ids`` into contiguous (L, 1, n*bs, Hkv, D)
    prefix K/V for ``prefill_suffix_fn``. Float pools only: a dequantized
    int8 prefix is not the float prefix the donor computed, so int8 prefix
    hits recompute (storage-only sharing) instead of chaining."""
    if "k_scale" in live:
        raise NotImplementedError(
            "paged_gather_prefix: int8 pools share storage only — recompute "
            "the prompt and skip the shared-page writes")
    ids = jnp.asarray(block_ids, jnp.int32)
    outs = []
    for key in ("k", "v"):
        g = live[key][:, ids]                           # (L, n, bs, Hkv, D)
        L = g.shape[0]
        outs.append(g.reshape((L, 1, g.shape[1] * g.shape[2]) + g.shape[3:]))
    return tuple(outs)


# ------------------------------------------------------------ input specs --

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for the cell's step function inputs."""
    b, s = shape.global_batch, shape.seq_len
    cdt = jnp.dtype(cfg.compute_dtype)
    if shape.kind == "train":
        if cfg.family == "vlm":
            p = cfg.frontend_positions
            return {"tokens": _sds((b, s - p), jnp.int32),
                    "embeds": _sds((b, p, cfg.d_model), cdt)}
        if cfg.family == "encdec":
            # split budget: encoder frames S, decoder tokens S (paper-style AST)
            return {"frames": _sds((b, s, cfg.d_model), cdt),
                    "tokens": _sds((b, s), jnp.int32)}
        return {"tokens": _sds((b, s), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.family == "vlm":
            p = cfg.frontend_positions
            return {"tokens": _sds((b, s - p), jnp.int32),
                    "embeds": _sds((b, p, cfg.d_model), cdt)}
        if cfg.family == "encdec":
            return {"frames": _sds((b, s, cfg.d_model), cdt),
                    "tokens": _sds((b, 128), jnp.int32)}
        return {"tokens": _sds((b, s), jnp.int32)}
    # decode: one new token against a cache of seq_len
    specs = {"token": _sds((b, 1), jnp.int32)}
    specs["cache"] = cache_structs(cfg, b, s)
    return specs


def cache_structs(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    # eval_shape: never allocates (decode caches reach tens of GiB)
    if cfg.family != "encdec":
        return jax.eval_shape(
            lambda: T.init_cache(cfg, batch, max_len, dtype))
    return jax.eval_shape(lambda: _encdec_cache_struct(cfg, batch, max_len))


def _encdec_cache_struct(cfg: ModelConfig, batch: int, max_len: int):
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    L = cfg.n_layers
    z = jnp.zeros
    return {"k": z((L, batch, max_len, hkv, dh), jnp.bfloat16),
            "v": z((L, batch, max_len, hkv, dh), jnp.bfloat16),
            "xk": z((L, batch, max_len, hkv, dh), jnp.bfloat16),
            "xv": z((L, batch, max_len, hkv, dh), jnp.bfloat16),
            "len": z((), jnp.int32)}


def param_structs(cfg: ModelConfig):
    """ShapeDtypeStructs of the parameter tree (eval_shape; no allocation)."""
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
