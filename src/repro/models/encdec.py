"""Encoder-decoder backbone (SeamlessM4T-large-v2 [audio]).

The speech frontend is a STUB per the assignment: ``input_specs`` provides
precomputed fbank-frame embeddings (B, S_enc, d_model); the backbone here is
the full transformer enc-dec. Decoder self-attention is causal with a KV
cache; cross-attention K/V are computed once at prefill.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.parallel.sharding import constrain

from . import attention as A
from .blocks import cross_entropy, init_mlp, mlp, mlp_specs, rmsnorm
from .transformer import (_cdt, _pdt, _remat, _stack_init, attn_specs,
                          init_attn, _qkv, _pad_seq, unembed)


def init_encdec(cfg: ModelConfig, key) -> dict:
    pdt = _pdt(cfg)
    ke, kd, kemb = jax.random.split(key, 3)

    def enc_one(k):
        ka, kf = jax.random.split(k)
        return {"ln1": jnp.ones((cfg.d_model,), pdt),
                "ln2": jnp.ones((cfg.d_model,), pdt),
                "attn": init_attn(ka, cfg, pdt),
                "mlp": init_mlp(kf, cfg.d_model, cfg.d_ff, cfg.act, pdt)}

    def dec_one(k):
        ka, kx, kf = jax.random.split(k, 3)
        return {"ln1": jnp.ones((cfg.d_model,), pdt),
                "lnx": jnp.ones((cfg.d_model,), pdt),
                "ln2": jnp.ones((cfg.d_model,), pdt),
                "attn": init_attn(ka, cfg, pdt),
                "xattn": init_attn(kx, cfg, pdt),
                "mlp": init_mlp(kf, cfg.d_model, cfg.d_ff, cfg.act, pdt)}

    return {"embed": jax.random.normal(kemb, (cfg.vocab, cfg.d_model), pdt) * 0.02,
            "enc_layers": _stack_init(ke, cfg.n_encoder_layers, enc_one),
            "dec_layers": _stack_init(kd, cfg.n_layers, dec_one),
            "enc_norm": jnp.ones((cfg.d_model,), pdt),
            "final_norm": jnp.ones((cfg.d_model,), pdt)}


def encdec_param_specs(cfg: ModelConfig) -> dict:
    a = attn_specs(cfg)
    enc = {"ln1": ("layers", None), "ln2": ("layers", None),
           "attn": a, "mlp": mlp_specs(cfg.act)}
    dec = dict(enc, lnx=("layers", None), xattn=a)
    return {"embed": ("vocab", "embed_table"),
            "enc_layers": enc, "dec_layers": dec,
            "enc_norm": (None,), "final_norm": (None,)}


def encode(params, frames, cfg: ModelConfig, *, attn_impl="full", remat="full"):
    """frames: (B, S_enc, d_model) stub embeddings -> encoder output."""
    cdt = _cdt(cfg)
    h = constrain(frames.astype(cdt), "batch", None, None)

    def body(hh, lp):
        x = rmsnorm(hh, lp["ln1"], cfg.norm_eps)
        positions = jnp.arange(x.shape[1])[None, :]
        q, k, v = _qkv(lp["attn"], x, cfg, cdt, positions)
        o = A.attention(q, k, v, causal=False, impl=attn_impl)
        hh = hh + o.reshape(x.shape[0], x.shape[1], -1) @ lp["attn"]["wo"].astype(cdt)
        f = mlp(rmsnorm(hh, lp["ln2"], cfg.norm_eps), lp["mlp"], cfg.act, cdt)
        return hh + f, None

    h, _ = lax.scan(_remat(body, remat), h, params["enc_layers"])
    return rmsnorm(h, params["enc_norm"], cfg.norm_eps)


def _decoder_layer(hh, lp, enc_out, cfg, cdt, attn_impl):
    x = rmsnorm(hh, lp["ln1"], cfg.norm_eps)
    positions = jnp.arange(x.shape[1])[None, :]
    q, k, v = _qkv(lp["attn"], x, cfg, cdt, positions)
    self_kv = (k, v)
    o = A.attention(q, k, v, causal=True, impl=attn_impl)
    hh = hh + o.reshape(*x.shape[:2], -1) @ lp["attn"]["wo"].astype(cdt)
    # cross attention
    xx = rmsnorm(hh, lp["lnx"], cfg.norm_eps)
    epos = jnp.arange(enc_out.shape[1])[None, :]
    qx, _, _ = _qkv(lp["xattn"], xx, cfg, cdt, positions)
    _, kx, vx = _qkv(lp["xattn"], enc_out, cfg, cdt, epos)
    ox = A.attention(qx, kx, vx, causal=False, impl=attn_impl)
    hh = hh + ox.reshape(*xx.shape[:2], -1) @ lp["xattn"]["wo"].astype(cdt)
    f = mlp(rmsnorm(hh, lp["ln2"], cfg.norm_eps), lp["mlp"], cfg.act, cdt)
    return hh + f, (self_kv, (kx, vx))


def encdec_loss(params, batch, cfg: ModelConfig, *, attn_impl="full",
                remat="full", z_loss: float = 1e-4, loss_chunk: int = 512):
    from .blocks import chunked_softmax_ce
    cdt = _cdt(cfg)
    enc_out = encode(params, batch["frames"], cfg, attn_impl=attn_impl,
                     remat=remat)
    tokens = batch["tokens"]
    h = params["embed"][tokens[:, :-1]].astype(cdt)
    body = _remat(lambda hh, lp: (_decoder_layer(hh, lp, enc_out, cfg, cdt,
                                                 attn_impl)[0], None), remat)
    h, _ = lax.scan(body, h, params["dec_layers"])
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    # enc-dec ties decoder output projection to the token embedding table
    return chunked_softmax_ce(h, params["embed"].T, tokens[:, 1:],
                              chunk=loss_chunk, z_loss=z_loss)


def encdec_prefill(params, frames, tokens, cfg: ModelConfig, max_len: int,
                   *, attn_impl="flash"):
    """Encode + decoder prompt prefill. Returns (last_logits, cache)."""
    cdt = _cdt(cfg)
    enc_out = encode(params, frames, cfg, attn_impl=attn_impl)
    h = params["embed"][tokens].astype(cdt)

    def body(hh, lp):
        hh, ((k, v), (kx, vx)) = _decoder_layer(hh, lp, enc_out, cfg, cdt,
                                                attn_impl)
        return hh, (_pad_seq(k, max_len), _pad_seq(v, max_len), kx, vx)

    h, (ks, vs, kxs, vxs) = lax.scan(body, h, params["dec_layers"])
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = h[:, -1:].astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    cache = {"k": ks.astype(jnp.bfloat16), "v": vs.astype(jnp.bfloat16),
             "xk": kxs.astype(jnp.bfloat16), "xv": vxs.astype(jnp.bfloat16),
             "len": jnp.array(tokens.shape[1], jnp.int32)}
    return logits, cache


def encdec_cache_specs():
    kv = (None, "batch", "kv_seq", "kv_heads", None)
    return {"k": kv, "v": kv, "xk": kv, "xv": kv, "len": ()}


def encdec_decode_step(params, token, cache, cfg: ModelConfig, *,
                       sp_axis: Optional[str] = None):
    from .transformer import attn_decode
    cdt = _cdt(cfg)
    h = params["embed"][token].astype(cdt)
    clen = cache["len"]

    def body(hh, xs):
        lp, kc, vc, kx, vx = xs
        x = rmsnorm(hh, lp["ln1"], cfg.norm_eps)
        a, kc, vc = attn_decode(lp["attn"], x, cfg, cdt, kc, vc, clen,
                                sp_axis=sp_axis)
        hh = hh + a
        xx = rmsnorm(hh, lp["lnx"], cfg.norm_eps)
        positions = jnp.full((xx.shape[0], 1), clen, jnp.int32)
        qx, _, _ = _qkv(lp["xattn"], xx, cfg, cdt, positions)
        ox = A.decode_attention(qx, kx.astype(cdt), vx.astype(cdt), kx.shape[1])
        hh = hh + ox.reshape(*xx.shape[:2], -1) @ lp["xattn"]["wo"].astype(cdt)
        f = mlp(rmsnorm(hh, lp["ln2"], cfg.norm_eps), lp["mlp"], cfg.act, cdt)
        return hh + f, (kc, vc)

    h, (k_new, v_new) = lax.scan(body, h, (params["dec_layers"], cache["k"],
                                           cache["v"], cache["xk"], cache["xv"]))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = h.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    new_cache = dict(cache, k=k_new, v=v_new, len=clen + 1)
    return logits, new_cache
