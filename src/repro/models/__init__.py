from . import api, attention, blocks, encdec, mamba, moe, transformer
