"""Decoder-only LM covering the dense / moe / vlm / ssm / hybrid families.

Layers are scanned with stacked parameters (HLO size is O(1) in depth; FSDP
all-gathers happen per scan step so XLA's latency-hiding scheduler can
overlap them with compute). The hybrid (Jamba) family scans over
super-blocks of ``attn_period`` sublayers (7 mamba + 1 attention, MoE on
every other FFN) so the heterogeneous interleave stays scan-friendly.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.parallel.sharding import constrain

from . import attention as A
from .blocks import cross_entropy, init_mlp, mlp, mlp_specs, rmsnorm, rope
from .mamba import (init_mamba, mamba_decode_step, mamba_forward,
                    mamba_init_state, mamba_specs)
from .moe import init_moe, moe_ffn, moe_specs


def _cdt(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def _pdt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ===================================================================== init

def init_attn(key, cfg: ModelConfig, dtype):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {"wq": jax.random.normal(ks[0], (d, hq * dh), dtype) * s,
         "wk": jax.random.normal(ks[1], (d, hkv * dh), dtype) * s,
         "wv": jax.random.normal(ks[2], (d, hkv * dh), dtype) * s,
         "wo": jax.random.normal(ks[3], (hq * dh, d), dtype) * (hq * dh) ** -0.5}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def attn_specs(cfg: ModelConfig, prefix_layers=True):
    L = ("layers",) if prefix_layers else ()
    p = {"wq": L + ("embed", "heads"), "wk": L + ("embed", "kv_heads"),
         "wv": L + ("embed", "kv_heads"), "wo": L + ("heads", "embed")}
    if cfg.qkv_bias:
        p.update({"bq": L + ("heads",), "bk": L + ("kv_heads",),
                  "bv": L + ("kv_heads",)})
    return p


def _stack_init(key, n: int, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_lm(cfg: ModelConfig, key) -> dict:
    pdt = _pdt(cfg)
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    params: dict = {
        "embed": jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), pdt) * 0.02,
        "final_norm": jnp.ones((cfg.d_model,), pdt),
    }
    if not cfg.tied_embeddings:
        params["unembed"] = jax.random.normal(
            k_out, (cfg.d_model, cfg.vocab), pdt) * (cfg.d_model ** -0.5)

    if cfg.family == "ssm":
        def one(k):
            km, = jax.random.split(k, 1)
            return {"ln": jnp.ones((cfg.d_model,), pdt),
                    "mamba": init_mamba(km, cfg.d_model, cfg.mamba, pdt)}
        params["layers"] = _stack_init(k_layers, cfg.n_layers, one)
    elif cfg.family == "hybrid":
        params["blocks"] = _init_hybrid_blocks(cfg, k_layers, pdt)
    else:
        def one(k):
            ka, kf = jax.random.split(k)
            lp = {"ln1": jnp.ones((cfg.d_model,), pdt),
                  "ln2": jnp.ones((cfg.d_model,), pdt),
                  "attn": init_attn(ka, cfg, pdt)}
            if cfg.moe is not None:
                lp["moe"] = init_moe(kf, cfg.d_model, cfg.moe, cfg.act, pdt)
                if cfg.moe.dense_residual:
                    lp["mlp"] = init_mlp(jax.random.fold_in(kf, 1),
                                         cfg.d_model, cfg.d_ff, cfg.act, pdt)
            else:
                lp["mlp"] = init_mlp(kf, cfg.d_model, cfg.d_ff, cfg.act, pdt)
            return lp
        params["layers"] = _stack_init(k_layers, cfg.n_layers, one)
    return params


def _init_hybrid_blocks(cfg: ModelConfig, key, pdt):
    """Jamba super-blocks: per block `period` sublayers; index `offset` is
    attention, the rest mamba; odd sublayers use MoE FFN, even use dense."""
    period = cfg.attn_period
    n_blocks = cfg.n_layers // period
    n_mamba = period - 1
    n_moe = period // cfg.moe.every_n_layers
    n_dense = period - n_moe

    def one(k):
        ks = jax.random.split(k, 6)
        return {
            "ln_mix": jnp.ones((period, cfg.d_model), pdt),
            "ln_ffn": jnp.ones((period, cfg.d_model), pdt),
            "mamba": _stack_init(ks[0], n_mamba,
                                 lambda kk: init_mamba(kk, cfg.d_model, cfg.mamba, pdt)),
            "attn": init_attn(ks[1], cfg, pdt),
            "moe": _stack_init(ks[2], n_moe,
                               lambda kk: init_moe(kk, cfg.d_model, cfg.moe, cfg.act, pdt)),
            "mlp": _stack_init(ks[3], n_dense,
                               lambda kk: init_mlp(kk, cfg.d_model, cfg.d_ff, cfg.act, pdt)),
        }
    return _stack_init(key, n_blocks, one)


def lm_param_specs(cfg: ModelConfig) -> dict:
    specs: dict = {"embed": ("vocab", "embed_table"),
                   "final_norm": (None,)}
    if not cfg.tied_embeddings:
        specs["unembed"] = ("embed_table", "vocab")
    if cfg.family == "ssm":
        specs["layers"] = {"ln": ("layers", None),
                           "mamba": mamba_specs()}
    elif cfg.family == "hybrid":
        ms = {k: ("layers", None) + v[1:] for k, v in mamba_specs().items()}
        specs["blocks"] = {
            "ln_mix": ("layers", None, None), "ln_ffn": ("layers", None, None),
            "mamba": ms,
            "attn": {k: ("layers",) + v[1:] for k, v in attn_specs(cfg).items()},
            "moe": {k: ("layers", None) + v[1:] for k, v in moe_specs(cfg.act).items()},
            "mlp": {k: ("layers", None) + v[1:] for k, v in mlp_specs(cfg.act).items()},
        }
    else:
        lp = {"ln1": ("layers", None), "ln2": ("layers", None),
              "attn": attn_specs(cfg)}
        if cfg.moe is not None:
            lp["moe"] = moe_specs(cfg.act)
            if cfg.moe.dense_residual:
                lp["mlp"] = mlp_specs(cfg.act)
        else:
            lp["mlp"] = mlp_specs(cfg.act)
        specs["layers"] = lp
    return specs


# ==================================================================== layers

def _qkv(lp, x, cfg: ModelConfig, cdt, positions):
    b, s, _ = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ lp["wq"].astype(cdt)
    k = x @ lp["wk"].astype(cdt)
    v = x @ lp["wv"].astype(cdt)
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"].astype(cdt), k + lp["bk"].astype(cdt), v + lp["bv"].astype(cdt)
    # constrain the FLAT head dims (hq*dh, hkv*dh are mesh-divisible for
    # every assigned arch even when head counts are not — see make_rules)
    q = constrain(q, "batch", "seq", "heads").reshape(b, s, hq, dh)
    k = constrain(k, "batch", "seq", "kv_heads").reshape(b, s, hkv, dh)
    v = constrain(v, "batch", "seq", "kv_heads").reshape(b, s, hkv, dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_forward(lp, x, cfg: ModelConfig, cdt, *, impl: str, q_offset=0,
                 block_k: int = 256):
    b, s, _ = x.shape
    positions = q_offset + jnp.arange(s)[None, :]
    q, k, v = _qkv(lp, x, cfg, cdt, positions)
    o = A.attention(q, k, v, causal=True, impl=impl, block_k=block_k)
    o = constrain(o.reshape(b, s, cfg.n_heads * cfg.head_dim),
                  "batch", "seq", "heads")
    out = o @ lp["wo"].astype(cdt)
    return constrain(out, "batch", "seq", None), (k, v)


def attn_decode(lp, x, cfg: ModelConfig, cdt, k_cache, v_cache, cache_len,
                *, sp_axis: Optional[str] = None, kv_scales=None):
    """One decode step against the KV cache.

    ``cache_len`` is a () scalar for lockstep decode, or a (B,) vector for
    per-slot decode (continuous batching): row i writes its new K/V at its
    own position cache_len[i] and attends only its own valid prefix. The
    sequence-parallel path (``sp_axis``) supports scalar lengths only.

    ``kv_scales=(k_scale, v_scale)`` marks an int8 KV cache (codes in
    ``k_cache``/``v_cache``, per-(position, head) f32 scales (B,S,Hkv)):
    the new K/V row is quantized on write at its own position — per-token
    scales, so no other position is ever re-scaled — and the cache is
    dequantized on read. Returns a 5-tuple ``(out, k, v, k_scale,
    v_scale)`` in that mode (3-tuple otherwise); sp decode is float-only.
    """
    b = x.shape[0]
    cl = jnp.asarray(cache_len)
    kv8 = kv_scales is not None
    if kv8 and sp_axis is not None:
        raise NotImplementedError("int8 KV decode: sequence-parallel path "
                                  "is float-only")
    if cl.ndim == 0:
        positions = jnp.full((b, 1), cl, jnp.int32)
    else:
        positions = cl[:, None].astype(jnp.int32)
    q, k, v = _qkv(lp, x, cfg, cdt, positions)
    if kv8:
        k_scale, v_scale = kv_scales
        k, ks_new = A.quantize_kv(k)          # (B,1,Hkv,D) int8, (B,1,Hkv) f32
        v, vs_new = A.quantize_kv(v)
    if cl.ndim == 0:
        k_cache = lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), cl, axis=1)
        v_cache = lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), cl, axis=1)
        if kv8:
            k_scale = lax.dynamic_update_slice_in_dim(k_scale, ks_new, cl,
                                                      axis=1)
            v_scale = lax.dynamic_update_slice_in_dim(v_scale, vs_new, cl,
                                                      axis=1)
    else:
        # per-row scatter at each slot's own length; rows whose length is
        # past the end of the cache (retired slots) simply write nothing
        hot = (jnp.arange(k_cache.shape[1])[None, :] == cl[:, None])
        k_cache = jnp.where(hot[:, :, None, None], k.astype(k_cache.dtype),
                            k_cache)
        v_cache = jnp.where(hot[:, :, None, None], v.astype(v_cache.dtype),
                            v_cache)
        if kv8:
            k_scale = jnp.where(hot[:, :, None], ks_new, k_scale)
            v_scale = jnp.where(hot[:, :, None], vs_new, v_scale)
    if kv8:
        o = A.decode_attention_q8(q, k_cache, v_cache, k_scale, v_scale,
                                  cl + 1)
    elif sp_axis is None:
        o = A.decode_attention(q, k_cache, v_cache, cl + 1)
    else:
        o = _sp_decode(q, k_cache, v_cache, cl + 1, sp_axis)
    out = o.reshape(b, 1, cfg.n_heads * cfg.head_dim) @ lp["wo"].astype(cdt)
    if kv8:
        return out, k_cache, v_cache, k_scale, v_scale
    return out, k_cache, v_cache


def attn_decode_paged(lp, x, cfg: ModelConfig, cdt, k_pool, v_pool,
                      block_table, cache_len, *, scale_pools=None):
    """One decode step against a paged KV pool (one layer's pools:
    (NB, bs, Hkv, D); ``block_table`` (B, nb) int32; ``cache_len`` (B,)).

    Row i writes its new K/V into the pool page holding its own position —
    page ``block_table[i, cache_len[i] // bs]``, offset ``cache_len[i] %
    bs`` — then attends the gather-by-block-table view, which is
    bit-identical to the contiguous cache (attention.gather_kv_blocks).
    Retired rows (length 0, zeroed table row) write into the reserved
    garbage page 0, which no live table references.

    ``scale_pools=(k_scale_pool, v_scale_pool)`` marks an int8 pool (codes
    in ``k_pool``/``v_pool``, per-(position, head) f32 scale pools
    (NB, bs, Hkv)): the new K/V is quantized on write at its own page slot
    and the pool is dequantized on read. Returns ``(out, k_pool, v_pool
    [, k_scale_pool, v_scale_pool])``.
    """
    b = x.shape[0]
    bs = k_pool.shape[1]
    cl = jnp.asarray(cache_len)
    positions = cl[:, None].astype(jnp.int32)
    q, k, v = _qkv(lp, x, cfg, cdt, positions)
    bi = jnp.take_along_axis(block_table, (cl // bs)[:, None], axis=1)[:, 0]
    off = cl % bs
    kv8 = scale_pools is not None
    if kv8:
        k_scale_pool, v_scale_pool = scale_pools
        k, ks_new = A.quantize_kv(k)          # (B,1,Hkv,D) int8, (B,1,Hkv) f32
        v, vs_new = A.quantize_kv(v)
        k_scale_pool = k_scale_pool.at[bi, off].set(ks_new[:, 0])
        v_scale_pool = v_scale_pool.at[bi, off].set(vs_new[:, 0])
    k_pool = k_pool.at[bi, off].set(k[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[bi, off].set(v[:, 0].astype(v_pool.dtype))
    if kv8:
        o = A.decode_attention_paged_q8(q, k_pool, v_pool, k_scale_pool,
                                        v_scale_pool, block_table, cl + 1)
    else:
        o = A.decode_attention_paged(q, k_pool, v_pool, block_table, cl + 1)
    out = o.reshape(b, 1, cfg.n_heads * cfg.head_dim) @ lp["wo"].astype(cdt)
    if kv8:
        return out, k_pool, v_pool, k_scale_pool, v_scale_pool
    return out, k_pool, v_pool


def _sp_decode(q, k_cache, v_cache, n_valid, axis: str):
    """Sequence-parallel decode: KV cache sharded over `axis` along seq;
    batch stays on its DP axes. Per-shard flash statistics are combined with
    a psum whose payload is O(heads · head_dim), not O(S)."""
    from jax.experimental.shard_map import shard_map
    from repro.parallel.sharding import active_rules, current_mesh
    mesh = current_mesh()
    if mesh is None or mesh.shape.get(axis, 1) == 1:
        return A.decode_attention(q, k_cache, v_cache, n_valid)
    rules = active_rules()
    s_loc = k_cache.shape[1] // mesh.shape[axis]
    q_spec = rules.spec("batch", None, None, None)
    kv_spec = rules.spec("batch", "kv_seq", None, None)

    def body(qb, kb, vb, nv):
        i = lax.axis_index(axis)
        pos = i * s_loc + jnp.arange(s_loc)
        m, l, o = A.decode_attention_partial(qb, kb, vb, pos < nv)
        o = A.sp_combine(m, l, o, axis)
        b = qb.shape[0]
        return jnp.moveaxis(o, 3, 1).reshape(b, 1, -1, qb.shape[-1]).astype(qb.dtype)

    return shard_map(
        body, mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec, jax.sharding.PartitionSpec()),
        out_specs=q_spec, check_rep=False)(q, k_cache, v_cache, n_valid)


def ffn_forward(lp, x, cfg: ModelConfig, cdt, precision: str = "float"):
    """``precision``: "float" (default) or the serve engine's integer modes
    "int8" / "int8-xla" — those route the FFN matmuls through the quantized
    kernel layer (blocks.qmlp); the layer params must carry a "qmlp" tree
    (serve.Engine adds it at init)."""
    if precision != "float":
        if "qmlp" not in lp:
            raise ValueError(
                f"precision={precision!r} needs quantized FFN params; run "
                "blocks.quantize_mlp_params (serve.Engine does this when "
                "ServeConfig.precision != 'float')")
        from .blocks import qmlp
        return qmlp(x, lp["qmlp"], cfg.act, cdt,
                    method="xla" if precision == "int8-xla" else "pallas")
    if cfg.moe is not None and "moe" in lp:
        y = moe_ffn(x, lp["moe"], cfg.moe, cfg.act, cdt)
        if cfg.moe.dense_residual:
            y = y + mlp(x, lp["mlp"], cfg.act, cdt)
        return y
    return mlp(x, lp["mlp"], cfg.act, cdt)


def dense_layer(h, lp, cfg: ModelConfig, cdt, *, impl: str):
    a, _ = attn_forward(lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps),
                        cfg, cdt, impl=impl)
    h = h + a
    f = ffn_forward(lp, rmsnorm(h, lp["ln2"], cfg.norm_eps), cfg, cdt)
    # residual-stream constraint: the scan carry is the per-layer activation
    # checkpoint; sharding its d_model over "model" (when rules say so)
    # divides the dominant training-memory term by the TP degree
    return constrain(h + f, "batch", "seq", "d_model_act")


def ssm_layer(h, lp, cfg: ModelConfig, cdt, conv_method="auto"):
    y = mamba_forward(lp["mamba"], rmsnorm(h, lp["ln"], cfg.norm_eps),
                      cfg.mamba, cdt, conv_method=conv_method)
    return constrain(h + y, "batch", "seq", "d_model_act")


def hybrid_block(h, bp, cfg: ModelConfig, cdt, *, impl: str):
    period = cfg.attn_period
    m_idx = moe_idx = mlp_idx = 0
    for i in range(period):
        x = rmsnorm(h, bp["ln_mix"][i], cfg.norm_eps)
        if i == cfg.attn_offset:
            a, _ = attn_forward(bp["attn"], x, cfg, cdt, impl=impl)
            h = h + a
        else:
            lp = jax.tree_util.tree_map(lambda v, j=m_idx: v[j], bp["mamba"])
            h = h + mamba_forward(lp, x, cfg.mamba, cdt)
            m_idx += 1
        f_in = rmsnorm(h, bp["ln_ffn"][i], cfg.norm_eps)
        if i % cfg.moe.every_n_layers == 1:
            mp = jax.tree_util.tree_map(lambda v, j=moe_idx: v[j], bp["moe"])
            h = h + moe_ffn(f_in, mp, cfg.moe, cfg.act, cdt)
            moe_idx += 1
        else:
            dp = jax.tree_util.tree_map(lambda v, j=mlp_idx: v[j], bp["mlp"])
            h = h + mlp(f_in, dp, cfg.act, cdt)
            mlp_idx += 1
    return constrain(h, "batch", None, "d_model_act")


# =================================================================== forward

def _remat(fn, mode: str):
    if mode == "full":
        return jax.checkpoint(fn, prevent_cse=False)
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            prevent_cse=False)
    return fn


def embed_tokens(params, tokens, cfg: ModelConfig, cdt):
    e = params["embed"][tokens]
    return e.astype(cdt)


def unembed(params, h, cfg: ModelConfig):
    w = params["embed"].T if cfg.tied_embeddings else params["unembed"]
    logits = h.astype(jnp.float32) @ w.astype(jnp.float32)
    return constrain(logits, "batch", None, "vocab")


def forward_hidden(params, tokens, cfg: ModelConfig, *, embeds=None,
                   attn_impl: str = "full", remat: str = "full"):
    """Final hidden states (post final-norm), before the unembedding."""
    cdt = _cdt(cfg)
    h = embed_tokens(params, tokens, cfg, cdt)
    if embeds is not None:
        h = jnp.concatenate([embeds.astype(cdt), h], axis=1)
    h = constrain(h, "batch", "seq", None)

    if cfg.family == "hybrid":
        body = _remat(lambda hh, bp: (hybrid_block(hh, bp, cfg, cdt,
                                                   impl=attn_impl), None), remat)
        h, _ = lax.scan(body, h, params["blocks"])
    elif cfg.family == "ssm":
        body = _remat(lambda hh, lp: (ssm_layer(hh, lp, cfg, cdt), None), remat)
        h, _ = lax.scan(body, h, params["layers"])
    else:
        body = _remat(lambda hh, lp: (dense_layer(hh, lp, cfg, cdt,
                                                  impl=attn_impl), None), remat)
        h, _ = lax.scan(body, h, params["layers"])

    return rmsnorm(h, params["final_norm"], cfg.norm_eps)


def forward(params, tokens, cfg: ModelConfig, *, embeds=None,
            attn_impl: str = "full", remat: str = "full"):
    """tokens: (B, S_txt) int32; embeds (vlm/audio stub): (B, P, d_model)."""
    h = forward_hidden(params, tokens, cfg, embeds=embeds,
                       attn_impl=attn_impl, remat=remat)
    return unembed(params, h, cfg)


def lm_loss(params, batch, cfg: ModelConfig, *, attn_impl="full", remat="full",
            z_loss: float = 1e-4, loss_chunk: int = 512):
    from .blocks import chunked_softmax_ce
    tokens = batch["tokens"]
    h = forward_hidden(params, tokens[:, :-1], cfg,
                       embeds=batch.get("embeds"), attn_impl=attn_impl,
                       remat=remat)
    n_img = 0 if batch.get("embeds") is None else batch["embeds"].shape[1]
    w = params["embed"].T if cfg.tied_embeddings else params["unembed"]
    return chunked_softmax_ce(h[:, n_img:], w, tokens[:, 1:],
                              chunk=loss_chunk, z_loss=z_loss)


# ==================================================================== decode

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    if cfg.family == "ssm":
        st = mamba_init_state(cfg.d_model, cfg.mamba, batch)
        return {"conv": jnp.zeros((cfg.n_layers,) + st["conv"].shape, dtype),
                "ssm": jnp.zeros((cfg.n_layers,) + st["ssm"].shape, jnp.float32),
                "len": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        nb = cfg.n_layers // cfg.attn_period
        nm = cfg.attn_period - 1
        st = mamba_init_state(cfg.d_model, cfg.mamba, batch)
        return {"k": jnp.zeros((nb, batch, max_len, hkv, dh), dtype),
                "v": jnp.zeros((nb, batch, max_len, hkv, dh), dtype),
                "conv": jnp.zeros((nb, nm) + st["conv"].shape, dtype),
                "ssm": jnp.zeros((nb, nm) + st["ssm"].shape, jnp.float32),
                "len": jnp.zeros((), jnp.int32)}
    return {"k": jnp.zeros((cfg.n_layers, batch, max_len, hkv, dh), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, max_len, hkv, dh), dtype),
            "len": jnp.zeros((), jnp.int32)}


def cache_specs(cfg: ModelConfig):
    """Logical sharding for the cache (SP shards kv_seq over data)."""
    if cfg.family == "ssm":
        return {"conv": (None, "batch", None, "d_inner"),
                "ssm": (None, "batch", "d_inner", None), "len": ()}
    kv = (None, "batch", "kv_seq", "kv_heads", None)
    if cfg.family == "hybrid":
        return {"k": kv, "v": kv,
                "conv": (None, None, "batch", None, "d_inner"),
                "ssm": (None, None, "batch", "d_inner", None), "len": ()}
    return {"k": kv, "v": kv, "len": ()}


def decode_step(params, token, cache, cfg: ModelConfig, *,
                sp_axis: Optional[str] = None, precision: str = "float"):
    """One-token serve step. token: (B, 1) int32. ``precision`` "int8" /
    "int8-xla" runs the FFN matmuls integer-only (see ffn_forward)."""
    if precision != "float" and cfg.family in ("ssm", "hybrid"):
        raise NotImplementedError(
            "integer-FFN decode only covers attention-family dense MLPs")
    kv8 = "k_scale" in cache
    if kv8 and cfg.family in ("ssm", "hybrid"):
        raise NotImplementedError(
            "int8 KV decode only covers attention-family dense caches")
    cdt = _cdt(cfg)
    h = embed_tokens(params, token, cfg, cdt)
    clen = cache["len"]
    new_cache = dict(cache)

    if cfg.family == "ssm":
        def body(hh, xs):
            lp, conv, ssm = xs
            x = rmsnorm(hh, lp["ln"], cfg.norm_eps)
            y, st = mamba_decode_step(lp["mamba"], x, {"conv": conv, "ssm": ssm},
                                      cfg.mamba, cdt)
            return hh + y, (st["conv"], st["ssm"])
        h, (conv_new, ssm_new) = lax.scan(body, h,
                                          (params["layers"], cache["conv"],
                                           cache["ssm"]))
        new_cache.update(conv=conv_new, ssm=ssm_new)
    elif cfg.family == "hybrid":
        def body(hh, xs):
            bp, kc, vc, conv, ssm = xs
            period = cfg.attn_period
            m_idx = moe_idx = mlp_idx = 0
            convs, ssms = [], []
            for i in range(period):
                x = rmsnorm(hh, bp["ln_mix"][i], cfg.norm_eps)
                if i == cfg.attn_offset:
                    a, kc, vc = attn_decode(bp["attn"], x, cfg, cdt, kc, vc,
                                            clen, sp_axis=sp_axis)
                    hh = hh + a
                else:
                    lp = jax.tree_util.tree_map(lambda v, j=m_idx: v[j], bp["mamba"])
                    y, st = mamba_decode_step(
                        lp, x, {"conv": conv[m_idx], "ssm": ssm[m_idx]},
                        cfg.mamba, cdt)
                    hh = hh + y
                    convs.append(st["conv"]); ssms.append(st["ssm"])
                    m_idx += 1
                f_in = rmsnorm(hh, bp["ln_ffn"][i], cfg.norm_eps)
                if i % cfg.moe.every_n_layers == 1:
                    mp = jax.tree_util.tree_map(lambda v, j=moe_idx: v[j], bp["moe"])
                    hh = hh + moe_ffn(f_in, mp, cfg.moe, cfg.act, cdt)
                    moe_idx += 1
                else:
                    dp = jax.tree_util.tree_map(lambda v, j=mlp_idx: v[j], bp["mlp"])
                    hh = hh + mlp(f_in, dp, cfg.act, cdt)
                    mlp_idx += 1
            return hh, (kc, vc, jnp.stack(convs), jnp.stack(ssms))
        h, (k_new, v_new, conv_new, ssm_new) = lax.scan(
            body, h, (params["blocks"], cache["k"], cache["v"],
                      cache["conv"], cache["ssm"]))
        new_cache.update(k=k_new, v=v_new, conv=conv_new, ssm=ssm_new)
    elif "block_table" in cache:
        # paged pools: (L, NB, bs, Hkv, D) [+ (L, NB, bs, Hkv) scales];
        # the (B, nb) block table is shared by every layer (same logical
        # layout, per-layer pools indexed by the same page ids)
        bt = cache["block_table"]
        if sp_axis is not None:
            raise NotImplementedError("paged KV decode: sequence-parallel "
                                      "path is contiguous-only")
        if kv8:
            def body(hh, xs):
                lp, kp, vp, ksp, vsp = xs
                x = rmsnorm(hh, lp["ln1"], cfg.norm_eps)
                a, kp, vp, ksp, vsp = attn_decode_paged(
                    lp["attn"], x, cfg, cdt, kp, vp, bt, clen,
                    scale_pools=(ksp, vsp))
                hh = hh + a
                f = ffn_forward(lp, rmsnorm(hh, lp["ln2"], cfg.norm_eps),
                                cfg, cdt, precision=precision)
                return hh + f, (kp, vp, ksp, vsp)
            h, (k_new, v_new, ks_new, vs_new) = lax.scan(
                body, h, (params["layers"], cache["k"], cache["v"],
                          cache["k_scale"], cache["v_scale"]))
            new_cache.update(k=k_new, v=v_new, k_scale=ks_new, v_scale=vs_new)
        else:
            def body(hh, xs):
                lp, kp, vp = xs
                x = rmsnorm(hh, lp["ln1"], cfg.norm_eps)
                a, kp, vp = attn_decode_paged(lp["attn"], x, cfg, cdt,
                                              kp, vp, bt, clen)
                hh = hh + a
                f = ffn_forward(lp, rmsnorm(hh, lp["ln2"], cfg.norm_eps),
                                cfg, cdt, precision=precision)
                return hh + f, (kp, vp)
            h, (k_new, v_new) = lax.scan(
                body, h, (params["layers"], cache["k"], cache["v"]))
            new_cache.update(k=k_new, v=v_new)
    elif kv8:
        def body(hh, xs):
            lp, kc, vc, ks, vs = xs
            x = rmsnorm(hh, lp["ln1"], cfg.norm_eps)
            a, kc, vc, ks, vs = attn_decode(lp["attn"], x, cfg, cdt, kc, vc,
                                            clen, sp_axis=sp_axis,
                                            kv_scales=(ks, vs))
            hh = hh + a
            f = ffn_forward(lp, rmsnorm(hh, lp["ln2"], cfg.norm_eps), cfg, cdt,
                            precision=precision)
            return hh + f, (kc, vc, ks, vs)
        h, (k_new, v_new, ks_new, vs_new) = lax.scan(
            body, h, (params["layers"], cache["k"], cache["v"],
                      cache["k_scale"], cache["v_scale"]))
        new_cache.update(k=k_new, v=v_new, k_scale=ks_new, v_scale=vs_new)
    else:
        def body(hh, xs):
            lp, kc, vc = xs
            x = rmsnorm(hh, lp["ln1"], cfg.norm_eps)
            a, kc, vc = attn_decode(lp["attn"], x, cfg, cdt, kc, vc, clen,
                                    sp_axis=sp_axis)
            hh = hh + a
            f = ffn_forward(lp, rmsnorm(hh, lp["ln2"], cfg.norm_eps), cfg, cdt,
                            precision=precision)
            return hh + f, (kc, vc)
        h, (k_new, v_new) = lax.scan(body, h,
                                     (params["layers"], cache["k"], cache["v"]))
        new_cache.update(k=k_new, v=v_new)

    new_cache["len"] = clen + 1
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return unembed(params, h, cfg), new_cache


def prefill(params, tokens, cfg: ModelConfig, max_len: int, *, embeds=None,
            attn_impl: str = "flash", prompt_lens=None,
            precision: str = "float", attn_block_k: int = 256):
    """Run the prompt, build the cache, return (last_logits, cache).

    For attention families the per-layer K/V come out of the layer scan; for
    ssm/hybrid the states come from a chunk-scan epilogue (decode-step replay
    of the last conv window + final ssm state).

    With ``prompt_lens`` (a (B,) int32 vector) the batch is RIGHT-padded:
    row i's real tokens occupy positions [0, prompt_lens[i]) — causality
    already keeps real tokens from attending the trailing pads, pad K/V land
    at positions >= prompt_lens[i] where the per-slot decode mask (and the
    next writes) neutralize them, and rope positions stay 0..len-1 exactly
    as in an unpadded prefill. Logits are gathered at each row's last real
    position and ``cache["len"]`` becomes the per-row length vector (the
    slot-cache convention — see models/api.init_slot_cache). Right-padding
    is only exact for attention families; ssm/hybrid recurrences fold every
    position into their state, so callers must pass exact lengths
    (prompt_lens[i] == S) for those families.

    ``attn_block_k`` pins the flash-attention KV-block size. Serving passes
    a FIXED value across every prefill bucket: with a constant block size a
    prefix row's K/V are bitwise independent of how far the bucket extends
    past it (trailing fully-masked KV blocks are exact no-ops in the online
    softmax), which is what makes hash-based prefix reuse exact — see
    :func:`prefill_suffix`.
    """
    if precision != "float" and cfg.family in ("ssm", "hybrid"):
        raise NotImplementedError(
            "integer-FFN prefill only covers attention-family dense MLPs")
    cdt = _cdt(cfg)
    b = tokens.shape[0]
    s_prompt = tokens.shape[1] + (0 if embeds is None else embeds.shape[1])
    cache = init_cache(cfg, b, max_len)
    h = embed_tokens(params, tokens, cfg, cdt)
    if embeds is not None:
        h = jnp.concatenate([embeds.astype(cdt), h], axis=1)

    if cfg.family == "ssm":
        def body(hh, lp):
            x = rmsnorm(hh, lp["ln"], cfg.norm_eps)
            y, st = _mamba_forward_with_state(lp["mamba"], x, cfg.mamba, cdt)
            return hh + y, st
        h, states = lax.scan(body, h, params["layers"])
        cache.update(conv=states["conv"].astype(cache["conv"].dtype),
                     ssm=states["ssm"])
    elif cfg.family == "hybrid":
        def body(hh, bp):
            hh, kvs = _hybrid_block_with_state(hh, bp, cfg, cdt, attn_impl,
                                               max_len)
            return hh, kvs
        h, st = lax.scan(body, h, params["blocks"])
        cache.update(k=st["k"].astype(cache["k"].dtype),
                     v=st["v"].astype(cache["v"].dtype),
                     conv=st["conv"].astype(cache["conv"].dtype),
                     ssm=st["ssm"])
    else:
        def body(hh, lp):
            x = rmsnorm(hh, lp["ln1"], cfg.norm_eps)
            a, (k, v) = attn_forward(lp["attn"], x, cfg, cdt, impl=attn_impl,
                                     block_k=attn_block_k)
            hh = hh + a
            f = ffn_forward(lp, rmsnorm(hh, lp["ln2"], cfg.norm_eps), cfg, cdt,
                            precision=precision)
            k = _pad_seq(k, max_len).astype(cache["k"].dtype)
            v = _pad_seq(v, max_len).astype(cache["v"].dtype)
            return hh + f, (k, v)
        h, (ks, vs) = lax.scan(body, h, params["layers"])
        cache.update(k=ks, v=vs)

    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    if prompt_lens is None:
        cache["len"] = jnp.array(s_prompt, jnp.int32)
        return unembed(params, h[:, -1:], cfg), cache
    pl = jnp.asarray(prompt_lens, jnp.int32)
    cache["len"] = pl
    h_last = jnp.take_along_axis(h, (pl - 1)[:, None, None], axis=1)
    return unembed(params, h_last, cfg), cache


def _pad_seq(x, max_len):
    pad = max_len - x.shape[1]
    return jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else x


def _mamba_forward_with_state(p, x, m, cdt):
    """mamba_forward that also returns final {conv, ssm} state."""
    from .mamba import mamba_scan, _resolve_conv_method
    from repro.kernels.ops import causal_conv1d
    rank = p["dt_proj"].shape[0]
    n = p["A_log"].shape[-1]
    xz = x @ p["in_proj"].astype(cdt)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = constrain(x_in, "batch", None, "d_inner")
    x_c = causal_conv1d(x_in, p["conv_w"].astype(cdt),
                        method=_resolve_conv_method("auto"))
    x_c = jax.nn.silu(x_c + p["conv_b"].astype(cdt))
    dbc = x_c @ p["x_proj"].astype(cdt)
    dt_low, b_t, c_t = jnp.split(dbc, [rank, rank + n], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_proj"].astype(cdt) + p["dt_bias"].astype(cdt))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_last = mamba_scan(x_c, dt, A, b_t, c_t)
    y = y + p["D"].astype(cdt) * x_c
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(cdt)
    k = p["conv_w"].shape[0]
    conv_state = x_in[:, -(k - 1):, :]
    return out, {"conv": conv_state, "ssm": h_last}


def _hybrid_block_with_state(h, bp, cfg, cdt, attn_impl, max_len):
    period = cfg.attn_period
    m_idx = moe_idx = mlp_idx = 0
    convs, ssms, kv = [], [], None
    for i in range(period):
        x = rmsnorm(h, bp["ln_mix"][i], cfg.norm_eps)
        if i == cfg.attn_offset:
            a, (k, v) = attn_forward(bp["attn"], x, cfg, cdt, impl=attn_impl)
            h = h + a
            kv = (_pad_seq(k, max_len), _pad_seq(v, max_len))
        else:
            lp = jax.tree_util.tree_map(lambda v_, j=m_idx: v_[j], bp["mamba"])
            y, st = _mamba_forward_with_state(lp, x, cfg.mamba, cdt)
            h = h + y
            convs.append(st["conv"]); ssms.append(st["ssm"])
            m_idx += 1
        f_in = rmsnorm(h, bp["ln_ffn"][i], cfg.norm_eps)
        if i % cfg.moe.every_n_layers == 1:
            mp = jax.tree_util.tree_map(lambda v_, j=moe_idx: v_[j], bp["moe"])
            h = h + moe_ffn(f_in, mp, cfg.moe, cfg.act, cdt)
            moe_idx += 1
        else:
            dp = jax.tree_util.tree_map(lambda v_, j=mlp_idx: v_[j], bp["mlp"])
            h = h + mlp(f_in, dp, cfg.act, cdt)
            mlp_idx += 1
    return h, {"k": kv[0], "v": kv[1],
               "conv": jnp.stack(convs), "ssm": jnp.stack(ssms)}


def prefill_suffix(params, tokens, prefix_k, prefix_v, prefix_len: int,
                   cfg: ModelConfig, *, suffix_lens, attn_impl: str = "flash",
                   attn_block_k: int = 256, precision: str = "float"):
    """Chunked prefill against a cached prefix: compute only the suffix.

    The prefix-cache hit path of paged serving — the leading ``prefix_len``
    positions' K/V already live in the block pool (computed once by the
    donor request), so only ``tokens`` (the right-padded suffix, occupying
    global positions ``prefix_len .. prefix_len + S_sfx - 1``) runs through
    the layers. Per layer, the suffix queries attend the concatenation of
    the gathered prefix K/V and the fresh suffix K/V with
    ``q_offset=prefix_len``.

    Bit-exactness contract: causality makes a prefix position's hidden
    state independent of the suffix, and a FIXED ``attn_block_k`` (dividing
    both ``prefix_len`` and the suffix bucket) makes the flash KV-block
    schedule of every suffix row identical to the full-prompt prefill's —
    so the returned logits and suffix K/V are bitwise what a full prefill
    of the whole prompt would have produced (tested in test_paged.py).

    tokens: (B, S_sfx) int32; prefix_k/v: (L, B, prefix_len, Hkv, D) in the
    compute dtype; suffix_lens: (B,) int32 real suffix lengths. Returns
    ``(last_logits, k_sfx, v_sfx)`` with k/v_sfx (L, B, S_sfx, Hkv, D) —
    the caller scatters them into pool pages. Attention families with
    dense-layer stacks only (the paged engine's admission gate).
    """
    if cfg.family in ("ssm", "hybrid", "encdec"):
        raise NotImplementedError(
            "prefill_suffix covers attention-family dense layer stacks only")
    cdt = _cdt(cfg)
    s = tokens.shape[1]
    h = embed_tokens(params, tokens, cfg, cdt)

    def body(hh, xs):
        lp, pk, pv = xs
        x = rmsnorm(hh, lp["ln1"], cfg.norm_eps)
        positions = prefix_len + jnp.arange(s)[None, :]
        q, k, v = _qkv(lp["attn"], x, cfg, cdt, positions)
        k_cat = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
        v_cat = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
        o = A.attention(q, k_cat, v_cat, causal=True, impl=attn_impl,
                        q_offset=prefix_len, block_k=attn_block_k)
        b = x.shape[0]
        o = constrain(o.reshape(b, s, cfg.n_heads * cfg.head_dim),
                      "batch", "seq", "heads")
        a = constrain(o @ lp["attn"]["wo"].astype(cdt), "batch", "seq", None)
        hh = hh + a
        f = ffn_forward(lp, rmsnorm(hh, lp["ln2"], cfg.norm_eps), cfg, cdt,
                        precision=precision)
        return hh + f, (k, v)

    h, (ks, vs) = lax.scan(body, h, (params["layers"], prefix_k, prefix_v))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    sl = jnp.asarray(suffix_lens, jnp.int32)
    h_last = jnp.take_along_axis(h, (sl - 1)[:, None, None], axis=1)
    return unembed(params, h_last, cfg), ks, vs
