"""Paper-side CNN: a small image classifier where EVERY conv block's
primitive is selectable (standard / grouped / dws / shift / add), exactly
the way the paper swaps NNoM layer implementations. Training runs on the
float primitives; inference and PTQ run through the ``repro.graph`` layer
IR — ``quantize_cnn`` lowers the graph in one calibration sweep and returns
the single-jit integer-only executor (activations int8 end to end, fused
ReLU/pool epilogues). `method="pallas"` routes every layer through the TPU
kernels."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import ConvSpec, apply_block, init_block


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    primitive: str = "standard"
    groups: int = 2
    widths: tuple = (16, 32, 64)
    kernel_size: int = 3
    num_classes: int = 10
    in_channels: int = 3
    image_size: int = 32


def _specs(cfg: CNNConfig):
    specs = []
    cin = cfg.in_channels
    for w in cfg.widths:
        prim = cfg.primitive
        groups = cfg.groups if prim == "grouped" else 1
        if prim == "grouped" and (cin % groups or w % groups):
            prim, groups = "standard", 1      # first layer: 3 channels
        if prim in ("dws", "shift") and cin < 4:
            prim = "standard"                 # stem stays standard (paperlike)
        specs.append(ConvSpec(primitive=prim, in_channels=cin, out_channels=w,
                              kernel_size=cfg.kernel_size, groups=groups))
        cin = w
    return specs


def init_cnn(cfg: CNNConfig, key):
    specs = _specs(cfg)
    ks = jax.random.split(key, len(specs) + 1)
    params = {"blocks": [init_block(ks[i], s, with_bn=True)
                         for i, s in enumerate(specs)],
              "head": jax.random.normal(ks[-1], (cfg.widths[-1], cfg.num_classes))
              * cfg.widths[-1] ** -0.5}
    return params


def cnn_forward(params, x, cfg: CNNConfig, *, train: bool = False):
    if not train:
        # inference runs on the layer-graph IR — the same graph the
        # quantized executor lowers, so float eval and int8 deployment
        # share one structural description (repro.graph)
        from repro.graph import build_cnn_graph, float_forward
        return float_forward(build_cnn_graph(cfg), params, x)
    specs = _specs(cfg)
    h = x
    for p, s in zip(params["blocks"], specs):
        h = apply_block(p, h, s, train_stats={})
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "VALID")
    h = jnp.mean(h, axis=(1, 2))
    return h @ params["head"]


def cnn_loss(params, batch, cfg: CNNConfig):
    logits = cnn_forward(params, batch["images"], cfg, train=True)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return nll, acc


# ---------------------------------------------------- BN re-estimation ---

def calibrate_bn(params, cfg: CNNConfig, calib_x):
    """Deployment-time BN statistics re-estimation: run calibration data
    through the network (one walk of the graph interpreter —
    ``repro.graph.lower.interpret``, the same sweep PTQ lowering uses) and
    write each block's activation mean/var into the inference BN buffers
    (training normalizes with batch stats; the EMA is owned by this
    calibration pass)."""
    from repro.graph import build_cnn_graph
    from repro.graph.lower import interpret
    bn_calib = interpret(build_cnn_graph(cfg), params, calib_x,
                         calibrate=True)["bn"]
    new_blocks = [dict(p, bn=bn_calib[f"bn{i}"])
                  for i, p in enumerate(params["blocks"])]
    return dict(params, blocks=new_blocks)


# ------------------------------------------------------------------ PTQ ---

def quantize_cnn(params, cfg: CNNConfig, calib_x, *, method: str = "xla"):
    """Post-training quantization (paper scheme) through ``repro.graph``:
    build the layer-graph IR, lower it in ONE calibration sweep (BN stat
    re-estimation + BN folding + power-of-two scale annotation + the
    requant/ReLU/pool fusion pass), and return the single-jit integer-only
    executor. Activations stay int8 end to end between conv layers — no
    per-layer float bounce (the pre-graph behavior survives as
    ``repro.graph.unfused_forward`` for comparison benchmarks).

    ``method`` picks the integer execution engine for every layer:
    ``"pallas"`` runs the fused int8 TPU kernels (the paper's SIMD
    analogue), ``"xla"`` the jnp integer oracles (direct / no-SIMD),
    ``"auto"`` pallas where the kernel layer can express the layer —
    all bit-exact with each other (tests/test_graph.py).

    Returns a :class:`repro.graph.CompiledPlan` (callable; its ``.plan``
    and ``.profile`` expose the lowered scales and per-layer costs)."""
    from repro.graph import CompiledPlan, build_cnn_graph, lower
    plan = lower(build_cnn_graph(cfg), params, calib_x)
    return CompiledPlan(plan, method=method)
