"""Paper-side CNN: a small image classifier where EVERY conv block's
primitive is selectable (standard / grouped / dws / shift / add), exactly
the way the paper swaps NNoM layer implementations. Runs on the float
primitives for training and on the integer-only Algorithm-1 path (with BN
folding where applicable) after PTQ. `method="pallas"` routes the forward
through the TPU kernels."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import (ConvSpec, apply, apply_block, batchnorm_apply, fold,
                        frac_bits_for, init_block, quantize)
from repro.core.qconv import qconv_apply, quantize_conv_params
from repro.kernels import ops as K


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    primitive: str = "standard"
    groups: int = 2
    widths: tuple = (16, 32, 64)
    kernel_size: int = 3
    num_classes: int = 10
    in_channels: int = 3
    image_size: int = 32


def _specs(cfg: CNNConfig):
    specs = []
    cin = cfg.in_channels
    for w in cfg.widths:
        prim = cfg.primitive
        groups = cfg.groups if prim == "grouped" else 1
        if prim == "grouped" and (cin % groups or w % groups):
            prim, groups = "standard", 1      # first layer: 3 channels
        if prim in ("dws", "shift") and cin < 4:
            prim = "standard"                 # stem stays standard (paperlike)
        specs.append(ConvSpec(primitive=prim, in_channels=cin, out_channels=w,
                              kernel_size=cfg.kernel_size, groups=groups))
        cin = w
    return specs


def init_cnn(cfg: CNNConfig, key):
    specs = _specs(cfg)
    ks = jax.random.split(key, len(specs) + 1)
    params = {"blocks": [init_block(ks[i], s, with_bn=True)
                         for i, s in enumerate(specs)],
              "head": jax.random.normal(ks[-1], (cfg.widths[-1], cfg.num_classes))
              * cfg.widths[-1] ** -0.5}
    return params


def cnn_forward(params, x, cfg: CNNConfig, *, train: bool = False):
    specs = _specs(cfg)
    h = x
    for p, s in zip(params["blocks"], specs):
        stats = {} if train else None
        h = apply_block(p, h, s, train_stats=stats)
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "VALID")
    h = jnp.mean(h, axis=(1, 2))
    return h @ params["head"]


def cnn_loss(params, batch, cfg: CNNConfig):
    logits = cnn_forward(params, batch["images"], cfg, train=True)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return nll, acc


# ---------------------------------------------------- BN re-estimation ---

def calibrate_bn(params, cfg: CNNConfig, calib_x):
    """Deployment-time BN statistics re-estimation: run calibration data
    through the network and write each block's activation mean/var into the
    inference BN buffers (training normalizes with batch stats; the EMA is
    owned by this calibration pass)."""
    specs = _specs(cfg)
    h = calib_x
    new_blocks = []
    for p, s in zip(params["blocks"], specs):
        y = apply(p["conv"], h, s)
        bn = dict(p["bn"],
                  mean=jnp.mean(y, axis=(0, 1, 2)).astype(jnp.float32),
                  var=jnp.var(y, axis=(0, 1, 2)).astype(jnp.float32))
        p = dict(p, bn=bn)
        new_blocks.append(p)
        h = jax.nn.relu(batchnorm_apply(bn, y))
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "VALID")
    return dict(params, blocks=new_blocks)


# ------------------------------------------------------------------ PTQ ---

def quantize_cnn(params, cfg: CNNConfig, calib_x, *, method: str = "xla"):
    """Post-training quantization (paper scheme): re-estimate BN stats,
    BN-fold the foldable blocks, pick power-of-two scales from calibration
    activations, return an integer-only forward closure.

    ``method`` picks the integer execution engine for every layer:
    ``"pallas"`` runs the fused int8 TPU kernels (the paper's SIMD
    analogue), ``"xla"`` the jnp integer oracles (direct / no-SIMD) —
    bit-exact with each other (see core/qconv.qconv_apply)."""
    params = calibrate_bn(params, cfg, calib_x)
    specs = _specs(cfg)
    h = calib_x
    qblocks = []
    for p, s in zip(params["blocks"], specs):
        float_out = apply_block(p, h, s)
        if s.primitive != "add":
            folded = fold(p["conv"], p["bn"], s)
            qp = quantize_conv_params(folded, s)
            bn = None
        else:                                  # paper: add-conv keeps BN
            qp = quantize_conv_params(p["conv"], s)
            bn = p["bn"]
        ofb = frac_bits_for(float_out)
        qblocks.append(dict(qp=qp, spec=s, out_fb=ofb, bn=bn))
        h = jax.lax.reduce_window(float_out, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    head = params["head"]

    def int_forward(x):
        xq = quantize(x)
        for blk in qblocks:
            yq = qconv_apply(blk["qp"], xq, blk["spec"], blk["out_fb"],
                             method=method)
            y = yq.dequantize()
            if blk["bn"] is not None:
                y = batchnorm_apply(blk["bn"], y)
            y = jax.nn.relu(y)
            y = jax.lax.reduce_window(y, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                      (1, 2, 2, 1), "VALID")
            xq = quantize(y)
        h2 = jnp.mean(xq.dequantize(), axis=(1, 2))
        return h2 @ head

    return int_forward
