"""Shared model blocks: norms, MLPs, embeddings, RoPE."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain


def rmsnorm(x, w, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def layernorm(x, w, b, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def mlp(h, p, act: str, cdt):
    """SwiGLU (3 mats) or GELU (2 mats) feed-forward."""
    h = h.astype(cdt)
    if act == "silu":
        g = h @ p["w_gate"].astype(cdt)
        u = h @ p["w_up"].astype(cdt)
        z = jax.nn.silu(g) * u
    else:
        u = h @ p["w_up"].astype(cdt)
        z = jax.nn.gelu(u)
    z = constrain(z, "batch", "seq", "ffn")
    return z @ p["w_down"].astype(cdt)


# ----------------------------------------------------- quantized MLP (W8A8)
#
# The paper's Eq. 4 / Algorithm 1 scheme applied to the LM's FFN matmuls —
# the dominant weight volume of a decode step. Weights are PTQ'd once per
# tensor (power-of-two scale, concrete at engine init); activations are
# quantized on the fly at a FIXED power-of-two scale, so every requantization
# is a static arithmetic shift fused into the matmul_q8 epilogue. The
# nonlinearity runs in float between the integer matmuls (standard W8A8).

ACT_FRAC_BITS = 4      # activation scale 2^-4: post-rmsnorm streams are O(1)


def quantize_mlp_params(p, *, bits: int = 8, group_size: int = 32):
    """PTQ of one (possibly layer-stacked) MLP parameter tree.

    ``bits=8``: QTensor per weight; stacked (L, d, ff) tensors share one
    scale across layers so the static frac_bits survive a lax.scan over the
    stack. ``bits=4``: nibble-packed :class:`QTensorW4` per weight —
    per-layer group scales along the contraction (K) axis, but ONE base
    ``frac_bits`` pinned across the whole stack (min of the per-layer
    defaults, the clip-safe choice) so every scan slice carries identical
    statics; the per-layer slice ``(q[l], shifts[l])`` is exactly the 2D
    packed operand ``matmul_q8`` consumes (see QTensorW4's stacked-tree
    note)."""
    from repro.core.quantize import QTensorW4, quantize, quantize_w4
    if bits not in (8, 4):
        raise ValueError(f"quantize_mlp_params: bits must be 8 or 4, "
                         f"got {bits}")
    if bits == 8:
        return {k: quantize(v) for k, v in p.items()}
    out = {}
    for k, v in p.items():
        if v.ndim == 2:                       # single layer: (d_in, d_out)
            out[k] = quantize_w4(v, axis=0, group_size=group_size)
            continue
        layers = [quantize_w4(v[l], axis=0, group_size=group_size)
                  for l in range(v.shape[0])]
        fb = min(t.frac_bits for t in layers)
        if any(t.frac_bits != fb for t in layers):
            layers = [quantize_w4(v[l], axis=0, group_size=group_size,
                                  frac_bits=fb)
                      for l in range(v.shape[0])]
        out[k] = QTensorW4(jnp.stack([t.q for t in layers]),
                           jnp.stack([t.shifts for t in layers]),
                           frac_bits=fb, size=v.shape[1], axis=0)
    return out


def qmlp(h, qp, act: str, cdt, *, a_fb: int = ACT_FRAC_BITS,
         method: str = "pallas"):
    """Integer FFN: every matmul runs int8 x int8 -> int32 -> shift -> int8
    through the kernel layer (``matmul_q8``'s requantized epilogue under
    ``method="pallas"``, the jnp integer oracle under ``"xla"``). Both
    methods are bit-exact against each other. Serve-path only (no sharding
    constraints — the engine runs unpartitioned decode)."""
    from repro.core.quantize import QTensorW4, quantize
    from repro.kernels import ops as K
    b, s, d = h.shape
    x = quantize(h.reshape(b * s, d), frac_bits=a_fb)

    def mm(xq, w):
        # acc frac bits = a_fb + w.fb; requantize back to the activation
        # scale => shift by w.fb (static per tensor). W4 leaves stay
        # nibble-packed — matmul_q8 unpacks the half-width block in-register
        if isinstance(w, QTensorW4):
            return K.matmul(xq.q, w.q, method=method,
                            requant_shift=w.frac_bits, w_shifts=w.shifts)
        return K.matmul(xq.q, w.q, method=method, requant_shift=w.frac_bits)

    scale = 2.0 ** -a_fb
    if act == "silu":
        g = mm(x, qp["w_gate"]).astype(jnp.float32) * scale
        u = mm(x, qp["w_up"]).astype(jnp.float32) * scale
        z = jax.nn.silu(g) * u
    else:
        u = mm(x, qp["w_up"]).astype(jnp.float32) * scale
        z = jax.nn.gelu(u)
    zq = quantize(z, frac_bits=a_fb)
    y = mm(zq, qp["w_down"]).astype(jnp.float32) * scale
    return y.reshape(b, s, -1).astype(cdt)


def init_mlp(key, d, ff, act, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, ff ** -0.5
    p = {"w_up": jax.random.normal(k1, (d, ff), dtype) * s_in,
         "w_down": jax.random.normal(k2, (ff, d), dtype) * s_out}
    if act == "silu":
        p["w_gate"] = jax.random.normal(k3, (d, ff), dtype) * s_in
    return p


def mlp_specs(act, prefix_layers=True):
    L = ("layers",) if prefix_layers else ()
    p = {"w_up": L + ("embed", "ffn"), "w_down": L + ("ffn", "embed")}
    if act == "silu":
        p["w_gate"] = L + ("embed", "ffn")
    return p


# ----------------------------------------------------------------- RoPE ---
def rope(x, positions, theta: float):
    """x: (..., S, H, Dh); positions: (..., S). Rotates pairs (d, d+Dh/2)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq        # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def cross_entropy(logits, labels, mask=None):
    """Mean token CE in f32; labels < 0 are ignored."""
    logits = logits.astype(jnp.float32)
    valid = (labels >= 0) if mask is None else mask
    lab = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * valid
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)


def chunked_softmax_ce(h, w_unembed, labels, *, chunk: int = 512,
                       z_loss: float = 1e-4):
    """CE over a huge vocab without materializing (B, S, V) logits.

    Scans remat'd chunks of the sequence: each chunk computes its logits,
    reduces to (nll_sum, z_sum, count), and the (B, chunk, V) tensor is
    recomputed in the backward pass. Peak memory drops from O(S·V) to
    O(chunk·V) per device — mandatory at vocab 150k+ x 1M tokens.
    """
    b, s, d = h.shape
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = (s + pad) // c
    hc = jnp.moveaxis(h.reshape(b, n, c, d), 1, 0)          # (n, B, c, d)
    yc = jnp.moveaxis(labels.reshape(b, n, c), 1, 0)

    @jax.checkpoint
    def body(carry, xs):
        h_c, y_c = xs
        logits = h_c.astype(jnp.float32) @ w_unembed.astype(jnp.float32)
        logits = constrain(logits, "batch", None, "vocab")
        valid = y_c >= 0
        lab = jnp.maximum(y_c, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = jnp.sum((lse - ll) * valid)
        zs = jnp.sum(jnp.square(lse) * valid)
        cnt = jnp.sum(valid)
        l_sum, z_sum, n_sum = carry
        return (l_sum + nll, z_sum + zs, n_sum + cnt), None

    (l_sum, z_sum, n_sum), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(()), jnp.zeros((), jnp.int32)),
        (hc, yc))
    denom = jnp.maximum(n_sum, 1).astype(jnp.float32)
    return l_sum / denom + z_loss * z_sum / denom
