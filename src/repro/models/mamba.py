"""Mamba-1 block: depthwise causal conv1d (paper primitive, Pallas kernel)
+ selective state-space scan.

The conv1d stage runs on ``kernels/conv1d_causal.py`` — the paper's
depthwise convolution adapted to the LM stack (DESIGN.md §Arch-applicability).

The selective scan is chunked: a sequential ``lax.scan`` over chunks carries
the (B, d_inner, d_state) state; inside each chunk an
``associative_scan`` computes the recurrence in parallel. Chunking bounds
the backward-pass residuals to O(n_chunks · state) instead of O(L · state),
and each chunk body is remat'd.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import MambaConfig
from repro.kernels.ops import causal_conv1d
from repro.parallel.sharding import constrain


def init_mamba(key, d: int, m: MambaConfig, dtype):
    di = m.expand * d
    rank = m.rank(d)
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    p = {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (m.d_conv, di), dtype) * (m.d_conv ** -0.5),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": jax.random.normal(ks[2], (di, rank + 2 * m.d_state), dtype) * (di ** -0.5),
        "dt_proj": jax.random.normal(ks[3], (rank, di), dtype) * (rank ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(jnp.clip(
            jnp.exp(jax.random.uniform(ks[4], (di,)) * 7.0 - 7.0) * 0.099 + 0.001,
            1e-4))).astype(dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, m.d_state + 1, dtype=jnp.float32),
                                  (di, 1))).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": jax.random.normal(ks[5], (di, d), dtype) * (di ** -0.5),
    }
    return p


def mamba_specs(prefix_layers=True):
    L = ("layers",) if prefix_layers else ()
    return {
        "in_proj": L + ("embed", "d_inner"),
        "conv_w": L + (None, "d_inner"),
        "conv_b": L + ("d_inner",),
        "x_proj": L + ("d_inner", None),
        "dt_proj": L + (None, "d_inner"),
        "dt_bias": L + ("d_inner",),
        "A_log": L + ("d_inner", None),
        "D": L + ("d_inner",),
        "out_proj": L + ("d_inner", "embed"),
    }


def _ssm_chunk(h0, a_c, b_c, c_t):
    """One chunk of the selective scan.

    h0: (B, dI, N); a_c/b_c: (B, Lc, dI, N); c_t: (B, Lc, N).
    Returns (h_last, y (B, Lc, dI)).
    """
    def comb(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    cum_a, cum_b = lax.associative_scan(comb, (a_c, b_c), axis=1)
    h = cum_a * h0[:, None] + cum_b                        # (B, Lc, dI, N)
    y = jnp.einsum("blds,bls->bld", h, c_t)
    return h[:, -1], y


def mamba_scan(x_c, dt, A, B_t, C_t, *, chunk: int = 256, h0=None):
    """Selective scan. x_c, dt: (B,L,dI); A: (dI,N); B_t, C_t: (B,L,N).

    Discretization (a = exp(dt*A), b = dt*B*x) happens LAZILY inside each
    remat'd chunk: only (B, chunk, dI, N) f32 tensors ever materialize —
    never (B, L, dI, N) — which keeps the per-layer footprint at
    O(L/chunk) of the naive formulation.
    """
    b, l, di = x_c.shape
    n = A.shape[-1]
    ch = min(chunk, l)
    while l % ch:
        ch -= 1
    nchunks = l // ch
    A32 = A.astype(jnp.float32)

    def chunked(t):
        return jnp.moveaxis(t.reshape(b, nchunks, ch, t.shape[-1]), 1, 0)

    @jax.checkpoint
    def step(h, inp):
        dt_c, x_cc, b_c, c_c = inp
        dt32 = dt_c.astype(jnp.float32)
        a_c = jnp.exp(dt32[..., None] * A32[None, None])       # (B,ch,dI,N)
        bx_c = (dt32 * x_cc.astype(jnp.float32))[..., None] \
            * b_c.astype(jnp.float32)[:, :, None, :]
        return _ssm_chunk(h, a_c, bx_c, c_c.astype(jnp.float32))

    h_init = jnp.zeros((b, di, n), jnp.float32) if h0 is None else h0
    h_last, ys = lax.scan(step, h_init,
                          (chunked(dt), chunked(x_c), chunked(B_t),
                           chunked(C_t)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, l, di)
    return y.astype(x_c.dtype), h_last


def _resolve_conv_method(method: str) -> str:
    """'auto': the Pallas kernel on single-device runs (exercises the paper
    primitive); the XLA path under a mesh — an opaque pallas_call would
    force its operands replicated under SPMD partitioning (DESIGN.md)."""
    if method != "auto":
        return method
    from repro.parallel.sharding import current_mesh
    return "xla" if current_mesh() is not None else "pallas"


def mamba_forward(p, x, m: MambaConfig, cdt, *, chunk: int = 256,
                  conv_method: str = "auto"):
    """Full-sequence Mamba block. x: (B, L, d) -> (B, L, d)."""
    di = p["conv_w"].shape[-1]
    rank = p["dt_proj"].shape[0]
    n = p["A_log"].shape[-1]
    xz = x @ p["in_proj"].astype(cdt)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = constrain(x_in, "batch", None, "d_inner")
    x_c = causal_conv1d(x_in, p["conv_w"].astype(cdt), method=conv_method)
    x_c = jax.nn.silu(x_c + p["conv_b"].astype(cdt))
    dbc = x_c @ p["x_proj"].astype(cdt)
    dt_low, b_t, c_t = jnp.split(dbc, [rank, rank + n], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_proj"].astype(cdt)
                         + p["dt_bias"].astype(cdt))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, _ = mamba_scan(x_c, dt, A, b_t, c_t, chunk=chunk)
    y = y + p["D"].astype(cdt) * x_c
    y = y * jax.nn.silu(z)
    y = constrain(y, "batch", None, "d_inner")
    return y @ p["out_proj"].astype(cdt)


# ---------------------------------------------------------------- decode ---

def mamba_init_state(cfg_d: int, m: MambaConfig, batch: int, dtype=jnp.float32):
    di = m.expand * cfg_d
    return {"conv": jnp.zeros((batch, m.d_conv - 1, di), dtype),
            "ssm": jnp.zeros((batch, di, m.d_state), jnp.float32)}


def mamba_decode_step(p, x_t, state, m: MambaConfig, cdt):
    """One token. x_t: (B, 1, d); state: {conv (B,K-1,dI), ssm (B,dI,N)}."""
    rank = p["dt_proj"].shape[0]
    n = p["A_log"].shape[-1]
    xz = x_t @ p["in_proj"].astype(cdt)
    x_in, z = jnp.split(xz, 2, axis=-1)                    # (B,1,dI)
    window = jnp.concatenate([state["conv"].astype(cdt), x_in], axis=1)
    w = p["conv_w"].astype(cdt)                            # (K, dI)
    x_c = jnp.einsum("bkd,kd->bd", window, w)[:, None] + p["conv_b"].astype(cdt)
    x_c = jax.nn.silu(x_c)
    dbc = x_c @ p["x_proj"].astype(cdt)
    dt_low, b_t, c_t = jnp.split(dbc, [rank, rank + n], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_proj"].astype(cdt)
                         + p["dt_bias"].astype(cdt))       # (B,1,dI)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt.astype(jnp.float32)[0 if False else ...][..., None] * A[None, None])
    a = a[:, 0]                                            # (B,dI,N)
    bx = (dt.astype(jnp.float32) * x_c.astype(jnp.float32))[:, 0, :, None] \
        * b_t.astype(jnp.float32)[:, 0, None, :]
    h = a * state["ssm"] + bx
    y = jnp.einsum("bds,bs->bd", h, c_t.astype(jnp.float32)[:, 0])[:, None]
    y = y.astype(cdt) + p["D"].astype(cdt) * x_c
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(cdt)
    new_state = {"conv": window[:, 1:].astype(state["conv"].dtype), "ssm": h}
    return out, new_state
