"""Mixture-of-Experts with sort-free capacity dispatch + expert parallelism.

Dispatch is scatter-based (GShard-style capacity + dropping) but never
materializes a (tokens, E, C) one-hot: position-in-expert comes from a
cumsum over a (tokens, E) one-hot and tokens scatter into a dense
(E, C, d) buffer. Two execution paths:

  * local   — no collectives; used on 1 device and as the test oracle.
  * sharded — shard_map over ("model",): tokens are split across the model
    axis (token parallelism), dispatched locally, then exchanged with
    all_to_all so each model shard computes only its E/nm local experts
    (expert parallelism), and a2a'd back. DP/pod axes stay batch-parallel.
    This is the DeepSpeed-MoE / GShard layout mapped to jax collectives.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.parallel.sharding import active_rules, current_mesh

from .blocks import mlp


def init_moe(key, d: int, moe: MoEConfig, act: str, dtype):
    ks = jax.random.split(key, 4)
    e, ff = moe.num_experts, moe.d_ff
    s_in, s_out = d ** -0.5, ff ** -0.5
    p = {"router": jax.random.normal(ks[0], (d, e), dtype) * s_in,
         "w_up": jax.random.normal(ks[1], (e, d, ff), dtype) * s_in,
         "w_down": jax.random.normal(ks[2], (e, ff, d), dtype) * s_out}
    if act == "silu":
        p["w_gate"] = jax.random.normal(ks[3], (e, d, ff), dtype) * s_in
    return p


def moe_specs(act, prefix_layers=True):
    L = ("layers",) if prefix_layers else ()
    p = {"router": L + ("embed", None),
         "w_up": L + ("experts", "embed", "ffn_expert"),
         "w_down": L + ("experts", "ffn_expert", "embed")}
    if act == "silu":
        p["w_gate"] = L + ("experts", "embed", "ffn_expert")
    return p


def _capacity(tokens: int, moe: MoEConfig) -> int:
    c = int(moe.top_k * tokens * moe.capacity_factor / moe.num_experts)
    return max(c, 1)


def _route(x2d, router, top_k: int):
    logits = (x2d.astype(jnp.float32) @ router.astype(jnp.float32))
    vals, ids = lax.top_k(logits, top_k)                  # (T, k)
    probs = jax.nn.softmax(vals, axis=-1)                 # normalize over top-k
    return probs, ids


def _dispatch_combine(x2d, probs, ids, expert_fn, num_experts: int, cap: int):
    """Scatter tokens to (E, C, d), run expert_fn, gather-combine back."""
    t, d = x2d.shape
    k = ids.shape[1]
    flat_ids = ids.reshape(-1)                            # (T*k,)
    tok_idx = jnp.repeat(jnp.arange(t), k)
    oh = jax.nn.one_hot(flat_ids, num_experts, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=0) - 1                      # running count
    pos = jnp.take_along_axis(pos, flat_ids[:, None], axis=1)[:, 0]
    keep = pos < cap
    dest = jnp.where(keep, flat_ids * cap + pos, num_experts * cap)  # drop slot
    disp = jnp.zeros((num_experts * cap + 1, d), x2d.dtype)
    disp = disp.at[dest].add(x2d[tok_idx] * keep[:, None].astype(x2d.dtype))
    h = expert_fn(disp[:-1].reshape(num_experts, cap, d))
    h = h.reshape(num_experts * cap, d)
    h = jnp.concatenate([h, jnp.zeros((1, d), h.dtype)], axis=0)
    gathered = h[dest] * (probs.reshape(-1)[:, None].astype(h.dtype)
                          * keep[:, None].astype(h.dtype))
    out = jnp.zeros((t, d), x2d.dtype)
    return out.at[tok_idx].add(gathered.astype(x2d.dtype))


def _expert_ffn(blocks, p, act, cdt):
    """blocks: (E_local, C, d); expert weights (E_local, d, ff)/(E_local, ff, d)."""
    blocks = blocks.astype(cdt)     # keep the MXU path in compute dtype —
    # a stray f32 operand would promote (and LICM-hoist an f32 copy of) the
    # whole stacked expert-weight tensor
    up = jnp.einsum("ecd,edf->ecf", blocks, p["w_up"].astype(cdt))
    if act == "silu":
        gate = jnp.einsum("ecd,edf->ecf", blocks, p["w_gate"].astype(cdt))
        z = jax.nn.silu(gate) * up
    else:
        z = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", z, p["w_down"].astype(cdt))


def moe_ffn_local(x, p, moe: MoEConfig, act: str, cdt):
    """(B, S, d) -> (B, S, d), no collectives."""
    b, s, d = x.shape
    x2 = x.reshape(-1, d)
    probs, ids = _route(x2, p["router"], moe.top_k)
    cap = _capacity(x2.shape[0], moe)
    fn = functools.partial(_expert_ffn, p=p, act=act, cdt=cdt)
    y = _dispatch_combine(x2, probs, ids, lambda blk: fn(blk), moe.num_experts, cap)
    return y.reshape(b, s, d)


# decode paths prefer the local (pjit-constraint) path: one token per slot
# is too small for the token-split + a2a pipeline to pay off.
_PREFER_LOCAL: list = [False]


class prefer_local:
    def __enter__(self):
        _PREFER_LOCAL.append(True)

    def __exit__(self, *exc):
        _PREFER_LOCAL.pop()


def moe_ffn_sharded(x, p, moe: MoEConfig, act: str, cdt, model_axis="model"):
    """shard_map EP path. x: (B, S, d) with batch sharded over the DP axes and
    d replicated across model_axis; experts sharded over model_axis."""
    mesh = current_mesh()
    if mesh is None or mesh.shape.get(model_axis, 1) == 1 \
            or moe.num_experts % mesh.shape.get(model_axis, 1) \
            or _PREFER_LOCAL[-1]:
        return moe_ffn_local(x, p, moe, act, cdt)
    kept, size = [], 1
    for a in ("pod", "data"):
        if a in mesh.axis_names and x.shape[0] % (size * mesh.shape[a]) == 0:
            kept.append(a)
            size *= mesh.shape[a]
    batch_axes = tuple(kept)
    nm = mesh.shape[model_axis]
    e_local = moe.num_experts // nm
    d = x.shape[-1]

    def body(xb, router, *expert_w):
        pw = dict(zip(sorted(k for k in p if k != "router"), expert_w))
        bl, sl, _ = xb.shape
        t2 = xb.reshape(-1, d)
        t_pad = -(-t2.shape[0] // nm) * nm
        t2p = jnp.pad(t2, ((0, t_pad - t2.shape[0]), (0, 0)))
        tloc = t_pad // nm
        j = lax.axis_index(model_axis)
        xj = lax.dynamic_slice_in_dim(t2p, j * tloc, tloc)      # token split (TP->token-parallel)
        probs, ids = _route(xj, router, moe.top_k)
        cap = _capacity(tloc, moe)

        def experts_a2a(blocks):                 # (E, C, d) global experts
            de = lax.all_to_all(blocks, model_axis, split_axis=0,
                                concat_axis=1, tiled=True)      # (E/nm, nm*C, d)
            h = _expert_ffn(de, pw, act, cdt)
            return lax.all_to_all(h, model_axis, split_axis=1,
                                  concat_axis=0, tiled=True)    # (E, C, d)

        yj = _dispatch_combine(xj, probs, ids, experts_a2a,
                               moe.num_experts, cap)
        y = lax.all_gather(yj, model_axis, axis=0, tiled=True)  # (t_pad, d)
        return y[:t2.shape[0]].reshape(bl, sl, d)

    from jax.experimental.shard_map import shard_map
    batch_spec = P(batch_axes, None, None) if batch_axes else P(None, None, None)
    expert_keys = sorted(k for k in p if k != "router")
    expert_specs = tuple(P(model_axis, None, None) for _ in expert_keys)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(batch_spec, P(None, None)) + expert_specs,
        out_specs=batch_spec, check_rep=False)
    # cast expert weights to the compute dtype BEFORE they cross the
    # shard_map boundary: a promotion inside would be LICM-hoisted into a
    # full f32 copy of the stacked expert tensors
    return fn(x, p["router"], *[p[k].astype(cdt) for k in expert_keys])


def moe_ffn(x, p, moe: MoEConfig, act: str, cdt):
    return moe_ffn_sharded(x, p, moe, act, cdt)
