"""Optimizers (AdamW, SGD-momentum), LR schedules, global-norm clipping.

Optimizer state inherits the parameter sharding (ZeRO by construction when
FSDP rules shard the weights). ``state_dtype`` lets very large archs
(arctic-480b) hold m/v in bf16 — the 8-bit-optimizer-class memory tradeoff,
sized in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: Optional[str] = None     # None -> same as params


def schedule(cfg: OptConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def _is_float(x):
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree) if _is_float(x)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return jax.tree_util.tree_map(
        lambda x: x * scale.astype(x.dtype) if _is_float(x) else x, grads), g


def init_opt_state(params, cfg: OptConfig):
    sdt = cfg.state_dtype
    def zeros_like(p):
        dt = jnp.dtype(sdt) if sdt else p.dtype
        return jnp.zeros(p.shape, dt)
    if cfg.name == "adamw":
        return {"m": jax.tree_util.tree_map(zeros_like, params),
                "v": jax.tree_util.tree_map(zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}
    if cfg.name == "sgdm":
        return {"m": jax.tree_util.tree_map(zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.name)


def apply_updates(params, grads, state, cfg: OptConfig):
    """One optimizer step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = schedule(cfg, step)

    if cfg.name == "adamw":
        b1, b2 = cfg.b1, cfg.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            if not _is_float(p):          # int params (e.g. shift tables)
                return (p, m, v)
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                    m32.astype(m.dtype), v32.astype(v.dtype))

        out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
        new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"m": new_m, "v": new_v, "step": step}, \
            {"lr": lr, "grad_norm": gnorm}

    if cfg.name == "sgdm":
        def upd(p, g, m):
            if not _is_float(p):
                return (p, m)
            m32 = 0.9 * m.astype(jnp.float32) + g.astype(jnp.float32)
            return ((p.astype(jnp.float32)
                     - lr * (m32 + cfg.weight_decay * p.astype(jnp.float32))
                     ).astype(p.dtype), m32.astype(m.dtype))
        out = jax.tree_util.tree_map(upd, params, grads, state["m"])
        new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"m": new_m, "step": step}, {"lr": lr, "grad_norm": gnorm}

    raise ValueError(cfg.name)
