"""int8 error-feedback gradient compression for the DP all-reduce.

Applies the paper's power-of-two int8 scheme (core/quantize) to gradient
all-reduce traffic: each DP step quantizes grads to int8 with a per-tensor
power-of-two scale, all-reduces the int8 payload (4x fewer DCN bytes on the
pod axis), dequantizes, and folds the quantization residual into the next
step (error feedback), which keeps SGD/Adam convergence unbiased in
practice. Used with shard_map on the ("pod","data") axes; off by default,
recommended for multi-pod runs (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _pow2_scale(x):
    """Power-of-two scale covering max|x| (Eq. 4, dynamic/traced version)."""
    m = jnp.max(jnp.abs(x))
    exp = jnp.ceil(jnp.log2(jnp.maximum(m, 1e-30)))
    return jnp.exp2(exp - 7.0)                 # int8 full scale


def compress(x, err):
    """-> (int8 payload, scale, new_err). x+err is quantized."""
    t = x.astype(jnp.float32) + err
    s = _pow2_scale(t)
    q = jnp.clip(jnp.round(t / s), -128, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * s
    return q, s, t - deq


def allreduce_compressed(grads, errors, axis_names):
    """Per-leaf int8 psum over `axis_names` with error feedback.

    Must run inside shard_map (needs named axes). Returns (mean grads,
    new errors).
    """
    # jax.lax.axis_size only exists on newer JAX; psum(1) is the portable
    # spelling of the same quantity (product of the named axis sizes)
    n = jax.lax.psum(1, axis_names)

    def leaf(g, e):
        q, s, new_e = compress(g, e)
        # psum int32 accumulates exactly; scales are shared via max
        s_max = jax.lax.pmax(s, axis_names)
        # requantize to the common scale before summing
        q_common = jnp.clip(jnp.round(q.astype(jnp.float32) * (s / s_max)),
                            -128, 127).astype(jnp.int32)
        tot = jax.lax.psum(q_common, axis_names)
        return (tot.astype(jnp.float32) * s_max / n).astype(g.dtype), new_e

    out = jax.tree_util.tree_map(leaf, grads, errors)
    new_g = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_e = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_e


def init_errors(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
