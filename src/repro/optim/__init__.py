from .optimizer import (OptConfig, apply_updates, clip_by_global_norm,
                        global_norm, init_opt_state, schedule)
from .compression import allreduce_compressed, compress, init_errors
