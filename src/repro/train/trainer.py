"""Fault-tolerant training loop.

Production behaviors, all CI-tested on fake devices:
  * checkpoint/restart: async sharded checkpoints every `ckpt_every` steps;
    restore resumes from the latest committed step — the index-based data
    pipeline replays the exact batch sequence, so an interrupted run and an
    uninterrupted run produce identical losses (tests/test_trainer.py).
  * preemption: SIGTERM triggers a final blocking checkpoint and clean exit.
  * bad-step rejection: non-finite loss/grad-norm steps are SKIPPED (params
    and optimizer state are kept; the batch is consumed) — the standard
    large-run guard against data spikes; a counter is reported.
  * straggler/heartbeat hook: each step reports (step, wall_time) to a
    monitor; the monitor flags steps slower than `straggler_factor` x the
    trailing median — on real fleets this feeds the remesh/evict policy
    (here: logged + counted, and the policy object is pluggable).
  * elastic restart: `restore()` reshards onto whatever mesh is active.
"""
from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import IndexedDataset, PrefetchLoader
from repro.optim import OptConfig, init_opt_state

from .train_step import TrainConfig, make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0


class HeartbeatMonitor:
    """Tracks step wall-times; flags stragglers vs trailing median."""

    def __init__(self, factor: float = 3.0, window: int = 32):
        self.factor = factor
        self.window = window
        self.times: list[float] = []
        self.stragglers = 0

    def beat(self, dt: float) -> bool:
        flagged = False
        if len(self.times) >= 8:
            med = statistics.median(self.times[-self.window:])
            flagged = dt > self.factor * med
            self.stragglers += int(flagged)
        self.times.append(dt)
        return flagged


class Trainer:
    def __init__(self, cfg, tcfg: TrainConfig, loop: LoopConfig,
                 dataset: IndexedDataset, init_params_fn: Callable,
                 param_shardings=None, batch_shardings=None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.loop = loop
        self.ds = dataset
        self.ckpt = Checkpointer(loop.ckpt_dir, keep=loop.keep)
        self.monitor = HeartbeatMonitor(loop.straggler_factor)
        self.step_fn = jax.jit(make_train_step(cfg, tcfg),
                               donate_argnums=(0, 1))
        self._preempted = False
        self._init_params_fn = init_params_fn
        self.param_shardings = param_shardings
        self.batch_shardings = batch_shardings
        self.skipped = 0

    # -------------------------------------------------------- lifecycle --
    def install_preemption_handler(self):
        def _handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, _handler)

    def init_or_restore(self, seed: int = 0):
        params = self._init_params_fn(jax.random.PRNGKey(seed))
        opt_state = init_opt_state(params, self.tcfg.opt)
        start = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            tree = {"params": params, "opt": opt_state}
            sh = None
            if self.param_shardings is not None:
                sh = {"params": self.param_shardings,
                      "opt": {"m": self.param_shardings,
                              "v": self.param_shardings, "step": None}}
            tree, start = self.ckpt.restore(tree, shardings=sh)
            params, opt_state = tree["params"], tree["opt"]
        return params, opt_state, start

    # -------------------------------------------------------------- run --
    def run(self, params=None, opt_state=None, start_step: Optional[int] = None,
            seed: int = 0):
        if params is None:
            params, opt_state, start_step = self.init_or_restore(seed)
        start_step = start_step or 0
        loader = PrefetchLoader(self.ds, start_step,
                                sharding=self.batch_shardings)
        history = []
        step = start_step
        while step < self.loop.total_steps:
            batch = next(loader)
            t0 = time.perf_counter()
            new_params, new_opt, metrics = self.step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            gnorm = float(metrics["grad_norm"])
            dt = time.perf_counter() - t0
            self.monitor.beat(dt)
            if not (jnp.isfinite(loss) and jnp.isfinite(gnorm)):
                # bad step: drop the update, keep going (donated bufs force
                # a re-materialization path — acceptable for the rare case)
                self.skipped += 1
                params, opt_state = new_params, new_opt   # buffers are donated
                # restore from last checkpoint if state itself went bad
                if not all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
                           for l in jax.tree_util.tree_leaves(params)
                           if jnp.issubdtype(l.dtype, jnp.floating)):
                    tree, _ = self.ckpt.restore(
                        {"params": params, "opt": opt_state})
                    params, opt_state = tree["params"], tree["opt"]
            else:
                params, opt_state = new_params, new_opt
                history.append(dict(step=step, loss=loss, grad_norm=gnorm,
                                    sec=dt))
            step += 1
            if step % self.loop.ckpt_every == 0 or self._preempted:
                self.ckpt.save(step, {"params": params, "opt": opt_state},
                               block=self._preempted)
                if self._preempted:
                    return params, opt_state, step, history
            if self.loop.log_every and step % self.loop.log_every == 0:
                print(f"step {step} loss {loss:.4f} gnorm {gnorm:.3f} "
                      f"{dt*1e3:.0f}ms", flush=True)
        self.ckpt.save(self.loop.total_steps,
                       {"params": params, "opt": opt_state}, block=True)
        self.ckpt.wait()
        return params, opt_state, step, history
