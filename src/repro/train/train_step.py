"""Train-step builder: loss + grad + optimizer update, with microbatch
gradient accumulation and optional int8 error-feedback grad compression."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import api
from repro.optim import OptConfig, apply_updates


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    remat: str = "full"                 # full | dots | none
    attn_impl: str = "full"
    microbatches: int = 1               # grad accumulation steps
    compress_grads: bool = False        # int8 EF all-reduce on DP axes


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    loss_fn = api.loss_fn(cfg, attn_impl=tcfg.attn_impl, remat=tcfg.remat)

    def grads_of(params, batch):
        if tcfg.microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        n = tcfg.microbatches

        def micro(carry, mb):
            acc_loss, acc_g = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            return (acc_loss + l,
                    jax.tree_util.tree_map(jnp.add, acc_g, g)), None

        mbs = jax.tree_util.tree_map(
            lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)
        # accumulate in the param dtype: f32 archs get f32 accumulation;
        # bf16-param archs (arctic) trade accumulation precision for memory
        zero_g = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, p.dtype), params)
        (loss, grads), _ = lax.scan(micro, (jnp.zeros(()), zero_g), mbs)
        scale = 1.0 / n
        return loss * scale, jax.tree_util.tree_map(
            lambda g: g * scale, grads)

    def step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        params, opt_state, om = apply_updates(params, grads, opt_state, tcfg.opt)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return step


def estimate_model_flops(cfg: ModelConfig, tokens: int, kind: str = "train") -> float:
    """MODEL_FLOPS: 6·N·D train (2·N·D serve), N = active params (MoE)."""
    n = cfg.param_count()
    if cfg.moe is not None:
        moe = cfg.moe
        act = moe.top_k / moe.num_experts
        n_mat = 3 if cfg.act == "silu" else 2
        expert_params = n_mat * cfg.d_model * moe.d_ff * moe.num_experts
        if cfg.family == "moe":
            n_moe_layers = cfg.n_layers
        else:                       # hybrid
            n_moe_layers = cfg.n_layers // moe.every_n_layers
        total_expert = n_moe_layers * expert_params
        n = n - total_expert + total_expert * act
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
