from .train_step import TrainConfig, estimate_model_flops, make_train_step
from .trainer import LoopConfig, Trainer, HeartbeatMonitor
