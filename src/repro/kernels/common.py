"""Shared helpers for the Pallas TPU kernels.

TPU target, CPU-validated: kernels are written against the TPU memory
hierarchy (HBM -> VMEM BlockSpecs -> MXU/VPU) and validated on CPU with
``interpret=True``. ``use_interpret()`` auto-selects interpret mode when no
TPU is present so the same call sites run everywhere.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

# MXU native tile: 128x128 systolic; VPU lanes (8, 128).
MXU = 128
SUBLANE = 8


def use_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "auto")
    if env in ("1", "true"):
        return True
    if env in ("0", "false"):
        return False
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def pad_dim(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    """Zero-pad one axis up to a multiple (wrapper-level tile alignment)."""
    size = x.shape[axis]
    target = round_up(size, multiple)
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads)


def pick_block(dim: int, preferred: int) -> int:
    """Largest power-of-two block <= preferred that is used with a cdiv grid
    + wrapper padding, so any dim works while MXU-aligned dims stay aligned."""
    b = min(preferred, dim)
    # round up small dims to themselves; keep pow2-ish blocks otherwise
    p = 1
    while p * 2 <= b:
        p *= 2
    return p if dim >= preferred else round_up(dim, SUBLANE) if dim % SUBLANE else dim


def acc_dtype(dtype) -> jnp.dtype:
    return jnp.int32 if jnp.issubdtype(dtype, jnp.integer) else jnp.float32


def apply_requant(acc: jax.Array, requant_shift: int | None) -> jax.Array:
    """Algorithm-1 epilogue on an int32 accumulator: round-to-nearest
    arithmetic shift to the output scale, clipped to the int8 range.

    The shift IS ``core.quantize.rshift_round`` (one implementation, so the
    Pallas kernel epilogues, the jnp oracles in ``kernels/ref.py``, and the
    host-side requantization are bit-exact by construction).
    ``requant_shift`` may be negative (pure left shift, exact) or ``None``
    (no-op, float paths).
    """
    if requant_shift is None:
        return acc
    from repro.core.quantize import rshift_round
    return jnp.clip(rshift_round(acc, requant_shift), -128, 127)


def apply_act(acc: jax.Array, act: str | None) -> jax.Array:
    """Fused activation epilogue, applied at ACCUMULATOR scale (int32/f32),
    i.e. before ``apply_requant``. Requantization is a monotonic shift with
    ``rshift_round(0) == 0``, so ``relu`` before the shift is bit-exact with
    relu on the requantized int8 (and with float relu after dequantization)
    — which is what lets the graph executor fuse the whole
    conv+BN+ReLU block into one kernel with zero float round-trips.
    """
    if act is None:
        return acc
    if act == "relu":
        return jnp.maximum(acc, 0)
    raise ValueError(f"unknown act {act!r}; expected 'relu' or None")


def effective_block(dim: int, block: int) -> int:
    """The block size a divisor-gridded kernel actually runs: the largest
    divisor of ``dim`` that is <= ``block``. Single source of truth shared by
    the kernel wrappers, the tuner's search space, and its cost model — two
    configs with the same effective block are the same schedule."""
    b = max(1, min(block, dim))
    while dim % b:
        b -= 1
    return b
