"""Shared helpers for the Pallas TPU kernels.

TPU target, CPU-validated: kernels are written against the TPU memory
hierarchy (HBM -> VMEM BlockSpecs -> MXU/VPU) and validated on CPU with
``interpret=True``. ``use_interpret()`` auto-selects interpret mode when no
TPU is present so the same call sites run everywhere.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

# MXU native tile: 128x128 systolic; VPU lanes (8, 128).
MXU = 128
SUBLANE = 8


def use_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "auto")
    if env in ("1", "true"):
        return True
    if env in ("0", "false"):
        return False
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret) -> bool:
    """Resolve a kernel wrapper's ``interpret`` argument: ``None`` (the
    default everywhere) means backend-detected — compiled on TPU, interpreter
    elsewhere (and whatever REPRO_PALLAS_INTERPRET forces, which is how CI
    pins interpret mode). An explicit bool always wins, so benchmarks can
    still measure the interpreter deliberately."""
    return use_interpret() if interpret is None else bool(interpret)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def pad_dim(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    """Zero-pad one axis up to a multiple (wrapper-level tile alignment)."""
    size = x.shape[axis]
    target = round_up(size, multiple)
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads)


def pick_block(dim: int, preferred: int) -> int:
    """Largest power-of-two block <= preferred that is used with a cdiv grid
    + wrapper padding, so any dim works while MXU-aligned dims stay aligned."""
    b = min(preferred, dim)
    # round up small dims to themselves; keep pow2-ish blocks otherwise
    p = 1
    while p * 2 <= b:
        p *= 2
    return p if dim >= preferred else round_up(dim, SUBLANE) if dim % SUBLANE else dim


def acc_dtype(dtype) -> jnp.dtype:
    return jnp.int32 if jnp.issubdtype(dtype, jnp.integer) else jnp.float32


def apply_requant(acc: jax.Array, requant_shift: int | None) -> jax.Array:
    """Algorithm-1 epilogue on an int32 accumulator: round-to-nearest
    arithmetic shift to the output scale, clipped to the int8 range.

    The shift IS ``core.quantize.rshift_round`` (one implementation, so the
    Pallas kernel epilogues, the jnp oracles in ``kernels/ref.py``, and the
    host-side requantization are bit-exact by construction).
    ``requant_shift`` may be negative (pure left shift, exact) or ``None``
    (no-op, float paths).
    """
    if requant_shift is None:
        return acc
    from repro.core.quantize import rshift_round
    return jnp.clip(rshift_round(acc, requant_shift), -128, 127)


def apply_act(acc: jax.Array, act: str | None) -> jax.Array:
    """Fused activation epilogue, applied at ACCUMULATOR scale (int32/f32),
    i.e. before ``apply_requant``. Requantization is a monotonic shift with
    ``rshift_round(0) == 0``, so ``relu`` before the shift is bit-exact with
    relu on the requantized int8 (and with float relu after dequantization)
    — which is what lets the graph executor fuse the whole
    conv+BN+ReLU block into one kernel with zero float round-trips.
    """
    if act is None:
        return acc
    if act == "relu":
        return jnp.maximum(acc, 0)
    raise ValueError(f"unknown act {act!r}; expected 'relu' or None")


def resolve_tile_config(config, block_n: int, block_h, block_w):
    """Overlay a repro.tune schedule dict onto a kernel wrapper's
    (block_n, block_h, block_w) arguments — the one place the tiled-grid
    knobs are parsed, so every kernel stays in sync with the tuner's space
    (falsy/absent spatial blocks mean "whole extent")."""
    if config:
        block_n = int(config.get("block_n", block_n))
        block_h = int(config["block_h"]) if config.get("block_h") else block_h
        block_w = int(config["block_w"]) if config.get("block_w") else block_w
    return block_n, block_h, block_w


def batch_spatial_schedule(n: int, h: int, w: int, block_n: int,
                           block_h, block_w):
    """Resolve the (batch_block, spatial_tile) half of the tiled conv grid.

    ``block_n`` degrades to the largest divisor of the batch (the executor's
    pow2 batch buckets make this exact in practice); ``block_h``/``block_w``
    clamp to the output extent and grid with cdiv + wrapper padding, so odd
    feature maps get ragged final tiles instead of degenerate 1-row blocks.
    ``None`` spatial blocks mean "whole extent" (the untiled pre-batching
    schedule). Returns ``(bn, bh, bw, n_th, n_tw)``.
    """
    bn = effective_block(n, max(1, int(block_n)))
    bh = max(1, min(int(block_h) if block_h else h, h))
    bw = max(1, min(int(block_w) if block_w else w, w))
    return bn, bh, bw, cdiv(h, bh), cdiv(w, bw)


def halo_tiles(x: jax.Array, n_th: int, n_tw: int, step_h: int, step_w: int,
               size_h: int, size_w: int) -> jax.Array:
    """Overlapping spatial tile tensor for the halo-padded conv/pool grids:
    ``(N, Hp, Wp, C) -> (N, Th, Tw, size_h, size_w, C)`` where tile (i, j)
    is ``x[:, i*step_h : i*step_h+size_h, j*step_w : j*step_w+size_w]``.

    Pallas blocked BlockSpecs stride by the block shape, so halos cannot be
    expressed as overlapping blocks directly; instead the wrapper duplicates
    the ``size - step`` halo rows/cols once in HBM (overhead factor
    ``size/step`` per axis — small for the tile sizes the tuner picks) and
    the kernel grid indexes disjoint tiles. Bottom/right are zero-padded to
    full tiles; the padded region only feeds output rows the wrapper crops,
    so correctness never depends on the pad value. The untiled case
    (one tile covering everything) degenerates to a free reshape.
    """
    n, hp, wp, c = x.shape
    need_h = (n_th - 1) * step_h + size_h
    need_w = (n_tw - 1) * step_w + size_w
    if need_h > hp or need_w > wp:
        x = jnp.pad(x, ((0, 0), (0, max(0, need_h - hp)),
                        (0, max(0, need_w - wp)), (0, 0)))
    if n_th == 1 and n_tw == 1:
        return x[:, None, None, :size_h, :size_w, :]
    rows = jnp.stack([x[:, i * step_h:i * step_h + size_h]
                      for i in range(n_th)], axis=1)
    return jnp.stack([rows[:, :, :, j * step_w:j * step_w + size_w, :]
                      for j in range(n_tw)], axis=2)


def unpack_w4_block(wp: jax.Array, size: int, axis: int = 0) -> jax.Array:
    """In-register nibble unpack for a W4-packed weight block: int8 bytes
    holding two two's-complement int4 codes -> int32 codes, ``shape[axis]``
    going ``ceil(size/2) * 2 -> size``. Element ``2i`` is the low nibble of
    byte ``i`` (``core.quantize.pack_w4``'s layout). Runs inside kernel
    bodies on VPU registers, so the packed block is what crosses HBM->VMEM
    (the halved-weight-traffic contract); the arithmetic mirrors
    ``core.quantize.unpack_w4`` bit-for-bit. Zero bytes unpack to zero
    codes, so Pallas' zero-padded ragged blocks stay neutral."""
    axis = axis % wp.ndim
    pi = wp.astype(jnp.int32)
    lo = jnp.right_shift(jnp.left_shift(pi, 28), 28)    # sign-extend bits 0-3
    hi = jnp.right_shift(jnp.left_shift(pi, 24), 28)    # sign-extend bits 4-7
    out = jnp.stack([lo, hi], axis=axis + 1)
    shape = list(wp.shape)
    shape[axis] = shape[axis] * 2
    out = out.reshape(shape)
    if out.shape[axis] == size:
        return out
    return jax.lax.slice_in_dim(out, 0, size, axis=axis)


def shift_w4_block(w4: jax.Array, ws: jax.Array, axis: int = 0) -> jax.Array:
    """Apply a W4 per-element group-scale shift vector along ``axis`` of an
    unpacked int32 code block: ``q4 << shift`` at the shared base scale —
    the in-kernel half of ``core.quantize.expand_w4``."""
    bshape = [1] * w4.ndim
    bshape[axis % w4.ndim] = ws.shape[-1]
    return jnp.left_shift(w4, ws.astype(jnp.int32).reshape(bshape))


def effective_block(dim: int, block: int) -> int:
    """The block size a divisor-gridded kernel actually runs: the largest
    divisor of ``dim`` that is <= ``block``. Single source of truth shared by
    the kernel wrappers, the tuner's search space, and its cost model — two
    configs with the same effective block are the same schedule."""
    b = max(1, min(block, dim))
    while dim % b:
        b -= 1
    return b
