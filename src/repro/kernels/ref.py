"""Pure-jnp oracles for every Pallas kernel (the 'no-SIMD' reference path).

These double as (a) allclose targets for the kernel tests and (b) the
scalar/direct baseline in the benchmark harness — the analogue of the
paper's non-SIMD NNoM implementations.

The ``*_q8_ref`` variants are the integer-only oracles: int8 operands,
int32 accumulation, and the SAME Algorithm-1 epilogue as the Pallas kernels
(``common.apply_requant`` — round-to-nearest shift, clip, int8; with the
optional ``act="relu"`` fused at accumulator scale via ``common.apply_act``
first, exactly like the kernel epilogues). Integer accumulation is
order-independent, so the Pallas kernels are bit-exact against these refs,
which is what ``tests/test_qconv.py`` and ``tests/test_graph.py`` assert.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import primitives as P

from .common import apply_act, apply_requant


def conv2d_ref(x, w, bias=None, *, groups: int = 1, act=None):
    y = P.standard_conv(x, w, groups=groups)
    if bias is not None:
        y = y + bias
    return apply_act(y, act)


def conv2d_q8_ref(x_q, w_q, bias_q=None, *, groups: int = 1,
                  requant_shift: int = 0, act=None):
    acc = P.standard_conv(x_q.astype(jnp.int32), w_q.astype(jnp.int32),
                          groups=groups)
    if bias_q is not None:
        acc = acc + bias_q.astype(jnp.int32)
    acc = apply_act(acc, act)
    return apply_requant(acc, requant_shift).astype(jnp.int8)


def depthwise2d_ref(x, w_dw, *, act=None):
    w4 = w_dw[..., None] if w_dw.ndim == 3 else w_dw   # (HK,HK,C) -> (HK,HK,C,1)
    return apply_act(P.depthwise_conv(x, w4), act)


def depthwise2d_q8_ref(x_q, w_dw_q, *, requant_shift: int = 0, act=None):
    w4 = w_dw_q[..., None] if w_dw_q.ndim == 3 else w_dw_q
    acc = P.depthwise_conv(x_q.astype(jnp.int32), w4.astype(jnp.int32))
    acc = apply_act(acc, act)
    return apply_requant(acc, requant_shift).astype(jnp.int8)


def shift_conv2d_ref(x, shifts, w_pw, *, max_shift=None, act=None):
    w4 = w_pw[None, None] if w_pw.ndim == 2 else w_pw
    return apply_act(P.standard_conv(
        P.shift_channels(x, jnp.asarray(shifts), max_shift=max_shift), w4), act)


def shift_conv2d_q8_ref(x_q, shifts, w_pw_q, bias_q=None, *,
                        requant_shift: int = 0, max_shift=None, act=None):
    """Shift is pure data movement — exact in the integer domain (the paper's
    point) — so only the pointwise matmul accumulates."""
    w4 = w_pw_q[None, None] if w_pw_q.ndim == 2 else w_pw_q
    shifted = P.shift_channels(x_q.astype(jnp.int32), jnp.asarray(shifts),
                               max_shift=max_shift)
    acc = P.standard_conv(shifted, w4.astype(jnp.int32))
    if bias_q is not None:
        acc = acc + bias_q.astype(jnp.int32)
    acc = apply_act(acc, act)
    return apply_requant(acc, requant_shift).astype(jnp.int8)


def add_conv2d_ref(x, w, *, act=None):
    return apply_act(P.add_conv(x, w), act)


def add_conv2d_q8_ref(x_q, w_q, bias_q=None, *, requant_shift: int = 0,
                      x_preshift: int = 0, w_preshift: int = 0, act=None):
    """AdderNet Algorithm-1 (right): align scales by left pre-shifts, then
    -Σ|x - w| in int32, bias at accumulator scale, requant epilogue."""
    xi = x_q.astype(jnp.int32)
    wi = w_q.astype(jnp.int32)
    if x_preshift:
        xi = jnp.left_shift(xi, x_preshift)
    if w_preshift:
        wi = jnp.left_shift(wi, w_preshift)
    acc = P.add_conv(xi, wi)
    if bias_q is not None:
        acc = acc + bias_q.astype(jnp.int32)
    acc = apply_act(acc, act)
    return apply_requant(acc, requant_shift).astype(jnp.int8)


# --------------------------------------------------------------------------
# W4 oracles: expand the nibble-packed weights to their int8 codes on the
# host (``core.quantize.expand_w4`` — unpack + per-group shift to the base
# scale), then run the UNCHANGED int8 oracle. This is the contract every W4
# Pallas kernel is tested bit-exact against: pallas == xla == oracle.
# --------------------------------------------------------------------------

def _w4_codes(w_p, w_shifts, size: int, axis: int):
    from repro.core.quantize import expand_w4
    return expand_w4(w_p, w_shifts, size, axis)


def conv2d_w4_ref(x_q, w_p, w_shifts, bias_q=None, *, groups: int = 1,
                  requant_shift: int = 0, act=None):
    cxg = x_q.shape[-1] // groups
    return conv2d_q8_ref(x_q, _w4_codes(w_p, w_shifts, cxg, 2), bias_q,
                         groups=groups, requant_shift=requant_shift, act=act)


def depthwise2d_w4_ref(x_q, w_dw_p, w_shifts, *, requant_shift: int = 0,
                       act=None):
    if w_dw_p.ndim == 4:
        w_dw_p = w_dw_p[..., 0]
    hk = w_dw_p.shape[1]                     # axis 0 is the packed tap axis
    return depthwise2d_q8_ref(x_q, _w4_codes(w_dw_p, w_shifts, hk, 0),
                              requant_shift=requant_shift, act=act)


def shift_conv2d_w4_ref(x_q, shifts, w_pw_p, w_shifts, bias_q=None, *,
                        requant_shift: int = 0, max_shift=None, act=None):
    if w_pw_p.ndim == 4:
        w_pw_p = w_pw_p[0, 0]
    c = x_q.shape[-1]
    return shift_conv2d_q8_ref(x_q, shifts, _w4_codes(w_pw_p, w_shifts, c, 0),
                               bias_q, requant_shift=requant_shift,
                               max_shift=max_shift, act=act)


def add_conv2d_w4_ref(x_q, w_p, w_shifts, bias_q=None, *,
                      requant_shift: int = 0, x_preshift: int = 0,
                      w_preshift: int = 0, act=None):
    cx = x_q.shape[-1]
    return add_conv2d_q8_ref(x_q, _w4_codes(w_p, w_shifts, cx, 2), bias_q,
                             requant_shift=requant_shift,
                             x_preshift=x_preshift, w_preshift=w_preshift,
                             act=act)


def matmul_w4_ref(a, b_p, w_shifts, *, requant_shift, act=None):
    k = a.shape[-1]
    return matmul_ref(a, _w4_codes(b_p, w_shifts, k, 0),
                      requant_shift=requant_shift, act=act)


def causal_conv1d_ref(x, w, *, act=None):
    """x: (B,L,D); w: (K,D). Zero history before t=0."""
    if w.ndim == 3:
        w = w[:, 0]
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for kk in range(k):
        out = out + xp[:, kk:kk + x.shape[1], :] * w[kk][None, None, :]
    return apply_act(out, act)


def matmul_ref(a, b, *, requant_shift=None, act=None):
    if requant_shift is None:
        return apply_act(jnp.dot(a, b, preferred_element_type=jnp.float32),
                         act).astype(a.dtype)
    acc = jnp.dot(a.astype(jnp.int32), b.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    acc = apply_act(acc, act)
    return apply_requant(acc, requant_shift).astype(jnp.int8)


def maxpool2d_ref(x, *, window: int = 2, stride: int | None = None):
    """VALID max-pool oracle — works on int8 codes (init = dtype min) and
    floats (init = -inf) alike."""
    stride = stride or window
    if jnp.issubdtype(x.dtype, jnp.integer):
        init = jnp.iinfo(x.dtype).min
    else:
        init = -jnp.inf
    return lax.reduce_window(x, jnp.asarray(init, x.dtype), lax.max,
                             (1, window, window, 1), (1, stride, stride, 1),
                             "VALID")
