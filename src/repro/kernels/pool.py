"""int8 max-pooling Pallas kernel (VPU path).

NNoM's integer pipeline pools BETWEEN the int8 requantization of one conv
and the int8 consumption of the next — max commutes with the (positive,
power-of-two) dequantization scale, so pooling int8 codes is bit-exact with
pooling the dequantized floats. This kernel is what lets the graph executor
keep activations int8 across pool boundaries (zero float round-trips).

Grid: (batch_block, spatial_tile, channel-block); one grid step reduces a
``block_n``-image, halo-padded (``block_h``, ``block_w``) OUTPUT tile as
W^2 statically-strided element-wise maxima on the 8x128 VPU — the same
shifted accumulation pattern as conv_dw, with max replacing multiply-add
(the input tile covers ``(block-1)*stride + window`` rows/cols).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .common import (batch_spatial_schedule, effective_block, halo_tiles,
                     resolve_interpret, resolve_tile_config)


def _kernel(x_ref, o_ref, *, win, stride, bh, bw):
    xv = x_ref[:, 0, 0]                      # (BN, TH_in, TW_in, BC)
    bn, bc = xv.shape[0], xv.shape[-1]
    out = None
    for i in range(win):                     # static unroll over window taps
        for j in range(win):
            v = lax.slice(xv, (0, i, j, 0),
                          (bn, i + (bh - 1) * stride + 1,
                           j + (bw - 1) * stride + 1, bc),
                          (1, stride, stride, 1))
            out = v if out is None else jnp.maximum(out, v)
    o_ref[...] = out


def maxpool2d(x: jax.Array, *, window: int = 2, stride: int | None = None,
              block_c: int = 128, block_n: int = 1,
              block_h: int | None = None, block_w: int | None = None,
              interpret: bool | None = None,
              config: dict | None = None) -> jax.Array:
    """VALID max-pool. x: (N,H,W,C) — int8 (the fused-graph path) or float.

    ``config`` (a repro.tune schedule dict) overrides the block parameters
    (``block_c``, ``block_n``, ``block_h``/``block_w`` — OUTPUT-tile
    extents). ``interpret=None`` auto-detects the backend.
    """
    if config:
        block_c = int(config.get("block_c", block_c))
    block_n, block_h, block_w = resolve_tile_config(config, block_n,
                                                    block_h, block_w)
    return _maxpool2d(x, window=window, stride=stride or window,
                      block_c=block_c, block_n=block_n, block_h=block_h,
                      block_w=block_w, interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("window", "stride", "block_c",
                                             "block_n", "block_h", "block_w",
                                             "interpret"))
def _maxpool2d(x: jax.Array, *, window: int, stride: int, block_c: int,
               block_n: int = 1, block_h: int | None = None,
               block_w: int | None = None,
               interpret: bool = True) -> jax.Array:
    n, h, w, c = x.shape
    hout = (h - window) // stride + 1
    wout = (w - window) // stride + 1
    bc = effective_block(c, block_c)
    bn, bh, bw, n_th, n_tw = batch_spatial_schedule(n, hout, wout, block_n,
                                                    block_h, block_w)
    # output tile (bh, bw) consumes input rows [th*bh*s, th*bh*s +
    # (bh-1)*s + win): overlapping tiles at stride bh*s (pad rows only feed
    # output rows the final crop discards)
    tiles = halo_tiles(x, n_th, n_tw, bh * stride, bw * stride,
                       (bh - 1) * stride + window, (bw - 1) * stride + window)

    def x_index(b, s, cb):
        return (b, s // n_tw, s % n_tw, 0, 0, cb)

    def o_index(b, s, cb):
        return (b, s // n_tw, s % n_tw, cb)

    kern = functools.partial(_kernel, win=window, stride=stride, bh=bh, bw=bw)
    out = pl.pallas_call(
        kern,
        grid=(n // bn, n_th * n_tw, c // bc),
        in_specs=[pl.BlockSpec((bn, 1, 1, (bh - 1) * stride + window,
                                (bw - 1) * stride + window, bc), x_index)],
        out_specs=pl.BlockSpec((bn, bh, bw, bc), o_index),
        out_shape=jax.ShapeDtypeStruct((n, n_th * bh, n_tw * bw, c), x.dtype),
        interpret=interpret,
    )(tiles)
    return out[:, :hout, :wout, :]
