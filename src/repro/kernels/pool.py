"""int8 max-pooling Pallas kernel (VPU path).

NNoM's integer pipeline pools BETWEEN the int8 requantization of one conv
and the int8 consumption of the next — max commutes with the (positive,
power-of-two) dequantization scale, so pooling int8 codes is bit-exact with
pooling the dequantized floats. This kernel is what lets the graph executor
keep activations int8 across pool boundaries (zero float round-trips).

Grid: (batch, channel-block); one grid step owns one image's full spatial
extent in VMEM (MCU-scale feature maps) and reduces the WxW window as W^2
statically-strided element-wise maxima on the 8x128 VPU — the same shifted
accumulation pattern as conv_dw, with max replacing multiply-add.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .common import effective_block


def _kernel(x_ref, o_ref, *, win, stride, hout, wout):
    xv = x_ref[0]                            # (H, W, BC)
    bc = xv.shape[-1]
    out = None
    for i in range(win):                     # static unroll over window taps
        for j in range(win):
            v = lax.slice(xv, (i, j, 0),
                          (i + (hout - 1) * stride + 1,
                           j + (wout - 1) * stride + 1, bc),
                          (stride, stride, 1))
            out = v if out is None else jnp.maximum(out, v)
    o_ref[0] = out


def maxpool2d(x: jax.Array, *, window: int = 2, stride: int | None = None,
              block_c: int = 128, interpret: bool = True,
              config: dict | None = None) -> jax.Array:
    """VALID max-pool. x: (N,H,W,C) — int8 (the fused-graph path) or float.

    ``config`` (a repro.tune schedule dict) overrides the block parameters.
    """
    if config:
        block_c = int(config.get("block_c", block_c))
    return _maxpool2d(x, window=window, stride=stride or window,
                      block_c=block_c, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "stride", "block_c",
                                             "interpret"))
def _maxpool2d(x: jax.Array, *, window: int, stride: int, block_c: int,
               interpret: bool = True) -> jax.Array:
    n, h, w, c = x.shape
    hout = (h - window) // stride + 1
    wout = (w - window) // stride + 1
    bc = effective_block(c, block_c)
    kern = functools.partial(_kernel, win=window, stride=stride,
                             hout=hout, wout=wout)
    return pl.pallas_call(
        kern,
        grid=(n, c // bc),
        in_specs=[pl.BlockSpec((1, h, w, bc), lambda b, cb: (b, 0, 0, cb))],
        out_specs=pl.BlockSpec((1, hout, wout, bc), lambda b, cb: (b, 0, 0, cb)),
        out_shape=jax.ShapeDtypeStruct((n, hout, wout, c), x.dtype),
        interpret=interpret,
    )(x)
