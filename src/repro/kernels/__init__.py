"""Pallas TPU kernels for the paper's compute hot spots (see EXAMPLE.md).

conv_im2col : standard/grouped conv -> lazy-im2col MXU matmuls (SIMD path)
conv_dw     : depthwise conv on the VPU
conv_shift  : shift conv, shifts fused into the im2col sampling (paper §3.3)
conv_add    : AdderNet L1 conv — VPU only, no MXU analogue (paper: no SIMD)
conv1d_causal: Mamba/Jamba depthwise causal conv1d (paper primitive in LMs)
matmul_q8   : tiled MXU matmul with int8 power-of-two requantization
pool        : int8 max-pool (the graph executor's integer pool boundary)

Every conv kernel + matmul_q8 takes ``act="relu"`` — the fused activation
epilogue at accumulator scale the repro.graph executor chains between
requantized layers.

All five conv kernels (+ pool) run the tiled ``(batch_block, spatial_tile,
group/channel, co_block)`` grid: ``block_n`` images share each weight-block
load per grid step (the paper's Fig-3 data reuse, scaled by the batch) and
``block_h``/``block_w`` halo tiles bound VMEM on large feature maps;
matmul_q8 folds a leading batch dim into its M grid. ``interpret`` defaults
to backend-detected (compiled on TPU, interpreter elsewhere; CI pins
REPRO_PALLAS_INTERPRET=1).
"""
from .ops import (conv2d, depthwise2d, shift_conv2d, add_conv2d,
                  causal_conv1d, matmul, maxpool2d)
