"""Shift convolution Pallas kernel: fused shifted-gather + pointwise MXU matmul.

The paper modifies im2col's *sampling step* to read each channel at its own
(alpha, beta) offset (§3.3) — the shift itself is free pointer arithmetic.
TPU-native translation: shifts are static layer parameters, so the wrapper
groups channels by identical shift (<= HK^2 distinct values), permutes the
channel axis so groups are contiguous, and the kernel accumulates one
statically-shifted (H*W, C_grp) x (C_grp, BCO) MXU matmul per group —
the shifted intermediate map I (Eq. 2) is never materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .common import acc_dtype, apply_act, apply_requant, effective_block


def _kernel(x_ref, w_ref, o_ref, *, groups, hout, wout, pad, out_dtype,
            requant_shift, act=None, bias_ref=None):
    adt = acc_dtype(x_ref.dtype)
    bco = w_ref.shape[-1]
    acc = jnp.zeros((hout * wout, bco), adt)
    for start, size, (da, db) in groups:     # static unroll over shift groups
        r0, c0 = pad + da, pad + db
        patch = x_ref[0, r0:r0 + hout, c0:c0 + wout, start:start + size]
        acc = acc + jnp.dot(patch.reshape(hout * wout, size).astype(adt),
                            w_ref[start:start + size, :].astype(adt),
                            preferred_element_type=adt)
    if bias_ref is not None:                 # bias at accumulator scale
        acc = acc + bias_ref[...].astype(adt)[None, :]
    acc = apply_act(acc, act)
    acc = apply_requant(acc, requant_shift)
    o_ref[0] = acc.reshape(hout, wout, bco).astype(out_dtype)


def shift_conv2d(x: jax.Array, shifts, w_pw: jax.Array, bias=None, *,
                 block_co: int = 128, requant_shift: int | None = None,
                 act: str | None = None,
                 out_dtype=None, interpret: bool = True,
                 config: dict | None = None) -> jax.Array:
    """x: (N,H,W,C); shifts: (C,2) static ints; w_pw: (C,Cy) or (1,1,C,Cy).

    ``bias`` (optional, (Cy,)) is added at accumulator scale before the
    requantization epilogue; ``act="relu"`` fuses the activation at
    accumulator scale after it. ``config`` (a repro.tune schedule dict)
    overrides the block parameters.
    """
    if config:
        block_co = int(config.get("block_co", block_co))
    if w_pw.ndim == 4:
        w_pw = w_pw[0, 0]
    n, h, wd, c = x.shape
    cy = w_pw.shape[-1]
    out_dtype = out_dtype or (jnp.int8 if requant_shift is not None else x.dtype)

    shifts_np = np.asarray(shifts)
    pad = max(1, int(np.abs(shifts_np).max()))
    # group channels by identical shift; permute so groups are contiguous
    order = np.lexsort((shifts_np[:, 1], shifts_np[:, 0]))
    groups = []
    i = 0
    while i < c:
        da, db = shifts_np[order[i]]
        j = i
        while j < c and shifts_np[order[j], 0] == da and shifts_np[order[j], 1] == db:
            j += 1
        groups.append((i, j - i, (int(da), int(db))))
        i = j
    groups = tuple(groups)

    xp = jnp.pad(x[..., order], ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    wp = w_pw[order, :]
    hp, wpd = xp.shape[1], xp.shape[2]
    bco = effective_block(cy, block_co)

    kern = functools.partial(_kernel, groups=groups, hout=h, wout=wd, pad=pad,
                             out_dtype=out_dtype, requant_shift=requant_shift,
                             act=act)
    in_specs = [
        pl.BlockSpec((1, hp, wpd, c), lambda b, cb: (b, 0, 0, 0)),
        pl.BlockSpec((c, bco), lambda b, cb: (0, cb)),
    ]
    args = [xp, wp]
    if bias is not None:
        def kern_bias(x_ref, w_ref, b_ref, o_ref):
            _kernel(x_ref, w_ref, o_ref, groups=groups, hout=h, wout=wd,
                    pad=pad, out_dtype=out_dtype, requant_shift=requant_shift,
                    act=act, bias_ref=b_ref)
        kern = kern_bias
        in_specs.append(pl.BlockSpec((bco,), lambda b, cb: (cb,)))
        args.append(bias)
    return pl.pallas_call(
        kern,
        grid=(n, cy // bco),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, h, wd, bco), lambda b, cb: (b, 0, 0, cb)),
        out_shape=jax.ShapeDtypeStruct((n, h, wd, cy), out_dtype),
        interpret=interpret,
    )(*args)
