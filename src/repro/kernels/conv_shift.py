"""Shift convolution Pallas kernel: fused shifted-gather + pointwise MXU matmul.

The paper modifies im2col's *sampling step* to read each channel at its own
(alpha, beta) offset (§3.3) — the shift itself is free pointer arithmetic.
TPU-native translation: shifts are static layer parameters, so the wrapper
groups channels by identical shift (<= HK^2 distinct values), permutes the
channel axis so groups are contiguous, and the kernel accumulates one
statically-shifted (BN*BH*BW, C_grp) x (C_grp, BCO) MXU matmul per group —
the shifted intermediate map I (Eq. 2) is never materialized.

Grid: (batch_block, spatial_tile, out-channel-block); ``block_n`` images
amortize each pointwise weight-block load and ``block_h``/``block_w`` bound
the VMEM tile (halo = 2*max|shift|).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .common import (acc_dtype, apply_act, apply_requant,
                     batch_spatial_schedule, effective_block, halo_tiles,
                     resolve_interpret, resolve_tile_config, shift_w4_block,
                     unpack_w4_block)


def _kernel(x_ref, w_ref, o_ref, *, groups, bh, bw, pad, out_dtype,
            requant_shift, act=None, bias_ref=None, ws_ref=None, c=None):
    # x_ref: (BN, 1, 1, BH+2P, BW+2P, C); w_ref: (C, BCO)
    # (W4: (ceil(C/2), BCO) nibble-packed + ws_ref (C,) shifts; unpacked
    # once at kernel top so the odd-sized shift-group slices below never
    # straddle a packed byte)
    adt = acc_dtype(x_ref.dtype)
    bco = w_ref.shape[-1]
    bn = x_ref.shape[0]
    if ws_ref is None:
        wv = w_ref
    else:
        wv = shift_w4_block(unpack_w4_block(w_ref[...], c, 0), ws_ref[...], 0)
    acc = jnp.zeros((bn * bh * bw, bco), adt)
    for start, size, (da, db) in groups:     # static unroll over shift groups
        r0, c0 = pad + da, pad + db
        patch = x_ref[:, 0, 0, r0:r0 + bh, c0:c0 + bw, start:start + size]
        acc = acc + jnp.dot(patch.reshape(bn * bh * bw, size).astype(adt),
                            wv[start:start + size, :].astype(adt),
                            preferred_element_type=adt)
    if bias_ref is not None:                 # bias at accumulator scale
        acc = acc + bias_ref[...].astype(adt)[None, :]
    acc = apply_act(acc, act)
    acc = apply_requant(acc, requant_shift)
    o_ref[...] = acc.reshape(bn, bh, bw, bco).astype(out_dtype)


def shift_conv2d(x: jax.Array, shifts, w_pw: jax.Array, bias=None, *,
                 block_co: int = 128, block_n: int = 1,
                 block_h: int | None = None, block_w: int | None = None,
                 requant_shift: int | None = None,
                 act: str | None = None,
                 out_dtype=None, interpret: bool | None = None,
                 config: dict | None = None,
                 w_shifts: jax.Array | None = None) -> jax.Array:
    """x: (N,H,W,C); shifts: (C,2) static ints; w_pw: (C,Cy) or (1,1,C,Cy).

    ``bias`` (optional, (Cy,)) is added at accumulator scale before the
    requantization epilogue; ``act="relu"`` fuses the activation at
    accumulator scale after it. ``config`` (a repro.tune schedule dict)
    overrides the block parameters (``block_co``, ``block_n``,
    ``block_h``/``block_w``). ``interpret=None`` auto-detects the backend.

    W4A8: with ``w_shifts`` (per-channel group-scale shifts), ``w_pw`` is
    nibble-packed along the channel axis (``(ceil(C/2), Cy)``). The wrapper
    re-packs along its shift-group channel permutation (pack∘unpack is the
    identity on int4 codes, so this is exact), and the kernel unpacks the
    half-width block in-register before taking the per-group slices.
    Quantized path only.
    """
    if config:
        block_co = int(config.get("block_co", block_co))
    block_n, block_h, block_w = resolve_tile_config(config, block_n,
                                                    block_h, block_w)
    if w_pw.ndim == 4:
        w_pw = w_pw[0, 0]
    n, h, wd, c = x.shape
    cy = w_pw.shape[-1]
    w4 = w_shifts is not None
    if w4:
        if requant_shift is None:
            raise ValueError("shift_conv2d: W4 weights need the quantized "
                             "path (requant_shift)")
        assert w_pw.shape[0] == (c + 1) // 2, \
            f"packed C extent {w_pw.shape[0]} != ceil({c}/2)"
    out_dtype = out_dtype or (jnp.int8 if requant_shift is not None else x.dtype)

    shifts_np = np.asarray(shifts)
    pad = max(1, int(np.abs(shifts_np).max()))
    # group channels by identical shift; permute so groups are contiguous
    order = np.lexsort((shifts_np[:, 1], shifts_np[:, 0]))
    groups = []
    i = 0
    while i < c:
        da, db = shifts_np[order[i]]
        j = i
        while j < c and shifts_np[order[j], 0] == da and shifts_np[order[j], 1] == db:
            j += 1
        groups.append((i, j - i, (int(da), int(db))))
        i = j
    groups = tuple(groups)

    xp = jnp.pad(x[..., order], ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    if w4:
        # permute in code space, then re-pack: the pallas_call still moves
        # only the half-width nibble array
        from repro.core.quantize import pack_w4, unpack_w4
        wp = pack_w4(unpack_w4(w_pw, c, 0)[order, :], 0)
        ws_perm = w_shifts[order]
    else:
        wp = w_pw[order, :]
    bco = effective_block(cy, block_co)
    n_co = cy // bco
    bn, bh, bw, n_th, n_tw = batch_spatial_schedule(n, h, wd, block_n,
                                                    block_h, block_w)
    tiles = halo_tiles(xp, n_th, n_tw, bh, bw, bh + 2 * pad, bw + 2 * pad)

    def x_index(b, s, cb):
        return (b, s // n_tw, s % n_tw, 0, 0, 0)

    def w_index(b, s, cb):
        return (0, cb)

    def co_index(b, s, cb):
        return (cb,)

    def o_index(b, s, cb):
        return (b, s // n_tw, s % n_tw, cb)

    in_specs = [
        pl.BlockSpec((bn, 1, 1, bh + 2 * pad, bw + 2 * pad, c), x_index),
        pl.BlockSpec(((c + 1) // 2 if w4 else c, bco), w_index),
    ]
    args = [tiles, wp]
    if w4:
        in_specs.append(pl.BlockSpec((c,), lambda b, s, cb: (0,)))
        args.append(ws_perm)
    if bias is not None:
        in_specs.append(pl.BlockSpec((bco,), co_index))
        args.append(bias)

    def kern(*refs):
        it = iter(refs)
        x_ref, w_ref = next(it), next(it)
        ws_ref = next(it) if w4 else None
        b_ref = next(it) if bias is not None else None
        _kernel(x_ref, w_ref, next(it), groups=groups, bh=bh, bw=bw, pad=pad,
                out_dtype=out_dtype, requant_shift=requant_shift, act=act,
                bias_ref=b_ref, ws_ref=ws_ref, c=c)
    out = pl.pallas_call(
        kern,
        grid=(n // bn, n_th * n_tw, n_co),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, bh, bw, bco), o_index),
        out_shape=jax.ShapeDtypeStruct((n, n_th * bh, n_tw * bw, cy), out_dtype),
        interpret=resolve_interpret(interpret),
    )(*args)
    return out[:, :h, :wd, :]
