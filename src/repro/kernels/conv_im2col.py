"""Standard / grouped convolution as lazy-im2col MXU matmuls (Pallas TPU).

TPU adaptation of the paper's CMSIS-NN im2col + __SMLAD path (§3.3):

* Cortex-M materializes 2 im2col columns and re-uses them against 2 filters
  to maximize register-file reuse. The TPU analogue keeps the patch tile in
  VMEM and re-uses it against a BCO-wide *block* of filters on the 128x128
  MXU — "lazy im2col": the HK x HK patch structure is expressed as HK^2
  statically-shifted (H*W, Cx) x (Cx, BCO) matmuls accumulated in VMEM, so
  the column matrix is never materialized in HBM at all. Data reuse per
  byte loaded is Cx*BCO MACs vs the scalar path's 1 (the Fig-3 quantity).
* int8 path: the MXU consumes int8 directly with int32 accumulation, and
  the epilogue applies the paper's Algorithm-1 shift requantization — no
  int16 widening step, unlike __SMLAD.

Grid: (batch, group, out-channel-block). One grid step owns one image, one
group, one filter block; the image's padded spatial extent lives in VMEM
(MCU-scale feature maps: <= a few hundred KB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import acc_dtype, apply_act, apply_requant, effective_block


def _kernel(x_ref, w_ref, o_ref, *, hk: int, hout: int, wout: int,
            out_dtype, requant_shift: int | None, act: str | None = None,
            bias_ref=None):
    cx = x_ref.shape[-1]
    bco = w_ref.shape[-1]
    adt = acc_dtype(x_ref.dtype)
    acc = jnp.zeros((hout * wout, bco), adt)
    for i in range(hk):                      # static unroll: HK^2 MXU calls
        for j in range(hk):
            patch = x_ref[0, i:i + hout, j:j + wout, :]
            a = patch.reshape(hout * wout, cx)
            b = w_ref[i, j]
            acc = acc + jnp.dot(a.astype(adt), b.astype(adt),
                                preferred_element_type=adt)
    if bias_ref is not None:
        acc = acc + bias_ref[...].astype(adt)[None, :]
    # fused activation at accumulator scale, then Algorithm 1: round-to-
    # nearest shift, clip, int8
    acc = apply_act(acc, act)
    acc = apply_requant(acc, requant_shift)
    o_ref[0] = acc.reshape(hout, wout, bco).astype(out_dtype)


def conv2d_im2col(x: jax.Array, w: jax.Array, bias=None, *, groups: int = 1,
                  block_co: int = 128, requant_shift: int | None = None,
                  act: str | None = None, out_dtype=None,
                  interpret: bool = True,
                  config: dict | None = None) -> jax.Array:
    """SAME-padded stride-1 conv. x: (N,H,W,Cx); w: (HK,HK,Cx/g,Cy).

    int8 x int8 -> int8 when ``requant_shift`` is given (int32 accumulate);
    float paths accumulate in f32. ``act="relu"`` fuses the activation at
    accumulator scale (after bias, before requantization). ``config`` (a
    repro.tune schedule dict) overrides the block parameters.
    """
    if config:
        block_co = int(config.get("block_co", block_co))
    return _conv2d_im2col(x, w, bias, groups=groups, block_co=block_co,
                          requant_shift=requant_shift, act=act,
                          out_dtype=out_dtype, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("groups", "block_co", "requant_shift",
                                             "act", "out_dtype", "interpret"))
def _conv2d_im2col(x: jax.Array, w: jax.Array, bias=None, *, groups: int = 1,
                   block_co: int = 128, requant_shift: int | None = None,
                   act: str | None = None,
                   out_dtype=None, interpret: bool = True) -> jax.Array:
    n, h, wd, cx = x.shape
    hk, _, cxg, cy = w.shape
    assert cx == cxg * groups and cy % groups == 0
    out_dtype = out_dtype or (jnp.int8 if requant_shift is not None else x.dtype)
    ph, pw = hk // 2, (hk - 1) // 2
    xp = jnp.pad(x, ((0, 0), (ph, pw), (ph, pw), (0, 0)))
    hp, wp = xp.shape[1], xp.shape[2]

    co_per_g = cy // groups
    bco = effective_block(co_per_g, block_co)
    n_co = co_per_g // bco

    kern = functools.partial(_kernel, hk=hk, hout=h, wout=wd,
                             out_dtype=out_dtype, requant_shift=requant_shift,
                             act=act)
    in_specs = [
        pl.BlockSpec((1, hp, wp, cxg), lambda b, g, c: (b, 0, 0, g)),
        pl.BlockSpec((hk, hk, cxg, bco),
                     lambda b, g, c, _n=n_co: (0, 0, 0, g * _n + c)),
    ]
    args = [xp, w]
    if bias is not None:
        def kern_bias(x_ref, w_ref, b_ref, o_ref):
            _kernel(x_ref, w_ref, o_ref, hk=hk, hout=h, wout=wd,
                    out_dtype=out_dtype, requant_shift=requant_shift,
                    act=act, bias_ref=b_ref)
        kern = kern_bias
        in_specs.append(pl.BlockSpec((bco,), lambda b, g, c, _n=n_co: (g * _n + c,)))
        args.append(bias)

    out = pl.pallas_call(
        kern,
        grid=(n, groups, n_co),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, h, wd, bco),
                               lambda b, g, c, _n=n_co: (b, 0, 0, g * _n + c)),
        out_shape=jax.ShapeDtypeStruct((n, h, wd, cy), out_dtype),
        interpret=interpret,
    )(*args)
    return out
