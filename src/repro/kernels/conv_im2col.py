"""Standard / grouped convolution as lazy-im2col MXU matmuls (Pallas TPU).

TPU adaptation of the paper's CMSIS-NN im2col + __SMLAD path (§3.3):

* Cortex-M materializes 2 im2col columns and re-uses them against 2 filters
  to maximize register-file reuse. The TPU analogue keeps the patch tile in
  VMEM and re-uses it against a BCO-wide *block* of filters on the 128x128
  MXU — "lazy im2col": the HK x HK patch structure is expressed as HK^2
  statically-shifted (BN*BH*BW, Cx) x (Cx, BCO) matmuls accumulated in
  VMEM, so the column matrix is never materialized in HBM at all.
* int8 path: the MXU consumes int8 directly with int32 accumulation, and
  the epilogue applies the paper's Algorithm-1 shift requantization — no
  int16 widening step, unlike __SMLAD.

Grid: (batch_block, spatial_tile, group, out-channel-block). One grid step
owns ``block_n`` images' worth of one halo-padded (block_h, block_w) output
tile, one group, one filter block. Batch blocking amortizes each filter
block load across ``block_n`` images — the Fig-3 data-reuse quantity grows
from Cx*BCO to BN*Cx*BCO MACs per weight byte — while spatial tiling keeps
the VMEM footprint bounded on feature maps larger than the MCU-scale ones
the paper measures (the per-layer blocking argument of "Not All Ops Are
Created Equal!").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (acc_dtype, apply_act, apply_requant,
                     batch_spatial_schedule, effective_block, halo_tiles,
                     resolve_interpret, resolve_tile_config, shift_w4_block,
                     unpack_w4_block)


def _kernel(x_ref, w_ref, o_ref, *, hk: int, bh: int, bw: int,
            out_dtype, requant_shift: int | None, act: str | None = None,
            bias_ref=None, ws_ref=None):
    # x_ref: (BN, 1, 1, BH+HK-1, BW+HK-1, Cx); w_ref: (HK, HK, Cx, BCO)
    # (W4: (HK, HK, ceil(Cx/2), BCO) nibble-packed + ws_ref (Cx,) shifts)
    cx = x_ref.shape[-1]
    bco = w_ref.shape[-1]
    bn = x_ref.shape[0]
    adt = acc_dtype(x_ref.dtype)
    acc = jnp.zeros((bn * bh * bw, bco), adt)
    for i in range(hk):                      # static unroll: HK^2 MXU calls
        for j in range(hk):
            patch = x_ref[:, 0, 0, i:i + bh, j:j + bw, :]
            a = patch.reshape(bn * bh * bw, cx)
            if ws_ref is None:
                b = w_ref[i, j]
            else:                            # unpack W4 in-register, then the
                b = shift_w4_block(          # unchanged int8 MXU body
                    unpack_w4_block(w_ref[i, j], cx, 0), ws_ref[...], 0)
            acc = acc + jnp.dot(a.astype(adt), b.astype(adt),
                                preferred_element_type=adt)
    if bias_ref is not None:
        acc = acc + bias_ref[...].astype(adt)[None, :]
    # fused activation at accumulator scale, then Algorithm 1: round-to-
    # nearest shift, clip, int8
    acc = apply_act(acc, act)
    acc = apply_requant(acc, requant_shift)
    o_ref[...] = acc.reshape(bn, bh, bw, bco).astype(out_dtype)


def conv2d_im2col(x: jax.Array, w: jax.Array, bias=None, *, groups: int = 1,
                  block_co: int = 128, block_n: int = 1,
                  block_h: int | None = None, block_w: int | None = None,
                  requant_shift: int | None = None,
                  act: str | None = None, out_dtype=None,
                  interpret: bool | None = None,
                  config: dict | None = None,
                  w_shifts: jax.Array | None = None) -> jax.Array:
    """SAME-padded stride-1 conv. x: (N,H,W,Cx); w: (HK,HK,Cx/g,Cy).

    int8 x int8 -> int8 when ``requant_shift`` is given (int32 accumulate);
    float paths accumulate in f32. ``act="relu"`` fuses the activation at
    accumulator scale (after bias, before requantization). ``config`` (a
    repro.tune schedule dict) overrides the block parameters:
    ``block_co`` (filters per step), ``block_n`` (images per step — weight
    reuse), ``block_h``/``block_w`` (halo-padded spatial tile; ``None`` =
    whole map). ``interpret=None`` auto-detects the backend.

    W4A8: passing ``w_shifts`` (the per-input-channel group-scale shifts of
    a ``QTensorW4``) marks ``w`` as nibble-packed along the Cx/g axis
    (extent ``ceil(Cx/g / 2)``); the kernel unpacks in-register so only the
    half-width weight block crosses HBM->VMEM. Quantized path only.
    """
    if config:
        block_co = int(config.get("block_co", block_co))
    block_n, block_h, block_w = resolve_tile_config(config, block_n,
                                                    block_h, block_w)
    return _conv2d_im2col(x, w, bias, w_shifts, groups=groups,
                          block_co=block_co,
                          block_n=block_n, block_h=block_h, block_w=block_w,
                          requant_shift=requant_shift, act=act,
                          out_dtype=out_dtype,
                          interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("groups", "block_co", "block_n",
                                             "block_h", "block_w",
                                             "requant_shift",
                                             "act", "out_dtype", "interpret"))
def _conv2d_im2col(x: jax.Array, w: jax.Array, bias=None, w_shifts=None, *,
                   groups: int = 1,
                   block_co: int = 128, block_n: int = 1,
                   block_h: int | None = None, block_w: int | None = None,
                   requant_shift: int | None = None,
                   act: str | None = None,
                   out_dtype=None, interpret: bool = True) -> jax.Array:
    n, h, wd, cx = x.shape
    hk, _, _, cy = w.shape
    w4 = w_shifts is not None
    cxg = cx // groups if w4 else w.shape[2]
    assert cx == cxg * groups and cy % groups == 0
    if w4:
        if requant_shift is None:
            raise ValueError("conv2d_im2col: W4 weights need the quantized "
                             "path (requant_shift)")
        assert w.shape[2] == (cxg + 1) // 2, \
            f"packed Cx/g extent {w.shape[2]} != ceil({cxg}/2)"
    out_dtype = out_dtype or (jnp.int8 if requant_shift is not None else x.dtype)
    ph, pw = hk // 2, (hk - 1) // 2
    xp = jnp.pad(x, ((0, 0), (ph, pw), (ph, pw), (0, 0)))

    co_per_g = cy // groups
    bco = effective_block(co_per_g, block_co)
    n_co = co_per_g // bco
    bn, bh, bw, n_th, n_tw = batch_spatial_schedule(n, h, wd, block_n,
                                                    block_h, block_w)
    halo = hk - 1
    tiles = halo_tiles(xp, n_th, n_tw, bh, bw, bh + halo, bw + halo)
    hp_out, wp_out = n_th * bh, n_tw * bw

    # index maps close over the RESOLVED schedule (n_co/n_tw computed from
    # the effective blocks above) — no default-arg captures, so a config
    # that rounds through effective_block can never leave a stale divisor
    # in the lambdas
    def x_index(b, s, g, c):
        return (b, s // n_tw, s % n_tw, 0, 0, g)

    def w_index(b, s, g, c):
        return (0, 0, 0, g * n_co + c)

    def co_index(b, s, g, c):
        return (g * n_co + c,)

    def o_index(b, s, g, c):
        return (b, s // n_tw, s % n_tw, g * n_co + c)

    in_specs = [
        pl.BlockSpec((bn, 1, 1, bh + halo, bw + halo, cxg), x_index),
        pl.BlockSpec((hk, hk, (cxg + 1) // 2 if w4 else cxg, bco), w_index),
    ]
    args = [tiles, w]
    if w4:                  # shifts ride whole (the packed axis is unblocked)
        in_specs.append(pl.BlockSpec((cxg,), lambda b, s, g, c: (0,)))
        args.append(w_shifts)
    if bias is not None:
        in_specs.append(pl.BlockSpec((bco,), co_index))
        args.append(bias)

    def kern(*refs):
        it = iter(refs)
        x_ref, w_ref = next(it), next(it)
        ws_ref = next(it) if w4 else None
        b_ref = next(it) if bias is not None else None
        _kernel(x_ref, w_ref, next(it), hk=hk, bh=bh, bw=bw,
                out_dtype=out_dtype, requant_shift=requant_shift,
                act=act, bias_ref=b_ref, ws_ref=ws_ref)

    out = pl.pallas_call(
        kern,
        grid=(n // bn, n_th * n_tw, groups, n_co),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, bh, bw, bco), o_index),
        out_shape=jax.ShapeDtypeStruct((n, hp_out, wp_out, cy), out_dtype),
        interpret=interpret,
    )(*args)
    return out[:, :h, :wd, :]
