"""Add (AdderNet) convolution Pallas kernel — VPU only, by necessity.

The paper could not give add-conv a SIMD path because no __SMLAD-like
instruction exists for |a-b| accumulation (§3.3). The same holds on TPU:
the MXU computes contractions (sum of products), and L1 distance
-Σ|w - x| is not a contraction, so the systolic array is unusable. This
kernel is the TPU-faithful equivalent: broadcast |patch - w| tiles on the
8x128 VPU with VMEM-blocked filters, accumulating in int32/f32. Its
per-MAC cost is intrinsically higher than the MXU paths — reproducing the
paper's measured add-conv penalty at the architectural level.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import acc_dtype, apply_act, apply_requant, effective_block


def _kernel(x_ref, w_ref, o_ref, *, hk, hout, wout, out_dtype, requant_shift,
            x_preshift, w_preshift, act=None, bias_ref=None):
    adt = acc_dtype(x_ref.dtype)
    cx = x_ref.shape[-1]
    bco = w_ref.shape[-1]
    acc = jnp.zeros((hout * wout, bco), adt)
    for i in range(hk):
        for j in range(hk):
            patch = x_ref[0, i:i + hout, j:j + wout, :].astype(adt)
            if x_preshift:                  # Algorithm 1 (right): align scales
                patch = jnp.left_shift(patch, x_preshift)
            wv = w_ref[i, j].astype(adt)    # (Cx, BCO)
            if w_preshift:
                wv = jnp.left_shift(wv, w_preshift)
            a = patch.reshape(hout * wout, cx)
            # -Σ_c |a[:, c] - w[c, n]| : VPU broadcast, no MXU analogue
            acc = acc - jnp.sum(jnp.abs(a[:, :, None] - wv[None, :, :]), axis=1)
    if bias_ref is not None:                # bias at accumulator scale
        acc = acc + bias_ref[...].astype(adt)[None, :]
    acc = apply_act(acc, act)
    acc = apply_requant(acc, requant_shift)
    o_ref[0] = acc.reshape(hout, wout, bco).astype(out_dtype)


def add_conv2d(x: jax.Array, w: jax.Array, bias=None, *, block_co: int = 8,
               requant_shift: int | None = None, x_preshift: int = 0,
               w_preshift: int = 0, act: str | None = None, out_dtype=None,
               interpret: bool = True, config: dict | None = None) -> jax.Array:
    """SAME stride-1 AdderNet conv (Eq. 3). x: (N,H,W,Cx); w: (HK,HK,Cx,Cy).

    ``bias`` (optional, (Cy,)) is added at accumulator scale before the
    requantization epilogue; ``act="relu"`` fuses the activation at
    accumulator scale after it. ``config`` (a repro.tune schedule dict)
    overrides the block parameters.
    """
    if config:
        block_co = int(config.get("block_co", block_co))
    return _add_conv2d(x, w, bias, block_co=block_co, requant_shift=requant_shift,
                       x_preshift=x_preshift, w_preshift=w_preshift, act=act,
                       out_dtype=out_dtype, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_co", "requant_shift",
                                             "x_preshift", "w_preshift",
                                             "act", "out_dtype", "interpret"))
def _add_conv2d(x: jax.Array, w: jax.Array, bias=None, *, block_co: int = 8,
                requant_shift: int | None = None, x_preshift: int = 0,
                w_preshift: int = 0, act: str | None = None, out_dtype=None,
                interpret: bool = True) -> jax.Array:
    n, h, wd, cx = x.shape
    hk, _, _, cy = w.shape
    out_dtype = out_dtype or (jnp.int8 if requant_shift is not None else x.dtype)
    ph, pw = hk // 2, (hk - 1) // 2
    xp = jnp.pad(x, ((0, 0), (ph, pw), (ph, pw), (0, 0)))
    hp, wp = xp.shape[1], xp.shape[2]
    bco = effective_block(cy, block_co)
    kern = functools.partial(_kernel, hk=hk, hout=h, wout=wd,
                             out_dtype=out_dtype, requant_shift=requant_shift,
                             x_preshift=x_preshift, w_preshift=w_preshift,
                             act=act)
    in_specs = [
        pl.BlockSpec((1, hp, wp, cx), lambda b, cb: (b, 0, 0, 0)),
        pl.BlockSpec((hk, hk, cx, bco), lambda b, cb: (0, 0, 0, cb)),
    ]
    args = [xp, w]
    if bias is not None:
        def kern_bias(x_ref, w_ref, b_ref, o_ref):
            _kernel(x_ref, w_ref, o_ref, hk=hk, hout=h, wout=wd,
                    out_dtype=out_dtype, requant_shift=requant_shift,
                    x_preshift=x_preshift, w_preshift=w_preshift,
                    act=act, bias_ref=b_ref)
        kern = kern_bias
        in_specs.append(pl.BlockSpec((bco,), lambda b, cb: (cb,)))
        args.append(bias)
    return pl.pallas_call(
        kern,
        grid=(n, cy // bco),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, h, wd, bco), lambda b, cb: (b, 0, 0, cb)),
        out_shape=jax.ShapeDtypeStruct((n, h, wd, cy), out_dtype),
        interpret=interpret,
    )(*args)
