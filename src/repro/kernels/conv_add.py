"""Add (AdderNet) convolution Pallas kernel — VPU only, by necessity.

The paper could not give add-conv a SIMD path because no __SMLAD-like
instruction exists for |a-b| accumulation (§3.3). The same holds on TPU:
the MXU computes contractions (sum of products), and L1 distance
-Σ|w - x| is not a contraction, so the systolic array is unusable. This
kernel is the TPU-faithful equivalent: broadcast |patch - w| tiles on the
8x128 VPU with VMEM-blocked filters, accumulating in int32/f32. Its
per-MAC cost is intrinsically higher than the MXU paths — reproducing the
paper's measured add-conv penalty at the architectural level.

Grid: (batch_block, spatial_tile, out-channel-block). The broadcast
intermediate is (BN*BH*BW, Cx, BCO), so the spatial tile is the knob that
keeps this kernel inside VMEM; ``block_n`` amortizes filter loads like the
MXU kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (acc_dtype, apply_act, apply_requant,
                     batch_spatial_schedule, effective_block, halo_tiles,
                     resolve_interpret, resolve_tile_config, shift_w4_block,
                     unpack_w4_block)


def _kernel(x_ref, w_ref, o_ref, *, hk, bh, bw, out_dtype, requant_shift,
            x_preshift, w_preshift, act=None, bias_ref=None, ws_ref=None):
    # x_ref: (BN, 1, 1, BH+HK-1, BW+HK-1, Cx); w_ref: (HK, HK, Cx, BCO)
    # (W4: (HK, HK, ceil(Cx/2), BCO) nibble-packed + ws_ref (Cx,) shifts.
    # The unpack slices the packed tail element off before |x - w| — a
    # zero-padded weight channel is NOT neutral for L1 distance.)
    adt = acc_dtype(x_ref.dtype)
    cx = x_ref.shape[-1]
    bco = w_ref.shape[-1]
    bn = x_ref.shape[0]
    acc = jnp.zeros((bn * bh * bw, bco), adt)
    for i in range(hk):
        for j in range(hk):
            patch = x_ref[:, 0, 0, i:i + bh, j:j + bw, :].astype(adt)
            if x_preshift:                  # Algorithm 1 (right): align scales
                patch = jnp.left_shift(patch, x_preshift)
            if ws_ref is None:
                wv = w_ref[i, j].astype(adt)    # (Cx, BCO)
            else:                           # group shifts first (to the base
                wv = shift_w4_block(        # scale), then the common align
                    unpack_w4_block(w_ref[i, j], cx, 0),
                    ws_ref[...], 0).astype(adt)
            if w_preshift:
                wv = jnp.left_shift(wv, w_preshift)
            a = patch.reshape(bn * bh * bw, cx)
            # -Σ_c |a[:, c] - w[c, n]| : VPU broadcast, no MXU analogue
            acc = acc - jnp.sum(jnp.abs(a[:, :, None] - wv[None, :, :]), axis=1)
    if bias_ref is not None:                # bias at accumulator scale
        acc = acc + bias_ref[...].astype(adt)[None, :]
    acc = apply_act(acc, act)
    acc = apply_requant(acc, requant_shift)
    o_ref[...] = acc.reshape(bn, bh, bw, bco).astype(out_dtype)


def add_conv2d(x: jax.Array, w: jax.Array, bias=None, *, block_co: int = 8,
               block_n: int = 1, block_h: int | None = None,
               block_w: int | None = None,
               requant_shift: int | None = None, x_preshift: int = 0,
               w_preshift: int = 0, act: str | None = None, out_dtype=None,
               interpret: bool | None = None,
               config: dict | None = None,
               w_shifts: jax.Array | None = None) -> jax.Array:
    """SAME stride-1 AdderNet conv (Eq. 3). x: (N,H,W,Cx); w: (HK,HK,Cx,Cy).

    ``bias`` (optional, (Cy,)) is added at accumulator scale before the
    requantization epilogue; ``act="relu"`` fuses the activation at
    accumulator scale after it. ``config`` (a repro.tune schedule dict)
    overrides the block parameters (``block_co``, ``block_n``,
    ``block_h``/``block_w``). ``interpret=None`` auto-detects the backend.

    W4A8: with ``w_shifts`` (per-input-channel group shifts), ``w`` is
    nibble-packed along the Cx axis (``(HK, HK, ceil(Cx/2), Cy)``); the
    kernel unpacks in-register, applies the group shifts, then the usual
    ``w_preshift`` scale alignment. Quantized path only.
    """
    if config:
        block_co = int(config.get("block_co", block_co))
    block_n, block_h, block_w = resolve_tile_config(config, block_n,
                                                    block_h, block_w)
    return _add_conv2d(x, w, bias, w_shifts, block_co=block_co,
                       block_n=block_n,
                       block_h=block_h, block_w=block_w,
                       requant_shift=requant_shift,
                       x_preshift=x_preshift, w_preshift=w_preshift, act=act,
                       out_dtype=out_dtype,
                       interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_co", "block_n", "block_h",
                                             "block_w", "requant_shift",
                                             "x_preshift", "w_preshift",
                                             "act", "out_dtype", "interpret"))
def _add_conv2d(x: jax.Array, w: jax.Array, bias=None, w_shifts=None, *,
                block_co: int = 8,
                block_n: int = 1, block_h: int | None = None,
                block_w: int | None = None,
                requant_shift: int | None = None, x_preshift: int = 0,
                w_preshift: int = 0, act: str | None = None, out_dtype=None,
                interpret: bool = True) -> jax.Array:
    n, h, wd, cx = x.shape
    hk, _, _, cy = w.shape
    w4 = w_shifts is not None
    if w4:
        if requant_shift is None:
            raise ValueError("add_conv2d: W4 weights need the quantized "
                             "path (requant_shift)")
        assert w.shape[2] == (cx + 1) // 2, \
            f"packed Cx extent {w.shape[2]} != ceil({cx}/2)"
    out_dtype = out_dtype or (jnp.int8 if requant_shift is not None else x.dtype)
    ph, pw = hk // 2, (hk - 1) // 2
    xp = jnp.pad(x, ((0, 0), (ph, pw), (ph, pw), (0, 0)))
    bco = effective_block(cy, block_co)
    n_co = cy // bco
    bn, bh, bw, n_th, n_tw = batch_spatial_schedule(n, h, wd, block_n,
                                                    block_h, block_w)
    halo = hk - 1
    tiles = halo_tiles(xp, n_th, n_tw, bh, bw, bh + halo, bw + halo)

    def x_index(b, s, cb):
        return (b, s // n_tw, s % n_tw, 0, 0, 0)

    def w_index(b, s, cb):
        return (0, 0, 0, cb)

    def co_index(b, s, cb):
        return (cb,)

    def o_index(b, s, cb):
        return (b, s // n_tw, s % n_tw, cb)

    in_specs = [
        pl.BlockSpec((bn, 1, 1, bh + halo, bw + halo, cx), x_index),
        pl.BlockSpec((hk, hk, (cx + 1) // 2 if w4 else cx, bco), w_index),
    ]
    args = [tiles, w]
    if w4:
        in_specs.append(pl.BlockSpec((cx,), lambda b, s, cb: (0,)))
        args.append(w_shifts)
    if bias is not None:
        in_specs.append(pl.BlockSpec((bco,), co_index))
        args.append(bias)

    def kern(*refs):
        it = iter(refs)
        x_ref, w_ref = next(it), next(it)
        ws_ref = next(it) if w4 else None
        b_ref = next(it) if bias is not None else None
        _kernel(x_ref, w_ref, next(it), hk=hk, bh=bh, bw=bw,
                out_dtype=out_dtype, requant_shift=requant_shift,
                x_preshift=x_preshift, w_preshift=w_preshift,
                act=act, bias_ref=b_ref, ws_ref=ws_ref)
    out = pl.pallas_call(
        kern,
        grid=(n // bn, n_th * n_tw, n_co),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, bh, bw, bco), o_index),
        out_shape=jax.ShapeDtypeStruct((n, n_th * bh, n_tw * bw, cy), out_dtype),
        interpret=interpret,
    )(*args)
    return out[:, :h, :wd, :]
