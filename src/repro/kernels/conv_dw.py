"""Depthwise convolution Pallas kernel (VPU path).

Depthwise conv has no channel contraction, so the MXU is idle — like the
paper's observation (via Jeon & Kim) that depthwise is *slower per MAC*
than pointwise on real hardware despite fewer MACs. On TPU it runs on the
8x128 VPU as HK^2 shifted element-wise multiply-accumulates; channels map
to the 128-lane dimension. Used standalone (dws primitive, stage 1) and as
the reference pattern for the Mamba causal conv1d kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import acc_dtype, apply_act, apply_requant, effective_block


def _kernel(x_ref, w_ref, o_ref, *, hk, hout, wout, out_dtype, requant_shift,
            act=None):
    adt = acc_dtype(x_ref.dtype)
    bc = w_ref.shape[-1]
    acc = jnp.zeros((hout, wout, bc), adt)
    for i in range(hk):
        for j in range(hk):
            acc = acc + (x_ref[0, i:i + hout, j:j + wout, :].astype(adt)
                         * w_ref[i, j].astype(adt)[None, None, :])
    acc = apply_act(acc, act)
    acc = apply_requant(acc, requant_shift)
    o_ref[0] = acc.astype(out_dtype)


def depthwise2d(x: jax.Array, w_dw: jax.Array, *, block_c: int = 128,
                requant_shift: int | None = None, act: str | None = None,
                out_dtype=None,
                interpret: bool = True, config: dict | None = None) -> jax.Array:
    """SAME stride-1 depthwise conv. x: (N,H,W,C); w_dw: (HK,HK,C).

    ``act="relu"`` fuses the activation at accumulator scale before the
    requantization epilogue. ``config`` (a repro.tune schedule dict)
    overrides the block parameters.
    """
    if config:
        block_c = int(config.get("block_c", block_c))
    return _depthwise2d(x, w_dw, block_c=block_c, requant_shift=requant_shift,
                        act=act, out_dtype=out_dtype, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_c", "requant_shift",
                                             "act", "out_dtype", "interpret"))
def _depthwise2d(x: jax.Array, w_dw: jax.Array, *, block_c: int = 128,
                 requant_shift: int | None = None, act: str | None = None,
                 out_dtype=None,
                 interpret: bool = True) -> jax.Array:
    n, h, wd, c = x.shape
    hk = w_dw.shape[0]
    if w_dw.ndim == 4:                       # accept (HK,HK,C,1) layout
        w_dw = w_dw[..., 0]
    out_dtype = out_dtype or (jnp.int8 if requant_shift is not None else x.dtype)
    ph, pw = hk // 2, (hk - 1) // 2
    xp = jnp.pad(x, ((0, 0), (ph, pw), (ph, pw), (0, 0)))
    hp, wp = xp.shape[1], xp.shape[2]
    bc = effective_block(c, block_c)
    kern = functools.partial(_kernel, hk=hk, hout=h, wout=wd,
                             out_dtype=out_dtype, requant_shift=requant_shift,
                             act=act)
    return pl.pallas_call(
        kern,
        grid=(n, c // bc),
        in_specs=[
            pl.BlockSpec((1, hp, wp, bc), lambda b, cb: (b, 0, 0, cb)),
            pl.BlockSpec((hk, hk, bc), lambda b, cb: (0, 0, cb)),
        ],
        out_specs=pl.BlockSpec((1, h, wd, bc), lambda b, cb: (b, 0, 0, cb)),
        out_shape=jax.ShapeDtypeStruct((n, h, wd, c), out_dtype),
        interpret=interpret,
    )(xp, w_dw)
