"""Depthwise convolution Pallas kernel (VPU path).

Depthwise conv has no channel contraction, so the MXU is idle — like the
paper's observation (via Jeon & Kim) that depthwise is *slower per MAC*
than pointwise on real hardware despite fewer MACs. On TPU it runs on the
8x128 VPU as HK^2 shifted element-wise multiply-accumulates; channels map
to the 128-lane dimension. Used standalone (dws primitive, stage 1) and as
the reference pattern for the Mamba causal conv1d kernel.

Grid: (batch_block, spatial_tile, channel-block). ``block_n`` images share
each filter-slice load per grid step and ``block_h``/``block_w`` bound the
halo-padded VMEM tile on large feature maps (same schedule family as
conv_im2col).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (acc_dtype, apply_act, apply_requant,
                     batch_spatial_schedule, effective_block, halo_tiles,
                     resolve_interpret, resolve_tile_config, shift_w4_block,
                     unpack_w4_block)


def _kernel(x_ref, w_ref, o_ref, *, hk, bh, bw, out_dtype, requant_shift,
            act=None, ws_ref=None):
    # x_ref: (BN, 1, 1, BH+HK-1, BW+HK-1, BC); w_ref: (HK, HK, BC)
    # (W4: (ceil(HK/2), HK, BC) nibble-packed along the tap-row axis —
    # channels stay the blocked 128-lane axis — + ws_ref (HK,) shifts)
    adt = acc_dtype(x_ref.dtype)
    bc = w_ref.shape[-1]
    bn = x_ref.shape[0]
    if ws_ref is None:
        wv = w_ref[...]
    else:
        wv = shift_w4_block(unpack_w4_block(w_ref[...], hk, 0), ws_ref[...], 0)
    acc = jnp.zeros((bn, bh, bw, bc), adt)
    for i in range(hk):
        for j in range(hk):
            acc = acc + (x_ref[:, 0, 0, i:i + bh, j:j + bw, :].astype(adt)
                         * wv[i, j].astype(adt)[None, None, None, :])
    acc = apply_act(acc, act)
    acc = apply_requant(acc, requant_shift)
    o_ref[...] = acc.astype(out_dtype)


def depthwise2d(x: jax.Array, w_dw: jax.Array, *, block_c: int = 128,
                block_n: int = 1, block_h: int | None = None,
                block_w: int | None = None,
                requant_shift: int | None = None, act: str | None = None,
                out_dtype=None,
                interpret: bool | None = None,
                config: dict | None = None,
                w_shifts: jax.Array | None = None) -> jax.Array:
    """SAME stride-1 depthwise conv. x: (N,H,W,C); w_dw: (HK,HK,C).

    ``act="relu"`` fuses the activation at accumulator scale before the
    requantization epilogue. ``config`` (a repro.tune schedule dict)
    overrides the block parameters (``block_c``, ``block_n``,
    ``block_h``/``block_w``). ``interpret=None`` auto-detects the backend.

    W4A8: with ``w_shifts`` (per-tap-row group shifts), ``w_dw`` is
    nibble-packed along the tap-row axis — ``(ceil(HK/2), HK, C)`` — so the
    channel axis keeps arbitrary ``block_c`` blocking while the weight block
    crossing HBM->VMEM is halved. Quantized path only.
    """
    if config:
        block_c = int(config.get("block_c", block_c))
    block_n, block_h, block_w = resolve_tile_config(config, block_n,
                                                    block_h, block_w)
    return _depthwise2d(x, w_dw, w_shifts, block_c=block_c, block_n=block_n,
                        block_h=block_h, block_w=block_w,
                        requant_shift=requant_shift,
                        act=act, out_dtype=out_dtype,
                        interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_c", "block_n", "block_h",
                                             "block_w", "requant_shift",
                                             "act", "out_dtype", "interpret"))
def _depthwise2d(x: jax.Array, w_dw: jax.Array, w_shifts=None, *,
                 block_c: int = 128,
                 block_n: int = 1, block_h: int | None = None,
                 block_w: int | None = None,
                 requant_shift: int | None = None, act: str | None = None,
                 out_dtype=None,
                 interpret: bool = True) -> jax.Array:
    n, h, wd, c = x.shape
    w4 = w_shifts is not None
    if w_dw.ndim == 4:                       # accept (HK,HK,C,1) layout
        w_dw = w_dw[..., 0]
    hk = w_dw.shape[1] if w4 else w_dw.shape[0]
    if w4:
        if requant_shift is None:
            raise ValueError("depthwise2d: W4 weights need the quantized "
                             "path (requant_shift)")
        assert w_dw.shape[0] == (hk + 1) // 2, \
            f"packed HK extent {w_dw.shape[0]} != ceil({hk}/2)"
    out_dtype = out_dtype or (jnp.int8 if requant_shift is not None else x.dtype)
    ph, pw = hk // 2, (hk - 1) // 2
    xp = jnp.pad(x, ((0, 0), (ph, pw), (ph, pw), (0, 0)))
    bc = effective_block(c, block_c)
    bn, bh, bw, n_th, n_tw = batch_spatial_schedule(n, h, wd, block_n,
                                                    block_h, block_w)
    halo = hk - 1
    tiles = halo_tiles(xp, n_th, n_tw, bh, bw, bh + halo, bw + halo)

    def x_index(b, s, cb):
        return (b, s // n_tw, s % n_tw, 0, 0, cb)

    def w_index(b, s, cb):
        return (0, 0, cb)

    def o_index(b, s, cb):
        return (b, s // n_tw, s % n_tw, cb)

    in_specs = [
        pl.BlockSpec((bn, 1, 1, bh + halo, bw + halo, bc), x_index),
        pl.BlockSpec(((hk + 1) // 2 if w4 else hk, hk, bc), w_index),
    ]
    args = [tiles, w_dw]
    if w4:
        in_specs.append(pl.BlockSpec((hk,), lambda b, s, cb: (0,)))
        args.append(w_shifts)

    def kern(*refs):
        it = iter(refs)
        x_ref, w_ref = next(it), next(it)
        ws_ref = next(it) if w4 else None
        _kernel(x_ref, w_ref, next(it), hk=hk, bh=bh, bw=bw,
                out_dtype=out_dtype, requant_shift=requant_shift, act=act,
                ws_ref=ws_ref)

    out = pl.pallas_call(
        kern,
        grid=(n // bn, n_th * n_tw, c // bc),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, bh, bw, bc), o_index),
        out_shape=jax.ShapeDtypeStruct((n, n_th * bh, n_tw * bw, c), out_dtype),
        interpret=interpret,
    )(*args)
    return out[:, :h, :wd, :]
