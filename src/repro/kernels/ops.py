"""Public jit'd entry points for the Pallas kernel layer.

``method='pallas'`` runs the TPU kernels (interpret=True automatically off
TPU); ``method='xla'`` runs the pure-jnp oracle (the direct / no-SIMD
baseline). Models and benchmarks call these, never pallas_call directly.

Schedule selection: every Pallas path consults the ``repro.tune`` subsystem
unless an explicit ``config=`` dict is passed — persistent cache entries
(committed by ``scripts/tune.py``) win, otherwise the analytic fallback
cost model picks the schedule. Lookups are memoized in-process, so the
per-call overhead after the first trace is one dict probe. An explicit
``config=`` together with ``method='xla'`` is a contradiction (the oracle
has no schedule knobs) and raises, mirroring ``_check_method``.

Epilogues: the quantized entry points thread ``requant_shift`` (Algorithm-1
round-to-nearest shift) and ``act="relu"`` (fused activation at accumulator
scale, applied before the shift) to both engines, so pallas and xla stay
bit-exact including the fused activation.

Observability: every entry point counts its dispatch into the process
metrics registry as ``kernels.dispatch.<kernel>.<method>`` (pallas vs xla
per primitive — the engine-coverage picture ``scripts/bench_snapshot.py``
snapshots), and ``causal_conv1d``'s auto->xla mesh fallback is counted
separately as ``kernels.fallback.causal_conv1d.mesh``. Calls from inside a
jit count once per trace, eager calls once per call.

Failure model (EXPERIMENTS.md §Resilience): the ``kernels.dispatch`` fault
seam (``repro.faults``) fires once per pallas dispatch (per trace from
inside a jit). An injected raise is retried a bounded number of times;
repeated failure degrades THAT kernel to its jnp oracle for the rest of
the process — sticky, one warning, counted as
``kernels.degraded.<kernel>`` — which is semantics-preserving because the
oracles are bit-exact with the pallas kernels (tests/test_kernels.py).
``reset_degraded()`` clears the sticky state (tests); the dispatch
counters keep recording the *requested* method, so degraded traffic is
the gap between ``kernels.dispatch.<k>.pallas`` and
``kernels.degraded.<k>``.
"""
from __future__ import annotations

import functools
import warnings
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.faults import inject as _faults
from repro.obs import metrics as _obs_metrics

from . import ref
from .common import use_interpret
from .conv_add import add_conv2d as _add_pallas
from .conv_dw import depthwise2d as _dw_pallas
from .conv_im2col import conv2d_im2col as _conv_pallas
from .conv_shift import shift_conv2d as _shift_pallas
from .conv1d_causal import causal_conv1d as _c1d_pallas
from .matmul_q8 import matmul as _mm_pallas
from .pool import maxpool2d as _pool_pallas


def _check_method(method: str, allowed=("pallas", "xla")):
    if method not in allowed:
        raise ValueError(f"unknown method {method!r}; expected one of {allowed}")


def _count_dispatch(kernel: str, method: str):
    _obs_metrics.counter(f"kernels.dispatch.{kernel}.{method}").inc()


# --------------------------------------------------- degradation (resilience)

#: kernels stuck on their xla oracle after repeated pallas failure:
#: kernel name -> repr of the exception that exhausted the retries
_DEGRADED: Dict[str, str] = {}

#: retries per dispatch before a kernel degrades. Kernel dispatch happens
#: at trace time, so there is no backoff sleep — a deterministic failure
#: fails identically on every attempt and degrades immediately after.
_MAX_DISPATCH_RETRIES = 2


def degraded() -> Dict[str, str]:
    """Kernels currently degraded to their oracle (name -> cause)."""
    return dict(_DEGRADED)


def reset_degraded() -> None:
    """Clear the sticky pallas->xla degradations (test isolation)."""
    _DEGRADED.clear()


def _is_degraded(kernel: str) -> bool:
    return kernel in _DEGRADED


def _pallas_guard(kernel: str, pallas_fn, xla_fn):
    """Run ``pallas_fn`` behind the ``kernels.dispatch`` fault seam with
    bounded retries; repeated failure (injected or real) degrades
    ``kernel`` to ``xla_fn`` — once, stickily, with one warning. The
    schedule lookup / explicit-config feasibility check stay OUTSIDE this
    guard: a CheckError is a caller bug, not a transient kernel fault."""
    last: Optional[BaseException] = None
    for _ in range(_MAX_DISPATCH_RETRIES + 1):
        try:
            _faults.check("kernels.dispatch")
            return pallas_fn()
        except _faults.InjectedFault as e:
            last = e                    # transient by construction: retry
        except Exception as e:
            last = e                    # deterministic failure: degrading
            break                       # now beats re-failing twice more
    _DEGRADED[kernel] = repr(last)
    _obs_metrics.counter(f"kernels.degraded.{kernel}").inc()
    warnings.warn(
        f"kernel {kernel}: pallas dispatch failed repeatedly ({last!r}); "
        f"degraded to the xla oracle for the rest of the process "
        f"(bit-exact, slower — reset_degraded() to retry pallas)",
        RuntimeWarning, stacklevel=3)
    return xla_fn()


def _check_no_config(method: str, config, *extra_knobs):
    """The xla oracle has no schedule: an explicit config (or explicit block
    knobs) together with method='xla' is a conflicting-arguments error, not
    something to silently ignore."""
    if config is not None or any(k is not None for k in extra_knobs):
        raise ValueError(
            f"method={method!r} runs the jnp oracle, which has no schedule "
            "knobs; drop the explicit config=/block arguments or use "
            "method='pallas'")


def _tuned(sig_fn, *dims, dtype):
    """Cache/analytic schedule lookup; lazy import avoids a module cycle
    (repro.tune.runner measures through these very kernels)."""
    from repro import tune
    return tune.get_config(sig_fn(*dims), str(dtype))


def _check_explicit(sig_fn, *dims, config, dtype):
    """An explicitly-passed schedule gets the same hard feasibility verdict
    the tuner and the executor enforce (``repro.check.check_schedule``) —
    a readable error here beats a Mosaic VMEM failure three layers down.
    Returns the config unchanged."""
    from repro.check import CheckError
    from repro.check.footprint import check_schedule
    v = check_schedule(sig_fn(*dims), config, str(dtype))
    if not v.ok:
        raise CheckError(
            f"infeasible schedule for {v.kernel}/{v.sig_key} "
            f"[{v.dtype}] {v.config}:", v.errors)
    return config


def _w4_dtype(x, w_shifts):
    """Tune-space dtype key: W4-packed weights get their own signature
    dtype ('w4a8') so v2-era int8 cache entries are never misapplied to the
    halved-weight-traffic search space (see tune.cache.SCHEMA_VERSION)."""
    if w_shifts is None:
        return x.dtype
    if x.dtype != jnp.int8:
        raise ValueError("W4 weights require int8 activations (W4A8)")
    return "w4a8"


def conv2d(x, w, bias=None, *, groups: int = 1, method: str = "pallas",
           requant_shift: Optional[int] = None, act: Optional[str] = None,
           config: Optional[dict] = None,
           w_shifts: Optional[jax.Array] = None):
    _check_method(method)
    _count_dispatch("conv2d", method)

    def _xla():
        if w_shifts is not None:
            return ref.conv2d_w4_ref(x, w, w_shifts, bias, groups=groups,
                                     requant_shift=requant_shift, act=act)
        if requant_shift is not None:
            return ref.conv2d_q8_ref(x, w, bias, groups=groups,
                                     requant_shift=requant_shift, act=act)
        return ref.conv2d_ref(x, w, bias, groups=groups, act=act)

    if method == "xla":
        _check_no_config(method, config)
        return _xla()
    if _is_degraded("conv2d"):
        return _xla()
    from repro.tune import sig_conv2d
    n, h, wd, cx = x.shape
    if config is None:
        config = _tuned(sig_conv2d, n, h, wd, cx, w.shape[-1], w.shape[0],
                        groups, dtype=_w4_dtype(x, w_shifts))
    else:
        _check_explicit(sig_conv2d, n, h, wd, cx, w.shape[-1], w.shape[0],
                        groups, config=config, dtype=_w4_dtype(x, w_shifts))
    return _pallas_guard("conv2d", lambda: _conv_pallas(
        x, w, bias, groups=groups, requant_shift=requant_shift,
        act=act, interpret=use_interpret(), config=config,
        w_shifts=w_shifts), _xla)


def depthwise2d(x, w_dw, *, method: str = "pallas",
                requant_shift: Optional[int] = None, act: Optional[str] = None,
                config: Optional[dict] = None,
                w_shifts: Optional[jax.Array] = None):
    _check_method(method)
    _count_dispatch("depthwise2d", method)

    def _xla():
        if w_shifts is not None:
            return ref.depthwise2d_w4_ref(x, w_dw, w_shifts,
                                          requant_shift=requant_shift, act=act)
        if requant_shift is not None:
            return ref.depthwise2d_q8_ref(x, w_dw, requant_shift=requant_shift,
                                          act=act)
        return ref.depthwise2d_ref(x, w_dw, act=act)

    if method == "xla":
        _check_no_config(method, config)
        return _xla()
    if _is_degraded("depthwise2d"):
        return _xla()
    from repro.tune import sig_depthwise2d
    n, h, wd, c = x.shape
    hk = w_dw.shape[1] if w_shifts is not None else w_dw.shape[0]
    if config is None:
        config = _tuned(sig_depthwise2d, n, h, wd, c, hk,
                        dtype=_w4_dtype(x, w_shifts))
    else:
        _check_explicit(sig_depthwise2d, n, h, wd, c, hk,
                        config=config, dtype=_w4_dtype(x, w_shifts))
    return _pallas_guard("depthwise2d", lambda: _dw_pallas(
        x, w_dw, requant_shift=requant_shift, act=act,
        interpret=use_interpret(), config=config, w_shifts=w_shifts), _xla)


def shift_conv2d(x, shifts, w_pw, bias=None, *, method: str = "pallas",
                 requant_shift: Optional[int] = None,
                 act: Optional[str] = None,
                 config: Optional[dict] = None,
                 max_shift: Optional[int] = None,
                 w_shifts: Optional[jax.Array] = None):
    """``max_shift`` bounds |shift| when the table is traced (jit): pass
    ``kernel_size // 2``; unused when the table is concrete. ``bias`` is
    added at accumulator scale (quantized path only)."""
    _check_method(method)
    _count_dispatch("shift_conv2d", method)

    def _xla():
        if w_shifts is not None:
            return ref.shift_conv2d_w4_ref(x, shifts, w_pw, w_shifts, bias,
                                           requant_shift=requant_shift,
                                           max_shift=max_shift, act=act)
        if requant_shift is not None:
            return ref.shift_conv2d_q8_ref(x, shifts, w_pw, bias,
                                           requant_shift=requant_shift,
                                           max_shift=max_shift, act=act)
        if bias is not None:
            raise ValueError("shift_conv2d: bias without requant_shift is "
                             "only supported on the quantized path")
        return ref.shift_conv2d_ref(x, shifts, w_pw, max_shift=max_shift,
                                    act=act)

    if method == "xla":
        _check_no_config(method, config)
        return _xla()
    if _is_degraded("shift_conv2d"):
        return _xla()
    from repro.tune import sig_shift_conv2d
    n, h, wd, c = x.shape
    if config is None:
        config = _tuned(sig_shift_conv2d, n, h, wd, c, w_pw.shape[-1],
                        dtype=_w4_dtype(x, w_shifts))
    else:
        _check_explicit(sig_shift_conv2d, n, h, wd, c, w_pw.shape[-1],
                        config=config, dtype=_w4_dtype(x, w_shifts))
    return _pallas_guard("shift_conv2d", lambda: _shift_pallas(
        x, shifts, w_pw, bias, requant_shift=requant_shift,
        act=act, interpret=use_interpret(), config=config,
        w_shifts=w_shifts), _xla)


def add_conv2d(x, w, bias=None, *, method: str = "pallas",
               requant_shift: Optional[int] = None,
               x_preshift: int = 0, w_preshift: int = 0,
               act: Optional[str] = None,
               config: Optional[dict] = None,
               w_shifts: Optional[jax.Array] = None):
    """``bias`` is added at accumulator scale (quantized path only);
    ``x_preshift``/``w_preshift`` are the Algorithm-1 (right) scale-alignment
    left shifts applied to the operands before |x - w|."""
    _check_method(method)
    _count_dispatch("add_conv2d", method)

    def _xla():
        if w_shifts is not None:
            return ref.add_conv2d_w4_ref(x, w, w_shifts, bias,
                                         requant_shift=requant_shift,
                                         x_preshift=x_preshift,
                                         w_preshift=w_preshift, act=act)
        if requant_shift is not None:
            return ref.add_conv2d_q8_ref(x, w, bias,
                                         requant_shift=requant_shift,
                                         x_preshift=x_preshift,
                                         w_preshift=w_preshift, act=act)
        if bias is not None or x_preshift or w_preshift:
            raise ValueError("add_conv2d: bias/preshifts without "
                             "requant_shift are only supported on the "
                             "quantized path")
        return ref.add_conv2d_ref(x, w, act=act)

    if method == "xla":
        _check_no_config(method, config)
        return _xla()
    if _is_degraded("add_conv2d"):
        return _xla()
    from repro.tune import sig_add_conv2d
    n, h, wd, cx = x.shape
    if config is None:
        config = _tuned(sig_add_conv2d, n, h, wd, cx, w.shape[-1], w.shape[0],
                        dtype=_w4_dtype(x, w_shifts))
    else:
        _check_explicit(sig_add_conv2d, n, h, wd, cx, w.shape[-1], w.shape[0],
                        config=config, dtype=_w4_dtype(x, w_shifts))
    return _pallas_guard("add_conv2d", lambda: _add_pallas(
        x, w, bias, requant_shift=requant_shift,
        x_preshift=x_preshift, w_preshift=w_preshift, act=act,
        interpret=use_interpret(), config=config, w_shifts=w_shifts), _xla)


def maxpool2d(x, *, window: int = 2, stride: Optional[int] = None,
              method: str = "pallas", config: Optional[dict] = None):
    """VALID max-pool, int8 or float. Pooling int8 codes is bit-exact with
    pooling the dequantized floats (max commutes with the positive pow2
    scale) — the graph executor's integer-only pool boundary."""
    _check_method(method)
    _count_dispatch("maxpool2d", method)

    def _xla():
        return ref.maxpool2d_ref(x, window=window, stride=stride)

    if method == "xla":
        _check_no_config(method, config)
        return _xla()
    if _is_degraded("maxpool2d"):
        return _xla()
    from repro.tune import sig_maxpool2d
    n, h, wd, c = x.shape
    if config is None:
        config = _tuned(sig_maxpool2d, n, h, wd, c, window, stride or window,
                        dtype=x.dtype)
    else:
        _check_explicit(sig_maxpool2d, n, h, wd, c, window, stride or window,
                        config=config, dtype=x.dtype)
    return _pallas_guard("maxpool2d", lambda: _pool_pallas(
        x, window=window, stride=stride,
        interpret=use_interpret(), config=config), _xla)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _causal_conv1d_diff(x, w, block_l, block_c):
    """Pallas forward + analytic jnp backward (pallas_call has no AD rule).

    bwd: dx is the anti-causal conv of g with the same taps (flip-conv-flip);
    dw[k,d] = sum_{b,l} g[b,l,d] * x_leftpad[b,l+k,d].
    """
    return _c1d_pallas(x, w, block_l=block_l, block_c=block_c,
                       interpret=use_interpret())


def _c1d_fwd(x, w, block_l, block_c):
    return _causal_conv1d_diff(x, w, block_l, block_c), (x, w)


def _c1d_bwd(block_l, block_c, res, g):
    x, w = res
    k = w.shape[0]
    gx = jnp.flip(_causal_conv1d_diff(jnp.flip(g, axis=1), w,
                                      block_l, block_c), axis=1)
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    l = x.shape[1]
    dw = jnp.stack([jnp.einsum("bld,bld->d", g.astype(jnp.float32),
                               xp[:, kk:kk + l, :].astype(jnp.float32))
                    for kk in range(k)], axis=0).astype(w.dtype)
    return gx, dw


_causal_conv1d_diff.defvjp(_c1d_fwd, _c1d_bwd)


def causal_conv1d(x, w, *, method: str = "auto",
                  config: Optional[dict] = None):
    """method='auto': Pallas kernel off-mesh (exercises the paper primitive);
    XLA path under SPMD — an opaque pallas_call would force its operands to
    be gathered/replicated by the partitioner. Pass ``config=`` only with an
    explicit method='pallas' request: the auto->xla resolution under a mesh
    must stay legal for schedule-pinned call sites, but a hard method='xla'
    with a config is the same conflicting-arguments error as everywhere
    else."""
    _check_method(method, ("auto", "pallas", "xla"))
    if method == "xla":
        _check_no_config(method, config)
    if method == "auto":
        from repro.parallel.sharding import current_mesh
        method = "xla" if current_mesh() is not None else "pallas"
        if method == "xla":     # auto degraded: opaque pallas_call vs SPMD
            _obs_metrics.counter("kernels.fallback.causal_conv1d.mesh").inc()
    _count_dispatch("causal_conv1d", method)
    if method == "xla":
        return ref.causal_conv1d_ref(x, w)
    if _is_degraded("causal_conv1d"):
        return ref.causal_conv1d_ref(x, w)
    from repro.tune import sig_causal_conv1d
    b, l, d = x.shape
    if config is None:
        config = _tuned(sig_causal_conv1d, b, l, d, w.shape[0], dtype=x.dtype)
    else:
        _check_explicit(sig_causal_conv1d, b, l, d, w.shape[0],
                        config=config, dtype=x.dtype)
    from repro.tune import default_config
    base = default_config("causal_conv1d")
    return _pallas_guard("causal_conv1d", lambda: _causal_conv1d_diff(
        x, w, int(config.get("block_l", base["block_l"])),
        int(config.get("block_c", base["block_c"]))),
        lambda: ref.causal_conv1d_ref(x, w))


def matmul(a, b, *, method: str = "pallas", requant_shift: Optional[int] = None,
           act: Optional[str] = None,
           bm: Optional[int] = None, bn: Optional[int] = None,
           bk: Optional[int] = None, config: Optional[dict] = None,
           w_shifts: Optional[jax.Array] = None):
    """Explicit bm/bn/bk win over ``config``, which wins over the tuner."""
    _check_method(method)
    _count_dispatch("matmul", method)

    def _xla():
        if w_shifts is not None:
            return ref.matmul_w4_ref(a, b, w_shifts,
                                     requant_shift=requant_shift, act=act)
        return ref.matmul_ref(a, b, requant_shift=requant_shift, act=act)

    if method == "xla":
        _check_no_config(method, config, bm, bn, bk)
        return _xla()
    if _is_degraded("matmul"):
        return _xla()
    from repro.tune import sig_matmul
    explicit = config is not None or any(v is not None for v in (bm, bn, bk))
    if config is None and None in (bm, bn, bk):
        config = _tuned(sig_matmul, a.shape[0], a.shape[1], b.shape[1],
                        dtype=_w4_dtype(a, w_shifts))
    config = dict(config or {})
    for name, val in (("bm", bm), ("bn", bn), ("bk", bk)):
        if val is not None:
            config[name] = val
    if explicit:
        _check_explicit(sig_matmul, a.shape[0], a.shape[1], b.shape[1],
                        config=config, dtype=_w4_dtype(a, w_shifts))
    return _pallas_guard("matmul", lambda: _mm_pallas(
        a, b, requant_shift=requant_shift, act=act,
        interpret=use_interpret(), config=config, w_shifts=w_shifts), _xla)
