"""Public jit'd entry points for the Pallas kernel layer.

``method='pallas'`` runs the TPU kernels (interpret=True automatically off
TPU); ``method='xla'`` runs the pure-jnp oracle (the direct / no-SIMD
baseline). Models and benchmarks call these, never pallas_call directly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .common import use_interpret
from .conv_add import add_conv2d as _add_pallas
from .conv_dw import depthwise2d as _dw_pallas
from .conv_im2col import conv2d_im2col as _conv_pallas
from .conv_shift import shift_conv2d as _shift_pallas
from .conv1d_causal import causal_conv1d as _c1d_pallas
from .matmul_q8 import matmul as _mm_pallas


def conv2d(x, w, bias=None, *, groups: int = 1, method: str = "pallas",
           requant_shift: Optional[int] = None):
    if method == "xla":
        if requant_shift is not None:
            return ref.conv2d_q8_ref(x, w, bias, groups=groups,
                                     requant_shift=requant_shift)
        return ref.conv2d_ref(x, w, bias, groups=groups)
    return _conv_pallas(x, w, bias, groups=groups, requant_shift=requant_shift,
                        interpret=use_interpret())


def depthwise2d(x, w_dw, *, method: str = "pallas"):
    if method == "xla":
        return ref.depthwise2d_ref(x, w_dw)
    return _dw_pallas(x, w_dw, interpret=use_interpret())


def shift_conv2d(x, shifts, w_pw, *, method: str = "pallas",
                 requant_shift: Optional[int] = None):
    if method == "xla":
        return ref.shift_conv2d_ref(x, shifts, w_pw)
    return _shift_pallas(x, shifts, w_pw, requant_shift=requant_shift,
                         interpret=use_interpret())


def add_conv2d(x, w, *, method: str = "pallas",
               requant_shift: Optional[int] = None,
               x_preshift: int = 0, w_preshift: int = 0):
    if method == "xla":
        return ref.add_conv2d_ref(x, w)
    return _add_pallas(x, w, requant_shift=requant_shift,
                       x_preshift=x_preshift, w_preshift=w_preshift,
                       interpret=use_interpret())


@jax.custom_vjp
def _causal_conv1d_diff(x, w):
    """Pallas forward + analytic jnp backward (pallas_call has no AD rule).

    bwd: dx is the anti-causal conv of g with the same taps (flip-conv-flip);
    dw[k,d] = sum_{b,l} g[b,l,d] * x_leftpad[b,l+k,d].
    """
    return _c1d_pallas(x, w, interpret=use_interpret())


def _c1d_fwd(x, w):
    return _causal_conv1d_diff(x, w), (x, w)


def _c1d_bwd(res, g):
    x, w = res
    k = w.shape[0]
    gx = jnp.flip(_causal_conv1d_diff(jnp.flip(g, axis=1), w), axis=1)
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    l = x.shape[1]
    dw = jnp.stack([jnp.einsum("bld,bld->d", g.astype(jnp.float32),
                               xp[:, kk:kk + l, :].astype(jnp.float32))
                    for kk in range(k)], axis=0).astype(w.dtype)
    return gx, dw


_causal_conv1d_diff.defvjp(_c1d_fwd, _c1d_bwd)


def causal_conv1d(x, w, *, method: str = "auto"):
    """method='auto': Pallas kernel off-mesh (exercises the paper primitive);
    XLA path under SPMD — an opaque pallas_call would force its operands to
    be gathered/replicated by the partitioner."""
    if method == "auto":
        from repro.parallel.sharding import current_mesh
        method = "xla" if current_mesh() is not None else "pallas"
    if method == "xla":
        return ref.causal_conv1d_ref(x, w)
    return _causal_conv1d_diff(x, w)


def matmul(a, b, *, method: str = "pallas", requant_shift: Optional[int] = None,
           bm: int = 256, bn: int = 256, bk: int = 512):
    if method == "xla":
        return ref.matmul_ref(a, b, requant_shift=requant_shift)
    return _mm_pallas(a, b, bm=bm, bn=bn, bk=bk, requant_shift=requant_shift,
                      interpret=use_interpret())
