"""Tiled MXU matmul kernel with the paper's int8 power-of-two requantization.

Backs the pointwise stage of dws/shift at LM scale and the optional
integer-only serve path (DESIGN.md: Eq. 4 / Algorithm 1 applied to LM
matmuls). Classic 3-D grid (M/BM, N/BN, K/BK): the K axis is the innermost
("arbitrary") dimension and the output block is revisited across K steps,
accumulating in VMEM; on the last K step the epilogue applies bias + the
Algorithm-1 arithmetic shift and clips to int8. bf16/f32 paths share the
same schedule with an f32 accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import acc_dtype, apply_act, apply_requant, cdiv, resolve_interpret


def _make_compiler_params(n_parallel: int):
    try:
        from jax.experimental.pallas import tpu as pltpu
        sem = ("parallel",) * n_parallel + ("arbitrary",)
        try:
            return pltpu.CompilerParams(dimension_semantics=sem)
        except AttributeError:      # older naming
            return pltpu.TPUCompilerParams(dimension_semantics=sem)
    except Exception:               # pragma: no cover - CPU-only envs
        return None


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, nk, out_dtype, requant_shift,
            act=None):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    adt = acc_ref.dtype
    acc_ref[...] += jnp.dot(a_ref[...].astype(adt), b_ref[...].astype(adt),
                            preferred_element_type=adt)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        acc = apply_act(acc_ref[...], act)
        o_ref[...] = apply_requant(acc, requant_shift).astype(out_dtype)


def matmul(a: jax.Array, b: jax.Array, *, bm: int = 256, bn: int = 256,
           bk: int = 512, requant_shift: int | None = None,
           act: str | None = None, out_dtype=None,
           interpret: bool | None = None,
           config: dict | None = None) -> jax.Array:
    """a: (M, K) or (N_batch, M, K) @ b: (K, N). int8 inputs +
    requant_shift -> int8 output.

    A 3-D ``a`` is the batched serving path: the leading batch dim is
    folded into M, so one kernel launch covers the whole microbatch and the
    ``bm`` grid tiles the combined batch-row axis — each ``b`` block load
    is amortized across every image in the batch (the same weight-reuse
    schedule as the conv kernels' ``block_n``), and batched-vs-looped is
    bit-exact by construction (identical per-row contractions).

    ``act="relu"`` fuses the activation at accumulator scale on the last
    K step, before requantization. ``config`` (a repro.tune schedule dict)
    overrides the block parameters. ``interpret=None`` auto-detects the
    backend.
    """
    if config:
        bm = int(config.get("bm", bm))
        bn = int(config.get("bn", bn))
        bk = int(config.get("bk", bk))
    if a.ndim == 3:
        nb, m, k = a.shape
        out = _matmul(a.reshape(nb * m, k), b, bm=bm, bn=bn, bk=bk,
                      requant_shift=requant_shift, act=act,
                      out_dtype=out_dtype,
                      interpret=resolve_interpret(interpret))
        return out.reshape(nb, m, b.shape[-1])
    return _matmul(a, b, bm=bm, bn=bn, bk=bk, requant_shift=requant_shift,
                   act=act, out_dtype=out_dtype,
                   interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "requant_shift",
                                             "act", "out_dtype", "interpret"))
def _matmul(a: jax.Array, b: jax.Array, *, bm: int = 256, bn: int = 256,
            bk: int = 512, requant_shift: int | None = None,
            act: str | None = None, out_dtype=None,
            interpret: bool = True) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    out_dtype = out_dtype or (jnp.int8 if requant_shift is not None else a.dtype)
    adt = acc_dtype(a.dtype)
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    grid = (cdiv(m, bm_), cdiv(n, bn_), cdiv(k, bk_))
    kern = functools.partial(_kernel, nk=grid[2], out_dtype=out_dtype,
                             requant_shift=requant_shift, act=act)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), adt)],
        interpret=interpret,
    )(a, b)
