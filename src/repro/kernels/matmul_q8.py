"""Tiled MXU matmul kernel with the paper's int8 power-of-two requantization.

Backs the pointwise stage of dws/shift at LM scale and the optional
integer-only serve path (DESIGN.md: Eq. 4 / Algorithm 1 applied to LM
matmuls). Classic 3-D grid (M/BM, N/BN, K/BK): the K axis is the innermost
("arbitrary") dimension and the output block is revisited across K steps,
accumulating in VMEM; on the last K step the epilogue applies bias + the
Algorithm-1 arithmetic shift and clips to int8. bf16/f32 paths share the
same schedule with an f32 accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import (acc_dtype, apply_act, apply_requant, cdiv,
                     resolve_interpret, shift_w4_block, unpack_w4_block)


def _make_compiler_params(n_parallel: int):
    try:
        from jax.experimental.pallas import tpu as pltpu
        sem = ("parallel",) * n_parallel + ("arbitrary",)
        try:
            return pltpu.CompilerParams(dimension_semantics=sem)
        except AttributeError:      # older naming
            return pltpu.TPUCompilerParams(dimension_semantics=sem)
    except Exception:               # pragma: no cover - CPU-only envs
        return None


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, nk, out_dtype, requant_shift,
            act=None, ws_ref=None):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    adt = acc_ref.dtype
    if ws_ref is None:
        bv = b_ref[...].astype(adt)
    else:
        # W4: b block is (BK/2, BN) nibble-packed along K; padded tail bytes
        # unpack to zero codes, matching a's zero-padded ragged block
        bv = shift_w4_block(
            unpack_w4_block(b_ref[...], 2 * b_ref.shape[0], 0),
            ws_ref[...], 0).astype(adt)
    acc_ref[...] += jnp.dot(a_ref[...].astype(adt), bv,
                            preferred_element_type=adt)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        acc = apply_act(acc_ref[...], act)
        o_ref[...] = apply_requant(acc, requant_shift).astype(out_dtype)


def matmul(a: jax.Array, b: jax.Array, *, bm: int = 256, bn: int = 256,
           bk: int = 512, requant_shift: int | None = None,
           act: str | None = None, out_dtype=None,
           interpret: bool | None = None,
           config: dict | None = None,
           w_shifts: jax.Array | None = None) -> jax.Array:
    """a: (M, K) or (N_batch, M, K) @ b: (K, N). int8 inputs +
    requant_shift -> int8 output.

    W4A8: with ``w_shifts`` (per-K group-scale shifts), ``b`` is
    nibble-packed along K (``(ceil(K/2), N)``); the K block size is forced
    even so packed blocks never straddle a byte, and the kernel unpacks
    in-register — only the half-width weight block crosses HBM->VMEM.
    Quantized path only.

    A 3-D ``a`` is the batched serving path: the leading batch dim is
    folded into M, so one kernel launch covers the whole microbatch and the
    ``bm`` grid tiles the combined batch-row axis — each ``b`` block load
    is amortized across every image in the batch (the same weight-reuse
    schedule as the conv kernels' ``block_n``), and batched-vs-looped is
    bit-exact by construction (identical per-row contractions).

    ``act="relu"`` fuses the activation at accumulator scale on the last
    K step, before requantization. ``config`` (a repro.tune schedule dict)
    overrides the block parameters. ``interpret=None`` auto-detects the
    backend.
    """
    if config:
        bm = int(config.get("bm", bm))
        bn = int(config.get("bn", bn))
        bk = int(config.get("bk", bk))
    if a.ndim == 3:
        nb, m, k = a.shape
        out = _matmul(a.reshape(nb * m, k), b, w_shifts, bm=bm, bn=bn, bk=bk,
                      requant_shift=requant_shift, act=act,
                      out_dtype=out_dtype,
                      interpret=resolve_interpret(interpret))
        return out.reshape(nb, m, b.shape[-1])
    return _matmul(a, b, w_shifts, bm=bm, bn=bn, bk=bk,
                   requant_shift=requant_shift,
                   act=act, out_dtype=out_dtype,
                   interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "requant_shift",
                                             "act", "out_dtype", "interpret"))
def _matmul(a: jax.Array, b: jax.Array, w_shifts=None, *, bm: int = 256,
            bn: int = 256,
            bk: int = 512, requant_shift: int | None = None,
            act: str | None = None, out_dtype=None,
            interpret: bool = True) -> jax.Array:
    m, k = a.shape
    w4 = w_shifts is not None
    k2, n = b.shape
    if w4:
        if requant_shift is None:
            raise ValueError("matmul: W4 weights need the quantized path "
                             "(requant_shift)")
        assert k2 == (k + 1) // 2, f"packed K extent {k2} != ceil({k}/2)"
    else:
        assert k == k2
    out_dtype = out_dtype or (jnp.int8 if requant_shift is not None else a.dtype)
    adt = acc_dtype(a.dtype)
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    if w4 and bk_ % 2:          # packed K blocks must not straddle a byte
        bk_ += 1
    grid = (cdiv(m, bm_), cdiv(n, bn_), cdiv(k, bk_))
    in_specs = [
        pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk_ // 2 if w4 else bk_, bn_), lambda i, j, kk: (kk, j)),
    ]
    args = [a, b]
    if w4:
        in_specs.append(pl.BlockSpec((bk_,), lambda i, j, kk: (kk,)))
        args.append(w_shifts)

    def kern(*refs):
        it = iter(refs)
        a_ref, b_ref = next(it), next(it)
        ws_ref = next(it) if w4 else None
        o_ref = next(it)
        _kernel(a_ref, b_ref, o_ref, next(it), nk=grid[2],
                out_dtype=out_dtype, requant_shift=requant_shift, act=act,
                ws_ref=ws_ref)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), adt)],
        interpret=interpret,
    )(*args)
