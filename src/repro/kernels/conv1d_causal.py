"""Depthwise causal conv1d Pallas kernel — the paper's depthwise primitive
integrated into the LM stack (Mamba / Jamba hot path).

Mamba's short (K=4) causal conv1d is exactly a depthwise convolution in 1-D,
so this is the flagship carry-over of the paper's primitive library into the
assigned SSM/hybrid architectures (DESIGN.md §Arch-applicability).

Tiling: grid over (batch, seq-block, channel-block). The K-1 left halo is
obtained without overlapping BlockSpecs by passing the SAME padded array
twice with consecutive index maps (block i-1 supplies the halo tail); the
wrapper left-pads with K-1 zeros so block 0 needs no special casing and
appends one zero block so index i+1 never overruns.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import acc_dtype, apply_act, cdiv, effective_block, resolve_interpret


def _kernel(xa_ref, xb_ref, w_ref, o_ref, *, k, bl, out_dtype, act=None):
    adt = acc_dtype(xa_ref.dtype)
    # window rows [0, bl + k - 1): current block + first k-1 rows of next
    window = jnp.concatenate([xa_ref[0], xb_ref[0, :k - 1]], axis=0).astype(adt)
    w = w_ref[...].astype(adt)               # (K, BC)
    acc = jnp.zeros((bl, w.shape[-1]), adt)
    for kk in range(k):                       # static unroll, VPU MACs
        acc = acc + window[kk:kk + bl, :] * w[kk][None, :]
    acc = apply_act(acc, act)
    o_ref[0] = acc.astype(out_dtype)


def causal_conv1d(x: jax.Array, w: jax.Array, *, block_l: int = 512,
                  block_c: int = 512, act: str | None = None,
                  interpret: bool | None = None,
                  config: dict | None = None) -> jax.Array:
    """out[b,l,d] = sum_k w[k,d] * x[b, l-K+1+k, d]. x: (B,L,D); w: (K,D).

    ``act="relu"`` fuses the activation into the epilogue (inference only —
    the ops-layer custom VJP assumes a linear kernel, so the differentiable
    entry point does not expose it). ``config`` (a repro.tune schedule dict)
    overrides the block parameters. ``interpret=None`` auto-detects the
    backend.
    """
    if config:
        block_l = int(config.get("block_l", block_l))
        block_c = int(config.get("block_c", block_c))
    return _causal_conv1d(x, w, block_l=block_l, block_c=block_c, act=act,
                          interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_l", "block_c", "act",
                                             "interpret"))
def _causal_conv1d(x: jax.Array, w: jax.Array, *, block_l: int = 512,
                   block_c: int = 512, act: str | None = None,
                   interpret: bool = True) -> jax.Array:
    b, l, d = x.shape
    k = w.shape[0]
    if w.ndim == 3:                           # accept (K, 1, D)
        w = w[:, 0]
    bl = effective_block(l, block_l)
    bc = effective_block(d, block_c)
    nl = l // bl
    # left halo pad (K-1) + one trailing zero block for the i+1 lookahead ref
    xp = jnp.pad(x, ((0, 0), (k - 1, bl), (0, 0)))
    kern = functools.partial(_kernel, k=k, bl=bl, out_dtype=x.dtype, act=act)
    return pl.pallas_call(
        kern,
        grid=(b, nl, d // bc),
        in_specs=[
            pl.BlockSpec((1, bl, bc), lambda bi, li, ci: (bi, li, ci)),
            pl.BlockSpec((1, bl, bc), lambda bi, li, ci: (bi, li + 1, ci)),
            pl.BlockSpec((k, bc), lambda bi, li, ci: (0, ci)),
        ],
        out_specs=pl.BlockSpec((1, bl, bc), lambda bi, li, ci: (bi, li, ci)),
        out_shape=jax.ShapeDtypeStruct((b, l, d), x.dtype),
        interpret=interpret,
    )(xp, xp, w)
