"""Jamba v0.1 52B [hybrid] — Mamba:attention 1:7 interleave (attn every 8
layers at offset 4), MoE (16e top-2) on every other layer [arXiv:2403.19887]."""
from .base import MambaConfig, ModelConfig, MoEConfig, register

register(ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536, act="silu",
    attn_period=8, attn_offset=4,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=14336, every_n_layers=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
))
