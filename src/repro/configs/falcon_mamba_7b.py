"""Falcon-Mamba-7B [ssm] — attention-free Mamba-1, d_state=16
[arXiv:2410.05355]."""
from .base import MambaConfig, ModelConfig, register

register(ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=65024, act="silu",
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
))
