"""Config system: architecture + shape + run configs, and the registry
behind ``--arch <id>`` / ``--shape <id>``."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                         # expert hidden size
    capacity_factor: float = 1.25
    dense_residual: bool = False      # arctic: dense FFN in parallel w/ MoE
    every_n_layers: int = 1           # jamba: MoE on every other layer


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None     # default ceil(d_model/16)

    def rank(self, d_model: int) -> int:
        return self.dt_rank or -(-d_model // 16)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|encdec|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    tied_embeddings: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    # hybrid (jamba): attention layer each `attn_period` layers at offset
    attn_period: int = 0
    attn_offset: int = 0
    # encdec
    n_encoder_layers: int = 0
    # frontends (vlm/audio): the modality embedder is a stub; inputs arrive
    # as precomputed frame/patch embeddings of this many positions
    frontend_positions: int = 0
    act: str = "silu"
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic total parameter count (sanity vs the advertised size)."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tied_embeddings else 2)
        per_attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim + \
            self.n_heads * self.head_dim * d
        if self.qkv_bias:
            per_attn += (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
        def ffn_params(ff):
            n_mat = 3 if self.act == "silu" else 2
            return n_mat * d * ff
        total = emb
        if self.family in ("dense", "vlm", "audio"):
            total += self.n_layers * (per_attn + ffn_params(self.d_ff) + 2 * d)
        elif self.family == "moe":
            moe = self.moe
            per_moe = moe.num_experts * ffn_params(moe.d_ff) + d * moe.num_experts
            dense_part = ffn_params(self.d_ff) if moe.dense_residual else 0
            total += self.n_layers * (per_attn + per_moe + dense_part + 2 * d)
        elif self.family == "ssm":
            m = self.mamba
            di = m.expand * d
            per = (d * 2 * di            # in_proj
                   + m.d_conv * di + di  # conv + bias
                   + di * (m.rank(d) + 2 * m.d_state)   # x_proj
                   + m.rank(d) * di + di # dt_proj
                   + di * m.d_state + di # A_log, D
                   + di * d              # out_proj
                   + d)                  # norm
            total += self.n_layers * per
        elif self.family == "hybrid":
            m = self.mamba
            di = m.expand * d
            per_mamba = (d * 2 * di + m.d_conv * di + di
                         + di * (m.rank(d) + 2 * m.d_state)
                         + m.rank(d) * di + di + di * m.d_state + di + di * d)
            n_attn = self.n_layers // self.attn_period
            n_mamba = self.n_layers - n_attn
            moe = self.moe
            n_moe = self.n_layers // moe.every_n_layers
            n_dense = self.n_layers - n_moe
            total += (n_attn * per_attn + n_mamba * per_mamba
                      + n_moe * (moe.num_experts * ffn_params(moe.d_ff) + d * moe.num_experts)
                      + n_dense * ffn_params(self.d_ff)
                      + self.n_layers * 2 * d)
        elif self.family == "encdec":
            enc = self.n_encoder_layers * (per_attn + ffn_params(self.d_ff) + 2 * d)
            dec = self.n_layers * (2 * per_attn + ffn_params(self.d_ff) + 3 * d)
            total += enc + dec
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


ARCH_IDS = [
    "internvl2-1b", "arctic-480b", "granite-moe-1b-a400m", "granite-34b",
    "qwen1.5-32b", "granite-3-2b", "qwen2-0.5b", "seamless-m4t-large-v2",
    "jamba-v0.1-52b", "falcon-mamba-7b",
]

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        mod = arch.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[arch]


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment skip rules (documented in DESIGN.md)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic():
        return False, "long_500k needs sub-quadratic attention; " \
                      f"{cfg.name} is pure full-attention (skip per DESIGN.md)"
    return True, ""
