"""IBM Granite 34B code [dense] — GPTBigCode-lineage, MQA (kv=1), GELU MLP
[arXiv:2405.04324]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152, act="gelu", rope_theta=1e4,
))
