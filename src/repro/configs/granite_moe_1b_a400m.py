"""IBM Granite 3.0 1B-A400M [moe] — 32 experts, top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from .base import ModelConfig, MoEConfig, register

register(ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab=49155, tied_embeddings=True, rope_theta=1e4, act="silu",
    moe=MoEConfig(num_experts=32, top_k=8, d_ff=512),
))
