from .base import (ARCH_IDS, SHAPES, ModelConfig, MoEConfig, MambaConfig,
                   ShapeConfig, get_config, register, cell_supported)
