"""InternVL2-1B [vlm] — InternViT frontend (STUB) + InternLM2-chat-1b-style
backbone [arXiv:2404.16821]. Backbone config verbatim from the assignment;
the vision tower supplies precomputed patch embeddings via input_specs()."""
from .base import ModelConfig, register

register(ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151655,
    qkv_bias=True, tied_embeddings=True, rope_theta=1e6,
    frontend_positions=256, act="silu",
))
