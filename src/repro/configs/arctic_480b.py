"""Snowflake Arctic 480B [moe] — 128-expert top-2 MoE with a parallel dense
residual FFN per layer [hf:Snowflake/snowflake-arctic-base]."""
from .base import ModelConfig, MoEConfig, register

register(ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000, rope_theta=1e6, act="silu",
    moe=MoEConfig(num_experts=128, top_k=2, d_ff=4864, dense_residual=True),
))
