"""SeamlessM4T-large v2 [audio] — encoder-decoder text/speech backbone
[arXiv:2308.11596]. Speech frontend is a STUB: input_specs() supplies
precomputed frame embeddings (B, S_enc, d_model)."""
from .base import ModelConfig, register

register(ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_encoder_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206, act="gelu",
    frontend_positions=0,   # encoder length comes from the shape config
))
