from .pipeline import DataConfig, IndexedDataset, PrefetchLoader
