"""Deterministic, shardable, resumable data pipelines.

Design for 1000+-node runs: the pipeline is INDEX-BASED — batch `i` is a
pure function of (seed, i), so resume-after-preemption needs only the step
counter from the checkpoint (no iterator state files), every host can
compute exactly its own shard (disjoint by construction), and skip-ahead is
O(1). Synthetic sources stand in for the tokenized corpus; the interface is
what a real loader would implement.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    kind: str = "lm"              # lm | vlm | encdec | image
    vocab: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    seed: int = 0
    # image (paper-side CNN)
    image_size: int = 32
    channels: int = 3
    num_classes: int = 10
    d_model: int = 0              # vlm/encdec stub embedding dim
    frontend_positions: int = 0


class IndexedDataset:
    """batch(i) -> host-local shard of global batch i (numpy arrays)."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts

    def _rng(self, step: int) -> np.random.Generator:
        # counter-based: independent of call order, O(1) skip-ahead
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.host_id]))

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = self._rng(step)
        if cfg.kind == "lm":
            # structured synthetic LM stream: mixture of ngram-ish repeats so
            # a real model can actually reduce loss on it
            toks = rng.integers(0, cfg.vocab, (self.local_batch, cfg.seq_len + 1),
                                dtype=np.int32)
            period = 3 + (step % 5)
            toks[:, period:] = np.where(
                rng.random((self.local_batch, cfg.seq_len + 1 - period)) < 0.7,
                toks[:, :-period], toks[:, period:])
            return {"tokens": toks}
        if cfg.kind == "vlm":
            toks = rng.integers(0, cfg.vocab,
                                (self.local_batch,
                                 cfg.seq_len - cfg.frontend_positions + 1),
                                dtype=np.int32)
            emb = rng.standard_normal(
                (self.local_batch, cfg.frontend_positions, cfg.d_model),
                dtype=np.float32)
            return {"tokens": toks, "embeds": emb}
        if cfg.kind == "encdec":
            toks = rng.integers(0, cfg.vocab, (self.local_batch, cfg.seq_len + 1),
                                dtype=np.int32)
            frames = rng.standard_normal(
                (self.local_batch, cfg.seq_len, cfg.d_model), dtype=np.float32)
            return {"frames": frames, "tokens": toks}
        if cfg.kind == "image":
            # class-conditional gaussian blobs -> learnable classification
            y = rng.integers(0, cfg.num_classes, (self.local_batch,), dtype=np.int32)
            means = np.linspace(-1.5, 1.5, cfg.num_classes)[y]
            x = rng.standard_normal(
                (self.local_batch, cfg.image_size, cfg.image_size, cfg.channels)
            ).astype(np.float32) * 0.5 + means[:, None, None, None]
            # class-dependent spatial pattern so convs matter
            xs = np.linspace(0, np.pi * 2, cfg.image_size)
            pat = np.sin(xs[None, :, None] * (1 + y[:, None, None] % 4))
            x += pat[..., None].astype(np.float32)
            return {"images": x, "labels": y}
        raise ValueError(cfg.kind)

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


class PrefetchLoader:
    """Double-buffered host->device prefetch (overlaps H2D with compute)."""

    def __init__(self, ds: IndexedDataset, start_step: int = 0, depth: int = 2,
                 sharding=None):
        self.ds = ds
        self.step = start_step
        self.depth = depth
        self.sharding = sharding
        self.buf: list = []

    def _put(self, batch):
        if self.sharding is not None:
            return jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), batch, self.sharding)
        return jax.tree_util.tree_map(jax.device_put, batch)

    def __next__(self):
        while len(self.buf) < self.depth:
            self.buf.append(self._put(self.ds.batch(self.step + len(self.buf))))
        out = self.buf.pop(0)
        self.step += 1
        return out

    def __iter__(self):
        return self
