"""repro.graph — layer-graph IR, lowering passes (BN fold + single-sweep
PTQ + requant/ReLU/pool fusion) and the single-jit integer executor with
per-layer cost attribution. See EXPERIMENTS.md §Per-layer."""
from .ir import Graph, Node, build_cnn_graph, params_for
from .lower import Plan, PlanNode, annotate, lower
from .executor import CompiledPlan, float_forward, unfused_forward

__all__ = [
    "Graph", "Node", "build_cnn_graph", "params_for",
    "Plan", "PlanNode", "annotate", "lower",
    "CompiledPlan", "float_forward", "unfused_forward",
]
