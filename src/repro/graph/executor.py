"""Plan executor: one jit for the whole integer network.

:class:`CompiledPlan` takes a lowered :class:`~repro.graph.lower.Plan`,
resolves each node's execution method (pallas / xla) and tuned kernel
schedule ONCE (at first trace, via ``repro.tune``), and jits the entire
forward as a single function — the one-compiled-artifact-per-model regime
the ROADMAP's north star calls for. Inside the jit the activations stay
int8 from the input quantization to the global average pool: ReLU runs as
the conv kernels' accumulator-scale epilogue and pooling runs on int8 codes
(``kernels.ops.maxpool2d``), so there are zero float round-trips between
conv layers.

Three more entry points share the plan:

* :func:`float_forward` — the float inference interpreter over the IR
  (``models.convnet.cnn_forward``'s eval path).
* :func:`unfused_forward` — the OLD float-bounce regime reconstructed from
  the same plan (dequantize -> float ReLU/BN/pool -> requantize at the same
  annotated scales). Bit-exact with the fused path by construction (relu
  and max commute with the positive pow2 scale; requantization is monotone
  with ``rshift_round(0) == 0``) — pinned by tests/test_graph.py and used
  as the fused-vs-unfused baseline in benchmarks/layer_bench.py.
* :meth:`CompiledPlan.profile` — instrumented per-layer attribution:
  measured latency, analytic MACs and the paper-calibrated MCU
  latency/energy model per node ("Not All Ops Are Created Equal": cost is
  a per-layer, not per-network, quantity).

Observability (``repro.obs``): jit traces count into the process metrics
registry (``graph.compiles`` + a per-batch-bucket counter, and
``graph.fallback.xla`` when ``method="auto"`` degrades a node to the
oracle), and with ``REPRO_TRACE=1`` every ``__call__``/``forward_batch``
emits a span while ``profile`` emits one ``layer.<name>`` span per row —
the per-layer executor track in an exported Perfetto trace.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.energy import MCUModel
from repro.core.qconv import _kernel_layer_ok, qconv_apply
from repro.core.quantize import QTensor, QTensorW4, quantize, requantize
from repro.kernels.common import apply_act
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from .ir import Graph
from .lower import Plan, PlanNode


def _node_dtype(node: PlanNode) -> str:
    """Tune-space dtype key for one qconv node: "w4a8" when its weights are
    nibble-packed (the W4-aware cost model prices halved weight traffic),
    else "int8"."""
    if any(isinstance(v, QTensorW4) for v in (node.qparams or {}).values()):
        return "w4a8"
    return "int8"


def _qbn_apply(qp: dict, x: QTensor, out_fb: int, act: Optional[str]) -> QTensor:
    """Integer per-channel BN affine: int8 act * int16-range multiplier +
    bias at accumulator scale, fused act, Algorithm-1 requantization. Pure
    int32 jnp — identical under both methods, so it never breaks
    pallas==xla."""
    acc = x.q.astype(jnp.int32) * qp["a"] + qp["b"]
    acc = apply_act(acc, act)
    return QTensor(requantize(acc, x.frac_bits + qp["a_frac_bits"], out_fb),
                   out_fb)


class CompiledPlan:
    """Callable integer-only forward for one lowered plan.

    ``method`` selects the kernel engine for every eligible node:
    ``"pallas"`` (fused TPU kernels; raises on layers outside the kernel
    envelope), ``"xla"`` (jnp integer oracles), or ``"auto"`` (pallas where
    expressible, oracle fallback elsewhere). Schedules come from the
    ``repro.tune`` cache/fallback, resolved once per compile and recorded in
    ``self.node_configs``.

    Every plan is statically verified at build (``repro.check``: dataflow
    legality + int32 accumulator/requant-shift safety from the actual
    weight codes) and every resolved schedule gets a hard VMEM feasibility
    verdict at first trace — ``validate=False`` opts out of both (e.g. for
    deliberately adversarial plans under test).
    """

    def __init__(self, plan: Plan, *, method: str = "auto", jit: bool = True,
                 validate: bool = True):
        if method not in ("pallas", "xla", "auto"):
            raise ValueError(f"unknown method {method!r}; expected "
                             "'pallas', 'xla' or 'auto'")
        if validate:
            from repro.check import validate_plan
            validate_plan(plan)
        self.plan = plan
        self.method = method
        self.validate = validate
        self.node_configs: Dict[str, dict] = {}
        self.traces = 0                  # python-side compile counter
        self.degraded = False            # degrade_to_xla happened (one-shot)
        self._jit = jit
        self._fn = jax.jit(self._forward) if jit else self._forward

    def degrade_to_xla(self):
        """ONE-SHOT graceful degradation: re-point every node at the jnp
        integer oracle (``method="xla"``) and re-jit the forward, dropping
        the compiled pallas artifact. The xla oracles are bit-exact with
        the pallas kernels (tests/test_kernels.py), so already-served
        results stay comparable — only throughput degrades. Called by the
        serving layer after repeated round failures; idempotent, logged
        once, counted as ``graph.degraded`` in the process metrics."""
        if self.degraded:
            return
        self.degraded = True
        self.method = "xla"
        self.node_configs = {}           # pallas schedules no longer apply
        self._fn = jax.jit(self._forward) if self._jit else self._forward
        obs_metrics.counter("graph.degraded").inc()
        import warnings
        warnings.warn(
            "CompiledPlan degraded to the xla reference path after repeated "
            "kernel failure — serving continues bit-exact but slower",
            RuntimeWarning, stacklevel=2)

    # ------------------------------------------------------------- dispatch

    def _node_method(self, node: PlanNode) -> str:
        if (self.method == "auto" and node.op == "qconv"
                and not _kernel_layer_ok(node.spec)):
            return "xla"         # auto degrades to the oracle...
        # ...but an explicit "pallas" keeps the node on pallas so qconv_apply
        # raises for out-of-envelope layers instead of silently running xla
        return "pallas" if self.method in ("pallas", "auto") else "xla"

    def _resolve_configs(self, node: PlanNode, xq: QTensor) -> Optional[dict]:
        """Tuned-schedule lookup for one qconv node, keyed on the concrete
        traced shapes; runs once per compile (inside the single trace)."""
        if self._node_method(node) != "pallas":
            return None
        from repro import tune
        n, h, w, c = xq.q.shape
        spec = node.spec
        p = spec.primitive
        dt = _node_dtype(node)
        if p in ("standard", "grouped"):
            g = spec.groups if p == "grouped" else 1
            sigs = {"main": tune.sig_conv2d(n, h, w, c, spec.out_channels,
                                            spec.kernel_size, g)}
        elif p == "dws":
            sigs = {"dw": tune.sig_depthwise2d(n, h, w, c, spec.kernel_size),
                    "pw": tune.sig_conv2d(n, h, w, c, spec.out_channels,
                                          1, 1)}
        elif p == "shift":
            sigs = {"main": tune.sig_shift_conv2d(n, h, w, c,
                                                  spec.out_channels)}
        else:                            # add
            sigs = {"main": tune.sig_add_conv2d(n, h, w, c,
                                                spec.out_channels,
                                                spec.kernel_size)}
        cfg = {stage: tune.get_config(sig, dt) for stage, sig in sigs.items()}
        if self.validate:
            # hard feasibility gate on every resolved schedule: the tune
            # layer prunes its own candidates, but a stale/hand-edited cache
            # entry could still smuggle in an oversized block
            from repro.check import CheckError
            from repro.check.footprint import check_schedule
            bad = []
            for stage, sig in sigs.items():
                verdict = check_schedule(sig, cfg[stage], dt)
                if not verdict.ok:
                    bad.extend(f"{node.name}/{stage} "
                               f"[{sig.kernel}/{sig.key()}]: {e}"
                               for e in verdict.errors)
            if bad:
                raise CheckError(
                    f"infeasible kernel schedule for node {node.name!r} "
                    "(repro.check.check_schedule; pass validate=False to "
                    "bypass):", bad)
        self.node_configs[node.name] = cfg
        return cfg

    # -------------------------------------------------------------- forward

    def _run_node(self, node: PlanNode, h):
        from repro.kernels import ops as K
        if node.op == "qconv":
            m = self._node_method(node)
            if self.method == "auto" and m == "xla":
                # auto degraded to the oracle for this node (outside the
                # pallas kernel envelope) — count it so coverage regressions
                # of the kernel layer are visible in the metrics snapshot
                obs_metrics.counter("graph.fallback.xla").inc()
            return qconv_apply(node.qparams, h, node.spec, node.out_fb,
                               method=m, act=node.act,
                               configs=self._resolve_configs(node, h))
        if node.op == "qbn":
            return _qbn_apply(node.qparams, h, node.out_fb, node.act)
        if node.op == "maxpool":
            q = K.maxpool2d(h.q, window=node.attrs["window"],
                            stride=node.attrs["stride"],
                            method=self._node_method(node))
            return QTensor(q, h.frac_bits)
        if node.op == "gap":             # head boundary: int8 -> float
            return jnp.mean(h.dequantize(), axis=(1, 2))
        if node.op == "dense":
            return h @ node.qparams["w"]
        raise ValueError(node.op)

    def _forward(self, x):
        self.traces += 1                 # counts jit traces, not calls
        # compile-event counters (trace-time python side effects): one total
        # plus one per batch bucket, so recompile storms show up per shape
        obs_metrics.counter("graph.compiles").inc()
        obs_metrics.counter(f"graph.compiles.n{x.shape[0]}").inc()
        with obs_trace.span("plan.trace", n=x.shape[0], method=self.method):
            h = quantize(x, self.plan.in_fb)
            for node in self.plan.nodes:
                h = self._run_node(node, h)
            return h

    def __call__(self, x):
        with obs_trace.span("plan.forward", n=x.shape[0]):
            return self._fn(x)

    # ------------------------------------------------------ batched serving

    @staticmethod
    def batch_bucket(n: int) -> int:
        """Smallest power of two >= n: the batch sizes forward_batch
        actually compiles for, so arbitrary request counts cost at most
        O(log max_batch) traces."""
        b = 1
        while b < n:
            b *= 2
        return b

    def forward_batch(self, x):
        """Throughput entry point: one batched forward over a leading batch
        dim, zero-padded up to the pow2 batch bucket and cropped back, so
        the ONE plan jit is reused across ragged microbatches instead of
        retracing per batch size. The int8 trunk is bit-exact with the
        per-sample loop — every plan op is row-independent and the batched
        kernel grids accumulate each image's taps in the per-image order —
        while the float gap->dense head agrees only to ~1e-6 (and exactly
        by argmax): XLA picks batch-size-dependent float matmul kernels, so
        don't hash or exact-compare the logits across batch sizes."""
        n = x.shape[0]
        b = self.batch_bucket(n)
        with obs_trace.span("plan.forward_batch", n=n, bucket=b):
            if b != n:
                x = jnp.concatenate(
                    [x, jnp.zeros((b - n,) + x.shape[1:], x.dtype)])
            return self._fn(x)[:n]

    def throughput(self, x, *, reps: int = 5, warmup: int = 2) -> dict:
        """Measured images/s of the batched path at ``x``'s batch size
        (post-warmup, median-of-reps — the §Throughput headline number)."""
        from repro.tune.runner import time_config
        us = time_config(self.forward_batch, x, reps=reps, warmup=warmup)
        n = x.shape[0]
        return {"batch": n, "bucket": self.batch_bucket(n),
                "us_per_batch": us, "us_per_image": us / n,
                "images_per_s": 1e6 * n / us}

    # ------------------------------------------------- per-layer attribution

    def profile(self, x, *, f_mhz: float = 84.0, reps: int = 3,
                mode: str = "latency") -> List[dict]:
        """Instrumented execution: one row per plan node with measured
        latency (node jitted standalone), analytic MACs, and the
        paper-calibrated MCU latency/energy model (scalar vs SIMD) for the
        conv nodes — the paper's per-layer Table-2 reading.

        ``mode="throughput"`` reads the same rows as a traffic-serving
        profile: each row additionally carries the node's delivered
        ``images_per_s`` and amortized ``us_per_image`` at ``x``'s batch
        size (per-layer cost is a per-batch quantity under the tiled
        batched schedules, so profile at the batch you serve)."""
        if mode not in ("latency", "throughput"):
            raise ValueError(f"unknown profile mode {mode!r}; expected "
                             "'latency' or 'throughput'")
        from repro.tune.runner import time_config
        mcu = MCUModel()
        rows: List[dict] = []
        batch = x.shape[0]
        h = quantize(x, self.plan.in_fb)
        for node in self.plan.nodes:
            fn = jax.jit(lambda v, _n=node: self._run_node(_n, v))
            # per-layer span aligned with this row: one "layer.<name>" slice
            # per profile row, carrying the measured us as a span attribute
            with obs_trace.span(f"layer.{node.name}", cat="graph.profile",
                                op=node.op, batch=batch) as sp:
                us = time_config(fn, h, reps=reps, warmup=1)
                sp.set(us=us)
            row = dict(name=node.name, op=node.op, us=us, macs=0,
                       primitive=node.spec.primitive if node.spec else None)
            if node.op == "qconv":
                width = node.attrs["in_hw"][1]
                row["macs"] = node.spec.mac_count(width)
                row["mcu_lat_scalar_ms"] = 1e3 * mcu.latency_s(
                    node.spec, width, simd=False, f_mhz=f_mhz)
                row["mcu_lat_simd_ms"] = 1e3 * mcu.latency_s(
                    node.spec, width, simd=True, f_mhz=f_mhz)
                row["mcu_e_scalar_mj"] = mcu.energy_mj(
                    node.spec, width, simd=False, f_mhz=f_mhz)
                row["mcu_e_simd_mj"] = mcu.energy_mj(
                    node.spec, width, simd=True, f_mhz=f_mhz)
            if mode == "throughput":
                row["us_per_image"] = us / batch
                row["images_per_s"] = 1e6 * batch / us if us > 0 else 0.0
            h = fn(h)
            rows.append(row)
        return rows


# ---------------------------------------------------------------- references

def float_forward(graph: Graph, params: dict, x: jax.Array) -> jax.Array:
    """Float inference over the IR (BN inference buffers, no stat
    re-estimation) — the eval path of ``models.convnet.cnn_forward``; one
    walk of ``lower.interpret``, the same interpreter the calibration sweep
    runs."""
    from .lower import interpret
    return interpret(graph, params, x)["acts"][graph.output]


def unfused_forward(plan: Plan, x, *, method: str = "xla"):
    """The pre-graph float-bounce regime, reconstructed from the same plan:
    every layer dequantizes to float for ReLU/BN-act/pool and re-quantizes
    at the node's annotated scale before the next conv. Same integer conv
    arithmetic, same scales — bit-exact with :class:`CompiledPlan` (the
    fused epilogues commute with dequantization), but with the two float
    round-trips per block the fusion pass removes. Baseline side of
    ``benchmarks/layer_bench.py``'s fused-vs-unfused comparison."""
    h = quantize(x, plan.in_fb)
    for node in plan.nodes:
        if node.op == "qconv":
            yq = qconv_apply(node.qparams, h, node.spec, node.out_fb,
                             method=method, act=None)
            y = yq.dequantize()
            if node.act == "relu":
                y = jax.nn.relu(y)
            h = quantize(y, node.out_fb)
        elif node.op == "qbn":
            zq = _qbn_apply(node.qparams, h, node.out_fb, act=None)
            y = zq.dequantize()
            if node.act == "relu":
                y = jax.nn.relu(y)
            h = quantize(y, node.out_fb)
        elif node.op == "maxpool":
            from repro.kernels.ref import maxpool2d_ref
            y = maxpool2d_ref(h.dequantize(), window=node.attrs["window"],
                              stride=node.attrs["stride"])
            h = quantize(y, node.out_fb)
        elif node.op == "gap":
            h = jnp.mean(h.dequantize(), axis=(1, 2))
        elif node.op == "dense":
            h = h @ node.qparams["w"]
    return h
