"""Lowering passes: float graph + params + calibration data -> integer Plan.

Three passes, replacing the old ``calibrate_bn`` + ``quantize_cnn`` double
sweep with ONE pass over the calibration data:

1. **annotate** — run the calibration batch through the float graph once,
   recording every node's activation. BN statistics are read off the conv
   outputs *during the same sweep* (the old pipeline ran the data once in
   ``calibrate_bn`` and then a second time inside ``quantize_cnn`` to pick
   scales; the activations are identical, so one sweep suffices — pinned by
   tests/test_graph.py).
2. **quantize** — per conv block: BN-fold the foldable primitives
   (``core/folding.fold``), per-tensor power-of-two PTQ
   (``core/quantize``), output frac bits from the post-BN+ReLU calibration
   activation (paper Eq. 4). Add-conv cannot fold (|W-x| is not linear in
   W), so its BN is lowered to an INTEGER per-channel affine (``qbn`` node:
   int16 multiplier + accumulator-scale bias + Algorithm-1 shift) instead
   of the old dequantize->float-BN bounce.
3. **fuse** — chain each layer's requantization into its consumer: ReLU
   becomes the producer kernel's ``act="relu"`` epilogue (applied at
   accumulator scale — bit-exact with float relu after dequantization),
   max-pool becomes an int8 ``maxpool`` node at the producer's scale, and
   every consumer reads its input at the producer's annotated frac bits.
   Activations therefore stay int8 from the first conv to the global
   average pool: zero float round-trips between conv layers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import apply, batchnorm_apply, fold
from repro.core.folding import FOLDABLE
from repro.core.primitives import ConvSpec
from repro.core.qconv import quantize_conv_params
from repro.core.quantize import frac_bits_for

from .ir import Graph, Node, params_for

PLAN_OPS = ("qconv", "qbn", "maxpool", "gap", "dense")


@dataclasses.dataclass
class PlanNode:
    """One executable step of the lowered plan.

    ``qparams`` holds the node's quantized parameters (QTensor leaves for
    qconv, int32 multiplier/bias for qbn, the float head for dense).
    ``in_fb``/``out_fb`` are the annotated power-of-two scales; the implied
    requantization shift is chained into the kernel epilogue by the
    executor. ``act`` is the fused activation ("relu" or None).
    """

    name: str
    op: str
    spec: Optional[ConvSpec] = None
    qparams: Optional[dict] = None
    in_fb: Optional[int] = None
    out_fb: Optional[int] = None
    act: Optional[str] = None
    attrs: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.op not in PLAN_OPS:
            raise ValueError(f"unknown plan op {self.op!r}; known: {PLAN_OPS}")


@dataclasses.dataclass
class Plan:
    """Topologically-ordered integer execution plan for one model."""

    nodes: Tuple[PlanNode, ...]
    in_fb: int                      # input quantization frac bits
    graph: Graph

    def conv_nodes(self) -> Tuple[PlanNode, ...]:
        return tuple(n for n in self.nodes if n.op == "qconv")


# -------------------------------------------- float interpreter + annotate --

def interpret(graph: Graph, params: dict, x: jax.Array, *,
              calibrate: bool = False) -> dict:
    """THE float interpreter over the IR — the single graph walk behind
    float inference (``executor.float_forward``), deployment-time BN
    re-estimation (``models.convnet.calibrate_bn``) and the lowering
    calibration sweep (:func:`annotate`). ``calibrate=True`` overwrites each
    BN node's buffers with the activation mean/var of its producing conv
    (recorded in the returned ``"bn"`` dict) before normalizing."""
    node_params = params_for(graph, params)
    acts: Dict[str, jax.Array] = {graph.input: x}
    bn_calib: Dict[str, dict] = {}
    for n in graph.nodes:
        h = acts[n.inputs[0]]
        if n.op == "conv":
            acts[n.name] = apply(node_params[n.name], h, n.spec)
        elif n.op == "bn":
            bn = node_params[n.name]
            if calibrate:
                bn = dict(bn,
                          mean=jnp.mean(h, axis=(0, 1, 2)).astype(jnp.float32),
                          var=jnp.var(h, axis=(0, 1, 2)).astype(jnp.float32))
                bn_calib[n.name] = bn
            acts[n.name] = batchnorm_apply(bn, h)
        elif n.op == "relu":
            acts[n.name] = jax.nn.relu(h)
        elif n.op == "pool":
            from repro.kernels.ref import maxpool2d_ref
            acts[n.name] = maxpool2d_ref(h, window=n.attr("window", 2),
                                         stride=n.attr("stride", 2))
        elif n.op == "gap":
            acts[n.name] = jnp.mean(h, axis=(1, 2))
        elif n.op == "dense":
            acts[n.name] = h @ node_params[n.name]["w"]
    return {"acts": acts, "bn": bn_calib, "params": node_params}


def annotate(graph: Graph, params: dict, calib_x: jax.Array) -> dict:
    """One calibration sweep: every node's float activation + calibrated BN
    buffers (activation mean/var of the producing conv, as deployment-time
    BN re-estimation does)."""
    return interpret(graph, params, calib_x, calibrate=True)


# ----------------------------------------------- pass 2+3: quantize + fuse --

def _quantize_bn_affine(bn: dict, in_fb: int, eps: float = 1e-5) -> dict:
    """Integer lowering of an (unfoldable) BN: y = a*x + b as a per-channel
    multiplier at a power-of-two scale plus a bias at the accumulator scale
    — NNoM-style integer BN, no float bounce. The multiplier gets a
    15-frac-bit budget (magnitude ≤ 2^15, held in int32 — one past int16 on
    exact-pow2 maxima), keeping its quantization error two orders below the
    int8 activation LSB."""
    a = bn["gamma"] * (bn["var"] + eps) ** -0.5
    b = bn["beta"] - bn["mean"] * a
    m = float(jnp.max(jnp.abs(a)))
    fb_a = 15 - math.ceil(math.log2(m)) if m > 0 else 15
    # keep the accumulator (int8 act * mult + bias) inside int32: cap the
    # accumulator scale at 24 frac bits AND low enough that the largest
    # |b| * 2^acc_fb stays under 2^30 — a large BN offset would otherwise
    # wrap silently on the astype(int32)
    mb = float(jnp.max(jnp.abs(b)))
    cap = 24 if mb <= 0 else min(24, 30 - math.ceil(math.log2(mb)))
    fb_a = max(0, min(fb_a, cap - in_fb))
    acc_fb = in_fb + fb_a
    return {
        "a": jnp.round(a * 2.0 ** fb_a).astype(jnp.int32),
        "b": jnp.round(b * 2.0 ** acc_fb).astype(jnp.int32),
        "a_frac_bits": fb_a,
    }


def lower(graph: Graph, params: dict, calib_x: jax.Array, *,
          weight_bits: int = 8, group_size: int = 32) -> Plan:
    """Lower a float graph to an integer-only Plan (single calibration
    sweep; see module docstring for the pass structure).

    ``weight_bits=4`` lowers every conv/dws/shift/add weight tensor to
    nibble-packed W4 with per-group scales (``group_size`` elements per
    scale group along the unpack axis) — the executor then dispatches the
    packed kernel paths (W4A8); activations and the whole scale-chaining
    arithmetic are unchanged (int8 end to end)."""
    ann = annotate(graph, params, calib_x)
    acts, bn_calib, node_params = ann["acts"], ann["bn"], ann["params"]
    in_fb = frac_bits_for(calib_x)

    # producer scale chaining: value name -> frac bits of its int8 encoding
    fb: Dict[str, int] = {graph.input: in_fb}
    plan_nodes = []
    consumed = set()                   # bn/relu nodes fused into a producer

    for n in graph.nodes:
        if n.name in consumed:
            continue
        src = n.inputs[0]
        if n.op == "conv":
            spec = n.spec
            conv_p = node_params[n.name]
            # fuse the conv -> bn -> relu chain of this block
            bnode = next((c for c in graph.consumers(n.name) if c.op == "bn"),
                         None)
            rnode = None
            if bnode is not None:
                rnode = next((c for c in graph.consumers(bnode.name)
                              if c.op == "relu"), None)
            tail = rnode or bnode or n           # last fused float node
            out_fb = frac_bits_for(acts[tail.name])
            h_in, w_in = acts[src].shape[1], acts[src].shape[2]
            if bnode is not None and spec.primitive in FOLDABLE:
                qp = quantize_conv_params(
                    fold(conv_p, bn_calib[bnode.name], spec), spec,
                    bits=weight_bits, group_size=group_size)
                plan_nodes.append(PlanNode(
                    n.name, "qconv", spec=spec, qparams=qp, in_fb=fb[src],
                    out_fb=out_fb, act="relu" if rnode is not None else None,
                    attrs={"in_hw": (h_in, w_in)}))
                consumed.update(c.name for c in (bnode, rnode) if c)
                fb[tail.name] = out_fb
            elif bnode is not None:              # add-conv: integer BN node
                conv_fb = frac_bits_for(acts[n.name])
                qp = quantize_conv_params(conv_p, spec,
                                          bits=weight_bits,
                                          group_size=group_size)
                plan_nodes.append(PlanNode(
                    n.name, "qconv", spec=spec, qparams=qp, in_fb=fb[src],
                    out_fb=conv_fb, act=None, attrs={"in_hw": (h_in, w_in)}))
                fb[n.name] = conv_fb
                plan_nodes.append(PlanNode(
                    bnode.name, "qbn",
                    qparams=_quantize_bn_affine(bn_calib[bnode.name], conv_fb),
                    in_fb=conv_fb, out_fb=out_fb,
                    act="relu" if rnode is not None else None))
                consumed.update(c.name for c in (bnode, rnode) if c)
                fb[tail.name] = out_fb
            else:                                # bare conv (no BN in graph)
                qp = quantize_conv_params(conv_p, spec,
                                          bits=weight_bits,
                                          group_size=group_size)
                plan_nodes.append(PlanNode(
                    n.name, "qconv", spec=spec, qparams=qp, in_fb=fb[src],
                    out_fb=out_fb, act=None, attrs={"in_hw": (h_in, w_in)}))
                fb[n.name] = out_fb
        elif n.op == "pool":
            # int8 max-pool at the producer's scale (max commutes with the
            # positive pow2 dequantization, so this is exact)
            plan_nodes.append(PlanNode(
                n.name, "maxpool", in_fb=fb[src], out_fb=fb[src],
                attrs={"window": n.attr("window", 2),
                       "stride": n.attr("stride", 2),
                       "in_hw": (acts[src].shape[1], acts[src].shape[2]),
                       "in_ch": acts[src].shape[3]}))
            fb[n.name] = fb[src]
        elif n.op == "gap":
            plan_nodes.append(PlanNode(n.name, "gap", in_fb=fb[src]))
        elif n.op == "dense":
            plan_nodes.append(PlanNode(
                n.name, "dense", qparams={"w": node_params[n.name]["w"]}))
        elif n.op in ("bn", "relu"):
            raise ValueError(f"dangling {n.op} node {n.name!r}: lowering "
                             "only fuses bn/relu chained behind a conv")
    return Plan(tuple(plan_nodes), in_fb, graph)
