"""Layer-graph IR: the network as data, built from ``CNNConfig``.

A :class:`Graph` is a topologically-ordered tuple of :class:`Node`\\ s over
named values; each node names its op, its input values, and (for conv
nodes) its :class:`~repro.core.primitives.ConvSpec`. The IR is deliberately
small — exactly the ops the paper's NNoM deployments chain: the five
convolution primitives (one ``conv`` op, primitive selected by the spec),
BN, ReLU, max-pool, global average pool, and the dense head.

The IR stage is *structural only*: no parameters, no scales. Lowering
(``graph/lower.py``) pairs it with trained parameters + calibration data to
produce an executable integer :class:`~repro.graph.lower.Plan`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.primitives import ConvSpec

OPS = ("conv", "bn", "relu", "pool", "gap", "dense")


@dataclasses.dataclass(frozen=True)
class Node:
    """One layer: ``op`` over ``inputs`` producing the value named ``name``."""

    name: str
    op: str
    inputs: Tuple[str, ...]
    spec: Optional[ConvSpec] = None     # conv nodes only
    attrs: tuple = ()                   # static kwargs, e.g. pool window

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"unknown graph op {self.op!r}; known: {OPS}")
        if self.op == "conv" and self.spec is None:
            raise ValueError(f"conv node {self.name!r} needs a ConvSpec")

    def attr(self, key, default=None):
        return dict(self.attrs).get(key, default)


@dataclasses.dataclass(frozen=True)
class Graph:
    """Topologically-ordered layer graph; ``input`` names the graph input."""

    nodes: Tuple[Node, ...]
    input: str = "x"

    def __post_init__(self):
        seen = {self.input}
        for n in self.nodes:
            for i in n.inputs:
                if i not in seen:
                    raise ValueError(f"node {n.name!r} consumes {i!r} before "
                                     "it is produced (not topological?)")
            seen.add(n.name)

    def node(self, name: str) -> Node:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def consumers(self, name: str) -> Tuple[Node, ...]:
        return tuple(n for n in self.nodes if name in n.inputs)

    @property
    def output(self) -> str:
        return self.nodes[-1].name


def build_cnn_graph(cfg) -> Graph:
    """The paper-side CNN as a graph: per block conv -> bn -> relu -> pool,
    then gap -> dense. ``cfg`` is a ``models.convnet.CNNConfig``; the
    per-block specs replicate its primitive-selection rules exactly (the
    grouped/dws/shift stem fallbacks), so graph execution and the legacy
    loop agree layer for layer."""
    from repro.models.convnet import _specs   # single source of spec rules
    nodes = []
    prev = "x"
    for i, spec in enumerate(_specs(cfg)):
        nodes.append(Node(f"conv{i}", "conv", (prev,), spec=spec))
        nodes.append(Node(f"bn{i}", "bn", (f"conv{i}",)))
        nodes.append(Node(f"relu{i}", "relu", (f"bn{i}",)))
        nodes.append(Node(f"pool{i}", "pool", (f"relu{i}",),
                          attrs=(("window", 2), ("stride", 2))))
        prev = f"pool{i}"
    nodes.append(Node("gap", "gap", (prev,)))
    nodes.append(Node("head", "dense", ("gap",),
                      attrs=(("features", cfg.num_classes),)))
    return Graph(tuple(nodes))


def params_for(graph: Graph, params: dict) -> Dict[str, dict]:
    """Map graph node names to the CNN parameter pytree's leaves: conv{i} /
    bn{i} index ``params["blocks"]``, the dense head takes ``params["head"]``.
    """
    out: Dict[str, dict] = {}
    for n in graph.nodes:
        if n.op in ("conv", "bn"):
            idx = int(n.name[len(n.op):])
            blk = params["blocks"][idx]
            out[n.name] = blk["conv"] if n.op == "conv" else blk["bn"]
        elif n.op == "dense":
            out[n.name] = {"w": params["head"]}
    return out
