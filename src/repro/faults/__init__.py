"""repro.faults — deterministic, seeded fault injection plus the chaos
harness that drives the serve engines through seeded fault schedules.

``inject`` is the zero-dependency core (stdlib only, importable from the
kernel dispatch layer without cycles): named fault *sites* at the hot
seams, a seeded :class:`FaultPlan` of ``{site, kind, nth/probability}``
entries activated via context manager or the ``REPRO_FAULTS`` env var,
and a :func:`check` entry point that is a single global read when no plan
is active. ``chaos`` (imported lazily — it pulls in the serve stack)
runs paired fault-free/faulted workloads and checks the invariants that
define correctness under failure (EXPERIMENTS.md §Resilience).
"""
from .inject import (CORRUPT_SITES, KINDS, SITES, FaultPlan, FaultSpec,
                     Fired, InjectedFault, active_plan, check, deactivate,
                     install, install_from_env, parse_env)

__all__ = [
    "CORRUPT_SITES", "KINDS", "SITES", "FaultPlan", "FaultSpec", "Fired",
    "InjectedFault", "active_plan", "check", "deactivate", "install",
    "install_from_env", "parse_env",
]
