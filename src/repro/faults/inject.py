"""Deterministic fault injection core.

Fault *sites* are named seams in the hot paths — the instrumented code
calls :func:`check(site)` at each seam. With no plan active that call is
one module-global read and a ``None`` compare (the same near-zero-cost
discipline as ``repro.obs.trace``'s ``_NullSpan``), so the seams ride in
production paths permanently. With a plan active, the per-site hit
counter advances and any matching :class:`FaultSpec` fires:

* ``kind="raise"``  — raises :class:`InjectedFault` out of the seam (the
  hardened caller must absorb it: retry, retire, degrade).
* ``kind="delay"``  — sleeps ``delay_s`` inside the seam (simulates a
  stuck round; per-request deadlines catch it at the next boundary).
* ``kind="corrupt"`` — returns a :class:`Fired` directive whose
  :meth:`Fired.apply` deterministically corrupts a host array (poisoned
  logits — silent data corruption the engine *cannot* detect, only
  contain). Allowed only at sites whose consumers hold host values
  (``CORRUPT_SITES``); raising/stalling sites inside jit traces cannot
  corrupt traced arrays.

Firing is fully deterministic: ``nth`` entries fire on hits
``[nth, nth + times)`` of their site (a *consecutive* window, sized to
defeat — or be absorbed by — bounded retries, which re-hit the seam);
``probability`` entries draw from the plan's own seeded ``random.Random``
in hit order, so the same plan over the same workload fires identically
every run. Every fire is appended to ``FaultPlan.log`` and counted into
the process metrics registry as ``faults.fired.<site>``.

Activation: ``with FaultPlan([...], seed=7): ...`` (nestable; restores
the previous plan on exit), :func:`install` / :func:`deactivate` for
non-scoped use, or the ``REPRO_FAULTS`` environment variable parsed at
import — ``;``-separated entries of ``site:kind[:k=v...]`` plus an
optional ``seed=N`` entry, e.g.::

    REPRO_FAULTS="engine.decode_round:raise:nth=2:times=1;seed=7"
    REPRO_FAULTS="kernels.dispatch:raise:p=0.05:times=3"

Recognized per-entry keys: ``nth`` (1-indexed hit), ``p`` (per-hit
probability), ``times`` (window length / max fires, default 1),
``delay`` (seconds, delay kind).
"""
from __future__ import annotations

import dataclasses
import os
import random
import threading
import time
import zlib
from typing import Dict, List, Optional

from repro.obs import metrics as _obs_metrics

ENV_VAR = "REPRO_FAULTS"

#: The registered fault sites — the hot seams of the serving stack. A
#: FaultSpec naming any other site is a construction-time ValueError, so
#: schedules can't silently rot when a seam is renamed.
SITES = frozenset({
    "kernels.dispatch",      # repro.kernels.ops pallas dispatch (per trace)
    "engine.prefill",        # LM Engine admission prefill (per attempt)
    "engine.decode_round",   # LM Engine decode round (per attempt)
    "blockpool.alloc",       # paged-KV BlockPool.alloc (per call)
    "tune.cache_load",       # persistent tune-cache load (per file read)
    "cnn.batch_round",       # CNNEngine batch round (per attempt)
})

KINDS = ("raise", "delay", "corrupt")

#: Sites whose instrumented consumer holds a *host* value a corrupt
#: directive can be applied to. The jit-interior seams are excluded — a
#: traced array cannot be deterministically corrupted from the host side.
CORRUPT_SITES = frozenset({
    "engine.prefill", "engine.decode_round", "cnn.batch_round",
})


class InjectedFault(RuntimeError):
    """The exception an active ``kind="raise"`` fault throws at its seam."""


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault: fire ``kind`` at ``site`` on the ``nth`` hit
    (for ``times`` consecutive hits), or with ``probability`` per hit (up
    to ``times`` total fires)."""
    site: str
    kind: str
    nth: Optional[int] = None
    probability: Optional[float] = None
    times: int = 1
    delay_s: float = 0.05

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"registered sites: {sorted(SITES)}")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"choose from {KINDS}")
        if self.kind == "corrupt" and self.site not in CORRUPT_SITES:
            raise ValueError(
                f"kind='corrupt' is not applicable at site {self.site!r} "
                f"(no host value to corrupt); allowed: "
                f"{sorted(CORRUPT_SITES)}")
        if (self.nth is None) == (self.probability is None):
            if self.nth is None:
                self.nth = 1            # default: fire on the first hit
            else:
                raise ValueError("give exactly one of nth= or probability=")
        if self.nth is not None and self.nth < 1:
            raise ValueError(f"nth must be >= 1 (1-indexed), got {self.nth}")
        if self.probability is not None \
                and not 0.0 < self.probability <= 1.0:
            raise ValueError(f"probability must be in (0, 1], "
                             f"got {self.probability}")
        if self.times < 1:
            raise ValueError(f"times must be >= 1, got {self.times}")
        if self.delay_s < 0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")


@dataclasses.dataclass
class Fired:
    """One fired fault (also the corrupt directive handed to the seam's
    caller). ``apply`` is deterministic in (plan seed, site, hit)."""
    site: str
    kind: str
    hit: int                    # the site hit index (1-based) that fired
    seed: int

    def apply(self, arr):
        """Deterministically corrupt a host array: overwrite a few seeded
        positions with out-of-band large values (moves float argmaxes, so
        poisoned logits visibly derail a greedy stream)."""
        import numpy as np
        a = np.array(arr, copy=True)
        if a.size == 0:
            return a
        rng = np.random.default_rng(
            [self.seed & 0x7FFFFFFF, self.hit,
             zlib.crc32(self.site.encode())])
        flat = a.reshape(-1)
        k = min(8, flat.size)
        idx = rng.choice(flat.size, size=k, replace=False)
        if np.issubdtype(a.dtype, np.floating):
            flat[idx] = float(flat.max()) + 1e3 + rng.standard_normal(k)
        elif np.issubdtype(a.dtype, np.integer):
            flat[idx] = np.iinfo(a.dtype).max
        return a


class FaultPlan:
    """A seeded, deterministic schedule of :class:`FaultSpec` entries.

    Context manager (nestable — restores the previously active plan), or
    install process-wide via :func:`install`. One plan instance carries
    its own per-site hit counters and rng; reuse across runs accumulates
    hits, so paired baseline/faulted comparisons should construct a fresh
    plan (or call :meth:`reset`) per run.
    """

    def __init__(self, specs: List[FaultSpec], seed: int = 0):
        self.specs = list(specs)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._by_site: Dict[str, List[FaultSpec]] = {}
        for s in self.specs:
            self._by_site.setdefault(s.site, []).append(s)
        self.reset()

    def reset(self):
        """Zero the hit counters, fire counts, rng, and log."""
        with getattr(self, "_lock", threading.Lock()):
            self._hits: Dict[str, int] = {}
            self._fires: Dict[int, int] = {id(s): 0 for s in self.specs}
            self._rng = random.Random(self.seed)
            self.log: List[Fired] = []

    # ------------------------------------------------------------ firing --

    def hit(self, site: str) -> Optional[Fired]:
        """Advance ``site``'s hit counter; raise/sleep/return-directive per
        the first matching spec. Returns None when nothing fires."""
        with self._lock:
            h = self._hits.get(site, 0) + 1
            self._hits[site] = h
            fired: Optional[Fired] = None
            spec: Optional[FaultSpec] = None
            for s in self._by_site.get(site, ()):
                if self._fires[id(s)] >= s.times:
                    continue
                if s.nth is not None:
                    fire = s.nth <= h < s.nth + s.times
                else:
                    fire = self._rng.random() < s.probability
                if fire:
                    self._fires[id(s)] += 1
                    fired = Fired(site=site, kind=s.kind, hit=h,
                                  seed=self.seed)
                    spec = s
                    self.log.append(fired)
                    break
        if fired is None:
            return None
        _obs_metrics.counter(f"faults.fired.{site}").inc()
        if fired.kind == "raise":
            raise InjectedFault(
                f"injected fault at {site} (hit {fired.hit})")
        if fired.kind == "delay":
            time.sleep(spec.delay_s)
            return None
        return fired                    # corrupt: the caller applies it

    # -------------------------------------------------------- activation --

    def __enter__(self) -> "FaultPlan":
        global _ACTIVE
        self._prev = _ACTIVE
        _ACTIVE = self
        return self

    def __exit__(self, *exc):
        global _ACTIVE
        _ACTIVE = self._prev
        return False


# The active plan. None -> every check() is a global read + None compare.
_ACTIVE: Optional[FaultPlan] = None


def check(site: str) -> Optional[Fired]:
    """THE seam entry point. No-op (None) when no plan is active; else may
    raise :class:`InjectedFault`, sleep, or return a corrupt directive."""
    plan = _ACTIVE
    if plan is None:
        return None
    return plan.hit(site)


def active_plan() -> Optional[FaultPlan]:
    return _ACTIVE


def install(plan: Optional[FaultPlan]):
    """Activate ``plan`` process-wide (None deactivates)."""
    global _ACTIVE
    _ACTIVE = plan


def deactivate():
    install(None)


# ------------------------------------------------------------- env parsing

def parse_env(s: str) -> FaultPlan:
    """``REPRO_FAULTS`` grammar -> FaultPlan (see module docstring)."""
    specs: List[FaultSpec] = []
    seed = 0
    for part in s.split(";"):
        part = part.strip()
        if not part:
            continue
        if part.startswith("seed="):
            seed = int(part[len("seed="):])
            continue
        fields = part.split(":")
        if len(fields) < 2:
            raise ValueError(
                f"REPRO_FAULTS entry {part!r}: expected site:kind[:k=v...]")
        kw: dict = {}
        for f in fields[2:]:
            k, sep, v = f.partition("=")
            if not sep:
                raise ValueError(f"REPRO_FAULTS entry {part!r}: "
                                 f"malformed field {f!r} (expected k=v)")
            if k == "nth":
                kw["nth"] = int(v)
            elif k == "p":
                kw["probability"] = float(v)
            elif k == "times":
                kw["times"] = int(v)
            elif k == "delay":
                kw["delay_s"] = float(v)
            else:
                raise ValueError(f"REPRO_FAULTS entry {part!r}: unknown "
                                 f"field {k!r} (nth/p/times/delay)")
        specs.append(FaultSpec(site=fields[0], kind=fields[1], **kw))
    return FaultPlan(specs, seed=seed)


def install_from_env(force: bool = False):
    """Install a plan from ``REPRO_FAULTS`` if set (import-time hook).
    ``force=True`` re-reads the env even when a plan is already active."""
    if _ACTIVE is not None and not force:
        return
    val = os.environ.get(ENV_VAR, "")
    if val:
        install(parse_env(val))


install_from_env()
