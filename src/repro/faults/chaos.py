"""Chaos harness: paired fault-free / faulted runs + invariant checks.

The fault-injection counterpart of the bench suite's exactness flags: a
fault schedule is only useful if the *hardened* engine provably keeps its
promises under it. This module runs the same workload twice on fresh
engines — once clean, once under a :class:`~repro.faults.inject.FaultPlan`
— and checks the degradation contract (EXPERIMENTS.md §Resilience):

1. **Terminal statuses** — every submitted request ends ``done`` with a
   terminal ``status`` in {ok, timeout, error, shed}; nothing hangs and no
   injected fault escapes ``run_until_drained`` as an exception.
2. **Survivor bit-identity** — requests the faulted run completed with
   ``status="ok"`` whose uid is NOT in ``engine.poisoned_uids`` must carry
   byte-for-byte the stream the clean run produced (greedy decode is
   batch-composition-independent, so retiring a poisoned neighbour must
   not perturb survivors).
3. **Pool conservation** — after a paged drain, ``BlockPool.audit``
   (free + allocated == usable, non-negative refcounts, no leaked pages)
   returns no violations.
4. **Balanced spans** — every ``obs.trace`` B event emitted during the
   faulted run has its E: the error paths unwind through the same span
   context managers as the happy path.

Import discipline: ``repro.faults.__init__`` must NOT import this module
(it pulls in the serving stack, which itself imports ``faults.inject`` at
its seams). Use it as ``from repro.faults import chaos``.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, List, Sequence

import numpy as np

from repro.faults import inject
from repro.obs import trace as obs_trace

TERMINAL = ("ok", "timeout", "error", "shed")


@dataclasses.dataclass
class ChaosReport:
    """Outcome of one paired run. ``violations`` empty == every invariant
    held; each entry is one human-readable broken invariant (full-list
    style, same as ``repro.check``)."""
    violations: List[str]
    statuses: Dict[int, str]            # uid -> terminal status (faulted)
    survivors: List[int]                # uids compared bit-identically
    poisoned: set                       # uids a corrupt fault touched
    fired: int                          # faults the plan actually fired
    pool_violations: List[str]          # BlockPool.audit output (LM paged)
    stats: dict                         # faulted engine's stats snapshot

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> str:
        by = collections.Counter(self.statuses.values())
        head = (f"fired={self.fired} statuses="
                + ",".join(f"{k}:{v}" for k, v in sorted(by.items()))
                + f" survivors={len(self.survivors)}"
                  f" poisoned={len(self.poisoned)}")
        if self.ok:
            return head + " [all invariants held]"
        return head + "\n" + "\n".join(f"  - {v}" for v in self.violations)


def _submit_all(engine, reqs) -> List:
    """Submit tolerating shed rejections; returns every request (shed-
    rejected ones carry no terminal status — they never entered)."""
    from repro.serve.engine import QueueFullError
    entered = []
    for r in reqs:
        try:
            engine.submit(r)
        except QueueFullError:
            r.status = "shed"
            r.done = True
            continue
        entered.append(r)
    return entered


def _check_terminal(reqs, violations: List[str]):
    for r in reqs:
        if not r.done:
            violations.append(f"request {r.uid} not done after drain")
        if r.status not in TERMINAL:
            violations.append(f"request {r.uid} has non-terminal "
                              f"status {r.status!r}")


def _check_spans(events: Sequence[dict], violations: List[str]):
    open_spans = collections.Counter()
    for ev in events:
        if ev["ph"] == "B":
            open_spans[ev["name"]] += 1
        elif ev["ph"] == "E":
            open_spans[ev["name"]] -= 1
    for name, n in sorted(open_spans.items()):
        if n:
            violations.append(
                f"unbalanced span {name!r}: {n:+d} (an error path returned "
                "without unwinding its trace context manager)")


def _capture_spans(fn):
    """Run ``fn()`` with the process tracer force-enabled; returns
    (fn result, the events emitted during the call)."""
    tr = obs_trace.TRACER
    was = tr.enabled
    before = len(tr.events())
    tr.enable()
    try:
        out = fn()
    finally:
        if not was:
            tr.disable()
    return out, tr.events()[before:]


def _drain_faulted(engine, reqs, fault_plan: inject.FaultPlan):
    fault_plan.reset()
    with fault_plan:
        entered = _submit_all(engine, reqs)
        done = engine.run_until_drained()
    return entered, done


# ---------------------------------------------------------------------- LM


def run_lm_chaos(make_engine: Callable[[], object],
                 make_requests: Callable[[], List[object]],
                 fault_plan: inject.FaultPlan,
                 *, check_spans: bool = True,
                 expect_fired: bool = True) -> ChaosReport:
    """Paired LM run: ``make_engine``/``make_requests`` must build a fresh
    engine / identical request list per call (requests are consumed).
    The workload should be greedy — survivor bit-identity leans on greedy
    streams being independent of batch composition."""
    # clean reference: same engine config, no plan active
    base_eng = make_engine()
    base_reqs = make_requests()
    prev = inject.active_plan()
    inject.deactivate()
    try:
        _submit_all(base_eng, base_reqs)
        base_eng.run_until_drained()
    finally:
        inject.install(prev)
    baseline = {r.uid: list(r.out_tokens) for r in base_reqs
                if r.status == "ok"}

    eng = make_engine()
    reqs = make_requests()
    if check_spans:
        _, events = _capture_spans(
            lambda: _drain_faulted(eng, reqs, fault_plan))
    else:
        _drain_faulted(eng, reqs, fault_plan)
        events = []

    violations: List[str] = []
    _check_terminal(reqs, violations)
    if expect_fired and not fault_plan.log:
        violations.append("fault plan never fired — the schedule does not "
                          "intersect this workload's site hits")
    survivors = [r.uid for r in reqs
                 if r.status == "ok" and r.uid not in eng.poisoned_uids]
    for r in reqs:
        if r.uid not in survivors:
            continue
        if r.uid not in baseline:
            violations.append(f"survivor {r.uid} has no clean-run "
                              "reference (baseline did not finish it ok)")
        elif list(r.out_tokens) != baseline[r.uid]:
            violations.append(
                f"survivor {r.uid} diverged from the fault-free stream: "
                f"{baseline[r.uid]} -> {list(r.out_tokens)}")
    pool_violations: List[str] = []
    if getattr(eng, "pool", None) is not None:
        pool_violations = eng.pool.audit(expect_drained=True)
        violations += [f"pool: {v}" for v in pool_violations]
    if check_spans:
        _check_spans(events, violations)
    return ChaosReport(
        violations=violations,
        statuses={r.uid: r.status for r in reqs},
        survivors=survivors,
        poisoned=set(eng.poisoned_uids),
        fired=len(fault_plan.log),
        pool_violations=pool_violations,
        stats=eng.stats,
    )


# --------------------------------------------------------------------- CNN


def run_cnn_chaos(make_engine: Callable[[], object],
                  make_requests: Callable[[], List[object]],
                  fault_plan: inject.FaultPlan,
                  *, check_spans: bool = True,
                  expect_fired: bool = True,
                  logits_exact: bool = True) -> ChaosReport:
    """Paired CNN run. Survivor identity compares logits bitwise by
    default. ``logits_exact=False`` relaxes to tight allclose + identical
    argmax for workloads where plan degradation switches the numeric path
    mid-run (the integer trunk is bit-exact across pallas/xla but the
    float gap->dense head is tolerance-exact; see tests/test_batched.py).
    A plan built with ``method="xla"`` degrades onto the same path and
    stays bitwise."""
    base_eng = make_engine()
    base_reqs = make_requests()
    prev = inject.active_plan()
    inject.deactivate()
    try:
        _submit_all(base_eng, base_reqs)
        base_eng.run_until_drained()
    finally:
        inject.install(prev)
    baseline = {r.uid: np.asarray(r.logits) for r in base_reqs
                if r.status == "ok"}

    eng = make_engine()
    reqs = make_requests()
    if check_spans:
        _, events = _capture_spans(
            lambda: _drain_faulted(eng, reqs, fault_plan))
    else:
        _drain_faulted(eng, reqs, fault_plan)
        events = []

    violations: List[str] = []
    _check_terminal(reqs, violations)
    if expect_fired and not fault_plan.log:
        violations.append("fault plan never fired — the schedule does not "
                          "intersect this workload's site hits")
    survivors = [r.uid for r in reqs
                 if r.status == "ok" and r.uid not in eng.poisoned_uids]
    for r in reqs:
        if r.uid not in survivors:
            continue
        if r.uid not in baseline:
            violations.append(f"survivor {r.uid} has no clean-run "
                              "reference (baseline did not finish it ok)")
            continue
        got, want = np.asarray(r.logits), baseline[r.uid]
        if logits_exact:
            same = np.array_equal(got, want)
        else:
            same = (np.allclose(got, want, rtol=1e-5, atol=1e-6)
                    and np.argmax(got) == np.argmax(want))
        if not same:
            violations.append(f"survivor {r.uid} logits diverged from the "
                              "fault-free run")
    if check_spans:
        _check_spans(events, violations)
    return ChaosReport(
        violations=violations,
        statuses={r.uid: r.status for r in reqs},
        survivors=survivors,
        poisoned=set(eng.poisoned_uids),
        fired=len(fault_plan.log),
        pool_violations=[],
        stats=eng.stats,
    )
