"""Serve a small LM with batched requests through the engine: prefill +
lockstep decode with KV caches, batching multiple queued prompts.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch qwen2-0.5b --requests 8
(the arch config is reduced for CPU; the full config is what the dry-run
lowers for the 256/512-chip meshes)
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.serve import Engine, Request, ServeConfig


def reduce_cfg(cfg):
    kw = dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
              vocab=512)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, num_experts=4, top_k=2,
                                        d_ff=128)
    if cfg.family == "hybrid":
        kw.update(n_layers=8, attn_period=8, attn_offset=4)
    if cfg.family == "encdec":
        kw["n_encoder_layers"] = 2
    return dataclasses.replace(cfg, **kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = reduce_cfg(get_config(args.arch))
    if cfg.family == "encdec":
        raise SystemExit("serve_lm drives decoder-only archs; "
                         "seamless uses examples/translate stub via engine API")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_batch=4, max_len=64))

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(4, 16))
        eng.submit(Request(uid=i, prompt=rng.integers(
            0, cfg.vocab, (plen,)).astype(np.int32),
            max_new_tokens=args.max_new))
    done = eng.run_until_drained()
    dt = time.time() - t0
    for r in done[:4]:
        print(f"req {r.uid}: +{len(r.out_tokens)} tokens "
              f"{r.out_tokens[:8]}...")
    toks = sum(len(r.out_tokens) for r in done)
    print(f"\n{len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s); engine stats: {eng.stats}")


if __name__ == "__main__":
    main()
