"""Serve a small LM through the engine, comparing schedulers.

Continuous batching (the default) prefills each request into a free KV slot
and refills slots between decode rounds; ``--scheduler static`` runs the
legacy drain strategy (batch runs to completion). ``--scheduler both``
runs the same workload through each and prints throughput / occupancy /
TTFT side by side — the §Serving experiment at example scale.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch qwen2-0.5b --requests 8
(the arch config is reduced for CPU; the full config is what the dry-run
lowers for the 256/512-chip meshes)
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.serve import Engine, Request, ServeConfig


def reduce_cfg(cfg):
    kw = dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
              vocab=512)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, num_experts=4, top_k=2,
                                        d_ff=128)
    if cfg.family == "hybrid":
        kw.update(n_layers=8, attn_period=8, attn_offset=4)
    if cfg.family == "encdec":
        kw["n_encoder_layers"] = 2
    return dataclasses.replace(cfg, **kw)


def make_requests(n, vocab, max_new, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(4, 16))
        # skew the decode lengths: every 4th request runs 4x longer — the
        # workload where slot refill visibly beats draining static batches
        reqs.append(Request(uid=i, prompt=rng.integers(
            0, vocab, (plen,)).astype(np.int32),
            max_new_tokens=max_new * 4 if i % 4 == 0 else max_new))
    return reqs


def run_one(scheduler, cfg, params, args):
    eng = Engine(cfg, params, ServeConfig(max_batch=4, max_len=128,
                                          scheduler=scheduler))
    t0 = time.perf_counter()
    for r in make_requests(args.requests, cfg.vocab, args.max_new):
        eng.submit(r)
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    for r in done[:4]:
        print(f"  req {r.uid}: +{len(r.out_tokens)} tokens "
              f"{r.out_tokens[:8]}...")
    toks = sum(len(r.out_tokens) for r in done)
    st = eng.stats
    print(f"  {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s)\n  stats: {st}")
    return dict(tok_s=toks / dt, occupancy=st["occupancy"],
                ttft_ms=st["ttft_avg_s"] * 1e3, rounds=st["decode_steps"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--scheduler", default="both",
                    choices=["continuous", "static", "both"])
    args = ap.parse_args()

    cfg = reduce_cfg(get_config(args.arch))
    if cfg.family == "encdec":
        raise SystemExit("serve_lm drives decoder-only archs; "
                         "seamless uses examples/translate stub via engine API")
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    scheds = (["continuous", "static"] if args.scheduler == "both"
              else [args.scheduler])
    results = {}
    for sched in scheds:
        print(f"\n--- scheduler={sched} ---")
        results[sched] = run_one(sched, cfg, params, args)
    if len(results) == 2:
        c, s = results["continuous"], results["static"]
        print(f"\ncontinuous vs static drain: "
              f"{c['tok_s']:.1f} vs {s['tok_s']:.1f} tok/s "
              f"({c['tok_s'] / s['tok_s']:.2f}x), occupancy "
              f"{c['occupancy']:.2f} vs {s['occupancy']:.2f}, "
              f"decode rounds {c['rounds']} vs {s['rounds']}")


if __name__ == "__main__":
    main()
