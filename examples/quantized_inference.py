"""The paper's quantization scheme (Eq. 4 / Algorithm 1) applied at LM
scale: quantize a small transformer's matmul weights to int8 with
power-of-two scales, run the shift-requantized integer matmuls via the
Pallas matmul_q8 kernel path, and compare next-token agreement vs float.

Run:  PYTHONPATH=src python examples/quantized_inference.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.quantize import frac_bits_for, quantize
from repro.kernels.ops import matmul
from repro.models import api

cfg = dataclasses.replace(get_config("qwen2-0.5b"), n_layers=2, d_model=128,
                          n_heads=4, n_kv_heads=2, d_ff=256, vocab=512)
key = jax.random.PRNGKey(0)
params = api.init_params(cfg, key)
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 24), 0, cfg.vocab)

# float reference: final hidden states + logits
from repro.models.transformer import forward_hidden, unembed
h = forward_hidden(params, toks, cfg, remat="none")
logits_f = unembed(params, h, cfg)

# int8 path for the biggest matmul: the unembedding (d_model x vocab)
w = params["embed"].T                                  # tied unembed
wq = quantize(w)
hq = quantize(h)
acc_fb = hq.frac_bits + wq.frac_bits
out_fb = frac_bits_for(logits_f)
q_logits = matmul(hq.q.reshape(-1, hq.q.shape[-1]), wq.q,
                  requant_shift=acc_fb - out_fb, method="pallas")
q_logits = q_logits.reshape(logits_f.shape).astype(jnp.float32) * 2.0 ** -out_fb

top1_f = jnp.argmax(logits_f[:, -1], -1)
top1_q = jnp.argmax(q_logits[:, -1], -1)
agree = float(jnp.mean((top1_f == top1_q).astype(jnp.float32)))
rel = float(jnp.mean(jnp.abs(q_logits - logits_f)) /
            jnp.mean(jnp.abs(logits_f)))
print(f"int8 pow2 unembed: top-1 agreement {agree:.2f}, rel L1 {rel:.3f}")
print("(full-layer integer inference is exercised in examples/train_cnn.py"
      " --primitive ... via quantize_cnn)")
