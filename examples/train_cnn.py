"""End-to-end driver: train a CNN built from a selectable paper primitive
for a few hundred steps on the synthetic image pipeline, with the full
production substrate — AdamW, cosine schedule, async checkpointing,
preemption-safe resume, NaN guard — then post-training-quantize it to the
integer-only path and compare accuracy (the paper's deployment flow).

Run:  PYTHONPATH=src python examples/train_cnn.py --primitive shift --steps 300
      PYTHONPATH=src python examples/train_cnn.py --primitive add --steps 150
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.data import DataConfig, IndexedDataset
from repro.models.convnet import CNNConfig, cnn_forward, cnn_loss, init_cnn, quantize_cnn
from repro.optim import OptConfig, apply_updates, init_opt_state
from repro.checkpoint import Checkpointer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--primitive", default="standard",
                    choices=["standard", "grouped", "dws", "shift", "add"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_cnn_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = CNNConfig(primitive=args.primitive, widths=(16, 32, 64))
    dcfg = DataConfig(kind="image", global_batch=args.batch, image_size=32,
                      num_classes=10, seed=7)
    ds = IndexedDataset(dcfg)
    opt = OptConfig(lr=2e-3, warmup_steps=20, total_steps=args.steps,
                    weight_decay=1e-4, grad_clip=1.0)
    ckpt = Checkpointer(args.ckpt_dir, keep=2)

    params = init_cnn(cfg, jax.random.PRNGKey(0))
    state = init_opt_state(params, opt)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        tree, start = ckpt.restore({"params": params, "opt": state})
        params, state = tree["params"], tree["opt"]
        print(f"resumed from step {start}")

    @jax.jit
    def step_fn(params, state, batch):
        (loss, acc), grads = jax.value_and_grad(
            lambda p: cnn_loss(p, batch, cfg), has_aux=True,
            allow_int=True)(params)
        params, state, om = apply_updates(params, grads, state, opt)
        return params, state, {"loss": loss, "acc": acc, **om}

    t0 = time.perf_counter()
    skipped = 0
    for i in range(start, args.steps):
        batch = jax.tree_util.tree_map(jnp.asarray, ds.batch(i))
        new_params, new_state, m = step_fn(params, state, batch)
        if not bool(jnp.isfinite(m["loss"])):
            skipped += 1                      # NaN guard: reject the step
        else:
            params, state = new_params, new_state
        if (i + 1) % 50 == 0:
            ckpt.save(i + 1, {"params": params, "opt": state})
            print(f"step {i+1:4d} loss {float(m['loss']):.4f} "
                  f"acc {float(m['acc']):.3f} ({time.perf_counter()-t0:.0f}s)",
                  flush=True)
    ckpt.wait()

    # ---- evaluation: float vs integer-only (paper PTQ flow) --------------
    from repro.models.convnet import calibrate_bn
    test = jax.tree_util.tree_map(jnp.asarray, ds.batch(10_000))
    calib = jnp.asarray(ds.batch(20_000)["images"])
    params = calibrate_bn(params, cfg, calib)   # deployment BN re-estimation
    logits_f = cnn_forward(params, test["images"], cfg)
    acc_f = float(jnp.mean((jnp.argmax(logits_f, -1) == test["labels"])))
    int_fwd = quantize_cnn(params, cfg, calib)
    logits_q = int_fwd(test["images"])
    acc_q = float(jnp.mean((jnp.argmax(logits_q, -1) == test["labels"])))
    print(f"\nprimitive={args.primitive}  float acc={acc_f:.3f}  "
          f"int8-pow2 acc={acc_q:.3f}  drop={acc_f-acc_q:+.3f}  "
          f"nan_skipped={skipped}")


if __name__ == "__main__":
    main()
