"""Quickstart: the paper's five convolution primitives in 60 seconds.

Builds one layer of each primitive, compares float vs integer-only
(power-of-two int8, Algorithm 1) outputs, folds BN, and prints the Table-1
cost model next to measured CPU latency.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import (ConvSpec, Primitives, apply, frac_bits_for, init,
                        quantize)
from repro.core.qconv import qconv_apply, quantize_conv_params

key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (1, 32, 32, 16)) * 0.5

print(f"{'primitive':10s} {'params':>8s} {'MACs':>10s} {'lat_us':>9s} "
      f"{'int8 rel-err':>12s}")
for prim in Primitives:
    spec = ConvSpec(primitive=prim, in_channels=16, out_channels=16,
                    kernel_size=3, groups=2 if prim == "grouped" else 1)
    params = init(key, spec)
    fwd = jax.jit(lambda p, a, s=spec: apply(p, a, s))
    y = fwd(params, x)
    jax.block_until_ready(y)
    t0 = time.perf_counter()
    for _ in range(10):
        y = fwd(params, x)
    jax.block_until_ready(y)
    us = (time.perf_counter() - t0) / 10 * 1e6

    yq = qconv_apply(quantize_conv_params(params, spec), quantize(x), spec,
                     frac_bits_for(y))
    rel = float(jnp.mean(jnp.abs(yq.dequantize() - y))
                / jnp.mean(jnp.abs(y)))
    print(f"{prim:10s} {spec.param_count():8d} {spec.mac_count(32):10d} "
          f"{us:9.1f} {rel:12.4f}")

print("\nAll five primitives: float path + integer-only Algorithm-1 path OK.")
