"""LM pretraining driver on the full production substrate: any --arch from
the registry at any --scale, with the fault-tolerant Trainer (async
checkpoints, resume, NaN guard, straggler monitor) on the deterministic
synthetic token pipeline.

The default --scale tiny fits a CPU smoke run; --scale 100m instantiates a
~100M-param model (the e2e deliverable size; a few hundred steps on real
hardware — on this CPU container use --steps 5..20 to see loss descend).

Run:  PYTHONPATH=src python examples/train_lm.py --arch qwen2-0.5b \\
          --scale tiny --steps 60
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.data import DataConfig, IndexedDataset
from repro.models import api
from repro.optim import OptConfig
from repro.train import LoopConfig, TrainConfig, Trainer

SCALES = {
    # (n_layers, d_model, n_heads, n_kv, d_ff, vocab, seq)
    "tiny": (2, 64, 4, 2, 128, 512, 64),
    "10m": (4, 256, 8, 4, 1024, 4096, 256),
    "100m": (12, 768, 12, 4, 3072, 16384, 512),
}


def scaled_cfg(arch: str, scale: str):
    cfg = get_config(arch)
    L, d, h, kv, ff, v, seq = SCALES[scale]
    kw = dict(n_layers=L, d_model=d, n_heads=h, n_kv_heads=kv, d_ff=ff,
              vocab=v)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, num_experts=8,
                                        top_k=min(cfg.moe.top_k, 2), d_ff=ff // 4)
    if cfg.family == "hybrid":
        kw.update(n_layers=max(L, 8) // 8 * 8, attn_period=8, attn_offset=4)
    if cfg.family == "encdec":
        kw["n_encoder_layers"] = L
    if cfg.family == "vlm":
        kw["frontend_positions"] = 8
    return dataclasses.replace(cfg, **kw), seq


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--scale", default="tiny", choices=list(SCALES))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg, seq = scaled_cfg(args.arch, args.scale)
    n = cfg.param_count()
    print(f"arch={args.arch} scale={args.scale}: {n/1e6:.1f}M params, "
          f"seq={seq}, batch={args.batch}")

    kind = {"vlm": "vlm", "encdec": "encdec"}.get(cfg.family, "lm")
    dcfg = DataConfig(kind=kind, vocab=cfg.vocab, seq_len=seq,
                      global_batch=args.batch, seed=11, d_model=cfg.d_model,
                      frontend_positions=cfg.frontend_positions)
    ds = IndexedDataset(dcfg)
    tcfg = TrainConfig(
        opt=OptConfig(lr=3e-4, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps),
        remat="full", attn_impl="full", microbatches=args.microbatches)
    loop = LoopConfig(total_steps=args.steps, ckpt_every=max(args.steps // 3, 1),
                      ckpt_dir=args.ckpt_dir, log_every=10)
    tr = Trainer(cfg, tcfg, loop, ds,
                 init_params_fn=lambda k: api.init_params(cfg, k))
    tr.install_preemption_handler()
    _, _, step, hist = tr.run()
    first = [h["loss"] for h in hist[:5]]
    last = [h["loss"] for h in hist[-5:]]
    print(f"\ndone at step {step}: loss {sum(first)/len(first):.3f} -> "
          f"{sum(last)/len(last):.3f}; stragglers={tr.monitor.stragglers} "
          f"skipped={tr.skipped}")


if __name__ == "__main__":
    main()
