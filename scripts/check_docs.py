"""Docs link-checker: the CI docs job fails on any dangling reference.

Checks two classes of intra-repo references:

1. Markdown links in every tracked ``*.md``: ``[text](target)`` where the
   target is a repo-relative path (http/mailto links are skipped). The file
   must exist; if the link carries a ``#anchor``, some heading of the target
   file must slugify to it (GitHub-style: lowercase, punctuation stripped,
   spaces -> dashes).
2. ``EXPERIMENTS.md §<Section>`` citations anywhere in the repo's Python
   sources and markdown (the contract that ``core/energy.py``,
   ``optim/compression.py``, ``scripts/report.py`` and
   ``scripts/hillclimb.py`` rely on): EXPERIMENTS.md must contain a heading
   carrying that literal ``§<Section>`` anchor.

Run:  python scripts/check_docs.py        (exits 1 listing dangling refs)
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.M)
SECTION_CITE = re.compile(r"EXPERIMENTS\.md\s+§([\w-]+)")
SKIP_DIRS = {".git", "__pycache__", ".github", "artifacts", ".claude"}


def walk(exts):
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for f in filenames:
            if os.path.splitext(f)[1] in exts:
                yield os.path.join(dirpath, f)


def strip_fences(text: str) -> str:
    """Drop fenced code blocks: example links in snippets are not real
    references, and '# comment' lines in bash blocks are not headings."""
    return re.sub(r"^```.*?^```", "", text, flags=re.S | re.M)


def slugify(heading: str) -> str:
    """GitHub-flavored anchor slug (ASCII subset: drop non-alnum, keep -_)."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s, flags=re.A)
    return s.replace(" ", "-")


def headings_of(md_path: str):
    with open(md_path, encoding="utf-8") as fh:
        return HEADING.findall(strip_fences(fh.read()))


def check_markdown_links() -> list:
    errors = []
    for path in walk({".md"}):
        rel = os.path.relpath(path, ROOT)
        text = strip_fences(open(path, encoding="utf-8").read())
        for target in MD_LINK.findall(text):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:, …
                continue
            frag = ""
            if "#" in target:
                target, frag = target.split("#", 1)
            tgt_path = path if not target else os.path.normpath(
                os.path.join(os.path.dirname(path), target))
            if not os.path.exists(tgt_path):
                errors.append(f"{rel}: broken link -> {target or '#' + frag}")
                continue
            if frag and os.path.splitext(tgt_path)[1] == ".md":
                slugs = {slugify(h) for h in headings_of(tgt_path)}
                if frag.lower() not in slugs:
                    errors.append(
                        f"{rel}: dangling anchor -> "
                        f"{os.path.relpath(tgt_path, ROOT)}#{frag}")
    return errors


# Anchors the harness/doc contract depends on even when no source line
# happens to cite them at check time (e.g. §Per-layer backs
# benchmarks/layer_bench.py's section of the benchmark book).
REQUIRED_SECTIONS = ("Roofline", "Perf", "Dry-run", "Serving", "Paged-KV",
                     "Quantized", "Sub-byte", "Per-layer", "Throughput",
                     "Observability", "Static-checks", "Resilience")


def check_section_citations() -> list:
    exp_path = os.path.join(ROOT, "EXPERIMENTS.md")
    if not os.path.exists(exp_path):
        return ["EXPERIMENTS.md is missing (cited from source docstrings)"]
    anchors = set()
    for h in headings_of(exp_path):
        anchors.update(re.findall(r"§([\w-]+)", h))
    errors = [f"EXPERIMENTS.md: required §{s} heading is missing"
              for s in REQUIRED_SECTIONS if s not in anchors]
    for path in walk({".py", ".md"}):
        if os.path.samefile(path, exp_path):
            continue
        rel = os.path.relpath(path, ROOT)
        text = open(path, encoding="utf-8").read()
        if path.endswith(".md"):
            text = strip_fences(text)
        for m in SECTION_CITE.finditer(text):
            if m.group(1) not in anchors:
                errors.append(f"{rel}: cites EXPERIMENTS.md §{m.group(1)} "
                              f"but EXPERIMENTS.md has no such § heading")
    return errors


def main() -> int:
    errors = check_markdown_links() + check_section_citations()
    for e in errors:
        print(f"DANGLING: {e}")
    print(f"check_docs: {len(errors)} dangling reference(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
